"""Executing backends: eager JAX (XLA) and the Pallas fused kernel.

Both wrap :class:`~repro.core.engine.AsyncMatmulEngine` — dispatch stages
a thunk, wait forces it — and differ only in which ``cute_matmul`` route
the thunk takes.  ``run_graph`` walks a TaskGraph through
``execute_graph_jax`` (single GEMM, fused epilogues applied at the
graph's granularity) or ``execute_workload_jax`` (multi-GEMM schedule
graphs, one ``(a, b)`` pair per GEMM label).
"""

from __future__ import annotations

from typing import Callable

from repro.backend.base import (Backend, ExecResult, GraphOperands,
                                MatMulOperands, NO_MATMUL_OPERANDS)
from repro.backend.registry import register
from repro.core.engine import AsyncMatmulEngine
from repro.core.fusion import Epilogue
from repro.core.task import MatMulTask
from repro.obs import instrument


class _EagerBackend(Backend):
    """Shared dispatch/run_graph plumbing for the executing backends."""

    executes = True
    matmul_string = "xla"          # the cute_matmul(backend=...) route

    def __init__(self, **kw):
        super().__init__(**kw)
        self._engine = AsyncMatmulEngine(unit=self.unit,
                                         backend=self.matmul_string)

    def _stage(self, task: MatMulTask, operands: MatMulOperands,
               epilogue: Epilogue) -> Callable[[], ExecResult]:
        if not operands.concrete:
            raise ValueError(
                f"backend {self.name!r} executes numbers: dispatch needs "
                "MatMulOperands(a=..., b=...)")
        h = self._engine.dispatch(task, operands.a, operands.b,
                                  epilogue=epilogue,
                                  operands=operands.epilogue)
        return lambda: ExecResult(output=h.force())

    @instrument("run_graph")
    def run_graph(self, graph, operands: GraphOperands = None) -> ExecResult:
        from repro.sim.lower import execute_graph_jax, execute_workload_jax
        engine = self._engine
        if isinstance(operands, dict):
            outs = execute_workload_jax(graph, operands, engine=engine)
            return ExecResult(outputs=outs)
        ops = operands or NO_MATMUL_OPERANDS
        if not ops.concrete:
            raise ValueError(
                f"backend {self.name!r} needs concrete operands: pass "
                "MatMulOperands(a, b) or a {gemm label: (a, b)} dict")
        out = execute_graph_jax(graph, ops.a, ops.b, operands=ops.epilogue,
                                engine=engine)
        return ExecResult(output=out)


@register("jax")
class JaxBackend(_EagerBackend):
    """Eager execution through einsum + fused-consumer epilogue (XLA)."""

    matmul_string = "xla"


@register("pallas")
class PallasBackend(_EagerBackend):
    """Execution through the ``kernels/matmul`` fused Pallas kernel
    (grid-pipelined MXU/VPU overlap on TPU; interpret mode on CPU)."""

    matmul_string = "pallas"

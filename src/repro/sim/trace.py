"""Chrome-trace (Trace Event Format) export of DESim timelines.

The emitted JSON loads directly in Perfetto (https://ui.perfetto.dev)
or chrome://tracing: one row per machine resource, one complete ("X")
event per busy interval, timestamps in microseconds of simulated time.
"""

from __future__ import annotations

import json

from repro.sim.desim import DESimResult

#: stable row order in the viewer, dispatcher (the cause) on top.
_RESOURCE_ORDER = ("dispatcher", "mem_loader", "scratchpad", "pe_array",
                   "vector_unit")


def chrome_trace(result: DESimResult, *, process_name: str = "cutev2-desim",
                 ) -> dict:
    """Trace Event Format dict: ``{"traceEvents": [...], ...}``."""
    us_per_cycle = 1e6 / result.freq_hz
    events = []
    names = [r for r in _RESOURCE_ORDER if r in result.intervals]
    names += [r for r in result.intervals if r not in names]
    events.append({"name": "process_name", "ph": "M", "pid": 0,
                   "args": {"name": process_name}})
    for tid, rname in enumerate(names):
        events.append({"name": "thread_name", "ph": "M", "pid": 0,
                       "tid": tid, "args": {"name": rname}})
        for start, end, label in result.intervals[rname]:
            events.append({
                "name": label, "cat": rname, "ph": "X", "pid": 0, "tid": tid,
                "ts": start * us_per_cycle,
                "dur": max(end - start, 0.0) * us_per_cycle,
            })
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "total_cycles": result.cycles,
            "matrix_utilization": result.matrix_utilization,
            "resource_utilization": result.utilizations(),
        },
    }


def dump_chrome_trace(result: DESimResult, path: str, **kw) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(result, **kw), f)
    return path

import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ must precede any jax import (same contract as dryrun.py).

"""§Perf hillclimb driver: hypothesis → change → re-lower → measure.

Each experiment names a (cell, overrides, rules, tcfg-delta) tuple with
an explicit hypothesis; results land in tagged result dirs next to the
baselines and are summarised as before/after on the dominant term.

    PYTHONPATH=src python -m repro.launch.perf_iter [--only NAME]
"""

import argparse
import dataclasses
import json

from repro.launch import dryrun
from repro.training.train_step import TrainConfig


def _tc(microbatches=None, **kw):
    base = TrainConfig(**kw)
    if microbatches is not None:
        base = dataclasses.replace(base, microbatches=microbatches)
    return base


EXPERIMENTS = [
    # ---- deepseek-67b x train_4k (paper-representative dense train) -----
    dict(name="ds_pv_bf16", arch="deepseek-67b", shape="train_4k",
         overrides={"attn_pv_bf16": True},
         hypothesis="memory term is dominated by fp32 attention transients"
                    " (P and PV blocks); bf16 P*V halves them -> memory"
                    " bytes down ~15-25%"),
    dict(name="ds_remat_dots", arch="deepseek-67b", shape="train_4k",
         overrides={"remat": "dots"},
         hypothesis="full remat recomputes every forward dot in backward;"
                    " saving dot outputs cuts HLO FLOPs ~25% (MODEL/HLO"
                    " 0.73 -> ~0.95) at higher activation residency"),
    dict(name="ds_mb8", arch="deepseek-67b", shape="train_4k",
         tcfg=_tc(microbatches=8),
         hypothesis="FSDP re-gathers every weight once per microbatch;"
                    " halving microbatches halves gather traffic ->"
                    " collective ~-50%, temp ~+2x carry"),
    dict(name="ds_combo", arch="deepseek-67b", shape="train_4k",
         overrides={"attn_pv_bf16": True, "remat": "dots"},
         tcfg=_tc(microbatches=8),
         hypothesis="combined: compute -25%, memory -25%, collective -50%"),

    # ---- gemma2-2b x train_4k (worst improvable roofline fraction) ------
    dict(name="g2_onehot_ce", arch="gemma2-2b", shape="train_4k",
         tcfg=_tc(ce_onehot_pick=True),
         hypothesis="take_along_axis over the vocab-sharded 256k logits"
                    " forces an unsharded materialisation; one-hot"
                    " contraction keeps logits sharded -> memory down"),
    dict(name="g2_pv_bf16", arch="gemma2-2b", shape="train_4k",
         overrides={"attn_pv_bf16": True},
         hypothesis="as ds_pv_bf16 (8 heads unshardable on model=16 =>"
                    " attention transients are 16x replicated: bigger win)"),
    dict(name="g2_remat_dots", arch="gemma2-2b", shape="train_4k",
         overrides={"remat": "dots"},
         hypothesis="MODEL/HLO 0.58 -> ~0.8; compute term -25%"),
    dict(name="g2_combo", arch="gemma2-2b", shape="train_4k",
         overrides={"attn_pv_bf16": True, "remat": "dots"},
         tcfg=_tc(ce_onehot_pick=True),
         hypothesis="combined memory-term reduction > 35%"),

    # ---- round 2 (informed by round-1 refutations) -----------------------
    dict(name="ds_chunk2048", arch="deepseek-67b", shape="train_4k",
         overrides={"attn_chunk": 2048},
         hypothesis="halving the number of attention chunk-scan steps"
                    " halves the per-step carry copies and scan overhead"
                    " buffers -> memory term down ~5-10%"),
    dict(name="ds_gradcomp", arch="deepseek-67b", shape="train_4k",
         tcfg=_tc(grad_compression=True),
         hypothesis="int8 error-feedback gradient compression cuts the"
                    " fp32 grad reduce-scatter bytes 4x -> collective"
                    " term down ~30-50%"),
    dict(name="g2_seq_parallel", arch="gemma2-2b", shape="train_4k",
         rules={"seq": "model"},
         hypothesis="Megatron-style sequence parallelism: shard the"
                    " residual stream's seq dim over the idle model axis"
                    " between attention/MLP -> elementwise+norm traffic"
                    " /16 -> memory term down"),

    # ---- arctic-480b x decode_32k (most collective-bound) ---------------
    dict(name="ar_gspmd_ep", arch="arctic-480b", shape="decode_32k",
         overrides={"moe_shard_map": False},
         rules={"experts": "data", "mlp_expert": "model", "embed": None},
         hypothesis="collective term = FSDP re-gather of ~3.7 GB/chip of"
                    " expert weights per decoded token; owning experts"
                    " fully on (data x model) shards removes the gather"
                    " -> collective down >10x"),
    dict(name="ar_kv_fp8", arch="arctic-480b", shape="decode_32k",
         overrides={"kv_cache_dtype": "fp8"},
         hypothesis="32k KV cache reads halve with fp8 storage ->"
                    " memory term down ~2x on the cache component"),
    dict(name="ar_combo", arch="arctic-480b", shape="decode_32k",
         overrides={"moe_shard_map": False, "kv_cache_dtype": "fp8"},
         rules={"experts": "data", "mlp_expert": "model", "embed": None},
         hypothesis="both: step bound moves to dense-weight reads"),
]


def _resolve_overrides(ov):
    if not ov:
        return {}
    out = dict(ov)
    if out.get("kv_cache_dtype") == "fp8":
        import jax.numpy as jnp
        out["kv_cache_dtype"] = jnp.float8_e4m3fn
    return out


def run_experiment(exp, force=False):
    base = dryrun.run_cell(exp["arch"], exp["shape"], "single")
    res = dryrun.run_cell(
        exp["arch"], exp["shape"], "single", force=force,
        rules=exp.get("rules"),
        overrides=_resolve_overrides(exp.get("overrides")),
        tcfg=exp.get("tcfg"), tag="_" + exp["name"])
    b, a = base["roofline"], res["roofline"]

    def fmt(r, m):
        return (f"compute={r['compute_s']:.3g}s memory={r['memory_s']:.3g}s "
                f"collective={r['collective_s']:.3g}s "
                f"dom={r['dominant']} frac={r['roofline_fraction']:.3f} "
                f"useful={r['useful_flops_ratio']:.2f} "
                f"temp={m['temp_bytes'] / 2**30:.1f}GiB")

    bound_b = max(b["compute_s"], b["memory_s"], b["collective_s"])
    bound_a = max(a["compute_s"], a["memory_s"], a["collective_s"])
    print(f"\n=== {exp['name']} ({exp['arch']} x {exp['shape']}) ===")
    print("hypothesis:", exp["hypothesis"])
    print("before:", fmt(b, base["memory"]))
    print("after: ", fmt(a, res["memory"]))
    print(f"bound: {bound_b:.3g}s -> {bound_a:.3g}s "
          f"({bound_b / max(bound_a, 1e-12):.2f}x) | frac "
          f"{b['roofline_fraction']:.3f} -> {a['roofline_fraction']:.3f}")
    return {"name": exp["name"], "before": b, "after": a,
            "speedup": bound_b / max(bound_a, 1e-12)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    results = []
    for exp in EXPERIMENTS:
        if args.only and exp["name"] != args.only:
            continue
        results.append(run_experiment(exp, force=args.force))
    out = os.path.join(dryrun.RESULTS_DIR, "..", "perf_iterations.json")
    existing = []
    if os.path.exists(out) and args.only:
        with open(out) as f:
            existing = [r for r in json.load(f)
                        if r["name"] not in {x["name"] for x in results}]
    with open(out, "w") as f:
        json.dump(existing + results, f, indent=1)
    print(f"\nwrote {len(results)} results")


if __name__ == "__main__":
    main()

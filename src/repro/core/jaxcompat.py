"""Shims over jax API churn so one codebase spans 0.4.x and newer.

``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax`` and
renamed its replication-check kwarg (``check_rep`` -> ``check_vma``);
this wrapper accepts either spelling and translates to whatever the
installed jax understands.  Mesh-construction shims live in
``repro.launch.mesh`` (``compat_make_mesh`` / ``compat_abstract_mesh``).
"""

from __future__ import annotations

import functools
import inspect

try:
    from jax import shard_map as _shard_map
except ImportError:  # older jax exposes it under experimental
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f=None, **kw):
    for ours, theirs in (("check_vma", "check_rep"),
                         ("check_rep", "check_vma")):
        if ours in kw and ours not in _SHARD_MAP_PARAMS \
                and theirs in _SHARD_MAP_PARAMS:
            kw[theirs] = kw.pop(ours)
    if f is None:
        return functools.partial(shard_map, **kw)
    return _shard_map(f, **kw)

"""Serving launcher: batched generation over the async engine.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \\
        --requests 6 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ALL_ARCHS, get_config
from repro.models.base import family_module
from repro.serving.engine import ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS, default="yi-6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    if args.reduced:
        cfg = cfg.with_(dtype=jnp.float32, remat="none",
                        kv_cache_dtype=jnp.float32)
    mod = family_module(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))

    eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                        cache_len=256)
    key = jax.random.PRNGKey(1)
    for i in range(args.requests):
        n = 4 + (i * 3) % 12
        key, sub = jax.random.split(key)
        eng.submit(jax.random.randint(sub, (n,), 0, cfg.vocab_size))
    t0 = time.perf_counter()
    outs = eng.run(max_new_tokens=args.max_new,
                   temperature=args.temperature)
    dt = time.perf_counter() - t0
    tok = sum(int(o.shape[0]) for o in outs)
    print(f"served {len(outs)} requests, {tok} tokens "
          f"in {dt:.2f}s ({tok / dt:.1f} tok/s)")
    for i, o in enumerate(outs):
        print(f"  req{i}: {list(map(int, o))}")


if __name__ == "__main__":
    main()

"""TaskGraph partitioner: shard matmul work across cluster units.

``partition_graph`` rewrites a (single- or multi-GEMM) TaskGraph so
every node carries a ``unit`` placement and every producer→consumer edge
that crosses units goes through an explicit **transfer node** — a
``memory`` node occupying the shared loader for the producer's output
bytes.  Three strategies, the classic GEMM-sharding axes:

* ``row-panel`` — contiguous blocks of M row-panels per unit.  Each unit
  owns full output rows, so per-panel epilogues stay unit-local; the
  cluster mirror of Megatron row parallelism (and of
  ``distributed.collective_matmul``'s X-sharding).
* ``output-tile`` — contiguous blocks of N tile-columns per unit.  Each
  unit owns full output columns (B sharded, A replicated); GLU/full-N
  epilogues force gather transfers.
* ``layer-pipeline`` — whole GEMMs round-robin across units; inter-layer
  activations cross units as transfers, the pipeline-parallel layout.
* ``unit-affinity`` — whole GEMMs placed by a serving policy's
  per-request affinity hints (``affinity={layer or GEMM label: unit}``),
  with unhinted GEMMs balanced greedily onto the least-loaded unit
  under per-unit ``weights`` (relative throughput — heterogeneous
  clusters want MACs routed in proportion to PE width, not round-robin).
  The co-optimisation seam between ``serving.scheduler`` batching
  policies and shard placement.

Why transfers are charged the way they are: in this machine model every
tile load/writeback already moves through shared DRAM, so a same-unit
dependent pays nothing extra (the data is conceptually still warm in the
unit's scratchpad/L2).  A *cross-unit* dependent, however, must wait for
the producer's bytes to actually land in shared memory and be re-read —
the DES's fire-and-forget writeback no longer hides it.  The transfer
node makes that synchronisation explicit and puts its bytes on the
shared loader, which is exactly the contention term multi-unit studies
(CAMP, arXiv 2504.08137) identify.

The *same* partitioned graph is consumed by ``sim.desim
.simulate_cluster`` (contended timelines) and by the ``sharded`` backend
(``shard_map`` execution over a ``units`` mesh axis, int8 bit-exact
against the ``jax`` backend) — the paper's unified-stack claim at
cluster scale.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.sim.graph import Node, TaskGraph

STRATEGIES = ("row-panel", "output-tile", "layer-pipeline",
              "unit-affinity")

#: strategy -> GEMM dimension it shards (None: whole GEMMs per unit).
#: The simulation and execution halves must agree on this axis.
STRATEGY_DIM = {"row-panel": "m", "output-tile": "n",
                "layer-pipeline": None, "unit-affinity": None}

#: accumulator bytes per output element (resident C is fp32/int32).
ACC_BYTES = 4.0


@dataclasses.dataclass
class Partition:
    """A partitioned graph plus the metadata execution backends need."""

    graph: TaskGraph
    n_units: int
    strategy: str
    #: new-graph nid -> unit (matches ``Node.unit``; kept for reporting)
    assignment: "dict[int, int]"
    #: row-panel/output-tile: gemm label -> per-unit (lo, hi) extents
    #: along the sharded dim (M rows or N cols); None for idle units.
    spans: "dict[str, list[Optional[tuple[int, int]]]]"
    #: layer-pipeline: gemm label -> owning unit.
    unit_of_label: "dict[str, int]"
    n_transfers: int
    transfer_bytes: float

    @property
    def shard_dim(self) -> Optional[str]:
        return STRATEGY_DIM[self.strategy]

    def balanced(self, label: str) -> bool:
        """True when every unit owns an equally-sized contiguous span of
        ``label`` — the precondition for one ``shard_map`` over the
        whole GEMM (otherwise execution falls back to per-unit slices)."""
        spans = self.spans.get(label)
        if not spans or any(s is None for s in spans):
            return False
        sizes = {hi - lo for lo, hi in spans}
        return len(sizes) == 1


def _matmul_area(graph: TaskGraph, node: Node) -> float:
    """Output elements a node produces (transitively, through memory
    nodes, for vector regions)."""
    if node.kind == "matmul":
        return float(node.tile.m * node.tile.n) if node.tile else \
            float(node.task.m * node.task.n)
    area = 0.0
    for d in node.deps:
        area += _matmul_area(graph, graph.nodes[d])
    return area


def _affinity_placement(label_order: "list[str]",
                        by_label: "dict[str, list[Node]]",
                        n_units: int,
                        affinity: "dict[str, int] | None",
                        weights: "list[float] | None",
                        ) -> "dict[str, int]":
    """Whole-GEMM placement for ``unit-affinity``: honour hints first,
    then greedily put each unhinted GEMM on the unit with the lowest
    *normalised* load (cumulative MACs / throughput weight)."""
    affinity = affinity or {}
    if weights is None:
        weights = [1.0] * n_units
    if len(weights) != n_units or any(w <= 0 for w in weights):
        raise ValueError(
            f"weights must be {n_units} positive per-unit throughputs; "
            f"got {weights}")
    load = [0.0] * n_units

    def hint_for(lbl: str):
        # a hint may name the GEMM label ("step/g2") or its whole
        # layer/step ("step" — what a serving policy emits per step).
        if lbl in affinity:
            return affinity[lbl]
        head = lbl.rsplit("/g", 1)[0]
        return affinity.get(head)

    placement: "dict[str, int]" = {}
    for lbl in label_order:
        macs = sum(t.task.macs for t in by_label[lbl])
        hint = hint_for(lbl)
        if hint is not None:
            if not 0 <= hint < n_units:
                raise ValueError(
                    f"affinity hint {hint} for {lbl!r} out of range for "
                    f"{n_units} unit(s)")
            u = hint
        else:
            u = min(range(n_units),
                    key=lambda i: ((load[i] + macs) / weights[i], i))
        placement[lbl] = u
        load[u] += macs
    return placement


def partition_graph(graph: TaskGraph, n_units: int,
                    strategy: str = "row-panel", *,
                    affinity: "dict[str, int] | None" = None,
                    weights: "list[float] | None" = None) -> Partition:
    """Rewrite ``graph`` with per-node unit placements + transfer nodes.

    ``n_units == 1`` returns a copy with everything on unit 0 and no
    transfers (the degenerate cluster).  ``affinity``/``weights`` feed
    the ``unit-affinity`` strategy (and are ignored by the others):
    per-label placement hints from a serving policy, and relative
    per-unit throughputs for balancing the rest.
    """
    if strategy not in STRATEGIES:
        raise ValueError(
            f"unknown partition strategy {strategy!r}; one of {STRATEGIES}")
    if n_units < 1:
        raise ValueError(f"n_units must be >= 1, got {n_units}")

    nodes = graph.topo_order()
    # Per-GEMM geometry for the spatial strategies.
    by_label: "dict[str, list[Node]]" = {}
    for n in nodes:
        if n.kind == "matmul":
            by_label.setdefault(n.layer, []).append(n)
    label_order = list(by_label)
    if strategy == "unit-affinity":
        unit_of_label = _affinity_placement(label_order, by_label, n_units,
                                            affinity, weights)
    else:
        unit_of_label = {lbl: i % n_units
                         for i, lbl in enumerate(label_order)}

    panel_unit: "dict[str, dict[int, int]]" = {}   # label -> {m0/n0 -> unit}
    spans: "dict[str, list[Optional[tuple[int, int]]]]" = {}
    if strategy in ("row-panel", "output-tile"):
        for lbl, tiles in by_label.items():
            key = (lambda t: t.tile.m0) if strategy == "row-panel" \
                else (lambda t: t.tile.n0)
            ext = (lambda t: t.tile.m) if strategy == "row-panel" \
                else (lambda t: t.tile.n)
            starts = sorted({key(t) for t in tiles})
            n_panels = len(starts)
            panel_unit[lbl] = {
                s: min(i * n_units // n_panels, n_units - 1)
                for i, s in enumerate(starts)}
            per_unit: "list[Optional[tuple[int, int]]]" = [None] * n_units
            for t in tiles:
                u = panel_unit[lbl][key(t)]
                lo, hi = key(t), key(t) + ext(t)
                cur = per_unit[u]
                per_unit[u] = (lo, hi) if cur is None else \
                    (min(cur[0], lo), max(cur[1], hi))
            spans[lbl] = per_unit

    def assign(node: Node) -> int:
        if STRATEGY_DIM[strategy] is None:     # whole-GEMM placements
            return unit_of_label[node.layer]
        key = node.tile.m0 if strategy == "row-panel" else node.tile.n0
        return panel_unit[node.layer][key]

    out = TaskGraph()
    remap: "dict[int, int]" = {}
    unit_of: "dict[int, int]" = {}        # new nid -> unit
    xfers: "dict[tuple[int, int], int]" = {}   # (old nid, unit) -> new nid
    n_transfers = 0
    transfer_bytes = 0.0

    def dep_for(old_dep: int, consumer_unit: int) -> int:
        nonlocal n_transfers, transfer_bytes
        prod = graph.nodes[old_dep]
        new_dep = remap[old_dep]
        if prod.kind == "memory" or unit_of[new_dep] == consumer_unit:
            # memory nodes already live in shared DRAM — no extra hop.
            return new_dep
        key = (old_dep, consumer_unit)
        if key not in xfers:
            nbytes = _matmul_area(graph, prod) * ACC_BYTES
            t = out.add("memory",
                        f"{prod.name}/xfer@u{consumer_unit}",
                        deps=(new_dep,), layer=prod.layer,
                        unit=consumer_unit, mem_bytes=nbytes)
            unit_of[t.nid] = consumer_unit
            xfers[key] = t.nid
            n_transfers += 1
            transfer_bytes += nbytes
        return xfers[key]

    for node in nodes:
        if node.kind == "matmul":
            u = assign(node)
        elif node.deps:
            # vector/memory nodes co-locate with their first producer
            # (ties epilogues to the unit that computed the panel).
            first = remap[node.deps[0]]
            u = unit_of[first]
        else:
            u = 0
        deps = tuple(dep_for(d, u) for d in node.deps)
        new = out.add(node.kind, node.name, deps=deps, layer=node.layer,
                      unit=u, task=node.task, tile=node.tile,
                      release_time=node.release_time,
                      vector_ops=dict(node.vector_ops),
                      epilogue=node.epilogue, mem_bytes=node.mem_bytes)
        remap[node.nid] = new.nid
        unit_of[new.nid] = u

    return Partition(graph=out, n_units=n_units, strategy=strategy,
                     assignment=unit_of, spans=spans,
                     unit_of_label=unit_of_label, n_transfers=n_transfers,
                     transfer_bytes=transfer_bytes)

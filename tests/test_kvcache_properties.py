"""Hypothesis property tests of the paged KV allocator.

The randomised twin of ``test_kvcache.py``: arbitrary interleavings of
append / ensure_resident / release must preserve the pool partition, the
no-double-allocation invariant, LRU victim order and trace determinism.
Skipped (like the other hypothesis suites in this repo) when the
optional dependency is absent.
"""

import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serving.kvcache import KVPoolExhausted, PagedKVCache  # noqa: E402

OPS = st.lists(
    st.one_of(
        st.tuples(st.just("append"), st.integers(0, 5), st.integers(1, 6)),
        st.tuples(st.just("ensure"), st.integers(0, 5), st.just(0)),
        st.tuples(st.just("release"), st.integers(0, 5), st.just(0)),
    ),
    min_size=1, max_size=40)


def run(ops, *, hot_blocks=4, block_tokens=2, policy="lru", seed=0):
    c = PagedKVCache(hot_blocks=hot_blocks, block_tokens=block_tokens,
                     policy=policy, seed=seed)
    for i, (kind, rid, n) in enumerate(ops):
        try:
            if kind == "append":
                c.append(rid, n, t=float(i))
            elif kind == "ensure":
                c.ensure_resident(rid, t=float(i))
            else:
                c.release(rid, t=float(i))
        except KVPoolExhausted:
            pass                       # legal outcome, state must stay sane
    return c


@settings(max_examples=60, deadline=None)
@given(ops=OPS, policy=st.sampled_from(("lru", "recompute")))
def test_partition_and_no_double_allocation(ops, policy):
    c = run(ops, policy=policy)
    free, alloc = c.free_slots(), c.allocated_slots()
    assert set(free) | set(alloc) == set(range(c.hot_blocks))
    assert set(free) & set(alloc) == set()
    assert len(alloc) == len(set(alloc))      # no slot owned twice


@settings(max_examples=60, deadline=None)
@given(ops=OPS, seed=st.integers(0, 7))
def test_traces_identical_across_runs(ops, seed):
    a = run(ops, seed=seed)
    b = run(ops, seed=seed)
    assert a.trace == b.trace
    assert a.trace_digest() == b.trace_digest()


@settings(max_examples=60, deadline=None)
@given(ops=OPS)
def test_eviction_times_monotonic(ops):
    """Victims leave in call order — the LRU policy never reorders the
    trace against the logical clock."""
    c = run(ops)
    times = [e[1] for e in c.trace]
    assert times == sorted(times)


@settings(max_examples=60, deadline=None)
@given(ops=OPS, policy=st.sampled_from(("lru", "recompute")))
def test_refill_restores_full_residency(ops, policy):
    c = run(ops, policy=policy)
    for rid in range(6):
        try:
            c.ensure_resident(rid, t=99.0)
        except KVPoolExhausted:
            continue
        assert c.residency(rid) == 1.0
        assert c.refill_bytes(rid) == 0.0

"""AdamW with fp32 master weights, global-norm clip, cosine schedule.

Hand-rolled (no optax in this environment) but production-shaped: the
optimizer state keeps fp32 master parameters alongside the moments so
models can train in bf16 compute precision; ``update`` is pure and
jit/shard-friendly (state shards follow parameter shards).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init(cfg: AdamWConfig, params):
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    # copy=True: fp32 params must not alias the master (donation safety).
    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(zeros32, params),
        "nu": jax.tree.map(zeros32, params),
        "master": jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def update(cfg: AdamWConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def leaf(g, mu, nu, master, p):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        upd = (mu / c1) / (jnp.sqrt(nu / c2) + cfg.eps)
        if master.ndim >= 2:                      # decay matrices only
            upd = upd + cfg.weight_decay * master
        master = master - lr * upd
        return mu, nu, master, master.astype(p.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    flat_ma = treedef.flatten_up_to(state["master"])
    flat_p = treedef.flatten_up_to(params)
    out = [leaf(*args) for args in zip(flat_g, flat_mu, flat_nu, flat_ma,
                                       flat_p)]
    new_state = {
        "step": step,
        "mu": jax.tree.unflatten(treedef, [o[0] for o in out]),
        "nu": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "master": jax.tree.unflatten(treedef, [o[2] for o in out]),
    }
    new_params = jax.tree.unflatten(treedef, [o[3] for o in out])
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}

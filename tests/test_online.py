"""Online closed-loop serving: arrival determinism, admission epochs,
preemption/eviction state carry, SLO-aware planning, saturation.

Pins the contracts ``repro.serving.online`` promises:

* arrival sources are bit-identical under a seed (Mersenne-Twister
  stream, pinned values) and the admission sequence does not depend on
  which backend executes the epochs;
* low-load online TTFT matches the offline plan (same arrivals, DES
  spans on both sides) within 10%;
* a preempted-then-resumed decode stream keeps one monotonic,
  complete ``decode_iter`` chain and a clean ``SpanLog.validate()``;
* ``auto-slo`` picks an SLO-meeting candidate whenever one exists and
  degrades gracefully when none can;
* the pricing cache never aliases schedules that differ only in
  arrival times (release is part of the key);
* every concrete policy shows a goodput saturation knee.
"""

import jax.numpy as jnp
import pytest

from repro.configs.registry import get_config
from repro.obs import disable_metrics, enable_metrics
from repro.serving import scheduler
from repro.serving.arrivals import (DeterministicArrivals, PoissonArrivals,
                                    TraceArrivals, gap_to_qps, qps_to_gap,
                                    write_trace)
from repro.serving.engine import ServingEngine
from repro.serving.online import (OnlineServingEngine, find_saturation,
                                  qps_sweep)
from repro.serving.scheduler import (PolicyContext, _percentile,
                                     select_schedule)


def _cfg():
    return get_config("yi-6b", reduced=True)


def _concrete_policies():
    return [n for n in scheduler.available_policies()
            if not getattr(scheduler.get_policy(n), "meta", False)]


# ---------------------------------------------------------------------------
# Arrival sources — determinism audit (satellite: seeded generators)
# ---------------------------------------------------------------------------

class TestArrivalDeterminism:
    def test_same_seed_bit_identical(self):
        kw = dict(mean_gap=5000.0, n=8, seed=42)
        assert PoissonArrivals(**kw).arrivals() == \
            PoissonArrivals(**kw).arrivals()

    def test_repeated_iteration_identical(self):
        src = PoissonArrivals(mean_gap=5000.0, n=4, seed=1)
        assert tuple(src) == tuple(src) == src.arrivals()

    def test_pinned_poisson_stream(self):
        # random.Random's Mersenne-Twister stream is pinned across
        # platforms and Python versions — these exact floats are the
        # cross-backend determinism contract.
        src = PoissonArrivals(mean_gap=1000.0, n=3, seed=0,
                              prompt_lengths=(8,))
        assert [a.time for a in src] == [1860.6071110652233,
                                         3279.236264036985,
                                         3824.949409578578]

    def test_different_seed_differs(self):
        a = PoissonArrivals(mean_gap=1000.0, n=4, seed=0).arrivals()
        b = PoissonArrivals(mean_gap=1000.0, n=4, seed=1).arrivals()
        assert [x.time for x in a] != [x.time for x in b]

    def test_deterministic_gap_times(self):
        src = DeterministicArrivals(gap=100.0, n=3, prompt_lengths=(7,))
        assert [(a.time, a.prompt_len) for a in src] == \
            [(100.0, 7), (200.0, 7), (300.0, 7)]

    def test_qps_gap_roundtrip(self):
        assert qps_to_gap(20000.0, 2e9) == 100000.0
        assert gap_to_qps(qps_to_gap(12345.0, 2e9), 2e9) == \
            pytest.approx(12345.0)

    def test_admission_sequence_backend_independent(self):
        # Same seed -> identical admission sequence whether epochs
        # execute on the DES or the analytical closed form.
        src = PoissonArrivals(mean_gap=30000.0, n=6, seed=3,
                              prompt_lengths=(16, 32))
        orders = {}
        for be in ("analytical", "desim"):
            eng = OnlineServingEngine(_cfg(), max_batch=2,
                                      max_new_tokens=4,
                                      policy="chunked-prefill",
                                      execute_backend=be)
            res = eng.run(src)
            orders[be] = [rid for e in res.epochs for rid in e.admitted]
        assert orders["analytical"] == orders["desim"]
        assert sorted(orders["desim"]) == list(range(6))


class TestTraceRoundTrip:
    def test_write_then_replay_is_identical(self, tmp_path):
        src = PoissonArrivals(mean_gap=2000.0, n=5, seed=9)
        path = str(tmp_path / "trace.jsonl")
        assert write_trace(path, src) == 5
        replay = TraceArrivals(path).arrivals()
        assert replay == src.arrivals()

    def test_bad_record_reports_line(self, tmp_path):
        path = tmp_path / "bad.jsonl"
        path.write_text('{"time": 1.0, "prompt_len": 4}\n{"time": 2.0}\n')
        with pytest.raises(ValueError, match="bad.jsonl:2"):
            TraceArrivals(str(path)).arrivals()

    def test_decreasing_times_rejected(self, tmp_path):
        path = tmp_path / "dec.jsonl"
        path.write_text('{"time": 5.0, "prompt_len": 4}\n'
                        '{"time": 1.0, "prompt_len": 4}\n')
        with pytest.raises(ValueError, match="non-decreasing"):
            TraceArrivals(str(path)).arrivals()


# ---------------------------------------------------------------------------
# Closed loop — low-load parity with the offline plan
# ---------------------------------------------------------------------------

class TestLowLoadParity:
    def test_online_ttft_matches_offline_plan(self):
        # At low offered load the closed loop degenerates to the
        # offline plan: same arrivals, same policy, DES spans on both
        # sides — TTFT p50 within 10% (acceptance criterion).
        cfg = _cfg()
        src = PoissonArrivals(mean_gap=2e5, n=5, seed=7,
                              prompt_lengths=(8, 12, 16))
        oeng = OnlineServingEngine(cfg, max_batch=1, max_new_tokens=4,
                                   policy="full-prefill")
        ores = oeng.run(src)
        assert ores.span_log.validate() == []
        assert len(ores.completed()) == 5
        p50o = _percentile(sorted(ores.ttfts().values()), 50)

        feng = ServingEngine(cfg, None, max_batch=1)
        for a in src:
            feng.submit(jnp.zeros((a.prompt_len,), jnp.int32), a.time)
        _, fres = feng.evaluate_schedule("desim", max_new_tokens=4,
                                         policy="full-prefill")
        flog = fres.detail["span_log"]
        p50f = _percentile(sorted(flog.ttft(r)
                                  for r in flog.requests()), 50)
        assert p50f > 0
        assert abs(p50o - p50f) / p50f <= 0.10, (p50o, p50f)

    def test_gap_zero_admits_everything_at_once(self):
        res = OnlineServingEngine(
            _cfg(), max_batch=2, max_new_tokens=2,
            execute_backend="analytical",
        ).run(DeterministicArrivals(gap=0.0, n=4, prompt_lengths=(16,)))
        assert res.epochs[0].admitted == (0, 1, 2, 3)
        assert len(res.completed()) == 4
        assert res.span_log.validate() == []


# ---------------------------------------------------------------------------
# Preemption / eviction — state carried across re-plans (satellite 3)
# ---------------------------------------------------------------------------

class TestPreemptionEviction:
    @pytest.fixture(scope="class")
    def churny(self):
        # Short prompts + long decode + tight admission cap: request 1
        # is evicted for a waiting arrival, preempted twice by
        # re-plans, resumed, and still finishes all 16 tokens.
        eng = OnlineServingEngine(_cfg(), max_batch=2, max_new_tokens=16,
                                  policy="decode-priority",
                                  policy_kw={"chunk_tokens": 16},
                                  execute_backend="analytical",
                                  max_inflight=2, evict_to_admit=True)
        return eng.run(DeterministicArrivals(gap=3000.0, n=5,
                                             prompt_lengths=(8,)))

    def test_churn_actually_happened(self, churny):
        assert churny.n_preemptions >= 2
        assert churny.n_evictions >= 1

    def test_all_requests_complete(self, churny):
        assert len(churny.completed()) == 5
        assert all(r.decode_done == 16 for r in churny.requests)

    def test_span_log_validates_clean(self, churny):
        assert churny.span_log.validate() == []

    def test_resumed_decode_chain_monotonic_and_complete(self, churny):
        victim = max(churny.requests, key=lambda r: r.evictions)
        assert victim.evictions >= 1 and victim.preemptions >= 1
        spans = sorted((s for s in churny.span_log
                        if s.request == victim.rid
                        and s.phase.startswith("decode_iter")),
                       key=lambda s: s.start)
        # one span per token, indices 0..15 in start order, starts
        # non-decreasing across the eviction gap — the chain resumes,
        # it never restarts.
        assert [s.phase for s in spans] == \
            [f"decode_iter{k}" for k in range(16)]
        for a, b in zip(spans, spans[1:]):
            assert b.start >= a.end - 1e-9

    def test_lifecycle_markers_present(self, churny):
        victim = max(churny.requests, key=lambda r: r.evictions)
        marks = [s.phase for s in churny.span_log
                 if s.request == victim.rid and s.start == s.end]
        for phase in ("preempted", "evicted", "resumed", "complete"):
            assert phase in marks, (phase, marks)

    def test_epoch_records_name_the_churn(self, churny):
        preempted = [rid for e in churny.epochs for rid in e.preempted]
        evicted = [rid for e in churny.epochs for rid in e.evicted]
        assert len(preempted) == churny.n_preemptions
        assert len(evicted) == churny.n_evictions


# ---------------------------------------------------------------------------
# auto-slo — SLO-aware candidate selection
# ---------------------------------------------------------------------------

class TestAutoSLO:
    def test_registered_as_meta_policy(self):
        assert "auto-slo" in scheduler.available_policies()
        assert getattr(scheduler.get_policy("auto-slo"), "meta", False)
        assert "auto-slo" not in _concrete_policies()

    def test_meets_target_when_any_candidate_can(self):
        ctx = PolicyContext(cfg=_cfg(), prompt_lengths=(64, 96, 128),
                            max_batch=2, max_new_tokens=8)
        _, rep = select_schedule(ctx, ttft_p99_slo=1e9)
        chosen = rep["chosen"]
        assert chosen["slo_met"] is True
        assert chosen["ttft_p99"] <= 1e9
        # among SLO-meeting candidates the cheapest wins.
        cands = {k: v for k, v in rep.items() if k != "chosen"}
        meeting = [v for v in cands.values() if v["ttft_p99"] <= 1e9]
        assert chosen["workload_cycles"] == min(
            v["workload_cycles"] for v in meeting)

    def test_unmeetable_target_degrades_to_best_ttft(self):
        ctx = PolicyContext(cfg=_cfg(), prompt_lengths=(64, 96, 128),
                            max_batch=2, max_new_tokens=8)
        _, rep = select_schedule(ctx, ttft_p99_slo=1.0)
        chosen = rep["chosen"]
        assert chosen["slo_met"] is False
        assert chosen["ttft_p99"] == min(
            v["ttft_p99"] for k, v in rep.items() if k != "chosen")

    def test_online_engine_routes_through_slo_sweep(self):
        eng = OnlineServingEngine(_cfg(), max_batch=2, max_new_tokens=2,
                                  execute_backend="analytical",
                                  ttft_p99_slo=2e5)
        res = eng.run(DeterministicArrivals(gap=50000.0, n=3,
                                            prompt_lengths=(16,)))
        assert res.epochs
        assert all(e.slo_met is True for e in res.epochs)
        assert all(e.candidate in _concrete_policies()
                   for e in res.epochs)


# ---------------------------------------------------------------------------
# Pricing cache — arrivals reach the key (satellite 1)
# ---------------------------------------------------------------------------

class TestPriceCacheArrivals:
    def _plan(self, arrival_gap):
        eng = ServingEngine(_cfg(), None, max_batch=2)
        for i in range(4):
            eng.submit(jnp.zeros((16,), jnp.int32),
                       arrival_time=float(i) * arrival_gap)
        return eng.plan(max_new_tokens=2, policy="full-prefill")

    def test_schedules_differing_only_in_arrivals_do_not_alias(self):
        s0 = self._plan(0.0)
        s1 = self._plan(40000.0)
        assert [lt.gemms for lt in s0.layers] == \
            [lt.gemms for lt in s1.layers]      # same shapes...
        assert s0.release_times != s1.release_times
        # ...but no key of a released step aliases the t=0 schedule.
        kw = scheduler.backend_kwargs_for(s0)
        k0 = {scheduler._layer_price_key(lt, s0, "analytical", kw, r)
              for lt, r in zip(s0.layers, s0.release_times)}
        released = [scheduler._layer_price_key(lt, s1, "analytical", kw, r)
                    for lt, r in zip(s1.layers, s1.release_times)
                    if r > 0.0]
        assert released
        assert not set(released) & k0

    def test_shifted_arrivals_miss_the_cache(self):
        scheduler.clear_price_cache()
        s0 = self._plan(0.0)
        s1 = self._plan(40000.0)
        scheduler.price_steps(s0)               # warm the t=0 entries
        reg = enable_metrics()
        try:
            scheduler.price_steps(s1)
            snap = reg.snapshot()
        finally:
            disable_metrics()
            reg.clear()
        misses = sum(e["value"]
                     for e in snap["counters"]["price_cache_misses_total"])
        n_released = sum(1 for r in s1.release_times if r > 0.0)
        assert misses >= n_released >= 1, \
            "released steps must not reuse t=0 cached prices"

    def test_overlap_mode_reaches_the_key(self):
        import dataclasses
        s0 = self._plan(0.0)
        s1 = dataclasses.replace(s0, overlap="relaxed")
        kw = scheduler.backend_kwargs_for(s0)
        assert scheduler._layer_price_key(s0.layers[0], s0,
                                          "analytical", kw, 0.0) != \
            scheduler._layer_price_key(s1.layers[0], s1,
                                       "analytical", kw, 0.0)


# ---------------------------------------------------------------------------
# Sustained load — QPS sweep + saturation knee
# ---------------------------------------------------------------------------

class TestSustainedLoad:
    def test_qps_sweep_rows_complete(self):
        rows = qps_sweep(_cfg(), [1e4, 1e5], n_requests=4, seed=0,
                         prompt_lengths=(32, 64), max_batch=2,
                         max_new_tokens=4, execute_backend="analytical")
        assert [r["offered_qps"] for r in rows] == [1e4, 1e5]
        for r in rows:
            assert r["completed"] == 4.0
            assert r["goodput_qps"] > 0.0
            assert r["ttft_p99"] >= r["ttft_p50"] > 0.0

    def test_sweep_deterministic_under_seed(self):
        kw = dict(n_requests=4, seed=5, prompt_lengths=(32,),
                  max_batch=2, max_new_tokens=4,
                  execute_backend="analytical")
        assert qps_sweep(_cfg(), [5e4], **kw) == \
            qps_sweep(_cfg(), [5e4], **kw)

    @pytest.mark.parametrize("policy", ["full-prefill",
                                        "chunked-prefill",
                                        "decode-priority"])
    def test_every_policy_has_a_saturation_knee(self, policy):
        sat = find_saturation(_cfg(), start_qps=1e4, factor=4.0,
                              max_points=6, n_requests=6, seed=0,
                              prompt_lengths=(64, 96, 128),
                              policy=policy, max_batch=2,
                              max_new_tokens=8,
                              execute_backend="analytical")
        assert sat["saturated"], sat
        assert sat["knee_qps"] is not None
        assert sat["peak_goodput_qps"] > 0.0
        kept = sat["points"]
        assert kept[0]["keeps_up"] and not kept[-1]["keeps_up"]

"""Quantization kernel + SmoothQuant properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.quant.ops import quantize_rowwise
from repro.kernels.quant.ref import (quantize_colwise_ref,
                                     quantize_rowwise_ref,
                                     smoothquant_migrate)


def test_kernel_matches_ref():
    x = jax.random.normal(jax.random.PRNGKey(0), (300, 128)) * 3
    q, s = quantize_rowwise(x, block_m=128)
    qr, sr = quantize_rowwise_ref(x)
    assert np.array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


@given(seed=st.integers(0, 1000), scale=st.floats(1e-3, 1e3))
@settings(max_examples=20, deadline=None)
def test_roundtrip_error_bound(seed, scale):
    """|x - dequant(quant(x))| <= scale/2 = absmax/254 per row."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (16, 64)) * scale
    q, s = quantize_rowwise_ref(x)
    deq = q.astype(jnp.float32) * s[:, None]
    err = jnp.abs(x - deq)
    bound = s[:, None] * 0.5 + 1e-7
    assert bool(jnp.all(err <= bound))


def test_zero_rows_safe():
    x = jnp.zeros((8, 32))
    q, s = quantize_rowwise_ref(x)
    assert bool(jnp.all(q == 0))
    assert bool(jnp.all(jnp.isfinite(s)))


def test_int8_matmul_accuracy():
    """End-to-end W8A8: dequantized int8 GEMM tracks fp32 within ~1%."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (64, 256))
    w = jax.random.normal(jax.random.PRNGKey(2), (256, 128))
    qx, sx = quantize_rowwise_ref(x)
    qw, sw = quantize_colwise_ref(w)
    acc = jnp.matmul(qx.astype(jnp.int32), qw.astype(jnp.int32),
                     preferred_element_type=jnp.int32)
    y = acc.astype(jnp.float32) * sx[:, None] * sw[None, :]
    ref = x @ w
    rel = float(jnp.abs(y - ref).max() / jnp.abs(ref).max())
    assert rel < 0.02


def test_smoothquant_migration_preserves_product():
    """(X / s) @ (diag(s) W) == X @ W."""
    x = jax.random.normal(jax.random.PRNGKey(3), (32, 64))
    w = jax.random.normal(jax.random.PRNGKey(4), (64, 48))
    s = smoothquant_migrate(jnp.abs(x).max(0), jnp.abs(w).max(1))
    y = (x / s) @ (w * s[:, None])
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-4,
                               atol=1e-4)


def test_smoothquant_flattens_outliers():
    """Activation outlier channels shrink after migration (the point of
    SmoothQuant: migrate difficulty to weights)."""
    x = jax.random.normal(jax.random.PRNGKey(5), (128, 64))
    x = x.at[:, 0].mul(50.0)                      # outlier channel
    w = jax.random.normal(jax.random.PRNGKey(6), (64, 48))
    s = smoothquant_migrate(jnp.abs(x).max(0), jnp.abs(w).max(1), alpha=0.5)
    xs = x / s
    before = jnp.abs(x).max(0)
    after = jnp.abs(xs).max(0)
    assert float(after.max() / after.min()) < float(before.max()
                                                    / before.min())

"""Discrete-event execution of a TaskGraph on an explicit machine model.

Where ``core.simulator`` asserts the overlap with a closed-form
``max(matrix, vec)``, this module *derives* it: every node of the graph
contends for explicit resources and the timeline falls out of the event
schedule.

Machine resources (paper §4.1/§4.4), per matrix unit:

* ``dispatcher`` — the CPU front-end.  Every ``asyncMatMul`` occupies it
  for ``platform.dispatch_cycles`` (RoCC few tens, CSR ~100, Table 3)
  and every completion poll for ``platform.check_cycles``.  It is a
  single serial resource: a slow interface genuinely backpressures the
  tile stream instead of being a term in a max().
* ``banks`` — the double-buffered scratchpad: ``unit.scratchpad_banks``
  slots, each held for a tile's load+compute span.  Two banks is what
  lets tile *i+1*'s load overlap tile *i*'s compute.
* ``pe`` — the M_pe×N_pe array; a tile occupies it for the Eq.1 compute
  time with PE-quantised extents, plus a six-stage pipeline drain on the
  result latency.
* ``vector`` — the Saturn RVV unit running epilogue nodes.

and shared across the cluster:

* ``loader`` — streams A/B panels in and the C tile out.  A
  :class:`~repro.sim.resources.ClusterTopology` decides how many units
  contend for it and under which bandwidth-partitioning policy
  (``fair`` processor sharing vs ``fcfs``); the single-unit machine is
  the ``n_units=1, fcfs`` special case.

A matmul node's life: dispatch → wait for a scratchpad bank → load →
compute → (writeback ‖ status poll) → dependents released.  With
``k_stream`` enabled the load arrives in ``k_scp``-sized chunks and the
PE starts after the first chunk, overlapping a single tile's fill with
its own compute (DES-fidelity ROADMAP item).  Vector and memory nodes
occupy their single resource for their modelled duration.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.core.config import MatrixUnitConfig
from repro.core.hardware import CpuPlatform, SHUTTLE
from repro.core.precision import policy
from repro.core.simulator import SATURN_512, VectorUnit
from repro.core.task import BiasType
from repro.sim.graph import Node, TaskGraph
from repro.sim.resources import (BandwidthResource, ClusterTopology,
                                 EventLoop, Resource, contiguous_run_bytes,
                                 dram_stride_efficiency)


@dataclasses.dataclass
class Machine:
    """The resource set one (unit, platform, vector) triple implies.

    Retained as the single-unit cost-model context (``tile_costs``, the
    analytical backend); simulation itself runs on :class:`ClusterMachine`.
    """

    loop: EventLoop
    unit: MatrixUnitConfig
    platform: CpuPlatform
    vector_unit: VectorUnit
    dispatcher: Resource
    loader: Resource
    banks: Resource
    pe: Resource
    vector: Resource

    @property
    def bytes_per_cycle(self) -> float:
        return (self.unit.bandwidth * self.platform.dram_efficiency
                / self.unit.freq_hz)

    def resources(self) -> "list[Resource]":
        return [self.dispatcher, self.loader, self.banks, self.pe,
                self.vector]


def build_machine(unit: MatrixUnitConfig, platform: CpuPlatform,
                  vector_unit: VectorUnit = SATURN_512) -> Machine:
    loop = EventLoop()
    return Machine(
        loop=loop, unit=unit, platform=platform, vector_unit=vector_unit,
        dispatcher=Resource(loop, "dispatcher"),
        loader=Resource(loop, "mem_loader"),
        banks=Resource(loop, "scratchpad", capacity=unit.scratchpad_banks),
        pe=Resource(loop, "pe_array"),
        vector=Resource(loop, "vector_unit"),
    )


# ---------------------------------------------------------------------------
# Per-node cost model (mirrors core.simulator.simulate_gemm's per-tile terms).
# ---------------------------------------------------------------------------

def tile_work(unit: MatrixUnitConfig, platform: CpuPlatform, node: Node,
              out_bytes: float = 4.0,
              streams: int = 1) -> "dict[str, float]":
    """Per-tile compute cycles and *effective* load/writeback bytes.

    Effective bytes are actual bytes divided by the stride-dependent DRAM
    efficiency the operand's access pattern achieves (``Task`` strides,
    paper §5.4) — a dense panel streams at the platform's calibrated
    derate, a narrow tile cut from a wide row-major matrix pays per-row
    address jumps.  Dividing by a loader's raw bytes/cycle turns them
    into cycles, which is how the shared cluster loader charges them.

    ``streams`` is the row-buffer interleaving factor
    (``ClusterTopology.interleaved_streams``): tiles riding a shared
    pool alongside ``streams - 1`` other units see their contiguous runs
    chopped accordingly; 1 (default, and any private slice) keeps the
    single-stream curve.
    """
    task = node.task
    base = platform.dram_efficiency
    dt = task.data_type
    eb = policy(dt).bytes_per_elem
    m_eff = -(-task.m // unit.m_pe) * unit.m_pe
    n_eff = -(-task.n // unit.n_pe) * unit.n_pe
    kpe = unit.k_pe_elems(dt)
    k_eff = -(-task.k // kpe) * kpe
    compute = m_eff * n_eff * k_eff / unit.macs_per_cycle(dt)
    bias_bytes = {BiasType.ZERO: 0.0, BiasType.ROW: task.n * 4.0,
                  BiasType.FULL: task.m * task.n * 4.0}[task.bias_type]
    eff_a = dram_stride_efficiency(
        contiguous_run_bytes(task.m, task.k, task.stride_a, eb), base,
        streams)
    eff_b = dram_stride_efficiency(
        contiguous_run_bytes(task.k, task.n, task.stride_b, eb), base,
        streams)
    eff_c = dram_stride_efficiency(
        contiguous_run_bytes(task.m, task.n, task.stride_c, out_bytes),
        base, streams)
    load_eff = (task.m * task.k * eb / eff_a
                + task.k * task.n * eb / eff_b
                + bias_bytes / base)
    wb_eff = task.m * task.n * out_bytes / eff_c
    return {"compute": compute, "load_eff": load_eff, "wb_eff": wb_eff,
            "eff_a": eff_a, "eff_b": eff_b, "bias_eff": bias_bytes / base}


def tile_costs(machine: Machine, node: Node,
               out_bytes: float = 4.0) -> "dict[str, float]":
    """Per-tile compute/load/writeback cycles on a dedicated loader at
    ``unit.bandwidth`` (the single-unit machine; the analytical backend's
    cost source)."""
    w = tile_work(machine.unit, machine.platform, node, out_bytes)
    raw_bpc = machine.unit.bandwidth / machine.unit.freq_hz
    return {"compute": w["compute"], "load": w["load_eff"] / raw_bpc,
            "writeback": w["wb_eff"] / raw_bpc}


def tile_chunks(unit: MatrixUnitConfig, platform: CpuPlatform, node: Node,
                out_bytes: float = 4.0,
                streams: int = 1) -> "list[tuple[float, float]]":
    """K-chunked (load_eff_bytes, compute_cycles) stream for one tile.

    The scratchpad stages ``k_scp_bytes`` of the K extent at a time; the
    PE may reduce chunk *j* as soon as chunk *j* is resident, so a
    tile's fill overlaps its own compute.  Bias rides the first chunk.
    ``streams`` is the row-buffer interleaving factor (see
    :func:`tile_work`).
    """
    task = node.task
    w = tile_work(unit, platform, node, out_bytes, streams)
    dt = task.data_type
    eb = policy(dt).bytes_per_elem
    ck = max(1, int(unit.k_scp_bytes / eb))
    if task.k <= ck:
        return [(w["load_eff"], w["compute"])]
    m_eff = -(-task.m // unit.m_pe) * unit.m_pe
    n_eff = -(-task.n // unit.n_pe) * unit.n_pe
    kpe = unit.k_pe_elems(dt)
    macs = unit.macs_per_cycle(dt)
    chunks = []
    k0 = 0
    while k0 < task.k:
        kc = min(ck, task.k - k0)
        load = (task.m * kc * eb / w["eff_a"]
                + kc * task.n * eb / w["eff_b"])
        if k0 == 0:
            load += w["bias_eff"]
        compute = m_eff * n_eff * (-(-kc // kpe) * kpe) / macs
        chunks.append((load, compute))
        k0 += kc
    return chunks


# ---------------------------------------------------------------------------
# Cluster machine: N units behind one shared loader.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class UnitMachine:
    """One matrix unit's private resources inside a cluster.

    ``config`` is the unit's own :class:`MatrixUnitConfig` (heterogeneous
    clusters mix them); ``private_loader`` is the unit's dedicated
    bandwidth slice when the topology carves one out of the pool —
    ``None`` means the unit's traffic contends on the shared loader.
    """

    idx: int
    prefix: str                       # "" for a 1-unit cluster, "u0/" etc.
    config: MatrixUnitConfig
    dispatcher: Resource
    banks: Resource
    pe: Resource
    vector: Resource
    private_loader: Optional[BandwidthResource] = None
    private_bpc: float = 0.0          # raw bytes/cycle of the private slice

    def resources(self) -> "list[Resource]":
        return [self.dispatcher, self.banks, self.pe, self.vector]


@dataclasses.dataclass
class ClusterMachine:
    loop: EventLoop
    topology: ClusterTopology
    units: "list[UnitMachine]"
    loader: BandwidthResource

    @property
    def loader_bpc(self) -> float:
        """Raw *contended-pool* loader bytes/cycle: the pooled bandwidth
        minus private slices (derates are per-transfer)."""
        return self.topology.shared_bandwidth / self.topology.unit.freq_hz

    @property
    def memory_node_bpc(self) -> float:
        """Bytes/cycle a bulk memory node achieves (flat platform derate,
        mirroring the single-unit ``Machine.bytes_per_cycle``)."""
        return self.loader_bpc * self.topology.platform.dram_efficiency


def unit_prefix(idx: int, n_units: int) -> str:
    return "" if n_units == 1 else f"u{idx}/"


def build_cluster(topology: ClusterTopology) -> ClusterMachine:
    loop = EventLoop()
    freq = topology.unit.freq_hz
    units = []
    for i in range(topology.n_units):
        p = unit_prefix(i, topology.n_units)
        cfg = topology.unit_config(i)
        private = topology.private_bandwidth(i)
        units.append(UnitMachine(
            idx=i, prefix=p, config=cfg,
            dispatcher=Resource(loop, p + "dispatcher"),
            banks=Resource(loop, p + "scratchpad",
                           capacity=cfg.scratchpad_banks),
            pe=Resource(loop, p + "pe_array"),
            vector=Resource(loop, p + "vector_unit"),
            private_loader=BandwidthResource(loop, p + "local_loader",
                                             policy="fcfs")
            if private > 0 else None,
            private_bpc=private / freq))
    loader = BandwidthResource(loop, "mem_loader",
                               policy=topology.loader_policy)
    return ClusterMachine(loop=loop, topology=topology, units=units,
                          loader=loader)


# ---------------------------------------------------------------------------
# Results.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DESimResult:
    cycles: float                       # makespan
    ideal_matrix_cycles: float          # Eq.1 lower bound for all matmul work
    node_span: "dict[int, tuple[float, float]]"   # nid -> (start, end)
    intervals: "dict[str, list[tuple[float, float, str]]]"
    capacity: "dict[str, int]"
    freq_hz: float

    @property
    def matrix_utilization(self) -> float:
        return (self.ideal_matrix_cycles / self.cycles) if self.cycles else 0.0

    def busy(self, resource: str) -> float:
        return sum(e - s for s, e, _ in self.intervals[resource])

    def utilization(self, resource: str) -> float:
        if not self.cycles:
            return 0.0
        return self.busy(resource) / (self.cycles * self.capacity[resource])

    def utilizations(self) -> "dict[str, float]":
        return {r: self.utilization(r) for r in self.intervals}

    def seconds(self) -> float:
        return self.cycles / self.freq_hz


@dataclasses.dataclass
class ClusterDESimResult(DESimResult):
    """Per-unit timelines + shared-loader contention of a cluster run.

    ``intervals["mem_loader"]`` holds per-transfer spans (overlapping
    under the ``fair`` policy — that overlap *is* the visible
    contention); ``loader_busy`` is the union busy time, which is what
    loader utilization/saturation is judged on.
    """

    n_units: int = 1
    loader_busy: float = 0.0
    topology: Optional[ClusterTopology] = None

    @property
    def aggregate_matrix_utilization(self) -> float:
        """Ideal unit-cycles over makespan × cluster width — 1.0 means
        every PE array busy with useful MACs the whole run."""
        if not self.cycles:
            return 0.0
        return self.ideal_matrix_cycles / (self.cycles * self.n_units)

    @property
    def loader_utilization(self) -> float:
        return (self.loader_busy / self.cycles) if self.cycles else 0.0

    def utilization(self, resource: str) -> float:
        if resource == "mem_loader":
            return self.loader_utilization
        return super().utilization(resource)

    def unit_utilizations(self) -> "list[float]":
        """Per-unit PE-array busy fraction."""
        out = []
        for i in range(self.n_units):
            name = unit_prefix(i, self.n_units) + "pe_array"
            out.append(self.busy(name) / self.cycles if self.cycles else 0.0)
        return out

    def loader_contention(self) -> float:
        """Σ transfer spans / union busy — 1.0 means no two transfers
        ever overlapped; higher means the fair-share loader was split."""
        demand = sum(e - s for s, e, _ in self.intervals["mem_loader"])
        return demand / self.loader_busy if self.loader_busy else 0.0


# ---------------------------------------------------------------------------
# The discrete-event engine.
# ---------------------------------------------------------------------------

def simulate_cluster(graph: TaskGraph,
                     topology: ClusterTopology) -> ClusterDESimResult:
    """Run ``graph`` on a cluster machine; per-unit timelines + contention.

    Node placement comes from ``Node.unit`` (see ``sim.partition``); an
    unpartitioned graph runs entirely on unit 0.
    """
    nodes = graph.topo_order()
    machine = build_cluster(topology)
    loop = machine.loop
    n_units = topology.n_units
    for n in nodes:
        if n.unit >= n_units:
            raise ValueError(
                f"node {n.nid} ({n.name!r}) assigned to unit {n.unit} but "
                f"topology has {n_units} unit(s); re-partition the graph")

    remaining = {n.nid: len(n.deps) for n in nodes}
    dependents: "dict[int, list[Node]]" = {n.nid: [] for n in nodes}
    for n in nodes:
        for d in n.deps:
            dependents[d].append(n)
    span: "dict[int, tuple[float, float]]" = {}
    started: "dict[int, float]" = {}

    def complete(node: Node) -> None:
        span[node.nid] = (started[node.nid], loop.now)
        for succ in dependents[node.nid]:
            remaining[succ.nid] -= 1
            if remaining[succ.nid] == 0:
                ready(succ)

    def ready(node: Node) -> None:
        # Deps satisfied; the node still waits out its release time (a
        # request that has not arrived yet cannot enter the machine).
        if node.release_time > loop.now:
            loop.after(node.release_time - loop.now,
                       (lambda nn: lambda: start(nn))(node))
        else:
            start(node)

    def start(node: Node) -> None:
        started[node.nid] = loop.now
        mu = machine.units[node.unit]
        if node.kind == "matmul":
            _run_matmul(machine, mu, node, lambda: complete(node))
        elif node.kind == "vector":
            cyc = topology.vector.cycles_for(node.vector_ops)
            mu.vector.busy(cyc, node.name, then=lambda: complete(node))
        elif node.kind == "memory":
            work = node.mem_bytes / machine.memory_node_bpc
            machine.loader.transfer(work, node.name,
                                    then=lambda: complete(node))
        else:
            raise ValueError(f"unknown node kind {node.kind!r}")

    for n in nodes:                      # sources, in program order
        if remaining[n.nid] == 0:
            loop.after(max(0.0, n.release_time),
                       (lambda nn: lambda: start(nn))(n))

    loop.run()
    if len(span) != len(nodes):
        stuck = [n.nid for n in nodes if n.nid not in span]
        raise RuntimeError(f"graph deadlocked; unfinished nodes {stuck[:8]}")

    intervals = {"mem_loader": machine.loader.intervals}
    capacity = {"mem_loader": 1}
    for mu in machine.units:
        for r in mu.resources():
            intervals[r.name] = r.intervals
            capacity[r.name] = r.capacity
        if mu.private_loader is not None:
            intervals[mu.private_loader.name] = mu.private_loader.intervals
            capacity[mu.private_loader.name] = 1
    # Makespan from recorded activity, not the raw event-heap horizon:
    # the fair-share loader leaves superseded no-op wakeups in the heap.
    makespan = 0.0
    for s, e in span.values():
        makespan = max(makespan, e)
    for ivals in intervals.values():
        for _, e, _ in ivals:
            makespan = max(makespan, e)

    # Ideal cycles are per-node against the *owning* unit's throughput —
    # on a heterogeneous cluster a fast unit's tile has a smaller bound.
    unit = topology.unit
    ideal = sum(n.task.macs
                / topology.unit_config(n.unit).macs_per_cycle(
                    n.task.data_type)
                for n in nodes if n.kind == "matmul")
    return ClusterDESimResult(
        cycles=makespan, ideal_matrix_cycles=ideal, node_span=span,
        intervals=intervals, capacity=capacity, freq_hz=unit.freq_hz,
        n_units=n_units, loader_busy=machine.loader.busy_cycles(),
        topology=topology)


def simulate_graph(graph: TaskGraph, unit: MatrixUnitConfig,
                   platform: CpuPlatform = SHUTTLE,
                   vector_unit: VectorUnit = SATURN_512,
                   machine: Optional[Machine] = None) -> DESimResult:
    """Run ``graph`` to completion on the classic single-unit machine
    (``n_units=1``, dedicated FCFS loader, K-streamed fills — the same
    chunked scratchpad streaming every cluster machine uses); returns
    timelines + utilization."""
    if machine is not None:
        unit, platform = machine.unit, machine.platform
        vector_unit = machine.vector_unit
    topo = ClusterTopology(n_units=1, unit=unit, platform=platform,
                           vector=vector_unit, loader_policy="fcfs")
    return simulate_cluster(graph, topo)


def _run_matmul(machine: ClusterMachine, mu: UnitMachine, node: Node,
                done: Callable[[], None]) -> None:
    """dispatch → bank → load (k-chunked) → compute → (writeback ‖ poll)
    → done."""
    topo = machine.topology
    platform = topo.platform
    unit = mu.config                   # the owning unit's own geometry
    label = node.name
    # A private bandwidth slice keeps this unit's tile traffic off the
    # contended pool (cross-unit transfers still share — see `start`).
    if mu.private_loader is not None:
        loader, bpc = mu.private_loader, mu.private_bpc
        streams = 1                    # a private slice never interleaves
    else:
        loader, bpc = machine.loader, machine.loader_bpc
        streams = topo.interleaved_streams()
    w = tile_work(unit, platform, node, streams=streams)
    if topo.k_stream:
        chunks = tile_chunks(unit, platform, node, streams=streams)
    else:
        chunks = [(w["load_eff"], w["compute"])]
    n_chunks = len(chunks)

    bank_start = [0.0]
    loaded = [False] * n_chunks
    next_compute = [0]
    pe_free = [True]

    def after_dispatch():
        def granted():
            bank_start[0] = machine.loop.now
            issue_load(0)

        mu.banks.acquire(granted)

    def issue_load(j):
        loader.transfer(chunks[j][0] / bpc, label,
                        then=lambda: chunk_loaded(j))

    def chunk_loaded(j):
        loaded[j] = True
        if j + 1 < n_chunks:
            issue_load(j + 1)           # chunks of one tile stream serially
        maybe_compute()

    def maybe_compute():
        j = next_compute[0]
        if pe_free[0] and j < n_chunks and loaded[j]:
            pe_free[0] = False
            mu.pe.busy(chunks[j][1], label,
                       then=lambda: chunk_computed(j))

    def chunk_computed(j):
        pe_free[0] = True
        next_compute[0] += 1
        if next_compute[0] == n_chunks:
            finish()
        else:
            maybe_compute()

    def finish():
        # A/B bank held from load start to compute end, then freed.
        mu.banks.intervals.append((bank_start[0], machine.loop.now, label))
        mu.banks.release()
        loader.transfer(w["wb_eff"] / bpc, label + "/wb")
        # Result usable after the PE pipeline drains; the CPU then owes a
        # checkMatmul poll before dependents (vector epilogues) may issue.
        machine.loop.after(
            unit.pe_pipeline_stages,
            lambda: mu.dispatcher.busy(
                platform.check_cycles, label + "/chk", then=done))

    mu.dispatcher.busy(platform.dispatch_cycles, label + "/disp",
                       then=after_dispatch)

"""``repro.backend`` — pluggable execution engines behind one contract.

The paper's asyncMatMul/checkMatmul programming model is the seam that
lets one software stack target four CPUs; this package is that seam for
the reproduction.  One :class:`~repro.backend.base.Backend` protocol —
``dispatch(task, operands) -> handle``, ``check(handle)``,
``wait(handle)``, ``run_graph(TaskGraph)`` — with first-class
granularity (``tile | panel | layer``), epilogue fusion and a cluster
``units`` dimension, and six registered implementations:

=========================  =================================================
``get("jax")``             eager XLA execution (``AsyncMatmulEngine`` /
                           ``cute_matmul``) — numbers, no cycles
``get("pallas")``          the ``kernels/matmul`` fused Pallas kernel —
                           numbers via the grid-pipelined on-chip path
``get("desim")``           the discrete-event machine model — per-resource
                           timelines + Chrome traces, and (given operands)
                           the numbers from executing the *same* graph
``get("analytical")``      ``core.simulator`` closed forms — cycles only
``get("desim-cluster")``   N matrix units behind one shared, bandwidth-
                           partitioned loader (``sim.partition`` shards
                           the graph) — contended per-unit timelines
``get("sharded")``         the identical partitioned graph executed over
                           ``launch.mesh``/``shard_map`` — int8 bit-exact
                           against ``jax``
=========================  =================================================

Every front door goes through the registry: ``serving.ServingEngine``
lowers batch schedules here (``plan(units=N)`` prices them on contended
cluster timelines), ``benchmarks/run.py --engine``/``--units`` is a
registry lookup, the model zoo's ``linear`` resolves its matmul route
here, and ``examples/sim_timeline.py`` / ``examples/cluster_scaling.py``
drive several backends with one graph.  A new engine is one
``@register`` away.

Typical use::

    from repro import backend
    from repro.core.task import MatMulTask

    b = backend.get("desim", granularity="panel")
    h = b.dispatch(MatMulTask(m=512, n=512, k=4096))      # asyncMatMul
    r = b.wait(h)                                         # checkMatmul
    r.cycles, r.timeline                                  # DES payload
"""

from repro.backend.base import (Backend, DispatchHandle, ExecResult,
                                MatMulOperands, NO_MATMUL_OPERANDS)
from repro.backend.registry import (ALIASES, available,
                                    default_matmul_backend, dispatch_platform,
                                    get, get_tuned, matmul_backend_string,
                                    register, resolve,
                                    set_default_matmul_backend,
                                    set_dispatch_platform, set_tuned_dispatch,
                                    tuned_config, tuned_dispatch_enabled)

# Importing the implementation modules registers them.
from repro.backend.eager import JaxBackend, PallasBackend
from repro.backend.desim_backend import DESimBackend
from repro.backend.analytical_backend import AnalyticalBackend
from repro.backend.cluster_backend import ClusterDESimBackend
from repro.backend.sharded_backend import ShardedBackend

__all__ = [
    "Backend", "DispatchHandle", "ExecResult", "MatMulOperands",
    "NO_MATMUL_OPERANDS",
    "ALIASES", "available", "default_matmul_backend", "dispatch_platform",
    "get", "get_tuned", "matmul_backend_string", "register", "resolve",
    "set_default_matmul_backend", "set_dispatch_platform",
    "set_tuned_dispatch", "tuned_config", "tuned_dispatch_enabled",
    "JaxBackend", "PallasBackend", "DESimBackend", "AnalyticalBackend",
    "ClusterDESimBackend", "ShardedBackend",
]

"""KV-cache residency as a simulated resource, end to end.

The tentpole's integration bar, in four layers:

* **context** — ``PolicyContext`` carries per-request residency /
  refill bytes and validates them;
* **stamping + pricing** — ``_finish`` stamps each request's owed
  refill onto the first step that touches it, and both modelling
  backends (analytical closed form and the DES) price the lowered
  ``kv_refill`` memory node as a visible cost;
* **bit-exactness** — refill nodes are simulation-only: JAX execution
  of the same graph is byte-identical with and without them, across
  tile/panel/layer granularities;
* **closed loop** — under a hot pool smaller than the aggregate
  working set the online DES makespan visibly exceeds the unlimited-KV
  baseline, the residency-aware ``decode-priority`` beats its
  residency-blind twin on decode p50 (ITL), eviction churn emits
  ``kv_evicted``/``kv_refill`` span markers with ``validate()`` clean,
  and the whole run is deterministic given (seed, arrival order).
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro import backend
from repro.configs.registry import get_config
from repro.core.config import CASE_STUDY
from repro.serving import scheduler
from repro.serving.arrivals import DeterministicArrivals
from repro.serving.engine import ServingEngine
from repro.serving.kvcache import refill_cycles
from repro.serving.online import OnlineServingEngine
from repro.serving.scheduler import PolicyContext, price_steps
from repro.sim import Granularity, simulate_graph, workload_to_graph
from repro.sim.lower import execute_workload_jax, schedule_to_graph


@pytest.fixture(scope="module")
def cfg():
    return get_config("yi-6b", reduced=True)


def _ctx(cfg, refill=(0.0, 4096.0), residency=(1.0, 0.5), **kw):
    """Two carryover decode streams, request 1 half-cold."""
    base = dict(cfg=cfg, prompt_lengths=(8, 8), max_batch=2,
                max_new_tokens=4, prefill_progress=(8, 8),
                decode_done=(1, 1), kv_residency=residency,
                kv_refill_bytes=refill)
    base.update(kw)
    return PolicyContext(**base)


# ----- context ---------------------------------------------------------------

class TestPolicyContextKV:
    def test_accessors(self, cfg):
        ctx = _ctx(cfg)
        assert ctx.residency_of(0) == 1.0
        assert ctx.residency_of(1) == 0.5
        assert ctx.refill_of(1) == 4096.0
        # untracked requests fall back to the classic assumption
        assert ctx.residency_of(99) == 1.0
        assert ctx.refill_of(99) == 0.0

    def test_defaults_empty(self, cfg):
        ctx = PolicyContext(cfg=cfg, prompt_lengths=(8,), max_batch=2,
                            max_new_tokens=4)
        assert ctx.kv_residency == () and ctx.kv_refill_bytes == ()
        assert ctx.residency_of(0) == 1.0 and ctx.refill_of(0) == 0.0

    def test_length_validated(self, cfg):
        with pytest.raises(ValueError, match="kv_residency"):
            _ctx(cfg, residency=(1.0,))
        with pytest.raises(ValueError, match="kv_refill_bytes"):
            _ctx(cfg, refill=(0.0,))

    def test_range_validated(self, cfg):
        with pytest.raises(ValueError, match="outside"):
            _ctx(cfg, residency=(1.0, 1.5))
        with pytest.raises(ValueError, match="negative"):
            _ctx(cfg, refill=(0.0, -1.0))


# ----- stamping + pricing ----------------------------------------------------

class TestRefillStamping:
    @pytest.mark.parametrize("policy,kw", [
        ("full-prefill", {}),
        ("chunked-prefill", {"chunk_tokens": 6}),
        ("decode-priority", {}),
        ("decode-priority", {"residency_aware": False}),
    ])
    def test_refill_charged_exactly_once(self, cfg, policy, kw):
        sched = scheduler.get_policy(policy, **kw).schedule(_ctx(cfg))
        assert len(sched.refill_bytes) == len(sched.layers)
        assert sum(sched.refill_bytes) == pytest.approx(4096.0)
        # ... and on the first step that touches request 1
        first = next(i for i, s in enumerate(sched.steps)
                     if 1 in s.requests)
        assert sched.refill_bytes[first] == pytest.approx(4096.0)

    def test_no_refill_no_stamp(self, cfg):
        sched = scheduler.get_policy("decode-priority").schedule(
            _ctx(cfg, refill=(0.0, 0.0), residency=(1.0, 1.0)))
        assert not any(sched.refill_bytes)

    def test_residency_aware_drains_hot_first(self, cfg):
        """The hot stream's decode steps all precede the cold one's."""
        sched = scheduler.get_policy("decode-priority").schedule(_ctx(cfg))
        owner = [s.requests for s in sched.steps]
        last_hot_only = max(i for i, r in enumerate(owner) if r == (0,))
        first_cold = min(i for i, r in enumerate(owner) if 1 in r)
        assert last_hot_only < first_cold

    def test_blind_twin_interleaves(self, cfg):
        """residency_aware=False reproduces the classic merged drain."""
        blind = scheduler.get_policy(
            "decode-priority", residency_aware=False).schedule(_ctx(cfg))
        classic = scheduler.get_policy("decode-priority").schedule(
            _ctx(cfg, refill=(0.0, 0.0), residency=(1.0, 1.0)))
        assert [s.requests for s in blind.steps] == \
            [s.requests for s in classic.steps]

    @pytest.mark.parametrize("backend_name", ["analytical", "desim"])
    def test_price_steps_includes_refill(self, cfg, backend_name):
        sched = scheduler.get_policy("decode-priority").schedule(_ctx(cfg))
        bare = dataclasses.replace(sched, refill_bytes=())
        with_kv = sum(price_steps(sched, backend_name))
        without = sum(price_steps(bare, backend_name))
        assert with_kv > without
        eng = backend.get(backend_name)
        extra = refill_cycles(4096.0, eng.unit, eng.platform)
        assert with_kv - without == pytest.approx(extra, rel=1e-6)


class TestRefillLowering:
    def _sched(self, cfg):
        sched = scheduler.get_policy("decode-priority").schedule(_ctx(cfg))
        assert any(sched.refill_bytes)
        return sched

    def test_graph_grows_memory_nodes(self, cfg):
        sched = self._sched(cfg)
        g = schedule_to_graph(CASE_STUDY, sched)
        kv = [n for n in g.nodes if n.name.endswith("/kv_refill")]
        assert len(kv) == sum(1 for b in sched.refill_bytes if b > 0.0)
        for n in kv:
            assert n.kind == "memory"
            assert n.mem_bytes > 0.0
        # the step's tiles wait on the refill: some node depends on it
        nids = {n.nid for n in kv}
        assert any(set(n.deps) & nids for n in g.nodes)

    def test_length_mismatch_rejected(self, cfg):
        sched = self._sched(cfg)
        with pytest.raises(ValueError, match="refill_bytes"):
            workload_to_graph(CASE_STUDY, sched.layers,
                              refill_bytes=[1.0])

    @pytest.mark.parametrize("backend_name", ["analytical", "desim"])
    def test_both_backends_price_the_node(self, cfg, backend_name):
        """The lowered graph itself (not just price_steps) carries the
        cost, on the DES and the analytical closed form alike."""
        sched = self._sched(cfg)
        bare = dataclasses.replace(sched, refill_bytes=())
        eng = backend.get(backend_name)
        with_kv = eng.run_graph(schedule_to_graph(CASE_STUDY, sched))
        without = eng.run_graph(schedule_to_graph(CASE_STUDY, bare))
        assert with_kv.cycles > without.cycles


# ----- bit-exactness across granularities ------------------------------------

class TestRefillBitExactness:
    """Refill nodes shape *time*, never *numbers*: JAX execution of the
    same schedule is byte-identical with and without them, at every
    lowering granularity, while the DES sees a strictly larger
    makespan."""

    @pytest.fixture(scope="class")
    def planned(self, cfg):
        eng = ServingEngine(cfg, params=None, max_batch=2, cache_len=64)
        key = jax.random.PRNGKey(0)
        for i in range(3):
            key, sub = jax.random.split(key)
            eng.submit(jax.random.randint(sub, (4 + i,), 0, 100))
        sched = eng.plan(max_new_tokens=2, policy="decode-priority")
        refill = [0.0] * len(sched.layers)
        refill[1] = 65536.0
        ops = sched.example_operands(jax.random.PRNGKey(7))
        return sched, refill, ops

    @pytest.mark.parametrize("gran", list(Granularity))
    def test_jax_exact_desim_slower(self, planned, gran):
        sched, refill, ops = planned
        g0 = workload_to_graph(CASE_STUDY, sched.layers, granularity=gran)
        g1 = workload_to_graph(CASE_STUDY, sched.layers, granularity=gran,
                               refill_bytes=refill)
        assert any(n.name.endswith("/kv_refill") for n in g1.nodes)
        out0 = execute_workload_jax(g0, ops)
        out1 = execute_workload_jax(g1, ops)
        assert set(out0) == set(out1) == set(ops)
        for label in ops:
            assert np.array_equal(np.asarray(out0[label]),
                                  np.asarray(out1[label])), label
        assert simulate_graph(g1, CASE_STUDY).cycles > \
            simulate_graph(g0, CASE_STUDY).cycles

    def test_desim_backend_outputs_exact(self, planned):
        """The desim backend's lockstep execution sees the refill in
        cycles but not in the int8 outputs."""
        sched, refill, ops = planned
        de = backend.get("desim")
        r0 = de.run_graph(workload_to_graph(CASE_STUDY, sched.layers), ops)
        r1 = de.run_graph(workload_to_graph(CASE_STUDY, sched.layers,
                                            refill_bytes=refill), ops)
        assert r1.cycles > r0.cycles
        for label in ops:
            assert np.array_equal(np.asarray(r0.outputs[label]),
                                  np.asarray(r1.outputs[label])), label


# ----- the closed loop -------------------------------------------------------

_PROMPTS = (32, 40, 32, 48, 32, 40, 32, 48)


def _online(cfg, **extra):
    eng = OnlineServingEngine(cfg, max_batch=4, max_new_tokens=16,
                              policy="decode-priority", **extra)
    res = eng.run(DeterministicArrivals(gap=4000.0, n=8,
                                        prompt_lengths=_PROMPTS))
    return eng, res


@pytest.fixture(scope="module")
def pressured(cfg):
    return _online(cfg, kv_hot_blocks=10, kv_block_tokens=8)


@pytest.fixture(scope="module")
def unlimited(cfg):
    return _online(cfg)


@pytest.fixture(scope="module")
def blind(cfg):
    return _online(cfg, kv_hot_blocks=10, kv_block_tokens=8,
                   policy_kw={"residency_aware": False})


class TestOnlineKVPressure:
    def test_pool_pressure_costs_makespan(self, pressured, unlimited):
        """A hot pool smaller than the aggregate working set makes the
        DES decode makespan visibly exceed the unlimited-KV baseline."""
        _, res = pressured
        _, res0 = unlimited
        assert res.makespan > 1.01 * res0.makespan

    def test_eviction_churn_happened(self, pressured):
        eng, _ = pressured
        c = eng.kv_cache.counters
        assert c["evictions"] > 0 and c["refills"] > 0
        assert c["refill_bytes"] > 0.0

    def test_residency_aware_beats_blind_decode_p50(self, pressured,
                                                    blind):
        _, res = pressured
        _, resb = blind
        assert res.ttft_stats()["itl_p50"] < resb.ttft_stats()["itl_p50"]

    def test_all_requests_complete(self, pressured):
        eng, res = pressured
        assert all(r.finish is not None for r in res.requests)
        # every hot slot went back to the pool at completion
        assert eng.kv_cache.allocated_slots() == ()

    def test_metrics_counters(self, cfg):
        from repro.obs import disable_metrics, enable_metrics
        reg = enable_metrics()
        try:
            _online(cfg, kv_hot_blocks=10, kv_block_tokens=8)
            snap = reg.snapshot()["counters"]
        finally:
            disable_metrics()
            reg.clear()
        for name in ("online_kv_evictions_total",
                     "online_kv_refills_total",
                     "online_kv_refill_bytes_total"):
            assert sum(e["value"] for e in snap[name]) > 0, name

    def test_deterministic_given_seed_and_arrivals(self, cfg, pressured):
        eng1, res1 = pressured
        eng2, res2 = _online(cfg, kv_hot_blocks=10, kv_block_tokens=8)
        assert eng2.kv_cache.trace_digest() == eng1.kv_cache.trace_digest()
        assert res2.makespan == res1.makespan

    def test_oversized_request_rejected_up_front(self, cfg):
        eng = OnlineServingEngine(cfg, max_new_tokens=16,
                                  kv_hot_blocks=2, kv_block_tokens=8)
        with pytest.raises(ValueError, match="working set"):
            eng.run(DeterministicArrivals(gap=0.0, n=2,
                                          prompt_lengths=(64, 64)))

    def test_kv_commit_steps_validated(self, cfg):
        with pytest.raises(ValueError, match="kv_commit_steps"):
            OnlineServingEngine(cfg, kv_commit_steps=0)


class TestSpanLogUnderChurn:
    """Satellite: the cross-epoch SpanLog stays coherent through
    eviction churn — markers present, every chain still closes."""

    def test_markers_emitted(self, pressured):
        _, res = pressured
        phases = {s.phase for s in res.span_log}
        assert "kv_evicted" in phases and "kv_refill" in phases

    def test_validate_clean_under_churn(self, pressured, blind):
        for _, res in (pressured, blind):
            assert res.span_log.validate() == []

    def test_marks_attach_to_live_requests(self, pressured):
        """No kv mark after a request's completion: eviction victims
        are always still-running streams."""
        _, res = pressured
        complete = {}
        for s in res.span_log:
            if s.phase == "complete":
                complete[s.request] = s.end
        for s in res.span_log:
            if s.phase in ("kv_evicted", "kv_refill"):
                assert s.end <= complete[s.request] + 1e-6

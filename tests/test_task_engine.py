"""MatMulTask (Table 1) + the asyncMatMul/checkMatmul programming model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AsyncMatmulEngine, DataType, MatMulTask, Status,
                        pipelined_fused_matmul, tile_tasks)


class TestTask:
    def test_degenerate_rejected(self):
        with pytest.raises(ValueError):
            MatMulTask(m=0, n=4, k=4)

    def test_default_strides_dense(self):
        t = MatMulTask(m=8, n=16, k=32)
        assert (t.stride_a, t.stride_b, t.stride_c) == (32, 16, 16)

    def test_flops_bytes(self):
        t = MatMulTask(m=8, n=16, k=32, data_type=DataType.INT8)
        assert t.flops == 2 * 8 * 16 * 32
        assert t.in_bytes == 8 * 32 + 32 * 16

    def test_tiling_covers_matrix(self):
        t = MatMulTask(m=100, n=70, k=64)
        tiles = tile_tasks(t, 32, 32)
        assert len(tiles) == 4 * 3
        assert sum(s.m * s.n for s in tiles) == 100 * 70
        # edge tiles keep true extents
        assert {s.m for s in tiles} == {32, 4}
        assert {s.n for s in tiles} == {32, 6}


class TestEngine:
    def test_dispatch_is_lazy_wait_forces(self):
        eng = AsyncMatmulEngine()
        a = jnp.ones((8, 16), jnp.float32)
        b = jnp.ones((16, 4), jnp.float32)
        task = MatMulTask(m=8, n=4, k=16, data_type=DataType.FP32)
        h = eng.dispatch(task, a, b)
        assert task.status == Status.RUNNING
        assert not eng.check(h)
        out = eng.wait(h)
        assert eng.check(h)
        assert task.status == Status.DONE
        np.testing.assert_allclose(np.asarray(out), 16.0)

    def test_shape_mismatch_rejected(self):
        eng = AsyncMatmulEngine()
        with pytest.raises(ValueError):
            eng.dispatch(MatMulTask(m=9, n=4, k=16),
                         jnp.ones((8, 16)), jnp.ones((16, 4)))

    def test_drain(self):
        eng = AsyncMatmulEngine()
        a = jnp.ones((4, 8), jnp.float32)
        b = jnp.ones((8, 4), jnp.float32)
        for _ in range(3):
            eng.dispatch(MatMulTask(m=4, n=4, k=8, data_type=DataType.FP32),
                         a, b)
        outs = eng.drain()
        assert len(outs) == 3

    def test_handle_reads_status_register(self):
        """check/done polls the task's Status word, not private handle
        state — a handle and its task can never disagree."""
        eng = AsyncMatmulEngine()
        a = jnp.ones((4, 8), jnp.float32)
        b = jnp.ones((8, 4), jnp.float32)
        task = MatMulTask(m=4, n=4, k=8, data_type=DataType.FP32)
        h = eng.dispatch(task, a, b)
        assert task.status is Status.RUNNING and not h.done()
        task.status = Status.DONE            # hardware flips the register
        assert h.done() and eng.check(h)
        task.status = Status.RUNNING
        assert not h.done()
        eng.wait(h)
        assert task.status is Status.DONE and h.done()


class TestListing1Pipeline:
    def test_matches_reference(self):
        k0, k1 = jax.random.split(jax.random.PRNGKey(0))
        a = jax.random.normal(k0, (128, 64))
        b = jax.random.normal(k1, (64, 96))
        out = pipelined_fused_matmul(a, b, jax.nn.relu, tile_m=32)
        ref = jax.nn.relu(a @ b)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_under_jit(self):
        a = jnp.ones((64, 32))
        b = jnp.ones((32, 16))
        f = jax.jit(lambda a, b: pipelined_fused_matmul(
            a, b, lambda x: x * 2.0, tile_m=16))
        np.testing.assert_allclose(np.asarray(f(a, b)), 64.0)

    def test_tile_must_divide(self):
        with pytest.raises(ValueError):
            pipelined_fused_matmul(jnp.ones((10, 4)), jnp.ones((4, 4)),
                                   jax.nn.relu, tile_m=3)

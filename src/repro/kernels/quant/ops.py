"""jit'd wrapper for the row-wise quantization kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.quant.quant import quantize_rowwise_kernel


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def quantize_rowwise(x, *, block_m: int = 256, interpret: bool = True):
    """x: (M, K) -> (q int8 (M, K), scale f32 (M,))."""
    m, k = x.shape
    bm = min(block_m, m)
    pad = (-m) % bm
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    mp = xp.shape[0]

    q, scale = pl.pallas_call(
        quantize_rowwise_kernel,
        grid=(mp // bm,),
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0)),
                   pl.BlockSpec((bm,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((mp, k), jnp.int8),
                   jax.ShapeDtypeStruct((mp,), jnp.float32)],
        interpret=interpret,
    )(xp)
    return q[:m], scale[:m]

"""Production mesh builders + jax-version compatibility shims.

Functions, not module-level constants: importing this module never
touches jax device state.  Single pod: 16×16 = 256 chips (data, model);
multi-pod: 2×16×16 = 512 chips with an explicit "pod" axis that the
default sharding rules fold into data parallelism (DESIGN.md §3).

``compat_make_mesh`` / ``compat_abstract_mesh`` paper over the
``AxisType`` / ``AbstractMesh`` API churn between jax 0.4.x and newer
releases so the same code (and tests) run on both.
"""

from __future__ import annotations

import inspect

import jax

try:  # newer jax
    from jax.sharding import AxisType
except ImportError:  # jax <= 0.4.x has no explicit/auto axis types
    AxisType = None


def compat_make_mesh(shape, axes):
    """``jax.make_mesh`` across versions (``axis_types`` kwarg is newer
    jax; ``jax.make_mesh`` itself is absent before 0.4.35)."""
    shape, axes = tuple(shape), tuple(axes)
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes)
    from jax.experimental import mesh_utils
    from jax.sharding import Mesh
    return Mesh(mesh_utils.create_device_mesh(shape), axes)


def compat_abstract_mesh(sizes, names):
    """``AbstractMesh`` across the (sizes, names) vs shape_tuple signatures."""
    from jax.sharding import AbstractMesh
    params = list(inspect.signature(AbstractMesh.__init__).parameters)
    if "shape_tuple" in params:  # jax 0.4.x: tuple of (name, size) pairs
        return AbstractMesh(tuple(zip(names, sizes)))
    return AbstractMesh(tuple(sizes), tuple(names))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Debug mesh over whatever devices exist (tests / examples)."""
    n = jax.device_count()
    model = min(model, n)
    return compat_make_mesh((n // model, model), ("data", "model"))

"""gemma2-2b [dense]: 26L d=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.

Local(4096)+global alternating attention, attn softcap 50, final logit
softcap 30, GeGLU, sandwich RMSNorms with unit offset, tied & scaled
embeddings.  [arXiv:2408.00118; hf]
"""

from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="transformer",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    window=4096,
    layer_pattern="gemma2_alt",
    attn_softcap=50.0,
    final_softcap=30.0,
    query_scale=256 ** -0.5,
    mlp_activation="gelu_tanh",
    mlp_glu=True,
    sandwich_norms=True,
    rmsnorm_unit_offset=True,
    embed_scale=True,
    tie_embeddings=True,
)


def reduced() -> ArchConfig:
    """Smoke-test config: same family wiring, tiny dims."""
    return CONFIG.with_(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                        head_dim=16, d_ff=128, vocab_size=512, window=16,
                        attn_chunk=32)

"""Blockwise (flash) attention Pallas kernel for the model zoo.

Attention is the second GEMM hot-spot the paper's fusion argument applies
to: QK^T and PV are matrix-unit work while softmax (exp + the divide the
paper calls out as expensive on vector units, §5.4) is vector work.  The
online-softmax formulation interleaves them at block granularity — the
same matrix/vector software pipeline as Listing 1, realised in VMEM.

Features needed by the assigned architectures:
  * causal masking (all decoder LMs),
  * local sliding-window masking (gemma2 alternating layers, window 4096;
    recurrentgemma local-attention blocks, window 2048),
  * logit soft-capping (gemma2: 50.0 on attention scores),
  * GQA — H query heads share H_kv KV heads,
  * key-padding mask (``seq_len_k``) so the wrapper can pad freely,
  * ``q_start`` offset for chunked prefill.

Grid: (B·H, Sq/bq, Sk/bkv), KV innermost; online-softmax stats (m, l)
and the output accumulator live in VMEM scratch across the KV sweep.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
_STATS_LANES = 128     # m/l stats replicated across one lane register


def flash_attention_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                           *, sm_scale: float, causal: bool, window: int,
                           softcap: float, seq_len_k: int, q_start: int,
                           n_kv: int, bq: int, bkv: int):
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)              # (bq, d)
    k = k_ref[0].astype(jnp.float32)              # (bkv, d)
    v = v_ref[0].astype(jnp.float32)              # (bkv, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    qpos = q_start + pl.program_id(1) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bkv), 0)
    kpos = jk * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = kpos < seq_len_k
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= (qpos - kpos) < window

    s_masked = jnp.where(mask, s, NEG_INF)
    m_prev = m_ref[:, :1]                         # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s_masked, axis=1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)  # (bq, bkv)
    alpha = jnp.exp(m_prev - m_new)               # (bq, 1)

    l_ref[...] = alpha * l_ref[...] + jnp.broadcast_to(
        jnp.sum(p, axis=1, keepdims=True), l_ref.shape)
    acc_ref[...] = alpha * acc_ref[...] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(jk == n_kv - 1)
    def _finish():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)           # fully-masked rows -> 0
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)

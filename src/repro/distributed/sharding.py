"""Parameter / batch / cache sharding rules (divisibility-aware).

Maps every parameter leaf to logical axes by its name, then through the
active ``logical`` rules to a ``NamedSharding``.  Megatron-style TP falls
out of the name map: QKV and MLP-in shard their *output* column (column
parallel), attention-out and MLP-out shard their *input* row (row
parallel), so each transformer block costs one all-reduce in forward.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import logical
from repro.models.base import ArchConfig

#: leaf name -> logical axes (matched on the last path component).
_NAME_RULES: "dict[str, tuple]" = {
    "embedding": ("vocab", "embed"),
    "lm_head": ("embed", "vocab"),
    "wq": ("embed", "heads"),        # column parallel
    "wk": ("embed", "kv_heads"),
    "wv": ("embed", "kv_heads"),
    "wo": ("heads", "embed"),        # row parallel
    "wi": ("embed", "mlp"),          # column parallel (GLU keeps 2x cols)
    "w_router": ("embed", None),     # replicated router
    "experts_wi": ("experts", "embed", "mlp_expert"),
    "experts_wo": ("experts", "mlp_expert", "embed"),
    # Griffin recurrent block.
    "w_rnn_in": ("embed", "mlp"),
    "w_gate_in": ("embed", "mlp"),
    "w_rnn_out": ("mlp", "embed"),
    # RWKV time-mix projections.
    "w_r": ("embed", "heads"),
    "w_k": ("embed", "heads"),
    "w_v": ("embed", "heads"),
    "w_g": ("embed", "heads"),
    "w_o": ("heads", "embed"),
    "w_cm_k": ("embed", "mlp"),
    "w_cm_v": ("mlp", "embed"),
    "w_cm_r": ("embed", "mlp"),
}
# mlp wo: name collision with attention wo is fine — both are row parallel
# with the sharded dim first.


def _leaf_logical_axes(path, leaf) -> "tuple | None":
    name = None
    for part in reversed(path):
        key = getattr(part, "key", getattr(part, "name", None))
        if isinstance(key, str):
            name = key
            break
    if name in _NAME_RULES:
        axes = _NAME_RULES[name]
        if len(axes) == leaf.ndim:
            return axes
        # Stacked-over-layers leaves get a leading (replicated) layer dim.
        if len(axes) == leaf.ndim - 1:
            return (None,) + axes
        if len(axes) == leaf.ndim - 2:
            return (None, None) + axes
    return None


def param_shardings(params, mesh: Optional[Mesh], rules: Optional[dict] = None):
    """NamedSharding pytree for a (possibly abstract) param pytree."""
    if mesh is None:
        return jax.tree.map(lambda _: None, params)
    with logical.use_rules(mesh, rules):
        def one(path, leaf):
            axes = _leaf_logical_axes(path, leaf)
            if axes is None:
                return NamedSharding(mesh, P())      # replicate
            s = logical.sharding_for(leaf.shape, axes)
            return s if s is not None else NamedSharding(mesh, P())
        return jax.tree_util.tree_map_with_path(one, params)


def batch_shardings(batch, mesh: Optional[Mesh], rules: Optional[dict] = None):
    """Shard the leading (batch) dim of every input leaf over (pod, data)."""
    if mesh is None:
        return jax.tree.map(lambda _: None, batch)
    with logical.use_rules(mesh, rules):
        def one(leaf):
            axes = ("batch",) + (None,) * (leaf.ndim - 1)
            s = logical.sharding_for(leaf.shape, axes)
            return s if s is not None else NamedSharding(mesh, P())
        return jax.tree.map(one, batch)


def cache_shardings(cache, mesh: Optional[Mesh], cfg: ArchConfig,
                    rules: Optional[dict] = None):
    """KV caches: batch over (pod, data); the model axis takes the KV-head
    dim when it divides, else the cache *sequence* dim (sequence-parallel
    decode attention: scores/softmax/PV reduce over the sharded S with a
    single all-reduce — how a 2 TB 32k cache fits 16 GB chips when
    n_kv_heads < model size, e.g. deepseek-67b kv=8 on model=16)."""
    if mesh is None:
        return jax.tree.map(lambda _: None, cache)
    model = mesh.shape.get("model", 1)
    with logical.use_rules(mesh, rules):
        def one(leaf):
            if leaf.ndim == 5:
                # (L, B, Hkv, S, D) KV cache or (L, B, H, C, C) rwkv state.
                heads, seq = leaf.shape[2], leaf.shape[3]
                if heads % model == 0:
                    axes = (None, "batch", "kv_heads", None, None)
                elif seq % model == 0:
                    axes = (None, "batch", None, "heads", None)
                else:
                    axes = (None, "batch", None, None, None)
            elif leaf.ndim >= 2:
                axes = (None, "batch") + (None,) * (leaf.ndim - 2)
            else:
                axes = (None,) * leaf.ndim
            s = logical.sharding_for(leaf.shape, axes)
            return s if s is not None else NamedSharding(mesh, P())
        return jax.tree.map(one, cache)


def apply_shardings(tree, shardings):
    """Attach shardings to ShapeDtypeStructs (dry-run) or device_put (real)."""
    def one(x, s):
        if isinstance(x, jax.ShapeDtypeStruct):
            return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s)
        return x if s is None else jax.device_put(x, s)
    return jax.tree.map(one, tree, shardings)

"""Area / power model calibrated to paper Table 7 (14 nm, 2 GHz).

Table 7 for the 4 TOPS case study (4×4 PEs × 512-bit reduce = 1024 int8
MACs; ~96 KiB of scratchpad incl. double buffers and the fp32 accumulator
bank plus loader/reorder FIFOs):

    RAM    0.164 mm²   0.784 W
    Logic  0.367 mm²   0.722 W
    Total  0.531 mm²   1.506 W

We fit a two-parameter linear model (area/bit of SRAM, area/MAC of
datapath+control) on that single calibration point and use it to predict
the cost of other configurations — in particular the Eq.2-saturating
128×128 scratchpad variant explored in EXPERIMENTS.md §Perf (hardware
side), and the 0.5–32 TOPS envelope of §1.
"""

from __future__ import annotations

import dataclasses

from repro.core.config import CASE_STUDY, MatrixUnitConfig
from repro.core.precision import DataType

# Calibration constants derived from Table 7 / the case-study config.
_CASE_BITS = CASE_STUDY.scratchpad_bytes() * 8          # scratchpad bits
_FIFO_OVERHEAD = 1.25                                   # loader/reorder FIFOs
_RAM_MM2_PER_BIT = 0.164 / (_CASE_BITS * _FIFO_OVERHEAD)
_CASE_MACS = CASE_STUDY.macs_per_cycle(DataType.INT8)   # 1024 int8 MACs
_LOGIC_MM2_PER_MAC = 0.367 / _CASE_MACS
_RAM_W_PER_BIT = 0.784 / (_CASE_BITS * _FIFO_OVERHEAD)
_LOGIC_W_PER_MAC = 0.722 / _CASE_MACS


@dataclasses.dataclass(frozen=True)
class AreaPower:
    ram_mm2: float
    logic_mm2: float
    ram_w: float
    logic_w: float

    @property
    def total_mm2(self) -> float:
        return self.ram_mm2 + self.logic_mm2

    @property
    def total_w(self) -> float:
        return self.ram_w + self.logic_w


def estimate(cfg: MatrixUnitConfig) -> AreaPower:
    bits = cfg.scratchpad_bytes() * 8 * _FIFO_OVERHEAD
    macs = cfg.macs_per_cycle(DataType.INT8)
    freq_scale = cfg.freq_hz / CASE_STUDY.freq_hz    # dynamic power ~ f
    return AreaPower(
        ram_mm2=bits * _RAM_MM2_PER_BIT,
        logic_mm2=macs * _LOGIC_MM2_PER_MAC,
        ram_w=bits * _RAM_W_PER_BIT * freq_scale,
        logic_w=macs * _LOGIC_W_PER_MAC * freq_scale,
    )

"""Fused GEMM + epilogue Pallas kernel — the TPU body of CUTEv2.

This kernel *is* the paper's matrix unit, re-expressed for the TPU
memory hierarchy:

* the fp32/int32 accumulator tile lives in VMEM scratch across the whole
  K sweep — the paper's output-stationary, accumulator-resident
  scratchpad (§4.1);
* the Pallas grid pipeline double-buffers A/B block DMA against MXU
  compute — the paper's multi-bank scratchpad + Memory Loader;
* the epilogue (dequant scales, bias zero/row/full, soft-cap,
  activation, GLU gating, residual) executes on the VPU *inside* the
  same kernel while the MXU pipeline streams the next tiles — the
  paper's matrix–vector overlap (Fig. 5), realised without an HBM
  round-trip for the intermediate;
* tile sizes come from ``core.constraint.solve_tiles`` — Eq. 2 with HBM
  bandwidth and MXU throughput substituted in.

Supported input precisions (paper §4.1): int8 (int32 accumulate),
fp8 e4m3/e5m2, fp16, bf16 (fp32 accumulate), fp32.  TF32 maps to fp32
(DESIGN.md §2).

Operand layout for GLU epilogues: ``b`` is passed as ``(K, 2, N/2)`` —
gate and up projections interleaved on a leading sub-axis so one output
tile sees both halves (the wrapper reshapes a concatenated ``(K, N)``
weight).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.fusion import Epilogue, EpilogueOperands, apply_epilogue
from repro.core.task import BiasType


def fused_matmul_kernel(*refs, ep: Epilogue, n_k: int, acc_dtype):
    """Kernel body.  refs = a, b, [bias], [scale_a], [scale_b], [residual],
    o, acc_scratch — optional operands present iff the epilogue uses them.
    Grid: (m_tiles, n_tiles, k_tiles), K innermost ('arbitrary')."""
    it = iter(refs)
    a_ref = next(it)
    b_ref = next(it)
    bias_ref = next(it) if ep.bias_type != BiasType.ZERO else None
    scale_a_ref = next(it) if ep.has_scale_a else None
    scale_b_ref = next(it) if ep.has_scale_b else None
    residual_ref = next(it) if ep.has_residual else None
    o_ref = next(it)
    acc_ref = next(it)

    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    if ep.glu:
        # (bk, 2, bn/2) -> (bk, bn): gate columns then up columns.
        b = b.reshape(b.shape[0], -1)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=acc_dtype)

    @pl.when(k == n_k - 1)
    def _epilogue():
        def _flat(ref):
            # ROW bias / scale_b arrive as (2, bn/2) blocks under GLU
            # (they ride the same (K, 2, N/2) column split as ``b``).
            if ref is None:
                return None
            x = ref[...]
            return x.reshape(-1) if (ep.glu and x.ndim == 2) else x

        ops = EpilogueOperands(
            bias=_flat(bias_ref),
            scale_a=None if scale_a_ref is None else scale_a_ref[...],
            scale_b=_flat(scale_b_ref),
            residual=None if residual_ref is None else residual_ref[...],
        )
        o_ref[...] = apply_epilogue(acc_ref[...], ep, ops)

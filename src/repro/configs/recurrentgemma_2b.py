"""recurrentgemma-2b [hybrid]: 26L d=2560 10H (MQA kv=1) d_ff=7680.

Griffin: (rec, rec, local-attn) repeating — RG-LRU recurrent blocks with
short causal conv, local MQA window 2048, GeGLU MLP after every temporal
block, gemma-style unit-offset RMSNorm, tied + scaled embeddings, final
logit softcap 30.  Bounded state ⇒ runs long_500k.  [arXiv:2402.19427; hf]
"""

from repro.models.base import ArchConfig, RnnConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="griffin",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    window=2048,
    final_softcap=30.0,
    mlp_activation="gelu_tanh",
    mlp_glu=True,
    rmsnorm_unit_offset=True,
    embed_scale=True,
    tie_embeddings=True,
    rnn=RnnConfig(d_rnn=2560, conv_width=4),
)


def reduced() -> ArchConfig:
    return CONFIG.with_(n_layers=5, d_model=64, n_heads=4, n_kv_heads=1,
                        head_dim=16, d_ff=128, vocab_size=512, window=16,
                        attn_chunk=32, rnn=RnnConfig(d_rnn=64, conv_width=4))

"""Production mesh builders.

Functions, not module-level constants: importing this module never
touches jax device state.  Single pod: 16×16 = 256 chips (data, model);
multi-pod: 2×16×16 = 512 chips with an explicit "pod" axis that the
default sharding rules fold into data parallelism (DESIGN.md §3).
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """Debug mesh over whatever devices exist (tests / examples)."""
    n = jax.device_count()
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))

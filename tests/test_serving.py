"""Serving engine: greedy generation consistency + batching façade."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import concrete_batch, get_config
from repro.models.base import family_module
from repro.serving.engine import GenerateResult, ServingEngine, generate


def _cfg(name="yi-6b"):
    return get_config(name, reduced=True).with_(
        remat="none", dtype=jnp.float32, kv_cache_dtype=jnp.float32)


@pytest.fixture(scope="module")
def model():
    cfg = _cfg()
    mod = family_module(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    return cfg, mod, params


def test_greedy_generation_matches_forward_argmax(model):
    """Decode-loop greedy tokens == teacher-forced argmax re-derivation."""
    cfg, mod, params = model
    prompt = concrete_batch(cfg, 2, 12, "prefill")
    res = generate(cfg, params, prompt, max_new_tokens=4)
    assert res.tokens.shape == (2, 4)

    # Re-derive: append generated tokens and check each was the argmax of
    # the forward logits at its position.
    toks = jnp.concatenate([prompt["tokens"], res.tokens], axis=1)
    logits = mod.forward(cfg, params, {"tokens": toks})
    for i in range(4):
        expect = jnp.argmax(logits[:, 12 + i - 1], axis=-1)
        np.testing.assert_array_equal(np.asarray(res.tokens[:, i]),
                                      np.asarray(expect))


def test_generate_deterministic_at_zero_temperature(model):
    cfg, mod, params = model
    prompt = concrete_batch(cfg, 1, 8, "prefill")
    a = generate(cfg, params, prompt, max_new_tokens=3)
    b = generate(cfg, params, prompt, max_new_tokens=3)
    np.testing.assert_array_equal(np.asarray(a.tokens), np.asarray(b.tokens))


def test_temperature_sampling_runs(model):
    cfg, mod, params = model
    prompt = concrete_batch(cfg, 2, 8, "prefill")
    res = generate(cfg, params, prompt, max_new_tokens=3, temperature=1.0,
                   key=jax.random.PRNGKey(7))
    assert res.tokens.shape == (2, 3)
    assert bool(jnp.all((res.tokens >= 0)
                        & (res.tokens < cfg.padded_vocab)))


def test_serving_engine_batches_requests(model):
    cfg, mod, params = model
    eng = ServingEngine(cfg, params, max_batch=2, cache_len=64)
    for length in (5, 7, 6):
        eng.submit(jnp.arange(length) % cfg.vocab_size)
    outs = eng.run(max_new_tokens=3)
    assert len(outs) == 3
    for o in outs:
        assert o.shape == (3,)


def test_generate_on_stateful_family():
    """RWKV-family generation exercises the O(1)-state serving path."""
    cfg = _cfg("rwkv6-7b")
    mod = family_module(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    prompt = concrete_batch(cfg, 1, 8, "prefill")
    res = generate(cfg, params, prompt, max_new_tokens=3)
    toks = jnp.concatenate([prompt["tokens"], res.tokens], axis=1)
    logits = mod.forward(cfg, params, {"tokens": toks})
    for i in range(3):
        expect = jnp.argmax(logits[:, 8 + i - 1], axis=-1)
        np.testing.assert_array_equal(np.asarray(res.tokens[:, i]),
                                      np.asarray(expect))

"""Shared layers for the model zoo.

Every matmul in this file goes through ``core.fusion`` (``cute_matmul`` /
``linear``) so the paper's fused-epilogue contract applies framework-wide.
Attention offers three implementations:

* ``xla``    — chunked online-softmax in pure jnp (lax.scan over KV
  blocks).  This is the distributed/dry-run path: HLO stays compact at
  32k+ context, FLOPs are visible to ``cost_analysis``, GSPMD shards it.
* ``pallas`` — the ``kernels/attention`` flash kernel (interpret-mode on
  CPU; the on-chip path on real TPUs).
* ``dense``  — the reference oracle, for tiny smoke tests only.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.fusion import linear
from repro.distributed.logical import constrain
from repro.models.base import ArchConfig


# ---------------------------------------------------------------------------
# Initializers.
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, in_axis: int = 0):
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape) / jnp.sqrt(fan_in)).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps: float = 1e-6, unit_offset: bool = False):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if unit_offset else w.astype(jnp.float32)
    return (y * scale).astype(dt)


def layernorm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def groupnorm_heads(x, w, b, n_heads: int, eps: float = 64e-5):
    """RWKV ln_x: GroupNorm over head groups of the flattened channel dim."""
    dt = x.dtype
    *lead, c = x.shape
    xf = x.astype(jnp.float32).reshape(*lead, n_heads, c // n_heads)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(*lead, c)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE.
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """x: (B, H, S, D); positions: (S,) or (B, S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    pos = positions.astype(jnp.float32)
    angles = pos[..., None] * freqs                    # (..., S, D/2)
    if angles.ndim == 2:                               # (S, D/2) -> broadcast
        angles = angles[None, None]
    else:                                              # (B, S, D/2)
        angles = angles[:, None]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention.
# ---------------------------------------------------------------------------

def attention_xla_chunked(q, k, v, *, sm_scale, causal=True, window=0,
                          softcap=0.0, q_start=0, chunk=1024,
                          pv_bf16=False):
    """Online-softmax attention, lax.scan over KV chunks (flash-in-XLA).

    q: (B, H, Sq, D); k/v: (B, Hkv, Sk, D).  Peak live memory is one
    (B, H, Sq, chunk) score block instead of (B, H, Sq, Sk).
    ``pv_bf16`` keeps the probability block in bf16 for the P·V product
    (fp32 accumulation) — halves the dominant transient buffer (§Perf).
    """
    b, h, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    group = h // hkv
    chunk = min(chunk, sk)
    pad = (-sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    n_chunks = (sk + pad) // chunk
    qf = q.astype(jnp.float32) * sm_scale
    qf = qf.reshape(b, hkv, group * sq, d)             # fold GQA into rows
    kc = jnp.moveaxis(k.reshape(b, hkv, n_chunks, chunk, d), 2, 0)
    vc = jnp.moveaxis(v.reshape(b, hkv, n_chunks, chunk, d), 2, 0)

    qpos = q_start + jnp.tile(jnp.arange(sq), group)   # (group*Sq,)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def body(carry, inp):
        # Remat per KV chunk: the backward pass recomputes scores instead
        # of saving (B, H, Sq, chunk) residuals for every chunk step —
        # this is what makes 32k-context backward fit (§Perf memory term).
        m, l, acc, j = carry
        kj, vj = inp
        s = jnp.einsum("bnqd,bnkd->bnqk", qf, kj.astype(jnp.float32))
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        kpos = j * chunk + jnp.arange(chunk)
        mask = kpos[None, :] < sk
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window > 0:
            mask &= (qpos[:, None] - kpos[None, :]) < window
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(mask[None, None], jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m - m_new)
        l = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
        if pv_bf16:
            pv = jnp.einsum("bnqk,bnkd->bnqd", p.astype(jnp.bfloat16),
                            vj.astype(jnp.bfloat16),
                            preferred_element_type=jnp.float32)
        else:
            pv = jnp.einsum("bnqk,bnkd->bnqd", p, vj.astype(jnp.float32))
        acc = alpha * acc + pv
        return (m_new, l, acc, j + 1), None

    init = (jnp.full((b, hkv, group * sq, 1), -1e30, jnp.float32),
            jnp.zeros((b, hkv, group * sq, 1), jnp.float32),
            jnp.zeros((b, hkv, group * sq, d), jnp.float32),
            jnp.int32(0))
    (m, l, acc, _), _ = jax.lax.scan(body, init, (kc, vc))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l).reshape(b, h, sq, d)
    return out.astype(q.dtype)


def attention(cfg: ArchConfig, q, k, v, *, causal=True, window=0,
              softcap=None, q_start=0, sm_scale=None):
    """Backend-dispatching attention. q: (B, H, S, D), k/v: (B, Hkv, S, D)."""
    sm_scale = cfg.sm_scale if sm_scale is None else sm_scale
    softcap = cfg.attn_softcap if softcap is None else softcap
    if cfg.backend == "pallas":
        from repro.kernels.attention.ops import flash_attention
        return flash_attention(q, k, v, sm_scale=sm_scale, causal=causal,
                               window=window, softcap=softcap,
                               q_start=q_start)
    if cfg.backend == "dense":
        from repro.kernels.attention.ref import attention_ref
        return attention_ref(q, k, v, sm_scale=sm_scale, causal=causal,
                             window=window, softcap=softcap, q_start=q_start)
    return attention_xla_chunked(q, k, v, sm_scale=sm_scale, causal=causal,
                                 window=window, softcap=softcap,
                                 q_start=q_start, chunk=cfg.attn_chunk,
                                 pv_bf16=cfg.attn_pv_bf16)


# ---------------------------------------------------------------------------
# Attention block parameters + apply (GQA, optional bias / qk-norm / RoPE).
# ---------------------------------------------------------------------------

def attn_init(cfg: ArchConfig, key, *, d_in: Optional[int] = None):
    d = d_in if d_in is not None else cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "wq": dense_init(ks[0], (d, cfg.q_dim), cfg.dtype),
        "wk": dense_init(ks[1], (d, cfg.kv_dim), cfg.dtype),
        "wv": dense_init(ks[2], (d, cfg.kv_dim), cfg.dtype),
        "wo": dense_init(ks[3], (cfg.q_dim, d), cfg.dtype, in_axis=1),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), cfg.dtype)
        p["bk"] = jnp.zeros((cfg.kv_dim,), cfg.dtype)
        p["bv"] = jnp.zeros((cfg.kv_dim,), cfg.dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), cfg.dtype)
        p["k_norm"] = jnp.ones((cfg.head_dim,), cfg.dtype)
    return p


def qkv_project(cfg: ArchConfig, p, x, positions):
    """x: (B, S, d) -> q (B, H, S, hd), k/v (B, Hkv, S, hd) with RoPE."""
    b, s, _ = x.shape
    q = linear(x, p["wq"], p.get("bq"), backend=_mm_backend(cfg))
    k = linear(x, p["wk"], p.get("bk"), backend=_mm_backend(cfg))
    v = linear(x, p["wv"], p.get("bv"), backend=_mm_backend(cfg))
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.rms_eps)
        k = rmsnorm(k, p["k_norm"], cfg.rms_eps)
    if positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", "heads", "seq", None))
    k = constrain(k, ("batch", "kv_heads", "seq", None))
    v = constrain(v, ("batch", "kv_heads", "seq", None))
    return q, k, v


def attn_out(cfg: ArchConfig, p, ctx):
    """ctx: (B, H, S, hd) -> (B, S, d)."""
    b, h, s, hd = ctx.shape
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    return linear(ctx, p["wo"], backend=_mm_backend(cfg))


def _mm_backend(cfg: ArchConfig) -> str:
    # The zoo's matmul route is a registry lookup: repro.backend's
    # set_default_matmul_backend re-routes every projection here.  The
    # default stays on eager XLA because Pallas matmul everywhere is too
    # slow under interpret mode on CPU for whole-model tests; per-kernel
    # coverage lives in tests/.  cfg.backend routes *attention* through
    # the flash kernel.
    from repro.backend import matmul_backend_string
    return matmul_backend_string()


# ---------------------------------------------------------------------------
# MLP.
# ---------------------------------------------------------------------------

def mlp_init(cfg: ArchConfig, key, d_ff: Optional[int] = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 2)
    mult = 2 if cfg.mlp_glu else 1
    return {
        "wi": dense_init(ks[0], (d, mult * ff), cfg.dtype),
        "wo": dense_init(ks[1], (ff, d), cfg.dtype, in_axis=1),
    }


def mlp_apply(cfg: ArchConfig, p, x):
    h = linear(x, p["wi"], activation=cfg.mlp_activation, glu=cfg.mlp_glu,
               backend=_mm_backend(cfg))
    h = constrain(h, ("batch", "seq", "mlp"))
    return linear(h, p["wo"], backend=_mm_backend(cfg))


# ---------------------------------------------------------------------------
# Embedding / logits.
# ---------------------------------------------------------------------------

def embed_tokens(cfg: ArchConfig, embedding, tokens):
    x = embedding[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
    return x


def logits_out(cfg: ArchConfig, params, x):
    w = (params["embedding"].T if cfg.tie_embeddings
         else params["lm_head"])
    y = linear(x, w, softcap=cfg.final_softcap, out_dtype=jnp.float32,
               backend=_mm_backend(cfg))
    return constrain(y, ("batch", "seq", "vocab") if y.ndim == 3
                     else ("batch", "vocab"))


# ---------------------------------------------------------------------------
# KV cache helpers (dense ring buffer, optionally quantized dtype).
# ---------------------------------------------------------------------------

def cache_update(k_cache, v_cache, k_new, v_new, pos):
    """Write (B, Hkv, S_new, D) at position ``pos`` along the S axis."""
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), pos, axis=2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), pos, axis=2)
    return k_cache, v_cache


def remat_policy(cfg: ArchConfig):
    if cfg.remat == "none":
        return None
    if cfg.remat == "dots":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return jax.checkpoint_policies.nothing_saveable

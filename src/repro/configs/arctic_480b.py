"""arctic-480b [moe]: 35L d=7168 56H (GQA kv=8), MoE 128e top-2 + dense.

Snowflake Arctic: dense transformer residual in parallel with a
128-expert top-2 MoE (dense-MoE hybrid).  d_ff=4864 per expert; the
parallel dense branch uses the same hidden size (the assignment only
specifies 4864).  vocab 32000.  [hf:Snowflake/snowflake-arctic-base]
"""

from repro.models.base import ArchConfig, MoeConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="transformer",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    mlp_activation="silu",
    mlp_glu=True,
    moe=MoeConfig(n_experts=128, top_k=2, d_ff_expert=4864,
                  capacity_factor=1.25, renormalize=True,
                  dense_parallel=True),
)


def reduced() -> ArchConfig:
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        head_dim=16, d_ff=96, vocab_size=512, attn_chunk=32,
                        moe=MoeConfig(n_experts=8, top_k=2, d_ff_expert=96,
                                      capacity_factor=4.0, renormalize=True,
                                      dense_parallel=True))

"""Fault-tolerant checkpointing: atomic, async, keep-N, mesh-elastic.

Layout (one directory per step)::

    <root>/step_000123/
        index.json          # treedef paths, shapes, dtypes, extra state
        0000.npy … NNNN.npy # one host-np array per leaf

Guarantees:
  * **atomic** — written to ``step_..._tmp`` then ``os.rename``d; readers
    never observe partial checkpoints, and a crash mid-save leaves the
    previous step intact (restart-safety).
  * **async** — ``save_async`` snapshots leaves to host memory on the
    caller's thread, then writes on a background thread so the training
    loop overlaps I/O with compute (checkpoint stall ≈ device→host copy).
  * **elastic** — leaves are stored *unsharded*; ``restore`` device_puts
    them with whatever shardings the *new* mesh prescribes, so a 256-chip
    checkpoint restores onto 512 chips (or 8) unchanged.
  * **keep-N** — old steps garbage-collected after a successful save.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        key = getattr(p, "key", getattr(p, "idx", getattr(p, "name", None)))
        parts.append(str(key))
    return "/".join(parts)


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        self._write(step, self._snapshot(tree), extra or {})

    def save_async(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        snap = self._snapshot(tree)          # device->host before returning
        self._thread = threading.Thread(
            target=self._write, args=(step, snap, extra or {}), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _snapshot(self, tree):
        leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
        return ([( _path_str(p), np.asarray(jax.device_get(x)))
                 for p, x in leaves_with_paths], treedef)

    def _write(self, step: int, snap, extra: dict):
        leaves, _ = snap
        final = os.path.join(self.root, f"step_{step:08d}")
        tmp = final + "_tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        index = {"step": step, "extra": extra, "leaves": []}
        for i, (path, arr) in enumerate(leaves):
            fn = f"{i:04d}.npy"
            np.save(os.path.join(tmp, fn), arr)
            index["leaves"].append({"path": path, "file": fn,
                                    "shape": list(arr.shape),
                                    "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "index.json"), "w") as f:
            json.dump(index, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and not d.endswith("_tmp"):
                try:
                    out.append(int(d.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, shardings: Any = None):
        """Restore into the structure of ``like``; reshard on the fly."""
        d = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(d, "index.json")) as f:
            index = json.load(f)
        arrays = [np.load(os.path.join(d, e["file"]))
                  for e in index["leaves"]]
        leaves, treedef = jax.tree_util.tree_flatten(like)
        if len(arrays) != len(leaves):
            raise ValueError(
                f"checkpoint has {len(arrays)} leaves, expected {len(leaves)}")
        if shardings is not None:
            shard_leaves = treedef.flatten_up_to(shardings)
            arrays = [jax.device_put(a, s) if s is not None else a
                      for a, s in zip(arrays, shard_leaves)]
        tree = jax.tree_util.tree_unflatten(treedef, arrays)
        return tree, index["extra"]

"""Observability: metrics registry, lifecycle spans, instrumentation.

The measurement spine of the repo (see ``docs/observability.md``):

* :mod:`repro.obs.metrics` — counters / gauges / histograms with
  ``p50/p90/p99``, JSON-snapshot and Prometheus-text exporters, behind
  a **disabled-by-default** process registry;
* :mod:`repro.obs.spans` — per-request lifecycle :class:`SpanLog`
  (``arrival → admission → prefill(.chunk_j) → decode_iter_k →
  complete``) joined from a :class:`BatchSchedule` and a priced
  timeline;
* :func:`instrument` — the shared decorator the backend wrappers put on
  ``run_graph`` / ``run_workload``: wall-clock timings into the default
  registry, one attribute check and a plain call when it is disabled.
"""

from __future__ import annotations

import functools
import time

from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               NULL_METRIC, default_registry,
                               disable_metrics, enable_metrics)
from repro.obs.spans import Span, SpanAssembler, SpanLog

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "NULL_METRIC",
    "Span", "SpanAssembler", "SpanLog", "default_registry",
    "disable_metrics", "enable_metrics", "instrument",
]


def instrument(section: str, label_attr: str = "name"):
    """Decorate a backend method with wall-clock timing metrics.

    When the default registry is enabled, each call observes its elapsed
    seconds into the ``backend_seconds`` histogram and bumps the
    ``backend_calls_total`` counter, both labeled
    ``{backend: getattr(self, label_attr), section: section}``.  When it
    is disabled — the default everywhere outside the serving/bench entry
    points — the wrapper is a single truthiness check and a plain call,
    keeping the DES hot path unburdened (the overhead is measured by
    ``benchmarks/record.py`` and held < 5%).
    """
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(self, *args, **kwargs):
            reg = default_registry()
            if not reg.enabled:
                return fn(self, *args, **kwargs)
            backend = getattr(self, label_attr, type(self).__name__)
            t0 = time.perf_counter()
            try:
                return fn(self, *args, **kwargs)
            finally:
                dt = time.perf_counter() - t0
                reg.histogram("backend_seconds", backend=backend,
                              section=section).observe(dt)
                reg.counter("backend_calls_total", backend=backend,
                            section=section).inc()
        return wrapper
    return deco

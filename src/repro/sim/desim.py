"""Discrete-event execution of a TaskGraph on an explicit machine model.

Where ``core.simulator`` asserts the overlap with a closed-form
``max(matrix, vec)``, this module *derives* it: every node of the graph
contends for five explicit resources and the timeline falls out of the
event schedule.

Machine resources (paper §4.1/§4.4):

* ``dispatcher`` — the CPU front-end.  Every ``asyncMatMul`` occupies it
  for ``platform.dispatch_cycles`` (RoCC few tens, CSR ~100, Table 3)
  and every completion poll for ``platform.check_cycles``.  It is a
  single serial resource: a slow interface genuinely backpressures the
  tile stream instead of being a term in a max().
* ``loader`` — streams A/B panels in and the C tile out at the SoC
  bandwidth derated by ``platform.dram_efficiency`` (§5.4).
* ``banks`` — the double-buffered scratchpad: ``unit.scratchpad_banks``
  slots, each held for a tile's load+compute span.  Two banks is what
  lets tile *i+1*'s load overlap tile *i*'s compute.
* ``pe`` — the M_pe×N_pe array; a tile occupies it for the Eq.1 compute
  time with PE-quantised extents, plus a six-stage pipeline drain on the
  result latency.
* ``vector`` — the Saturn RVV unit running epilogue nodes.

A matmul node's life: dispatch → wait for a scratchpad bank → load →
compute → (writeback ‖ status poll) → dependents released.  Vector and
memory nodes occupy their single resource for their modelled duration.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.config import MatrixUnitConfig
from repro.core.hardware import CpuPlatform, SHUTTLE
from repro.core.precision import policy
from repro.core.simulator import SATURN_512, VectorUnit
from repro.core.task import BiasType
from repro.sim.graph import Node, TaskGraph
from repro.sim.resources import (EventLoop, Resource, contiguous_run_bytes,
                                 dram_stride_efficiency)


@dataclasses.dataclass
class Machine:
    """The resource set one (unit, platform, vector) triple implies."""

    loop: EventLoop
    unit: MatrixUnitConfig
    platform: CpuPlatform
    vector_unit: VectorUnit
    dispatcher: Resource
    loader: Resource
    banks: Resource
    pe: Resource
    vector: Resource

    @property
    def bytes_per_cycle(self) -> float:
        return (self.unit.bandwidth * self.platform.dram_efficiency
                / self.unit.freq_hz)

    def resources(self) -> "list[Resource]":
        return [self.dispatcher, self.loader, self.banks, self.pe,
                self.vector]


def build_machine(unit: MatrixUnitConfig, platform: CpuPlatform,
                  vector_unit: VectorUnit = SATURN_512) -> Machine:
    loop = EventLoop()
    return Machine(
        loop=loop, unit=unit, platform=platform, vector_unit=vector_unit,
        dispatcher=Resource(loop, "dispatcher"),
        loader=Resource(loop, "mem_loader"),
        banks=Resource(loop, "scratchpad", capacity=unit.scratchpad_banks),
        pe=Resource(loop, "pe_array"),
        vector=Resource(loop, "vector_unit"),
    )


# ---------------------------------------------------------------------------
# Per-node cost model (mirrors core.simulator.simulate_gemm's per-tile terms).
# ---------------------------------------------------------------------------

def tile_costs(machine: Machine, node: Node,
               out_bytes: float = 4.0) -> "dict[str, float]":
    """Per-tile compute/load/writeback cycles.  Load and writeback are
    charged per operand at the stride-dependent DRAM efficiency its
    access pattern achieves (``Task`` strides, paper §5.4) — a dense
    panel streams at the platform's calibrated derate, a narrow tile cut
    from a wide row-major matrix pays per-row address jumps."""
    task = node.task
    unit = machine.unit
    base = machine.platform.dram_efficiency
    raw_bpc = unit.bandwidth / unit.freq_hz
    dt = task.data_type
    eb = policy(dt).bytes_per_elem
    m_eff = -(-task.m // unit.m_pe) * unit.m_pe
    n_eff = -(-task.n // unit.n_pe) * unit.n_pe
    kpe = unit.k_pe_elems(dt)
    k_eff = -(-task.k // kpe) * kpe
    compute = m_eff * n_eff * k_eff / unit.macs_per_cycle(dt)
    bias_bytes = {BiasType.ZERO: 0.0, BiasType.ROW: task.n * 4.0,
                  BiasType.FULL: task.m * task.n * 4.0}[task.bias_type]
    eff_a = dram_stride_efficiency(
        contiguous_run_bytes(task.m, task.k, task.stride_a, eb), base)
    eff_b = dram_stride_efficiency(
        contiguous_run_bytes(task.k, task.n, task.stride_b, eb), base)
    eff_c = dram_stride_efficiency(
        contiguous_run_bytes(task.m, task.n, task.stride_c, out_bytes), base)
    load = (task.m * task.k * eb / (raw_bpc * eff_a)
            + task.k * task.n * eb / (raw_bpc * eff_b)
            + bias_bytes / (raw_bpc * base))
    writeback = task.m * task.n * out_bytes / (raw_bpc * eff_c)
    return {"compute": compute, "load": load, "writeback": writeback}


@dataclasses.dataclass
class DESimResult:
    cycles: float                       # makespan
    ideal_matrix_cycles: float          # Eq.1 lower bound for all matmul work
    node_span: "dict[int, tuple[float, float]]"   # nid -> (start, end)
    intervals: "dict[str, list[tuple[float, float, str]]]"
    capacity: "dict[str, int]"
    freq_hz: float

    @property
    def matrix_utilization(self) -> float:
        return (self.ideal_matrix_cycles / self.cycles) if self.cycles else 0.0

    def busy(self, resource: str) -> float:
        return sum(e - s for s, e, _ in self.intervals[resource])

    def utilization(self, resource: str) -> float:
        if not self.cycles:
            return 0.0
        return self.busy(resource) / (self.cycles * self.capacity[resource])

    def utilizations(self) -> "dict[str, float]":
        return {r: self.utilization(r) for r in self.intervals}

    def seconds(self) -> float:
        return self.cycles / self.freq_hz


def simulate_graph(graph: TaskGraph, unit: MatrixUnitConfig,
                   platform: CpuPlatform = SHUTTLE,
                   vector_unit: VectorUnit = SATURN_512,
                   machine: Optional[Machine] = None) -> DESimResult:
    """Run ``graph`` to completion; returns timelines + utilization."""
    nodes = graph.topo_order()
    machine = machine or build_machine(unit, platform, vector_unit)
    loop = machine.loop

    remaining = {n.nid: len(n.deps) for n in nodes}
    dependents: "dict[int, list[Node]]" = {n.nid: [] for n in nodes}
    for n in nodes:
        for d in n.deps:
            dependents[d].append(n)
    span: "dict[int, tuple[float, float]]" = {}
    started: "dict[int, float]" = {}

    def complete(node: Node) -> None:
        span[node.nid] = (started[node.nid], loop.now)
        for succ in dependents[node.nid]:
            remaining[succ.nid] -= 1
            if remaining[succ.nid] == 0:
                start(succ)

    def start(node: Node) -> None:
        started[node.nid] = loop.now
        if node.kind == "matmul":
            _run_matmul(machine, node, lambda: complete(node))
        elif node.kind == "vector":
            cyc = machine.vector_unit.cycles_for(node.vector_ops)
            machine.vector.busy(cyc, node.name, then=lambda: complete(node))
        elif node.kind == "memory":
            cyc = node.mem_bytes / machine.bytes_per_cycle
            machine.loader.busy(cyc, node.name, then=lambda: complete(node))
        else:
            raise ValueError(f"unknown node kind {node.kind!r}")

    for n in nodes:                      # sources, in program order
        if remaining[n.nid] == 0:
            loop.after(0.0, (lambda nn: lambda: start(nn))(n))

    makespan = loop.run()
    if len(span) != len(nodes):
        stuck = [n.nid for n in nodes if n.nid not in span]
        raise RuntimeError(f"graph deadlocked; unfinished nodes {stuck[:8]}")

    ideal = sum(n.task.macs / unit.macs_per_cycle(n.task.data_type)
                for n in nodes if n.kind == "matmul")
    return DESimResult(
        cycles=makespan, ideal_matrix_cycles=ideal, node_span=span,
        intervals={r.name: r.intervals for r in machine.resources()},
        capacity={r.name: r.capacity for r in machine.resources()},
        freq_hz=unit.freq_hz)


def _run_matmul(machine: Machine, node: Node, done) -> None:
    """dispatch → bank → load → compute → (writeback ‖ poll) → done."""
    c = tile_costs(machine, node)
    platform = machine.platform
    label = node.name

    bank_start = [0.0]

    def after_dispatch():
        def granted():
            bank_start[0] = machine.loop.now
            machine.loader.busy(c["load"], label, then=run_pe)

        machine.banks.acquire(granted)

    def run_pe():
        machine.pe.busy(c["compute"], label, then=finish)

    def finish():
        # A/B bank held from load start to compute end, then freed.
        machine.banks.intervals.append((bank_start[0], machine.loop.now,
                                        label))
        machine.banks.release()
        machine.loader.busy(c["writeback"], label + "/wb")
        # Result usable after the PE pipeline drains; the CPU then owes a
        # checkMatmul poll before dependents (vector epilogues) may issue.
        machine.loop.after(
            machine.unit.pe_pipeline_stages,
            lambda: machine.dispatcher.busy(
                platform.check_cycles, label + "/chk", then=done))

    machine.dispatcher.busy(platform.dispatch_cycles, label + "/disp",
                            then=after_dispatch)

"""Arrival processes driving the online serving loop.

The offline ``ServingEngine.plan`` assumes the whole queue is known at
t = 0; :mod:`repro.serving.online` replaces that with a *stream*: an
arrival source yields :class:`Arrival` records (cycle-stamped, in
non-decreasing time order) and the event loop admits them as the
simulated clock reaches them.  Three sources cover the usual load
shapes:

* :class:`PoissonArrivals` — seeded memoryless traffic (exponential
  inter-arrival gaps), the open-loop load model every QPS sweep uses;
* :class:`DeterministicArrivals` — fixed inter-arrival gap, the
  constant-rate control every comparison needs;
* :class:`TraceArrivals` — a JSONL trace file (one
  ``{"time": …, "prompt_len": …}`` object per line), for replaying
  recorded traffic.

Determinism is a hard contract: sources draw only from
:class:`random.Random` (whose Mersenne-Twister stream is pinned across
platforms and Python versions), materialise their sequence once, and
return the identical tuple on every call — same seed, bit-identical
admission sequence, regardless of which pricing backend the loop plans
with (pinned in ``tests/test_online.py``).

All times are **cycles** of the simulated machine — the currency every
backend prices in.  :func:`qps_to_gap` converts an offered
requests-per-second rate into a mean inter-arrival gap for a unit
clocked at ``freq_hz``.
"""

from __future__ import annotations

import dataclasses
import json
import random
from typing import Iterable, Iterator, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One request arriving at the serving loop.

    ``time`` is the arrival cycle; ``prompt_len`` the prompt length in
    tokens (the quantity scheduling actually consumes — concrete token
    ids are synthesised downstream when a run executes for real).
    """

    time: float
    prompt_len: int

    def __post_init__(self):
        if self.time < 0:
            raise ValueError(f"arrival time must be >= 0, got {self.time}")
        if self.prompt_len < 1:
            raise ValueError(f"prompt_len must be >= 1, "
                             f"got {self.prompt_len}")


def qps_to_gap(qps: float, freq_hz: float) -> float:
    """Mean inter-arrival gap (cycles) of an offered ``qps`` rate on a
    machine clocked at ``freq_hz``: ``freq_hz / qps``."""
    if qps <= 0:
        raise ValueError(f"qps must be > 0, got {qps}")
    return freq_hz / qps


def gap_to_qps(gap_cycles: float, freq_hz: float) -> float:
    """Offered requests/second of a mean ``gap_cycles`` inter-arrival
    gap — the inverse of :func:`qps_to_gap`."""
    if gap_cycles <= 0:
        raise ValueError(f"gap_cycles must be > 0, got {gap_cycles}")
    return freq_hz / gap_cycles


class ArrivalSource:
    """Base class: a finite, materialised, re-iterable arrival stream.

    Subclasses implement :meth:`_generate` (called once, lazily); the
    base caches the tuple so a source can be iterated any number of
    times and always yields the identical sequence — the determinism
    audit the online tests pin.
    """

    def _generate(self) -> "list[Arrival]":
        raise NotImplementedError

    def arrivals(self) -> "tuple[Arrival, ...]":
        cached = getattr(self, "_cache", None)
        if cached is None:
            out = list(self._generate())
            for prev, cur in zip(out, out[1:]):
                if cur.time < prev.time:
                    raise ValueError(
                        f"arrival times must be non-decreasing "
                        f"({cur.time} after {prev.time})")
            cached = tuple(out)
            object.__setattr__(self, "_cache", cached)
        return cached

    def __iter__(self) -> Iterator[Arrival]:
        return iter(self.arrivals())

    def __len__(self) -> int:
        return len(self.arrivals())


def _prompt_picker(prompt_lengths, rng: random.Random,
                   min_prompt: int, max_prompt: int):
    """Per-arrival prompt lengths: cycle a given sequence, or draw
    uniform ints from the source's own RNG stream (one draw per
    arrival, *after* the gap draw — the draw order is part of the
    determinism contract)."""
    if prompt_lengths is not None:
        seq = tuple(int(p) for p in prompt_lengths)
        if not seq:
            raise ValueError("prompt_lengths must be non-empty")
        return lambda i: seq[i % len(seq)]
    if not 1 <= min_prompt <= max_prompt:
        raise ValueError(f"need 1 <= min_prompt <= max_prompt, got "
                         f"[{min_prompt}, {max_prompt}]")
    return lambda i: rng.randint(min_prompt, max_prompt)


@dataclasses.dataclass(frozen=True)
class PoissonArrivals(ArrivalSource):
    """Seeded Poisson process: exponential inter-arrival gaps with mean
    ``mean_gap`` cycles, ``n`` arrivals total.  ``prompt_lengths``
    cycles a fixed tuple; omitted, lengths are uniform draws in
    ``[min_prompt, max_prompt]`` from the same seeded stream."""

    mean_gap: float
    n: int
    seed: int = 0
    prompt_lengths: "Optional[tuple[int, ...]]" = None
    min_prompt: int = 16
    max_prompt: int = 128

    def __post_init__(self):
        if self.mean_gap <= 0:
            raise ValueError(f"mean_gap must be > 0, got {self.mean_gap}")
        if self.n < 1:
            raise ValueError(f"n must be >= 1, got {self.n}")

    def _generate(self) -> "list[Arrival]":
        rng = random.Random(self.seed)
        pick = _prompt_picker(self.prompt_lengths, rng,
                              self.min_prompt, self.max_prompt)
        out, t = [], 0.0
        for i in range(self.n):
            t += rng.expovariate(1.0 / self.mean_gap)
            out.append(Arrival(time=t, prompt_len=pick(i)))
        return out


@dataclasses.dataclass(frozen=True)
class DeterministicArrivals(ArrivalSource):
    """Constant-rate traffic: arrival *i* at ``(i + 1) * gap`` cycles
    (``gap=0`` puts the whole queue at t = 0 — the offline limit)."""

    gap: float
    n: int
    prompt_lengths: "Optional[tuple[int, ...]]" = None
    min_prompt: int = 16
    max_prompt: int = 128
    seed: int = 0

    def __post_init__(self):
        if self.gap < 0:
            raise ValueError(f"gap must be >= 0, got {self.gap}")
        if self.n < 1:
            raise ValueError(f"n must be >= 1, got {self.n}")

    def _generate(self) -> "list[Arrival]":
        rng = random.Random(self.seed)
        pick = _prompt_picker(self.prompt_lengths, rng,
                              self.min_prompt, self.max_prompt)
        return [Arrival(time=(i + 1) * self.gap, prompt_len=pick(i))
                for i in range(self.n)]


@dataclasses.dataclass(frozen=True)
class TraceArrivals(ArrivalSource):
    """Replay a JSONL trace: one ``{"time": cycles, "prompt_len": n}``
    object per line (blank lines and ``#`` comments skipped), times
    non-decreasing.  Use :func:`write_trace` to produce one from any
    source."""

    path: str

    def _generate(self) -> "list[Arrival]":
        out: "list[Arrival]" = []
        with open(self.path) as f:
            for lineno, line in enumerate(f, 1):
                line = line.strip()
                if not line or line.startswith("#"):
                    continue
                try:
                    rec = json.loads(line)
                    out.append(Arrival(time=float(rec["time"]),
                                       prompt_len=int(rec["prompt_len"])))
                except (KeyError, TypeError, ValueError) as e:
                    raise ValueError(
                        f"{self.path}:{lineno}: bad trace record "
                        f"{line[:60]!r}: {e}") from None
        if not out:
            raise ValueError(f"{self.path}: empty arrival trace")
        return out


def from_records(records: "Iterable[dict]") -> "tuple[Arrival, ...]":
    """Arrivals from in-memory trace records (the JSONL schema)."""
    return tuple(Arrival(time=float(r["time"]),
                         prompt_len=int(r["prompt_len"])) for r in records)


def write_trace(path: str, arrivals: "Iterable[Arrival]") -> int:
    """Serialise arrivals to a JSONL trace readable by
    :class:`TraceArrivals`; returns the number of records written."""
    n = 0
    with open(path, "w") as f:
        for a in arrivals:
            f.write(json.dumps({"time": a.time,
                                "prompt_len": a.prompt_len}) + "\n")
            n += 1
    return n

"""gemma2-27b [dense]: 46L d=4608 32H (GQA kv=16) d_ff=36864 vocab=256000.

Local+global alternating, softcaps, query scale (d_model/n_heads)^-0.5 =
144^-0.5 (the 27B uses query_pre_attn_scalar=144).  [arXiv:2408.00118; hf]
"""

from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="transformer",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    window=4096,
    layer_pattern="gemma2_alt",
    attn_softcap=50.0,
    final_softcap=30.0,
    query_scale=144.0 ** -0.5,
    mlp_activation="gelu_tanh",
    mlp_glu=True,
    sandwich_norms=True,
    rmsnorm_unit_offset=True,
    embed_scale=True,
    tie_embeddings=True,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                        head_dim=16, d_ff=128, vocab_size=512, window=16,
                        attn_chunk=32)

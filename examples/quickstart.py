"""Quickstart: the CUTEv2 programming model in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Walks Listing 1 of the paper end-to-end: interface registers →
asyncMatMul dispatch → checkMatmul → overlapped vector epilogue → the
same computation through the fused Pallas kernel → the constraint model
that sized its tiles.
"""

import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import (AsyncMatmulEngine, BiasType, CASE_STUDY, DataType,
                        Epilogue, EpilogueOperands, MatMulTask, cute_matmul,
                        pipelined_fused_matmul)
from repro.core import constraint
from repro.core.simulator import simulate_gemm
from repro.core.hardware import SHUTTLE


def main():
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (256, 512), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (512, 1024), jnp.bfloat16)
    bias = jnp.zeros((1024,), jnp.float32)

    # 1. The interface registers (paper Table 1) ---------------------------
    task = MatMulTask(m=256, n=1024, k=512, data_type=DataType.BF16,
                      bias_type=BiasType.ROW)
    print(f"task: {task.m}x{task.n}x{task.k}, {task.flops / 1e6:.1f} MFLOP, "
          f"AI={task.arithmetic_intensity():.1f} flop/byte")

    # 2. asyncMatMul / checkMatmul (Listing 1) -----------------------------
    eng = AsyncMatmulEngine()
    handle = eng.dispatch(task, a, w,
                          epilogue=Epilogue(bias_type=BiasType.ROW,
                                            activation="gelu"),
                          operands=EpilogueOperands(bias=bias))
    print("dispatched; done?", eng.check(handle))       # False: async
    out = eng.wait(handle)                              # checkMatmul
    print("result:", out.shape, out.dtype)

    # 3. Tile-granular overlap: vector epilogue rides each tile -----------
    out2 = pipelined_fused_matmul(a.astype(jnp.float32),
                                  w.astype(jnp.float32),
                                  jax.nn.gelu, tile_m=64)
    print("pipelined max |Δ| vs fused:",
          float(jnp.abs(out2 - out.astype(jnp.float32) ).max()))

    # 4. The same matmul through the fused Pallas TPU kernel ---------------
    out3 = cute_matmul(a, w, epilogue=Epilogue(bias_type=BiasType.ROW,
                                               activation="gelu"),
                       operands=EpilogueOperands(bias=bias),
                       backend="pallas")
    print("pallas max |Δ|:",
          float(jnp.abs(out3.astype(jnp.float32)
                        - out.astype(jnp.float32)).max()))

    # 5. Eq. 2, both levels -------------------------------------------------
    print("\npaper case study:", CASE_STUDY.describe())
    r = simulate_gemm(CASE_STUDY, MatMulTask(m=512, n=512, k=4096), SHUTTLE)
    print(f"simulated GEMM utilization: {r.utilization:.1%} "
          f"({r.breakdown['bound']}-bound)")
    tc = constraint.solve_tiles(DataType.BF16)
    print(f"TPU tile from the same constraint model: "
          f"({tc.bm}, {tc.bn}, {tc.bk}), VMEM {tc.vmem_bytes >> 20} MiB, "
          f"ideal util {tc.ideal_utilization:.1%}")


if __name__ == "__main__":
    main()

"""Paged attention over a block-table KV layout (the vLLM idiom).

The serving stack's :mod:`repro.serving.kvcache` allocator hands out
fixed-size KV blocks from a shared physical pool; this module closes
the execution loop: the cache lives as a **page pool** ``(P, Hkv,
block_tokens, D)`` plus a per-sequence **block table** ``(B, n_blocks)``
of page indices, and attention gathers the pages back into the
contiguous ``(B, Hkv, S, D)`` layout before running *exactly* the same
math as the contiguous reference (``decode_attention`` for the pure-jnp
single-token path, ``flash_attention`` for the Pallas kernel).  Because
the gather is a pure permutation of rows followed by the identical
kernel, paged outputs are **bit-exact** against the contiguous path —
int8 in, int8 out, no tolerance needed — which is what the parity suite
pins across granularities and backends.
"""

from __future__ import annotations

import random
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.kernels.attention.ops import (_pad_axis, decode_attention,
                                         flash_attention)


def to_paged(k_cache, v_cache, block_tokens: int, *, seed: int = 0):
    """Scatter contiguous caches ``(B, Hkv, S, D)`` into a paged pool.

    Returns ``(k_pages, v_pages, block_table)`` with pages of shape
    ``(B * n_blocks, Hkv, block_tokens, D)`` and an int32 table
    ``(B, n_blocks)``.  ``seed`` shuffles the physical page order (the
    allocator's seeded free list does the same), so round-tripping
    through a *non-trivial* table is what the parity tests exercise.
    ``S`` is zero-padded up to a block multiple; padded positions sit
    past every ``cache_len`` so the attention mask ignores them.
    """
    if block_tokens < 1:
        raise ValueError(f"block_tokens must be >= 1, got {block_tokens}")
    if k_cache.shape != v_cache.shape:
        raise ValueError(f"k/v shape mismatch: {k_cache.shape} vs "
                         f"{v_cache.shape}")
    b, hkv, s, d = k_cache.shape
    n_blocks = -(-s // block_tokens)
    kp = _pad_axis(k_cache, 2, block_tokens)
    vp = _pad_axis(v_cache, 2, block_tokens)
    total = b * n_blocks
    # logical block i of sequence q lives at physical page perm[q*nb+i].
    perm = list(range(total))
    random.Random(seed).shuffle(perm)
    perm = np.asarray(perm, dtype=np.int32)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(total, dtype=np.int32)

    def paginate(x):
        blocks = x.reshape(b, hkv, n_blocks, block_tokens, d)
        blocks = blocks.transpose(0, 2, 1, 3, 4)
        blocks = blocks.reshape(total, hkv, block_tokens, d)
        return blocks[inv]                     # page p holds block inv[p]

    block_table = jnp.asarray(perm.reshape(b, n_blocks))
    return paginate(kp), paginate(vp), block_table


def gather_paged(pages, block_table, seq_len: Optional[int] = None):
    """Gather a paged pool back to the contiguous ``(B, Hkv, S, D)``
    layout: ``pages[block_table]`` per sequence, blocks re-ordered by
    table position, cropped to ``seq_len``."""
    g = pages[block_table]                     # (B, nb, Hkv, bt, D)
    b, nb, hkv, bt, d = g.shape
    out = g.transpose(0, 2, 1, 3, 4).reshape(b, hkv, nb * bt, d)
    if seq_len is not None:
        out = out[:, :, :seq_len]
    return out


def paged_decode_attention(q, k_pages, v_pages, block_table, cache_len, *,
                           seq_len: Optional[int] = None,
                           sm_scale: Optional[float] = None,
                           window: int = 0, softcap: float = 0.0):
    """Single-token decode against a paged cache — bit-exact with
    ``decode_attention`` on the gathered-contiguous layout (padded
    positions past ``cache_len`` are masked before the softmax, so the
    block-padding tail never contributes)."""
    k = gather_paged(k_pages, block_table, seq_len)
    v = gather_paged(v_pages, block_table, seq_len)
    return decode_attention(q, k, v, cache_len, sm_scale=sm_scale,
                            window=window, softcap=softcap)


def paged_flash_attention(q, k_pages, v_pages, block_table, *,
                          seq_len: Optional[int] = None, **kw):
    """Prefill/chunk attention against a paged cache via the Pallas
    flash kernel — the gather is a row permutation, so the kernel sees
    byte-identical operands to the contiguous call."""
    k = gather_paged(k_pages, block_table, seq_len)
    v = gather_paged(v_pages, block_table, seq_len)
    return flash_attention(q, k, v, **kw)

"""The discrete-event backend: timelines *and* results from one graph.

``dispatch``/``run_graph`` run the TaskGraph on the resource-level
machine model (``sim.desim``) for the per-resource timeline, and — when
concrete operands are supplied — execute the *same* graph through
``execute_graph_jax``/``execute_workload_jax`` so the numbers come back
alongside the cycles.  This is the paper's unified-stack claim made
operational: one graph, one schedule, simulated and executed.
"""

from __future__ import annotations

from typing import Callable

from repro.backend.base import (Backend, ExecResult, GraphOperands,
                                MatMulOperands)
from repro.backend.registry import register
from repro.core.fusion import Epilogue, NO_EPILOGUE
from repro.core.task import MatMulTask
from repro.obs import instrument


@register("desim")
class DESimBackend(Backend):
    """Discrete-event machine model + optional lockstep JAX execution."""

    executes = True
    models_time = True
    matmul_string = "xla"           # numeric half runs through XLA

    def _stage(self, task: MatMulTask, operands: MatMulOperands,
               epilogue: Epilogue) -> Callable[[], ExecResult]:
        ep = None if epilogue is NO_EPILOGUE else epilogue
        graph = self.lower(task, epilogue=ep)
        return lambda: self.run_graph(
            graph, operands if operands.concrete else None)

    @instrument("run_graph")
    def run_graph(self, graph, operands: GraphOperands = None) -> ExecResult:
        from repro.sim.desim import simulate_graph
        from repro.sim.lower import (execute_graph_jax,
                                     execute_workload_jax, step_spans)
        r = simulate_graph(graph, self.unit, self.platform, self.vector)
        output, outputs = None, None
        if isinstance(operands, dict):
            outputs = execute_workload_jax(graph, operands)
        elif operands is not None and operands.concrete:
            output = execute_graph_jax(graph, operands.a, operands.b,
                                       operands=operands.epilogue)
        return ExecResult(output=output, outputs=outputs, cycles=r.cycles,
                          seconds=r.seconds(),
                          utilization=r.matrix_utilization, timeline=r,
                          detail={"utilizations": r.utilizations(),
                                  "step_spans": step_spans(graph, r)})

    @instrument("run_workload")
    def run_workload(self, layers, *, fused=None, unit=None, platform=None,
                     vector=None):
        from repro.sim.lower import desim_workload
        return desim_workload(
            unit or self.unit, layers,
            platform=platform or self.platform,
            vector=vector or self.vector,
            fused=self.fused if fused is None else fused,
            granularity=self.granularity)

"""A minimal discrete-event simulation kernel + resource primitives.

``EventLoop`` is a classic calendar-queue DES driver: callbacks are
scheduled at absolute times (cycles, floats) and run in time order, with
insertion order breaking ties — which keeps program order deterministic
when many tasks become ready in the same cycle.

``Resource`` is a capacity-limited server with a FIFO wait queue.  Every
occupancy is recorded as a ``(start, end, label)`` interval, which is
what the utilization report and the Chrome-trace exporter consume.  The
scratchpad's double-buffered banks are just a ``Resource`` with
``capacity = scratchpad_banks`` held across a tile's load+compute span.

``dram_stride_efficiency`` / ``contiguous_run_bytes`` model the DRAM
bandwidth a strided operand stream achieves (paper §5.4): the memory
loader walks an operand row by row, and each address jump between rows
costs part of a burst plus a row-activation bubble.  The platform's flat
``dram_efficiency`` is the DRAMSim-calibrated value for standard dense
tile panels (64-byte runs); runs at or above that reference stream at
the calibrated rate, shorter runs — a narrow tile cut from a wide
row-major matrix, i.e. ``MatMulTask.stride_b ≫ n`` — degrade sharply.
"""

from __future__ import annotations

import heapq
from collections import deque
from typing import Callable, Optional

# ---------------------------------------------------------------------------
# Stride-dependent DRAM efficiency (paper §5.4).
# ---------------------------------------------------------------------------

#: run length the platform's flat ``dram_efficiency`` is calibrated at —
#: one DRAM burst, the panel width of a standard dense int8 tile.
DRAM_REFERENCE_RUN_BYTES = 64.0
#: bandwidth lost per address jump (burst remainder + activation bubble),
#: expressed in stream-equivalent bytes.
DRAM_JUMP_GAP_BYTES = 16.0


def contiguous_run_bytes(rows: int, row_elems: int, stride_elems: int,
                         elem_bytes: float) -> float:
    """Longest contiguous burst a (rows × row_elems) operand read can
    sustain given its row stride: dense rows (stride == row length)
    merge into one run; a strided view jumps every ``row_elems``."""
    if rows <= 0 or row_elems <= 0:
        return 0.0
    if stride_elems <= row_elems:
        return rows * row_elems * elem_bytes
    return row_elems * elem_bytes


def dram_stride_efficiency(run_bytes: float, base_efficiency: float) -> float:
    """Achieved/nominal DRAM bandwidth streaming contiguous runs of
    ``run_bytes`` between address jumps.

    The curve is ``run / (run + gap)`` normalised so the 64-byte
    reference run reproduces ``base_efficiency`` exactly (runs beyond it
    saturate there — dense streams are what the flat derate was
    calibrated on), while sub-burst runs degrade toward
    ``base * run / (run + gap) / 0.8``.
    """
    if run_bytes <= 0:
        return base_efficiency
    raw = run_bytes / (run_bytes + DRAM_JUMP_GAP_BYTES)
    ref = DRAM_REFERENCE_RUN_BYTES / (DRAM_REFERENCE_RUN_BYTES
                                      + DRAM_JUMP_GAP_BYTES)
    return base_efficiency * min(1.0, raw / ref)


class EventLoop:
    def __init__(self):
        self.now = 0.0
        self._heap: "list[tuple[float, int, Callable[[], None]]]" = []
        self._seq = 0

    def at(self, time: float, fn: Callable[[], None]) -> None:
        if time < self.now:
            raise ValueError(f"cannot schedule at {time} < now {self.now}")
        heapq.heappush(self._heap, (time, self._seq, fn))
        self._seq += 1

    def after(self, delay: float, fn: Callable[[], None]) -> None:
        self.at(self.now + delay, fn)

    def run(self, max_events: int = 50_000_000) -> float:
        n = 0
        while self._heap:
            self.now, _, fn = heapq.heappop(self._heap)
            fn()
            n += 1
            if n > max_events:
                raise RuntimeError("event budget exhausted (cycle in graph?)")
        return self.now


class Resource:
    """``capacity`` concurrent holders; FIFO beyond that."""

    def __init__(self, loop: EventLoop, name: str, capacity: int = 1):
        self.loop = loop
        self.name = name
        self.capacity = capacity
        self._free = capacity
        self._waiters: "deque[Callable[[], None]]" = deque()
        self.intervals: "list[tuple[float, float, str]]" = []

    # -- raw acquire / release ---------------------------------------------
    def acquire(self, fn: Callable[[], None]) -> None:
        """Call ``fn`` (same tick or later) once a slot is held."""
        if self._free > 0:
            self._free -= 1
            fn()
        else:
            self._waiters.append(fn)

    def release(self) -> None:
        if self._waiters:
            self._waiters.popleft()()
        else:
            self._free += 1
            if self._free > self.capacity:
                raise RuntimeError(f"{self.name}: release without acquire")

    # -- the common occupy-for-duration pattern -----------------------------
    def busy(self, duration: float, label: str,
             then: Optional[Callable[[], None]] = None) -> None:
        """Acquire → hold for ``duration`` → release → ``then()``."""

        def _granted():
            start = self.loop.now

            def _done():
                self.intervals.append((start, self.loop.now, label))
                self.release()
                if then is not None:
                    then()

            self.loop.after(duration, _done)

        self.acquire(_granted)

"""Training substrate: loss, AdamW, microbatch equivalence, compression,
actual loss descent on the synthetic stream."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.base import family_module
from repro.optim import adamw, compression
from repro.training import loss as loss_lib
from repro.training.train_step import TrainConfig, make_train_step


def _tiny():
    cfg = get_config("yi-6b", reduced=True).with_(
        remat="none", dtype=jnp.float32, n_layers=2, d_ff=64, d_model=32,
        n_heads=2, n_kv_heads=2, head_dim=16, vocab_size=64, attn_chunk=16)
    mod = family_module(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    return cfg, mod, params


class TestLoss:
    def test_chunked_equals_dense(self):
        cfg, mod, params = _tiny()
        h = jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model))
        labels = jax.random.randint(jax.random.PRNGKey(2), (2, 24), 0, 64)
        l_chunk, _ = loss_lib.chunked_softmax_xent(cfg, params, h, labels,
                                                   chunk=8, z_loss=0.0)
        logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
        lse = jax.nn.logsumexp(logits, -1)
        nll = lse - jnp.take_along_axis(logits, labels[..., None],
                                        -1)[..., 0]
        np.testing.assert_allclose(float(l_chunk), float(nll.mean()),
                                   rtol=1e-5)

    def test_masked_labels_excluded(self):
        cfg, mod, params = _tiny()
        h = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
        labels = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, 64)
        masked = labels.at[:, :8].set(-1)
        l_m, aux = loss_lib.chunked_softmax_xent(cfg, params, h, masked,
                                                 chunk=8, z_loss=0.0)
        assert float(aux["tokens"]) == 16.0
        l_half, _ = loss_lib.chunked_softmax_xent(
            cfg, params, h[:, 8:], labels[:, 8:], chunk=8, z_loss=0.0)
        np.testing.assert_allclose(float(l_m), float(l_half), rtol=1e-5)

    def test_grad_matches_dense(self):
        cfg, mod, params = _tiny()
        h = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model))
        labels = jax.random.randint(jax.random.PRNGKey(2), (1, 16), 0, 64)

        def f_chunk(w):
            p = dict(params, lm_head=w)
            return loss_lib.chunked_softmax_xent(cfg, p, h, labels, chunk=4,
                                                 z_loss=0.0)[0]

        def f_dense(w):
            logits = jnp.einsum("bsd,dv->bsv", h, w)
            lse = jax.nn.logsumexp(logits, -1)
            nll = lse - jnp.take_along_axis(logits, labels[..., None],
                                            -1)[..., 0]
            return nll.mean()

        g1 = jax.grad(f_chunk)(params["lm_head"])
        g2 = jax.grad(f_dense)(params["lm_head"])
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-4, atol=1e-6)


class TestAdamW:
    def test_descends_quadratic(self):
        cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                                total_steps=100)
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw.init(cfg, params)
        for _ in range(60):
            g = {"w": 2 * params["w"]}
            params, state, _ = adamw.update(cfg, g, state, params)
        assert float(jnp.abs(params["w"]).max()) < 0.5

    def test_schedule_shape(self):
        cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
        lrs = [float(adamw.schedule(cfg, jnp.int32(s)))
               for s in (0, 5, 10, 50, 100)]
        assert lrs[0] < lrs[1] < lrs[2]
        assert lrs[2] == pytest.approx(1e-3, rel=1e-3)
        assert lrs[4] < lrs[3] < lrs[2]

    def test_clipping(self):
        cfg = adamw.AdamWConfig(clip_norm=1.0, warmup_steps=0)
        params = {"w": jnp.zeros(4)}
        state = adamw.init(cfg, params)
        _, _, m = adamw.update(cfg, {"w": jnp.full(4, 100.0)}, state, params)
        assert float(m["grad_norm"]) == pytest.approx(200.0)

    def test_bf16_params_fp32_master(self):
        cfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=0, weight_decay=0.0)
        params = {"w": jnp.ones(4, jnp.bfloat16)}
        state = adamw.init(cfg, params)
        assert state["master"]["w"].dtype == jnp.float32
        p2, s2, _ = adamw.update(cfg, {"w": jnp.full(4, 1e-4)}, state, params)
        assert p2["w"].dtype == jnp.bfloat16
        # master tracks sub-bf16 updates
        assert float(jnp.abs(s2["master"]["w"] - 1.0).max()) > 0


class TestMicrobatching:
    def test_equivalent_to_single_batch(self):
        cfg, mod, params = _tiny()
        from repro.configs.registry import concrete_batch
        batch = concrete_batch(cfg, 4, 16, "train")
        t1 = TrainConfig(microbatches=1, loss_chunk=8,
                         optimizer=adamw.AdamWConfig(warmup_steps=0))
        t4 = TrainConfig(microbatches=4, loss_chunk=8,
                         optimizer=adamw.AdamWConfig(warmup_steps=0))
        s1, s4 = make_train_step(cfg, t1), make_train_step(cfg, t4)
        opt = adamw.init(t1.optimizer, params)
        p1, _, m1, _ = jax.jit(s1)(params, opt, batch)
        p4, _, m4, _ = jax.jit(s4)(params, opt, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                                   rtol=1e-5)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=1e-5)


class TestCompression:
    def test_error_feedback_tracks_exact_sgd(self):
        """Compressed-SGD with error feedback converges like exact SGD."""
        w_exact = jnp.array([4.0, -2.0, 1.0])
        w_comp = w_exact
        res = compression.init_residual({"w": w_comp})["w"]
        lr = 0.05
        for _ in range(200):
            g_e = 2 * w_exact
            w_exact = w_exact - lr * g_e
            g_c = {"w": 2 * w_comp}
            deq, new_res = compression.compressed_gradients(
                g_c, {"w": res})
            res = new_res["w"]
            w_comp = w_comp - lr * deq["w"]
        assert float(jnp.abs(w_comp).max()) < 0.05
        assert float(jnp.abs(w_exact - w_comp).max()) < 0.05

    def test_volume_reduction(self):
        g = {"w": jnp.ones((64, 64), jnp.float32)}
        q, s, _ = compression.compress_tree(g, compression.init_residual(g))
        assert q["w"].dtype == jnp.int8          # 4x smaller payload


class TestEndToEnd:
    def test_loss_decreases_on_synthetic_stream(self):
        cfg, mod, params = _tiny()
        tcfg = TrainConfig(loss_chunk=16, optimizer=adamw.AdamWConfig(
            lr=3e-3, warmup_steps=5, total_steps=60))
        step = jax.jit(make_train_step(cfg, tcfg))
        opt = adamw.init(tcfg.optimizer, params)
        data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                      global_batch=8, seq_len=32))
        losses = []
        for _ in range(40):
            batch = next(data)
            params, opt, m, _ = step(params, opt, batch)
            losses.append(float(m["loss"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses[:3]

"""Cross-entropy with sequence-chunked logits (fused-CE memory saver).

Materialising (B, S, V) logits for a 256k vocabulary at 4k context is the
single biggest activation in training (§Perf memory-term analysis).  The
chunked form scans the sequence, computing logits → log-softmax → NLL one
chunk at a time, so the live buffer is (B, chunk, V).  Soft-capping
(gemma2) happens inside the chunk.  Labels < 0 are masked (padding /
vision-prefix positions).  Optional z-loss regularises the partition
function (PaLM-style).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.base import ArchConfig


def _chunk_ce(x, w, labels, softcap: float, z_loss: float,
              onehot_pick: bool = False):
    """x: (B, C, d); w: (d, V); labels: (B, C) -> (sum_nll, sum_z, n_valid).

    ``onehot_pick`` selects the label logit with a one-hot contraction
    instead of ``take_along_axis`` — under a vocab-sharded (TP) layout
    the gather forces GSPMD to materialise unsharded logits, while the
    contraction reduces over the sharded vocab axis with one small psum
    (§Perf memory/collective lever).
    """
    logits = jnp.einsum("bcd,dv->bcv", x, w,
                        preferred_element_type=jnp.float32)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    lse = jax.nn.logsumexp(logits, axis=-1)                  # (B, C)
    valid = labels >= 0
    safe = jnp.maximum(labels, 0)
    if onehot_pick:
        onehot = jax.nn.one_hot(safe, logits.shape[-1],
                                dtype=logits.dtype)
        picked = jnp.einsum("bcv,bcv->bc", logits, onehot)
    else:
        picked = jnp.take_along_axis(logits, safe[..., None],
                                     axis=-1)[..., 0]
    nll = jnp.where(valid, lse - picked, 0.0)
    z = jnp.where(valid, jnp.square(lse), 0.0)
    return (jnp.sum(nll), z_loss * jnp.sum(z),
            jnp.sum(valid.astype(jnp.float32)))


def chunked_softmax_xent(cfg: ArchConfig, params, hidden, labels, *,
                         chunk: int = 512, z_loss: float = 1e-4,
                         onehot_pick: bool = False):
    """hidden: (B, S, d); labels: (B, S) with -1 = masked."""
    w = (params["embedding"].T if cfg.tie_embeddings else params["lm_head"])
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    n = (s + pad) // chunk
    xs = (jnp.moveaxis(hidden.reshape(b, n, chunk, d), 1, 0),
          jnp.moveaxis(labels.reshape(b, n, chunk), 1, 0))

    @jax.checkpoint
    def body(carry, inp):
        # Remat per chunk: backward recomputes chunk logits rather than
        # storing (B, chunk, V) residuals for every chunk.
        x_c, l_c = inp
        nll, z, cnt = _chunk_ce(x_c, w, l_c, cfg.final_softcap, z_loss,
                                onehot_pick)
        return (carry[0] + nll, carry[1] + z, carry[2] + cnt), None

    (nll, z, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0), jnp.float32(0), jnp.float32(0)), xs)
    cnt = jnp.maximum(cnt, 1.0)
    return (nll + z) / cnt, {"nll": nll / cnt, "z": z / cnt, "tokens": cnt}


def shift_labels(cfg: ArchConfig, tokens, labels):
    """Mask out positions the model cannot predict (vision prefix)."""
    if cfg.vision_prefix:
        labels = labels.at[:, : cfg.vision_prefix].set(-1)
    return labels

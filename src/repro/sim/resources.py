"""A minimal discrete-event simulation kernel + resource primitives.

``EventLoop`` is a classic calendar-queue DES driver: callbacks are
scheduled at absolute times (cycles, floats) and run in time order, with
insertion order breaking ties — which keeps program order deterministic
when many tasks become ready in the same cycle.

``Resource`` is a capacity-limited server with a FIFO wait queue.  Every
occupancy is recorded as a ``(start, end, label)`` interval, which is
what the utilization report and the Chrome-trace exporter consume.  The
scratchpad's double-buffered banks are just a ``Resource`` with
``capacity = scratchpad_banks`` held across a tile's load+compute span.

``dram_stride_efficiency`` / ``contiguous_run_bytes`` model the DRAM
bandwidth a strided operand stream achieves (paper §5.4): the memory
loader walks an operand row by row, and each address jump between rows
costs part of a burst plus a row-activation bubble.  The platform's flat
``dram_efficiency`` is the DRAMSim-calibrated value for standard dense
tile panels (64-byte runs); runs at or above that reference stream at
the calibrated rate, shorter runs — a narrow tile cut from a wide
row-major matrix, i.e. ``MatMulTask.stride_b ≫ n`` — degrade sharply.

``BandwidthResource`` and ``ClusterTopology`` generalise the machine
beyond one matrix unit: a cluster is N units — each with its own
dispatcher, scratchpad banks, PE array and vector unit — contending for
one shared memory loader.  The loader partitions its bandwidth under a
configurable policy (``fair``: processor sharing, every in-flight
transfer streams at ``BW / n_active``; ``fcfs``: serial FIFO at full
bandwidth), which is exactly the contention knob multi-unit scale-out
studies (CAMP, arXiv 2504.08137) show decides delivered throughput.
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Callable, Optional

# ---------------------------------------------------------------------------
# Stride-dependent DRAM efficiency (paper §5.4).
# ---------------------------------------------------------------------------

#: run length the platform's flat ``dram_efficiency`` is calibrated at —
#: one DRAM burst, the panel width of a standard dense int8 tile.
DRAM_REFERENCE_RUN_BYTES = 64.0
#: bandwidth lost per address jump (burst remainder + activation bubble),
#: expressed in stream-equivalent bytes.
DRAM_JUMP_GAP_BYTES = 16.0


def contiguous_run_bytes(rows: int, row_elems: int, stride_elems: int,
                         elem_bytes: float) -> float:
    """Longest contiguous burst a (rows × row_elems) operand read can
    sustain given its row stride: dense rows (stride == row length)
    merge into one run; a strided view jumps every ``row_elems``."""
    if rows <= 0 or row_elems <= 0:
        return 0.0
    if stride_elems <= row_elems:
        return rows * row_elems * elem_bytes
    return row_elems * elem_bytes


def dram_stride_efficiency(run_bytes: float, base_efficiency: float,
                           streams: int = 1) -> float:
    """Achieved/nominal DRAM bandwidth streaming contiguous runs of
    ``run_bytes`` between address jumps.

    The curve is ``run / (run + gap)`` normalised so the 64-byte
    reference run reproduces ``base_efficiency`` exactly (runs beyond it
    saturate there — dense streams are what the flat derate was
    calibrated on), while sub-burst runs degrade toward
    ``base * run / (run + gap) / 0.8``.

    ``streams`` carries the shared loader's **row-buffer state across
    interleaved streams** (``ClusterTopology.row_buffer``): N units
    drawing on one pool take turns on the memory channel, so each
    stream's bursts are chopped by the others' row activations and the
    contiguous run it actually sustains is ``run_bytes / N`` — one
    stream (the default) reproduces the single-unit curve exactly.
    """
    if run_bytes <= 0:
        return base_efficiency
    eff_run = run_bytes / max(1, streams)
    raw = eff_run / (eff_run + DRAM_JUMP_GAP_BYTES)
    ref = DRAM_REFERENCE_RUN_BYTES / (DRAM_REFERENCE_RUN_BYTES
                                      + DRAM_JUMP_GAP_BYTES)
    return base_efficiency * min(1.0, raw / ref)


class EventLoop:
    def __init__(self):
        self.now = 0.0
        self._heap: "list[tuple[float, int, Callable[[], None]]]" = []
        self._seq = 0

    def at(self, time: float, fn: Callable[[], None]) -> None:
        if time < self.now:
            raise ValueError(f"cannot schedule at {time} < now {self.now}")
        heapq.heappush(self._heap, (time, self._seq, fn))
        self._seq += 1

    def after(self, delay: float, fn: Callable[[], None]) -> None:
        self.at(self.now + delay, fn)

    def run(self, max_events: int = 50_000_000) -> float:
        n = 0
        while self._heap:
            self.now, _, fn = heapq.heappop(self._heap)
            fn()
            n += 1
            if n > max_events:
                raise RuntimeError("event budget exhausted (cycle in graph?)")
        return self.now


class Resource:
    """``capacity`` concurrent holders; FIFO beyond that."""

    def __init__(self, loop: EventLoop, name: str, capacity: int = 1):
        self.loop = loop
        self.name = name
        self.capacity = capacity
        self._free = capacity
        self._waiters: "deque[Callable[[], None]]" = deque()
        self.intervals: "list[tuple[float, float, str]]" = []

    # -- raw acquire / release ---------------------------------------------
    def acquire(self, fn: Callable[[], None]) -> None:
        """Call ``fn`` (same tick or later) once a slot is held."""
        if self._free > 0:
            self._free -= 1
            fn()
        else:
            self._waiters.append(fn)

    def release(self) -> None:
        if self._waiters:
            self._waiters.popleft()()
        else:
            self._free += 1
            if self._free > self.capacity:
                raise RuntimeError(f"{self.name}: release without acquire")

    # -- the common occupy-for-duration pattern -----------------------------
    def busy(self, duration: float, label: str,
             then: Optional[Callable[[], None]] = None) -> None:
        """Acquire → hold for ``duration`` → release → ``then()``."""

        def _granted():
            start = self.loop.now

            def _done():
                self.intervals.append((start, self.loop.now, label))
                self.release()
                if then is not None:
                    then()

            self.loop.after(duration, _done)

        self.acquire(_granted)


# ---------------------------------------------------------------------------
# Shared-bandwidth server: the cluster's one memory loader.
# ---------------------------------------------------------------------------

class _Flow:
    __slots__ = ("work_left", "label", "then", "start")

    def __init__(self, work, label, then, start):
        self.work_left = work
        self.label = label
        self.then = then
        self.start = start


class BandwidthResource:
    """A bandwidth server shared by many clients.

    A *transfer* is expressed in **work units** — cycles the transfer
    would take with the full bandwidth to itself (so per-operand stride
    derates are already folded in by the caller).  Two partition
    policies:

    * ``"fair"`` — processor sharing: every in-flight transfer streams
      at ``1 / n_active`` of the bandwidth, the hardware idealisation of
      a round-robin/interleaved DRAM controller.  A transfer that would
      take T cycles alone takes up to ``n·T`` under n-way contention.
    * ``"fcfs"`` — serial FIFO at full bandwidth: one transfer at a
      time, later arrivals queue.  With one client this is exactly the
      classic single-unit ``Resource`` loader.

    ``intervals`` records per-transfer ``(start, end, label)`` spans for
    the trace (overlapping under ``fair``); ``busy_intervals`` records
    the union busy periods of the server, which is what utilization /
    saturation should be judged on.
    """

    def __init__(self, loop: EventLoop, name: str, policy: str = "fair"):
        if policy not in ("fair", "fcfs"):
            raise ValueError(f"unknown loader policy {policy!r}; "
                             "use 'fair' or 'fcfs'")
        self.loop = loop
        self.name = name
        self.policy = policy
        self.capacity = 1
        self.intervals: "list[tuple[float, float, str]]" = []
        self.busy_intervals: "list[tuple[float, float, str]]" = []
        # fair-share state
        self._active: "list[_Flow]" = []
        self._last_t = 0.0
        self._epoch = 0
        self._busy_since: Optional[float] = None
        # fcfs state
        self._fifo = Resource(loop, name) if policy == "fcfs" else None

    def transfer(self, work: float, label: str,
                 then: Optional[Callable[[], None]] = None) -> None:
        """Stream ``work`` (full-bandwidth cycles) through the loader."""
        if self.policy == "fcfs":
            self._fcfs_transfer(work, label, then)
            return
        self._settle()
        if not self._active:
            self._busy_since = self.loop.now
        self._active.append(_Flow(max(work, 0.0), label, then,
                                  self.loop.now))
        self._reschedule()

    # -- fcfs ---------------------------------------------------------------
    def _fcfs_transfer(self, work, label, then):
        # Resource.busy with both interval lists populated.
        def _granted():
            start = self.loop.now

            def _end():
                self.intervals.append((start, self.loop.now, label))
                self.busy_intervals.append((start, self.loop.now, label))
                self._fifo.release()
                if then is not None:
                    then()

            self.loop.after(work, _end)

        self._fifo.acquire(_granted)

    # -- fair share ---------------------------------------------------------
    def _settle(self) -> None:
        """Advance every in-flight transfer to ``now`` at the shared rate."""
        dt = self.loop.now - self._last_t
        if dt > 0 and self._active:
            rate = 1.0 / len(self._active)
            for f in self._active:
                f.work_left -= dt * rate
        self._last_t = self.loop.now

    def _reschedule(self) -> None:
        self._epoch += 1
        if not self._active:
            return
        rate = 1.0 / len(self._active)
        t_next = min(f.work_left for f in self._active) / rate
        epoch = self._epoch
        self.loop.after(max(t_next, 0.0), lambda: self._fire(epoch))

    def _fire(self, epoch: int) -> None:
        if epoch != self._epoch:            # superseded by a newer arrival
            return
        self._settle()
        done = [f for f in self._active if f.work_left <= 1e-9]
        self._active = [f for f in self._active if f.work_left > 1e-9]
        now = self.loop.now
        for f in done:
            self.intervals.append((f.start, now, f.label))
        if not self._active and self._busy_since is not None:
            self.busy_intervals.append((self._busy_since, now, "busy"))
            self._busy_since = None
        self._reschedule()
        for f in done:                       # callbacks may start new flows
            if f.then is not None:
                f.then()

    def busy_cycles(self) -> float:
        """Union busy time (in-flight tail included)."""
        tail = 0.0
        if self.policy == "fair" and self._busy_since is not None:
            tail = self.loop.now - self._busy_since
        return sum(e - s for s, e, _ in self.busy_intervals) + tail


# ---------------------------------------------------------------------------
# Cluster topology: N matrix units behind one shared loader.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class UnitSpec:
    """One matrix unit's slot in a (possibly heterogeneous) cluster.

    ``unit`` is the full :class:`~repro.core.config.MatrixUnitConfig`
    (PE array shape, scratchpad extents and bank count, memory channel),
    so per-unit PE throughput and scratchpad capacity are just distinct
    configs.  ``private_bandwidth`` carves a NUMA-ish dedicated slice out
    of the pooled loader bandwidth: the unit's own tile loads/writebacks
    stream through that slice uncontended while cross-unit transfers and
    bulk memory nodes (and every unit without a slice) share the
    remainder of the pool.
    """

    unit: object = None               # MatrixUnitConfig (default CASE_STUDY)
    private_bandwidth: float = 0.0    # bytes/s carved out of the pool

    def __post_init__(self):
        if self.unit is None:
            from repro.core.config import CASE_STUDY
            object.__setattr__(self, "unit", CASE_STUDY)
        if self.private_bandwidth < 0:
            raise ValueError(
                f"private_bandwidth must be >= 0, got "
                f"{self.private_bandwidth}")


@dataclasses.dataclass(frozen=True)
class ClusterTopology:
    """The machine a multi-unit deployment implies (scale-out mirror of
    ``MatrixUnitConfig``): ``n_units`` matrix units, each with a private
    dispatcher, scratchpad banks, PE array and vector unit, all loading
    through one shared memory loader.

    Homogeneous clusters pass ``n_units`` + one ``unit`` config (the
    classic form); heterogeneous clusters pass ``unit_specs`` — a list
    of :class:`UnitSpec` (or bare ``MatrixUnitConfig``) entries with
    distinct PE throughput / scratchpad / private-bandwidth slices.
    All units must share one clock (``freq_hz``) so cycle counts remain
    a common currency across the cluster.

    ``total_bandwidth`` is the pooled loader bandwidth.  The default
    (``None``) assumes every unit brings its own memory channel into the
    pool — ``Σ unit.bandwidth`` — so weak scaling is limited by
    *contention/interleaving*, not raw starvation; pass a fixed value to
    study where the shared loader saturates.  Private slices
    (``UnitSpec.private_bandwidth``) are carved out of that pool; the
    remainder (:attr:`shared_bandwidth`) is what contended traffic sees.

    ``k_stream`` enables K-chunked scratchpad streaming (``k_scp``
    granularity): a tile's loads arrive chunk by chunk and its compute
    starts after the first chunk, overlapping fill with compute inside a
    single tile (ROADMAP DES-fidelity item).
    """

    n_units: int = 1
    unit: object = None               # MatrixUnitConfig (default CASE_STUDY)
    platform: object = None           # CpuPlatform (default SHUTTLE)
    vector: object = None             # VectorUnit (default SATURN_512)
    loader_policy: str = "fair"       # "fair" | "fcfs"
    total_bandwidth: Optional[float] = None
    k_stream: bool = True
    #: model the shared loader's row-buffer state across the units'
    #: interleaved operand streams: each shared-pool stream's contiguous
    #: runs are chopped by the others (``dram_stride_efficiency``'s
    #: ``streams`` knob).  Off by default — the flat calibrated derate.
    row_buffer: bool = False
    unit_specs: "Optional[tuple]" = None   # heterogeneous per-unit specs

    def __post_init__(self):
        if self.unit_specs is not None:
            specs = tuple(s if isinstance(s, UnitSpec) else UnitSpec(unit=s)
                          for s in self.unit_specs)
            if not specs:
                raise ValueError("unit_specs must name at least one unit")
            # n_units left at its default follows the spec list; an
            # explicit mismatching width is a caller bug.
            if self.n_units not in (1, len(specs)):
                raise ValueError(
                    f"n_units={self.n_units} but unit_specs has "
                    f"{len(specs)} entries")
            object.__setattr__(self, "unit_specs", specs)
            object.__setattr__(self, "n_units", len(specs))
            object.__setattr__(self, "unit", self.unit or specs[0].unit)
        if self.n_units < 1:
            raise ValueError(f"n_units must be >= 1, got {self.n_units}")
        if self.loader_policy not in ("fair", "fcfs"):
            raise ValueError(
                f"unknown loader policy {self.loader_policy!r}")
        if self.unit is None or self.platform is None or self.vector is None:
            from repro.core.config import CASE_STUDY
            from repro.core.hardware import SHUTTLE
            from repro.core.simulator import SATURN_512
            object.__setattr__(self, "unit", self.unit or CASE_STUDY)
            object.__setattr__(self, "platform", self.platform or SHUTTLE)
            object.__setattr__(self, "vector", self.vector or SATURN_512)
        freqs = {self.unit_config(i).freq_hz for i in range(self.n_units)}
        if len(freqs) > 1:
            raise ValueError(
                f"units must share one clock; got freq_hz={sorted(freqs)}")
        if self.private_total > 0 and self.shared_bandwidth <= 0:
            raise ValueError(
                f"private slices ({self.private_total:.3g} B/s) consume "
                f"the whole pool ({self.loader_bandwidth:.3g} B/s); "
                "shrink them or raise total_bandwidth")

    # ----- per-unit accessors ---------------------------------------------
    @property
    def heterogeneous(self) -> bool:
        return self.unit_specs is not None

    def spec(self, i: int) -> UnitSpec:
        if self.unit_specs is not None:
            return self.unit_specs[i]
        return UnitSpec(unit=self.unit)

    def unit_config(self, i: int):
        return self.spec(i).unit

    def private_bandwidth(self, i: int) -> float:
        return self.spec(i).private_bandwidth

    @property
    def private_total(self) -> float:
        return sum(self.private_bandwidth(i) for i in range(self.n_units))

    def throughput_weights(self, data_type=None) -> "list[float]":
        """Relative per-unit MAC throughput — the balance weights a
        heterogeneity-aware partitioner (``unit-affinity``) uses."""
        from repro.core.precision import DataType
        dt = data_type or DataType.INT8
        return [float(self.unit_config(i).macs_per_cycle(dt))
                for i in range(self.n_units)]

    # ----- bandwidth accounting -------------------------------------------
    @property
    def loader_bandwidth(self) -> float:
        if self.total_bandwidth is not None:
            return self.total_bandwidth
        return sum(self.unit_config(i).bandwidth
                   for i in range(self.n_units))

    @property
    def shared_bandwidth(self) -> float:
        """Pool left for contended traffic after private slices."""
        return self.loader_bandwidth - self.private_total

    def interleaved_streams(self) -> int:
        """Streams whose interleaving degrades the shared pool's
        row-buffer locality: the units *without* a private slice when
        ``row_buffer`` modelling is on, else 1 (each transfer sees the
        calibrated single-stream curve)."""
        if not self.row_buffer:
            return 1
        return max(1, sum(1 for i in range(self.n_units)
                          if self.private_bandwidth(i) <= 0))

    def with_(self, **kw) -> "ClusterTopology":
        return dataclasses.replace(self, **kw)

    def describe(self) -> str:
        from repro.core.hardware import GIGA
        if self.heterogeneous:
            units = " + ".join(
                f"[{s.unit.describe()}"
                + (f", {s.private_bandwidth / GIGA:.0f} GB/s private]"
                   if s.private_bandwidth else "]")
                for s in self.unit_specs)
        else:
            units = f"{self.n_units} unit(s) x [{self.unit.describe()}]"
        return (f"{units}, shared loader "
                f"{self.shared_bandwidth / GIGA:.0f} GB/s "
                f"({self.loader_policy})"
                + (", k-stream" if self.k_stream else ""))

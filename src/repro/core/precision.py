"""Mixed-precision policies (paper §4.1: TF32 / BF16 / FP16 / INT8 / FP8).

The PE in CUTEv2 multiplies in the input format and accumulates after
aligning to a common exponent — i.e. a wide accumulator.  On TPU the MXU
does the same thing natively: bf16/fp16/fp8 inputs accumulate in fp32,
int8 inputs accumulate in int32.  ``DataType`` mirrors the paper's
interface-register enum (Table 1), and ``PrecisionPolicy`` carries
everything a kernel or a layer needs to know.

TF32 note: TPUs have no 19-bit format; the closest native behaviour is
fp32 data fed through the MXU with bf16x3 decomposition (XLA's
``highest`` precision) — we map TF32 to that and record the substitution
(DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
import enum

import jax.numpy as jnp
from jax import lax


class DataType(str, enum.Enum):
    """Paper Table 1 ``DataType`` interface register."""

    INT8 = "int8"
    FP8_E4M3 = "fp8_e4m3"
    FP8_E5M2 = "fp8_e5m2"
    FP16 = "fp16"
    BF16 = "bf16"
    TF32 = "tf32"
    FP32 = "fp32"     # escape hatch for references / tests


_JNP = {
    DataType.INT8: jnp.int8,
    DataType.FP8_E4M3: jnp.float8_e4m3fn,
    DataType.FP8_E5M2: jnp.float8_e5m2,
    DataType.FP16: jnp.float16,
    DataType.BF16: jnp.bfloat16,
    DataType.TF32: jnp.float32,   # see module docstring
    DataType.FP32: jnp.float32,
}

_ACCUM = {
    DataType.INT8: jnp.int32,
    DataType.FP8_E4M3: jnp.float32,
    DataType.FP8_E5M2: jnp.float32,
    DataType.FP16: jnp.float32,
    DataType.BF16: jnp.float32,
    DataType.TF32: jnp.float32,
    DataType.FP32: jnp.float32,
}

_BITS = {
    DataType.INT8: 8,
    DataType.FP8_E4M3: 8,
    DataType.FP8_E5M2: 8,
    DataType.FP16: 16,
    DataType.BF16: 16,
    DataType.TF32: 32,   # stored as fp32
    DataType.FP32: 32,
}


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Input/accumulate/output dtypes for one matmul."""

    data_type: DataType
    out_dtype: object = None          # default: accum dtype

    @property
    def in_dtype(self):
        return _JNP[self.data_type]

    @property
    def accum_dtype(self):
        return _ACCUM[self.data_type]

    @property
    def bits(self) -> int:
        return _BITS[self.data_type]

    @property
    def bytes_per_elem(self) -> float:
        return self.bits / 8

    @property
    def output_dtype(self):
        return self.out_dtype if self.out_dtype is not None else self.accum_dtype

    @property
    def dot_precision(self):
        """XLA dot precision for the einsum backend."""
        if self.data_type == DataType.TF32:
            return lax.Precision.HIGHEST   # bf16x3 ≈ tf32-or-better
        return lax.Precision.DEFAULT

    def preferred_element_type(self):
        return self.accum_dtype


def policy(dt: "DataType | str", out_dtype=None) -> PrecisionPolicy:
    if isinstance(dt, str):
        dt = DataType(dt)
    return PrecisionPolicy(dt, out_dtype)


BF16 = policy(DataType.BF16)
INT8 = policy(DataType.INT8)
FP8 = policy(DataType.FP8_E4M3)
FP16 = policy(DataType.FP16)
TF32 = policy(DataType.TF32)
FP32 = policy(DataType.FP32)

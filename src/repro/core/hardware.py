"""Hardware descriptions for both sides of the CUTEv2 adaptation.

Two families live here:

* ``CpuPlatform`` — the four open-source RISC-V CPUs the paper integrates
  into (Rocket / Shuttle / BOOM / XiangShan-Kunminghu), plus the three
  commercial baselines of Table 5 (Xeon 8580 AMX, IBM S1022 MMA, Apple M4
  SME).  These feed the cycle-approximate simulator that reproduces the
  paper's figures.

* ``TpuChip`` — the TPU v5e target of the JAX/Pallas adaptation.  The
  roofline analysis and the constraint model (``core.constraint``) read
  their constants from here.

All bandwidths are bytes/second, frequencies in Hz, throughputs in ops/s
(1 MAC = 2 ops, matching the paper's Eq. 1).
"""

from __future__ import annotations

import dataclasses

GIGA = 1e9
TERA = 1e12
MEBI = 2**20
GIBI = 2**30


@dataclasses.dataclass(frozen=True)
class CpuPlatform:
    """A CPU front-end + memory system hosting the matrix extension.

    ``dispatch_cycles`` models the cost of programming the interface
    registers (paper Table 1) and firing one ``asyncMatMul``: a handful of
    cycles over RoCC, noticeably more over the CSR path used for
    XiangShan (paper §4.4).  ``dram_efficiency`` derates the nominal
    DRAMSim bandwidth for strided access patterns (paper §5.4 notes the
    GEMM fluctuations come from exactly this).
    """

    name: str
    microarch: str
    interface: str            # "RoCC" | "CSR"
    freq_hz: float
    dispatch_cycles: int      # per asyncMatMul task
    check_cycles: int         # per checkMatmul poll
    dram_efficiency: float    # achieved / nominal bandwidth
    l2_bytes: float = 1 * MEBI  # unfused intermediates below this stay on-chip

    # Vector unit attached to this CPU (the paper pairs Saturn 512-bit RVV).
    vector_bits: int = 512
    vector_issue: int = 1     # vector ops issued per cycle


# ---------------------------------------------------------------------------
# The four integration platforms (paper Table 3 / §5.2).
# Dispatch costs: RoCC is a tightly-coupled custom-instruction port (a few
# cycles); the CSR mailbox on Kunminghu costs a CSR write per field.
# ---------------------------------------------------------------------------
ROCKET = CpuPlatform("rocket", "in-order 1-issue", "RoCC", 2.0 * GIGA,
                     dispatch_cycles=24, check_cycles=6, dram_efficiency=0.92)
SHUTTLE = CpuPlatform("shuttle", "in-order 3-issue", "RoCC", 2.0 * GIGA,
                      dispatch_cycles=16, check_cycles=4, dram_efficiency=0.92)
BOOM = CpuPlatform("boom", "OoO 4-issue", "RoCC", 2.0 * GIGA,
                   dispatch_cycles=12, check_cycles=3, dram_efficiency=0.92)
KUNMINGHU = CpuPlatform("kunminghu", "OoO 6-issue", "CSR", 2.0 * GIGA,
                        dispatch_cycles=96, check_cycles=12, dram_efficiency=0.92)

PLATFORMS = {p.name: p for p in (ROCKET, SHUTTLE, BOOM, KUNMINGHU)}


@dataclasses.dataclass(frozen=True)
class CommercialBaseline:
    """Paper Table 5: commercial matrix extensions we compare against.

    ``sync_overhead`` models the fine-grained synchronous-instruction
    execution model (no matrix/vector overlap, per-tile issue pressure in
    the CPU instruction window) as a multiplicative derate on achievable
    matrix throughput on large GEMM (Fig. 8 regime).

    ``op_coverage`` is the per-workload *framework efficiency* the paper
    measures (§5.4 commentary): SME/ORT has **no convolution support**
    (ResNet falls back to scalar/NEON paths), MMA/ORT operator coverage
    is far behind OpenVINO on ResNet, OpenVINO pays softmax/SiLU costs on
    Llama3, etc.  These nine scalars are calibrated once against the
    paper's *unfused* column of Table 6 and then held fixed — the
    fused/unfused ratios and the overlap-contribution split remain
    genuine model predictions (benchmarks/run.py reports both raw and
    coverage-calibrated numbers).
    """

    name: str
    ise: str
    framework: str
    bandwidth: float          # bytes/s per core (MLC / STREAM measured)
    int8_peak: float          # ops/s per core
    sync_overhead: float      # fraction of peak reachable on large GEMM
    vector_relative: float    # vector-unit throughput relative to Saturn-512
    op_coverage: tuple = ()   # ((workload, efficiency), ...)

    def coverage(self, workload: "str | None") -> float:
        return dict(self.op_coverage).get(workload, 1.0)


XEON_8580 = CommercialBaseline(
    "xeon8580", "AMX", "OpenVINO", 49.48 * GIGA, 4.6 * TERA,
    sync_overhead=0.72, vector_relative=2.0,
    # Best operator support of the three (§5.4); Llama3 pays SmoothQuant
    # (de)quant + softmax overheads OpenVINO does not fuse.
    op_coverage=(("resnet50", 0.60), ("bert", 0.55), ("llama3", 0.45)))
IBM_S1022 = CommercialBaseline(
    "ibms1022", "MMA", "ONNXRuntime", 52.37 * GIGA, 2.0 * TERA,
    sync_overhead=0.35, vector_relative=1.0,
    # ORT+OpenBLAS coverage is weak on conv (Fig. 9 commentary).
    op_coverage=(("resnet50", 0.28), ("bert", 0.80), ("llama3", 1.0)))
APPLE_M4 = CommercialBaseline(
    "applem4", "SME", "ONNXRuntime", 131.31 * GIGA, 4.0 * TERA,
    sync_overhead=0.80, vector_relative=1.5,
    # "Currently, SME lacks support for convolution operators" (§5.4).
    op_coverage=(("resnet50", 0.16), ("bert", 0.40), ("llama3", 0.30)))

BASELINES = {b.name: b for b in (XEON_8580, IBM_S1022, APPLE_M4)}


# ---------------------------------------------------------------------------
# TPU target (the hardware-adaptation side).
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TpuChip:
    """Per-chip constants for the roofline and the tile constraint model."""

    name: str
    peak_bf16: float          # FLOP/s
    peak_int8: float          # OP/s
    hbm_bw: float             # bytes/s
    hbm_bytes: float          # capacity
    ici_bw: float             # bytes/s per link
    ici_links: int            # links per chip in a 2D torus
    vmem_bytes: float         # software-managed vector memory
    mxu_shape: tuple = (128, 128)   # systolic array dims
    vpu_lanes: int = 8 * 128        # VPU ALUs

    @property
    def ici_bw_total(self) -> float:
        return self.ici_bw * self.ici_links


# TPU v5e (assignment-provided constants: 197 TFLOP/s bf16, 819 GB/s HBM,
# ~50 GB/s per ICI link).
TPU_V5E = TpuChip(
    name="tpu_v5e",
    peak_bf16=197 * TERA,
    peak_int8=394 * TERA,
    hbm_bw=819 * GIGA,
    hbm_bytes=16 * GIBI,
    ici_bw=50 * GIGA,
    ici_links=4,
    vmem_bytes=128 * MEBI,
)

TARGET_CHIP = TPU_V5E

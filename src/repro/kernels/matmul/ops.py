"""jit'd wrapper around the fused matmul kernel.

Responsibilities: flatten batch dims, pick tile sizes from the Eq.2
solver (clamped to the problem), pad every axis to tile multiples
(zero K-padding is exact for both int and float accumulation), assemble
the optional epilogue-operand BlockSpecs, and slice the padding back off.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import constraint
from repro.core.fusion import Epilogue, EpilogueOperands
from repro.core.precision import PrecisionPolicy
from repro.core.task import BiasType
from repro.kernels.matmul.matmul import fused_matmul_kernel

_LANE = 128


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def default_tiles(m: int, n: int, k: int, policy: PrecisionPolicy):
    """Eq.2-solved tile, clamped to the (padded) problem size."""
    tc = constraint.solve_tiles(policy.data_type)
    bm = min(tc.bm, _round_up(m, _LANE))
    bn = min(tc.bn, _round_up(n, _LANE))
    bk = min(tc.bk, _round_up(k, _LANE))
    return bm, bn, bk


def _round_up(x, m):
    return x + (-x) % m


def supports(a_shape, b_shape, epilogue: Epilogue) -> bool:
    """Kernel contract: >=2D a, 2D (or GLU-3D) b, lane-sized inner dims."""
    if len(b_shape) not in (2, 3):
        return False
    n = b_shape[-1] * (2 if len(b_shape) == 3 else 1)
    return (a_shape[-1] % _LANE == 0 and n % _LANE == 0)


@functools.partial(jax.jit, static_argnames=("epilogue", "policy",
                                             "block_shape", "interpret"))
def fused_matmul(a: jax.Array, b: jax.Array, *,
                 epilogue: Epilogue = Epilogue(),
                 operands: EpilogueOperands = EpilogueOperands(),
                 policy: Optional[PrecisionPolicy] = None,
                 block_shape: Optional[tuple] = None,
                 interpret: bool = True) -> jax.Array:
    """epilogue(a @ b).  a: (..., M, K); b: (K, N) or (K, 2, N/2) for GLU."""
    from repro.core.fusion import _infer_policy   # cycle-free at call time
    if policy is None:
        policy = _infer_policy(a)
    import dataclasses
    if epilogue.out_dtype is None:
        epilogue = dataclasses.replace(epilogue, out_dtype=policy.output_dtype)

    lead = a.shape[:-2]
    m, k = a.shape[-2], a.shape[-1]
    a2 = a.reshape((-1, k)) if lead else a
    if lead:
        m = a2.shape[0]
    if epilogue.glu and b.ndim == 2:
        b = b.reshape(k, 2, b.shape[-1] // 2)
    n_logical = b.shape[-1] * (2 if b.ndim == 3 else 1)

    bm, bn, bk = block_shape or default_tiles(m, n_logical, k, policy)
    a2 = _pad_to(_pad_to(a2, 0, bm), 1, bk)
    if b.ndim == 3:
        b_p = _pad_to(_pad_to(b, 0, bk), 2, bn // 2)
    else:
        b_p = _pad_to(_pad_to(b, 0, bk), 1, bn)
    mp, kp = a2.shape
    n_p = b_p.shape[-1] * (2 if b.ndim == 3 else 1)
    grid = (mp // bm, n_p // bn, kp // bk)

    acc_dtype = policy.accum_dtype
    n_out = n_p // 2 if epilogue.glu else n_p
    bn_out = bn // 2 if epilogue.glu else bn

    in_arrays = [a2, b_p]
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        (pl.BlockSpec((bk, 2, bn // 2), lambda i, j, kk: (kk, 0, j))
         if b.ndim == 3 else
         pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))),
    ]

    def _add_col_operand(x, width):
        """(N,)-shaped epilogue operand, padded & blocked along columns."""
        if epilogue.glu:
            x = _pad_to(x.reshape(2, -1), 1, width // 2)
            in_specs.append(pl.BlockSpec((2, width // 2),
                                         lambda i, j, kk: (0, j)))
        else:
            x = _pad_to(x, 0, width)
            in_specs.append(pl.BlockSpec((width,), lambda i, j, kk: (j,)))
        in_arrays.append(x)

    if epilogue.bias_type == BiasType.ROW:
        _add_col_operand(operands.bias, bn)
    elif epilogue.bias_type == BiasType.FULL:
        in_arrays.append(_pad_to(_pad_to(operands.bias, 0, bm), 1, bn))
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)))
    if epilogue.has_scale_a:
        in_arrays.append(_pad_to(operands.scale_a.reshape(-1), 0, bm))
        in_specs.append(pl.BlockSpec((bm,), lambda i, j, kk: (i,)))
    if epilogue.has_scale_b:
        _add_col_operand(operands.scale_b, bn)
    if epilogue.has_residual:
        res = operands.residual.reshape((-1, operands.residual.shape[-1]))
        in_arrays.append(_pad_to(_pad_to(res, 0, bm), 1, bn_out))
        in_specs.append(pl.BlockSpec((bm, bn_out), lambda i, j, kk: (i, j)))

    kernel = functools.partial(fused_matmul_kernel, ep=epilogue,
                               n_k=grid[2], acc_dtype=acc_dtype)
    try:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    except (AttributeError, TypeError):
        compiler_params = None

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn_out), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, n_out), epilogue.out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        compiler_params=compiler_params,
        interpret=interpret,
    )(*in_arrays)

    out = out[:m, : (n_logical // 2 if epilogue.glu else n_logical)]
    if lead:
        out = out.reshape(*lead, a.shape[-2], out.shape[-1])
    return out

"""Whisper-tiny encoder-decoder (audio) — backbone only, conv stub.

Per the assignment, the conv frontend is a STUB: ``input_specs()``
supplies precomputed frame embeddings (B, n_audio_ctx, d) in place of
mel → conv1d×2 → GELU.  The backbone is faithful Whisper: pre-LN
transformer, learned positional embeddings, encoder bidirectional,
decoder causal self-attention + cross-attention, tied output embedding.

Serving: prefill precomputes the encoder once and caches per-layer
cross-attention K/V (the paper's "weights resident in scratchpad" reuse
pattern at serving scale, DESIGN.md §4); decode appends to the causal
self-attention cache.
"""

from __future__ import annotations

import sys

import jax
import jax.numpy as jnp

from repro.core.fusion import linear
from repro.models import common as cm
from repro.models.base import ArchConfig, register_family


def _attn_block_init(cfg, key, cross: bool):
    ks = jax.random.split(key, 3)
    p = {
        "attn": cm.attn_init(cfg, ks[0]),
        "ln": jnp.ones((cfg.d_model,), cfg.dtype),
        "ln_b": jnp.zeros((cfg.d_model,), cfg.dtype),
    }
    if cross:
        p["cross"] = cm.attn_init(cfg, ks[1])
        p["ln_cross"] = jnp.ones((cfg.d_model,), cfg.dtype)
        p["ln_cross_b"] = jnp.zeros((cfg.d_model,), cfg.dtype)
    p["mlp"] = cm.mlp_init(cfg, ks[2])
    p["ln_mlp"] = jnp.ones((cfg.d_model,), cfg.dtype)
    p["ln_mlp_b"] = jnp.zeros((cfg.d_model,), cfg.dtype)
    return p


def init(cfg: ArchConfig, key):
    ed = cfg.encdec
    ks = jax.random.split(key, 8)
    v = cfg.padded_vocab
    enc_keys = jax.random.split(ks[2], ed.n_encoder_layers)
    dec_keys = jax.random.split(ks[3], cfg.n_layers)
    return {
        "embedding": cm.embed_init(ks[0], (v, cfg.d_model), cfg.dtype),
        "pos_dec": cm.embed_init(ks[1], (ed.max_positions, cfg.d_model),
                                 cfg.dtype),
        "pos_enc": cm.embed_init(ks[4], (ed.n_audio_ctx, cfg.d_model),
                                 cfg.dtype),
        "enc_layers": jax.vmap(
            lambda k: _attn_block_init(cfg, k, cross=False))(enc_keys),
        "dec_layers": jax.vmap(
            lambda k: _attn_block_init(cfg, k, cross=True))(dec_keys),
        "ln_enc_final": jnp.ones((cfg.d_model,), cfg.dtype),
        "ln_enc_final_b": jnp.zeros((cfg.d_model,), cfg.dtype),
        "ln_final": jnp.ones((cfg.d_model,), cfg.dtype),
        "ln_final_b": jnp.zeros((cfg.d_model,), cfg.dtype),
    }


def encode(cfg: ArchConfig, params, audio_embeds):
    """audio_embeds: (B, Ta, d) — stub conv output."""
    x = audio_embeds.astype(cfg.dtype)
    x = x + params["pos_enc"][None, : x.shape[1]]

    def body(carry, lp):
        x = carry
        h = cm.layernorm(x, lp["ln"], lp["ln_b"])
        q, k, v = cm.qkv_project(cfg, lp["attn"], h, None)
        ctx = cm.attention(cfg, q, k, v, causal=False)
        x = x + cm.attn_out(cfg, lp["attn"], ctx)
        h = cm.layernorm(x, lp["ln_mlp"], lp["ln_mlp_b"])
        x = x + cm.mlp_apply(cfg, lp["mlp"], h)
        return x, None

    if cfg.remat != "none":
        body = jax.checkpoint(body, policy=cm.remat_policy(cfg),
                              prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return cm.layernorm(x, params["ln_enc_final"], params["ln_enc_final_b"])


def _dec_block(cfg, lp, x, positions, enc_out=None, cross_kv=None,
               self_kv=None, cache_pos=None):
    h = cm.layernorm(x, lp["ln"], lp["ln_b"])
    q, k, v = cm.qkv_project(cfg, lp["attn"], h, None)
    new_self = None
    if self_kv is not None:
        k_c, v_c = cm.cache_update(self_kv[0], self_kv[1], k, v, cache_pos)
        new_self = (k_c, v_c)
        if q.shape[2] == 1:
            from repro.kernels.attention.ops import decode_attention
            ctx = decode_attention(q, k_c, v_c, cache_pos + 1,
                                   sm_scale=cfg.sm_scale)
        else:
            ctx = cm.attention(cfg, q, k, v, causal=True)
    else:
        ctx = cm.attention(cfg, q, k, v, causal=True)
    x = x + cm.attn_out(cfg, lp["attn"], ctx)

    h = cm.layernorm(x, lp["ln_cross"], lp["ln_cross_b"])
    qc = linear(h, lp["cross"]["wq"]).reshape(
        h.shape[0], h.shape[1], cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
    if cross_kv is not None:
        kc, vc = cross_kv
    else:
        kc = linear(enc_out, lp["cross"]["wk"]).reshape(
            enc_out.shape[0], -1, cfg.n_kv_heads,
            cfg.head_dim).transpose(0, 2, 1, 3)
        vc = linear(enc_out, lp["cross"]["wv"]).reshape(
            enc_out.shape[0], -1, cfg.n_kv_heads,
            cfg.head_dim).transpose(0, 2, 1, 3)
    ctx = cm.attention(cfg, qc, kc, vc, causal=False)
    x = x + cm.attn_out(cfg, lp["cross"], ctx)

    h = cm.layernorm(x, lp["ln_mlp"], lp["ln_mlp_b"])
    x = x + cm.mlp_apply(cfg, lp["mlp"], h)
    return x, new_self, (kc, vc)


def _decode_stack(cfg, params, x, positions, enc_out=None, caches=None,
                  cache_pos=None):
    def body(carry, layer):
        x = carry
        if caches is not None:
            lp, self_kv, cross_kv = layer
            x, new_self, _ = _dec_block(cfg, lp, x, positions,
                                        cross_kv=cross_kv, self_kv=self_kv,
                                        cache_pos=cache_pos)
            return x, (new_self, cross_kv)
        lp = layer
        x, _, _ = _dec_block(cfg, lp, x, positions, enc_out=enc_out)
        return x, None

    if cfg.remat != "none":
        body = jax.checkpoint(body, policy=cm.remat_policy(cfg),
                              prevent_cse=False)
    xs = ((params["dec_layers"], caches["self"], caches["cross"])
          if caches is not None else params["dec_layers"])
    x, ys = jax.lax.scan(body, x, xs)
    return x, ys


def forward(cfg: ArchConfig, params, batch, return_hidden: bool = False):
    """batch: tokens (B, S) + audio_embeds (B, Ta, d)."""
    enc_out = encode(cfg, params, batch["audio_embeds"])
    tokens = batch["tokens"]
    x = cm.embed_tokens(cfg, params["embedding"], tokens)
    x = x + params["pos_dec"][None, : x.shape[1]]
    x, _ = _decode_stack(cfg, params, x, None, enc_out=enc_out)
    x = cm.layernorm(x, params["ln_final"], params["ln_final_b"])
    if return_hidden:
        return x
    return cm.logits_out(cfg, params, x)


def init_cache(cfg: ArchConfig, batch_size: int, max_len: int, dtype=None):
    dtype = dtype or cfg.kv_cache_dtype
    n, ed = cfg.n_layers, cfg.encdec
    self_shape = (n, batch_size, cfg.n_kv_heads, max_len, cfg.head_dim)
    cross_shape = (n, batch_size, cfg.n_kv_heads, ed.n_audio_ctx,
                   cfg.head_dim)
    return {"self": (jnp.zeros(self_shape, dtype),
                     jnp.zeros(self_shape, dtype)),
            "cross": (jnp.zeros(cross_shape, dtype),
                      jnp.zeros(cross_shape, dtype))}


def prefill(cfg: ArchConfig, params, batch, cache):
    """Encode audio, cache cross-KV, run the decoder prompt."""
    enc_out = encode(cfg, params, batch["audio_embeds"])

    # Cross-attention K/V per decoder layer (vmapped over the layer stack).
    def cross_kv(lp):
        k = linear(enc_out, lp["cross"]["wk"]).reshape(
            enc_out.shape[0], -1, cfg.n_kv_heads,
            cfg.head_dim).transpose(0, 2, 1, 3)
        v = linear(enc_out, lp["cross"]["wv"]).reshape(
            enc_out.shape[0], -1, cfg.n_kv_heads,
            cfg.head_dim).transpose(0, 2, 1, 3)
        return k.astype(cache["cross"][0].dtype), v.astype(
            cache["cross"][1].dtype)

    kc, vc = jax.vmap(cross_kv)(params["dec_layers"])
    cache = dict(cache)
    cache["cross"] = (kc, vc)

    tokens = batch["tokens"]
    x = cm.embed_tokens(cfg, params["embedding"], tokens)
    x = x + params["pos_dec"][None, : x.shape[1]]
    x, ys = _decode_stack(cfg, params, x, None, caches=cache, cache_pos=0)
    new_self, _ = ys
    x = cm.layernorm(x, params["ln_final"], params["ln_final_b"])
    return (cm.logits_out(cfg, params, x[:, -1]),
            {"self": new_self, "cross": cache["cross"]})


def decode_step(cfg: ArchConfig, params, tokens, cache, pos):
    x = cm.embed_tokens(cfg, params["embedding"], tokens)
    x = x + jax.lax.dynamic_slice_in_dim(params["pos_dec"], pos, 1)[None]
    x, ys = _decode_stack(cfg, params, x, None, caches=cache, cache_pos=pos)
    new_self, _ = ys
    x = cm.layernorm(x, params["ln_final"], params["ln_final_b"])
    return (cm.logits_out(cfg, params, x[:, -1]),
            {"self": new_self, "cross": cache["cross"]})


register_family("encdec")(sys.modules[__name__])

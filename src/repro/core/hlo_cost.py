"""Trip-count-aware cost extraction from compiled (post-optimization) HLO.

``compiled.cost_analysis()`` counts every ``while`` body **once** —
useless for scan-over-layers models (verified empirically: a 10-step
scan reports the same FLOPs as a 1-step scan).  This module walks the
compiled HLO text instead:

* computations are parsed into instruction lists with a per-computation
  symbol table (name → result dtype/shape) so operand shapes resolve;
* the call graph is walked from ENTRY; ``while`` ops multiply their body
  cost by the trip count taken from XLA's
  ``backend_config={"known_trip_count":{"n":...}}`` annotation (fallback:
  the ``compare(iv, constant), direction=LT`` pattern in the condition);
* FLOPs: ``dot`` = 2 · |result| · contraction size; convolution
  approximated from kernel volume; everything else ignored (dots
  dominate every model here by ≫100×);
* bytes: result + operand bytes of top-level instructions (fusion
  boundaries — the same HBM-traffic convention ``cost_analysis`` uses),
  multiplied by trip counts; instructions *inside* fusion computations
  contribute FLOPs only;
* collective bytes: result-shape bytes per collective kind × trip count.

All numbers are per-device (the compiled module is one device's SPMD
program).  Validated in tests against unrolled references where
``cost_analysis`` is exact.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_INST_HEAD_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_OP_RE = re.compile(r"\s*([a-z][a-z0-9\-]*)\(")


def _split_instruction(line: str):
    """name = TYPE op(args...) — TYPE may be a tuple with /*index=N*/
    comments (which contain '=' and break naive regexes); parens in tuple
    types are balanced, so scan for the matching close."""
    m = _INST_HEAD_RE.match(line)
    if not m:
        return None
    rest = line[m.end():]
    if rest.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        type_text, tail = rest[: end + 1], rest[end + 1:]
    else:
        mm = re.match(r"^\S+\s*", rest)
        if not mm:
            return None
        type_text, tail = mm.group(0), rest[mm.end():]
    mo = _OP_RE.match(tail)
    if not mo:
        return None
    return m.group(1), type_text, mo.group(1), tail[mo.end():]
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes_of_text(text: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shapes_in(text: str):
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        shape = tuple(int(d) for d in dims.split(",")) if dims else ()
        out.append((dt, shape))
    return out


@dataclasses.dataclass
class Instruction:
    name: str
    op: str
    result_text: str
    rest: str               # everything after the op's '('
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instructions: "list[Instruction]" = dataclasses.field(default_factory=list)
    symbols: dict = dataclasses.field(default_factory=dict)


def parse_module(hlo: str):
    comps: "dict[str, Computation]" = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        hm = _COMP_HDR_RE.match(line.strip())
        if hm:
            cur = Computation(hm.group(2), bool(hm.group(1)))
            comps[cur.name] = cur
            if cur.is_entry:
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        parts = _split_instruction(line)
        if parts:
            name, type_text, op, args = parts
            inst = Instruction(name, op, type_text, args, line)
            cur.instructions.append(inst)
            cur.symbols[inst.name] = _shapes_in(type_text)
    return comps, entry


def _operand_names(inst: Instruction):
    head = inst.rest.split(")", 1)[0]
    return _OPERAND_RE.findall(head)


def _sym_bytes(comp: Computation, name: str) -> float:
    total = 0.0
    for dt, shape in comp.symbols.get(name, ()):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _operand_bytes(comp: Computation, inst: Instruction) -> float:
    return sum(_sym_bytes(comp, n) for n in _operand_names(inst))


_SLICE_OPS = ("dynamic-slice", "gather", "slice")
_PARAM_IDX_RE = re.compile(r"param_(\d+)")


_PASSTHROUGH = ("bitcast", "convert", "copy", "reshape", "transpose")


def _fusion_sliced_params(comp: Computation) -> dict:
    """param index -> slice-result bytes, for fusion computations that
    dynamic-slice / gather / dynamic-update-slice a parameter (stacked
    layer weights, remat carries, KV caches): the HBM traffic is the
    slice/update, not the whole stacked operand.  Parameters reached
    through bitcast/convert/copy chains count too."""
    # Resolve pass-through chains back to parameter indices.
    root: dict = {}
    for inst in comp.instructions:
        if inst.op == "parameter":
            m = _PARAM_IDX_RE.match(inst.name)
            if m:
                root[inst.name] = int(m.group(1))
        elif inst.op in _PASSTHROUGH:
            ops = _operand_names(inst)
            if ops and ops[0] in root:
                root[inst.name] = root[ops[0]]

    out: dict = {}

    def mark(name, nbytes):
        if name in root:
            idx = root[name]
            out[idx] = max(out.get(idx, 0.0), nbytes)

    for inst in comp.instructions:
        ops = _operand_names(inst)
        if not ops:
            continue
        if inst.op in _SLICE_OPS:
            mark(ops[0], _shape_bytes_of_text(inst.result_text))
        elif inst.op == "dynamic-update-slice" and len(ops) > 1:
            mark(ops[0], _sym_bytes(comp, ops[1]))
    return out


def _fusion_bytes(comps: dict, comp: Computation, inst: Instruction) -> float:
    """Boundary bytes of a fusion op with slice-aware operand accounting.

    If the fusion's root is a dynamic-update-slice the output buffer is
    aliased with its input: the write traffic is the update slice, not
    the whole (e.g. 95-layer-stacked) buffer.
    """
    m = _CALLS_RE.search(inst.line)
    called = comps.get(m.group(1)) if m else None
    sliced = _fusion_sliced_params(called) if called is not None else {}
    result_bytes = _shape_bytes_of_text(inst.result_text)
    if called is not None and called.instructions:
        by_name = {i.name: i for i in called.instructions}
        root = called.instructions[-1]
        hops = 0
        while root.op in _PASSTHROUGH and hops < 8:
            ops = _operand_names(root)
            if not ops or ops[0] not in by_name:
                break
            root = by_name[ops[0]]
            hops += 1
        if root.op == "dynamic-update-slice":
            ops = _operand_names(root)
            if len(ops) > 1:
                result_bytes = min(result_bytes, _sym_bytes(called, ops[1]))
    total = result_bytes
    for i, name in enumerate(_operand_names(inst)):
        full = _sym_bytes(comp, name)
        total += min(full, sliced[i]) if i in sliced else full
    return total


def _dot_flops(comp: Computation, inst: Instruction) -> float:
    rshapes = _shapes_in(inst.result_text)
    out_elems = 1
    if rshapes:
        for d in rshapes[0][1]:
            out_elems *= d
    head = inst.rest.split(")", 1)[0]
    ops = _OPERAND_RE.findall(head)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.line)
    contract = 1
    if ops and m:
        lhs_shapes = comp.symbols.get(ops[0], ())
        if lhs_shapes:
            lhs = lhs_shapes[0][1]
            for d in m.group(1).split(","):
                if d and int(d) < len(lhs):
                    contract *= lhs[int(d)]
    return 2.0 * out_elems * contract


def _conv_flops(comp: Computation, inst: Instruction) -> float:
    rshapes = _shapes_in(inst.result_text)
    if not rshapes:
        return 0.0
    out = rshapes[0][1]
    out_elems = 1
    for d in out:
        out_elems *= d
    head = inst.rest.split(")", 1)[0]
    ops = _OPERAND_RE.findall(head)
    ker_elems = 1
    if len(ops) >= 2:
        ks = comp.symbols.get(ops[1], ())
        if ks:
            for d in ks[0][1]:
                ker_elems *= d
    cout = out[-1] if out else 1
    return 2.0 * out_elems * max(ker_elems // max(cout, 1), 1)


def _trip_count(comps: dict, inst: Instruction) -> Optional[int]:
    m = _TRIP_RE.search(inst.line)
    if m:
        return int(m.group(1))
    cm = _COND_RE.search(inst.line)
    if not cm:
        return None
    # Fallback: largest positive constant in the condition subtree with a
    # direction=LT compare anywhere below it.
    seen, stack, consts, has_lt = set(), [cm.group(1)], [], False
    while stack:
        name = stack.pop()
        if name in seen or name not in comps:
            continue
        seen.add(name)
        for i in comps[name].instructions:
            if "direction=LT" in i.line:
                has_lt = True
            if i.op == "constant":
                mc = re.search(r"constant\((-?\d+)\)", i.line)
                if mc:
                    consts.append(int(mc.group(1)))
            stack.extend(_CALLS_RE.findall(i.line))
    pos = [c for c in consts if c > 0]
    return max(pos) if (has_lt and pos) else None


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    per_collective: dict = dataclasses.field(default_factory=dict)
    unparsed_loops: int = 0


def _fusion_targets(comps: dict) -> set:
    fused = set()
    for comp in comps.values():
        for inst in comp.instructions:
            if inst.op == "fusion":
                m = _CALLS_RE.search(inst.line)
                if m:
                    fused.add(m.group(1))
    return fused


def analyze(hlo: str) -> HloCost:
    comps, entry = parse_module(hlo)
    cost = HloCost()
    if entry is None:
        return cost
    fused = _fusion_targets(comps)

    def walk(name: str, mult: float, stack=()):
        comp = comps.get(name)
        if comp is None or name in stack:
            return
        inside_fusion = name in fused
        for inst in comp.instructions:
            op = inst.op
            if op == "while":
                body = _CALLS_RE.search(inst.line)
                trip = _trip_count(comps, inst)
                if trip is None:
                    trip = 1
                    cost.unparsed_loops += 1
                if body:
                    walk(body.group(1), mult * trip, stack + (name,))
                continue
            for target in _CALLS_RE.findall(inst.line):
                walk(target, mult, stack + (name,))
            for target in re.findall(
                    r"(?:true_computation|false_computation)=%?([\w.\-]+)",
                    inst.line):
                walk(target, mult, stack + (name,))
            if op == "dot":
                cost.flops += mult * _dot_flops(comp, inst)
            elif op == "convolution":
                cost.flops += mult * _conv_flops(comp, inst)
            elif any(op.startswith(c) for c in _COLLECTIVES):
                if op.endswith("-done"):
                    continue
                b = _shape_bytes_of_text(inst.result_text)
                kind = next(c for c in _COLLECTIVES if op.startswith(c))
                cost.collective_bytes += mult * b
                cost.per_collective[kind] = (
                    cost.per_collective.get(kind, 0.0) + mult * b)
            if not inside_fusion and op not in (
                    "parameter", "constant", "get-tuple-element", "tuple",
                    "bitcast", "copy"):
                if op == "fusion":
                    cost.bytes += mult * _fusion_bytes(comps, comp, inst)
                elif op in _SLICE_OPS:
                    cost.bytes += mult * 2 * _shape_bytes_of_text(
                        inst.result_text)
                elif op == "dynamic-update-slice":
                    ops_ = _operand_names(inst)
                    upd = (_sym_bytes(comp, ops_[1]) if len(ops_) > 1
                           else _shape_bytes_of_text(inst.result_text))
                    cost.bytes += mult * 2 * upd
                else:
                    cost.bytes += mult * (
                        _shape_bytes_of_text(inst.result_text)
                        + _operand_bytes(comp, inst))

    walk(entry, 1.0)
    return cost

"""Flash-attention kernel + chunked-XLA path vs the dense oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention.ops import decode_attention, flash_attention
from repro.kernels.attention.ref import attention_ref
from repro.models.common import attention_xla_chunked


def _check(out, ref, rtol=2e-2):
    o, r = np.asarray(out, np.float32), np.asarray(ref, np.float32)
    err = np.abs(o - r).max() / (np.abs(r).max() + 1e-9)
    assert err < rtol, err


CASES = [
    dict(B=2, H=4, HKV=4, SQ=128, SK=128, D=64),
    dict(B=2, H=8, HKV=2, SQ=128, SK=128, D=64),              # GQA
    dict(B=1, H=4, HKV=4, SQ=100, SK=100, D=64),              # unaligned
    dict(B=1, H=4, HKV=4, SQ=256, SK=256, D=64, window=64),   # local
    dict(B=1, H=4, HKV=2, SQ=128, SK=128, D=64, softcap=50.0),
    dict(B=1, H=4, HKV=4, SQ=96, SK=96, D=64, causal=False),  # encoder
    dict(B=1, H=4, HKV=4, SQ=64, SK=192, D=64, causal=False), # cross
    dict(B=1, H=4, HKV=4, SQ=64, SK=192, D=64, q_start=128),  # chunked
    dict(B=1, H=8, HKV=4, SQ=160, SK=160, D=32, window=32, softcap=50.0),
]


def _mk(case, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (case["B"], case["H"], case["SQ"],
                                  case["D"]), dtype)
    k = jax.random.normal(ks[1], (case["B"], case["HKV"], case["SK"],
                                  case["D"]), dtype)
    v = jax.random.normal(ks[2], (case["B"], case["HKV"], case["SK"],
                                  case["D"]), dtype)
    kw = {k_: case[k_] for k_ in ("causal", "window", "softcap", "q_start")
          if k_ in case}
    return q, k, v, kw


@pytest.mark.parametrize("case", CASES, ids=lambda c: str(sorted(c.items())))
def test_pallas_kernel_vs_oracle(case):
    q, k, v, kw = _mk(case)
    out = flash_attention(q, k, v, block_q=64, block_kv=64, **kw)
    ref = attention_ref(q, k, v, **kw)
    _check(out, ref)


@pytest.mark.parametrize("case", CASES, ids=lambda c: str(sorted(c.items())))
def test_xla_chunked_vs_oracle(case):
    """The distributed/dry-run attention path computes the same function."""
    q, k, v, kw = _mk(case)
    out = attention_xla_chunked(q, k, v, sm_scale=q.shape[-1] ** -0.5,
                                chunk=64, **kw)
    ref = attention_ref(q, k, v, **kw)
    _check(out, ref, rtol=1e-3)


def test_bf16(case=CASES[1]):
    q, k, v, kw = _mk(case, jnp.bfloat16)
    out = flash_attention(q, k, v, block_q=64, block_kv=64, **kw)
    ref = attention_ref(q, k, v, **kw)
    _check(out, ref, rtol=4e-2)


def test_decode_matches_prefix_oracle():
    B, H, HKV, S, D, L = 2, 8, 2, 64, 32, 40
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, 1, D))
    kc = jax.random.normal(ks[1], (B, HKV, S, D))
    vc = jax.random.normal(ks[2], (B, HKV, S, D))
    out = decode_attention(q, kc, vc, jnp.array([L, L]))
    ref = attention_ref(q, kc[:, :, :L], vc[:, :, :L], q_start=L - 1)
    _check(out, ref, rtol=1e-4)


def test_decode_window():
    B, H, HKV, S, D, L, W = 1, 4, 1, 64, 32, 50, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, H, 1, D))
    kc = jax.random.normal(ks[1], (B, HKV, S, D))
    vc = jax.random.normal(ks[2], (B, HKV, S, D))
    out = decode_attention(q, kc, vc, jnp.array([L]), window=W)
    ref = attention_ref(q, kc[:, :, L - W:L], vc[:, :, L - W:L],
                        q_start=W - 1)
    _check(out, ref, rtol=1e-4)


def test_grad_flows_through_chunked_attention():
    """The remat'd chunk body must be differentiable (training path)."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 64, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 64, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 64, 32))

    def f(q, k, v):
        return attention_xla_chunked(q, k, v, sm_scale=0.17, chunk=32).sum()

    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    for gi in g:
        assert bool(jnp.all(jnp.isfinite(gi)))

    # grad matches dense-attention grad
    def f_ref(q, k, v):
        return attention_ref(q, k, v, sm_scale=0.17).sum()

    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for gi, gr in zip(g, g_ref):
        np.testing.assert_allclose(np.asarray(gi), np.asarray(gr),
                                   rtol=1e-3, atol=1e-4)

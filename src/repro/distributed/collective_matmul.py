"""Collective matmul: overlap an all-gather with the matmul that consumes it.

The cluster-scale mirror of the paper's matrix–vector overlap: instead of
``all_gather(x) @ w`` (link idle during compute, MXU idle during
gather), walk the ring with ``ppermute`` and multiply each arriving shard
immediately — compute and communication pipeline at shard granularity
(Wang et al., "Overlap communication with dependent computation", the
pattern XLA's async collectives approximate automatically).

In HLO this replaces one ``all-gather`` of X with N-1 ``collective-
permute``s of X/N each — same total bytes, but every chunk overlaps a
chunk matmul (§Perf collective-term iterations use this on the logits
GEMM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from repro.core.jaxcompat import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def _ring_matmul(x_shard, w_shard, axis_name: str, n_dev: int):
    """x_shard: (m_local, k); w_shard: (k, n_local) — X sharded on rows
    over the ring, W sharded on cols.  Output: (m_local, n) — i.e. the
    all-gather of W happens implicitly by rotating X? No: we rotate X
    shards around the ring and accumulate into the *full-M* output block
    owned by this device's W columns: out = all_gather(x) @ w_shard.
    ``n_dev`` is passed statically (jax.lax.axis_size is newer jax)."""
    idx = jax.lax.axis_index(axis_name)
    m_local = x_shard.shape[0]
    out = jnp.zeros((m_local * n_dev, w_shard.shape[1]), x_shard.dtype)
    perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]

    def body(i, carry):
        out, x = carry
        src = (idx - i) % n_dev                   # whose shard we hold now
        out = jax.lax.dynamic_update_slice_in_dim(
            out, jnp.dot(x, w_shard, preferred_element_type=out.dtype),
            src * m_local, axis=0)
        x = jax.lax.ppermute(x, axis_name, perm)  # overlaps next dot
        return out, x

    out, _ = jax.lax.fori_loop(0, n_dev, body, (out, x_shard))
    return out


def collective_matmul(x, w, mesh: Mesh, axis: str = "model"):
    """x: (M, K) sharded on M over ``axis``; w: (K, N) sharded on N.
    Returns (M, N) sharded on N (X implicitly all-gathered, overlapped)."""
    fn = shard_map(
        functools.partial(_ring_matmul, axis_name=axis,
                          n_dev=mesh.shape[axis]), mesh=mesh,
        in_specs=(P(axis, None), P(None, axis)),
        out_specs=P(None, axis), check_vma=False)
    return fn(x, w)


def allgather_matmul_reference(x, w):
    """The unoverlapped equivalent (numerical oracle)."""
    return jnp.dot(x, w, preferred_element_type=x.dtype)

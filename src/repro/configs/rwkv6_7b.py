"""rwkv6-7b [ssm]: 32L d=4096 (attention-free) d_ff=14336 vocab=65536.

Finch — data-dependent per-channel decay, 64 heads of size 64, DDLerp
token-shift, squared-ReLU channel mix.  Bounded state ⇒ runs long_500k.
[arXiv:2404.05892; hf]
"""

from repro.models.base import ArchConfig, RwkvConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="rwkv6",
    n_layers=32,
    d_model=4096,
    n_heads=64,                 # d_model / head_size
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    rwkv=RwkvConfig(head_size=64, lora_mix=32, lora_decay=64),
)


def reduced() -> ArchConfig:
    return CONFIG.with_(n_layers=3, d_model=128, n_heads=4, n_kv_heads=4,
                        head_dim=32, d_ff=256, vocab_size=512,
                        rwkv=RwkvConfig(head_size=32, lora_mix=8,
                                        lora_decay=8))

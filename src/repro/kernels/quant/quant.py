"""Per-row absmax int8 quantization kernel (SmoothQuant-O1 pipeline).

The paper evaluates Llama3.2-1B quantized with SmoothQuant-O1 (§5.1);
activation quantization is per-token (per-row) dynamic absmax, weights
are per-channel static.  Quantize is pure vector work — in the fused
pipeline it is a *prologue* overlapped with the previous tile's matmul
(Fig. 5); dequant rides the matmul epilogue (``scale_a``/``scale_b`` in
``cute_matmul``).

Grid: (M/bm,) — each program reduces its rows' absmax and emits int8.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def quantize_rowwise_kernel(x_ref, q_ref, scale_ref):
    x = x_ref[...].astype(jnp.float32)                      # (bm, K)
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(absmax == 0.0, 1.0, absmax / 127.0)   # (bm, 1)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    scale_ref[...] = scale[:, 0]

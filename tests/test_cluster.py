"""Cluster-scale DES + sharded execution: multi-unit topology, graph
partitioning, shared-loader contention, and cross-backend parity of the
partitioned graph (desim-cluster timelines == sharded/jax numbers)."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import backend
from repro.core.config import CASE_STUDY, PLATFORM_2TOPS
from repro.core.fusion import Epilogue, cute_matmul
from repro.core.hardware import SHUTTLE
from repro.core.simulator import LayerTrace
from repro.core.task import MatMulTask
from repro.sim import (ClusterTopology, Granularity, build_gemm_graph,
                       chrome_trace, dump_chrome_trace, partition_graph,
                       simulate_cluster, simulate_graph, workload_to_graph)
from repro.sim.resources import BandwidthResource, EventLoop


def int8_pair(key, m, n, k):
    ka, kb = jax.random.split(key)
    return (jax.random.randint(ka, (m, k), -8, 8, jnp.int8),
            jax.random.randint(kb, (k, n), -8, 8, jnp.int8))


# ---------------------------------------------------------------------------
# The shared-bandwidth loader.
# ---------------------------------------------------------------------------

class TestBandwidthResource:
    def test_fair_share_splits_bandwidth(self):
        loop = EventLoop()
        bw = BandwidthResource(loop, "l", policy="fair")
        ends = {}
        bw.transfer(100, "a", then=lambda: ends.setdefault("a", loop.now))
        bw.transfer(100, "b", then=lambda: ends.setdefault("b", loop.now))
        loop.run()
        # two equal flows at half rate each: both finish at 200
        assert ends == {"a": 200.0, "b": 200.0}
        assert bw.busy_cycles() == pytest.approx(200.0)

    def test_fair_share_staggered_arrival(self):
        loop = EventLoop()
        bw = BandwidthResource(loop, "l", policy="fair")
        ends = {}
        bw.transfer(100, "a", then=lambda: ends.setdefault("a", loop.now))
        loop.at(50, lambda: bw.transfer(
            100, "b", then=lambda: ends.setdefault("b", loop.now)))
        loop.run()
        # a: 50 alone + 50 work at half rate -> 150; b: 50 shared + 50 alone
        assert ends["a"] == pytest.approx(150.0)
        assert ends["b"] == pytest.approx(200.0)
        # per-flow spans overlap; union busy does not double count
        assert bw.busy_cycles() == pytest.approx(200.0)
        demand = sum(e - s for s, e, _ in bw.intervals)
        assert demand == pytest.approx(150.0 + 150.0)

    def test_fcfs_serialises(self):
        loop = EventLoop()
        bw = BandwidthResource(loop, "l", policy="fcfs")
        ends = {}
        bw.transfer(100, "a", then=lambda: ends.setdefault("a", loop.now))
        bw.transfer(100, "b", then=lambda: ends.setdefault("b", loop.now))
        loop.run()
        assert ends == {"a": 100.0, "b": 200.0}
        assert bw.busy_cycles() == pytest.approx(200.0)

    def test_bad_policy_rejected(self):
        with pytest.raises(ValueError, match="policy"):
            BandwidthResource(EventLoop(), "l", policy="lifo")
        with pytest.raises(ValueError):
            ClusterTopology(n_units=2, loader_policy="lifo")
        with pytest.raises(ValueError):
            ClusterTopology(n_units=0)


# ---------------------------------------------------------------------------
# Graph partitioning.
# ---------------------------------------------------------------------------

class TestPartition:
    def _gemm_graph(self, m=256, n=256, k=512, **kw):
        g, _ = build_gemm_graph(MatMulTask(m=m, n=n, k=k), 64, 64, **kw)
        return g

    def test_row_panel_contiguous_spans(self):
        p = partition_graph(self._gemm_graph(), 4, "row-panel")
        spans = p.spans["gemm"]
        assert spans == [(0, 64), (64, 128), (128, 192), (192, 256)]
        assert p.balanced("gemm")
        for node in p.graph.matmul_nodes():
            lo, hi = spans[node.unit]
            assert lo <= node.tile.m0 < hi

    def test_output_tile_shards_columns(self):
        p = partition_graph(self._gemm_graph(), 2, "output-tile")
        for node in p.graph.matmul_nodes():
            lo, hi = p.spans["gemm"][node.unit]
            assert lo <= node.tile.n0 < hi

    def test_single_unit_is_identity_placement(self):
        g = self._gemm_graph()
        p = partition_graph(g, 1, "row-panel")
        assert p.n_transfers == 0
        assert all(n.unit == 0 for n in p.graph.nodes)
        assert len(p.graph) == len(g)

    def test_layer_gran_epilogue_inserts_reduction_transfers(self):
        g = self._gemm_graph(granularity=Granularity.LAYER,
                             vector_ops={"relu": 256 * 256.0})
        p = partition_graph(g, 4, "row-panel")
        # the single epilogue consumes tiles from 3 remote units
        xfer = [n for n in p.graph.nodes if n.kind == "memory"]
        assert p.n_transfers == len(xfer) > 0
        assert p.transfer_bytes == sum(n.mem_bytes for n in xfer)
        vec = p.graph.vector_nodes()[0]
        dep_kinds = {p.graph.nodes[d].kind for d in vec.deps}
        assert "memory" in dep_kinds          # remote tiles behind transfers

    def test_panel_gran_row_panel_stays_local(self):
        """Each PANEL epilogue's tiles live on one unit: no transfers."""
        g = self._gemm_graph(granularity=Granularity.PANEL,
                             vector_ops={"relu": 256 * 256.0})
        p = partition_graph(g, 4, "row-panel")
        assert p.n_transfers == 0
        for v in p.graph.vector_nodes():
            units = {p.graph.nodes[d].unit for d in v.deps}
            assert units == {v.unit}

    def test_layer_pipeline_crosses_layers_with_transfers(self):
        layers = [LayerTrace(f"l{i}", (MatMulTask(m=64, n=64, k=64),))
                  for i in range(2)]
        g = workload_to_graph(CASE_STUDY, layers)
        p = partition_graph(g, 2, "layer-pipeline")
        assert p.unit_of_label == {"l0/g0": 0, "l1/g0": 1}
        assert p.n_transfers > 0               # activations cross units

    def test_unknown_strategy_rejected(self):
        with pytest.raises(ValueError, match="strategy"):
            partition_graph(self._gemm_graph(), 2, "diagonal")


# ---------------------------------------------------------------------------
# Cluster simulation: scaling, contention, fidelity.
# ---------------------------------------------------------------------------

def weak_scaling_run(n_units, total_bandwidth=None):
    unit = PLATFORM_2TOPS
    g, _ = build_gemm_graph(MatMulTask(m=512 * n_units, n=512, k=8192),
                            unit.m_scp, unit.n_scp)
    p = partition_graph(g, n_units, "row-panel")
    topo = ClusterTopology(n_units=n_units, unit=unit, platform=SHUTTLE,
                           total_bandwidth=total_bandwidth)
    return simulate_cluster(p.graph, topo)


class TestClusterSim:
    def test_weak_scaling_sustains_85pct_aggregate_util(self):
        """The acceptance pin: 4 units, paper GEMM regime, pooled
        bandwidth — ≥85% aggregate matrix-unit utilization with the
        shared-loader contention visible in the timeline."""
        r = weak_scaling_run(4)
        assert r.n_units == 4
        assert r.aggregate_matrix_utilization >= 0.85
        assert all(u >= 0.85 for u in r.unit_utilizations())
        # contention is visible: transfer spans overlap on the shared
        # loader (total demand exceeds union busy time)
        assert r.loader_contention() > 1.5
        # per-unit timelines exist and stay within the makespan
        for i in range(4):
            ivals = r.intervals[f"u{i}/pe_array"]
            assert ivals
            assert all(0 <= s <= e <= r.cycles + 1e-6 for s, e, _ in ivals)

    def test_fixed_bandwidth_pool_saturates_loader(self):
        """Strong bandwidth pressure: holding the pool at one unit's
        channel collapses aggregate utilization ~1/N past the knee."""
        r1 = weak_scaling_run(1, total_bandwidth=PLATFORM_2TOPS.bandwidth)
        r4 = weak_scaling_run(4, total_bandwidth=PLATFORM_2TOPS.bandwidth)
        assert r4.loader_utilization > 0.95          # saturated
        assert r4.aggregate_matrix_utilization < \
            0.5 * r1.aggregate_matrix_utilization
        assert r4.cycles > 2.0 * r1.cycles

    def test_pooled_weak_scaling_holds_makespan(self):
        r1, r4 = weak_scaling_run(1), weak_scaling_run(4)
        assert r4.cycles == pytest.approx(r1.cycles, rel=0.05)

    def test_unit_out_of_range_rejected(self):
        g, _ = build_gemm_graph(MatMulTask(m=128, n=64, k=64), 64, 64)
        p = partition_graph(g, 4, "row-panel")
        topo = ClusterTopology(n_units=2, unit=PLATFORM_2TOPS,
                               platform=SHUTTLE)
        with pytest.raises(ValueError, match="unit"):
            simulate_cluster(p.graph, topo)

    def test_transfers_occupy_shared_loader(self):
        g, _ = build_gemm_graph(MatMulTask(m=256, n=256, k=512), 64, 64,
                                granularity=Granularity.LAYER,
                                vector_ops={"relu": 256 * 256.0})
        p = partition_graph(g, 4, "row-panel")
        topo = ClusterTopology(n_units=4, unit=PLATFORM_2TOPS,
                               platform=SHUTTLE)
        r = simulate_cluster(p.graph, topo)
        xfer_spans = [iv for iv in r.intervals["mem_loader"]
                      if "/xfer@" in iv[2]]
        assert len(xfer_spans) == p.n_transfers > 0


class TestKStreamFidelity:
    """DES-fidelity ROADMAP item: K-chunked scratchpad streaming
    (``k_scp`` granularity) overlaps a single tile's fill with its own
    compute."""

    def _single_tile(self, k_stream):
        g, _ = build_gemm_graph(MatMulTask(m=64, n=64, k=8192), 64, 64)
        topo = ClusterTopology(n_units=1, unit=PLATFORM_2TOPS,
                               platform=SHUTTLE, loader_policy="fcfs",
                               k_stream=k_stream)
        return simulate_cluster(g, topo)

    def test_single_tile_latency_shortens(self):
        off = self._single_tile(False)
        on = self._single_tile(True)
        assert on.cycles < 0.75 * off.cycles
        # with streaming, the tile's first PE busy interval starts long
        # before its load stream completes: fill overlaps compute.
        load_end = max(e for s, e, lbl in on.intervals["mem_loader"]
                       if not lbl.endswith("/wb"))
        pe_start = min(s for s, e, _ in on.intervals["pe_array"])
        assert pe_start < 0.1 * load_end

    def test_chunked_equals_whole_tile_work(self):
        """Chunking changes the schedule, not the totals."""
        off, on = self._single_tile(False), self._single_tile(True)
        assert on.busy("pe_array") == pytest.approx(off.busy("pe_array"))
        assert on.ideal_matrix_cycles == off.ideal_matrix_cycles

    def test_gemm_utilization_improves(self):
        g, _ = build_gemm_graph(MatMulTask(m=512, n=512, k=8192), 64, 64)
        rs = [simulate_cluster(g, ClusterTopology(
            n_units=1, unit=PLATFORM_2TOPS, platform=SHUTTLE,
            loader_policy="fcfs", k_stream=ks)) for ks in (False, True)]
        assert rs[1].matrix_utilization >= rs[0].matrix_utilization
        assert rs[1].matrix_utilization > 0.99


# ---------------------------------------------------------------------------
# Trace export: one Perfetto process per unit.
# ---------------------------------------------------------------------------

class TestClusterTrace:
    def test_cluster_trace_pid_per_unit(self, tmp_path):
        r = weak_scaling_run(2)
        path = dump_chrome_trace(r, str(tmp_path / "c.json"))
        data = json.loads(open(path).read())
        events = data["traceEvents"]
        procs = {e["pid"]: e["args"]["name"] for e in events
                 if e["ph"] == "M" and e["name"] == "process_name"}
        # pid 0 = shared resources, pid i+1 = unit i
        assert set(procs) == {0, 1, 2}
        assert "unit0" in procs[1] and "unit1" in procs[2]
        threads = {(e["pid"], e["args"]["name"]) for e in events
                   if e["ph"] == "M" and e["name"] == "thread_name"}
        for pid in (1, 2):
            assert {(pid, "dispatcher"), (pid, "scratchpad"),
                    (pid, "pe_array"), (pid, "vector_unit")} <= threads
        assert (0, "mem_loader") in threads
        # a unit's X events land on that unit's pid; loader on pid 0
        pids_by_cat = {}
        for e in events:
            if e["ph"] == "X":
                pids_by_cat.setdefault(e["cat"], set()).add(e["pid"])
        assert pids_by_cat["u0/pe_array"] == {1}
        assert pids_by_cat["u1/pe_array"] == {2}
        assert pids_by_cat["mem_loader"] == {0}
        assert data["otherData"]["n_units"] == 2
        assert 0 < data["otherData"]["aggregate_matrix_utilization"] <= 1

    def test_single_unit_trace_shape_unchanged(self):
        r = simulate_graph(build_gemm_graph(
            MatMulTask(m=128, n=128, k=256), 64, 64)[0], CASE_STUDY,
            SHUTTLE)
        data = chrome_trace(r)
        events = data["traceEvents"]
        assert all(e["pid"] == 0 for e in events)
        rows = {e["args"]["name"] for e in events
                if e["ph"] == "M" and e["name"] == "thread_name"}
        assert {"dispatcher", "mem_loader", "scratchpad", "pe_array",
                "vector_unit"} <= rows


# ---------------------------------------------------------------------------
# Registry hygiene (satellite): duplicates raise, errors name the options.
# ---------------------------------------------------------------------------

class TestRegistryHygiene:
    def test_cluster_backends_registered(self):
        assert {"desim-cluster", "sharded"} <= set(backend.available())

    def test_unknown_backend_error_lists_names(self):
        with pytest.raises(KeyError) as ei:
            backend.get("verilator")
        msg = str(ei.value)
        for name in backend.available():
            assert name in msg
        assert "analytic" in msg               # aliases shown too

    def test_duplicate_registration_raises(self):
        from repro.backend.base import Backend

        with pytest.raises(ValueError, match="already registered"):
            @backend.register("jax")
            class Impostor(Backend):           # pragma: no cover
                def _stage(self, *a):
                    raise NotImplementedError

                def run_graph(self, *a):
                    raise NotImplementedError
        # the original class is untouched
        assert backend.get("jax").name == "jax"

    def test_reregistering_same_class_idempotent(self):
        cls = type(backend.get("jax"))
        assert backend.register("jax")(cls) is cls

    def test_override_replaces_and_restores(self):
        orig = type(backend.get("desim"))

        @backend.register("desim", override=True)
        class Stand_in(orig):
            pass

        try:
            assert type(backend.get("desim")) is Stand_in
        finally:
            backend.register("desim", override=True)(orig)
        assert type(backend.get("desim")) is orig

    def test_single_unit_backends_reject_units(self):
        for name in ("jax", "pallas", "desim"):
            with pytest.raises(ValueError, match="single matrix unit"):
                backend.get(name, units=4)
            assert backend.get(name, units=1) is not None
        # analytical joined the cluster-aware set in PR 4: units=N
        # switches it to the contention-aware closed form.
        assert backend.get("analytical", units=4).supports_units


# ---------------------------------------------------------------------------
# The two cluster backends behind the registry.
# ---------------------------------------------------------------------------

class TestShardedParity:
    """Acceptance: the partitioned graph executes int8 bit-exact on the
    sharded backend vs the jax backend."""

    @pytest.mark.parametrize("strategy", ["row-panel", "output-tile",
                                          "layer-pipeline"])
    @pytest.mark.parametrize("units", [2, 4])
    def test_int8_bit_exact(self, strategy, units):
        task = MatMulTask(m=128, n=192, k=256)
        a, b = int8_pair(jax.random.PRNGKey(1), 128, 192, 256)
        ops = backend.MatMulOperands(a=a, b=b)
        jx = backend.get("jax")
        ref = np.asarray(jx.wait(jx.dispatch(task, ops)).output)
        sh = backend.get("sharded", units=units, strategy=strategy)
        out = np.asarray(sh.wait(sh.dispatch(task, ops)).output)
        assert out.dtype == ref.dtype == np.int32
        assert (out == ref).all()

    def test_epilogue_graph_matches_jax_backend(self):
        ep = Epilogue(activation="silu", glu=True, out_dtype=jnp.float32)
        task = MatMulTask(m=128, n=256, k=128)
        a, b = int8_pair(jax.random.PRNGKey(4), 128, 256, 128)
        jx = backend.get("jax", granularity="panel")
        graph = jx.lower(task, epilogue=ep)
        ref = jx.run_graph(graph, backend.MatMulOperands(a=a, b=b)).output
        sh = backend.get("sharded", units=2, granularity="panel")
        out = sh.run_graph(graph, backend.MatMulOperands(a=a, b=b)).output
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)
        direct = cute_matmul(a, b, epilogue=ep, backend="xla")
        np.testing.assert_allclose(np.asarray(out), np.asarray(direct),
                                   rtol=1e-6, atol=1e-6)

    def test_requires_operands(self):
        with pytest.raises(ValueError):
            backend.get("sharded", units=2).dispatch(
                MatMulTask(m=8, n=8, k=8))

    def test_mismatched_partition_rejected(self):
        g, _ = build_gemm_graph(MatMulTask(m=128, n=64, k=64), 64, 64)
        part = partition_graph(g, 4, "row-panel")
        with pytest.raises(ValueError, match="partitioned for 4"):
            backend.get("sharded", units=2).run_graph(part)

    def test_unbalanced_spans_execute_partition_layout(self):
        """m=128 over 4 units leaves two units idle (2 panels): execution
        walks the partition's own spans — not an even 32-row split — and
        stays bit-exact."""
        from repro.distributed.sharding import shard_map_gemm
        g, _ = build_gemm_graph(MatMulTask(m=128, n=64, k=64), 64, 64)
        part = partition_graph(g, 4, "row-panel")
        spans = part.spans["gemm"]
        assert not part.balanced("gemm") and None in spans
        a, b = int8_pair(jax.random.PRNGKey(3), 128, 64, 64)
        ref = np.asarray(cute_matmul(a, b, backend="xla"))
        out = backend.get("sharded", units=4).run_graph(
            part, backend.MatMulOperands(a=a, b=b)).output
        assert (np.asarray(out) == ref).all()
        # the low-level path honours explicit spans too
        acc = shard_map_gemm(a, b, 4, dim="m", bounds=spans)
        assert (np.asarray(acc) == ref).all()


class TestClusterBackend:
    def test_capability_flags(self):
        eng = backend.get("desim-cluster", units=2)
        assert eng.models_time and eng.executes and eng.supports_units
        assert eng.units == 2

    def test_not_zoo_routable(self):
        with pytest.raises(ValueError):
            backend.set_default_matmul_backend("desim-cluster")

    def test_dispatch_wait_returns_contended_timeline(self):
        eng = backend.get("desim-cluster", units=2)
        r = eng.wait(eng.dispatch(MatMulTask(m=512, n=512, k=4096)))
        assert r.cycles > 0
        assert r.timeline.n_units == 2
        assert {"u0/pe_array", "u1/pe_array",
                "mem_loader"} <= set(r.timeline.intervals)
        assert 0 < r.utilization <= 1.0
        assert r.detail["partition"]["n_units"] == 2

    def test_two_units_roughly_halve_the_makespan(self):
        one = backend.get("desim")
        two = backend.get("desim-cluster", units=2)
        task = MatMulTask(m=512, n=512, k=4096)
        r1 = one.wait(one.dispatch(task))
        r2 = two.wait(two.dispatch(task))
        assert r2.cycles < 0.7 * r1.cycles

    def test_executes_partitioned_graph_bit_exact(self):
        task = MatMulTask(m=128, n=128, k=256)
        a, b = int8_pair(jax.random.PRNGKey(2), 128, 128, 256)
        eng = backend.get("desim-cluster", units=2)
        r = eng.wait(eng.dispatch(task, backend.MatMulOperands(a=a, b=b)))
        ref = np.asarray(cute_matmul(a, b, backend="xla"))
        assert (np.asarray(r.output) == ref).all()
        assert r.cycles > 0                    # both halves of the claim

    def test_run_workload_dict_shape(self):
        layers = [LayerTrace("l", (MatMulTask(m=128, n=256, k=512),),
                             vector_ops={"silu": 128 * 256.0}, repeat=2)]
        r = backend.get("desim-cluster", units=2).run_workload(layers)
        assert {"cycles", "matrix", "vector", "seconds", "flops",
                "matrix_utilization", "loader_utilization"} <= set(r)
        single = backend.get("desim").run_workload(layers)
        assert r["cycles"] < single["cycles"]

    def test_strategy_validated(self):
        with pytest.raises(ValueError, match="strategy"):
            backend.get("desim-cluster", units=2, strategy="diagonal")


# ---------------------------------------------------------------------------
# Serving schedules priced on the contended cluster.
# ---------------------------------------------------------------------------

class TestServingOnCluster:
    @pytest.fixture(scope="class")
    def engine(self):
        from repro.configs.registry import get_config
        from repro.serving.engine import ServingEngine
        cfg = get_config("yi-6b", reduced=True)
        eng = ServingEngine(cfg, params=None, max_batch=2, cache_len=64)
        key = jax.random.PRNGKey(0)
        for i in range(3):
            key, sub = jax.random.split(key)
            eng.submit(jax.random.randint(sub, (4 + i,), 0, 100))
        return eng

    def test_plan_records_units(self, engine):
        sched = engine.plan(max_new_tokens=4, units=4)
        assert sched.units == 4
        assert engine.plan(max_new_tokens=4).units == 1

    def test_evaluate_schedule_on_cluster(self, engine):
        # output-tile: serving GEMMs are short (few token rows) but wide
        # (hidden dim) — sharding N is what actually spreads the work.
        sched, res = engine.evaluate_schedule(
            "desim-cluster", max_new_tokens=4, units=2,
            strategy="output-tile")
        assert sched.units == 2
        assert res.timeline.n_units == 2
        assert {"u0/pe_array", "u1/pe_array"} <= set(res.timeline.intervals)
        # both units genuinely compute
        assert all(u > 0 for u in res.timeline.unit_utilizations())
        assert res.detail["workload"]["cycles"] >= res.cycles
        single, r1 = engine.evaluate_schedule("desim", max_new_tokens=4)
        assert res.detail["workload"]["cycles"] < \
            r1.detail["workload"]["cycles"]

    def test_sharded_executes_schedule_bit_exact(self, engine):
        sched = engine.plan(max_new_tokens=4, units=2)
        ops = sched.example_operands(jax.random.PRNGKey(7))
        jx = backend.get("jax")
        rj = jx.run_graph(jx.lower(sched.layers), ops)
        sh = backend.get("sharded", units=2)
        rs = sh.run_graph(sh.lower(sched.layers), ops)
        assert set(rs.outputs) == set(rj.outputs) == set(ops)
        for label in ops:
            assert (np.asarray(rs.outputs[label])
                    == np.asarray(rj.outputs[label])).all(), label


# ---------------------------------------------------------------------------
# Shared DRAM row-buffer state across units' interleaved streams.
# ---------------------------------------------------------------------------

class TestRowBufferInterleaving:
    """``ClusterTopology.row_buffer``: N shared-pool streams chop each
    other's contiguous runs (``dram_stride_efficiency``'s ``streams``
    knob).  Opt-in — the default is bit-identical to the calibrated flat
    derate — and the DES and the analytical closed form stay within 5%
    of each other with it enabled."""

    # narrow tiles cut from a wide row-major matrix on a small fixed
    # pool: short runs + loader-bound, where interleaving actually bites.
    TASK = MatMulTask(m=512, n=128, k=2048, stride_b=8192, stride_c=8192)

    def _pair(self, n, row_buffer):
        from repro.core.hardware import GIGA
        unit = PLATFORM_2TOPS
        g, _ = build_gemm_graph(self.TASK, unit.m_scp, unit.n_scp)
        part = partition_graph(g, n, "row-panel")
        topo = ClusterTopology(n_units=n, unit=unit, platform=SHUTTLE,
                               total_bandwidth=16 * GIGA,
                               row_buffer=row_buffer)
        des = simulate_cluster(part.graph, topo)
        ana = backend.get("analytical", topology=topo).run_graph(part)
        return des, ana

    def test_streams_chop_runs(self):
        from repro.sim.resources import dram_stride_efficiency
        base = SHUTTLE.dram_efficiency
        # default reproduces the single-stream curve exactly
        assert dram_stride_efficiency(256.0, base, streams=1) == \
            pytest.approx(dram_stride_efficiency(256.0, base))
        # more interleaved streams -> shorter effective runs; long runs
        # only degrade once chopped below the 64-byte reference burst
        assert dram_stride_efficiency(256.0, base, 4) == \
            pytest.approx(base)                       # 64 B each: still ok
        e1 = dram_stride_efficiency(96.0, base)
        e2 = dram_stride_efficiency(96.0, base, 2)
        e4 = dram_stride_efficiency(96.0, base, 4)
        assert e4 < e2 < e1 == pytest.approx(base)
        # N streams of run R behave like one stream of run R/N
        assert dram_stride_efficiency(128.0, base, 2) == \
            pytest.approx(dram_stride_efficiency(64.0, base))

    def test_topology_stream_count(self):
        from repro.core.hardware import GIGA
        from repro.sim import UnitSpec
        topo = ClusterTopology(n_units=4, unit=PLATFORM_2TOPS)
        assert topo.interleaved_streams() == 1       # off by default
        assert topo.with_(row_buffer=True).interleaved_streams() == 4
        # private slices never interleave on the shared pool
        het = ClusterTopology(
            unit_specs=(UnitSpec(unit=PLATFORM_2TOPS,
                                 private_bandwidth=24 * GIGA),
                        UnitSpec(unit=PLATFORM_2TOPS),
                        UnitSpec(unit=PLATFORM_2TOPS)),
            total_bandwidth=96 * GIGA, row_buffer=True)
        assert het.interleaved_streams() == 2

    def test_default_off_is_bit_identical(self):
        """row_buffer=False (the default) must not move a single cycle —
        the existing calibration pins stay valid."""
        unit = PLATFORM_2TOPS
        g, _ = build_gemm_graph(self.TASK, unit.m_scp, unit.n_scp)
        part = partition_graph(g, 2, "row-panel")
        base = ClusterTopology(n_units=2, unit=unit, platform=SHUTTLE)
        expl = base.with_(row_buffer=False)
        assert simulate_cluster(part.graph, base).cycles == \
            simulate_cluster(part.graph, expl).cycles
        # ... and a single unit never interleaves with itself
        solo = ClusterTopology(n_units=1, unit=unit, platform=SHUTTLE)
        assert simulate_cluster(g, solo.with_(row_buffer=True)).cycles \
            == simulate_cluster(g, solo).cycles

    @pytest.mark.parametrize("n", [2, 4])
    def test_interleaving_costs_visible_makespan(self, n):
        des_off, _ = self._pair(n, row_buffer=False)
        des_on, _ = self._pair(n, row_buffer=True)
        # more streams -> worse locality -> monotonically costlier
        floor = {2: 1.05, 4: 1.2}[n]
        assert des_on.cycles > floor * des_off.cycles

    @pytest.mark.parametrize("n", [2, 4])
    def test_des_vs_analytical_within_5pct(self, n):
        des, ana = self._pair(n, row_buffer=True)
        assert abs(ana.cycles / des.cycles - 1.0) <= 0.05

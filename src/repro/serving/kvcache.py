"""Paged KV-cache residency as a simulated resource.

The serving stack's decode steps used to price attention as if every
request's KV cache were free and always resident — the realism gap
ROADMAP flags for decode-heavy traffic.  This module makes residency a
first-class, *simulated* resource, in the same spirit as the DES's
``BandwidthResource`` loaders: the KV working set lives in fixed-size
**blocks** (the vLLM block-table idiom) over two tiers,

* **hot** — scratchpad-bank slots, a fixed pool of ``hot_blocks``
  physical slots the allocator hands out;
* **cold** — DRAM (an ``lru`` demotion keeps the bytes) or dropped
  (the ``recompute`` policy throws them away and re-derives on touch).

Touching a cold block owes a **refill**: ``block_bytes`` of loader
traffic for an LRU demotion, ``RECOMPUTE_REFILL_FACTOR × block_bytes``
for a dropped block (activations stream back in and the block's K/V is
re-emitted — a first-order recompute price).  The serving scheduler
threads per-request residency through ``PolicyContext`` so
``decode-priority`` can prefer hot-KV requests, stamps each step's owed
refill bytes onto the ``BatchSchedule``, and ``sim.lower`` turns them
into real ``memory`` TaskGraph nodes riding the shared loader — so the
DES and the analytical cluster form both price a visible refill cost,
while JAX execution (which skips memory nodes) stays bit-exact.

Everything here is deterministic given ``(seed, call order)``: the free
list is a seeded shuffle, recency is a ``(time, seq)`` pair with a
monotonic logical sequence as the tiebreak, and every mutation appends
to :attr:`PagedKVCache.trace` — byte-identical across runs.
"""

from __future__ import annotations

import dataclasses
import hashlib
import random
from typing import List, Optional, Tuple

#: refill multiplier for the ``recompute`` policy: a dropped block's K/V
#: must be re-derived, so the loader moves the block's activations back
#: in *and* the recomputed K/V out — priced first-order as 2x the plain
#: DRAM reload an ``lru`` demotion costs.
RECOMPUTE_REFILL_FACTOR = 2.0

#: supported eviction policies.
EVICTION_POLICIES = ("lru", "recompute")


class KVPoolExhausted(RuntimeError):
    """No evictable block: every hot slot is pinned by the operation in
    progress (one request's working set exceeds the whole hot pool)."""


def kv_bytes_per_token(cfg, dtype_bytes: float = 1.0) -> float:
    """Bytes of K+V one token occupies across all layers of ``cfg``
    (int8 cache by default): ``2 * kv_dim * n_layers * dtype_bytes``."""
    return 2.0 * cfg.kv_dim * cfg.n_layers * float(dtype_bytes)


def refill_cycles(refill_bytes: float, unit, platform,
                  units: int = 1) -> float:
    """Loader cycles a KV refill of ``refill_bytes`` occupies — the same
    price the DES charges a ``memory`` node: the shared pool's bytes per
    cycle (``units × unit.bandwidth / freq``) derated by the platform's
    DRAM efficiency.  Matches ``ClusterMachine.memory_node_bpc`` on the
    default homogeneous pool and the single-unit ``Machine`` at
    ``units=1``."""
    if refill_bytes <= 0.0:
        return 0.0
    bpc = (unit.bandwidth * max(1, units) / unit.freq_hz
           * platform.dram_efficiency)
    return float(refill_bytes) / bpc


@dataclasses.dataclass
class Block:
    """One logical KV block of a request's sequence."""

    rid: int                    # owning request
    tokens: int                 # tokens written (<= block_tokens)
    hot: bool = True            # True: scratchpad slot; False: cold
    dropped: bool = False       # recompute policy threw the bytes away
    slot: Optional[int] = None  # physical hot slot id (None when cold)
    last_used: Tuple[float, int] = (0.0, 0)


class PagedKVCache:
    """Fixed-size paged KV block allocator over hot/cold tiers.

    ``hot_blocks`` physical scratchpad slots are shared by every
    request; ``block_tokens`` tokens fit one block and one block holds
    ``block_tokens × kv_bytes_per_token`` bytes.  ``policy`` picks what
    eviction does with the bytes (``lru`` demotes to DRAM, ``recompute``
    drops), ``seed`` fixes the free-list order.  All mutating calls
    take the simulation time ``t`` (cycles) for LRU recency and event
    stamping; ties break on a monotonic internal sequence, so behaviour
    is a pure function of ``(seed, call order)``.
    """

    def __init__(self, *, hot_blocks: int, block_tokens: int = 16,
                 kv_bytes_per_token: float = 1.0, policy: str = "lru",
                 seed: int = 0):
        if policy not in EVICTION_POLICIES:
            raise ValueError(f"unknown eviction policy {policy!r}; "
                             f"choose from {EVICTION_POLICIES}")
        if hot_blocks < 1:
            raise ValueError(f"hot_blocks must be >= 1, got {hot_blocks}")
        if block_tokens < 1:
            raise ValueError(f"block_tokens must be >= 1, "
                             f"got {block_tokens}")
        self.hot_blocks = int(hot_blocks)
        self.block_tokens = int(block_tokens)
        self.kv_bytes_per_token = float(kv_bytes_per_token)
        self.block_bytes = self.block_tokens * self.kv_bytes_per_token
        self.policy = policy
        self.seed = int(seed)
        slots = list(range(self.hot_blocks))
        random.Random(self.seed).shuffle(slots)
        self._free: List[int] = slots        # pop from the end
        self._seqs: "dict[int, list[Block]]" = {}
        self._seq = 0
        #: append-only event log — ``(kind, time, rid, slot, extra)``
        #: tuples, byte-identical across runs given (seed, call order).
        self.trace: "list[tuple]" = []
        self.counters = {"allocs": 0, "evictions": 0, "refills": 0,
                         "refill_bytes": 0.0, "frees": 0}

    # ----- introspection ---------------------------------------------------
    def free_slots(self) -> Tuple[int, ...]:
        """Currently free hot slot ids, sorted."""
        return tuple(sorted(self._free))

    def allocated_slots(self) -> Tuple[int, ...]:
        """Hot slot ids currently owned by some block, sorted."""
        return tuple(sorted(b.slot for bs in self._seqs.values()
                            for b in bs if b.hot))

    def blocks_of(self, rid: int) -> Tuple[Block, ...]:
        return tuple(self._seqs.get(rid, ()))

    def tokens_of(self, rid: int) -> int:
        return sum(b.tokens for b in self._seqs.get(rid, ()))

    def residency(self, rid: int) -> float:
        """Hot fraction of ``rid``'s blocks — 1.0 for an empty (or
        unknown) request: nothing cached means nothing to refill."""
        blocks = self._seqs.get(rid, ())
        if not blocks:
            return 1.0
        return sum(1 for b in blocks if b.hot) / len(blocks)

    def refill_bytes(self, rid: int) -> float:
        """Loader bytes owed before ``rid`` can decode: cold blocks at
        ``block_bytes``, dropped blocks at the recompute factor."""
        total = 0.0
        for b in self._seqs.get(rid, ()):
            if not b.hot:
                total += self.block_bytes * (RECOMPUTE_REFILL_FACTOR
                                             if b.dropped else 1.0)
        return total

    def trace_digest(self) -> str:
        """SHA-256 over the repr of the event log — the determinism
        contract: same seed + same call order -> same digest."""
        return hashlib.sha256(repr(self.trace).encode()).hexdigest()

    # ----- mutation --------------------------------------------------------
    def _key(self, t: float) -> Tuple[float, int]:
        self._seq += 1
        return (float(t), self._seq)

    def _evict_one(self, t: float, pinned: "set[int]"):
        """Evict the least-recently-used unpinned hot block; returns
        ``(freed slot, (victim rid, slot, tier))``."""
        victims = [b for bs in self._seqs.values() for b in bs
                   if b.hot and b.slot not in pinned]
        if not victims:
            raise KVPoolExhausted(
                f"all {self.hot_blocks} hot blocks are pinned by the "
                f"operation in progress; the hot pool is smaller than "
                f"one request's working set")
        victim = min(victims, key=lambda b: b.last_used)
        slot, tier = victim.slot, \
            ("dropped" if self.policy == "recompute" else "dram")
        victim.hot = False
        victim.dropped = self.policy == "recompute"
        victim.slot = None
        self.counters["evictions"] += 1
        self.trace.append(("evict", float(t), victim.rid, slot, tier))
        return slot, (victim.rid, slot, tier)

    def _alloc_slot(self, rid: int, t: float, pinned: "set[int]"):
        if self._free:
            return self._free.pop(), None
        return self._evict_one(t, pinned)

    def append(self, rid: int, n_tokens: int, t: float = 0.0):
        """Write ``n_tokens`` of fresh KV for ``rid`` (a prefill chunk
        or decode iterations), allocating hot blocks as needed.  Returns
        the list of ``(victim rid, slot, tier)`` evictions this caused.
        Blocks allocated by this call are pinned against self-eviction.
        """
        if n_tokens <= 0:
            return []
        blocks = self._seqs.setdefault(rid, [])
        key = self._key(t)
        evicted = []
        pinned: "set[int]" = {b.slot for b in blocks if b.hot}
        left = int(n_tokens)
        if blocks and blocks[-1].hot \
                and blocks[-1].tokens < self.block_tokens:
            take = min(left, self.block_tokens - blocks[-1].tokens)
            blocks[-1].tokens += take
            left -= take
        while left > 0:
            slot, ev = self._alloc_slot(rid, t, pinned)
            if ev is not None:
                evicted.append(ev)
            take = min(left, self.block_tokens)
            blocks.append(Block(rid=rid, tokens=take, hot=True,
                                slot=slot, last_used=key))
            pinned.add(slot)
            left -= take
            self.counters["allocs"] += 1
            self.trace.append(("alloc", float(t), rid, slot, take))
        for b in blocks:            # the whole sequence was just touched
            if b.hot:
                b.last_used = key
        return evicted

    def ensure_resident(self, rid: int, t: float = 0.0):
        """Bring every cold block of ``rid`` back hot, evicting LRU
        victims from *other* requests as needed.  Returns ``(refill
        bytes charged, evictions caused)`` — the bytes are what the
        scheduler lowers into a ``memory`` node."""
        blocks = self._seqs.get(rid, ())
        key = self._key(t)
        total, evicted = 0.0, []
        pinned: "set[int]" = {b.slot for b in blocks if b.hot}
        for b in blocks:
            if b.hot:
                b.last_used = key
                continue
            slot, ev = self._alloc_slot(rid, t, pinned)
            if ev is not None:
                evicted.append(ev)
            cost = self.block_bytes * (RECOMPUTE_REFILL_FACTOR
                                       if b.dropped else 1.0)
            b.hot, b.dropped, b.slot, b.last_used = True, False, slot, key
            pinned.add(slot)
            total += cost
            self.counters["refills"] += 1
            self.counters["refill_bytes"] += cost
            self.trace.append(("refill", float(t), rid, slot, cost))
        return total, evicted

    def release(self, rid: int, t: float = 0.0) -> int:
        """Free every block of a finished request; returns how many hot
        slots went back to the pool."""
        blocks = self._seqs.pop(rid, ())
        freed = 0
        for b in blocks:
            if b.hot:
                self._free.append(b.slot)
                freed += 1
                self.counters["frees"] += 1
                self.trace.append(("free", float(t), rid, b.slot, b.tokens))
        return freed

"""Int8 error-feedback gradient compression (distributed-optimization).

Before the data-parallel all-reduce, gradients are quantized to int8 with
a per-tensor scale; the quantization error is kept in a local residual
buffer and added back next step (error feedback — 1-bit-Adam lineage).
Collective volume drops 4× (fp32) / 2× (bf16); convergence is preserved
by the residual (property-tested: compressed SGD tracks exact SGD).

This wraps the *gradient tree*, not the collective itself: under GSPMD
the psum happens inside jit, so we quantize-dequantize around it; under
shard_map the int8 tensors can be psummed directly (``psum_compressed``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant(x):
    absmax = jnp.max(jnp.abs(x))
    scale = jnp.where(absmax == 0, 1.0, absmax / 127.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_tree(grads, residual):
    """Returns (q_tree, scale_tree, new_residual)."""
    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, scale = _quant(x)
        deq = q.astype(jnp.float32) * scale
        return q, scale, x - deq
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    unf = lambda i: jax.tree.unflatten(treedef, [o[i] for o in out])
    return unf(0), unf(1), unf(2)


def decompress_tree(q_tree, scale_tree):
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s,
                        q_tree, scale_tree)


def compressed_gradients(grads, residual):
    """Quantize→dequantize with error feedback (GSPMD-psum friendly)."""
    q, s, new_res = compress_tree(grads, residual)
    return decompress_tree(q, s), new_res


def psum_compressed(grads, residual, axis_name: str):
    """shard_map path: all-reduce int8 payloads + scales explicitly."""
    q, s, new_res = compress_tree(grads, residual)
    summed = jax.tree.map(
        lambda qq: jax.lax.psum(qq.astype(jnp.int32), axis_name), q)
    n = jax.lax.psum(1, axis_name)
    avg = jax.tree.map(lambda acc, ss: acc.astype(jnp.float32) * ss / n,
                       summed, s)
    return avg, new_res

"""Roofline report generator: reads the dry-run artifacts and renders the
per-(arch × shape × mesh) three-term table for EXPERIMENTS.md §Roofline.

    PYTHONPATH=src python -m benchmarks.roofline [--mesh single|multi|both]
"""

from __future__ import annotations

import argparse
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")

_SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_rows(tag: str = "") -> "list[dict]":
    rows = []
    for mesh in ("single", "multi"):
        d = os.path.join(RESULTS, mesh + tag)
        if not os.path.isdir(d):
            continue
        for fn in sorted(os.listdir(d)):
            with open(os.path.join(d, fn)) as f:
                r = json.load(f)
            roof = r["roofline"]
            total = (roof["compute_s"] + roof["memory_s"]
                     + roof["collective_s"]) or 1e-30
            rows.append({
                "arch": r["arch"], "shape": r["shape"], "mesh": mesh,
                "mode": r["mode"], "chips": r["chips"],
                "compute_s": roof["compute_s"], "memory_s": roof["memory_s"],
                "collective_s": roof["collective_s"],
                "dominant": roof["dominant"],
                "frac": roof["roofline_fraction"],
                "useful": roof["useful_flops_ratio"],
                "coll_share": roof["collective_s"] / max(
                    roof["compute_s"], roof["memory_s"],
                    roof["collective_s"], 1e-30),
                "temp_gb": (r["memory"]["temp_bytes"] or 0) / 2**30,
                "hbm_ok": ((r["memory"]["temp_bytes"] or 0)
                           + (r["memory"]["argument_bytes"] or 0)) / 2**30
                          < 16.0,
            })
    rows.sort(key=lambda r: (r["mesh"], r["arch"],
                             _SHAPE_ORDER.index(r["shape"])))
    return rows


def render_markdown(rows, mesh="single"):
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | MODEL/HLO flops | roofline frac | temp GiB |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"{r['dominant']} | {r['useful']:.2f} | {r['frac']:.3f} | "
            f"{r['temp_gb']:.1f} |")
    return "\n".join(out)


def summarize(print_table: bool = True, tag: str = ""):
    rows = load_rows(tag)
    if print_table and rows:
        for mesh in ("single", "multi"):
            if any(r["mesh"] == mesh for r in rows):
                print(f"\n== {mesh}-pod mesh ==")
                print(render_markdown(rows, mesh))
    return rows


def pick_hillclimb_cells(rows):
    """Assignment rule: worst roofline fraction, most collective-bound,
    most representative of the paper's technique (GEMM-dominated train).

    Decode cells are excluded from the "worst fraction" pick: their
    fraction is bounded by decode arithmetic intensity (tokens/chip), not
    by the implementation — see EXPERIMENTS.md §3.
    """
    single = [r for r in rows if r["mesh"] == "single"]
    improvable = [r for r in single if r["mode"] != "decode"]
    worst = min(improvable, key=lambda r: r["frac"] if r["frac"] > 0 else 1e9)
    coll = max(single, key=lambda r: r["coll_share"])
    train = [r for r in single if r["mode"] == "train"]
    rep = max(train, key=lambda r: r["compute_s"])
    return {"worst_fraction": worst, "most_collective": coll,
            "paper_representative": rep}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    rows = summarize(tag=args.tag)
    if rows:
        picks = pick_hillclimb_cells(rows)
        print("\n== hillclimb picks ==")
        for why, r in picks.items():
            print(f"{why}: {r['arch']} x {r['shape']} "
                  f"(frac={r['frac']:.3f}, dominant={r['dominant']}, "
                  f"coll_share={r['coll_share']:.2f})")


if __name__ == "__main__":
    main()

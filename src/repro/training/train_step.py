"""Training step factory: microbatched, remat'd, compression-optional.

``make_train_step(cfg, tcfg)`` builds a pure (params, opt_state, batch,
residual) → (params, opt_state, metrics, residual) function suitable for
``jax.jit`` with donated buffers.  Gradient accumulation scans over
microbatches (sliced along the batch axis) so the activation working set
is 1/N of the global batch — the memory-term lever of §Perf.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.base import ArchConfig, family_module
from repro.optim import adamw, compression
from repro.training import loss as loss_lib


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: adamw.AdamWConfig = adamw.AdamWConfig()
    microbatches: int = 1
    z_loss: float = 1e-4
    loss_chunk: int = 512
    grad_compression: bool = False
    ce_onehot_pick: bool = False     # vocab-sharded CE without the gather


def _loss_fn(cfg: ArchConfig, tcfg: TrainConfig, params, batch):
    mod = family_module(cfg)
    labels = loss_lib.shift_labels(cfg, batch["tokens"], batch["labels"])
    hidden = mod.forward(cfg, params, batch, return_hidden=True)
    loss, metrics = loss_lib.chunked_softmax_xent(
        cfg, params, hidden, labels, chunk=tcfg.loss_chunk,
        z_loss=tcfg.z_loss, onehot_pick=tcfg.ce_onehot_pick)
    return loss, metrics


def _split_microbatch(batch, n: int, i):
    def slice_one(x):
        mb = x.shape[0] // n
        return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)
    return jax.tree.map(slice_one, batch)


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig = TrainConfig()):
    grad_fn = jax.value_and_grad(
        functools.partial(_loss_fn, cfg, tcfg), has_aux=True)

    def train_step(params, opt_state, batch, residual=None):
        n = tcfg.microbatches
        if n == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def body(carry, i):
                acc, loss_acc = carry
                mb = _split_microbatch(batch, n, i)
                (l, _), g = grad_fn(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, loss_acc + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(
                body, (zeros, jnp.float32(0)), jnp.arange(n))
            grads = jax.tree.map(lambda g: g / n, grads)
            loss = loss_sum / n
            metrics = {}

        if tcfg.grad_compression and residual is not None:
            grads, residual = compression.compressed_gradients(grads,
                                                               residual)
        params, opt_state, opt_metrics = adamw.update(
            tcfg.optimizer, grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return params, opt_state, metrics, residual

    return train_step


def abstract_state(cfg: ArchConfig, tcfg: TrainConfig, key=None):
    """(abstract params, abstract opt_state) via eval_shape — no alloc."""
    mod = family_module(cfg)
    key = key if key is not None else jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda k: mod.init(cfg, k), key)
    opt_state = jax.eval_shape(
        lambda p: adamw.init(tcfg.optimizer, p), params)
    return params, opt_state

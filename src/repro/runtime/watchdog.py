"""Straggler / hang mitigation for the training loop.

On a real multi-pod deployment every host runs this around its step
function; the controller aggregates.  Mechanisms:

* **EMA step-time outlier detection** — a step slower than
  ``threshold ×`` the EMA flags a straggler event (logged + counted;
  deployment hooks decide whether to evict/replace the host).
* **hang watchdog** — a monitor thread fires a callback if no step
  completes within ``hang_timeout`` seconds (e.g. a stuck collective),
  so the launcher can checkpoint-and-restart instead of burning the
  reservation.
* **preemption** — SIGTERM sets a flag the loop polls to trigger a final
  synchronous checkpoint before the machine disappears.
"""

from __future__ import annotations

import signal
import threading
import time
from typing import Callable, Optional


class StepWatchdog:
    def __init__(self, ema_alpha: float = 0.1, threshold: float = 2.5,
                 hang_timeout: float = 0.0,
                 on_hang: Optional[Callable[[], None]] = None):
        self.ema_alpha = ema_alpha
        self.threshold = threshold
        self.hang_timeout = hang_timeout
        self.on_hang = on_hang
        self.ema: Optional[float] = None
        self.straggler_events = 0
        self.steps = 0
        self._last_beat = time.monotonic()
        self._stop = threading.Event()
        self._monitor: Optional[threading.Thread] = None
        if hang_timeout > 0:
            self._monitor = threading.Thread(target=self._watch, daemon=True)
            self._monitor.start()

    def record_step(self, seconds: float) -> bool:
        """Returns True if this step was a straggler."""
        self.steps += 1
        self._last_beat = time.monotonic()
        straggler = False
        if self.ema is not None and seconds > self.threshold * self.ema:
            self.straggler_events += 1
            straggler = True
        if self.ema is None:
            self.ema = seconds
        else:
            # Clamp outliers so one straggler doesn't poison the baseline.
            s = min(seconds, 4.0 * self.ema)
            self.ema = (1 - self.ema_alpha) * self.ema + self.ema_alpha * s
        return straggler

    def _watch(self):
        while not self._stop.wait(min(self.hang_timeout / 4, 5.0)):
            if time.monotonic() - self._last_beat > self.hang_timeout:
                if self.on_hang:
                    self.on_hang()
                self._last_beat = time.monotonic()

    def close(self):
        self._stop.set()


class PreemptionHandler:
    """SIGTERM/SIGINT → ``requested`` flag the train loop polls."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self.requested = False
        self._prev = {}
        for sig in signals:
            self._prev[sig] = signal.signal(sig, self._handle)

    def _handle(self, signum, frame):
        self.requested = True

    def restore(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)

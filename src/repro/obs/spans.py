"""Per-request lifecycle spans over a priced :class:`BatchSchedule`.

A serving request's journey is ``arrival → admission →
prefill(.chunk_j) → decode_iter_k → complete``.  The schedule knows the
*structure* (which steps touch which request ids, how many decode
iterations each step carries); a priced timeline knows the *times*
(per-step ``(start, end)`` cycles — either the DES/closed-form
``detail["step_spans"]`` keyed by step label, or
``serving.scheduler.schedule_timeline``'s list).  :class:`SpanLog`
joins the two into one span list per request:

* ``arrival`` — a point span at the request's arrival cycle;
* ``admission`` — arrival to the start of the first step carrying the
  request (the queueing delay a batching policy controls);
* ``prefill`` / ``prefill.chunk<j>`` — the request's prefill steps, one
  span each (chunked policies produce one per chunk);
* ``decode_iter<k>`` — each decode iteration, sub-divided uniformly
  across its step's span exactly the way ``decode_latency_stats``
  places tokens (a step covering ``repeat / n_layers`` iterations
  emits them evenly);
* ``complete`` — a point span when the request's last step ends.

:meth:`SpanLog.validate` checks every request for a complete, monotonic
chain — the round-trip property the serving tests pin.  The same
request-id ↔ step mapping drives the Perfetto flow events
``sim.trace.chrome_trace(schedule=...)`` stitches across units.
"""

from __future__ import annotations

import dataclasses

#: start-ordering slack (cycles) — float noise, not real overlap.
_EPS = 1e-6


@dataclasses.dataclass(frozen=True)
class Span:
    """One lifecycle interval of one request, in simulated cycles."""

    request: int
    phase: str            # arrival | admission | prefill[.chunk<j>]
    #                     # | decode_iter<k> | complete
    start: float
    end: float
    step: int = -1        # schedule step index (-1: synthetic span)
    label: str = ""       # step layer name ("" : synthetic span)
    kind: str = ""        # step kind ("" : synthetic span)

    def to_json(self) -> dict:
        d = {"request": self.request, "phase": self.phase,
             "start": self.start, "end": self.end}
        if self.step >= 0:
            d.update(step=self.step, label=self.label, kind=self.kind)
        return d


def _decode_requests(step) -> "tuple[int, ...]":
    """Requests receiving a decode token from ``step`` — the same
    fallback ``decode_latency_stats`` applies (classic full-prefill pure
    decode steps leave ``decode_requests`` empty but mean everyone)."""
    return step.decode_requests or (
        step.requests if step.kind == "decode" else ())


def _step_windows(sched, step_spans) -> "list[tuple[float, float]]":
    """Normalise either timeline currency into per-step ``(start, end)``:
    a dict keyed by step label (``detail["step_spans"]``) or a list
    aligned with ``sched.steps`` (``schedule_timeline``)."""
    if isinstance(step_spans, dict):
        missing = [lt.name for lt in sched.layers
                   if lt.name not in step_spans]
        if missing:
            raise KeyError(f"step_spans missing steps {missing[:4]} "
                           f"(of {len(sched.steps)})")
        return [tuple(step_spans[lt.name]) for lt in sched.layers]
    spans = list(step_spans)
    if len(spans) != len(sched.steps):
        raise ValueError(f"{len(spans)} step spans for "
                         f"{len(sched.steps)} steps")
    return [tuple(s) for s in spans]


class SpanLog:
    """The lifecycle spans of every request of one priced schedule."""

    def __init__(self, spans: "list[Span]", n_requests: int = 0):
        self.spans = list(spans)
        self.n_requests = n_requests or (
            1 + max((s.request for s in self.spans), default=-1))

    # ----- construction ----------------------------------------------------
    @classmethod
    def from_schedule(cls, sched, step_spans, n_layers: int) -> "SpanLog":
        """Join a :class:`~repro.serving.engine.BatchSchedule` with its
        priced per-step windows (dict by label or list by index) into
        per-request lifecycle spans.  ``n_layers`` converts a decode
        step's ``repeat`` into its iteration count, matching
        ``decode_latency_stats``."""
        windows = _step_windows(sched, step_spans)
        requests = sorted({r for s in sched.steps for r in s.requests})
        prefill_count = {r: sum(
            1 for s in sched.steps
            if r in s.requests and r not in _decode_requests(s))
            for r in requests}
        spans: "list[Span]" = []
        chunk_idx = {r: 0 for r in requests}
        decode_idx = {r: 0 for r in requests}
        first_start: "dict[int, float]" = {}
        last_end: "dict[int, float]" = {}
        for j, (step, lt, (start, end)) in enumerate(
                zip(sched.steps, sched.layers, windows)):
            dr = set(_decode_requests(step))
            iters = max(1, round(step.repeat / n_layers))
            for r in step.requests:
                first_start.setdefault(r, start)
                last_end[r] = max(last_end.get(r, end), end)
                if r in dr:
                    for k in range(iters):
                        s = start + (end - start) * k / iters
                        e = start + (end - start) * (k + 1) / iters
                        spans.append(Span(
                            r, f"decode_iter{decode_idx[r]}", s, e,
                            step=j, label=lt.name, kind=step.kind))
                        decode_idx[r] += 1
                else:
                    phase = ("prefill" if prefill_count[r] <= 1
                             else f"prefill.chunk{chunk_idx[r]}")
                    chunk_idx[r] += 1
                    spans.append(Span(r, phase, start, end, step=j,
                                      label=lt.name, kind=step.kind))
        for r in requests:
            arr = sched.arrival_of(r)
            spans.append(Span(r, "arrival", arr, arr))
            spans.append(Span(r, "admission", arr, first_start[r]))
            spans.append(Span(r, "complete", last_end[r], last_end[r]))
        spans.sort(key=lambda s: (s.request, s.start, s.end, s.step))
        return cls(spans, n_requests=len(requests))

    @classmethod
    def from_timeline(cls, sched, step_cycles: "list[float]",
                      n_layers: int) -> "SpanLog":
        """Build from per-step prices via the first-order
        ``schedule_timeline`` placement (no DES run needed)."""
        from repro.serving.scheduler import schedule_timeline
        return cls.from_schedule(sched, schedule_timeline(sched, step_cycles),
                                 n_layers)

    # ----- queries ---------------------------------------------------------
    def requests(self) -> "tuple[int, ...]":
        return tuple(sorted({s.request for s in self.spans}))

    def for_request(self, request: int) -> "list[Span]":
        return [s for s in self.spans if s.request == request]

    def phase(self, request: int, phase: str) -> Span:
        for s in self.for_request(request):
            if s.phase == phase:
                return s
        raise KeyError(f"request {request} has no {phase!r} span")

    def ttft(self, request: int) -> float:
        """Arrival to end of the first decode iteration — the span-log
        view of the TTFT ``decode_latency_stats`` reports."""
        return (self.phase(request, "decode_iter0").end
                - self.phase(request, "arrival").start)

    def to_json(self) -> "list[dict]":
        return [s.to_json() for s in self.spans]

    # ----- the round-trip property -----------------------------------------
    def validate(self) -> "list[str]":
        """Every request must carry a *complete, monotonic* chain:
        arrival and admission first, at least one work span, complete
        last, successive spans never starting before their predecessor
        (within float slack) and every span non-negative.  Returns the
        list of violations (empty == healthy)."""
        errors: "list[str]" = []
        for r in self.requests():
            chain = self.for_request(r)
            phases = [s.phase for s in chain]
            for needed in ("arrival", "admission", "complete"):
                if needed not in phases:
                    errors.append(f"request {r}: missing {needed!r} span")
            if not any(p.startswith(("prefill", "decode")) for p in phases):
                errors.append(f"request {r}: no prefill/decode work span")
            if phases and phases[-1] != "complete":
                errors.append(f"request {r}: chain ends with "
                              f"{phases[-1]!r}, not 'complete'")
            prev = None
            for s in chain:
                if s.end < s.start - _EPS:
                    errors.append(f"request {r}: span {s.phase} ends "
                                  f"before it starts ({s.end} < {s.start})")
                if prev is not None and s.start < prev.start - _EPS:
                    errors.append(
                        f"request {r}: span {s.phase} starts at {s.start} "
                        f"before {prev.phase} at {prev.start}")
                prev = s
        return errors

    def complete(self) -> bool:
        """True when every request's chain validates clean."""
        return not self.validate()

    def __len__(self) -> int:
        return len(self.spans)

    def __iter__(self):
        return iter(self.spans)

    def __repr__(self) -> str:
        return (f"SpanLog({len(self.spans)} spans, "
                f"{self.n_requests} requests)")


class SpanAssembler:
    """Builds one global :class:`SpanLog` across *admission epochs*.

    The online loop (:mod:`repro.serving.online`) executes one committed
    sub-schedule per epoch; each epoch's DES/closed-form
    ``detail["step_spans"]`` is epoch-relative and keyed by *local*
    request ids.  The assembler joins them into the same per-request
    lifecycle chain :meth:`SpanLog.from_schedule` produces offline:
    per-epoch work spans are shifted onto the global clock (``offset``)
    and remapped to global ids (``id_map``), decode-iteration and
    prefill-chunk counters persist across epochs (a preempted stream
    resumed three epochs later continues at ``decode_iter<k>``, not
    ``decode_iter0``), and :meth:`finalize` closes every chain with the
    synthetic ``arrival`` / ``admission`` / ``complete`` spans — so
    :meth:`SpanLog.validate` holds across preemption and eviction
    (pinned by ``tests/test_online.py``).

    Point *marker* spans (:meth:`mark` — ``preempted`` / ``evicted`` /
    ``resumed``) ride in the same chain; ``validate`` ignores unknown
    phases as long as the chain stays monotonic.
    """

    def __init__(self, n_layers: int):
        self.n_layers = n_layers
        self._decode_idx: "dict[int, int]" = {}
        self._decode_spans: "list[Span]" = []
        # prefill work per request, phase assigned at finalize (one
        # chunk -> "prefill", several -> "prefill.chunk<j>" in order —
        # the offline labels exactly).
        self._prefill: "dict[int, list[tuple]]" = {}
        self._marks: "list[Span]" = []
        self._arrival: "dict[int, float]" = {}
        self._first_start: "dict[int, float]" = {}
        self._last_end: "dict[int, float]" = {}
        self._step_base = 0

    def observe_arrival(self, request: int, time: float) -> None:
        """Record a request's (global) arrival cycle."""
        self._arrival[request] = float(time)

    def mark(self, request: int, phase: str, time: float) -> None:
        """Append a point marker span (``preempted`` / ``evicted`` /
        ``resumed``) to a request's chain at a global cycle."""
        self._marks.append(Span(request, phase, float(time), float(time)))

    def add_epoch(self, sched, step_spans, *, offset: float = 0.0,
                  id_map: "Optional[dict[int, int]]" = None) -> None:
        """Fold one committed epoch's priced windows into the log.

        ``sched`` / ``step_spans`` use the epoch's *local* request ids
        and epoch-relative cycles; ``id_map`` translates local → global
        ids (identity when omitted) and ``offset`` is the epoch's start
        on the global clock."""
        windows = _step_windows(sched, step_spans)
        for j, (step, lt, (s0, e0)) in enumerate(
                zip(sched.steps, sched.layers, windows)):
            start, end = s0 + offset, e0 + offset
            dr = set(_decode_requests(step))
            iters = max(1, round(step.repeat / self.n_layers))
            gj = self._step_base + j
            for r in step.requests:
                g = id_map[r] if id_map is not None else r
                self._first_start.setdefault(g, start)
                self._last_end[g] = max(self._last_end.get(g, end), end)
                if r in dr:
                    k0 = self._decode_idx.get(g, 0)
                    for k in range(iters):
                        s = start + (end - start) * k / iters
                        e = start + (end - start) * (k + 1) / iters
                        self._decode_spans.append(Span(
                            g, f"decode_iter{k0 + k}", s, e,
                            step=gj, label=lt.name, kind=step.kind))
                    self._decode_idx[g] = k0 + iters
                else:
                    self._prefill.setdefault(g, []).append(
                        (start, end, gj, lt.name, step.kind))
        self._step_base += len(sched.steps)

    def finalize(self) -> SpanLog:
        """Close every chain and return the global :class:`SpanLog`."""
        spans: "list[Span]" = list(self._decode_spans)
        for g, chunks in self._prefill.items():
            one = len(chunks) == 1
            for j, (s, e, gj, label, kind) in enumerate(chunks):
                phase = "prefill" if one else f"prefill.chunk{j}"
                spans.append(Span(g, phase, s, e, step=gj,
                                  label=label, kind=kind))
        spans.extend(self._marks)
        requests = sorted(self._first_start)
        for g in requests:
            arr = self._arrival.get(g, 0.0)
            spans.append(Span(g, "arrival", arr, arr))
            spans.append(Span(g, "admission", arr, self._first_start[g]))
            spans.append(Span(g, "complete", self._last_end[g],
                              self._last_end[g]))
        spans.sort(key=lambda s: (s.request, s.start, s.end, s.step))
        return SpanLog(spans, n_requests=len(requests))

"""Generic decoder-only transformer family.

One implementation, flag-driven, covers five assigned architectures:
  * gemma2-2b / gemma2-27b — sandwich norms, GeGLU, logit soft-caps,
    alternating local(4096)/global attention, tied + scaled embeddings;
  * deepseek-67b / yi-6b — llama arch (pre-RMSNorm, SwiGLU, RoPE GQA);
  * internvl2-1b — Qwen2 backbone (QKV bias) + stub ViT prefix tokens;
  * olmoe-1b-7b — QK-norm + 64-expert top-8 MoE;
  * arctic-480b — 128-expert top-2 MoE + parallel dense residual MLP.

Layers are stacked and scanned (``lax.scan`` over layer parameters) so
HLO size is depth-independent; gemma2's alternating pattern scans
(local, global) *pairs*.  Activation remat wraps the scan body.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.logical import constrain
from repro.models import common as cm
from repro.models.base import ArchConfig, register_family
from repro.models import moe as moe_lib


# ---------------------------------------------------------------------------
# One block.
# ---------------------------------------------------------------------------

def block_init(cfg: ArchConfig, key):
    ks = jax.random.split(key, 4)
    p = {
        "attn": cm.attn_init(cfg, ks[0]),
        "ln_attn": jnp.zeros((cfg.d_model,), cfg.dtype),
        "ln_mlp": jnp.zeros((cfg.d_model,), cfg.dtype),
    }
    if not cfg.rmsnorm_unit_offset:
        p["ln_attn"] = jnp.ones((cfg.d_model,), cfg.dtype)
        p["ln_mlp"] = jnp.ones((cfg.d_model,), cfg.dtype)
    if cfg.sandwich_norms:
        zero = jnp.zeros if cfg.rmsnorm_unit_offset else jnp.ones
        p["ln_attn_post"] = zero((cfg.d_model,), cfg.dtype)
        p["ln_mlp_post"] = zero((cfg.d_model,), cfg.dtype)
    if cfg.moe is not None:
        p["moe"] = moe_lib.moe_init(cfg, ks[1])
    else:
        p["mlp"] = cm.mlp_init(cfg, ks[1])
    return p


def _norm(cfg, x, w):
    return cm.rmsnorm(x, w, cfg.rms_eps, cfg.rmsnorm_unit_offset)


def block_apply(cfg: ArchConfig, p, x, *, positions, window: int,
                kv_cache=None, cache_pos=None):
    """x: (B, S, d).  Returns (x, new_kv) — new_kv None outside decode."""
    h = _norm(cfg, x, p["ln_attn"])
    q, k, v = cm.qkv_project(cfg, p["attn"], h, positions)

    new_kv = None
    if kv_cache is not None:
        k_cache, v_cache = kv_cache
        k_cache, v_cache = cm.cache_update(k_cache, v_cache, k, v, cache_pos)
        new_kv = (k_cache, v_cache)
        if q.shape[2] == 1:                      # decode: one new token
            from repro.kernels.attention.ops import decode_attention
            ctx = decode_attention(
                q, k_cache, v_cache, cache_pos + 1,
                sm_scale=cfg.sm_scale, window=window,
                softcap=cfg.attn_softcap)
        else:                                    # prefill writes + attends
            ctx = cm.attention(cfg, q, k, v, causal=True, window=window)
    else:
        ctx = cm.attention(cfg, q, k, v, causal=True, window=window)

    attn_out = cm.attn_out(cfg, p["attn"], ctx)
    if cfg.sandwich_norms:
        attn_out = _norm(cfg, attn_out, p["ln_attn_post"])
    x = x + attn_out
    x = constrain(x, ("batch", "seq", "embed"))

    h = _norm(cfg, x, p["ln_mlp"])
    if cfg.moe is not None:
        mlp_out = moe_lib.moe_apply(cfg, p["moe"], h)
    else:
        mlp_out = cm.mlp_apply(cfg, p["mlp"], h)
    if cfg.sandwich_norms:
        mlp_out = _norm(cfg, mlp_out, p["ln_mlp_post"])
    x = x + mlp_out
    return constrain(x, ("batch", "seq", "embed")), new_kv


# ---------------------------------------------------------------------------
# Layer stacking: uniform scan or gemma2 (local, global) pairs.
# ---------------------------------------------------------------------------

def _stack_init(cfg: ArchConfig, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: block_init(cfg, k))(keys)


def _windows(cfg: ArchConfig):
    if cfg.layer_pattern == "gemma2_alt":
        return (cfg.window, 0)                   # local then global
    return (cfg.window,)


def init(cfg: ArchConfig, key):
    ks = jax.random.split(key, 4)
    v = cfg.padded_vocab
    params = {
        "embedding": cm.embed_init(ks[0], (v, cfg.d_model), cfg.dtype),
        "ln_final": (jnp.zeros if cfg.rmsnorm_unit_offset else jnp.ones)(
            (cfg.d_model,), cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = cm.dense_init(ks[1], (cfg.d_model, v), cfg.dtype)
    wins = _windows(cfg)
    group = len(wins)
    assert cfg.n_layers % group == 0, (cfg.n_layers, group)
    layer_keys = jax.random.split(ks[2], group)
    params["layers"] = tuple(
        _stack_init(cfg, layer_keys[i], cfg.n_layers // group)
        for i in range(group))
    return params


def _scan_blocks(cfg: ArchConfig, params, x, *, positions, caches=None,
                 cache_pos=None):
    """One scan over layer *groups*; each step applies the whole group in
    order (so gemma2's (local, global) pairs stay interleaved).  KV caches
    are threaded through the scan as per-group ys."""
    wins = _windows(cfg)
    policy = cm.remat_policy(cfg)

    def body(carry, layer):
        x = carry
        lps, kvs = layer if caches is not None else (layer, None)
        new_kvs = [] if caches is not None else None
        for i, window in enumerate(wins):
            kv = kvs[i] if kvs is not None else None
            x, new_kv = block_apply(cfg, lps[i], x, positions=positions,
                                    window=window, kv_cache=kv,
                                    cache_pos=cache_pos)
            if new_kvs is not None:
                new_kvs.append(new_kv)
        return x, (tuple(new_kvs) if new_kvs is not None else None)

    if cfg.remat != "none":
        body = jax.checkpoint(body, policy=policy, prevent_cse=False)

    xs = (params["layers"], caches) if caches is not None else params["layers"]
    x, ys = jax.lax.scan(body, x, xs)
    return x, ys


# ---------------------------------------------------------------------------
# Public protocol.
# ---------------------------------------------------------------------------

def _embed_inputs(cfg: ArchConfig, params, batch):
    tokens = batch["tokens"]
    x = cm.embed_tokens(cfg, params["embedding"], tokens)
    if cfg.vision_prefix:
        # Stub ViT frontend: precomputed patch embeddings replace the
        # first ``vision_prefix`` positions (assignment: frontend is a
        # stub; ``input_specs()`` supplies the embeddings).
        vis = batch["vision_embeds"].astype(x.dtype)
        x = jnp.concatenate([vis, x[:, cfg.vision_prefix:]], axis=1)
    return x


def forward(cfg: ArchConfig, params, batch, return_hidden: bool = False):
    """Full-sequence forward (training / evaluation)."""
    x = _embed_inputs(cfg, params, batch)
    positions = jnp.arange(x.shape[1])
    x, _ = _scan_blocks(cfg, params, x, positions=positions)
    x = _norm(cfg, x, params["ln_final"])
    if return_hidden:
        return x
    return cm.logits_out(cfg, params, x)


def init_cache(cfg: ArchConfig, batch_size: int, max_len: int,
               dtype=None):
    dtype = dtype or cfg.kv_cache_dtype
    wins = _windows(cfg)
    group = len(wins)
    n = cfg.n_layers // group
    shape = (n, batch_size, cfg.n_kv_heads, max_len, cfg.head_dim)
    return tuple((jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
                 for _ in range(group))


def prefill(cfg: ArchConfig, params, batch, cache):
    """Process the prompt, fill the cache, return last-position logits."""
    x = _embed_inputs(cfg, params, batch)
    positions = jnp.arange(x.shape[1])
    x, cache = _scan_blocks(cfg, params, x, positions=positions,
                            caches=cache, cache_pos=0)
    x = _norm(cfg, x, params["ln_final"])
    return cm.logits_out(cfg, params, x[:, -1]), cache


def decode_step(cfg: ArchConfig, params, tokens, cache, pos):
    """tokens: (B, 1); pos: scalar current length.  One decode step."""
    x = cm.embed_tokens(cfg, params["embedding"], tokens)
    positions = jnp.full((tokens.shape[0], 1), pos, jnp.int32)
    x, cache = _scan_blocks(cfg, params, x, positions=positions,
                            caches=cache, cache_pos=pos)
    x = _norm(cfg, x, params["ln_final"])
    return cm.logits_out(cfg, params, x[:, -1]), cache


import sys as _sys  # noqa: E402

register_family("transformer")(_sys.modules[__name__])

"""Top-k MoE block (OLMoE 64e/top-8, Arctic 128e/top-2 + dense residual).

Distribution strategy (DESIGN.md §3, EP): activations are replicated
across the ``model`` axis (standard Megatron TP layout), experts are
sharded across it.  Each model shard sort-dispatches its *local* tokens
to the experts it owns, runs the grouped GEMM, combines with the gate
weights, and a single ``psum`` over ``model`` adds the partial outputs —
the same collective cost class as a Megatron row-parallel all-reduce,
with no global sort and no (T, E, C) one-hot.

Token overflow beyond ``capacity = ceil(T·k/E · cf)`` is dropped
(GShard-style); the property tests check conservation under capacity.
The single-device path is the same function with ``e_start=0`` and all
experts local.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from repro.core.jaxcompat import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.fusion import Epilogue, linear
from repro.models.base import ArchConfig
from repro.models.common import dense_init


def moe_init(cfg: ArchConfig, key):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    mult = 2 if cfg.mlp_glu else 1
    p = {
        "w_router": dense_init(ks[0], (d, m.n_experts), jnp.float32),
        "experts_wi": dense_init(
            ks[1], (m.n_experts, d, mult * m.d_ff_expert), cfg.dtype),
        "experts_wo": dense_init(
            ks[2], (m.n_experts, m.d_ff_expert, d), cfg.dtype, in_axis=2),
    }
    if m.dense_parallel:
        p["dense_wi"] = dense_init(ks[3], (d, mult * cfg.d_ff), cfg.dtype)
        p["dense_wo"] = dense_init(ks[4], (cfg.d_ff, d), cfg.dtype, in_axis=1)
    return p


def _expert_ffn(cfg: ArchConfig, wi, wo, x):
    """x: (E_l, C, d) -> (E_l, C, d) through the per-expert GLU MLP."""
    if cfg.backend == "pallas":
        from repro.kernels.moe.ops import grouped_matmul
        h = grouped_matmul(x, wi, epilogue=Epilogue(
            activation=cfg.mlp_activation, glu=cfg.mlp_glu,
            out_dtype=x.dtype))
        return grouped_matmul(h, wo)
    h = jnp.einsum("ecd,edf->ecf", x, wi,
                   preferred_element_type=jnp.float32)
    if cfg.mlp_glu:
        half = h.shape[-1] // 2
        from repro.core.fusion import ACTIVATIONS
        h = ACTIVATIONS[cfg.mlp_activation](h[..., :half]) * h[..., half:]
    else:
        from repro.core.fusion import ACTIVATIONS
        h = ACTIVATIONS[cfg.mlp_activation](h)
    h = h.astype(x.dtype)
    return jnp.einsum("ecf,efd->ecd", h, wo,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def moe_apply_local(cfg: ArchConfig, x2d, w_router, wi_local, wo_local,
                    e_start, capacity: int):
    """Partial MoE output of the locally-held experts.

    x2d: (T, d); wi_local: (E_l, d, mult·ff); e_start: first owned expert
    (traced OK).  Returns (T, d) — sum over model shards = full output.
    """
    m = cfg.moe
    t, d = x2d.shape
    e_local = wi_local.shape[0]

    logits = (x2d.astype(jnp.float32) @ w_router)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, m.top_k)              # (T, k)
    if m.renormalize:
        gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    flat_idx = idx.reshape(-1)                             # (T·k,)
    flat_gate = gate.reshape(-1)
    local_e = jnp.where(
        (flat_idx >= e_start) & (flat_idx < e_start + e_local),
        flat_idx - e_start, e_local)                       # e_local = trash

    order = jnp.argsort(local_e)                           # stable
    sorted_e = local_e[order]
    counts = jnp.bincount(local_e, length=e_local + 1)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(t * m.top_k) - starts[sorted_e]
    keep = (sorted_e < e_local) & (rank < capacity)
    slot = jnp.where(keep, sorted_e * capacity + rank, e_local * capacity)
    token = order // m.top_k

    disp = jnp.zeros((e_local * capacity + 1, d), x2d.dtype)
    disp = disp.at[slot].set(
        jnp.where(keep[:, None], x2d[token], 0.0).astype(x2d.dtype))
    disp = disp[:-1].reshape(e_local, capacity, d)

    y = _expert_ffn(cfg, wi_local, wo_local, disp)         # (E_l, C, d)
    y_flat = y.reshape(e_local * capacity, d)

    contrib = jnp.where(keep[:, None],
                        flat_gate[order][:, None].astype(x2d.dtype)
                        * y_flat[jnp.minimum(slot, e_local * capacity - 1)],
                        0.0)
    out = jnp.zeros((t, d), x2d.dtype).at[token].add(contrib.astype(x2d.dtype))
    return out


def moe_capacity(cfg: ArchConfig, tokens_local: int) -> int:
    m = cfg.moe
    cap = int(tokens_local * m.top_k * m.capacity_factor / m.n_experts) + 1
    return max(8, cap + (-cap) % 8)


def moe_apply(cfg: ArchConfig, p, x, mesh: Optional[Mesh] = None):
    """x: (B, S, d) -> (B, S, d).  Uses shard_map(EP over 'model') when a
    mesh with a 'model' axis is active; single-shard math otherwise."""
    b, s, d = x.shape
    m = cfg.moe
    if mesh is None:
        from repro.distributed import logical
        mesh = logical.active_mesh()

    if cfg.moe_shard_map and mesh is not None and "model" in mesh.shape \
            and m.n_experts % mesh.shape["model"] == 0:
        n_shards = mesh.shape["model"]
        e_local = m.n_experts // n_shards
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        t_local = (b // _size(mesh, data_axes)) * s
        capacity = moe_capacity(cfg, t_local)

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(data_axes, None, None), P(), P("model", None, None),
                      P("model", None, None)),
            out_specs=P(data_axes, None, None),
            check_vma=False)
        def sharded(x_l, w_router, wi_l, wo_l):
            shard = jax.lax.axis_index("model")
            x2d = x_l.reshape(-1, d)
            out = moe_apply_local(cfg, x2d, w_router, wi_l, wo_l,
                                  shard * e_local, capacity)
            out = jax.lax.psum(out, "model")
            return out.reshape(x_l.shape)

        y = sharded(x, p["w_router"], p["experts_wi"], p["experts_wo"])
    else:
        capacity = moe_capacity(cfg, b * s)
        y = moe_apply_local(cfg, x.reshape(-1, d), p["w_router"],
                            p["experts_wi"], p["experts_wo"], 0,
                            capacity).reshape(b, s, d)

    if m.dense_parallel:
        # Arctic: dense residual MLP in parallel with the MoE branch.
        h = linear(x, p["dense_wi"], activation=cfg.mlp_activation,
                   glu=cfg.mlp_glu)
        y = y + linear(h, p["dense_wo"])
    return y


def _size(mesh: Mesh, axes) -> int:
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out

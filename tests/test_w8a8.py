"""W8A8 SmoothQuant inference path (paper §5.1 pipeline)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.quantized import W8A8Linear, quantize_mlp


def _rel(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return np.abs(a - b).max() / (np.abs(b).max() + 1e-9)


def test_w8a8_linear_tracks_fp32():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (32, 256))
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 128))
    b = jax.random.normal(jax.random.PRNGKey(2), (128,))
    lin = W8A8Linear.from_float(w, bias=b)
    y = lin(x, activation="gelu", out_dtype=jnp.float32)
    ref = jax.nn.gelu(x @ w + b)
    assert _rel(y, ref) < 0.03


def test_smoothquant_beats_naive_on_outliers():
    """The paper's reason for SmoothQuant-O1 on Llama3: activation
    outlier channels wreck per-row dynamic quant; migration fixes it."""
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (64, 128))
    x = x.at[:, :4].mul(60.0)                      # outlier channels
    w = jax.random.normal(jax.random.PRNGKey(4), (128, 64))
    ref = x @ w
    naive = W8A8Linear.from_float(w)
    smooth = W8A8Linear.from_float(w, act_absmax=jnp.abs(x).max(0))
    err_naive = _rel(naive(x, out_dtype=jnp.float32), ref)
    err_smooth = _rel(smooth(x, out_dtype=jnp.float32), ref)
    assert err_smooth < err_naive
    assert err_smooth < 0.05


def test_w8a8_pallas_backend_matches_xla():
    x = jax.random.normal(jax.random.PRNGKey(5), (32, 128))
    w = jax.random.normal(jax.random.PRNGKey(6), (128, 128))
    lin = W8A8Linear.from_float(w)
    y_x = lin(x, out_dtype=jnp.float32, backend="xla")
    y_p = lin(x, out_dtype=jnp.float32, backend="pallas")
    assert _rel(y_p, y_x) < 1e-5


def test_quantized_swiglu_mlp():
    """Whole fused MLP block in W8A8 (gate/up single GEMM + down)."""
    d, ff = 64, 128
    x = jax.random.normal(jax.random.PRNGKey(7), (16, d))
    wi = jax.random.normal(jax.random.PRNGKey(8), (d, 2 * ff)) / np.sqrt(d)
    wo = jax.random.normal(jax.random.PRNGKey(9), (ff, d)) / np.sqrt(ff)
    lin_in, lin_out = quantize_mlp(wi, wo, x)

    h = lin_in(x, activation="none", out_dtype=jnp.float32)
    h = jax.nn.silu(h[:, :ff]) * h[:, ff:]
    y = lin_out(h, out_dtype=jnp.float32)

    h_ref = x @ wi
    h_ref = jax.nn.silu(h_ref[:, :ff]) * h_ref[:, ff:]
    ref = h_ref @ wo
    assert _rel(y, ref) < 0.05

"""Dense-attention oracle with identical mask semantics to the kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, sm_scale: float = None, causal: bool = True,
                  window: int = 0, softcap: float = 0.0, q_start: int = 0):
    """q: (B, H, Sq, D); k, v: (B, Hkv, Sk, D).  fp32 math throughout."""
    b, h, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    if sm_scale is None:
        sm_scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    group = h // hkv
    kk = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vv = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk) * sm_scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qpos = q_start + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows: softmax of all -inf -> uniform; zero them instead.
    any_valid = mask.any(axis=-1)[None, None, :, None]
    p = jnp.where(any_valid, p, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv).astype(q.dtype)

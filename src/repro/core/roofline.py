"""Three-term roofline model for the TPU adaptation.

    compute   = HLO_FLOPs   / peak_FLOP/s            (per chip)
    memory    = HLO_bytes   / HBM_bw                 (per chip)
    collective= coll_bytes  / link_bw                (per chip)

``cost_analysis()`` on a GSPMD-compiled executable reports *per-device*
FLOPs/bytes (verified empirically in the API prototype), so the terms
divide by single-chip peaks; the assignment's ``/(chips × …)`` form is
recovered by multiplying FLOPs back up — both are recorded in the
dry-run JSON.  Collective bytes come from summing operand sizes of
``all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute`` ops in the compiled HLO text (they are not in
``cost_analysis``).
"""

from __future__ import annotations

import dataclasses
import re

from repro.core.hardware import TARGET_CHIP, TpuChip

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:[%\w.\-]+\s*=\s*)?"
    r"((?:\([^)]*\)|[\w\[\],{}\s]+?))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s32|u32|s16|u16|"
                       r"s8|u8|pred)\[([\d,]*)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1}


def _shape_bytes(sig: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(sig):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> "dict[str, float]":
    """Sum output-shape bytes of every collective op, by op kind.

    HLO prints the result shape before the op name; for collectives the
    result size equals (all-reduce) or upper-bounds (all-gather output =
    gathered size) the bytes moved per device, which is the quantity the
    link-bandwidth term wants.  ``-start``/``-done`` async pairs are
    counted once (the ``-done`` op repeats the shape; we skip it).
    """
    per_kind: "dict[str, float]" = {}
    seen_done = set()
    for m in re.finditer(
            r"^\s*(?:ROOT\s+)?([%\w.\-]+)\s*=\s*([^=\n]*?)\s*"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(-start|-done)?\(",
            hlo_text, re.M):
        name, sig, kind, phase = m.group(1), m.group(2), m.group(3), m.group(4)
        if phase == "-done":
            continue
        b = _shape_bytes(sig)
        per_kind[kind] = per_kind.get(kind, 0.0) + b
    per_kind["total"] = sum(per_kind.values())
    return per_kind


@dataclasses.dataclass(frozen=True)
class Roofline:
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    chips: int
    model_flops_per_chip: float      # 6·N·D (dense) / 6·N_active·D (MoE), per chip
    chip: TpuChip = TARGET_CHIP
    dtype_peak: str = "bf16"

    @property
    def peak(self) -> float:
        return (self.chip.peak_int8 if self.dtype_peak == "int8"
                else self.chip.peak_bf16)

    @property
    def compute_s(self) -> float:
        return self.flops_per_chip / self.peak

    @property
    def memory_s(self) -> float:
        return self.bytes_per_chip / self.chip.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_chip / self.chip.ici_bw_total

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat / redundancy waste."""
        return (self.model_flops_per_chip / self.flops_per_chip
                if self.flops_per_chip else 0.0)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / achievable step time (the score)."""
        useful_s = self.model_flops_per_chip / self.peak
        return useful_s / self.bound_s if self.bound_s else 0.0

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "model_flops_per_chip": self.model_flops_per_chip,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "chips": self.chips,
        }

"""Cycle-approximate simulator vs the paper's headline claims."""

import pytest

from repro.core.config import CASE_STUDY, PLATFORM_2TOPS
from repro.core.hardware import BOOM, KUNMINGHU, PLATFORMS, ROCKET, SHUTTLE, \
    XEON_8580
from repro.core.simulator import (LayerTrace, SATURN_512, baseline_workload_seconds,
                                  simulate_gemm, simulate_layer,
                                  simulate_workload)
from repro.core.task import BiasType, MatMulTask


class TestGemmUtilization:
    def test_fig6_above_90pct_all_platforms(self):
        """Paper Fig. 6: 2 TOPS unit, M=N=512, K in 256..8192, util > 90%."""
        for platform in PLATFORMS.values():
            for k in (256, 512, 1024, 2048, 4096, 8192):
                t = MatMulTask(m=512, n=512, k=k)
                r = simulate_gemm(PLATFORM_2TOPS, t, platform)
                assert r.utilization > 0.90, (platform.name, k, r.utilization)

    def test_case_study_band(self):
        """4 TOPS @ 48 GB/s is bandwidth-limited: util in the ~70-85% band
        the paper's Fig. 7 shows for Eq.2-matched configurations."""
        t = MatMulTask(m=512, n=512, k=4096)
        r = simulate_gemm(CASE_STUDY, t, SHUTTLE)
        assert 0.60 < r.utilization < 0.85

    def test_bound_classification(self):
        small_k = simulate_gemm(CASE_STUDY, MatMulTask(m=512, n=512, k=256),
                                SHUTTLE)
        assert small_k.breakdown["bound"] == "memory"
        r2 = simulate_gemm(PLATFORM_2TOPS, MatMulTask(m=512, n=512, k=4096),
                           SHUTTLE)
        assert r2.breakdown["bound"] == "compute"

    def test_csr_dispatch_costs_more_than_rocc(self):
        t = MatMulTask(m=64, n=64, k=64)     # dispatch-dominated tiny task
        rocc = simulate_gemm(PLATFORM_2TOPS, t, BOOM)
        csr = simulate_gemm(PLATFORM_2TOPS, t, KUNMINGHU)
        assert csr.cycles >= rocc.cycles

    def test_bias_adds_traffic(self):
        t0 = MatMulTask(m=512, n=512, k=256)
        t1 = MatMulTask(m=512, n=512, k=256, bias_type=BiasType.FULL)
        r0 = simulate_gemm(CASE_STUDY, t0, SHUTTLE)
        r1 = simulate_gemm(CASE_STUDY, t1, SHUTTLE)
        assert r1.cycles > r0.cycles


def _layer(k=2048, vec_elems=512 * 512):
    return LayerTrace(
        name="linear+silu",
        gemms=(MatMulTask(m=512, n=512, k=k),),
        vector_ops={"silu": vec_elems, "quant": vec_elems},
        intermediate_bytes=vec_elems * 4.0,
    )


class TestFusion:
    def test_fused_faster_than_unfused(self):
        layer = _layer()
        f = simulate_layer(CASE_STUDY, layer, fused=True)
        u = simulate_layer(CASE_STUDY, layer, fused=False)
        assert f["cycles"] < u["cycles"]

    def test_fused_hides_shorter_stream(self):
        layer = _layer()
        f = simulate_layer(CASE_STUDY, layer, fused=True)
        assert f["cycles"] < f["matrix"] + f["vector"]
        assert f["cycles"] >= max(f["matrix"], f["vector"])

    def test_workload_aggregation(self):
        layers = [_layer(), _layer(k=4096)]
        w = simulate_workload(CASE_STUDY, layers, fused=True)
        assert w["seconds"] > 0
        assert w["flops"] == sum(l.flops() for l in layers)

    def test_baseline_no_overlap(self):
        layers = [_layer()]
        ours = simulate_workload(CASE_STUDY, layers, fused=True)["seconds"]
        base = baseline_workload_seconds(XEON_8580, layers)
        # With AMX-class compute and the same vector work, the fused
        # schedule should not lose (Table 6 shows >= 1x on every model).
        assert base >= 0.8 * ours

    def test_division_cost_visible(self):
        """§5.4: Saturn's element-wise divide makes SiLU expensive."""
        silu = SATURN_512.cycles("silu", 1 << 20)
        relu = SATURN_512.cycles("relu", 1 << 20)
        assert silu > 5 * relu

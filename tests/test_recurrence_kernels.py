"""RWKV-6 / RG-LRU kernels: Pallas vs chunked-jnp vs naive-scan oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.rglru.ops import rglru_scan
from repro.kernels.rglru.ref import rglru_decode_step, rglru_ref
from repro.kernels.rwkv6.ops import rwkv6_scan
from repro.kernels.rwkv6.ref import rwkv6_ref
from repro.models.rwkv6 import rwkv6_chunked_jnp


def _rwkv_inputs(B=2, H=3, T=96, C=64, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r = jax.random.normal(ks[0], (B, H, T, C))
    k = jax.random.normal(ks[1], (B, H, T, C))
    v = jax.random.normal(ks[2], (B, H, T, C))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, H, T, C)) * 0.5)
    u = jax.random.normal(ks[4], (H, C)) * 0.5
    return r, k, v, lw, u


class TestRwkv6:
    @pytest.mark.parametrize("t", [32, 70, 96])
    def test_pallas_vs_oracle(self, t):
        r, k, v, lw, u = _rwkv_inputs(T=t)
        out = rwkv6_scan(r, k, v, lw, u, chunk=32)
        ref, _ = rwkv6_ref(r, k, v, lw, u)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

    def test_chunked_jnp_vs_oracle(self):
        r, k, v, lw, u = _rwkv_inputs(T=80)
        out, state = rwkv6_chunked_jnp(r, k, v, lw, u, chunk=32)
        ref, state_ref = rwkv6_ref(r, k, v, lw, u)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(state), np.asarray(state_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_initial_state_continuation(self):
        """chunked(T) == chunked(T/2) ∘ chunked(T/2) with carried state."""
        r, k, v, lw, u = _rwkv_inputs(T=64)
        full, state_full = rwkv6_chunked_jnp(r, k, v, lw, u, chunk=32)
        h1, s1 = rwkv6_chunked_jnp(r[:, :, :32], k[:, :, :32], v[:, :, :32],
                                   lw[:, :, :32], u, chunk=32)
        h2, s2 = rwkv6_chunked_jnp(r[:, :, 32:], k[:, :, 32:], v[:, :, 32:],
                                   lw[:, :, 32:], u, chunk=32,
                                   initial_state=s1)
        np.testing.assert_allclose(np.asarray(h2),
                                   np.asarray(full[:, :, 32:]),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s2), np.asarray(state_full),
                                   rtol=1e-4, atol=1e-4)

    def test_strong_decay_forgets_beyond_one_token(self):
        """Property: with decay ≈ 0, S_{t-1} ≈ k_{t-1}ᵀ v_{t-1}, so each
        output sees exactly the previous token + its own bonus term."""
        r, k, v, lw, u = _rwkv_inputs(T=32)
        lw_hard = jnp.full_like(lw, -30.0)          # w = e^-30 ≈ 0
        out, _ = rwkv6_ref(r, k, v, lw_hard, u)
        bonus = jnp.sum(r * u[None, :, None, :] * k, axis=-1,
                        keepdims=True) * v
        prev = (jnp.sum(r[:, :, 1:] * k[:, :, :-1], axis=-1, keepdims=True)
                * v[:, :, :-1])
        expect = bonus.at[:, :, 1:].add(prev)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-3, atol=1e-3)


class TestRgLru:
    @pytest.mark.parametrize("t,c", [(64, 128), (100, 192), (32, 64)])
    def test_pallas_vs_oracle(self, t, c):
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        log_a = -jax.nn.softplus(jax.random.normal(ks[0], (2, t, c)))
        x = jax.random.normal(ks[1], (2, t, c))
        out = rglru_scan(log_a, x, chunk=32, block_c=64)
        ref, _ = rglru_ref(log_a, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_decode_step_matches_scan(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 2)
        log_a = -jax.nn.softplus(jax.random.normal(ks[0], (2, 8, 16)))
        x = jax.random.normal(ks[1], (2, 8, 16))
        seq, final = rglru_ref(log_a, x)
        h = jnp.zeros((2, 16))
        for t in range(8):
            out, h = rglru_decode_step(h, log_a[:, t], x[:, t])
            np.testing.assert_allclose(np.asarray(out),
                                       np.asarray(seq[:, t]),
                                       rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(h), np.asarray(final),
                                   rtol=1e-5, atol=1e-5)

    def test_a_one_is_pure_integrator_limit(self):
        """log_a = 0 => a=1, beta=0: state never changes from 0."""
        x = jnp.ones((1, 16, 8))
        out = rglru_scan(jnp.zeros((1, 16, 8)), x, chunk=8, block_c=8)
        np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)

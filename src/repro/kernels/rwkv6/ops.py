"""jit'd wrapper for the chunked RWKV-6 WKV kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.rwkv6.rwkv6 import rwkv6_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, lw, u, *, chunk: int = 32, interpret: bool = True):
    """r/k/v/lw: (B, H, T, C); u: (H, C) -> o (B, H, T, C).

    T must be a multiple of ``chunk`` (the wrapper pads with zero decay /
    zero keys, which leaves the state untouched, then slices).
    """
    b, h, t, c = r.shape
    pad = (-t) % chunk
    if pad:
        widths = ((0, 0), (0, 0), (0, pad), (0, 0))
        r, k, v = (jnp.pad(x, widths) for x in (r, k, v))
        lw = jnp.pad(lw, widths)          # lw=0 => w=1, but k=0 => no-op
    tp = t + pad
    shp = (b * h, tp, c)
    r2, k2, v2, lw2 = (x.reshape(shp) for x in (r, k, v, lw))
    grid = (b * h, tp // chunk)

    kernel = functools.partial(rwkv6_kernel, n_chunks=grid[1])
    try:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
    except (AttributeError, TypeError):
        compiler_params = None

    o = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, c), lambda bh, ch: (bh, ch, 0)),
            pl.BlockSpec((1, chunk, c), lambda bh, ch: (bh, ch, 0)),
            pl.BlockSpec((1, chunk, c), lambda bh, ch: (bh, ch, 0)),
            pl.BlockSpec((1, chunk, c), lambda bh, ch: (bh, ch, 0)),
            pl.BlockSpec((1, c), lambda bh, ch: (bh % h, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, c), lambda bh, ch: (bh, ch, 0)),
        out_shape=jax.ShapeDtypeStruct(shp, r.dtype),
        scratch_shapes=[pltpu.VMEM((c, c), jnp.float32)],
        compiler_params=compiler_params,
        interpret=interpret,
    )(r2, k2, v2, lw2, u)
    return o.reshape(b, h, tp, c)[:, :, :t]

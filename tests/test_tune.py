"""The autotuner, its cache, and the tuned capability dispatch.

Covers the PR's acceptance pins: tuned dispatch beats the untuned
default on cluster-DES makespan for the Llama-style decode regime on
every platform config (>= 2 required), the epilogue-fusion contribution
is isolated and pinned, same-config autotune reruns are byte-
deterministic, and the fused-epilogue execution path stays int8
bit-exact against the unfused matmul+vector reference on every
executing backend x granularity — including through the tuned dispatch.
"""

import dataclasses
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import backend, tune
from repro.core.config import CASE_STUDY
from repro.core.fusion import NO_OPERANDS, Epilogue, apply_epilogue
from repro.core.hardware import PLATFORMS
from repro.core.task import MatMulTask
from repro.sim.graph import Granularity
from repro.tune import autotune, regime
from repro.tune.space import DEFAULT_CONFIG, TunedConfig


def int8_pair(key, m, n, k):
    ka, kb = jax.random.split(key)
    return (jax.random.randint(ka, (m, k), -8, 8, jnp.int8),
            jax.random.randint(kb, (k, n), -8, 8, jnp.int8))


class TestSpace:
    def test_default_config_roundtrips_empty(self):
        assert DEFAULT_CONFIG.to_dict() == {}
        assert TunedConfig.from_dict({}) == DEFAULT_CONFIG

    def test_sparse_roundtrip(self):
        cfg = TunedConfig(granularity="panel", k_stream=False)
        d = cfg.to_dict()
        assert d == {"granularity": "panel", "k_stream": False}
        assert TunedConfig.from_dict(d) == cfg

    def test_unknown_field_rejected(self):
        with pytest.raises(ValueError, match="unknown TunedConfig"):
            TunedConfig.from_dict({"tile_q": 3})

    def test_shape_buckets(self):
        assert tune.shape_bucket(4, 4096, 4096) == "decode"
        assert tune.shape_bucket(32, 64, 64) == "decode"
        assert tune.shape_bucket(33, 64, 64) == "prefill"
        assert tune.bucket_of_task(MatMulTask(m=512, n=512, k=512)) \
            == "gemm|prefill"

    def test_schedule_bucket_decode_heavy(self):
        _, sched = regime.decode_regime_schedule()
        assert tune.schedule_bucket(sched) == "sched|u2|decode"

    def test_schedule_bucket_kv_pressure_suffix(self):
        """Refill-carrying schedules tune in their own bucket; an
        all-zero stamp is the classic all-resident regime."""
        import dataclasses
        _, sched = regime.decode_regime_schedule()
        refill = (0.0,) * (len(sched.layers) - 1) + (4096.0,)
        kv = dataclasses.replace(sched, refill_bytes=refill)
        assert tune.schedule_bucket(kv) == "sched|u2|decode|kv"
        zero = dataclasses.replace(sched,
                                   refill_bytes=(0.0,) * len(sched.layers))
        assert tune.schedule_bucket(zero) == "sched|u2|decode"

    def test_candidates_lead_with_default_and_dedupe(self):
        for cands in (tune.gemm_candidates(CASE_STUDY),
                      tune.schedule_candidates(CASE_STUDY)):
            assert cands[0] == DEFAULT_CONFIG
            assert len(cands) == len(set(cands))
            # deterministic order: the space is a pure function.
        assert tune.gemm_candidates(CASE_STUDY) \
            == tune.gemm_candidates(CASE_STUDY)

    def test_backend_kwargs_apply_tile_cut(self):
        cfg = TunedConfig(tile_m=32, granularity="layer", fused=False)
        kw = cfg.backend_kwargs(CASE_STUDY)
        assert kw["unit"].m_scp == 32
        assert kw["unit"].n_scp == CASE_STUDY.n_scp
        assert kw["granularity"] == "layer" and kw["fused"] is False


class TestCache:
    ENTRY = {"config": {"granularity": "panel"},
             "metrics": {"speedup": 1.25, "desim_cycles": 123.4567891}}

    def test_save_load_roundtrip(self, tmp_path):
        tune.save_cache("shuttle", {"sched|u2|decode": self.ENTRY},
                        cache_dir=tmp_path)
        loaded = tune.load_cache("shuttle", cache_dir=tmp_path)
        assert loaded["sched|u2|decode"]["config"] == {"granularity": "panel"}
        # floats are rounded on write (byte-determinism contract).
        assert loaded["sched|u2|decode"]["metrics"]["desim_cycles"] == 123.457

    def test_dump_is_byte_deterministic(self):
        a = tune.dump_cache("boom", {"gemm|decode": self.ENTRY})
        b = tune.dump_cache("boom", {"gemm|decode": dict(self.ENTRY)})
        assert a == b and a.endswith("\n")

    def test_missing_or_mismatched_schema_degrades_to_untuned(self, tmp_path):
        assert tune.load_cache("rocket", cache_dir=tmp_path) == {}
        p = tmp_path / "rocket.json"
        p.write_text('{"schema_version": 999, "entries": {"x": {}}}')
        assert tune.load_cache("rocket", cache_dir=tmp_path) == {}
        assert tune.lookup("rocket", "x", cache_dir=tmp_path) is None
        tune.clear_memo()

    def test_lookup_resolves_config(self, tmp_path):
        tune.save_cache("boom", {"gemm|decode": self.ENTRY},
                        cache_dir=tmp_path)
        cfg = tune.lookup("boom", "gemm|decode", cache_dir=tmp_path)
        assert cfg == TunedConfig(granularity="panel")
        assert tune.lookup("boom", "gemm|prefill", cache_dir=tmp_path) is None
        tune.clear_memo()

    @pytest.mark.parametrize("plat", sorted(PLATFORMS))
    def test_committed_caches_self_consistent(self, plat):
        entries = tune.load_cache(plat)
        assert entries, f"no committed tuning cache for {plat}"
        assert {"gemm|decode", "gemm|prefill", "sched|u2|decode"} \
            <= set(entries)
        for bucket, e in entries.items():
            m = e["metrics"]
            assert m["speedup"] >= 1.0, (plat, bucket)
            assert m["analytical_speedup"] >= 1.0, (plat, bucket)
            TunedConfig.from_dict(e["config"])    # parses


class TestAutotune:
    def test_budget_truncates_but_keeps_default(self):
        plat = PLATFORMS["shuttle"]
        entry = autotune.autotune_bucket(
            [next(iter(_decode_layers()))], tune.gemm_candidates(CASE_STUDY),
            plat, price=autotune.price_workload,
            measure=autotune.measure_workload, budget=1, top_k=2)
        assert entry["proposed"] == 1
        assert entry["config"] == {}          # only the default competed
        assert entry["metrics"]["speedup"] == 1.0

    def test_rerun_is_byte_identical(self):
        docs = []
        for _ in range(2):
            entries = autotune.autotune_platform(
                "shuttle", budget=8, buckets=["gemm|decode"])
            docs.append(tune.dump_cache("shuttle", entries))
        assert docs[0] == docs[1]

    def test_election_invariants_small_budget(self):
        entries = autotune.autotune_platform("kunminghu", budget=6)
        for bucket, e in entries.items():
            m = e["metrics"]
            assert m["speedup"] >= 1.0, bucket
            assert m["analytical_speedup"] >= 1.0, bucket
            assert e["proposed"] == 6 and e["measured"] >= 1


def _decode_layers():
    from repro.configs.registry import get_config
    from repro.serving.engine import _step_layer
    cfg = get_config("yi-6b", reduced=True)
    return [_step_layer(cfg, "tune-decode", autotune.DECODE_TOKENS, 1)]


class TestDispatch:
    """Precedence: explicit argument > tuned cache > untuned default."""

    def test_tuned_config_resolves_committed_cache(self):
        cfg = backend.tuned_config(shape=(4, 4096, 4096))
        assert cfg is not None and cfg.k_stream is False
        _, sched = regime.decode_regime_schedule()
        cfg = backend.tuned_config(sched=sched)
        assert cfg is not None and cfg.granularity == "panel"

    def test_get_tuned_applies_cache(self):
        _, sched = regime.decode_regime_schedule()
        eng = backend.get_tuned("desim-cluster", sched=sched, units=2)
        assert eng.granularity is Granularity.PANEL

    def test_explicit_argument_wins(self):
        _, sched = regime.decode_regime_schedule()
        eng = backend.get_tuned("desim-cluster", sched=sched, units=2,
                                granularity="layer")
        assert eng.granularity is Granularity.LAYER

    def test_untuned_fallback_on_unknown_bucket(self):
        eng = backend.get_tuned("analytical", bucket="sched|u7|prefill")
        assert eng.granularity is Granularity.TILE and eng.fused

    def test_kstream_dropped_for_single_unit_backends(self):
        # gemm|decode pins k_stream=False, which only cluster-aware
        # engines accept; the dispatch must not crash 'desim'/'jax'.
        eng = backend.get_tuned("desim", shape=(4, 4096, 4096))
        assert not eng.supports_units

    def test_disable_toggle(self):
        prev = backend.set_tuned_dispatch(False)
        try:
            assert backend.tuned_config(shape=(4, 4096, 4096)) is None
            eng = backend.get_tuned("analytical", shape=(4, 4096, 4096))
            assert eng.k_stream is True       # untuned default
        finally:
            backend.set_tuned_dispatch(prev)

    def test_dispatch_platform_validated(self):
        assert backend.dispatch_platform() in PLATFORMS
        with pytest.raises(KeyError):
            backend.set_dispatch_platform("pentium")
        prev = backend.set_dispatch_platform("kunminghu")
        try:
            assert backend.dispatch_platform() == "kunminghu"
        finally:
            backend.set_dispatch_platform(prev)

    def test_matmul_route_untouched_without_pin(self):
        # no committed cache pins a route, so the shape-aware resolution
        # falls through to the zoo default.
        assert backend.matmul_backend_string(shape=(4, 4096, 4096)) == "xla"
        assert backend.matmul_backend_string() == "xla"


class TestDecodeRegime:
    """The pinned end-to-end win (ISSUE acceptance): tuned dispatch
    beats the untuned default on cluster-DES makespan for the canonical
    Llama-style decode regime, on >= 2 platform configs, with the
    epilogue-fusion contribution isolated."""

    @pytest.mark.parametrize("plat", sorted(PLATFORMS))
    def test_tuned_beats_untuned_on_des(self, plat):
        m = regime.measure_decode_regime(plat)
        assert m["tuned_speedup"] >= 1.10, (plat, m)
        # fusion dominates: >2x with every other tuned knob held fixed
        # (the paper attributes >30% of its serving win to fusion).
        assert m["fusion_speedup"] >= 2.0, (plat, m)
        assert m["speedup"] >= m["tuned_speedup"], (plat, m)

    def test_bench_rows_match_live_measurement(self):
        import json
        import pathlib
        doc = json.loads((pathlib.Path(__file__).parent.parent
                          / "BENCH_serving.json").read_text())
        rows = {k: v["metrics"] for k, v in doc["entries"].items()
                if k.startswith("tuned|")}
        assert len(rows) >= 2
        live = regime.measure_decode_regime("shuttle")
        rec = rows["tuned|decode|shuttle"]
        assert live["tuned"] == pytest.approx(rec["tuned"], rel=1e-9)
        assert live["tuned_speedup"] == pytest.approx(rec["tuned_speedup"],
                                                      rel=1e-9)

    def test_engine_tuned_path_matches_regime(self):
        _, eng = regime.decode_regime_engine()
        sched = eng.plan(max_new_tokens=16, units=2,
                         policy="decode-priority", tuned=True)
        tuned = eng.run_schedule(sched, backend_name="desim-cluster",
                                 tuned=True, workload=False)
        plain = eng.run_schedule(
            eng.plan(max_new_tokens=16, units=2, policy="decode-priority"),
            backend_name="desim-cluster", workload=False)
        assert plain.cycles / tuned.cycles >= 1.10


class TestFusedBitExact:
    """Satellite: fused-epilogue execution is int8 bit-exact against the
    unfused matmul + vector reference on every executing backend x
    granularity, including through the tuned dispatch path."""

    EP = Epilogue(activation="relu", out_dtype=jnp.int32)

    def _ref(self, a, b):
        acc = jnp.matmul(a, b, preferred_element_type=jnp.int32)
        return np.asarray(apply_epilogue(acc, self.EP, NO_OPERANDS))

    @pytest.mark.parametrize("name", ["jax", "pallas", "desim"])
    @pytest.mark.parametrize("gran", ["tile", "panel", "layer"])
    def test_fused_matches_unfused(self, name, gran):
        a, b = int8_pair(jax.random.PRNGKey(7), 128, 128, 256)
        eng = backend.get(name, granularity=gran)
        g = eng.lower(MatMulTask(m=128, n=128, k=256), epilogue=self.EP)
        out = eng.run_graph(g, backend.MatMulOperands(a=a, b=b)).output
        assert (np.asarray(out) == self._ref(a, b)).all()

    @pytest.mark.parametrize("name", ["jax", "desim"])
    @pytest.mark.parametrize("shape", [(16, 128, 256), (128, 128, 256)])
    def test_tuned_dispatch_stays_bit_exact(self, name, shape):
        # decode bucket (m=16) resolves k_stream=False from the cache;
        # prefill (m=128) resolves the default — both must execute
        # identically to the unfused reference.
        m, n, k = shape
        a, b = int8_pair(jax.random.PRNGKey(8), m, n, k)
        eng = backend.get_tuned(name, shape=shape)
        g = eng.lower(MatMulTask(m=m, n=n, k=k), epilogue=self.EP)
        out = eng.run_graph(g, backend.MatMulOperands(a=a, b=b)).output
        assert (np.asarray(out) == self._ref(a, b)).all()

    def test_tuned_dispatch_bit_exact_desim_cluster(self):
        # the cluster DES executes the same graph it times when handed
        # operands; tuned dispatch must preserve that equivalence too.
        a, b = int8_pair(jax.random.PRNGKey(9), 128, 128, 256)
        eng = backend.get_tuned("desim-cluster", shape=(128, 128, 256),
                                units=2)
        g = eng.lower(MatMulTask(m=128, n=128, k=256), epilogue=self.EP)
        res = eng.run_graph(g, backend.MatMulOperands(a=a, b=b))
        if res.output is not None:
            assert (np.asarray(res.output) == self._ref(a, b)).all()
        assert res.cycles > 0


class TestOnlySelector:
    """Satellite: an unknown --only selector errors with the known
    bench list instead of running nothing."""

    def test_unknown_bench_name_lists_known(self):
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--only", "nope"],
            capture_output=True, text=True)
        assert proc.returncode != 0
        err = proc.stderr
        assert "unknown bench name(s): nope" in err
        for known in ("table6", "serving", "tune"):
            assert known in err

    def test_comma_separated_selector_parses(self):
        from benchmarks.run import BENCHES
        # the selector grammar: every advertised name must stay known.
        assert {"eq1", "tune", "serving"} <= set(BENCHES)

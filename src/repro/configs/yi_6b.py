"""yi-6b [dense]: 32L d=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.

Llama architecture with GQA; Yi uses a 5M RoPE base.  [arXiv:2403.04652; hf]
"""

from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b",
    family="transformer",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=5e6,
    mlp_activation="silu",
    mlp_glu=True,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                        head_dim=16, d_ff=128, vocab_size=512, attn_chunk=32)

"""repro.backend — the unified asyncMatMul contract, cross-engine parity.

The acceptance bar of the API redesign: one ``MatMulTask`` (and one
serving ``BatchSchedule``) travels the whole stack unchanged, and the
four registered engines agree — executing backends bit-exactly (int8),
modelling backends within ~1% on the makespan.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import backend
from repro.core.config import CASE_STUDY
from repro.core.fusion import Epilogue, cute_matmul
from repro.core.task import MatMulTask, Status
from repro.sim.graph import Granularity


def int8_pair(key, m, n, k):
    ka, kb = jax.random.split(key)
    return (jax.random.randint(ka, (m, k), -8, 8, jnp.int8),
            jax.random.randint(kb, (k, n), -8, 8, jnp.int8))


class TestRegistry:
    def test_four_backends_registered(self):
        assert set(backend.available()) >= {"jax", "pallas", "desim",
                                            "analytical"}

    def test_aliases_resolve(self):
        assert backend.resolve("analytic") == "analytical"
        assert backend.resolve("xla") == "jax"

    def test_unknown_backend_rejected(self):
        with pytest.raises(KeyError):
            backend.get("verilator")

    def test_constructor_kwargs(self):
        b = backend.get("desim", granularity="panel", fused=False)
        assert b.granularity is Granularity.PANEL and not b.fused

    def test_capability_flags(self):
        assert backend.get("jax").executes
        assert not backend.get("jax").models_time
        assert backend.get("analytical").models_time
        assert not backend.get("analytical").executes
        d = backend.get("desim")
        assert d.executes and d.models_time

    def test_zoo_default_route(self):
        assert backend.matmul_backend_string() in ("xla", "pallas")
        prev = backend.set_default_matmul_backend("pallas")
        try:
            assert backend.matmul_backend_string() == "pallas"
        finally:
            backend.set_default_matmul_backend(prev)

    def test_modelling_backends_not_zoo_routable(self):
        for name in ("desim", "analytical"):
            with pytest.raises(ValueError):
                backend.set_default_matmul_backend(name)


class TestDispatchContract:
    """asyncMatMul / checkMatmul semantics, identical across engines."""

    @pytest.mark.parametrize("name", ["jax", "desim", "analytical"])
    def test_status_register_lifecycle(self, name):
        task = MatMulTask(m=64, n=64, k=128)
        eng = backend.get(name)
        ops = (backend.MatMulOperands(*int8_pair(jax.random.PRNGKey(0),
                                                 64, 64, 128))
               if eng.executes and not eng.models_time else None)
        assert task.status is Status.IDLE
        h = eng.dispatch(task, ops)
        assert task.status is Status.RUNNING
        assert not eng.check(h) and not h.done()
        r = eng.wait(h)
        assert task.status is Status.DONE
        assert eng.check(h) and h.done()
        assert (r.output is not None) == (name == "jax")
        assert (r.cycles is not None) == (name != "jax")

    def test_drain_forces_all(self):
        eng = backend.get("analytical")
        for _ in range(3):
            eng.dispatch(MatMulTask(m=64, n=64, k=128))
        out = eng.drain()
        assert len(out) == 3 and all(r.cycles > 0 for r in out)
        assert not eng.dispatched

    def test_executing_backend_requires_operands(self):
        with pytest.raises(ValueError):
            backend.get("jax").dispatch(MatMulTask(m=8, n=8, k=8))

    @pytest.mark.parametrize("gran,n_vec", [("tile", 8), ("panel", 2),
                                            ("layer", 1)])
    def test_lower_granularity(self, gran, n_vec):
        eng = backend.get("desim", granularity=gran)
        ep = Epilogue(activation="relu", out_dtype=jnp.float32)
        graph = eng.lower(MatMulTask(m=128, n=256, k=64), epilogue=ep)
        assert len(graph.matmul_nodes()) == 2 * 4
        assert len(graph.vector_nodes()) == n_vec


class TestExecutionParity:
    """The same task, three executing routes, one answer."""

    def test_int8_bit_exact_jax_desim(self):
        task = MatMulTask(m=128, n=192, k=256)
        a, b = int8_pair(jax.random.PRNGKey(1), 128, 192, 256)
        ops = backend.MatMulOperands(a=a, b=b)
        outs = {}
        for name in ("jax", "desim"):
            outs[name] = np.asarray(
                backend.get(name).wait(
                    backend.get(name).dispatch(task, ops)).output)
        ref = np.asarray(cute_matmul(a, b, backend="xla"))
        assert (outs["jax"] == ref).all()
        assert (outs["desim"] == ref).all()

    def test_int8_bit_exact_pallas(self):
        # lane-aligned shape: the Pallas kernel's divisibility contract.
        task = MatMulTask(m=128, n=128, k=256)
        a, b = int8_pair(jax.random.PRNGKey(2), 128, 128, 256)
        out = backend.get("pallas").wait(
            backend.get("pallas").dispatch(
                task, backend.MatMulOperands(a=a, b=b))).output
        ref = cute_matmul(a, b, backend="xla")
        assert (np.asarray(out) == np.asarray(ref)).all()

    def test_bf16_tolerance(self):
        ka, kb = jax.random.split(jax.random.PRNGKey(3))
        a = jax.random.normal(ka, (128, 256), jnp.bfloat16)
        b = jax.random.normal(kb, (256, 128), jnp.bfloat16)
        task = MatMulTask(m=128, n=128, k=256)
        ops = backend.MatMulOperands(a=a, b=b)
        ref = np.asarray(cute_matmul(a, b, backend="xla"), np.float32)
        for name in ("jax", "pallas", "desim"):
            out = np.asarray(backend.get(name).wait(
                backend.get(name).dispatch(task, ops)).output, np.float32)
            np.testing.assert_allclose(out, ref, rtol=2e-2, atol=2e-2)

    def test_run_graph_with_epilogue_matches_direct(self):
        ep = Epilogue(activation="silu", glu=True, out_dtype=jnp.float32)
        task = MatMulTask(m=128, n=256, k=128)
        a, b = int8_pair(jax.random.PRNGKey(4), 128, 256, 128)
        eng = backend.get("jax", granularity="panel")
        graph = eng.lower(task, epilogue=ep)
        out = eng.run_graph(graph, backend.MatMulOperands(a=a, b=b)).output
        ref = cute_matmul(a, b, epilogue=ep, backend="xla")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


class TestMakespanParity:
    """analytical asserts the makespan the DES derives.  Re-baselined
    for the K-streamed default (both sides now stream K chunks): the
    legacy ~1% pins tightened to float noise on the GEMM regime and
    ≤1% on the fused-epilogue regime (layer granularity exposes the
    whole epilogue, the one place the closed form still approximates)."""

    @pytest.mark.parametrize("shape", [(256, 256, 1024), (512, 512, 4096),
                                       (512, 512, 8192)])
    def test_gemm_regime(self, shape):
        m, n, k = shape
        desim, ana = backend.get("desim"), backend.get("analytical")
        g = desim.lower(MatMulTask(m=m, n=n, k=k))
        rd, ra = desim.run_graph(g), ana.run_graph(g)
        assert rd.cycles > 0
        assert abs(ra.cycles / rd.cycles - 1.0) < 0.001
        assert abs(ra.utilization - rd.utilization) < 0.001

    @pytest.mark.parametrize("gran", ["tile", "panel", "layer"])
    def test_fused_epilogue_regime(self, gran):
        ep = Epilogue(activation="relu", out_dtype=jnp.float32)
        desim = backend.get("desim", granularity=gran)
        ana = backend.get("analytical", granularity=gran)
        g = desim.lower(MatMulTask(m=256, n=512, k=1024), epilogue=ep)
        rel = ana.run_graph(g).cycles / desim.run_graph(g).cycles - 1.0
        assert abs(rel) < 0.01

    def test_dispatch_path_agrees_too(self):
        task = MatMulTask(m=512, n=512, k=4096)
        rd = backend.get("desim").wait(backend.get("desim").dispatch(task))
        ra = backend.get("analytical").wait(
            backend.get("analytical").dispatch(task))
        assert abs(ra.cycles / rd.cycles - 1.0) < 0.001

    def test_run_workload_same_shape_dict(self):
        from repro.core.simulator import LayerTrace
        layers = [LayerTrace("l", (MatMulTask(m=128, n=256, k=512),),
                             vector_ops={"silu": 128 * 256.0}, repeat=2)]
        for name in ("desim", "analytical"):
            r = backend.get(name).run_workload(layers)
            assert {"cycles", "matrix", "vector", "seconds",
                    "flops"} <= set(r)
        with pytest.raises(NotImplementedError):
            backend.get("jax").run_workload(layers)


class TestServingSchedule:
    """ROADMAP item: serving batch schedules on DES timelines, and the
    identical schedule executed bit-exactly by the jax backend."""

    @pytest.fixture(scope="class")
    def engine(self):
        from repro.configs.registry import get_config
        from repro.serving.engine import ServingEngine
        cfg = get_config("yi-6b", reduced=True)
        eng = ServingEngine(cfg, params=None, max_batch=2, cache_len=64)
        key = jax.random.PRNGKey(0)
        for i in range(5):
            key, sub = jax.random.split(key)
            eng.submit(jax.random.randint(sub, (4 + i,), 0, 100))
        return eng

    def test_plan_shape(self, engine):
        sched = engine.plan(max_new_tokens=4)
        kinds = [s.kind for s in sched.steps]
        assert kinds == ["prefill", "decode"] * 3       # 5 reqs, batch 2
        assert sched.steps[0].requests == (0, 1)
        assert sched.steps[-1].requests == (4,)
        assert len(sched.layers) == len(sched.steps)
        assert engine._queue and len(engine._queue) == 5   # non-destructive

    def test_desim_timeline(self, engine):
        sched, res = engine.evaluate_schedule("desim", max_new_tokens=4)
        assert res.timeline is not None
        assert set(res.timeline.intervals) == {
            "dispatcher", "mem_loader", "scratchpad", "pe_array",
            "vector_unit"}
        assert res.cycles > 0
        assert res.detail["workload"]["cycles"] >= res.cycles
        assert all(0.0 <= u <= 1.0
                   for u in res.timeline.utilizations().values())

    def test_jax_executes_identical_schedule_bit_exact(self, engine):
        sched = engine.plan(max_new_tokens=4)
        ops = sched.example_operands(jax.random.PRNGKey(7))
        jax_eng, desim = backend.get("jax"), backend.get("desim")
        graph = jax_eng.lower(sched.layers)
        rj = jax_eng.run_graph(graph, ops)
        rd = desim.run_graph(desim.lower(sched.layers), ops)
        assert set(rj.outputs) == set(ops) == set(rd.outputs)
        for label, (a, b) in ops.items():
            ref = np.asarray(cute_matmul(a, b, backend="xla"))
            assert (np.asarray(rj.outputs[label]) == ref).all(), label
            assert (np.asarray(rd.outputs[label]) == ref).all(), label

    def test_analytical_agrees_on_schedule(self, engine):
        # re-baselined for the K-streamed default: serving steps tile
        # into tiny load-bound GEMMs where the first-chunk fill fold is
        # optimistic (~4%) — the same ≤5% band the cluster form carries.
        sched = engine.plan(max_new_tokens=4)
        desim, ana = backend.get("desim"), backend.get("analytical")
        g = desim.lower(sched.layers)
        rel = ana.run_graph(g).cycles / desim.run_graph(g).cycles - 1.0
        assert abs(rel) < 0.05

    def test_rejects_executing_backend(self, engine):
        with pytest.raises(ValueError):
            engine.evaluate_schedule("jax")


class TestBackendBenchmarkHook:
    def test_benchmarks_engine_lookup(self):
        """benchmarks/run.py resolves --engine through the registry."""
        import benchmarks.run as br
        old = br.ENGINE
        try:
            br.ENGINE = "desim"
            sim = br.workload_sim()
            from repro.core.simulator import LayerTrace
            r = sim(CASE_STUDY,
                    [LayerTrace("l", (MatMulTask(m=128, n=128, k=256),))])
            assert r["cycles"] > 0
        finally:
            br.ENGINE = old

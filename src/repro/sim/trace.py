"""Chrome-trace (Trace Event Format) export of DESim timelines.

The emitted JSON loads directly in Perfetto (https://ui.perfetto.dev)
or chrome://tracing: one *process* per matrix unit (plus pid 0 for
shared resources — the memory loader), one *thread* row per resource,
one complete ("X") event per busy interval, timestamps in microseconds
of simulated time.  Cluster results (``simulate_cluster``) name unit
resources ``u<i>/<resource>``; the exporter splits that prefix into the
process so each unit renders as its own track group instead of
interleaving on one row.  Overlapping events on the shared loader row
are the fair-share contention, made visible.

Serving-schedule graphs carry their batching policy's phase in the node
labels (``b0/prefill.c2/...``, ``dp3/decode/...``): the exporter
annotates each slice with ``args.phase`` (``prefill`` / ``prefill-chunk``
/ ``decode`` / ``mixed``) and a matching Perfetto colour, so a
``chunked-prefill`` or ``decode-priority`` timeline shows exactly where
decode iterations preempt prefill chunks.

Passing the priced :class:`~repro.serving.engine.BatchSchedule` as
``schedule=`` adds the request dimension: every serving slice gains
``args.request`` (the request ids riding that step) and ``args.step``,
and per request one chain of Perfetto *flow events* (``ph: "s"/"t"/"f"``
sharing ``id``) links its first slice of every step — so a request's
journey ``prefill chunk → decode iterations``, across whichever units
the partitioner placed them on, renders as a clickable arrow chain.
"""

from __future__ import annotations

import json
import re

from repro.sim.desim import DESimResult

#: stable row order in the viewer, dispatcher (the cause) on top.
_RESOURCE_ORDER = ("dispatcher", "mem_loader", "scratchpad", "pe_array",
                   "vector_unit")

#: serving-policy phase of an event label; chunked prefill steps are
#: named ``.../prefill.c<j>/...`` by ``serving.scheduler``.
_PHASE_RE = re.compile(r"(?:^|/)(prefill|decode|mixed)(\.[^/]*)?(?:/|$)")

#: Perfetto reserved colour names per phase — decode pops against the
#: prefill stream at a glance.
_PHASE_COLOR = {"prefill": "thread_state_running",
                "prefill-chunk": "thread_state_runnable",
                "decode": "thread_state_iowait",
                "mixed": "thread_state_unknown"}


def phase_of(label: str) -> "str | None":
    """Serving-policy phase of a node/interval label, or ``None`` for
    non-schedule work (bare GEMM tiles, transfers): ``prefill`` /
    ``prefill-chunk`` (a chunked-prefill slice) / ``decode`` /
    ``mixed`` (decode iterations piggybacked on a prefill chunk)."""
    m = _PHASE_RE.search(label)
    if m is None:
        return None
    kind, suffix = m.group(1), m.group(2)
    if kind == "prefill" and suffix:
        return "prefill-chunk"
    return kind


def _split(resource: str) -> "tuple[int, str]":
    """``"u3/pe_array" -> (4, "pe_array")``; shared/unprefixed -> pid 0."""
    if resource.startswith("u") and "/" in resource:
        head, _, rest = resource.partition("/")
        if head[1:].isdigit():
            return int(head[1:]) + 1, rest
    return 0, resource


def _order(name: str) -> int:
    return _RESOURCE_ORDER.index(name) if name in _RESOURCE_ORDER \
        else len(_RESOURCE_ORDER)


def _step_of(label: str, step_names: "list[str]") -> "str | None":
    """Schedule-step name a node/interval label belongs to: the step
    whose name prefixes the label at a ``/`` boundary (node names are
    ``<step>/g<i>/t<r>,<c>`` plus DES suffixes), longest match wins."""
    best = None
    for name in step_names:
        if label == name or label.startswith(name + "/"):
            if best is None or len(name) > len(best):
                best = name
    return best


def _flow_events(schedule, slices: "dict[str, list[dict]]",
                 ) -> "list[dict]":
    """One flow-event chain per request id: bind to the request's first
    ``pe_array`` slice (first slice at all as fallback) of each of its
    steps, in schedule order — ``ph:"s"`` opens the chain, ``"t"`` steps
    it, ``"f"`` (``bp:"e"``) closes it, all sharing ``id``."""
    rep: "dict[str, dict]" = {}
    for name, evs in slices.items():
        pe = [e for e in evs if e["cat"].endswith("pe_array")]
        rep[name] = min(pe or evs, key=lambda e: e["ts"])
    flows: "list[dict]" = []
    for r in sorted({q for s in schedule.steps for q in s.requests}):
        chain = [rep[lt.name]
                 for s, lt in zip(schedule.steps, schedule.layers)
                 if r in s.requests and lt.name in rep]
        if len(chain) < 2:
            continue
        for i, ev in enumerate(chain):
            ph = "s" if i == 0 else ("f" if i == len(chain) - 1 else "t")
            flow = {"name": f"req{r}", "cat": "request", "ph": ph,
                    "id": r, "pid": ev["pid"], "tid": ev["tid"],
                    "ts": ev["ts"]}
            if ph == "f":
                flow["bp"] = "e"
            flows.append(flow)
    return flows


def chrome_trace(result: DESimResult, *, process_name: str = "cutev2-desim",
                 schedule=None) -> dict:
    """Trace Event Format dict: ``{"traceEvents": [...], ...}``.

    ``schedule`` (the priced ``BatchSchedule`` the graph was lowered
    from) annotates serving slices with their request ids and stitches
    per-request flow-event chains — see the module docstring."""
    us_per_cycle = 1e6 / result.freq_hz
    step_names: "list[str]" = []
    step_requests: "dict[str, list[int]]" = {}
    slices: "dict[str, list[dict]]" = {}
    if schedule is not None:
        step_names = [lt.name for lt in schedule.layers]
        step_requests = {lt.name: list(s.requests)
                         for s, lt in zip(schedule.steps, schedule.layers)}
    events = []
    rows = sorted(((_split(r), r) for r in result.intervals),
                  key=lambda x: (x[0][0], _order(x[0][1])))
    pids_seen = set()
    tids: "dict[int, int]" = {}
    for (pid, thread), rname in rows:
        if pid not in pids_seen:
            pids_seen.add(pid)
            pname = process_name if pid == 0 else \
                f"{process_name}/unit{pid - 1}"
            events.append({"name": "process_name", "ph": "M", "pid": pid,
                           "args": {"name": pname}})
        tid = tids.get(pid, 0)
        tids[pid] = tid + 1
        events.append({"name": "thread_name", "ph": "M", "pid": pid,
                       "tid": tid, "args": {"name": thread}})
        for start, end, label in result.intervals[rname]:
            ev = {
                "name": label, "cat": rname, "ph": "X", "pid": pid,
                "tid": tid,
                "ts": start * us_per_cycle,
                "dur": max(end - start, 0.0) * us_per_cycle,
            }
            phase = phase_of(label)
            if phase is not None:
                ev["args"] = {"phase": phase}
                ev["cname"] = _PHASE_COLOR[phase]
            if step_names:
                step = _step_of(label, step_names)
                if step is not None:
                    ev.setdefault("args", {})
                    ev["args"]["step"] = step
                    ev["args"]["request"] = step_requests[step]
                    slices.setdefault(step, []).append(ev)
            events.append(ev)
    if schedule is not None and slices:
        events.extend(_flow_events(schedule, slices))
    other = {
        "total_cycles": result.cycles,
        "matrix_utilization": result.matrix_utilization,
        "resource_utilization": result.utilizations(),
    }
    n_units = getattr(result, "n_units", 1)
    if n_units > 1:
        other["n_units"] = n_units
        other["aggregate_matrix_utilization"] = \
            result.aggregate_matrix_utilization
        other["loader_utilization"] = result.loader_utilization
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": other,
    }


def dump_chrome_trace(result: DESimResult, path: str, **kw) -> str:
    with open(path, "w") as f:
        json.dump(chrome_trace(result, **kw), f)
    return path

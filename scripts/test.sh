#!/usr/bin/env bash
# Tier-1 verification: the command CI and the roadmap agree on, plus a
# backend-registry smoke run (benchmarks/run.py --engine is a
# repro.backend lookup, and table6 prices workloads through
# Backend.run_workload; regressions there should fail CI, not only
# interactive runs).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q "$@"
python -m benchmarks.run --only table6 --engine desim

"""Backend registry: names -> Backend classes, plus the zoo's default.

``get("desim", unit=..., granularity="panel")`` is the one lookup every
front door (serving, launch, benchmarks, examples, tests) goes through;
registering a new engine (multi-core DES, sharded execution, ...) is a
``@register("name")`` decoration away and every front door picks it up.
"""

from __future__ import annotations

from typing import Callable, Optional, Type

from repro.backend.base import Backend

_REGISTRY: "dict[str, Type[Backend]]" = {}

#: spelling compatibility: old benchmark/engine names -> registry names.
ALIASES = {"analytic": "analytical", "xla": "jax"}


def register(name: str, *,
             override: bool = False) -> Callable[[Type[Backend]], Type[Backend]]:
    """Register a Backend class under ``name``.

    Re-registering the *same* class is idempotent (module re-import
    safety); registering a different class under a taken name raises
    unless ``override=True`` — silent replacement has bitten every
    plugin registry ever.
    """
    def deco(cls: Type[Backend]) -> Type[Backend]:
        existing = _REGISTRY.get(name)
        if existing is not None and existing is not cls and not override:
            raise ValueError(
                f"backend name {name!r} already registered to "
                f"{existing.__name__}; pass register({name!r}, "
                f"override=True) to replace it")
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def resolve(name: str) -> str:
    canon = ALIASES.get(name, name)
    if canon not in _REGISTRY:
        raise KeyError(
            f"unknown backend {name!r}; registered: {available()} "
            f"(aliases: {dict(ALIASES)})")
    return canon


def get(name: str, **kwargs) -> Backend:
    """Instantiate a registered backend by name (aliases accepted)."""
    return _REGISTRY[resolve(name)](**kwargs)


def available() -> "tuple[str, ...]":
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# The model zoo's matmul route.  ``core.fusion.linear`` calls are resolved
# through here so the zoo speaks registry vocabulary; the default stays on
# the eager jax backend because Pallas-everywhere is too slow under
# interpret mode on CPU for whole-model tests (per-kernel coverage lives
# in tests/).
# ---------------------------------------------------------------------------

_DEFAULT_MATMUL = "jax"


def set_default_matmul_backend(name: str) -> str:
    """Route the model zoo's ``linear``/``cute_matmul`` calls through a
    different executing backend.  Returns the previous setting."""
    global _DEFAULT_MATMUL
    canon = resolve(name)
    cls = _REGISTRY[canon]
    if not cls.executes or cls.models_time:
        raise ValueError(
            f"backend {canon!r} is not an eager matmul route for the "
            "model zoo; use 'jax' or 'pallas' (modelling backends price "
            "schedules, they don't serve projections)")
    prev, _DEFAULT_MATMUL = _DEFAULT_MATMUL, canon
    return prev


def default_matmul_backend() -> str:
    return _DEFAULT_MATMUL


def matmul_backend_string(name: Optional[str] = None) -> str:
    """The ``cute_matmul(backend=...)`` string for a registry name
    (default: the zoo-wide setting)."""
    cls = _REGISTRY[resolve(name or _DEFAULT_MATMUL)]
    s = getattr(cls, "matmul_string", None)
    if s is None:
        raise ValueError(f"backend {cls.name!r} has no cute_matmul route")
    return s

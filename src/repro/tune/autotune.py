"""Model-guided kernel autotuner: the model proposes, measurement
disposes.

The contention-aware analytical closed form prices the *entire*
candidate space for pennies (microseconds per candidate); the ranked
top-K then goes to the discrete-event simulator, whose tile-by-tile
timelines decide the winner.  This is the paper's design loop run at
software speed: the analytical model is trusted to *order* candidates,
never to elect one.

Winner election is restricted to measured candidates whose analytical
price does not exceed the untuned default's — the default itself is
always measured — so two invariants hold by construction:

* the winner is never slower than the default on the DES
  (``speedup >= 1``), and
* the winner is never slower than the default on the analytical model
  (``analytical_speedup >= 1``) — the cheap CI smoke check.

Run as a module for the CI smoke job / cache regeneration::

    python -m repro.tune.autotune --platform shuttle --budget 20 --check
"""

from __future__ import annotations

import argparse
import sys
from typing import Optional

from repro.core.config import CASE_STUDY
from repro.core.hardware import PLATFORMS
from repro.sim.desim import simulate_cluster
from repro.sim.resources import ClusterTopology
from repro.tune import regime
from repro.tune.cache import cache_path, dump_cache, save_cache
from repro.tune.space import (DEFAULT_CONFIG, TunedConfig, gemm_candidates,
                              schedule_bucket, schedule_candidates)

#: how many analytically-ranked candidates the DES re-measures.
TOP_K = 4

#: representative GEMM-bucket row counts (decode: one row per in-flight
#: sequence at the regime's batch width; prefill: a full chunk).
DECODE_TOKENS = 4
PREFILL_TOKENS = 256


def _cycles(res) -> float:
    return float(res.cycles if hasattr(res, "cycles") else res["cycles"])


# ---------------------------------------------------------------------------
# Pricing: analytical proposer / DES disposer.
# ---------------------------------------------------------------------------

def price_workload(layers, cfg: TunedConfig, platform,
                   unit=CASE_STUDY) -> float:
    """Proposer price of a LayerTrace workload under candidate ``cfg``."""
    from repro import backend
    eng = backend.get("analytical", **cfg.backend_kwargs(unit, platform))
    return _cycles(eng.run_graph(eng.lower(layers)))


def measure_workload(layers, cfg: TunedConfig, platform,
                     unit=CASE_STUDY) -> float:
    """Disposer price: the single-unit DES machine (dedicated FCFS
    loader), honouring the candidate's ``k_stream`` choice."""
    from repro import backend
    eng = backend.get("analytical", **cfg.backend_kwargs(unit, platform))
    topo = ClusterTopology(n_units=1, unit=eng.unit, platform=eng.platform,
                           vector=eng.vector, loader_policy="fcfs",
                           k_stream=cfg.k_stream)
    return float(simulate_cluster(eng.lower(layers), topo).cycles)


def _apply_overlap(sched, cfg: TunedConfig):
    import dataclasses
    if cfg.overlap and cfg.overlap != sched.overlap:
        sched = dataclasses.replace(sched, overlap=cfg.overlap)
    return sched


def _schedule_engine(sched, cfg: TunedConfig, platform, backend_name: str,
                     unit=CASE_STUDY):
    from repro import backend
    from repro.serving.scheduler import backend_kwargs_for
    sched = _apply_overlap(sched, cfg)
    kw = backend_kwargs_for(sched, **cfg.backend_kwargs(unit, platform))
    return backend.get(backend_name, **kw), sched


def price_schedule(sched, cfg: TunedConfig, platform,
                   unit=CASE_STUDY) -> float:
    """Proposer price of a serving schedule: the analytical cluster form
    (M/G/1-PS loader contention) on the candidate-lowered graph."""
    eng, sched = _schedule_engine(sched, cfg, platform, "analytical", unit)
    return _cycles(eng.run_graph(eng.lower(sched)))


def measure_schedule(sched, cfg: TunedConfig, platform,
                     unit=CASE_STUDY) -> float:
    """Disposer price: the cluster DES on the same candidate lowering."""
    eng, sched = _schedule_engine(sched, cfg, platform, "desim-cluster", unit)
    return _cycles(eng.run_graph(eng.lower(sched)))


# ---------------------------------------------------------------------------
# The propose / dispose loop.
# ---------------------------------------------------------------------------

def autotune_bucket(work, candidates, platform, *,
                    price, measure, budget: Optional[int] = None,
                    top_k: int = TOP_K, unit=CASE_STUDY) -> dict:
    """Tune one (workload, candidate list) pair; returns a cache entry.

    ``budget`` truncates the deterministic candidate list (the untuned
    default is index 0, so any budget >= 1 keeps the comparison
    meaningful).  Ties — analytical and DES — resolve toward the lower
    candidate index, i.e. toward the default, so reruns are stable.
    """
    cands = list(candidates)
    if budget is not None:
        cands = cands[:max(1, budget)]
    if cands[0] != DEFAULT_CONFIG:
        raise ValueError("candidate list must lead with the default")

    proposed = [(price(work, c, platform, unit), i, c)
                for i, c in enumerate(cands)]
    default_analytical = proposed[0][0]
    ranked = sorted(proposed, key=lambda t: (t[0], t[1]))
    short = ranked[:max(1, top_k)]
    if all(c != DEFAULT_CONFIG for _, _, c in short):
        short.append(proposed[0])

    measured = [(measure(work, c, platform, unit), a, i, c)
                for a, i, c in short]
    # Election: DES-best among candidates the model does not price worse
    # than the default (the default always qualifies) — keeps both the
    # DES and the analytical speedup >= 1 by construction.
    eligible = [t for t in measured if t[1] <= default_analytical]
    des, analytical, _, winner = min(eligible, key=lambda t: (t[0], t[2]))
    default_des = next(t[0] for t in measured if t[3] == DEFAULT_CONFIG)

    return {
        "config": winner.to_dict(),
        "metrics": {
            "analytical_cycles": analytical,
            "default_analytical_cycles": default_analytical,
            "desim_cycles": des,
            "default_desim_cycles": default_des,
            "speedup": default_des / des,
            "analytical_speedup": default_analytical / analytical,
        },
        "proposed": len(cands),
        "measured": len(measured),
    }


def autotune_platform(platform_name: str, *, budget: Optional[int] = None,
                      top_k: int = TOP_K, units: int = regime.UNITS,
                      buckets=None) -> dict:
    """Tune every bucket of one platform; returns ``{bucket: entry}``.

    Buckets: ``gemm|decode`` and ``gemm|prefill`` tune a representative
    serving-step layer (the model's four projection GEMMs + epilogue
    vector work) at skinny and deep M; ``sched|u{units}|decode`` tunes
    the whole canonical decode-regime schedule, where the overlap mode
    joins the space.
    """
    from repro.serving.engine import _step_layer

    platform = PLATFORMS[platform_name]
    unit = CASE_STUDY
    cfg, sched = regime.decode_regime_schedule(units=units)
    reps = {
        "gemm|decode": [_step_layer(cfg, "tune-decode", DECODE_TOKENS, 1)],
        "gemm|prefill": [_step_layer(cfg, "tune-prefill", PREFILL_TOKENS, 1)],
    }
    sched_key = schedule_bucket(sched)

    entries = {}
    for key in buckets or (*reps, sched_key):
        if key in reps:
            entries[key] = autotune_bucket(
                reps[key], gemm_candidates(unit), platform,
                price=price_workload, measure=measure_workload,
                budget=budget, top_k=top_k, unit=unit)
        elif key == sched_key:
            entries[key] = autotune_bucket(
                sched, schedule_candidates(unit), platform,
                price=price_schedule, measure=measure_schedule,
                budget=budget, top_k=top_k, unit=unit)
        else:
            raise ValueError(f"unknown bucket {key!r}; known: "
                             f"{sorted((*reps, sched_key))}")
    return entries


# ---------------------------------------------------------------------------
# CLI — cache regeneration and the CI smoke check.
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="model-guided autotune: write per-platform tuning "
                    "caches and/or check their invariants")
    ap.add_argument("--platform", choices=sorted(PLATFORMS), action="append",
                    help="platform(s) to tune (default: all four)")
    ap.add_argument("--budget", type=int, default=None,
                    help="max candidates per bucket (default: full space)")
    ap.add_argument("--top-k", type=int, default=TOP_K,
                    help="analytically-ranked candidates the DES measures")
    ap.add_argument("--bucket", action="append",
                    help="restrict to specific bucket key(s)")
    ap.add_argument("--dry-run", action="store_true",
                    help="print the cache document instead of writing it")
    ap.add_argument("--check", action="store_true",
                    help="assert tuned >= untuned on both models")
    args = ap.parse_args(argv)

    failures = []
    for name in args.platform or sorted(PLATFORMS):
        entries = autotune_platform(name, budget=args.budget,
                                    top_k=args.top_k, buckets=args.bucket)
        if args.dry_run:
            sys.stdout.write(dump_cache(name, entries))
        else:
            path = save_cache(name, entries)
            print(f"wrote {path}")
        for bucket, e in entries.items():
            m = e["metrics"]
            line = (f"{name:10s} {bucket:16s} -> {e['config'] or 'default'} "
                    f"speedup {m['speedup']:.3f} "
                    f"(analytical {m['analytical_speedup']:.3f}, "
                    f"{e['proposed']} proposed / {e['measured']} measured)")
            print(line)
            if args.check:
                if m["analytical_speedup"] < 1.0 or m["speedup"] < 1.0:
                    failures.append(line)
    if failures:
        print("FAIL: tuned slower than untuned default:", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""GPipe-style pipeline parallelism (optional ``pp`` mesh axis).

The production mesh maps ``pod`` to data parallelism (DESIGN.md §3); this
module provides the PP alternative for deployments where cross-pod DCN
bandwidth cannot carry gradient all-reduces: stages hold layer slices,
microbatches stream through a ``lax.scan`` schedule, bubbles =
(stages-1)/(microbatches+stages-1).

Implementation: the classic "collective-permute pipeline" — the stage
axis lives in a shard_map; each scan step every stage processes one
microbatch and ppermutes its activation to the next stage.  Layers are
assumed stacked (scan-over-layers pytrees) so a stage slice is a leading-
axis slice of every leaf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from repro.core.jaxcompat import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def stage_slice(stacked_params, n_stages: int, stage: int):
    """Slice layer-stacked params into one stage's sub-stack."""
    def one(x):
        per = x.shape[0] // n_stages
        return jax.lax.dynamic_slice_in_dim(x, stage * per, per, axis=0)
    return jax.tree.map(one, stacked_params)


def pipeline_apply(block_fn, stacked_params, x_microbatches, mesh: Mesh,
                   axis: str = "pp"):
    """Run microbatches through pipeline stages.

    block_fn(stage_params, x) -> x applies one stage's layer sub-stack.
    x_microbatches: (n_micro, mb, ...) activations.
    Returns (n_micro, mb, ...) outputs after all stages.
    """
    n_stages = mesh.shape[axis]
    n_micro = x_microbatches.shape[0]
    steps = n_micro + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(axis), P()),               # params sharded by stage
        out_specs=P(), check_vma=False)
    def run(params_stage, xs):
        stage = jax.lax.axis_index(axis)
        params_stage = jax.tree.map(lambda p: p[0], params_stage)

        def body(carry, t):
            buf, outs = carry
            # Stage 0 injects microbatch t; others take the permuted buf.
            inject = jnp.where(t < n_micro, t, 0)
            x_in = jnp.where(stage == 0, xs[inject], buf)
            y = block_fn(params_stage, x_in)
            # Last stage emits a finished microbatch (t - n_stages + 1).
            done_idx = t - (n_stages - 1)
            emit = jnp.logical_and(stage == n_stages - 1, done_idx >= 0)
            outs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(done_idx, 0), 0),
                lambda o: o, outs)
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, outs), None

        buf0 = jnp.zeros_like(xs[0])
        outs0 = jnp.zeros_like(xs)
        (buf, outs), _ = jax.lax.scan(body, (buf0, outs0),
                                      jnp.arange(steps))
        # Collect the finished outputs from the last stage to all stages.
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs

    # shard_map wants the stage axis explicit on params' leading dim.
    def add_stage_axis(p):
        per = p.shape[0] // n_stages
        return p.reshape((n_stages, per) + p.shape[1:])

    staged = jax.tree.map(add_stage_axis, stacked_params)
    return run(staged, x_microbatches)


def pipeline_bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)

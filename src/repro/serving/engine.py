"""Batched serving engine on the async programming model.

The paper's asyncMatMul/checkMatmul contract shows up twice here:

* per step — every projection is a ``cute_matmul`` with fused epilogue,
  routed through the ``repro.backend`` registry default
  (``set_default_matmul_backend`` re-routes serving without touching
  this module);
* across *schedules* — ``ServingEngine.plan`` lowers the pending queue
  into a continuous-batching prefill/decode :class:`BatchSchedule` whose
  ``LayerTrace`` steps feed ``sim.lower.workload_to_graph``, so a
  batching policy can be priced on the ``desim`` backend's per-resource
  timelines (and the identical schedule graph executed bit-exactly by
  ``backend.get("jax")``) before it ever hits hardware.

``generate`` is the synchronous core: prefill the prompt batch, then a
``lax.scan`` decode loop with greedy/temperature sampling.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.precision import DataType
from repro.core.simulator import VECTOR_OP_INSTRS, LayerTrace
from repro.core.task import MatMulTask
from repro.models.base import ArchConfig, family_module


@dataclasses.dataclass
class GenerateResult:
    tokens: jax.Array          # (B, n_new)
    logits_last: jax.Array     # (B, V)
    steps: int


@dataclasses.dataclass(frozen=True)
class Request:
    """One queued serving request.

    ``arrival_time`` is the cycle (simulated-machine clock, the same
    currency every backend prices in) at which the request becomes
    available.  It flows ``submit`` → ``PolicyContext.arrival_times`` →
    per-step ``BatchSchedule.release_times`` → ``Node.release_time``,
    so the DES refuses to start a step before its requests exist and
    ``decode_latency_stats`` reports TTFT against the arrival instead of
    the t = 0 lower bound.  The default 0.0 reproduces the classic
    everything-queued-at-plan-time behaviour exactly.
    """

    tokens: jax.Array
    arrival_time: float = 0.0


def make_prefill(cfg: ArchConfig):
    mod = family_module(cfg)

    def prefill_step(params, batch, cache):
        return mod.prefill(cfg, params, batch, cache)
    return prefill_step


def make_decode(cfg: ArchConfig):
    mod = family_module(cfg)

    def decode_step(params, tokens, cache, pos):
        return mod.decode_step(cfg, params, tokens, cache, pos)
    return decode_step


def sample(logits, key, temperature: float = 0.0):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature,
                                  axis=-1).astype(jnp.int32)


def generate(cfg: ArchConfig, params, batch, *, max_new_tokens: int,
             temperature: float = 0.0, key=None,
             cache_len: Optional[int] = None) -> GenerateResult:
    """Prefill + scan-decode.  batch["tokens"]: (B, S_prompt)."""
    mod = family_module(cfg)
    b, s = batch["tokens"].shape
    cache_len = cache_len or (s + max_new_tokens)
    key = key if key is not None else jax.random.PRNGKey(0)

    cache = mod.init_cache(cfg, b, cache_len)
    logits, cache = mod.prefill(cfg, params, batch, cache)
    first = sample(logits, key, temperature)

    def body(carry, step_key):
        tok, cache, pos = carry
        logits, cache = mod.decode_step(cfg, params, tok[:, None], cache,
                                        pos)
        nxt = sample(logits, step_key, temperature)
        return (nxt, cache, pos + 1), (nxt, logits)

    keys = jax.random.split(key, max_new_tokens - 1) \
        if max_new_tokens > 1 else jnp.zeros((0, 2), jnp.uint32)
    (last, cache, _), (toks, logit_seq) = jax.lax.scan(
        body, (first, cache, jnp.int32(s)), keys)
    tokens = jnp.concatenate([first[:, None], jnp.moveaxis(toks, 0, 1)],
                             axis=1)
    logits_last = (logit_seq[-1] if max_new_tokens > 1 else logits)
    return GenerateResult(tokens=tokens, logits_last=logits_last,
                          steps=max_new_tokens)


# ---------------------------------------------------------------------------
# Batch schedules: the serving queue as a TaskGraph workload.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BatchStep:
    """One continuous-batching step: a padded batch through the model.

    ``kind`` is ``"prefill"``, ``"decode"``, or ``"mixed"`` (a chunked-
    prefill step with decode iterations piggybacked onto the chunk).
    ``decode_requests`` names the subset of ``requests`` that receives a
    decode token from this step — empty for pure prefill, and left empty
    by the classic full-prefill lowering (whose pure decode steps imply
    ``decode_requests == requests``).
    """

    kind: str                    # "prefill" | "decode" | "mixed"
    requests: "tuple[int, ...]"  # request ids riding this batch
    tokens: int                  # rows M entering each projection GEMM
    repeat: int                  # model layers (× decode steps for decode)
    decode_requests: "tuple[int, ...]" = ()


@dataclasses.dataclass
class BatchSchedule:
    """A planned drain of the queue, in the simulator's vocabulary.

    ``layers`` carries one :class:`~repro.core.simulator.LayerTrace` per
    step (a representative transformer layer's projection GEMMs + vector
    work; ``repeat`` scales it to full depth), ready for
    ``sim.lower.workload_to_graph`` / any ``repro.backend`` engine.

    ``units`` records the cluster width the schedule is planned against:
    a cluster backend (``desim-cluster`` / ``sharded``) shards every
    step's GEMMs across that many matrix units, so the same schedule is
    priced on contended multi-unit timelines.

    ``policy`` names the :mod:`repro.serving.scheduler` batching policy
    that produced the schedule; ``affinity`` carries that policy's
    per-step unit hints (``{step layer name: unit}``) for the
    ``unit-affinity`` partition strategy, and ``strategy`` records the
    partition strategy ``plan(policy="auto")`` priced the schedule
    against (``None``: caller's choice).

    ``overlap`` selects how the steps lower into one TaskGraph
    (``sim.lower.workload_to_graph``): ``"chained"`` serialises every
    step behind the previous one (the classic over-approximation);
    ``"relaxed"`` keeps only the true per-request data hazards
    (:meth:`step_deps`), so steps placed on disjoint units genuinely run
    concurrently.  ``arrival_times`` (per request id, cycles) and
    ``release_times`` (per step — the max arrival over the step's
    requests) carry request-arrival semantics into the graph as node
    release times and into ``decode_latency_stats`` as the TTFT
    baseline.

    ``refill_bytes`` (per step) carries the paged KV-cache refill each
    step owes — stamped by :meth:`repro.serving.scheduler
    .SchedulingPolicy._finish` from the context's residency state and
    lowered by ``workload_to_graph`` into a ``memory`` node ahead of
    the step's tiles, so the DES and the analytical form both price
    evicted-block refills while JAX execution (which skips memory
    nodes) stays bit-exact.  Empty means no tracked KV pressure.
    """

    steps: "list[BatchStep]"
    layers: "list[LayerTrace]"
    units: int = 1
    policy: str = "full-prefill"
    affinity: "dict[str, int]" = dataclasses.field(default_factory=dict)
    strategy: "Optional[str]" = None
    overlap: str = "chained"
    arrival_times: "tuple[float, ...]" = ()
    release_times: "tuple[float, ...]" = ()
    refill_bytes: "tuple[float, ...]" = ()

    def step_deps(self) -> "list[tuple[int, ...]]":
        """True cross-step data hazards: step *j* depends on step *i*
        iff *i* is the most recent earlier step touching one of *j*'s
        requests — the per-request KV-cache/activation chain (a decode
        iteration reads the KV its own prefill and earlier decode steps
        wrote; steps over disjoint requests share no state).  This is
        the dependency set ``overlap="relaxed"`` lowers, replacing the
        coarse chain with edges that cannot change results."""
        last: "dict[int, int]" = {}
        deps: "list[tuple[int, ...]]" = []
        for j, step in enumerate(self.steps):
            dj = sorted({last[r] for r in step.requests if r in last})
            deps.append(tuple(dj))
            for r in step.requests:
                last[r] = j
        return deps

    def arrival_of(self, request: int) -> float:
        """Arrival cycle of a request id (0.0 when arrivals untracked)."""
        return (self.arrival_times[request]
                if request < len(self.arrival_times) else 0.0)

    def gemm_tasks(self) -> "dict[str, MatMulTask]":
        """``{graph GEMM label: task}`` — the labels
        ``workload_to_graph`` assigns, keyed for ``run_graph`` operands."""
        return {f"{lt.name}/g{i}": g
                for lt in self.layers for i, g in enumerate(lt.gemms)}

    def example_operands(self, key, low: int = -8, high: int = 8,
                         ) -> "dict[str, tuple]":
        """Random int8 ``(a, b)`` arrays for every GEMM of the schedule —
        lets an executing backend run the identical schedule graph for
        real (the parity suite checks jax and desim agree bit-exactly).

        Per-GEMM keys are ``fold_in`` derivations from the *label*, so a
        GEMM's operands depend only on ``key`` and its own label — two
        schedules sharing a label (or one schedule re-planned with more
        steps) get identical arrays, where the old sequential
        ``jax.random.split`` chain made every operand depend on how many
        GEMMs preceded it.
        """
        ops = {}
        for label, t in self.gemm_tasks().items():
            sub = jax.random.fold_in(key, zlib.crc32(label.encode()))
            ka, kb = jax.random.split(sub)
            ops[label] = (jax.random.randint(ka, (t.m, t.k), low, high,
                                             jnp.int8),
                          jax.random.randint(kb, (t.k, t.n), low, high,
                                             jnp.int8))
        return ops


def _step_layer(cfg: ArchConfig, name: str, tokens: int,
                repeat: int) -> LayerTrace:
    """One serving step as a fused region: the four projection GEMMs of a
    representative transformer layer (int8, the paper's W8A8 pipeline)
    plus first-order vector work (norms, dequant, activation, residual)."""
    d = cfg.d_model
    mlp_n = cfg.d_ff * (2 if cfg.mlp_glu else 1)
    gemms = (
        MatMulTask(m=tokens, n=cfg.q_dim + 2 * cfg.kv_dim, k=d,
                   data_type=DataType.INT8),
        MatMulTask(m=tokens, n=d, k=cfg.q_dim, data_type=DataType.INT8),
        MatMulTask(m=tokens, n=mlp_n, k=d, data_type=DataType.INT8),
        MatMulTask(m=tokens, n=d, k=cfg.d_ff, data_type=DataType.INT8),
    )
    act = (cfg.mlp_activation if cfg.mlp_activation in VECTOR_OP_INSTRS
           else "eltwise_misc")
    vector_ops = {
        "rmsnorm": 2.0 * tokens * d,
        "dequant": float(sum(t.m * t.n for t in gemms)),
        act: float(tokens * cfg.d_ff),
        "residual": 2.0 * tokens * d,
    }
    if cfg.mlp_glu:
        vector_ops["glu_mul"] = float(tokens * cfg.d_ff)
    return LayerTrace(name, gemms, vector_ops=vector_ops,
                      intermediate_bytes=4.0 * tokens * mlp_n,
                      repeat=repeat)


class ServingEngine:
    """Continuous-batching façade with async prefill dispatch.

    ``metrics`` is the :class:`~repro.obs.metrics.MetricsRegistry` the
    engine reports into — by default the process registry, which starts
    *disabled* so planning/pricing pay nothing; serving entry points
    (``launch/serve.py --metrics-out``, ``benchmarks/record.py``) enable
    it or pass their own.
    """

    def __init__(self, cfg: ArchConfig, params, max_batch: int = 8,
                 cache_len: int = 512, metrics=None):
        from repro.obs import default_registry
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.metrics = metrics if metrics is not None else default_registry()
        self._queue: list = []            # token arrays, submission order
        self._arrivals: "list[float]" = []   # per-request arrival cycles

    def submit(self, tokens, arrival_time: float = 0.0) -> int:
        """Queue a request; returns a request id (asyncMatMul-style).

        ``tokens`` is a prompt token array or a :class:`Request`.
        ``arrival_time`` (cycles) is when the request becomes available:
        schedules planned from this queue stamp it on their steps as
        release times, so pricing reports genuine time-to-first-token
        under load rather than the all-arrived-at-t=0 lower bound.
        Requests must be submitted in non-decreasing arrival order (the
        queue *is* the arrival order)."""
        if isinstance(tokens, Request):
            tokens, arrival_time = tokens.tokens, tokens.arrival_time
        if arrival_time < 0:
            raise ValueError(f"arrival_time must be >= 0, "
                             f"got {arrival_time}")
        if self._arrivals and arrival_time < self._arrivals[-1]:
            raise ValueError(
                f"arrival_time {arrival_time} precedes the previous "
                f"request's {self._arrivals[-1]}; submit in arrival order")
        self._queue.append(jnp.asarray(tokens))
        self._arrivals.append(float(arrival_time))
        return len(self._queue) - 1

    @property
    def requests(self) -> "list[Request]":
        """The pending queue as :class:`Request` records."""
        return [Request(t, a) for t, a in zip(self._queue, self._arrivals)]

    # ----- batch schedules -> backends -----------------------------------
    def _policy_context(self, max_new_tokens: int, units: int):
        from repro.serving.scheduler import PolicyContext
        return PolicyContext(
            cfg=self.cfg,
            prompt_lengths=tuple(int(t.shape[-1]) for t in self._queue),
            max_batch=self.max_batch, max_new_tokens=max_new_tokens,
            units=units,
            arrival_times=(tuple(self._arrivals)
                           if any(self._arrivals) else ()))

    def plan(self, max_new_tokens: int = 32, units: int = 1,
             policy: str = "full-prefill", overlap: str = "chained",
             tuned: bool = False, **policy_kw) -> BatchSchedule:
        """Plan the continuous-batching drain of the current queue
        (non-destructive) under a :mod:`repro.serving.scheduler` batching
        policy.  The default ``full-prefill`` reproduces the classic
        inline policy bit-identically: per padded chunk, one prefill step
        over ``B × S_padded`` tokens, then ``max_new_tokens`` decode
        steps of ``B`` tokens (collapsed into one repeated LayerTrace).
        ``chunked-prefill`` / ``decode-priority`` interleave prefill
        chunks with in-flight decode; ``policy="auto"`` prices every
        (policy × partition × overlap) candidate with the
        contention-aware ``analytical`` closed form and returns the best
        one.

        ``units`` is the cluster width the schedule targets — recorded on
        the schedule and consumed by ``evaluate_schedule`` so a cluster
        backend prices the drain on ``units`` contended matrix units.
        ``overlap`` selects the step-chaining mode the schedule lowers
        with (``"chained"`` serial / ``"relaxed"`` true data hazards
        only — see :class:`BatchSchedule`); ignored by ``policy="auto"``
        which sweeps both.

        ``tuned=True`` consults the per-platform tuning cache
        (``repro.backend.tuned_config``) for this schedule's shape
        bucket and applies the cached ``overlap`` choice — explicit
        ``overlap`` still loses to the tuned one only on this opt-in
        path; the default stays exactly the untuned plan."""
        from repro.serving import scheduler
        from repro.sim.lower import OVERLAP_MODES
        if overlap not in OVERLAP_MODES:
            raise ValueError(f"unknown overlap mode {overlap!r}; one of "
                             f"{OVERLAP_MODES}")
        ctx = self._policy_context(max_new_tokens, units)
        if policy == "auto":
            # policy kwargs (chunk_tokens, ...) sweep the candidates;
            # select_schedule's own knobs pass through by name.
            select = {"backend_name", "objective", "makespan_slack",
                      "policies", "strategies", "overlaps", "policy_kw"}
            kw = {k: v for k, v in policy_kw.items() if k in select}
            extra = {k: v for k, v in policy_kw.items()
                     if k not in select}
            if extra:
                kw["policy_kw"] = {**extra, **kw.get("policy_kw", {})}
            sched, _ = scheduler.select_schedule(ctx, **kw)
        else:
            pol = scheduler.get_policy(policy, **policy_kw)
            sched = pol.schedule(ctx)
            if not getattr(pol, "meta", False):
                # meta-policies (auto-slo) sweep overlap themselves; the
                # caller's default must not clobber their choice.
                sched.overlap = overlap
        if tuned:
            self._apply_tuned_overlap(sched)
        self._record_plan(sched)
        return sched

    @staticmethod
    def _apply_tuned_overlap(sched) -> None:
        """Fold the tuning cache's overlap choice for this schedule's
        bucket into the plan (no-op when the bucket is untuned)."""
        from repro import backend
        cfg = backend.tuned_config(sched=sched)
        if cfg is not None and cfg.overlap:
            sched.overlap = cfg.overlap

    def _record_plan(self, sched) -> None:
        """Planning counters (no-ops while the registry is disabled)."""
        m = self.metrics
        m.counter("serving_plans_total", policy=sched.policy,
                  overlap=sched.overlap, units=sched.units).inc()
        m.counter("serving_requests_total", policy=sched.policy).inc(
            len({r for s in sched.steps for r in s.requests}))
        m.counter("serving_steps_total", policy=sched.policy).inc(
            len(sched.steps))

    def autoplan(self, max_new_tokens: int = 32, units: int = 1,
                 **select_kw) -> "tuple[BatchSchedule, dict]":
        """``plan(policy="auto")`` with the full pricing report: every
        (policy × partition) candidate priced by the analytical closed
        form, plus the chosen candidate's metrics under ``"chosen"``."""
        from repro.serving import scheduler
        return scheduler.select_schedule(
            self._policy_context(max_new_tokens, units), **select_kw)

    def evaluate_schedule(self, backend_name: str = "desim",
                          max_new_tokens: int = 32, operands=None,
                          units: Optional[int] = None,
                          policy: str = "full-prefill",
                          overlap: str = "chained",
                          workload: bool = True,
                          tuned: bool = False,
                          **backend_kwargs):
        """Price the planned schedule on a modelling backend.

        Lowers ``plan(max_new_tokens, units, policy, overlap)`` through
        ``workload_to_graph`` at the backend's granularity/fusion policy
        (``overlap="relaxed"`` keeps only true per-request hazards, so
        steps on disjoint units overlap on the priced timeline; arrival
        times become node release times either way)
        and runs the graph — ``desim`` returns the per-resource timeline
        (and, given ``operands``, the executed numbers);
        ``desim-cluster`` with ``units=N`` prices the same schedule on N
        matrix units contending for the shared loader, and
        ``analytical`` with ``units=N`` prices it with the contention-
        aware closed form without running the DES.  Cluster partition
        defaults follow ``scheduler.backend_kwargs_for`` (the caller's
        explicit ``strategy`` wins, else the schedule's auto-chosen one,
        else ``unit-affinity`` when the policy emitted placement hints,
        else ``output-tile`` — serving GEMMs are short and wide), so
        this prices the same deployment ``price_steps`` does.  Returns
        ``(schedule, ExecResult)``; ``result.detail["workload"]``
        carries the repeat-weighted whole-schedule cost dict
        (``workload=False`` skips that second pricing pass — callers
        that also run ``scheduler.price_steps`` already have it as the
        per-step sum).
        """
        units = 1 if units is None else units
        sched = self.plan(max_new_tokens, units=units, policy=policy,
                          overlap=overlap, tuned=tuned)
        return sched, self.run_schedule(
            sched, backend_name=backend_name, operands=operands,
            workload=workload, tuned=tuned, **backend_kwargs)

    def run_schedule(self, sched: BatchSchedule,
                     backend_name: str = "desim", operands=None,
                     workload: bool = True, attach_spans: bool = True,
                     tuned: bool = False, **backend_kwargs):
        """Price an already-planned schedule on a modelling backend —
        the execution half of :meth:`evaluate_schedule`, callable with a
        schedule from any source (the online loop re-plans its own
        epoch schedules and executes each committed one through here,
        so spans/metrics stay grounded in the same DES path).  Returns
        the :class:`~repro.backend.base.ExecResult`; ``attach_spans``
        controls the :class:`~repro.obs.SpanLog` join (the online loop
        assembles its own global log across epochs instead).

        ``tuned=True`` resolves the backend through
        ``repro.backend.get_tuned``: the platform's cached winner for
        this schedule's bucket supplies granularity / fusion /
        K-streaming / tile kwargs (plus the overlap lowering mode,
        applied to the schedule), and any explicit ``backend_kwargs``
        still win over the cache."""
        from repro import backend
        from repro.serving.scheduler import backend_kwargs_for
        if tuned:
            self._apply_tuned_overlap(sched)
        backend_kwargs = backend_kwargs_for(sched, units=sched.units,
                                            **backend_kwargs)
        # the schedule records the partition it was actually priced
        # under, so downstream latency timelines agree with the pricing.
        sched.strategy = backend_kwargs.get("strategy", sched.strategy)
        if tuned:
            eng = backend.get_tuned(backend_name, sched=sched,
                                    **backend_kwargs)
        else:
            eng = backend.get(backend_name, **backend_kwargs)
        if not eng.models_time:
            raise ValueError(
                f"backend {backend_name!r} executes but does not model "
                "time; use 'desim' or 'analytical'")
        graph = eng.lower(sched)
        result = eng.run_graph(graph, operands)
        if workload:
            result.detail["workload"] = eng.run_workload(sched.layers)
        spans = result.detail.get("step_spans")
        if attach_spans and spans is not None and sched.steps:
            from repro.obs import SpanLog
            log = SpanLog.from_schedule(sched, spans, self.cfg.n_layers)
            result.detail["span_log"] = log
            self._record_spans(log, sched, backend_name)
        return result

    def _record_spans(self, log, sched, backend_name: str) -> None:
        """Fold a priced run's span log into the metrics registry:
        per-request TTFT, per-request span counts, the run's makespan."""
        m = self.metrics
        if not m.enabled:
            return
        labels = dict(policy=sched.policy, backend=backend_name,
                      units=sched.units, overlap=sched.overlap)
        ttft = m.histogram("serving_ttft_cycles", **labels)
        for r in log.requests():
            try:
                ttft.observe(log.ttft(r))
            except KeyError:
                pass                      # request never decodes
        m.histogram("serving_request_spans", **labels).observe(len(log))
        m.gauge("serving_makespan_cycles", **labels).set(
            max((s.end for s in log.spans), default=0.0))

    def run(self, max_new_tokens: int = 32, temperature: float = 0.0):
        """Drain the queue in padded batches; returns list of token arrays."""
        out = []
        while self._queue:
            chunk, self._queue = (self._queue[: self.max_batch],
                                  self._queue[self.max_batch:])
            self._arrivals = self._arrivals[len(chunk):]
            s = max(int(t.shape[-1]) for t in chunk)
            toks = jnp.stack([jnp.pad(t, (s - t.shape[-1], 0)) for t in chunk])
            batch = {"tokens": toks}
            if self.cfg.encdec is not None:
                batch["audio_embeds"] = jnp.zeros(
                    (toks.shape[0], self.cfg.encdec.n_audio_ctx,
                     self.cfg.d_model), jnp.float32)
            if self.cfg.vision_prefix:
                batch["vision_embeds"] = jnp.zeros(
                    (toks.shape[0], self.cfg.vision_prefix,
                     self.cfg.d_model), jnp.float32)
            res = generate(self.cfg, self.params, batch,
                           max_new_tokens=max_new_tokens,
                           temperature=temperature,
                           cache_len=self.cache_len)
            out.extend(list(res.tokens))
        return out

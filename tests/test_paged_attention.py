"""Bit-exactness of paged attention against the contiguous reference.

The paged path gathers block-table pages back into the contiguous
layout and runs the identical kernel, so every comparison here is exact
array equality (int8 in, int8 out — no tolerances).  Both attention
routes are covered: the pure-jnp ``decode_attention`` and the Pallas
``flash_attention`` kernel (interpret mode).  The granularity/backend
sweep (tile/panel/layer lowering on jax + desim with KV refill nodes in
the graph) lives in ``test_kv_residency.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention.ops import decode_attention, flash_attention
from repro.kernels.attention.paged import (gather_paged,
                                           paged_decode_attention,
                                           paged_flash_attention, to_paged)


def int8(key, shape):
    return jax.random.randint(key, shape, -127, 128, dtype=jnp.int8)


def caches(seed=0, b=2, hkv=2, s=40, d=16):
    k0, k1 = jax.random.split(jax.random.PRNGKey(seed))
    return int8(k0, (b, hkv, s, d)), int8(k1, (b, hkv, s, d))


# ----- page layout ----------------------------------------------------------

def test_round_trip_is_identity():
    k, v = caches(s=40)
    kp, vp, table = to_paged(k, v, 8, seed=3)
    assert np.array_equal(gather_paged(kp, table, 40), k)
    assert np.array_equal(gather_paged(vp, table, 40), v)


def test_round_trip_with_ragged_tail():
    k, v = caches(s=37)                      # not a block multiple
    kp, vp, table = to_paged(k, v, 8, seed=1)
    assert kp.shape == (2 * 5, 2, 8, 16)     # padded to 5 blocks
    assert np.array_equal(gather_paged(kp, table, 37), k)


def test_block_table_is_shuffled():
    k, v = caches()
    _, _, table = to_paged(k, v, 8, seed=2)
    flat = np.asarray(table).ravel()
    assert sorted(flat) == list(range(flat.size))
    assert not np.array_equal(flat, np.arange(flat.size))


def test_to_paged_validates():
    k, v = caches()
    with pytest.raises(ValueError, match="block_tokens"):
        to_paged(k, v, 0)
    with pytest.raises(ValueError, match="mismatch"):
        to_paged(k, v[:, :, :-1], 8)


# ----- decode route (pure jnp) ----------------------------------------------

@pytest.mark.parametrize("block_tokens", (4, 8, 16))
def test_paged_decode_bit_exact_int8(block_tokens):
    k, v = caches(s=40)
    q = int8(jax.random.PRNGKey(9), (2, 4, 1, 16))
    cache_len = jnp.array([33, 40])
    ref = decode_attention(q, k, v, cache_len)
    kp, vp, table = to_paged(k, v, block_tokens, seed=7)
    got = paged_decode_attention(q, kp, vp, table, cache_len, seq_len=40)
    assert got.dtype == jnp.int8
    assert np.array_equal(got, ref)


def test_paged_decode_bit_exact_window_softcap():
    k, v = caches(seed=4, s=48)
    q = int8(jax.random.PRNGKey(5), (2, 4, 1, 16))
    cache_len = jnp.array([48, 21])
    ref = decode_attention(q, k, v, cache_len, window=16, softcap=50.0)
    kp, vp, table = to_paged(k, v, 8, seed=2)
    got = paged_decode_attention(q, kp, vp, table, cache_len, seq_len=48,
                                 window=16, softcap=50.0)
    assert np.array_equal(got, ref)


def test_paged_decode_independent_of_page_placement():
    """Different physical page orders give byte-identical outputs."""
    k, v = caches(s=32)
    q = int8(jax.random.PRNGKey(1), (2, 4, 1, 16))
    cache_len = jnp.array([32, 30])
    outs = []
    for seed in (0, 1, 2):
        kp, vp, table = to_paged(k, v, 8, seed=seed)
        outs.append(np.asarray(paged_decode_attention(
            q, kp, vp, table, cache_len, seq_len=32)))
    assert np.array_equal(outs[0], outs[1])
    assert np.array_equal(outs[1], outs[2])


# ----- flash route (Pallas, interpret) --------------------------------------

@pytest.mark.parametrize("block_tokens", (8, 16))
def test_paged_flash_bit_exact_int8(block_tokens):
    k, v = caches(s=32)
    q = int8(jax.random.PRNGKey(3), (2, 4, 32, 16))
    ref = flash_attention(q, k, v, block_q=16, block_kv=16)
    kp, vp, table = to_paged(k, v, block_tokens, seed=5)
    got = paged_flash_attention(q, kp, vp, table, seq_len=32,
                                block_q=16, block_kv=16)
    assert got.dtype == jnp.int8
    assert np.array_equal(got, ref)


def test_paged_flash_gqa_noncausal():
    k, v = caches(seed=2, s=24)
    q = int8(jax.random.PRNGKey(8), (2, 8, 8, 16))     # 8 q heads, 2 kv
    ref = flash_attention(q, k, v, causal=False, block_q=8, block_kv=8)
    kp, vp, table = to_paged(k, v, 8, seed=6)
    got = paged_flash_attention(q, kp, vp, table, seq_len=24, causal=False,
                                block_q=8, block_kv=8)
    assert np.array_equal(got, ref)

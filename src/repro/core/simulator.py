"""Cycle-approximate simulator of the CUTEv2 matrix unit + vector unit.

The paper evaluates on Chipyard + Verilator + DRAMSim RTL simulation.  We
reproduce its *claims* with a first-order analytical model of the same
microarchitecture (§4.1):

* **Memory Loader** — streams A/B panels and writes back C at the SoC's
  data-supply bandwidth, derated by a DRAM-efficiency factor (the paper
  attributes its GEMM fluctuations to DRAMSim stride behaviour, §5.4).
* **Scratchpad** — multi-bank, so loading overlaps compute (double
  buffering); the fp32/int32 accumulator tile stays resident across the
  whole K sweep (output-stationary, §4.1) and is written back once.
* **PE array** — ``M_pe × N_pe`` PEs, each reducing ``K_pe`` bits/cycle;
  six-stage pipeline gives a fill latency.
* **CPU front-end** — per-tile ``asyncMatMul`` dispatch cost depends on
  the interface (RoCC few cycles, CSR mailbox ~100; paper §4.4/Table 3).
  Dispatch proceeds concurrently with the unit, so it only exposes when
  the CPU cannot stay ahead of the matrix unit.
* **Vector unit** — Saturn-style 512-bit RVV; element-wise ops modelled
  with instructions/element and a slow non-pipelined divider (the paper
  calls out SiLU/softmax division cost on Saturn explicitly, §5.4).

Fused (Listing 1) execution overlaps per-tile vector epilogues with
matrix compute and skips the DRAM round-trip of the intermediate;
unfused runs matrix then vector with the round-trip.  Commercial
baselines (Table 5) use a synchronous no-overlap model with calibrated
efficiency factors.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core.config import MatrixUnitConfig
from repro.core.hardware import CommercialBaseline, CpuPlatform, SHUTTLE
from repro.core.precision import DataType, policy
from repro.core.task import BiasType, MatMulTask


# ---------------------------------------------------------------------------
# Vector-unit model.
# ---------------------------------------------------------------------------

#: vector instructions per element (fp32 lanes), first-order costs.
VECTOR_OP_INSTRS = {
    "copy": 1, "add": 1, "mul": 1, "bias": 1, "residual": 1, "relu": 1,
    "relu2": 2, "quant": 3, "dequant": 2, "rope": 6, "exp": 8,
    "gelu": 12, "tanh": 9, "softcap": 11,
    "sigmoid": 9,     # exp + add (div accounted separately)
    "silu": 10,       # sigmoid + mul (div accounted separately)
    "softmax": 12,    # max-reduce + exp + sum-reduce (div separately)
    "rmsnorm": 8,     # square + reduce + rsqrt + scale
    "layernorm": 11,
    "swiglu": 12, "geglu": 14, "glu_mul": 1,
    "topk_route": 24, "scatter": 4, "gather": 4,
    "pool": 2, "eltwise_misc": 2,
}

#: ops whose inner divide hits the non-pipelined divider (elems per divide).
DIV_OPS = {"silu": 1.0, "sigmoid": 1.0, "softmax": 1.0, "layernorm": 0.0}


@dataclasses.dataclass(frozen=True)
class VectorUnit:
    bits: int = 512
    freq_hz: float = 2.0e9
    issue: int = 2       # Saturn on the 3-issue Shuttle dual-issues vector
    div_elems_per_cycle: float = 2.0   # Saturn: element-wise, not pipelined

    @property
    def lanes(self) -> int:
        return (self.bits // 32) * self.issue    # fp32 lanes

    def cycles(self, op: str, n_elems: float) -> float:
        instrs = VECTOR_OP_INSTRS[op]
        c = n_elems / self.lanes * instrs
        if op in DIV_OPS and DIV_OPS[op] > 0:
            c += n_elems * DIV_OPS[op] / self.div_elems_per_cycle
        return c

    def cycles_for(self, vector_ops: "dict[str, float]") -> float:
        return sum(self.cycles(op, n) for op, n in vector_ops.items())


SATURN_512 = VectorUnit()


# ---------------------------------------------------------------------------
# GEMM on the matrix unit.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SimResult:
    cycles: float
    ideal_cycles: float
    breakdown: dict

    @property
    def utilization(self) -> float:
        return self.ideal_cycles / self.cycles if self.cycles else 0.0

    def seconds(self, freq_hz: float) -> float:
        return self.cycles / freq_hz


def _tile_extents(total: int, tile: int):
    full, rem = divmod(total, tile)
    return [tile] * full + ([rem] if rem else [])


def simulate_gemm(unit: MatrixUnitConfig, task: MatMulTask,
                  platform: CpuPlatform = SHUTTLE,
                  out_bytes: float = 4.0) -> SimResult:
    """Output-stationary GEMM schedule; returns matrix-unit cycles."""
    dt = task.data_type
    eb = policy(dt).bytes_per_elem
    macs_cyc = unit.macs_per_cycle(dt)
    bw_cyc = unit.bandwidth * platform.dram_efficiency / unit.freq_hz

    compute_total = 0.0
    mem_total = 0.0
    busy_total = 0.0
    n_tiles = 0
    for m_t in _tile_extents(task.m, unit.m_scp):
        for n_t in _tile_extents(task.n, unit.n_scp):
            # PE-array quantisation: partial rows/cols still occupy PEs.
            m_eff = math.ceil(m_t / unit.m_pe) * unit.m_pe
            n_eff = math.ceil(n_t / unit.n_pe) * unit.n_pe
            k_eff = math.ceil(task.k / unit.k_pe_elems(dt)) * unit.k_pe_elems(dt)
            compute = m_eff * n_eff * k_eff / macs_cyc
            bias_bytes = {BiasType.ZERO: 0.0, BiasType.ROW: n_t * 4.0,
                          BiasType.FULL: m_t * n_t * 4.0}[task.bias_type]
            mem_bytes = ((m_t + n_t) * task.k * eb
                         + m_t * n_t * out_bytes + bias_bytes)
            mem = mem_bytes / bw_cyc
            compute_total += compute
            mem_total += mem
            busy_total += max(compute, mem)   # double-buffered overlap
            n_tiles += 1

    # Pipeline fill: first chunk's load + PE pipeline depth.
    first_chunk = ((unit.m_scp + unit.n_scp) * unit.k_scp_bytes) / bw_cyc
    fill = first_chunk + unit.pe_pipeline_stages
    # CPU dispatch stream runs concurrently; expose only if it lags.
    dispatch = n_tiles * (platform.dispatch_cycles + platform.check_cycles)
    total = max(busy_total, dispatch) + fill

    ideal = task.m * task.n * task.k / macs_cyc
    return SimResult(total, ideal, {
        "compute": compute_total, "memory": mem_total, "dispatch": dispatch,
        "fill": fill, "tiles": n_tiles,
        "bound": "compute" if compute_total >= mem_total else "memory",
    })


# ---------------------------------------------------------------------------
# Layers and fused / unfused execution.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LayerTrace:
    """One fused region: GEMM(s) + the vector work around them.

    ``vector_ops`` maps op name → element count per execution.
    ``intermediate_bytes`` is the tensor that an *unfused* schedule
    round-trips through DRAM between matrix and vector phases.
    """

    name: str
    gemms: "tuple[MatMulTask, ...]"
    vector_ops: "dict[str, float]" = dataclasses.field(default_factory=dict)
    intermediate_bytes: float = 0.0
    repeat: int = 1

    def flops(self) -> float:
        return self.repeat * sum(t.flops for t in self.gemms)


def simulate_layer(unit: MatrixUnitConfig, layer: LayerTrace, *,
                   platform: CpuPlatform = SHUTTLE,
                   vector: VectorUnit = SATURN_512,
                   fused: bool = True) -> "dict[str, float]":
    """Cycles for one layer execution (matrix + vector), fused or not."""
    matrix = sum(simulate_gemm(unit, g, platform).cycles for g in layer.gemms)
    vec = vector.cycles_for(layer.vector_ops)
    bw_cyc = unit.bandwidth * platform.dram_efficiency / unit.freq_hz

    if fused:
        # Listing 1: software pipeline at matrix-tile granularity.  Steady
        # state runs the slower of the two streams; the shorter stream
        # hides.  Fill = one vector-tile epilogue exposed at the end.
        n_tiles = max(1, sum(
            math.ceil(g.m / unit.m_scp) * math.ceil(g.n / unit.n_scp)
            for g in layer.gemms))
        fill = vec / n_tiles
        cycles = max(matrix, vec) + fill
    else:
        # Unfused intermediates round-trip DRAM only beyond the L2
        # working set (small ResNet feature maps stay cached).
        spill = max(0.0, layer.intermediate_bytes - platform.l2_bytes)
        roundtrip = 2.0 * spill / bw_cyc
        cycles = matrix + vec + roundtrip
    return {"cycles": cycles * layer.repeat, "matrix": matrix * layer.repeat,
            "vector": vec * layer.repeat}


def simulate_workload(unit: MatrixUnitConfig, layers: "list[LayerTrace]", *,
                      platform: CpuPlatform = SHUTTLE,
                      vector: VectorUnit = SATURN_512,
                      fused: bool = True) -> "dict[str, float]":
    tot = {"cycles": 0.0, "matrix": 0.0, "vector": 0.0}
    for layer in layers:
        r = simulate_layer(unit, layer, platform=platform, vector=vector,
                           fused=fused)
        for k in tot:
            tot[k] += r[k]
    tot["seconds"] = tot["cycles"] / unit.freq_hz
    tot["flops"] = sum(l.flops() for l in layers)
    return tot


# ---------------------------------------------------------------------------
# Commercial baselines (Table 5): synchronous, no matrix-vector overlap.
# ---------------------------------------------------------------------------

def baseline_layer_seconds(base: CommercialBaseline, layer: LayerTrace,
                           vector: VectorUnit = SATURN_512,
                           workload: str = None) -> float:
    gemm_s = 0.0
    for g in layer.gemms:
        peak = base.int8_peak * base.sync_overhead
        t_compute = g.flops / peak
        t_mem = (g.in_bytes + g.out_bytes()) / base.bandwidth
        gemm_s += max(t_compute, t_mem)
    vec_cycles = vector.cycles_for(layer.vector_ops) / base.vector_relative
    vec_s = vec_cycles / vector.freq_hz
    spill = max(0.0, layer.intermediate_bytes - 2 * 2**20)   # server L2
    roundtrip_s = 2.0 * spill / base.bandwidth
    return ((gemm_s + vec_s + roundtrip_s) * layer.repeat
            / base.coverage(workload))


def baseline_workload_seconds(base: CommercialBaseline,
                              layers: "list[LayerTrace]",
                              vector: VectorUnit = SATURN_512,
                              workload: str = None) -> float:
    return sum(baseline_layer_seconds(base, l, vector, workload)
               for l in layers)

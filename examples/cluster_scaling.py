"""Cluster scaling: N matrix units sharing one memory loader.

    PYTHONPATH=src python examples/cluster_scaling.py [--units 8]
        [--out cluster_trace.json]

Answers the scale-out question the single-unit reproduction cannot:
what happens when N decoupled matrix units (paper §4) share memory
bandwidth?  Three experiments on the paper's GEMM regime (int8,
512 rows/unit × 512 × 8192, the Fig. 6 setup):

1. **Weak scaling, pooled bandwidth** — every unit brings its own
   memory channel into the shared pool (``ClusterTopology`` default).
   Aggregate utilization should hold >90%: contention reshuffles
   transfers but the pool keeps up.
2. **Weak scaling, fixed bandwidth** — the pool stays at one unit's
   channel.  The shared loader saturates (utilization -> 1.0) and
   aggregate matrix utilization collapses ~1/N beyond the knee: the
   CAMP observation that memory contention, not peak compute, decides
   delivered throughput.
3. **Strategy comparison** — the same 4-unit GEMM under row-panel /
   output-tile / layer-pipeline partitioning, via the registered
   ``desim-cluster`` backend, plus the ``sharded`` backend executing
   the identical partitioned graph bit-exactly against ``jax``.

The widest sweep entry's trace is exported as Chrome-trace JSON: open
it in https://ui.perfetto.dev — one process per unit, the shared
loader's overlapping transfers on pid 0 are the contention, visible.
"""

import argparse
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import backend
from repro.core.config import PLATFORM_2TOPS
from repro.core.hardware import GIGA, SHUTTLE
from repro.core.task import MatMulTask
from repro.sim import (ClusterTopology, build_gemm_graph, dump_chrome_trace,
                       partition_graph, simulate_cluster)


def weak_gemm(n_units):
    """One paper-regime GEMM per unit (rows scale with the cluster)."""
    return MatMulTask(m=512 * n_units, n=512, k=8192)


def run(n_units, total_bandwidth=None, strategy="row-panel"):
    unit = PLATFORM_2TOPS
    g, _ = build_gemm_graph(weak_gemm(n_units), unit.m_scp, unit.n_scp)
    part = partition_graph(g, n_units, strategy)
    topo = ClusterTopology(n_units=n_units, unit=unit, platform=SHUTTLE,
                           total_bandwidth=total_bandwidth)
    return part, simulate_cluster(part.graph, topo)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--units", type=int, default=8,
                    help="largest cluster in the sweep")
    ap.add_argument("--out", default="cluster_trace.json",
                    help="Chrome-trace output for the widest sweep run")
    args = ap.parse_args()
    sweep = [n for n in (1, 2, 4, 8, 16) if n <= max(args.units, 1)]

    # 1. weak scaling, pooled bandwidth -----------------------------------
    print("weak scaling, pooled loader bandwidth (n x 48 GB/s):")
    print(f"{'units':>6}{'cycles':>12}{'agg_util':>10}{'loader':>8}"
          f"{'contention':>12}{'xfers':>7}")
    base = None
    for n in sweep:
        part, r = run(n)
        base = base or r.cycles
        print(f"{n:>6}{r.cycles:>12.0f}"
              f"{r.aggregate_matrix_utilization:>10.3f}"
              f"{r.loader_utilization:>8.2f}"
              f"{r.loader_contention():>12.2f}{part.n_transfers:>7}")

    # 2. weak scaling, fixed pool: where the shared loader saturates ------
    bw = PLATFORM_2TOPS.bandwidth
    print(f"\nweak scaling, fixed {bw / GIGA:.0f} GB/s pool "
          "(the saturation curve):")
    print(f"{'units':>6}{'cycles':>12}{'agg_util':>10}{'loader':>8}"
          f"{'scaling_eff':>12}")
    for n in sweep:
        _, r = run(n, total_bandwidth=bw)
        print(f"{n:>6}{r.cycles:>12.0f}"
              f"{r.aggregate_matrix_utilization:>10.3f}"
              f"{r.loader_utilization:>8.2f}{base / r.cycles:>12.3f}")

    # 3. strategies through the registered backends -----------------------
    print("\n4-unit strategies (desim-cluster backend) + sharded parity:")
    task = MatMulTask(m=512, n=512, k=2048)
    ka, kb = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.randint(ka, (task.m, task.k), -8, 8, jnp.int8)
    b = jax.random.randint(kb, (task.k, task.n), -8, 8, jnp.int8)
    ref = np.asarray(backend.get("jax").wait(backend.get("jax").dispatch(
        task, backend.MatMulOperands(a=a, b=b))).output)
    for strategy in ("row-panel", "output-tile", "layer-pipeline"):
        eng = backend.get("desim-cluster", units=4, strategy=strategy)
        r = eng.wait(eng.dispatch(task))
        sh = backend.get("sharded", units=4, strategy=strategy)
        out = np.asarray(sh.wait(sh.dispatch(
            task, backend.MatMulOperands(a=a, b=b))).output)
        exact = bool((out == ref).all())
        print(f"  {strategy:<16} cycles={r.cycles:>9.0f} "
              f"agg_util={r.utilization:.3f} "
              f"xfers={r.detail['partition']['transfers']:>3} "
              f"sharded==jax: {exact}")

    # 4. trace export ------------------------------------------------------
    widest = max(sweep)
    _, rw = run(widest)
    path = dump_chrome_trace(rw, args.out,
                             process_name=f"cutev2-cluster x{widest}")
    print(f"\nwrote {widest}-unit trace to {path} - open in "
          "https://ui.perfetto.dev (one process per unit; the "
          "overlapping mem_loader events are the shared-bandwidth "
          "contention)")


if __name__ == "__main__":
    main()

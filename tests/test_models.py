"""Per-architecture smoke tests (assignment requirement) + serving
consistency: every arch instantiates a REDUCED config, runs one forward /
train step on CPU, asserts shapes + finiteness; prefill/decode chains
match the full-sequence forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import (ALL_ARCHS, concrete_batch, get_config)
from repro.models.base import family_module


def _cfg(name):
    return get_config(name, reduced=True).with_(
        remat="none", dtype=jnp.float32, kv_cache_dtype=jnp.float32)


@pytest.fixture(scope="module")
def built():
    out = {}
    for name in ALL_ARCHS:
        cfg = _cfg(name)
        mod = family_module(cfg)
        params = mod.init(cfg, jax.random.PRNGKey(0))
        out[name] = (cfg, mod, params)
    return out


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_shapes_and_finite(built, name):
    cfg, mod, params = built[name]
    batch = concrete_batch(cfg, 2, 24, "train")
    logits = jax.jit(lambda p, b: mod.forward(cfg, p, b))(params, batch)
    assert logits.shape == (2, 24, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_one_train_step_no_nans(built, name):
    from repro.optim import adamw
    from repro.training.train_step import TrainConfig, make_train_step
    cfg, mod, params = built[name]
    tcfg = TrainConfig(loss_chunk=8)
    step = make_train_step(cfg, tcfg)
    opt = adamw.init(tcfg.optimizer, params)
    batch = concrete_batch(cfg, 2, 16, "train")
    params2, opt2, metrics, _ = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_matches_forward(built, name):
    cfg, mod, params = built[name]
    batch = concrete_batch(cfg, 2, 24, "train")
    logits = mod.forward(cfg, params, batch)
    cache = mod.init_cache(cfg, 2, 48)
    pb = {k: v for k, v in batch.items() if k != "labels"}
    last, _ = jax.jit(lambda p, b, c: mod.prefill(cfg, p, b, c))(
        params, pb, cache)
    ref = logits[:, -1]
    rel = float(jnp.abs(last - ref).max() / (jnp.abs(ref).max() + 1e-9))
    assert rel < 5e-3, rel


@pytest.mark.parametrize("name", ["gemma2-2b", "rwkv6-7b",
                                  "recurrentgemma-2b", "whisper-tiny",
                                  "olmoe-1b-7b"])
def test_decode_chain_matches_forward(built, name):
    """prefill(S) + decode×3 logits == forward(S+3) at those positions."""
    cfg, mod, params = built[name]
    s, extra = 16, 3
    full = concrete_batch(cfg, 2, s + extra, "train")
    logits_full = mod.forward(cfg, params, full)

    prompt = {k: (v[:, :s] if k in ("tokens", "labels") else v)
              for k, v in full.items() if k != "labels"}
    cache = mod.init_cache(cfg, 2, s + extra + 1)
    last, cache = mod.prefill(cfg, params, prompt, cache)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(logits_full[:, s - 1]),
                               rtol=2e-3, atol=2e-3)
    for i in range(extra):
        tok = full["tokens"][:, s + i: s + i + 1]
        last, cache = mod.decode_step(cfg, params, tok, cache, s + i)
        np.testing.assert_allclose(np.asarray(last),
                                   np.asarray(logits_full[:, s + i]),
                                   rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("name", ["yi-6b"])
def test_pallas_backend_matches_xla(built, name):
    """Attention backend equivalence on a dense llama-arch model."""
    cfg, mod, params = built[name]
    batch = concrete_batch(cfg, 1, 32, "train")
    ref = mod.forward(cfg, params, batch)
    cfg_p = cfg.with_(backend="pallas")
    out = family_module(cfg_p).forward(cfg_p, params, batch)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_param_counts_match_published_order():
    """Full configs land in the right parameter-count ballpark."""
    expect = {
        "gemma2-2b": (2.0e9, 3.5e9),
        "gemma2-27b": (24e9, 30e9),
        "deepseek-67b": (60e9, 72e9),
        "yi-6b": (5.5e9, 7e9),
        "internvl2-1b": (0.4e9, 0.8e9),     # Qwen2-0.5B backbone
        "rwkv6-7b": (6e9, 8.5e9),
        "olmoe-1b-7b": (6e9, 8e9),
        "arctic-480b": (400e9, 520e9),
        "whisper-tiny": (0.02e9, 0.08e9),
        "recurrentgemma-2b": (2.2e9, 3.2e9),
    }
    for name, (lo, hi) in expect.items():
        n = get_config(name).param_count()
        assert lo <= n <= hi, (name, n)


def test_moe_active_param_count():
    cfg = get_config("olmoe-1b-7b")
    active = cfg.param_count(active_only=True)
    total = cfg.param_count()
    assert active < total / 4          # 8 of 64 experts active

#!/usr/bin/env python
"""Docs CI: markdown link check + doctest of runnable snippets.

Usage (what the CI docs job runs)::

    PYTHONPATH=src python scripts/check_docs.py

* **Link check** — every relative markdown link / image in README.md,
  ROADMAP.md and docs/*.md must resolve to an existing file (anchors
  are stripped; ``http(s)://`` and ``mailto:`` links are skipped —
  no network in CI).
* **Doctest** — every ``>>>`` example in docs/*.md runs via
  :mod:`doctest`, so the documented snippets cannot rot away from the
  code.  stdlib only; exit status is non-zero on any failure.
"""

from __future__ import annotations

import doctest
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
#: inline markdown links/images: [text](target) — (nested parens not used
#: in this repo's docs).
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
_SKIP = ("http://", "https://", "mailto:")


def check_links(paths: "list[pathlib.Path]") -> "list[str]":
    errors = []
    for path in paths:
        text = path.read_text()
        # fenced code blocks may contain ](...)-shaped noise; drop them.
        prose = re.sub(r"```.*?```", "", text, flags=re.S)
        for m in _LINK.finditer(prose):
            target = m.group(1).split("#", 1)[0]
            if not target or target.startswith(_SKIP):
                continue
            resolved = (path.parent / target).resolve()
            if not resolved.exists():
                errors.append(f"{path.relative_to(ROOT)}: broken link "
                              f"-> {m.group(1)}")
    return errors


def run_doctests(paths: "list[pathlib.Path]") -> "list[str]":
    errors = []
    for path in paths:
        fails, tests = doctest.testfile(str(path), module_relative=False,
                                        optionflags=doctest.ELLIPSIS)
        label = path.relative_to(ROOT)
        print(f"doctest {label}: {tests} examples, {fails} failures")
        if fails:
            errors.append(f"{label}: {fails} doctest failure(s)")
    return errors


def main() -> int:
    md = [ROOT / "README.md", ROOT / "ROADMAP.md"]
    docs = sorted((ROOT / "docs").glob("*.md"))
    if not docs:
        print("no docs/*.md found", file=sys.stderr)
        return 1
    errors = check_links(md + docs)
    print(f"link check: {len(md + docs)} files, {len(errors)} broken")
    errors += run_doctests(docs)
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())

"""Tracked benchmark recorder — the committed ``BENCH_*.json`` trajectory.

Where ``benchmarks/run.py`` prints ephemeral CSV rows, this harness
writes schema-versioned JSON snapshots meant to be **committed**:

* ``BENCH_serving.json`` — the serving queue (``run.serving_queue``)
  priced by the contention-aware analytical closed form, one entry per
  ``policy|u<units>|<overlap>``: makespan, TTFT/ITL percentiles,
  aggregate matrix utilization.  Plus the **online closed-loop** rows
  (``online|policy|q<qps>``: sustained-load TTFT/ITL/goodput under
  seeded Poisson traffic; ``online-sat|policy``: the saturation knee),
  so CI gates online-serving drift too.
* ``BENCH_cluster.json`` — DES weak scaling on the paper GEMM regime
  (512 rows × 512 × 8192 per unit, int8): aggregate utilization, loader
  utilization, scaling efficiency per unit count.

Every entry separates ``metrics`` (deterministic simulated quantities —
regression-checked by ``scripts/check_bench.py`` against the committed
baseline, >10% drift in the bad direction fails CI) from ``info``
(wall-clock and environment noise — recorded, never compared).  The
cluster snapshot also carries the measured **metrics-collection
overhead** on the DES path (registry enabled vs disabled around the
instrumented ``run_graph``), the <5% budget the obs subsystem promises.

Run:  PYTHONPATH=src python -m benchmarks.record [--quick] [--out-dir D]
"""

from __future__ import annotations

import argparse
import json
import os
import time

SCHEMA_VERSION = 1

#: serving sweep: (policy, units, overlap, in_quick).  The --quick CI
#: subset must produce *identical* values for the entries it keeps, so
#: it selects rows rather than shrinking the workload.
SERVING_POINTS = [
    ("full-prefill", 1, "chained", True),
    ("full-prefill", 2, "chained", False),
    ("chunked-prefill", 1, "chained", False),
    ("chunked-prefill", 2, "chained", True),
    ("decode-priority", 1, "chained", False),
    ("decode-priority", 2, "chained", True),
    ("decode-priority", 2, "relaxed", True),
]

#: cluster weak-scaling unit counts (quick keeps the starred subset).
CLUSTER_UNITS = [(1, True), (2, True), (4, False)]

SERVING_METRICS = ("makespan", "ttft_p50", "ttft_p99", "itl_p50",
                   "itl_p99", "matrix_utilization", "workload_cycles")

#: online closed-loop sustained-load points: (policy, offered qps,
#: in_quick).  Fixed-seed Poisson traffic + analytical epoch execution
#: (benchmarks.run.ONLINE_TRAFFIC/ONLINE_ENGINE), so values are
#: deterministic and the --quick row gates online-serving drift in CI.
ONLINE_POINTS = [
    ("full-prefill", 2e4, True),
    ("full-prefill", 2e5, False),
    ("chunked-prefill", 2e4, False),
    ("decode-priority", 2e4, False),
]

#: saturation-knee rows per policy (full runs only — each is a
#: geometric sweep of closed-loop runs).
ONLINE_SATURATION = ["full-prefill", "chunked-prefill",
                     "decode-priority"]

ONLINE_METRICS = ("ttft_p50", "ttft_p99", "itl_p50", "itl_p99",
                  "goodput_qps", "makespan", "preemptions")

#: KV-pressure rows: the deterministic staggered burst (8 requests,
#: 32..48-token prompts, one arrival per 4000 cycles) decoded by the
#: closed loop on the DES execute path under decode-priority, with a
#: hot pool of 10 × 8-token blocks — smaller than the aggregate working
#: set, so eviction churn and refill pricing are exercised.  All rows
#: ride the --quick CI subset (the ``kv`` job gates them).
KV_POOL = dict(kv_hot_blocks=10, kv_block_tokens=8)
KV_TRAFFIC = dict(gap=4000.0, n=8,
                  prompt_lengths=(32, 40, 32, 48, 32, 40, 32, 48))
KV_ENGINE = dict(max_batch=4, max_new_tokens=16, policy="decode-priority",
                 execute_backend="desim")

#: tuned-dispatch decode-regime rows: (platform, in_quick).  Two
#: platforms with distinct dispatch models (RoCC in-order shuttle, CSR
#: OoO kunminghu) gate the tuned win in CI; the other two ride the full
#: recording.
TUNED_POINTS = [("shuttle", True), ("kunminghu", True),
                ("rocket", False), ("boom", False)]

#: cluster-DES makespans of the four (tuned × fused) corners plus the
#: derived speedups (higher-better in check_bench).  ``speedup`` is the
#: pinned end-to-end win: tuned-fused vs untuned-unfused.
TUNED_METRICS = ("tuned", "untuned", "tuned_unfused", "untuned_unfused",
                 "speedup", "tuned_speedup", "fusion_speedup")


def record_serving(quick: bool, backend_name: str = "analytical") -> dict:
    from benchmarks.run import require_units_support, serving_queue
    from repro.serving.scheduler import schedule_metrics

    cfg, eng = serving_queue()
    entries: "dict[str, dict]" = {}
    for policy, units, overlap, in_quick in SERVING_POINTS:
        if quick and not in_quick:
            continue
        # a u2 row priced by a single-unit backend would silently record
        # a wrong baseline — refuse the row instead of degrading it.
        require_units_support(backend_name, units)
        t0 = time.perf_counter()
        sched = eng.plan(max_new_tokens=16, units=units, policy=policy,
                         overlap=overlap)
        m = schedule_metrics(sched, cfg.n_layers, backend_name)
        wall = time.perf_counter() - t0
        entries[f"{policy}|u{units}|{overlap}"] = {
            "metrics": {k: m[k] for k in SERVING_METRICS},
            "info": {"wall_s": round(wall, 4), "steps": len(sched.steps)},
        }
    entries.update(record_online(quick))
    entries.update(record_tuned(quick))
    entries.update(record_kv(quick))
    return {
        "schema_version": SCHEMA_VERSION,
        "bench": "serving",
        "config": {"model": "yi-6b-reduced", "n_requests": 6,
                   "max_batch": 2, "max_new_tokens": 16,
                   "backend": backend_name,
                   "online": {"traffic": "poisson seed=0",
                              "execute_backend": "analytical",
                              "max_new_tokens": 8},
                   "tuned": {"regime": "decode-priority u2",
                             "backend": "desim-cluster"},
                   "kv": {"traffic": "deterministic gap=4000 n=8",
                          "pool": "10 x 8-token hot blocks",
                          "execute_backend": "desim"}},
        "entries": entries,
    }


def record_kv(quick: bool) -> "dict[str, dict]":
    """The KV-pressure rows: the same closed loop run three ways —
    unlimited KV, a small hot pool with the residency-aware
    decode-priority policy, and the same pool with residency scoring
    disabled.  Pins the two headline effects as tracked metrics: the
    pool makes the DES makespan visibly exceed the unlimited baseline
    (``pressure_ratio``), and residency-aware batching beats blind on
    decode p50 (``residency_speedup``, higher-better)."""
    del quick                       # all three rows ride the CI subset
    from repro.configs.registry import get_config
    from repro.serving.arrivals import DeterministicArrivals
    from repro.serving.online import OnlineServingEngine

    cfg = get_config("yi-6b", reduced=True)

    def run(**kv):
        t0 = time.perf_counter()
        eng = OnlineServingEngine(cfg, **KV_ENGINE, **kv)
        res = eng.run(DeterministicArrivals(**KV_TRAFFIC))
        return eng, res, round(time.perf_counter() - t0, 4)

    _, base, w0 = run()
    hot_eng, hot, w1 = run(**KV_POOL)
    _, blind, w2 = run(**KV_POOL, policy_kw={"residency_aware": False})
    stats = {r: res.ttft_stats() for r, res in
             (("base", base), ("hot", hot), ("blind", blind))}
    c = hot_eng.kv_cache.counters
    return {
        "kv|unlimited": {
            "metrics": {"makespan": base.makespan,
                        "ttft_p50": stats["base"]["ttft_p50"],
                        "itl_p50": stats["base"]["itl_p50"]},
            "info": {"wall_s": w0, "completed": len(base.requests)},
        },
        "kv|pressured": {
            "metrics": {"makespan": hot.makespan,
                        "ttft_p50": stats["hot"]["ttft_p50"],
                        "itl_p50": stats["hot"]["itl_p50"],
                        "pressure_ratio": hot.makespan / base.makespan,
                        "evictions": float(c["evictions"]),
                        "refill_bytes": c["refill_bytes"]},
            "info": {"wall_s": w1, "completed": len(hot.requests),
                     "trace_digest": hot_eng.kv_cache.trace_digest()},
        },
        "kv|residency": {
            "metrics": {"blind_itl_p50": stats["blind"]["itl_p50"],
                        "residency_speedup": (stats["blind"]["itl_p50"]
                                              / stats["hot"]["itl_p50"])},
            "info": {"wall_s": w2, "completed": len(blind.requests)},
        },
    }


def record_tuned(quick: bool) -> "dict[str, dict]":
    """The tuned-dispatch rows: per platform, the cluster-DES makespans
    of the canonical Llama-style decode regime at the four (tuned ×
    fused) corners, with the epilogue-fusion contribution isolated
    (``fusion_speedup`` = tuned-unfused / tuned-fused).  Deterministic —
    fixed queue, fixed plan, committed tuning caches — so the speedups
    are gated exactly like every other tracked metric."""
    from repro.tune.regime import measure_decode_regime

    entries: "dict[str, dict]" = {}
    for plat, in_quick in TUNED_POINTS:
        if quick and not in_quick:
            continue
        t0 = time.perf_counter()
        m = measure_decode_regime(plat)
        wall = time.perf_counter() - t0
        entries[f"tuned|decode|{plat}"] = {
            "metrics": {k: m[k] for k in TUNED_METRICS},
            "info": {"wall_s": round(wall, 4)},
        }
    return entries


def record_online(quick: bool) -> "dict[str, dict]":
    """The closed-loop sustained-load rows: one entry per
    (policy × offered QPS) point plus a saturation-knee entry per
    policy (full runs only).  Deterministic by construction — seeded
    Poisson arrivals, analytical epoch execution — so
    ``scripts/check_bench.py`` gates them exactly like the offline
    rows."""
    from benchmarks.run import ONLINE_ENGINE, ONLINE_TRAFFIC
    from repro.configs.registry import get_config
    from repro.serving.online import find_saturation, qps_sweep

    cfg = get_config("yi-6b", reduced=True)
    entries: "dict[str, dict]" = {}
    for policy, qps, in_quick in ONLINE_POINTS:
        if quick and not in_quick:
            continue
        t0 = time.perf_counter()
        row = qps_sweep(cfg, [qps], policy=policy,
                        **ONLINE_TRAFFIC, **ONLINE_ENGINE)[0]
        wall = time.perf_counter() - t0
        entries[f"online|{policy}|q{qps:.0e}"] = {
            "metrics": {k: row[k] for k in ONLINE_METRICS},
            "info": {"wall_s": round(wall, 4),
                     "epochs": row["epochs"],
                     "completed": row["completed"]},
        }
    if not quick:
        for policy in ONLINE_SATURATION:
            t0 = time.perf_counter()
            sat = find_saturation(cfg, start_qps=1e4, factor=4.0,
                                  max_points=6, policy=policy,
                                  **ONLINE_TRAFFIC, **ONLINE_ENGINE)
            wall = time.perf_counter() - t0
            entries[f"online-sat|{policy}"] = {
                "metrics": {"knee_qps": sat["knee_qps"],
                            "peak_goodput_qps": sat["peak_goodput_qps"]},
                "info": {"wall_s": round(wall, 4),
                         "saturated": sat["saturated"],
                         "points": len(sat["points"])},
            }
    return entries


def record_cluster(quick: bool) -> dict:
    from repro.core.config import PLATFORM_2TOPS
    from repro.core.hardware import SHUTTLE
    from repro.core.task import MatMulTask
    from repro.sim import (ClusterTopology, build_gemm_graph,
                           partition_graph, simulate_cluster)

    unit = PLATFORM_2TOPS
    entries: "dict[str, dict]" = {}
    base = None
    for n, in_quick in CLUSTER_UNITS:
        if quick and not in_quick:
            continue
        t0 = time.perf_counter()
        g, _ = build_gemm_graph(MatMulTask(m=512 * n, n=512, k=8192),
                                unit.m_scp, unit.n_scp)
        part = partition_graph(g, n, "row-panel")
        topo = ClusterTopology(n_units=n, unit=unit, platform=SHUTTLE)
        r = simulate_cluster(part.graph, topo)
        wall = time.perf_counter() - t0
        base = base if base is not None else r.cycles
        entries[f"weak|u{n}"] = {
            "metrics": {
                "cycles": r.cycles,
                "aggregate_matrix_utilization":
                    r.aggregate_matrix_utilization,
                "loader_utilization": r.loader_utilization,
                "scaling_efficiency": base / r.cycles,
            },
            "info": {"wall_s": round(wall, 4)},
        }
    return {
        "schema_version": SCHEMA_VERSION,
        "bench": "cluster",
        "config": {"gemm": "512*n x 512 x 8192 int8 per unit",
                   "strategy": "row-panel", "platform": "shuttle"},
        "entries": entries,
        "info": {"obs_overhead": measure_obs_overhead()},
    }


def measure_obs_overhead(repeats: int = 3) -> dict:
    """Wall-clock cost of metrics collection on the DES path: the same
    ``desim`` ``run_graph`` timed with the default registry disabled
    (the production default) and enabled.  The instrument decorator adds
    one timer around the whole simulation, so the fraction should be
    deep inside the <5% budget; the recorded number keeps it honest."""
    from repro import backend
    from repro.core.config import PLATFORM_2TOPS
    from repro.core.task import MatMulTask
    from repro.obs import default_registry

    eng = backend.get("desim")
    graph = eng.lower(MatMulTask(m=512, n=512, k=2048))
    reg = default_registry()
    was_enabled = reg.enabled

    def best_of(runs: int) -> float:
        best = float("inf")
        for _ in range(runs):
            t0 = time.perf_counter()
            eng.run_graph(graph)
            best = min(best, time.perf_counter() - t0)
        return best

    eng.run_graph(graph)                     # warm caches either way
    try:
        reg.disable()
        t_off = best_of(repeats)
        reg.enable()
        t_on = best_of(repeats)
    finally:
        reg.enabled = was_enabled
    frac = (t_on - t_off) / t_off if t_off > 0 else 0.0
    return {"disabled_s": round(t_off, 4), "enabled_s": round(t_on, 4),
            "overhead_frac": round(frac, 4), "budget_frac": 0.05}


def record_kernels() -> dict:
    """Wall-clock of the fused Pallas kernel (interpret mode on CPU) —
    pure ``info``: host timing is environment noise, never
    regression-checked, but worth a trajectory."""
    import jax
    import jax.numpy as jnp
    from repro.core.fusion import Epilogue
    from repro.kernels.matmul.ops import fused_matmul

    a = jax.random.normal(jax.random.PRNGKey(0), (256, 512), jnp.bfloat16)
    b = jax.random.normal(jax.random.PRNGKey(1), (512, 512), jnp.bfloat16)
    ep = Epilogue(activation="gelu", out_dtype=jnp.bfloat16)
    fused_matmul(a, b, epilogue=ep,
                 block_shape=(128, 128, 128)).block_until_ready()
    t0 = time.perf_counter()
    fused_matmul(a, b, epilogue=ep,
                 block_shape=(128, 128, 128)).block_until_ready()
    return {"fused_matmul_interpret_s": round(time.perf_counter() - t0, 4)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="the CI subset: fewer sweep points, identical "
                         "values for the entries it keeps")
    ap.add_argument("--out-dir", default=".",
                    help="directory for BENCH_*.json (default: cwd — "
                         "the repo root, where baselines are committed)")
    ap.add_argument("--only", choices=("serving", "cluster"), default=None)
    ap.add_argument("--skip-kernels", action="store_true",
                    help="skip the wall-clock kernel info row")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    written = []
    if args.only in (None, "serving"):
        doc = record_serving(args.quick)
        if not args.skip_kernels:
            doc["info"] = {"kernels": record_kernels()}
        path = os.path.join(args.out_dir, "BENCH_serving.json")
        with open(path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        written.append((path, len(doc["entries"])))
    if args.only in (None, "cluster"):
        doc = record_cluster(args.quick)
        path = os.path.join(args.out_dir, "BENCH_cluster.json")
        with open(path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        ov = doc["info"]["obs_overhead"]
        print(f"obs overhead on DES path: {ov['overhead_frac']:+.2%} "
              f"(budget {ov['budget_frac']:.0%})")
        written.append((path, len(doc["entries"])))
    for path, n in written:
        print(f"wrote {path} ({n} entries)")


if __name__ == "__main__":
    main()

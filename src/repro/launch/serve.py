"""Serving launcher: batched generation over the async engine.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --reduced \\
        --requests 6 --max-new 16

``--plan BACKEND`` prices the queued batch schedule on a modelling
backend from the ``repro.backend`` registry before serving: the queue is
lowered through ``workload_to_graph`` and run on e.g. ``desim`` for a
per-resource timeline — evaluate a batching policy (``--max-batch``)
without touching hardware.  The plan ends with a one-screen summary
table: TTFT/ITL percentiles, makespan, per-unit matrix utilization and
the request-span audit from the obs subsystem.

``--metrics-out PATH`` switches the process-wide metrics registry on
(it is off by default everywhere else) and writes its snapshot on exit —
JSON, or Prometheus text exposition when PATH ends in ``.prom``.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ALL_ARCHS, get_config
from repro.models.base import family_module
from repro.serving.engine import ServingEngine


def _table(rows: "list[tuple[str, str]]") -> str:
    w = max(len(k) for k, _ in rows)
    bar = "  " + "-" * (w + 24)
    body = "\n".join(f"  {k:<{w}}  {v}" for k, v in rows)
    return f"{bar}\n{body}\n{bar}"


def _span_audit_row(span_log) -> "tuple[str, str]":
    bad = span_log.validate()
    return ("request spans",
            f"{len(span_log)} across {len(span_log.requests())} requests"
            + ("" if not bad else f"  ({len(bad)} VIOLATIONS)"))


def _online_summary(res, policy: str, slo_cycles) -> str:
    """The closed-loop scoreboard: the plan table's latency rows plus
    the online-only goodput / preemption / eviction counters."""
    s = res.summary(slo_cycles)
    rows = [
        ("policy", policy),
        ("requests (completed)",
         f"{len(res.requests)} ({len(res.completed())})"),
        ("admission epochs", f"{len(res.epochs)}"),
        ("TTFT p50 / p99",
         f"{s['ttft_p50']:.0f} / {s['ttft_p99']:.0f} cyc"),
        ("ITL  p50 / p99",
         f"{s['itl_p50']:.0f} / {s['itl_p99']:.0f} cyc"),
        ("makespan", f"{s['makespan']:.0f} cyc"),
        ("goodput", f"{s['goodput_qps']:.0f} req/s"
         + ("" if slo_cycles is None
            else f" (TTFT p99 SLO {slo_cycles:.0f} cyc)")),
        ("preemptions / evictions",
         f"{res.n_preemptions} / {res.n_evictions}"),
        _span_audit_row(res.span_log),
    ]
    return _table(rows)


def _plan_summary(stats: dict, res, sched, span_log) -> str:
    """The one-screen plan scoreboard: latency percentiles, makespan,
    per-unit matrix utilization, span-chain audit."""
    rows = [
        ("policy / overlap", f"{sched.policy} / {sched.overlap}"),
        ("steps (prefill)",
         f"{len(sched.steps)} "
         f"({sum(s.kind == 'prefill' for s in sched.steps)})"),
        ("TTFT p50 / p99",
         f"{stats['ttft_p50']:.0f} / {stats['ttft_p99']:.0f} cyc"),
        ("ITL  p50 / p99",
         f"{stats['itl_p50']:.0f} / {stats['itl_p99']:.0f} cyc"),
        ("makespan", f"{stats['makespan']:.0f} cyc"),
    ]
    per_unit = {}
    if res.timeline is not None:
        for rname, u in res.timeline.utilizations().items():
            head, _, rest = rname.partition("/")
            if rest == "pe_array" and head[:1] == "u" and \
                    head[1:].isdigit():
                per_unit[int(head[1:])] = u
    for i in sorted(per_unit):
        rows.append((f"unit {i} matrix util", f"{per_unit[i]:.1%}"))
    if not per_unit:
        rows.append(("matrix util", f"{res.utilization:.1%}"))
    if span_log is not None:
        rows.append(_span_audit_row(span_log))
    return _table(rows)


def _write_metrics(reg, path: str) -> None:
    import json
    if path.endswith(".prom"):
        payload = reg.prometheus_text()
    else:
        payload = json.dumps(reg.snapshot(), indent=2,
                             sort_keys=True) + "\n"
    with open(path, "w") as f:
        f.write(payload)
    reg.disable()
    print(f"metrics snapshot -> {path}")


def _run_online(args, cfg, reg) -> None:
    """The ``--qps`` / ``--arrival-trace`` closed-loop path: streaming
    admission + per-epoch re-planning on the modelling backends (no
    weights are instantiated — this is the planning loop, grounded on
    the DES execution path)."""
    from repro.core.config import CASE_STUDY
    from repro.serving.arrivals import (PoissonArrivals, TraceArrivals,
                                        qps_to_gap)
    from repro.serving.online import OnlineServingEngine
    freq = CASE_STUDY.freq_hz
    slo = (None if args.slo_ttft_p99_ms is None
           else args.slo_ttft_p99_ms * 1e-3 * freq)
    if args.arrival_trace is not None:
        src = TraceArrivals(args.arrival_trace)
        offered = "trace"
    else:
        src = PoissonArrivals(mean_gap=qps_to_gap(args.qps, freq),
                              n=args.requests, seed=0)
        offered = f"{args.qps:.0f} req/s"
    execute = args.plan or "desim"
    try:
        eng = OnlineServingEngine(
            cfg, max_batch=args.max_batch, max_new_tokens=args.max_new,
            units=args.plan_units, policy=args.policy,
            overlap=args.overlap, execute_backend=execute,
            ttft_p99_slo=slo, metrics=reg)
        t0 = time.perf_counter()
        res = eng.run(src)
        dt = time.perf_counter() - t0
    except (KeyError, ValueError, OSError) as e:
        raise SystemExit(f"online serving: {e}")
    print(f"[online:{execute}] offered={offered} policy={args.policy}: "
          f"{len(res.completed())}/{len(res.requests)} requests over "
          f"{len(res.epochs)} admission epochs in {dt:.2f}s wall")
    print(_online_summary(res, args.policy, slo))
    if reg is not None and args.metrics_out:
        _write_metrics(reg, args.metrics_out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS, default="yi-6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--plan", default=None, metavar="BACKEND",
                    help="price the batch schedule on a modelling backend "
                         "('desim', 'analytical' or 'desim-cluster') "
                         "before serving")
    ap.add_argument("--plan-granularity", default="tile",
                    choices=("tile", "panel", "layer"))
    ap.add_argument("--plan-units", type=int, default=1,
                    help="cluster width for --plan: shard every schedule "
                         "step across N matrix units sharing the memory "
                         "loader (use with --plan desim-cluster or the "
                         "contention-aware analytical form)")
    ap.add_argument("--plan-strategy", default=None,
                    choices=("row-panel", "output-tile", "layer-pipeline",
                             "unit-affinity"),
                    help="partition strategy for a cluster --plan "
                         "(serving GEMMs are wide and short: "
                         "'output-tile' shards their large N dimension; "
                         "'unit-affinity' follows the policy's per-step "
                         "placement hints)")
    ap.add_argument("--policy", default="full-prefill",
                    help="serving batching policy for --plan: "
                         "'full-prefill', 'chunked-prefill', "
                         "'decode-priority', or 'auto' (price every "
                         "policy x partition x overlap candidate with "
                         "the analytical closed form and pick the best)")
    ap.add_argument("--overlap", default="chained",
                    choices=("chained", "relaxed"),
                    help="schedule lowering mode for --plan: 'chained' "
                         "serialises every step, 'relaxed' keeps only "
                         "true per-request hazards so steps on disjoint "
                         "units overlap (ignored by --policy auto, "
                         "which sweeps both)")
    ap.add_argument("--arrival-gap", type=float, default=0.0,
                    metavar="CYCLES",
                    help="inter-request arrival gap in cycles: request i "
                         "arrives at i*GAP, so --plan reports TTFT under "
                         "load instead of the all-at-t=0 lower bound")
    ap.add_argument("--qps", type=float, default=None,
                    help="run the ONLINE closed loop instead of the "
                         "offline plan: seeded Poisson arrivals at this "
                         "offered requests/second rate feed streaming "
                         "admission + per-epoch re-planning "
                         "(repro.serving.online)")
    ap.add_argument("--arrival-trace", default=None, metavar="PATH",
                    help="online mode driven by a JSONL arrival trace "
                         "(one {\"time\": cycles, \"prompt_len\": n} "
                         "object per line) instead of --qps")
    ap.add_argument("--slo-ttft-p99-ms", type=float, default=None,
                    metavar="MS",
                    help="p99 TTFT target in milliseconds: online "
                         "planning goes through the 'auto-slo' sweep "
                         "(cheapest candidate meeting the target) and "
                         "goodput counts only SLO-meeting completions")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="enable the obs metrics registry for this run "
                         "and write its snapshot to PATH on exit (JSON, "
                         "or Prometheus text when PATH ends in .prom)")
    args = ap.parse_args(argv)

    reg = None
    if args.metrics_out:
        from repro.obs import enable_metrics
        reg = enable_metrics()

    cfg = get_config(args.arch, reduced=args.reduced)
    if args.reduced:
        cfg = cfg.with_(dtype=jnp.float32, remat="none",
                        kv_cache_dtype=jnp.float32)

    if args.qps is not None or args.arrival_trace is not None:
        _run_online(args, cfg, reg)
        return
    mod = family_module(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))

    eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                        cache_len=256)
    key = jax.random.PRNGKey(1)
    for i in range(args.requests):
        n = 4 + (i * 3) % 12
        key, sub = jax.random.split(key)
        eng.submit(jax.random.randint(sub, (n,), 0, cfg.vocab_size),
                   arrival_time=i * args.arrival_gap)
    if args.plan:
        from repro.serving.scheduler import (decode_latency_stats,
                                             price_steps)
        plan_kw = {}
        if args.plan_strategy is not None:
            plan_kw["strategy"] = args.plan_strategy
        try:
            # one pricing pass: the per-step costs feed both the
            # latency stats and the full-schedule total (their sum).
            sched, res = eng.evaluate_schedule(
                args.plan, max_new_tokens=args.max_new,
                units=args.plan_units, policy=args.policy,
                overlap=args.overlap,
                granularity=args.plan_granularity, workload=False,
                **plan_kw)
            step_cycles = price_steps(sched, args.plan,
                                      granularity=args.plan_granularity,
                                      **plan_kw)
            stats = decode_latency_stats(sched, step_cycles,
                                         cfg.n_layers)
        except (KeyError, TypeError, ValueError) as e:
            ap.error(f"--plan: {e}")
        full = sum(step_cycles)
        full_us = full * res.seconds / res.cycles * 1e6
        print(f"[plan:{args.plan}] policy={sched.policy}: "
              f"{len(sched.steps)} steps "
              f"({sum(s.kind == 'prefill' for s in sched.steps)} prefill"
              + (f", {sched.units} units" if sched.units > 1 else "")
              + f"), graph slice {res.cycles:.0f} cyc "
              f"(matrix_util={res.utilization:.1%}); full schedule "
              f"{full:.0f} cyc = {full_us:.1f} us")
        print(f"[plan:{args.plan}] TTFT (first token from arrival) "
              f"p50={stats['ttft_p50']:.0f} cyc "
              f"p99={stats['ttft_p99']:.0f} cyc, inter-token "
              f"p50={stats['itl_p50']:.0f} cyc, "
              f"overlap={sched.overlap} "
              f"makespan={stats['makespan']:.0f} cyc")
        if res.timeline is not None:
            utils = " ".join(f"{k}={v:.1%}"
                             for k, v in res.timeline.utilizations().items())
            print(f"[plan:{args.plan}] per-resource utilization: {utils}")
        print(_plan_summary(stats, res, sched,
                            res.detail.get("span_log")))
    t0 = time.perf_counter()
    outs = eng.run(max_new_tokens=args.max_new,
                   temperature=args.temperature)
    dt = time.perf_counter() - t0
    tok = sum(int(o.shape[0]) for o in outs)
    print(f"served {len(outs)} requests, {tok} tokens "
          f"in {dt:.2f}s ({tok / dt:.1f} tok/s)")
    for i, o in enumerate(outs):
        print(f"  req{i}: {list(map(int, o))}")
    if reg is not None:
        _write_metrics(reg, args.metrics_out)


if __name__ == "__main__":
    main()

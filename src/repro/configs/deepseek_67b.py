"""deepseek-67b [dense]: 95L d=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.

Llama architecture: pre-RMSNorm, SwiGLU, RoPE GQA.  [arXiv:2401.02954; hf]
"""

from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-67b",
    family="transformer",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=102400,
    rope_theta=1e4,
    mlp_activation="silu",
    mlp_glu=True,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(n_layers=3, d_model=96, n_heads=6, n_kv_heads=2,
                        head_dim=16, d_ff=192, vocab_size=512, attn_chunk=32)

"""Naive per-token scan oracle for RWKV-6 WKV."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_ref(r, k, v, lw, u, initial_state=None):
    """r/k/v/lw: (B, H, T, C); u: (H, C).  Returns (o, final_state).

    o: (B, H, T, C); state: (B, H, C, C) with S[c_k, c_v] layout.
    """
    b, h, t, c = r.shape
    if initial_state is None:
        initial_state = jnp.zeros((b, h, c, c), jnp.float32)

    def step(s, inp):
        r_t, k_t, v_t, lw_t = inp                      # (B, H, C) each
        kv = k_t[..., :, None] * v_t[..., None, :]     # (B, H, C, C)
        s_eff = s + u[None, :, :, None] * kv
        o_t = jnp.einsum("bhc,bhcd->bhd", r_t, s_eff)
        s = jnp.exp(lw_t)[..., :, None] * s + kv
        return s, o_t

    xs = tuple(jnp.moveaxis(x.astype(jnp.float32), 2, 0) for x in (r, k, v, lw))
    final, o = jax.lax.scan(step, initial_state, xs)
    return jnp.moveaxis(o, 0, 2).astype(r.dtype), final

"""Observability subsystem: metrics registry, lifecycle spans, traces.

Acceptance bars:

* the registry is **off by default** and free when off — instrumented
  hot paths do zero bookkeeping against a disabled registry;
* every request submitted to ``ServingEngine`` gets a complete,
  monotonic ``arrival → admission → work → complete`` span chain from
  ``evaluate_schedule`` (DES step spans) and from the analytical
  ``schedule_spans`` timeline, across all policies and overlap modes;
* ``chrome_trace(schedule=...)`` stitches per-request Perfetto flow
  chains (``ph: "s"/"t"/"f"``) and stamps ``args.request`` on serving
  slices — shape-pinned like the per-unit pid test in test_cluster;
* the scheduler's pricing cache hits on repeated identical layers and
  never changes priced values;
* ``decode_latency_stats`` / ``schedule_metrics`` hold up on the queue
  edge cases (empty, single request, identical arrivals, arrival after
  the whole drain).
"""

import dataclasses
import json

import jax
import pytest

from repro.configs.registry import get_config
from repro.core.task import MatMulTask
from repro.core.simulator import LayerTrace
from repro.obs import (NULL_METRIC, MetricsRegistry, SpanLog,
                       default_registry, disable_metrics, enable_metrics)
from repro.serving.engine import BatchSchedule, BatchStep, ServingEngine
from repro.serving import scheduler
from repro.sim.trace import chrome_trace

POLICIES = ("full-prefill", "chunked-prefill", "decode-priority")


def _engine(n_requests=4, max_batch=2, arrival_gap=0.0, **kw):
    cfg = get_config("yi-6b", reduced=True)
    eng = ServingEngine(cfg, params=None, max_batch=max_batch,
                        cache_len=64, **kw)
    key = jax.random.PRNGKey(0)
    for i in range(n_requests):
        key, sub = jax.random.split(key)
        eng.submit(jax.random.randint(sub, (4 + 3 * i,), 0, 100),
                   arrival_time=arrival_gap * i)
    return cfg, eng


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

class TestMetricsRegistry:
    def test_counter_accumulates_per_label_set(self):
        reg = MetricsRegistry(enabled=True)
        reg.counter("calls", backend="desim").inc()
        reg.counter("calls", backend="desim").inc(2)
        reg.counter("calls", backend="jax").inc()
        snap = reg.snapshot()
        by_backend = {e["labels"]["backend"]: e["value"]
                      for e in snap["counters"]["calls"]}
        assert by_backend == {"desim": 3, "jax": 1}

    def test_counter_rejects_negative(self):
        reg = MetricsRegistry(enabled=True)
        with pytest.raises(ValueError):
            reg.counter("calls").inc(-1)

    def test_gauge_set_inc_dec(self):
        reg = MetricsRegistry(enabled=True)
        g = reg.gauge("depth")
        g.set(5.0)
        g.inc(2.0)
        g.dec()
        assert reg.snapshot()["gauges"]["depth"][0]["value"] == 6.0

    def test_histogram_percentiles(self):
        reg = MetricsRegistry(enabled=True)
        h = reg.histogram("lat")
        for v in range(1, 101):
            h.observe(float(v))
        s = reg.snapshot()["histograms"]["lat"][0]
        assert s["count"] == 100
        assert s["min"] == 1.0 and s["max"] == 100.0
        assert s["p50"] == 50.0 and s["p90"] == 90.0 and s["p99"] == 99.0

    def test_kind_conflict_rejected(self):
        reg = MetricsRegistry(enabled=True)
        reg.counter("x").inc()
        with pytest.raises(ValueError):
            reg.gauge("x")

    def test_disabled_registry_returns_null_metric(self):
        reg = MetricsRegistry(enabled=False)
        m = reg.counter("calls", backend="desim")
        assert m is NULL_METRIC
        m.inc()          # all mutators pass silently
        m.observe(1.0)
        m.set(2.0)
        assert reg.snapshot()["counters"] == {}

    def test_prometheus_text_format(self):
        reg = MetricsRegistry(enabled=True)
        reg.counter("calls_total", backend="desim").inc(3)
        reg.histogram("lat_cycles", policy="auto").observe(10.0)
        text = reg.prometheus_text()
        assert "# TYPE calls_total counter" in text
        assert 'calls_total{backend="desim"} 3' in text
        assert "# TYPE lat_cycles summary" in text
        assert 'lat_cycles_count{policy="auto"} 1' in text
        assert 'quantile="0.50"' in text

    def test_timer_observes_histogram(self):
        reg = MetricsRegistry(enabled=True)
        with reg.timer("op_seconds", section="x"):
            pass
        s = reg.snapshot()["histograms"]["op_seconds"][0]
        assert s["count"] == 1 and s["min"] >= 0.0

    def test_default_registry_toggle(self):
        assert default_registry().enabled is False, \
            "metrics must be off by default"
        try:
            assert enable_metrics() is default_registry()
            assert default_registry().enabled
        finally:
            disable_metrics()
        assert not default_registry().enabled


class TestInstrumentation:
    def test_disabled_path_records_nothing(self):
        from repro import backend
        disable_metrics()
        eng = backend.get("analytical")
        eng.run_graph(eng.lower(MatMulTask(m=64, n=64, k=64)))
        assert default_registry().snapshot()["histograms"] == {}

    def test_enabled_path_times_backend_sections(self):
        from repro import backend
        reg = enable_metrics()
        try:
            eng = backend.get("analytical")
            eng.run_graph(eng.lower(MatMulTask(m=64, n=64, k=64)))
            snap = reg.snapshot()
        finally:
            disable_metrics()
            reg.clear()
        entries = snap["histograms"]["backend_seconds"]
        labels = {(e["labels"]["backend"], e["labels"]["section"])
                  for e in entries}
        assert ("analytical", "run_graph") in labels
        calls = snap["counters"]["backend_calls_total"]
        assert any(e["value"] >= 1 for e in calls)


# ---------------------------------------------------------------------------
# Request-lifecycle spans
# ---------------------------------------------------------------------------

class TestSpanLog:
    @pytest.mark.parametrize("policy", POLICIES)
    @pytest.mark.parametrize("overlap", ("chained", "relaxed"))
    def test_evaluate_schedule_attaches_complete_chains(self, policy,
                                                       overlap):
        _, eng = _engine(arrival_gap=500.0)
        sched, res = eng.evaluate_schedule(
            "desim-cluster", max_new_tokens=4, units=2, policy=policy,
            overlap=overlap, strategy="unit-affinity", workload=False)
        log = res.detail["span_log"]
        assert isinstance(log, SpanLog)
        assert log.validate() == []
        assert list(log.requests()) == sorted(
            {r for s in sched.steps for r in s.requests})
        for r in log.requests():
            phases = [s.phase for s in log.for_request(r)]
            assert phases[0] == "arrival"
            assert phases[1] == "admission"
            assert phases[-1] == "complete"
            assert any(p.startswith("decode_iter") for p in phases)

    def test_arrival_and_ttft_semantics(self):
        _, eng = _engine(n_requests=3, arrival_gap=1000.0)
        sched, res = eng.evaluate_schedule(
            "desim", max_new_tokens=4, policy="full-prefill",
            workload=False)
        log = res.detail["span_log"]
        for r in log.requests():
            arr = log.for_request(r)[0]
            assert arr.start == pytest.approx(1000.0 * r)
            assert log.ttft(r) > 0.0

    def test_analytical_schedule_spans_match_latency_stats(self):
        cfg, eng = _engine(arrival_gap=200.0)
        sched = eng.plan(max_new_tokens=4, policy="chunked-prefill")
        cycles = scheduler.price_steps(sched)
        log = scheduler.schedule_spans(sched, cycles, cfg.n_layers)
        stats = scheduler.decode_latency_stats(sched, cycles, cfg.n_layers)
        assert log.validate() == []
        ttfts = sorted(log.ttft(r) for r in log.requests())
        assert scheduler._percentile(ttfts, 50.0) == \
            pytest.approx(stats["ttft_p50"])
        makespan = max(log.phase(r, "complete").end
                       for r in log.requests())
        assert makespan == pytest.approx(stats["makespan"])

    def test_chunked_prefill_names_chunks(self):
        cfg, eng = _engine(n_requests=6, max_batch=3)
        sched = eng.plan(max_new_tokens=2, policy="chunked-prefill",
                         chunk_tokens=4)
        cycles = scheduler.price_steps(sched)
        log = scheduler.schedule_spans(sched, cycles, cfg.n_layers)
        chunk_phases = {s.phase for s in log
                        if s.phase.startswith("prefill.chunk")}
        assert chunk_phases, "chunked prefill must emit per-chunk spans"

    def test_json_round_trip(self):
        cfg, eng = _engine()
        sched = eng.plan(max_new_tokens=2)
        log = scheduler.schedule_spans(
            sched, scheduler.price_steps(sched), cfg.n_layers)
        doc = json.loads(json.dumps(log.to_json()))
        assert len(doc) == len(log)
        for rec in doc:
            assert set(rec) >= {"request", "phase", "start", "end"}
            assert rec["end"] >= rec["start"]
        work = [rec for rec in doc if rec["phase"].startswith(
            ("prefill", "decode"))]
        assert all({"step", "label", "kind"} <= set(rec) for rec in work)

    def test_validate_flags_missing_phases(self):
        from repro.obs.spans import Span
        log = SpanLog([Span(0, "prefill", 5.0, 9.0)])
        bad = log.validate()
        assert any("arrival" in v for v in bad)
        assert any("complete" in v for v in bad)


# ---------------------------------------------------------------------------
# Perfetto flow events
# ---------------------------------------------------------------------------

class TestFlowEvents:
    @pytest.fixture(scope="class")
    def traced(self):
        _, eng = _engine()
        sched, res = eng.evaluate_schedule(
            "desim-cluster", max_new_tokens=4, units=2,
            policy="decode-priority", overlap="relaxed",
            strategy="unit-affinity", workload=False)
        return sched, chrome_trace(res.timeline, schedule=sched)

    def test_serving_slices_carry_request_ids(self, traced):
        sched, doc = traced
        xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        tagged = [e for e in xs if "request" in e.get("args", {})]
        assert tagged, "no slice carries args.request"
        valid = {r for s in sched.steps for r in s.requests}
        for e in tagged:
            assert set(e["args"]["request"]) <= valid
            assert e["args"]["step"] in {lt.name for lt in sched.layers}

    def test_flow_chain_shape_per_request(self, traced):
        sched, doc = traced
        flows = [e for e in doc["traceEvents"]
                 if e.get("cat") == "request"]
        assert flows, "no flow events emitted"
        by_id = {}
        for e in flows:
            by_id.setdefault(e["id"], []).append(e)
        for rid, chain in by_id.items():
            phs = [e["ph"] for e in chain]
            assert phs[0] == "s" and phs[-1] == "f"
            assert all(p == "t" for p in phs[1:-1])
            assert chain[-1]["bp"] == "e"
            assert all(e["name"] == f"req{rid}" for e in chain)
            ts = [e["ts"] for e in chain]
            assert ts == sorted(ts)

    def test_flow_ids_cover_multi_step_requests(self, traced):
        sched, doc = traced
        flow_ids = {e["id"] for e in doc["traceEvents"]
                    if e.get("cat") == "request"}
        multi = {r for r in
                 {q for s in sched.steps for q in s.requests}
                 if sum(r in s.requests for s in sched.steps) >= 2}
        assert flow_ids == multi

    def test_trace_without_schedule_unchanged(self):
        _, eng = _engine(n_requests=2)
        _, res = eng.evaluate_schedule("desim", max_new_tokens=2,
                                       workload=False)
        doc = chrome_trace(res.timeline)
        assert all(e.get("cat") != "request" for e in doc["traceEvents"])
        assert all("request" not in e.get("args", {})
                   for e in doc["traceEvents"])


# ---------------------------------------------------------------------------
# Pricing cache
# ---------------------------------------------------------------------------

class TestPriceCache:
    def test_identical_layers_hit_and_values_stable(self):
        scheduler.clear_price_cache()
        _, eng = _engine(n_requests=6, max_batch=2)
        sched = eng.plan(max_new_tokens=4)
        reg = enable_metrics()
        try:
            cold = scheduler.price_steps(sched)
            warm = scheduler.price_steps(sched)
            snap = reg.snapshot()
        finally:
            disable_metrics()
            reg.clear()
        assert warm == cold
        hits = sum(e["value"]
                   for e in snap["counters"]["price_cache_hits_total"])
        misses = sum(e["value"]
                     for e in snap["counters"]["price_cache_misses_total"])
        assert misses >= 1
        assert hits >= len(sched.steps), \
            "second pricing pass must be all cache hits"

    def test_cache_key_respects_units(self):
        scheduler.clear_price_cache()
        _, eng = _engine()
        s1 = eng.plan(max_new_tokens=2, units=1)
        s2 = eng.plan(max_new_tokens=2, units=2)
        c1 = scheduler.price_steps(s1)
        c2 = scheduler.price_steps(s2)
        assert c1 != c2, "unit count must reach the cache key"


# ---------------------------------------------------------------------------
# Latency-stat edge cases
# ---------------------------------------------------------------------------

def _tiny_sched(steps, arrivals=(), **kw):
    layers = [LayerTrace(name=f"s{i}", gemms=(MatMulTask(m=4, n=8, k=8),),
                         repeat=s.repeat)
              for i, s in enumerate(steps)]
    rel = tuple(max((arrivals[r] for r in s.requests), default=0.0)
                for s in steps) if arrivals else ()
    return BatchSchedule(steps=list(steps), layers=layers,
                         arrival_times=tuple(arrivals),
                         release_times=rel, **kw)


class TestLatencyEdgeCases:
    def test_empty_queue(self):
        sched = _tiny_sched([])
        stats = scheduler.decode_latency_stats(sched, [], 2)
        assert stats["makespan"] == 0.0
        assert stats["ttft_p50"] == 0.0 and stats["itl_p99"] == 0.0
        assert stats["decode_tokens"] == 0.0
        log = scheduler.schedule_spans(sched, [], 2)
        assert len(log) == 0 and log.validate() == []

    def test_empty_queue_plan_and_metrics(self):
        cfg, eng = _engine(n_requests=0)
        sched = eng.plan(max_new_tokens=4)
        assert sched.steps == []
        stats = scheduler.schedule_metrics(sched, cfg.n_layers)
        assert stats["workload_cycles"] == 0.0
        assert stats["matrix_utilization"] == 0.0

    def test_single_request(self):
        steps = [BatchStep("prefill", (0,), tokens=8, repeat=2),
                 BatchStep("decode", (0,), tokens=1, repeat=8)]
        sched = _tiny_sched(steps)
        stats = scheduler.decode_latency_stats(sched, [100.0, 400.0], 2)
        # 4 decode iterations across (100, 500): first token at 200.
        assert stats["ttft_p50"] == pytest.approx(200.0)
        assert stats["ttft_p99"] == stats["ttft_p50"]
        assert stats["itl_p50"] == pytest.approx(100.0)
        assert stats["makespan"] == pytest.approx(500.0)
        log = scheduler.schedule_spans(sched, [100.0, 400.0], 2)
        assert log.validate() == []
        assert log.ttft(0) == pytest.approx(200.0)

    def test_all_arrivals_identical(self):
        steps = [BatchStep("prefill", (0, 1), tokens=8, repeat=2),
                 BatchStep("decode", (0, 1), tokens=2, repeat=4)]
        sched = _tiny_sched(steps, arrivals=(300.0, 300.0))
        stats = scheduler.decode_latency_stats(sched, [100.0, 200.0], 2)
        # release waits for t=300, prefill ends 400, both tokens at 500
        # (single iteration): identical TTFT = 200 for both requests.
        assert stats["ttft_p50"] == pytest.approx(200.0)
        assert stats["ttft_p99"] == pytest.approx(200.0)
        assert stats["makespan"] == pytest.approx(600.0)

    def test_arrival_after_makespan_of_others(self):
        # request 1 arrives after request 0's whole drain would end.
        steps = [BatchStep("prefill", (0,), tokens=8, repeat=2),
                 BatchStep("decode", (0,), tokens=1, repeat=2),
                 BatchStep("prefill", (1,), tokens=8, repeat=2),
                 BatchStep("decode", (1,), tokens=1, repeat=2)]
        sched = _tiny_sched(steps, arrivals=(0.0, 10_000.0))
        cycles = [100.0, 50.0, 100.0, 50.0]
        stats = scheduler.decode_latency_stats(sched, cycles, 2)
        # idle gap: r1's prefill starts at its arrival, not at r0's end.
        assert stats["makespan"] == pytest.approx(10_150.0)
        assert stats["ttft_p50"] == pytest.approx(150.0)
        log = scheduler.schedule_spans(sched, cycles, 2)
        assert log.validate() == []
        arr1 = log.for_request(1)[0]
        assert arr1.start == pytest.approx(10_000.0)
        assert log.ttft(1) == pytest.approx(150.0)

    def test_length_mismatch_rejected(self):
        sched = _tiny_sched([BatchStep("decode", (0,), tokens=1, repeat=2)])
        with pytest.raises(ValueError):
            scheduler.decode_latency_stats(sched, [1.0, 2.0], 2)

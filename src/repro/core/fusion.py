"""``cute_matmul`` — the unified fused-matmul API (paper Listing 1, §4.3).

Every projection, MLP, logit and expert GEMM in every model in this
framework goes through this one function.  It implements the paper's
matrix–vector fusion contract: the matrix engine produces accumulator
tiles, and the "vector side" (bias, (de)quant scales, activation,
residual, soft-capping, GLU gating) is applied as an *epilogue* without a
round-trip through main memory.

Backends
--------
* ``"xla"``   — einsum + epilogue; XLA fuses the epilogue into the matmul
  consumer.  Used for distributed lowering (GSPMD shards it, and
  ``cost_analysis`` sees real FLOPs).
* ``"pallas"`` — the ``kernels/matmul`` fused kernel (MXU/VPU overlap via
  the Pallas grid pipeline).  Tile sizes default to the Eq.2-style solver
  in ``core.constraint``.
* ``"auto"``  — pallas when the shapes meet the kernel's divisibility
  contract on a real TPU, else xla.  On CPU hosts auto → xla.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import precision as prec
from repro.core.precision import DataType, PrecisionPolicy
from repro.core.task import BiasType


# ---------------------------------------------------------------------------
# Epilogue description — tile-local vector work fused after the matmul.
# ---------------------------------------------------------------------------

def _gelu_tanh(x):
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x * x * x)))


ACTIVATIONS: "dict[str, Callable]" = {
    "none": lambda x: x,
    "relu": jax.nn.relu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    "gelu": jax.nn.gelu,
    "gelu_tanh": _gelu_tanh,
    "silu": jax.nn.silu,
    "sigmoid": jax.nn.sigmoid,
    "tanh": jnp.tanh,
}


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """Vector-side work fused into the matmul (paper Fig. 5 'epilogue').

    Application order (matches the int8 inference pipeline of §5.1):
      acc -> *scale_a (per-row dequant) -> *scale_b (per-col dequant)
          -> +bias (zero/row/full) -> softcap -> activation
          -> GLU gate (optional; splits N in half: act(left) * right)
          -> +residual -> cast(out_dtype)
    """

    bias_type: BiasType = BiasType.ZERO
    activation: str = "none"
    softcap: float = 0.0            # gemma-style logit soft-capping; 0 = off
    glu: bool = False               # act(y[:, :n/2]) * y[:, n/2:]
    has_scale_a: bool = False       # per-row (M,) dequant scale
    has_scale_b: bool = False       # per-col (N,) dequant scale
    has_residual: bool = False
    out_dtype: object = None

    def __post_init__(self):
        if self.activation not in ACTIVATIONS:
            raise ValueError(f"unknown activation {self.activation!r}")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EpilogueOperands:
    """Arrays consumed by an Epilogue.  All optional, shapes as noted."""

    bias: Optional[jax.Array] = None       # (N,) for ROW, (M, N) for FULL
    scale_a: Optional[jax.Array] = None    # (M,) or scalar
    scale_b: Optional[jax.Array] = None    # (N,) or scalar
    residual: Optional[jax.Array] = None   # (M, N_out)


NO_EPILOGUE = Epilogue()
NO_OPERANDS = EpilogueOperands()


def apply_epilogue(acc: jax.Array, ep: Epilogue, ops: EpilogueOperands,
                   compute_dtype=jnp.float32) -> jax.Array:
    """Pure-jnp epilogue application.  ``acc`` is (..., M, N) accumulator.

    Shared by the XLA backend, the Pallas kernel's reference oracle and —
    on a per-tile basis — the Pallas kernel body itself.
    """
    out_dtype_final = ep.out_dtype if ep.out_dtype is not None else acc.dtype
    trivial = (not ep.has_scale_a and not ep.has_scale_b
               and ep.bias_type == BiasType.ZERO and not ep.softcap
               and not ep.glu and ep.activation == "none"
               and not ep.has_residual)
    if trivial:
        # Keep int32 accumulators exact (no float round-trip).
        return acc.astype(out_dtype_final)
    y = acc.astype(compute_dtype)
    if ep.has_scale_a:
        y = y * ops.scale_a[..., :, None].astype(compute_dtype)
    if ep.has_scale_b:
        y = y * ops.scale_b[..., None, :].astype(compute_dtype)
    if ep.bias_type == BiasType.ROW:
        y = y + ops.bias[..., None, :].astype(compute_dtype)
    elif ep.bias_type == BiasType.FULL:
        y = y + ops.bias.astype(compute_dtype)
    if ep.softcap:
        y = jnp.tanh(y / ep.softcap) * ep.softcap
    if ep.glu:
        half = y.shape[-1] // 2
        y = ACTIVATIONS[ep.activation](y[..., :half]) * y[..., half:]
    else:
        y = ACTIVATIONS[ep.activation](y)
    if ep.has_residual:
        y = y + ops.residual.astype(compute_dtype)
    out_dtype = ep.out_dtype if ep.out_dtype is not None else acc.dtype
    return y.astype(out_dtype)


# ---------------------------------------------------------------------------
# The unified entry point.
# ---------------------------------------------------------------------------

def _infer_policy(a: jax.Array) -> PrecisionPolicy:
    table = {
        jnp.int8.dtype: prec.INT8,
        jnp.bfloat16.dtype: prec.policy(DataType.BF16, out_dtype=jnp.bfloat16),
        jnp.float16.dtype: prec.policy(DataType.FP16, out_dtype=jnp.float16),
        jnp.float8_e4m3fn.dtype: prec.FP8,
        jnp.float8_e5m2.dtype: prec.policy(DataType.FP8_E5M2),
        jnp.float32.dtype: prec.FP32,
    }
    return table.get(a.dtype, prec.FP32)


def cute_matmul(a: jax.Array, b: jax.Array, *,
                epilogue: Epilogue = NO_EPILOGUE,
                operands: EpilogueOperands = NO_OPERANDS,
                policy: Optional[PrecisionPolicy] = None,
                backend: Optional[str] = None,
                interpret: bool = True) -> jax.Array:
    """C = epilogue(A @ B).  A: (..., M, K), B: (K, N) (or (..., K, N)).

    ``backend`` is a ``cute_matmul`` route string (``"xla"``,
    ``"pallas"``, ``"auto"``); ``None`` resolves the process-wide default
    from the ``repro.backend`` registry with tuned-dispatch precedence:
    ``set_default_matmul_backend`` wins, else a route the current
    platform's tuning cache pins for this shape class, else ``"xla"``.

    ``epilogue.transpose`` equivalent: the paper's result-transpose flag is
    expressed by the caller transposing the (cheap, fused) output — XLA
    folds it into the consuming op's layout.
    """
    if backend is None:
        from repro.backend import matmul_backend_string   # lazy: no cycle
        m = a.shape[-2] if a.ndim >= 2 else 1
        backend = matmul_backend_string(
            shape=(m, b.shape[-1], a.shape[-1]))
    if policy is None:
        policy = _infer_policy(a)
    if backend == "auto":
        backend = "pallas" if _pallas_supported(a, b, epilogue) else "xla"

    if backend == "pallas":
        from repro.kernels.matmul import ops as mm_ops   # lazy: avoid cycle
        return mm_ops.fused_matmul(a, b, epilogue=epilogue, operands=operands,
                                   policy=policy, interpret=interpret)

    # ----- XLA backend ------------------------------------------------------
    if epilogue.glu and b.ndim == 3:       # (K, 2, N/2) GLU layout
        b = b.reshape(b.shape[0], -1)
    acc = jnp.matmul(a, b, preferred_element_type=policy.accum_dtype,
                     precision=policy.dot_precision)
    ep = epilogue
    if ep.out_dtype is None:
        ep = dataclasses.replace(ep, out_dtype=policy.output_dtype)
    return apply_epilogue(acc, ep, operands)


def _pallas_supported(a, b, epilogue: Epilogue) -> bool:
    from repro.kernels.matmul import ops as mm_ops
    return mm_ops.supports(a.shape, b.shape, epilogue)


def linear(x: jax.Array, w: jax.Array, bias: Optional[jax.Array] = None, *,
           activation: str = "none", glu: bool = False, softcap: float = 0.0,
           out_dtype=None, backend: Optional[str] = None) -> jax.Array:
    """Convenience wrapper used by every model layer in this framework."""
    ep = Epilogue(
        bias_type=BiasType.ROW if bias is not None else BiasType.ZERO,
        activation=activation, glu=glu, softcap=softcap,
        out_dtype=out_dtype if out_dtype is not None else x.dtype)
    return cute_matmul(x, w, epilogue=ep,
                       operands=EpilogueOperands(bias=bias), backend=backend)

"""The closed-form backend: ``core.simulator`` behind the same contract.

``dispatch``/``run_graph`` price a TaskGraph with a closed-form pipeline
model over the *same* per-tile costs the DES charges (``tile_costs``):
per layer group, the steady state runs the slower of the matrix-tile
stream ``max(compute, load+writeback)`` and the CPU dispatch stream,
with the first load exposed as fill and the last compute/writeback/
status-poll as drain; fused epilogues overlap as ``max(matrix, vector)``
with one epilogue share exposed (paper Listing 1).  Where the desim
backend *derives* the makespan from the event schedule, this backend
asserts it — the cross-backend parity suite pins the two within ~1%.
``run_workload`` is ``simulate_workload`` verbatim (the paper's
model-level analytical numbers).  No array outputs are produced — this
backend answers "how long", not "what".
"""

from __future__ import annotations

import re
from typing import Callable

from repro.backend.base import Backend, ExecResult, GraphOperands, \
    MatMulOperands
from repro.backend.registry import register
from repro.core.fusion import Epilogue, NO_EPILOGUE
from repro.core.task import MatMulTask

_GEMM_SUFFIX = re.compile(r"/g\d+$")


@register("analytical")
class AnalyticalBackend(Backend):
    """First-order cost estimates from the closed-form model."""

    models_time = True

    def _stage(self, task: MatMulTask, operands: MatMulOperands,
               epilogue: Epilogue) -> Callable[[], ExecResult]:
        ep = None if epilogue is NO_EPILOGUE else epilogue
        graph = self.lower(task, epilogue=ep)
        return lambda: self.run_graph(graph)

    def run_graph(self, graph, operands: GraphOperands = None) -> ExecResult:
        """Closed-form makespan of a TaskGraph, mirroring the DES pipeline.

        Nodes are grouped by layer (successive layers of a schedule graph
        serialise on the dependency chain); within a group the matrix
        stream is ``fill + Σ max(compute, load+writeback) + drain``
        raced against the serial dispatch/check stream, and fused vector
        work overlaps it as ``max(matrix, vector)`` plus one exposed
        epilogue share.  Unfused groups (an explicit memory round-trip)
        serialise matrix, memory and vector phases.
        """
        from repro.sim.desim import build_machine, tile_costs
        machine = build_machine(self.unit, self.platform, self.vector)
        plat = self.platform
        groups: "dict[str, dict]" = {}
        order: "list[str]" = []
        ideal = 0.0
        for node in graph.topo_order():
            key = _GEMM_SUFFIX.sub("", node.layer)
            if key not in groups:
                groups[key] = {"tiles": [], "vec": 0.0, "n_vec": 0,
                               "mem": 0.0}
                order.append(key)
            g = groups[key]
            if node.kind == "matmul":
                g["tiles"].append(tile_costs(machine, node))
                ideal += (node.task.macs
                          / self.unit.macs_per_cycle(node.task.data_type))
            elif node.kind == "vector":
                g["vec"] += self.vector.cycles_for(node.vector_ops)
                g["n_vec"] += 1
            elif node.kind == "memory":
                g["mem"] += node.mem_bytes / machine.bytes_per_cycle

        cycles = 0.0
        detail = {"matrix": 0.0, "vector": 0.0, "memory": 0.0,
                  "dispatch": 0.0, "groups": len(order)}
        for key in order:
            g = groups[key]
            tiles, vec, mem = g["tiles"], g["vec"], g["mem"]
            if not tiles:
                cycles += vec + mem
                detail["vector"] += vec
                detail["memory"] += mem
                continue
            # Three streams race; the slower one carries the makespan.
            # PE stream: first load exposed as fill, then back-to-back
            # computes, then the last tile's writeback / pipeline drain.
            last = tiles[-1]
            pe_stream = (tiles[0]["load"]
                         + sum(c["compute"] for c in tiles)
                         + max(last["writeback"],
                               self.unit.pe_pipeline_stages
                               + plat.check_cycles))
            # Loader stream: every load and writeback serialises through
            # the memory loader; the last compute lands after the loads
            # drain, overlapping the ~two writebacks still backlogged.
            backlog = min(len(tiles) - 1, 2) * last["writeback"]
            loader_stream = (sum(c["load"] + c["writeback"] for c in tiles)
                             + max(0.0, last["compute"] - backlog))
            dispatch = len(tiles) * (plat.dispatch_cycles
                                     + plat.check_cycles)
            matrix = plat.dispatch_cycles + max(pe_stream, loader_stream,
                                                dispatch)
            if g["n_vec"] > 1 and not mem:
                # fused: the slower stream carries the group.  A compute-
                # bound group exposes the last epilogue share after the
                # final tile; a loader-bound group keeps draining queued
                # writebacks meanwhile, hiding up to that backlog; a
                # vector-bound group exposes the first tile as fill.
                share = vec / g["n_vec"]
                if loader_stream > max(pe_stream, dispatch):
                    share = max(0.0, share - 3.0 * last["writeback"])
                fill = (plat.dispatch_cycles + tiles[0]["load"]
                        + tiles[0]["compute"])
                cycles += max(matrix + share, fill + vec)
            else:
                # one epilogue after everything (LAYER granularity or an
                # unfused round-trip): phases serialise.
                cycles += matrix + vec + mem
            detail["matrix"] += matrix
            detail["vector"] += vec
            detail["memory"] += mem
            detail["dispatch"] += dispatch
        return ExecResult(cycles=cycles, seconds=cycles / self.unit.freq_hz,
                          utilization=ideal / cycles if cycles else 0.0,
                          detail=detail)

    def run_workload(self, layers, *, fused=None, unit=None, platform=None,
                     vector=None):
        from repro.core.simulator import simulate_workload
        return simulate_workload(
            unit or self.unit, layers,
            platform=platform or self.platform,
            vector=vector or self.vector,
            fused=self.fused if fused is None else fused)

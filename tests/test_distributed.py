"""Distribution: logical rules, sharding engine, HLO cost walker; the
multi-device behaviours (collective matmul, sharded MoE, pipeline) run in
a subprocess with 8 forced host devices so the main test process keeps
the single-device view the assignment requires."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hlo_cost
from repro.distributed import logical, sharding
from repro.launch.mesh import compat_abstract_mesh, compat_make_mesh
from repro.models.base import ArchConfig


def _mesh2x2():
    devs = jax.devices()
    if len(devs) < 4:
        return None
    return compat_make_mesh((2, 2), ("data", "model"))


class TestLogicalRules:
    def test_inactive_is_identity(self):
        x = jnp.ones((4, 4))
        assert logical.constrain(x, ("batch", "embed")) is x

    def test_divisibility_fallback(self):
        # AbstractMesh carries the axis sizes without needing 16 devices.
        mesh = compat_abstract_mesh((16,), ("model",))
        with logical.use_rules(mesh, {"heads": "model"}):
            # 7 heads cannot shard 16 ways -> replicate (gemma2-2b case).
            spec = logical.spec_for((7,), ("heads",))
            assert spec == jax.sharding.PartitionSpec(None)
            # 32 heads can.
            spec = logical.spec_for((32,), ("heads",))
            assert spec == jax.sharding.PartitionSpec("model")

    def test_missing_axis_partial_tuple(self):
        mesh = compat_make_mesh((1,), ("data",))
        with logical.use_rules(mesh, {"batch": ("pod", "data")}):
            spec = logical.spec_for((8, 4), ("batch", None))
            assert spec[0] == "data"      # pod silently dropped


class TestParamShardings:
    def test_name_rules_applied(self):
        from repro.configs.registry import get_config
        from repro.models.base import family_module
        cfg = get_config("yi-6b", reduced=True)
        mod = family_module(cfg)
        params = jax.eval_shape(lambda k: mod.init(cfg, k),
                                jax.random.PRNGKey(0))
        mesh = compat_make_mesh((1, 1), ("data", "model"))
        sh = sharding.param_shardings(params, mesh)
        flat = jax.tree_util.tree_flatten_with_path(sh)[0]
        # every leaf got a NamedSharding
        assert all(s is not None for _, s in flat)

    def test_opt_state_mirrors_params(self):
        """mu/nu/master leaves inherit the same name-based rules."""
        from repro.configs.registry import get_config
        from repro.models.base import family_module
        from repro.optim import adamw
        cfg = get_config("whisper-tiny", reduced=True)
        mod = family_module(cfg)
        params = jax.eval_shape(lambda k: mod.init(cfg, k),
                                jax.random.PRNGKey(0))
        opt = jax.eval_shape(lambda p: adamw.init(adamw.AdamWConfig(), p),
                             params)
        mesh = compat_make_mesh((1, 1), ("data", "model"))
        ps = sharding.param_shardings(params, mesh)
        ms = sharding.param_shardings(opt["mu"], mesh)
        p_leaves = jax.tree.leaves(ps)
        m_leaves = jax.tree.leaves(ms)
        assert [s.spec for s in p_leaves] == [s.spec for s in m_leaves]


class TestHloCost:
    def test_scan_trip_counts_exact(self):
        def fn(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, None, length=13)
            return y
        x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        c = jax.jit(fn).lower(x, x).compile()
        cost = hlo_cost.analyze(c.as_text())
        assert cost.flops == pytest.approx(2 * 256**3 * 13, rel=1e-6)
        assert cost.unparsed_loops == 0

    def test_matches_cost_analysis_when_unrolled(self):
        def fn(x, w):
            for _ in range(4):
                x = jnp.tanh(x @ w)
            return x
        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        c = jax.jit(fn).lower(x, x).compile()
        ours = hlo_cost.analyze(c.as_text()).flops
        ca = c.cost_analysis()
        if isinstance(ca, (list, tuple)):   # older jax returns [dict]
            ca = ca[0]
        xla = ca["flops"]
        assert ours == pytest.approx(xla, rel=0.05)

    def test_nested_scans_multiply(self):
        def fn(x, w):
            def outer(c, _):
                def inner(ci, _):
                    return jnp.tanh(ci @ w), None
                ci, _ = jax.lax.scan(inner, c, None, length=4)
                return ci, None
            y, _ = jax.lax.scan(outer, x, None, length=3)
            return y
        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        c = jax.jit(fn).lower(x, x).compile()
        cost = hlo_cost.analyze(c.as_text())
        assert cost.flops == pytest.approx(2 * 128**3 * 12, rel=1e-6)


_SUBPROCESS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, sys.argv[1])
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.launch.mesh import compat_make_mesh

    out = {}

    # ---- collective matmul == reference -------------------------------
    from repro.distributed.collective_matmul import (
        collective_matmul, allgather_matmul_reference)
    mesh = compat_make_mesh((8,), ("model",))
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 64))
    y = collective_matmul(x, w, mesh)
    ref = allgather_matmul_reference(x, w)
    out["cmm_err"] = float(jnp.abs(y - ref).max())
    hlo = jax.jit(lambda x, w: collective_matmul(x, w, mesh)).lower(
        x, w).compile().as_text()
    out["cmm_has_ppermute"] = "collective-permute" in hlo
    out["cmm_has_allgather"] = "all-gather(" in hlo

    # ---- collective matmul == single-device cute_matmul (kernel path) --
    from repro.core.fusion import cute_matmul
    ref_kernel = cute_matmul(x, w, backend="xla")
    out["cmm_vs_kernel_err"] = float(
        jnp.abs(y - ref_kernel).max() / (jnp.abs(ref_kernel).max() + 1e-9))
    # int8 through the same mesh shim: bit-exact against the kernel path
    xi = jax.random.randint(jax.random.PRNGKey(5), (64, 32), -8, 8,
                            jnp.int8).astype(jnp.int32)
    wi = jax.random.randint(jax.random.PRNGKey(6), (32, 64), -8, 8,
                            jnp.int8).astype(jnp.int32)
    yi = collective_matmul(xi, wi, mesh)
    ri = cute_matmul(xi.astype(jnp.int8), wi.astype(jnp.int8),
                     backend="xla")
    out["cmm_int8_exact"] = bool((yi == ri).all())

    # ---- sharded MoE == single-shard MoE ------------------------------
    from repro.configs.registry import get_config
    from repro.models.moe import moe_init, moe_apply, moe_apply_local
    from repro.models.moe import moe_capacity
    cfg = get_config("olmoe-1b-7b", reduced=True).with_(dtype=jnp.float32)
    mesh2 = compat_make_mesh((2, 4), ("data", "model"))
    p = moe_init(cfg, jax.random.PRNGKey(0))
    xx = jax.random.normal(jax.random.PRNGKey(2), (4, 16, cfg.d_model))
    y_sharded = moe_apply(cfg, p, xx, mesh=mesh2)
    cap = moe_capacity(cfg, 2 * 16)
    y_local = moe_apply_local(cfg, xx.reshape(-1, cfg.d_model),
                              p["w_router"], p["experts_wi"],
                              p["experts_wo"], 0, cap).reshape(xx.shape)
    out["moe_err"] = float(jnp.abs(y_sharded - y_local).max()
                           / (jnp.abs(y_local).max() + 1e-9))

    # ---- pipeline parallelism == sequential apply ----------------------
    from repro.distributed.pipeline import pipeline_apply, stage_slice
    meshp = compat_make_mesh((4,), ("pp",))
    L, D = 8, 16
    ws = jax.random.normal(jax.random.PRNGKey(3), (L, D, D)) / jnp.sqrt(D)

    def block_fn(stage_params, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, stage_params)
        return y

    xs = jax.random.normal(jax.random.PRNGKey(4), (6, 4, D))  # 6 microbatches
    y_pipe = pipeline_apply(lambda p, x: block_fn(p, x), ws, xs, meshp,
                            axis="pp")
    y_seq = jax.vmap(lambda x: block_fn(ws, x))(xs)
    out["pipe_err"] = float(jnp.abs(y_pipe - y_seq).max())

    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def multidevice_results():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_PROG, os.path.abspath(src)],
        capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


class TestMultiDevice:
    def test_collective_matmul_correct(self, multidevice_results):
        assert multidevice_results["cmm_err"] < 1e-4

    def test_collective_matmul_overlapped_form(self, multidevice_results):
        """The point of the pattern: ppermute chain, no all-gather of X."""
        assert multidevice_results["cmm_has_ppermute"]
        assert not multidevice_results["cmm_has_allgather"]

    def test_collective_matmul_matches_cute_matmul(self, multidevice_results):
        """Parity against the kernel path (``cute_matmul``) under the
        mesh shim, not just the local einsum reference — fp32 within
        tolerance, int8 accumulation bit-exact."""
        assert multidevice_results["cmm_vs_kernel_err"] < 1e-5
        assert multidevice_results["cmm_int8_exact"]

    def test_moe_ep_sharding_equivalent(self, multidevice_results):
        assert multidevice_results["moe_err"] < 1e-4

    def test_pipeline_parallel_equivalent(self, multidevice_results):
        assert multidevice_results["pipe_err"] < 1e-4

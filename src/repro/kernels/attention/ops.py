"""jit'd wrapper for the flash-attention kernel (+ decode attention).

Pads Sq/Sk to block multiples (padded keys are masked via ``seq_len_k``),
reshapes (B, H, S, D) → (B·H, S, D) for the head grid axis, and maps GQA
query heads onto their KV head through the BlockSpec index map.

``decode_attention`` (one query against a long cache) is deliberately a
pure-jnp path: decode is HBM-bandwidth-bound gather work with no MXU
reuse, so a Pallas kernel buys nothing on TPU — see DESIGN.md §4.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.attention.attention import (_STATS_LANES,
                                               flash_attention_kernel)


def _pad_axis(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=(
    "sm_scale", "causal", "window", "softcap", "q_start", "block_q",
    "block_kv", "interpret"))
def flash_attention(q, k, v, *, sm_scale: Optional[float] = None,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, q_start: int = 0,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = True):
    """q: (B, H, Sq, D); k, v: (B, Hkv, Sk, D) -> (B, H, Sq, D)."""
    b, h, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    assert h % hkv == 0, f"GQA needs H % Hkv == 0, got {h}, {hkv}"
    group = h // hkv
    if sm_scale is None:
        sm_scale = float(1.0 / (d ** 0.5))

    bq = min(block_q, _round_up(sq, 8))
    bkv = min(block_kv, _round_up(sk, 8))
    qp = _pad_axis(q.reshape(b * h, sq, d), 1, bq)
    kp = _pad_axis(k.reshape(b * hkv, sk, d), 1, bkv)
    vp = _pad_axis(v.reshape(b * hkv, sk, d), 1, bkv)
    sq_p, sk_p = qp.shape[1], kp.shape[1]
    grid = (b * h, sq_p // bq, sk_p // bkv)

    def kv_index(bh, iq, jk):
        return (bh // h) * hkv + (bh % h) // group, jk, 0

    kernel = functools.partial(
        flash_attention_kernel, sm_scale=sm_scale, causal=causal,
        window=window, softcap=softcap, seq_len_k=sk, q_start=q_start,
        n_kv=grid[2], bq=bq, bkv=bkv)
    try:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    except (AttributeError, TypeError):
        compiler_params = None

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, iq, jk: (bh, iq, 0)),
            pl.BlockSpec((1, bkv, d), kv_index),
            pl.BlockSpec((1, bkv, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, iq, jk: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, _STATS_LANES), jnp.float32),   # m
            pltpu.VMEM((bq, _STATS_LANES), jnp.float32),   # l
            pltpu.VMEM((bq, d), jnp.float32),              # acc
        ],
        compiler_params=compiler_params,
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :sq].reshape(b, h, sq, d)


def _round_up(x, m):
    return x + (-x) % m


def decode_attention(q, k_cache, v_cache, cache_len, *,
                     sm_scale: Optional[float] = None, window: int = 0,
                     softcap: float = 0.0):
    """Single-token decode: q (B, H, 1, D) vs cache (B, Hkv, S, D).

    ``cache_len`` (scalar or (B,)) marks the valid prefix; the new token
    is assumed already written at position cache_len - 1.
    """
    b, h, _, d = q.shape
    hkv, s = k_cache.shape[1], k_cache.shape[2]
    if sm_scale is None:
        sm_scale = float(1.0 / (d ** 0.5))
    group = h // hkv
    qe = q.reshape(b, hkv, group, d).astype(jnp.float32)
    scores = jnp.einsum("bngd,bnsd->bngs", qe,
                        k_cache.astype(jnp.float32)) * sm_scale
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    pos = jnp.arange(s)
    cache_len = jnp.asarray(cache_len)
    valid = pos[None, :] < cache_len.reshape(-1, 1)          # (B, S)
    if window > 0:
        valid &= pos[None, :] >= (cache_len.reshape(-1, 1) - window)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngs,bnsd->bngd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, h, 1, d).astype(q.dtype)

"""End-to-end system behaviour: fault-tolerant training (crash/resume
equivalence) and the dry-run artifact contract."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.base import family_module
from repro.optim import adamw
from repro.runtime.checkpoint import CheckpointManager
from repro.training.train_step import TrainConfig, make_train_step


def _tiny():
    cfg = get_config("yi-6b", reduced=True).with_(
        remat="none", dtype=jnp.float32, n_layers=2, d_model=32, d_ff=64,
        n_heads=2, n_kv_heads=2, head_dim=16, vocab_size=64, attn_chunk=16)
    return cfg, family_module(cfg)


def test_crash_resume_is_bit_identical(tmp_path):
    """Train 6 steps straight vs 3 steps -> checkpoint -> 'crash' ->
    restore -> 3 steps: identical parameters and data stream."""
    cfg, mod = _tiny()
    tcfg = TrainConfig(loss_chunk=16,
                       optimizer=adamw.AdamWConfig(lr=1e-3, warmup_steps=0))
    step = jax.jit(make_train_step(cfg, tcfg))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, global_batch=4, seq_len=16)

    # --- uninterrupted run ------------------------------------------------
    params = mod.init(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(tcfg.optimizer, params)
    data = SyntheticLM(dcfg)
    for _ in range(6):
        params, opt, _, _ = step(params, opt, next(data))
    straight = params

    # --- crash at step 3, resume -------------------------------------------
    mgr = CheckpointManager(str(tmp_path))
    params = mod.init(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(tcfg.optimizer, params)
    data = SyntheticLM(dcfg)
    for _ in range(3):
        params, opt, _, _ = step(params, opt, next(data))
    mgr.save(3, {"params": params, "opt": opt},
             extra={"data": data.state_dict()})
    del params, opt, data                      # "crash"

    p0 = mod.init(cfg, jax.random.PRNGKey(0))
    o0 = adamw.init(tcfg.optimizer, p0)
    restored, extra = mgr.restore(mgr.latest_step(),
                                  {"params": p0, "opt": o0})
    params, opt = restored["params"], restored["opt"]
    data = SyntheticLM(dcfg)
    data.load_state_dict(extra["data"])
    for _ in range(3):
        params, opt, _, _ = step(params, opt, next(data))

    for a, b in zip(jax.tree.leaves(straight), jax.tree.leaves(params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_dryrun_artifacts_schema():
    """Any dry-run JSONs produced so far satisfy the roofline contract."""
    root = os.path.join(os.path.dirname(__file__), "..",
                        "benchmarks", "results", "dryrun")
    if not os.path.isdir(root):
        return                                  # sweep not run yet
    n = 0
    for mesh_dir in os.listdir(root):
        d = os.path.join(root, mesh_dir)
        for fn in os.listdir(d):
            with open(os.path.join(d, fn)) as f:
                r = json.load(f)
            roof = r["roofline"]
            assert roof["dominant"] in ("compute", "memory", "collective")
            assert roof["compute_s"] >= 0
            assert r["chips"] in (256, 512)
            assert r["unparsed_loops"] == 0, fn
            n += 1
    assert n >= 0

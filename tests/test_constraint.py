"""Paper Eq.1/Eq.2 and the two-level constraint model."""

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import constraint
from repro.core.config import CASE_STUDY, PLATFORM_2TOPS, MatrixUnitConfig, \
    scaled_config, scaling_sweep
from repro.core.hardware import GIGA, TERA
from repro.core.precision import DataType


class TestEq1:
    def test_case_study_is_4tops_int8(self):
        # Table 2: 2 GHz x 4x4 PEs x (512b/8b) x 2 = 4.096 TOPS.
        assert CASE_STUDY.throughput(DataType.INT8) == pytest.approx(
            4.096 * TERA)

    def test_platform_config_is_2tops(self):
        assert PLATFORM_2TOPS.throughput(DataType.INT8) == pytest.approx(
            2.048 * TERA)

    def test_halving_precision_doubles_throughput(self):
        t8 = CASE_STUDY.throughput(DataType.INT8)
        t16 = CASE_STUDY.throughput(DataType.BF16)
        assert t8 == pytest.approx(2 * t16)

    def test_envelope_covers_half_to_32_tops(self):
        tops = [c.throughput(DataType.INT8) / TERA for c in scaling_sweep()]
        assert min(tops) <= 0.6
        assert max(tops) >= 32.0


class TestEq2:
    def test_paper_printed_form_case_study(self):
        # As printed, Eq.2 holds for the case study (compute <= memory):
        lhs, rhs = constraint.paper_eq2_lhs_rhs(CASE_STUDY)
        assert lhs <= rhs

    def test_case_study_is_memory_limited(self):
        # ...which means the PE array is NOT saturated: ideal util = 75%.
        assert constraint.ideal_utilization(CASE_STUDY) == pytest.approx(
            0.75, abs=0.01)

    def test_2tops_config_saturates(self):
        assert constraint.feeds_pe_array(PLATFORM_2TOPS)
        assert constraint.ideal_utilization(PLATFORM_2TOPS) == 1.0

    def test_solver_direction(self):
        # Saturating direction: the solved scratchpad feeds the PEs.
        m, n = constraint.solve_scratchpad(CASE_STUDY)
        cfg = CASE_STUDY.with_(m_scp=m, n_scp=n)
        assert constraint.feeds_pe_array(cfg)

    @given(bw_gb=st.integers(4, 128))
    @settings(max_examples=20, deadline=None)
    def test_lower_bandwidth_needs_larger_scratchpad(self, bw_gb):
        lo = MatrixUnitConfig(bandwidth=bw_gb * GIGA)
        hi = MatrixUnitConfig(bandwidth=2 * bw_gb * GIGA)
        m_lo, _ = constraint.solve_scratchpad(lo)
        m_hi, _ = constraint.solve_scratchpad(hi)
        assert m_lo >= m_hi

    def test_scaled_configs_satisfy_constraint(self):
        for cfg in scaling_sweep():
            assert constraint.feeds_pe_array(cfg), cfg.describe()


class TestTpuTiles:
    def test_solved_tile_fits_vmem_and_saturates(self):
        tc = constraint.solve_tiles(DataType.BF16)
        assert tc.vmem_bytes <= 0.5 * 128 * 2**20
        assert tc.compute_bound

    def test_int8_needs_bigger_tiles_than_bf16(self):
        # Double the OPS at the same bandwidth => higher required AI.
        t8 = constraint.solve_tiles(DataType.INT8)
        t16 = constraint.solve_tiles(DataType.BF16)
        assert t8.bm >= t16.bm

    def test_ridge_point(self):
        ai = constraint.arithmetic_intensity_needed(DataType.BF16)
        assert 200 < ai < 300          # 197e12 / 819e9 ≈ 240

    def test_ici_hiding(self):
        # A big matmul hides its weight gather; a tiny one does not.
        assert constraint.ici_gather_is_hidden(
            flops_per_chip=1e12, gather_bytes=1e8)
        assert not constraint.ici_gather_is_hidden(
            flops_per_chip=1e9, gather_bytes=1e9)

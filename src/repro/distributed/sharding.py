"""Parameter / batch / cache sharding rules (divisibility-aware).

Maps every parameter leaf to logical axes by its name, then through the
active ``logical`` rules to a ``NamedSharding``.  Megatron-style TP falls
out of the name map: QKV and MLP-in shard their *output* column (column
parallel), attention-out and MLP-out shard their *input* row (row
parallel), so each transformer block costs one all-reduce in forward.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import logical
from repro.models.base import ArchConfig

#: leaf name -> logical axes (matched on the last path component).
_NAME_RULES: "dict[str, tuple]" = {
    "embedding": ("vocab", "embed"),
    "lm_head": ("embed", "vocab"),
    "wq": ("embed", "heads"),        # column parallel
    "wk": ("embed", "kv_heads"),
    "wv": ("embed", "kv_heads"),
    "wo": ("heads", "embed"),        # row parallel
    "wi": ("embed", "mlp"),          # column parallel (GLU keeps 2x cols)
    "w_router": ("embed", None),     # replicated router
    "experts_wi": ("experts", "embed", "mlp_expert"),
    "experts_wo": ("experts", "mlp_expert", "embed"),
    # Griffin recurrent block.
    "w_rnn_in": ("embed", "mlp"),
    "w_gate_in": ("embed", "mlp"),
    "w_rnn_out": ("mlp", "embed"),
    # RWKV time-mix projections.
    "w_r": ("embed", "heads"),
    "w_k": ("embed", "heads"),
    "w_v": ("embed", "heads"),
    "w_g": ("embed", "heads"),
    "w_o": ("heads", "embed"),
    "w_cm_k": ("embed", "mlp"),
    "w_cm_v": ("mlp", "embed"),
    "w_cm_r": ("embed", "mlp"),
}
# mlp wo: name collision with attention wo is fine — both are row parallel
# with the sharded dim first.


def _leaf_logical_axes(path, leaf) -> "tuple | None":
    name = None
    for part in reversed(path):
        key = getattr(part, "key", getattr(part, "name", None))
        if isinstance(key, str):
            name = key
            break
    if name in _NAME_RULES:
        axes = _NAME_RULES[name]
        if len(axes) == leaf.ndim:
            return axes
        # Stacked-over-layers leaves get a leading (replicated) layer dim.
        if len(axes) == leaf.ndim - 1:
            return (None,) + axes
        if len(axes) == leaf.ndim - 2:
            return (None, None) + axes
    return None


def param_shardings(params, mesh: Optional[Mesh], rules: Optional[dict] = None):
    """NamedSharding pytree for a (possibly abstract) param pytree."""
    if mesh is None:
        return jax.tree.map(lambda _: None, params)
    with logical.use_rules(mesh, rules):
        def one(path, leaf):
            axes = _leaf_logical_axes(path, leaf)
            if axes is None:
                return NamedSharding(mesh, P())      # replicate
            s = logical.sharding_for(leaf.shape, axes)
            return s if s is not None else NamedSharding(mesh, P())
        return jax.tree_util.tree_map_with_path(one, params)


def batch_shardings(batch, mesh: Optional[Mesh], rules: Optional[dict] = None):
    """Shard the leading (batch) dim of every input leaf over (pod, data)."""
    if mesh is None:
        return jax.tree.map(lambda _: None, batch)
    with logical.use_rules(mesh, rules):
        def one(leaf):
            axes = ("batch",) + (None,) * (leaf.ndim - 1)
            s = logical.sharding_for(leaf.shape, axes)
            return s if s is not None else NamedSharding(mesh, P())
        return jax.tree.map(one, batch)


def cache_shardings(cache, mesh: Optional[Mesh], cfg: ArchConfig,
                    rules: Optional[dict] = None):
    """KV caches: batch over (pod, data); the model axis takes the KV-head
    dim when it divides, else the cache *sequence* dim (sequence-parallel
    decode attention: scores/softmax/PV reduce over the sharded S with a
    single all-reduce — how a 2 TB 32k cache fits 16 GB chips when
    n_kv_heads < model size, e.g. deepseek-67b kv=8 on model=16)."""
    if mesh is None:
        return jax.tree.map(lambda _: None, cache)
    model = mesh.shape.get("model", 1)
    with logical.use_rules(mesh, rules):
        def one(leaf):
            if leaf.ndim == 5:
                # (L, B, Hkv, S, D) KV cache or (L, B, H, C, C) rwkv state.
                heads, seq = leaf.shape[2], leaf.shape[3]
                if heads % model == 0:
                    axes = (None, "batch", "kv_heads", None, None)
                elif seq % model == 0:
                    axes = (None, "batch", None, "heads", None)
                else:
                    axes = (None, "batch", None, None, None)
            elif leaf.ndim >= 2:
                axes = (None, "batch") + (None,) * (leaf.ndim - 2)
            else:
                axes = (None,) * leaf.ndim
            s = logical.sharding_for(leaf.shape, axes)
            return s if s is not None else NamedSharding(mesh, P())
        return jax.tree.map(one, cache)


def apply_shardings(tree, shardings):
    """Attach shardings to ShapeDtypeStructs (dry-run) or device_put (real)."""
    def one(x, s):
        if isinstance(x, jax.ShapeDtypeStruct):
            return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s)
        return x if s is None else jax.device_put(x, s)
    return jax.tree.map(one, tree, shardings)


# ---------------------------------------------------------------------------
# Cluster-partitioned GEMM: the execution mirror of sim.partition.
# ---------------------------------------------------------------------------

def shard_map_gemm(a, b, n_units: int, dim: str = "m",
                   axis: str = "units", accum_dtype=None, precision=None,
                   bounds=None):
    """Accumulator-precision GEMM sharded over ``n_units``.

    ``dim="m"`` shards A's rows (row-panel partition: each unit owns
    full output rows), ``dim="n"`` shards B's columns (output-tile
    partition: each unit owns full output columns).  ``bounds`` is the
    per-unit ``(lo, hi)`` extent list of a ``sim.partition.Partition``
    (``None`` entries for idle units), so execution reproduces the
    *exact* unit-to-data mapping the DES timed; omitted, an even split
    is assumed.  When the spans are the even split and the host exposes
    at least ``n_units`` devices the shards run under a real
    ``shard_map`` over a ``(units,)`` mesh; otherwise an arithmetically
    identical per-shard loop walks the spans (integer dots are
    bit-exact either way, which is what the parity suite pins).

    ``accum_dtype``/``precision`` mirror ``cute_matmul``'s dot so the
    shards accumulate exactly like the single-device kernel path.
    Returns the full (M, N) accumulator (int32 for int8 inputs).
    """
    from repro.core.jaxcompat import shard_map

    if dim not in ("m", "n"):
        raise ValueError(f"dim must be 'm' or 'n', got {dim!r}")
    if accum_dtype is None:
        accum_dtype = jnp.int32 if a.dtype in (jnp.int8.dtype, jnp.uint8.dtype) \
            else jnp.float32

    def dot(a_s, b_s):
        return jnp.matmul(a_s, b_s, preferred_element_type=accum_dtype,
                          precision=precision)

    size = a.shape[0] if dim == "m" else b.shape[1]
    even = [(size * u // n_units, size * (u + 1) // n_units)
            for u in range(n_units)]
    if bounds is None:
        bounds = even
    if (n_units == 1 or list(bounds) != even or size % n_units != 0
            or jax.device_count() < n_units):
        # Partition-shaped (possibly unbalanced) spans / too few
        # devices: identical math, explicit per-span slices.
        return _sliced_gemm(a, b, bounds, dim, dot)

    from repro.launch.mesh import compat_make_mesh
    mesh = compat_make_mesh((n_units,), (axis,))
    in_specs = (P(axis, None), P(None, None)) if dim == "m" \
        else (P(None, None), P(None, axis))
    out_specs = P(axis, None) if dim == "m" else P(None, axis)
    fn = shard_map(dot, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_vma=False)
    return fn(a, b)


def _sliced_gemm(a, b, bounds, dim, dot):
    parts = []
    for span in bounds:
        if span is None:
            continue
        lo, hi = span
        if hi <= lo:
            continue
        parts.append(dot(a[lo:hi], b) if dim == "m"
                     else dot(a, b[:, lo:hi]))
    return jnp.concatenate(parts, axis=0 if dim == "m" else 1)

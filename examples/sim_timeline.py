"""One TaskGraph, two backends: simulate it, execute it, dump a timeline.

    PYTHONPATH=src python examples/sim_timeline.py [--out trace.json]

Builds a Llama-style fused Gate/Up layer as a TaskGraph (matrix tiles +
per-tile SiLU-GLU epilogues), then:

1. runs it on the discrete-event machine model for each of the four CPU
   platforms and prints per-resource utilization + overlap attribution;
2. executes the *same* graph through AsyncMatmulEngine/cute_matmul on
   JAX and checks it against the direct fused matmul;
3. exports the simulated timeline as Chrome-trace JSON — open it at
   https://ui.perfetto.dev (or chrome://tracing) to see the dispatcher,
   memory loader, scratchpad banks, PE array and vector unit lanes.
"""

import argparse
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core.config import CASE_STUDY
from repro.core.fusion import Epilogue, cute_matmul
from repro.core.hardware import PLATFORMS, SHUTTLE
from repro.core.simulator import LayerTrace
from repro.core.task import MatMulTask
from repro.sim import (Granularity, build_gemm_graph, chrome_trace,
                       desim_layer, dump_chrome_trace, execute_graph_jax,
                       simulate_graph)
from repro.sim.lower import epilogue_vector_ops


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="desim_trace.json",
                    help="Chrome-trace output path (view in Perfetto)")
    args = ap.parse_args()

    # A Gate/Up-like fused int8 tile stream, small enough to eyeball: the
    # SiLU divides make the vector stream long (§5.4), so overlap shows.
    m, n, k = 256, 512, 1024
    ep = Epilogue(activation="silu", glu=True, out_dtype=jnp.float32)
    task = MatMulTask(m=m, n=n, k=k)              # int8, the paper default
    graph, _ = build_gemm_graph(
        task, CASE_STUDY.m_scp, CASE_STUDY.n_scp,
        granularity=Granularity.PANEL,           # full-N panels (GLU needs N)
        vector_ops=epilogue_vector_ops(ep, m, n), epilogue=ep)
    print(f"TaskGraph: {graph.stats()}")

    # 1. Discrete-event simulation on the four integration platforms ------
    print(f"\n{'platform':<12}{'cycles':>10}{'pe':>7}{'vec':>7}"
          f"{'loader':>8}{'disp':>7}")
    results = {}
    for name, platform in PLATFORMS.items():
        r = simulate_graph(graph, CASE_STUDY, platform)
        results[name] = r
        u = r.utilizations()
        print(f"{name:<12}{r.cycles:>10.0f}{u['pe_array']:>7.1%}"
              f"{u['vector_unit']:>7.1%}{u['mem_loader']:>8.1%}"
              f"{u['dispatcher']:>7.1%}")

    # Overlap attribution: same graph, vector nodes after all tiles.
    layer = LayerTrace("gate_up", (task,),
                       vector_ops=epilogue_vector_ops(ep, m, n),
                       intermediate_bytes=4.0 * m * n)
    fused = desim_layer(CASE_STUDY, layer, fused=True,
                        granularity=Granularity.PANEL)
    unfused = desim_layer(CASE_STUDY, layer, fused=False)
    print(f"\nfused {fused['cycles']:.0f} vs unfused {unfused['cycles']:.0f} "
          f"cycles -> overlap speedup "
          f"{unfused['cycles'] / fused['cycles']:.2f}x")

    # 2. The same graph, executed for real through the async engine -------
    ka, kb = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.randint(ka, (m, k), -8, 8, jnp.int8)
    b = jax.random.randint(kb, (k, n), -8, 8, jnp.int8)
    out = execute_graph_jax(graph, a, b)
    ref = cute_matmul(a, b, epilogue=ep)
    print(f"JAX lowering of the graph: out {out.shape}, "
          f"max |Δ| vs cute_matmul = {float(jnp.abs(out - ref).max()):.2e}")

    # 3. Chrome-trace export ----------------------------------------------
    path = dump_chrome_trace(results["shuttle"], args.out,
                             process_name="cutev2-desim shuttle gate_up")
    n_events = len(chrome_trace(results["shuttle"])["traceEvents"])
    print(f"\nwrote {n_events} trace events to {path} "
          f"- open in https://ui.perfetto.dev")


if __name__ == "__main__":
    main()

"""The sharded execution backend: the partitioned graph, run for real.

``sharded`` executes the *identical* partitioned TaskGraph the
``desim-cluster`` backend times: ``sim.partition`` decides which unit
owns which tiles, and execution maps units onto a ``(units,)`` mesh axis
— ``distributed.sharding.shard_map_gemm`` computes each unit's output
block under ``shard_map`` (``launch.mesh``) when enough devices exist,
or through an arithmetically identical per-shard loop otherwise, so
int8 results are bit-exact against the ``jax`` backend either way.
Epilogue-carrying vector nodes are applied to the assembled accumulator
through the same region walk the single-device lowering uses
(``sim.lower.apply_graph_epilogues``).
"""

from __future__ import annotations

from typing import Callable

from repro.backend.base import (ExecResult, GraphOperands,
                                MatMulOperands, NO_MATMUL_OPERANDS)
from repro.backend.cluster_backend import PartitionedBackend
from repro.backend.registry import register
from repro.core.fusion import Epilogue, NO_EPILOGUE
from repro.core.task import MatMulTask
from repro.obs import instrument


@register("sharded")
class ShardedBackend(PartitionedBackend):
    """Cluster-partitioned execution over ``launch.mesh`` + shard_map."""

    executes = True
    matmul_string = "xla"

    @property
    def shard_dim(self):
        from repro.sim.partition import STRATEGY_DIM
        return STRATEGY_DIM[self.strategy]

    def _stage(self, task: MatMulTask, operands: MatMulOperands,
               epilogue: Epilogue) -> Callable[[], ExecResult]:
        if not operands.concrete:
            raise ValueError(
                f"backend {self.name!r} executes numbers: dispatch needs "
                "MatMulOperands(a=..., b=...)")
        ep = None if epilogue is NO_EPILOGUE else epilogue
        part = self.partition(self.lower(task, epilogue=ep))
        return lambda: self.run_graph(part, operands)

    @instrument("run_graph")
    def run_graph(self, graph, operands: GraphOperands = None) -> ExecResult:
        from repro.sim.lower import (_subgraph_for_gemm, gemm_labels,
                                     iter_gemm_operands)
        part = self.partition(graph)
        g = part.graph
        detail = {"partition": {"strategy": part.strategy,
                                "n_units": part.n_units,
                                "transfers": part.n_transfers}}
        if isinstance(operands, dict):
            outs = {}
            for label, a, b, eops in iter_gemm_operands(g, operands):
                outs[label] = self._execute_gemm(
                    _subgraph_for_gemm(g, label), a, b, eops,
                    part.spans.get(label))
            return ExecResult(outputs=outs, detail=detail)
        ops = operands or NO_MATMUL_OPERANDS
        if not ops.concrete:
            raise ValueError(
                f"backend {self.name!r} needs concrete operands: pass "
                "MatMulOperands(a, b) or a {gemm label: (a, b)} dict")
        labels = gemm_labels(g)
        if len(labels) > 1:
            raise ValueError(
                f"graph spans {len(labels)} GEMMs; pass a "
                "{gemm label: (a, b)} operand dict")
        out = self._execute_gemm(g, ops.a, ops.b, ops.epilogue,
                                 part.spans.get(labels[0]))
        return ExecResult(output=out, detail=detail)

    def _execute_gemm(self, graph, a, b, eops, spans=None):
        """One GEMM's partitioned subgraph on real arrays; ``spans`` is
        the partition's per-unit extent list, so execution reproduces
        the exact unit-to-data mapping the DES timed."""
        from repro.core.fusion import _infer_policy
        from repro.distributed.sharding import shard_map_gemm
        from repro.sim.lower import apply_graph_epilogues
        policy = _infer_policy(a)
        dim = self.shard_dim
        # layer-pipeline keeps each whole GEMM on one unit: within a
        # single GEMM there is nothing to shard.
        n = self.units if dim is not None else 1
        acc = shard_map_gemm(a, b, n, dim=dim or "m",
                             accum_dtype=policy.accum_dtype,
                             precision=policy.dot_precision,
                             bounds=spans if dim is not None else None)
        return apply_graph_epilogues(graph, acc, operands=eops,
                                     in_dtype=a.dtype)

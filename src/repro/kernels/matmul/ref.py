"""Pure-jnp oracle for the fused matmul kernel.

Deliberately boring: one ``jnp.matmul`` in the accumulate dtype plus the
*shared* ``apply_epilogue`` (the kernel reuses the same epilogue function
tile-wise, so tests exercise the tiling/accumulation logic, not two
copies of the same arithmetic).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.fusion import Epilogue, EpilogueOperands, apply_epilogue


def fused_matmul_ref(a, b, *, epilogue: Epilogue = Epilogue(),
                     operands: EpilogueOperands = EpilogueOperands(),
                     accum_dtype=jnp.float32):
    """a: (M, K); b: (K, N) — or (K, 2, N/2) when epilogue.glu."""
    if b.ndim == 3:
        b = b.reshape(b.shape[0], -1)
    acc = jnp.matmul(a, b, preferred_element_type=accum_dtype)
    return apply_epilogue(acc, epilogue, operands)

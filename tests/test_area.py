"""Area/power model: Table 7 calibration + scaling behaviour."""

import pytest

from repro.core.area import estimate
from repro.core.config import CASE_STUDY, scaled_config
from repro.core.hardware import GIGA


def test_table7_calibration_exact():
    ap = estimate(CASE_STUDY)
    assert ap.ram_mm2 == pytest.approx(0.164, rel=1e-6)
    assert ap.logic_mm2 == pytest.approx(0.367, rel=1e-6)
    assert ap.total_mm2 == pytest.approx(0.531, rel=1e-3)
    assert ap.total_w == pytest.approx(1.506, rel=1e-3)


def test_area_scales_with_pe_array():
    small = estimate(CASE_STUDY.with_(m_pe=2, n_pe=2))
    big = estimate(CASE_STUDY.with_(m_pe=8, n_pe=8))
    assert big.logic_mm2 == pytest.approx(4 * estimate(CASE_STUDY).logic_mm2,
                                          rel=1e-6)
    assert small.logic_mm2 < estimate(CASE_STUDY).logic_mm2


def test_scratchpad_cost_of_saturating_eq2():
    """The beyond-paper 128x128 scratchpad buys util with ~2.4x the SRAM."""
    sat = estimate(CASE_STUDY.with_(m_scp=128, n_scp=128))
    base = estimate(CASE_STUDY)
    assert 1.5 < sat.ram_mm2 / base.ram_mm2 < 4.0
    assert sat.total_mm2 < 2 * base.total_mm2   # still a small unit


def test_power_scales_with_frequency():
    hi = estimate(CASE_STUDY.with_(freq_hz=4 * GIGA))
    assert hi.total_w == pytest.approx(2 * estimate(CASE_STUDY).total_w,
                                       rel=1e-6)

"""Serving-scheduler policies, priced before they ever run.

Compares the three registered batching policies (``full-prefill``,
``chunked-prefill``, ``decode-priority``) on one queue:

* decode first-token p50/p99 + inter-token latency from the analytical
  closed form (no DES run), single-unit and on a 2-unit cluster;
* the auto-picked (policy × partition) candidate —
  ``plan(policy="auto")``;
* a heterogeneous topology (4-TOPS + 2-TOPS units) priced through the
  same contention-aware form with ``unit-affinity`` placement;
* a Perfetto trace of the decode-priority schedule on ``desim-cluster``
  with prefill-chunk / decode phase markers (open in
  https://ui.perfetto.dev).

    PYTHONPATH=src python examples/serving_policies.py
"""

import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro import backend
from repro.configs.registry import get_config
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import available_policies, schedule_metrics


def queue(cfg, n_requests=6, arrival_gap=0.0):
    eng = ServingEngine(cfg, params=None, max_batch=2, cache_len=256)
    key = jax.random.PRNGKey(0)
    for i in range(n_requests):
        key, sub = jax.random.split(key)
        eng.submit(jax.random.randint(sub, (48 + 24 * i,), 0,
                                      cfg.vocab_size),
                   arrival_time=i * arrival_gap)
    return eng


def main():
    cfg = get_config("yi-6b", reduced=True)
    eng = queue(cfg)

    print("== policies on the analytical closed form ==")
    for units in (1, 2):
        for pol in available_policies():
            sched = eng.plan(max_new_tokens=16, units=units, policy=pol)
            m = schedule_metrics(sched, cfg.n_layers, "analytical")
            print(f"  u{units} {pol:16s} decode_p50={m['decode_p50']:9.0f} "
                  f"p99={m['decode_p99']:9.0f} itl={m['itl_p50']:6.0f} "
                  f"makespan={m['makespan']:9.0f} cyc")

    sched, report = eng.autoplan(max_new_tokens=16, units=2)
    chosen = report["chosen"]
    print(f"auto -> {chosen['candidate']} "
          f"(decode_p50={chosen['decode_p50']:.0f}, "
          f"makespan={chosen['makespan']:.0f})")

    print("== heterogeneous cluster (4-TOPS + 2-TOPS) ==")
    from repro.core.config import CASE_STUDY, PLATFORM_2TOPS
    from repro.sim import ClusterTopology, UnitSpec
    fast = CASE_STUDY.with_(freq_hz=PLATFORM_2TOPS.freq_hz)
    topo = ClusterTopology(
        unit_specs=(UnitSpec(unit=fast), UnitSpec(unit=PLATFORM_2TOPS)),
        platform=None)
    print("  topology:", topo.describe())
    sched = eng.plan(max_new_tokens=16, units=2, policy="decode-priority")
    ana = backend.get("analytical", topology=topo,
                      strategy="unit-affinity",
                      affinity=dict(sched.affinity))
    w = ana.run_workload(sched.layers)
    print(f"  decode-priority on het topo: {w['cycles']:.0f} cyc, "
          f"agg util {w['matrix_utilization']:.1%}, "
          f"loader util {w['loader_utilization']:.1%}")

    print("== Perfetto trace with policy phase markers ==")
    from repro.sim.trace import dump_chrome_trace
    dc = backend.get("desim-cluster", units=2, strategy="output-tile")
    graph = dc.lower(sched.layers[:6])        # first scheduling rounds
    res = dc.run_graph(graph)
    path = dump_chrome_trace(res.timeline, "serving_policy_trace.json")
    print(f"  wrote {path} (slices carry args.phase = "
          "prefill-chunk / decode)")

    print("== cross-step overlap: relaxed vs chained lowering ==")
    # relaxed keeps only true per-request hazards, so decode (pinned to
    # unit 0 by the policy's affinity hints) runs beside hazard-free
    # prefill chunks on unit 1 — same GEMMs, lower makespan.
    for ov in ("chained", "relaxed"):
        sched, res = eng.evaluate_schedule(
            "desim-cluster", max_new_tokens=16, units=2,
            policy="decode-priority", overlap=ov, workload=False)
        print(f"  {ov:8s} DES makespan {res.cycles:10.0f} cyc "
              f"(agg util {res.utilization:.1%})")
        if ov == "relaxed":
            path = dump_chrome_trace(res.timeline,
                                     "serving_overlap_trace.json")
            print(f"  wrote {path} — decode slices on unit 0 overlap "
                  "prefill on unit 1 in Perfetto")

    print("== arrival times: TTFT under load ==")
    # requests trickling in every 30k cycles instead of all at t=0:
    # release times hold steps until their requests exist, and TTFT is
    # measured from each request's own arrival.
    late = queue(cfg, arrival_gap=30000.0)
    for label, e in (("all at t=0", eng), ("30k-cycle gaps", late)):
        m = schedule_metrics(e.plan(max_new_tokens=16,
                                    policy="decode-priority"),
                             cfg.n_layers, "analytical")
        print(f"  {label:15s} ttft_p50={m['ttft_p50']:9.0f} "
              f"ttft_p99={m['ttft_p99']:9.0f} "
              f"makespan={m['makespan']:9.0f} cyc")


if __name__ == "__main__":
    main()

"""Batched serving example: continuous batching over the async engine
across two architecture families (KV-cache attention + O(1)-state RWKV).

    PYTHONPATH=src python examples/serve_batched.py
"""

import os
import sys
import time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.models.base import family_module
from repro.serving.engine import ServingEngine


def serve(arch: str, n_requests: int = 5, max_new: int = 12):
    cfg = get_config(arch, reduced=True).with_(
        dtype=jnp.float32, remat="none", kv_cache_dtype=jnp.float32)
    mod = family_module(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_batch=4, cache_len=128)

    key = jax.random.PRNGKey(1)
    for i in range(n_requests):
        key, sub = jax.random.split(key)
        n = 4 + (i * 5) % 10
        eng.submit(jax.random.randint(sub, (n,), 0, cfg.vocab_size))

    t0 = time.perf_counter()
    outs = eng.run(max_new_tokens=max_new)
    dt = time.perf_counter() - t0
    total = sum(int(o.shape[0]) for o in outs)
    print(f"[{arch}] {len(outs)} requests, {total} new tokens, "
          f"{dt:.2f}s ({total / dt:.1f} tok/s)")
    for i, o in enumerate(outs[:3]):
        print(f"   req{i} -> {list(map(int, o))}")


def main():
    serve("yi-6b")                 # dense GQA + KV cache
    serve("rwkv6-7b")              # attention-free, O(1) state
    serve("recurrentgemma-2b")     # hybrid: RG-LRU + windowed cache


if __name__ == "__main__":
    main()

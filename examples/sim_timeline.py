"""One MatMulTask, three backends: dispatch it, simulate it, execute it.

    PYTHONPATH=src python examples/sim_timeline.py [--out trace.json]

Builds a Llama-style fused Gate/Up projection as one ``MatMulTask`` and
drives it through the unified ``repro.backend`` contract:

1. ``backend.get("desim")`` — ``dispatch``/``wait`` (asyncMatMul /
   checkMatmul) on the discrete-event machine model for each of the four
   CPU platforms: per-resource utilization + overlap attribution;
2. ``backend.get("jax")`` — the *same* TaskGraph executed for real
   through AsyncMatmulEngine/cute_matmul, checked against the direct
   fused matmul;
3. ``backend.get("analytical")`` — the closed-form makespan, cross-
   checked against the DES-derived one (the parity the test suite pins);
4. exports the simulated timeline as Chrome-trace JSON — open it at
   https://ui.perfetto.dev (or chrome://tracing) to see the dispatcher,
   memory loader, scratchpad banks, PE array and vector unit lanes.
"""

import argparse
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro import backend
from repro.core.fusion import Epilogue, cute_matmul
from repro.core.hardware import PLATFORMS
from repro.core.simulator import LayerTrace
from repro.core.task import MatMulTask
from repro.sim import chrome_trace, dump_chrome_trace
from repro.sim.lower import epilogue_vector_ops


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="desim_trace.json",
                    help="Chrome-trace output path (view in Perfetto)")
    args = ap.parse_args()

    # A Gate/Up-like fused int8 tile stream, small enough to eyeball: the
    # SiLU divides make the vector stream long (§5.4), so overlap shows.
    m, n, k = 256, 512, 1024
    ep = Epilogue(activation="silu", glu=True, out_dtype=jnp.float32)
    task = MatMulTask(m=m, n=n, k=k)              # int8, the paper default

    # 1. asyncMatMul on the DES backend, one per integration platform ----
    #    (PANEL granularity: GLU epilogues need full-N regions).
    print(f"{'platform':<12}{'cycles':>10}{'pe':>7}{'vec':>7}"
          f"{'loader':>8}{'disp':>7}")
    results = {}
    for name, platform in PLATFORMS.items():
        eng = backend.get("desim", platform=platform, granularity="panel")
        handle = eng.dispatch(task, epilogue=ep)      # asyncMatMul
        r = eng.wait(handle)                          # checkMatmul
        results[name] = r
        u = r.detail["utilizations"]
        print(f"{name:<12}{r.cycles:>10.0f}{u['pe_array']:>7.1%}"
              f"{u['vector_unit']:>7.1%}{u['mem_loader']:>8.1%}"
              f"{u['dispatcher']:>7.1%}")

    # Overlap attribution: the same layer, fused vs unfused schedule.
    desim = backend.get("desim", granularity="panel")
    layer = LayerTrace("gate_up", (task,),
                       vector_ops=epilogue_vector_ops(ep, m, n),
                       intermediate_bytes=4.0 * m * n)
    fused = desim.run_workload([layer], fused=True)
    unfused = desim.run_workload([layer], fused=False)
    print(f"\nfused {fused['cycles']:.0f} vs unfused {unfused['cycles']:.0f} "
          f"cycles -> overlap speedup "
          f"{unfused['cycles'] / fused['cycles']:.2f}x")

    # 2. The same graph, executed for real by the jax backend -------------
    graph = desim.lower(task, epilogue=ep)
    ka, kb = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.randint(ka, (m, k), -8, 8, jnp.int8)
    b = jax.random.randint(kb, (k, n), -8, 8, jnp.int8)
    out = backend.get("jax").run_graph(
        graph, backend.MatMulOperands(a=a, b=b)).output
    ref = cute_matmul(a, b, epilogue=ep)
    print(f"jax backend on the same graph: out {out.shape}, "
          f"max |Δ| vs cute_matmul = {float(jnp.abs(out - ref).max()):.2e}")

    # 3. Closed-form cross-check ------------------------------------------
    analytical = backend.get("analytical", granularity="panel")
    ra = analytical.run_graph(graph)
    rd = results["shuttle"]
    print(f"analytical backend: {ra.cycles:.0f} cycles "
          f"({ra.cycles / rd.cycles - 1.0:+.2%} vs desim)")

    # 4. Chrome-trace export ----------------------------------------------
    path = dump_chrome_trace(rd.timeline, args.out,
                             process_name="cutev2-desim shuttle gate_up")
    n_events = len(chrome_trace(rd.timeline)["traceEvents"])
    print(f"\nwrote {n_events} trace events to {path} "
          f"- open in https://ui.perfetto.dev")


if __name__ == "__main__":
    main()

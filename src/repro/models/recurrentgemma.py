"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local MQA.

Block pattern (arXiv:2402.19427): (recurrent, recurrent, local-attention)
repeating; every temporal block is followed by a GeGLU MLP block.  The
recurrent block is: two input projections (gate branch GeLU; rnn branch →
short causal conv1d → RG-LRU), merge by product, output projection.
Local attention is MQA (1 KV head) with window 2048 and RoPE.

26 layers = 8 × (rec, rec, attn) + 2 trailing recurrent blocks: the scan
runs the 8 triples; the remainder is applied unrolled.

Bounded state ⇒ this arch runs the ``long_500k`` cell (DESIGN.md §4).
"""

from __future__ import annotations

import sys

import jax
import jax.numpy as jnp

from repro.core.fusion import linear
from repro.distributed.logical import constrain
from repro.models import common as cm
from repro.models.base import ArchConfig, register_family


# ---------------------------------------------------------------------------
# RG-LRU + conv recurrent block.
# ---------------------------------------------------------------------------

def _rec_init(cfg: ArchConfig, key):
    d, rn = cfg.d_model, cfg.rnn
    ks = jax.random.split(key, 6)
    dt = cfg.dtype
    return {
        "w_gate_in": cm.dense_init(ks[0], (d, rn.d_rnn), dt),
        "w_rnn_in": cm.dense_init(ks[1], (d, rn.d_rnn), dt),
        "conv_w": (jax.random.normal(ks[2], (rn.conv_width, rn.d_rnn))
                   * 0.1).astype(dt),
        "conv_b": jnp.zeros((rn.d_rnn,), dt),
        # RG-LRU gates (block-diagonal dense in the reference; dense here).
        "w_input_gate": cm.dense_init(ks[3], (rn.d_rnn, rn.d_rnn), dt),
        "b_input_gate": jnp.zeros((rn.d_rnn,), dt),
        "w_rec_gate": cm.dense_init(ks[4], (rn.d_rnn, rn.d_rnn), dt),
        "b_rec_gate": jnp.zeros((rn.d_rnn,), dt),
        "lambda_p": (jax.random.uniform(ks[5], (rn.d_rnn,), jnp.float32,
                                        2.0, 6.0)),
        "w_rnn_out": cm.dense_init(ks[2], (rn.d_rnn, d), dt, in_axis=1),
    }


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv1d.  x: (B, T, C); w: (W, C).

    ``conv_state``: (B, W-1, C) trailing inputs from the previous call
    (decode); returns (y, new_state).
    """
    width = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros_like(x[:, : width - 1])
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width)) + b
    return y.astype(x.dtype), xp[:, -(width - 1):]


def _rglru_gates(cfg, p, x):
    """log_a (B, T, C) and gated input for the RG-LRU."""
    rn = cfg.rnn
    i_gate = jax.nn.sigmoid(
        linear(x, p["w_input_gate"], p["b_input_gate"]).astype(jnp.float32))
    r_gate = jax.nn.sigmoid(
        linear(x, p["w_rec_gate"], p["b_rec_gate"]).astype(jnp.float32))
    log_a = -rn.c * jax.nn.softplus(p["lambda_p"]) * r_gate
    return log_a, (i_gate * x.astype(jnp.float32))


def _rglru_seq(cfg, log_a, gated):
    if cfg.backend == "pallas":
        from repro.kernels.rglru.ops import rglru_scan
        return rglru_scan(log_a, gated.astype(jnp.float32))
    from repro.kernels.rglru.ref import rglru_ref
    return rglru_ref(log_a, gated)[0]


def rec_block_apply(cfg: ArchConfig, p, x, state=None):
    """x: (B, T, d).  state: {conv: (B, W-1, C), h: (B, C)} or None."""
    gate = linear(x, p["w_gate_in"], activation="gelu_tanh")
    rnn_in = linear(x, p["w_rnn_in"])
    conv_state = state["conv"] if state is not None else None
    rnn_in, new_conv = _causal_conv(rnn_in, p["conv_w"], p["conv_b"],
                                    conv_state)
    log_a, gated = _rglru_gates(cfg, p, rnn_in)
    if state is None:
        h = _rglru_seq(cfg, log_a, gated)
        new_state = None
    else:
        from repro.kernels.rglru.ref import rglru_ref
        h, h_final = rglru_ref(log_a, gated, initial_state=state["h"])
        new_state = {"conv": new_conv, "h": h_final}
    h = h.astype(x.dtype) * gate
    return linear(h, p["w_rnn_out"]), new_state


# ---------------------------------------------------------------------------
# Full blocks: temporal (rec | attn) + MLP, Griffin residual layout.
# ---------------------------------------------------------------------------

def _block_init(cfg: ArchConfig, key, kind: str):
    ks = jax.random.split(key, 3)
    p = {
        "ln_t": jnp.zeros((cfg.d_model,), cfg.dtype),
        "ln_mlp": jnp.zeros((cfg.d_model,), cfg.dtype),
        "mlp": cm.mlp_init(cfg, ks[1]),
    }
    if kind == "rec":
        p["temporal"] = _rec_init(cfg, ks[0])
    else:
        p["temporal"] = cm.attn_init(cfg, ks[0])
    return p


def block_apply(cfg: ArchConfig, p, x, *, kind, positions, state=None,
                cache_pos=None):
    h = cm.rmsnorm(x, p["ln_t"], cfg.rms_eps, unit_offset=True)
    if kind == "rec":
        t_out, new_state = rec_block_apply(cfg, p["temporal"], h, state)
    else:
        q, k, v = cm.qkv_project(cfg, p["temporal"], h, positions)
        if state is not None:
            k_c, v_c = cm.cache_update(state["k"], state["v"], k, v,
                                       cache_pos % cfg.window)
            # Ring-buffer local window cache: bounded at window size.
            new_state = {"k": k_c, "v": v_c}
            if q.shape[2] == 1:
                from repro.kernels.attention.ops import decode_attention
                ctx = _ring_decode(cfg, q, k_c, v_c, cache_pos)
            else:
                ctx = cm.attention(cfg, q, k, v, causal=True,
                                   window=cfg.window)
        else:
            new_state = None
            ctx = cm.attention(cfg, q, k, v, causal=True, window=cfg.window)
        t_out = cm.attn_out(cfg, p["temporal"], ctx)
    x = x + t_out
    h = cm.rmsnorm(x, p["ln_mlp"], cfg.rms_eps, unit_offset=True)
    x = x + cm.mlp_apply(cfg, p["mlp"], h)
    return constrain(x, ("batch", "seq", "embed")), new_state


def _ring_decode(cfg, q, k_cache, v_cache, pos):
    """Decode attention over a ring-buffered window cache.

    Positions are physical slots; validity = all slots once pos >= window,
    else slots < pos+1.  RoPE was applied pre-cache with absolute
    positions, so scores are position-consistent regardless of slot order.
    """
    import jax.numpy as jnp
    from repro.kernels.attention.ref import NEG_INF
    b, h, _, d = q.shape
    hkv = k_cache.shape[1]
    group = h // hkv
    qe = q.reshape(b, hkv, group, d).astype(jnp.float32)
    scores = jnp.einsum("bngd,bnsd->bngs", qe,
                        k_cache.astype(jnp.float32)) * cfg.sm_scale
    slots = jnp.arange(cfg.window)
    valid = slots[None, :] <= jnp.minimum(pos, cfg.window - 1)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngs,bnsd->bngd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, h, 1, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Stack: scan the (rec, rec, attn) triples; unroll the remainder.
# ---------------------------------------------------------------------------

def _pattern(cfg: ArchConfig):
    pat = cfg.rnn.block_pattern
    n_triples = cfg.n_layers // len(pat)
    rem = tuple(pat[i] for i in range(cfg.n_layers - n_triples * len(pat)))
    return pat, n_triples, rem


def init(cfg: ArchConfig, key):
    pat, n_triples, rem = _pattern(cfg)
    ks = jax.random.split(key, 3 + len(rem))
    v = cfg.padded_vocab
    params = {
        "embedding": cm.embed_init(ks[0], (v, cfg.d_model), cfg.dtype),
        "ln_final": jnp.zeros((cfg.d_model,), cfg.dtype),
    }
    tk = jax.random.split(ks[1], len(pat))
    params["triples"] = tuple(
        jax.vmap(lambda k, kind=kind: _block_init(cfg, k, kind))(
            jax.random.split(tk[i], n_triples))
        for i, kind in enumerate(pat))
    params["tail"] = tuple(_block_init(cfg, ks[3 + i], kind)
                           for i, kind in enumerate(rem))
    return params


def _apply_stack(cfg, params, x, positions, states=None, cache_pos=None):
    pat, n_triples, rem = _pattern(cfg)

    def body(carry, layer):
        x = carry
        lps, sts = layer if states is not None else (layer, None)
        new_sts = [] if states is not None else None
        for i in range(len(pat)):
            st = sts[i] if sts is not None else None
            x, ns = block_apply(cfg, lps[i], x, kind=pat[i],
                                positions=positions, state=st,
                                cache_pos=cache_pos)
            if new_sts is not None:
                new_sts.append(ns)
        return x, (tuple(new_sts) if new_sts is not None else None)

    if cfg.remat != "none":
        body = jax.checkpoint(body, policy=cm.remat_policy(cfg),
                              prevent_cse=False)
    xs = ((params["triples"], states["triples"]) if states is not None
          else params["triples"])
    x, ys = jax.lax.scan(body, x, xs)

    tail_states = []
    for i, lp in enumerate(params["tail"]):
        st = states["tail"][i] if states is not None else None
        x, ns = block_apply(cfg, lp, x, kind=rem[i], positions=positions,
                            state=st, cache_pos=cache_pos)
        tail_states.append(ns)
    new_states = None
    if states is not None:
        new_states = {"triples": ys, "tail": tuple(tail_states)}
    return x, new_states


def forward(cfg: ArchConfig, params, batch, return_hidden: bool = False):
    x = cm.embed_tokens(cfg, params["embedding"], batch["tokens"])
    positions = jnp.arange(x.shape[1])
    x, _ = _apply_stack(cfg, params, x, positions)
    x = cm.rmsnorm(x, params["ln_final"], cfg.rms_eps, unit_offset=True)
    if return_hidden:
        return x
    return cm.logits_out(cfg, params, x)


def _state_for(cfg, kind, batch_size, dtype):
    rn = cfg.rnn
    if kind == "rec":
        return {"conv": jnp.zeros((batch_size, rn.conv_width - 1, rn.d_rnn),
                                  dtype),
                "h": jnp.zeros((batch_size, rn.d_rnn), jnp.float32)}
    s = (batch_size, cfg.n_kv_heads, cfg.window, cfg.head_dim)
    return {"k": jnp.zeros(s, cfg.kv_cache_dtype),
            "v": jnp.zeros(s, cfg.kv_cache_dtype)}


def init_cache(cfg: ArchConfig, batch_size: int, max_len: int, dtype=None):
    del max_len                     # bounded: window cache + O(1) RNN state
    dtype = dtype or cfg.dtype
    pat, n_triples, rem = _pattern(cfg)

    def stacked(kind):
        one = _state_for(cfg, kind, batch_size, dtype)
        return jax.tree.map(
            lambda l: jnp.zeros((n_triples,) + l.shape, l.dtype), one)

    return {"triples": tuple(stacked(k) for k in pat),
            "tail": tuple(_state_for(cfg, k, batch_size, dtype)
                          for k in rem)}


def prefill(cfg: ArchConfig, params, batch, cache):
    # Prefill with bounded state: run the full sequence statefully.  The
    # attention window cache keeps the last ``window`` positions: for the
    # dry-run shapes prompt length >= window, so we refill from the tail.
    tokens = batch["tokens"]
    x = cm.embed_tokens(cfg, params["embedding"], tokens)
    positions = jnp.arange(x.shape[1])
    # Sequence-level pass (states updated at the end for the window tail).
    x_out, _ = _apply_stack(cfg, params, x, positions)
    x_last = cm.rmsnorm(x_out[:, -1], params["ln_final"], cfg.rms_eps,
                        unit_offset=True)
    logits = cm.logits_out(cfg, params, x_last)
    new_cache = _prefill_states(cfg, params, batch, cache)
    return logits, new_cache


def _prefill_states(cfg, params, batch, cache):
    """Recompute bounded states for the prompt tail (window + RNN carry).

    For dry-run cost purposes this is a second bounded-length pass over
    the final ``window`` tokens; an optimized serving path would fuse it
    into the main prefill sweep.
    """
    tokens = batch["tokens"]
    s = tokens.shape[1]
    tail = min(cfg.window, s)
    x = cm.embed_tokens(cfg, params["embedding"], tokens[:, -tail:])
    positions = jnp.arange(s - tail, s)
    _, new_states = _apply_stack(cfg, params, x, positions, states=cache,
                                 cache_pos=(s - tail) % cfg.window)
    return new_states


def decode_step(cfg: ArchConfig, params, tokens, cache, pos):
    x = cm.embed_tokens(cfg, params["embedding"], tokens)
    positions = jnp.full((tokens.shape[0], 1), pos, jnp.int32)
    x, cache = _apply_stack(cfg, params, x, positions, states=cache,
                            cache_pos=pos)
    x = cm.rmsnorm(x, params["ln_final"], cfg.rms_eps, unit_offset=True)
    return cm.logits_out(cfg, params, x[:, -1]), cache


register_family("griffin")(sys.modules[__name__])

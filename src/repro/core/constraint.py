"""The compute–bandwidth constraint model (paper Eq. 2) — both levels.

Level 1 (the paper's): size the scratchpad so that, under output-
stationary scheduling, the memory loader can keep the PE array busy.
Per unit of K, a resident ``(M_scp, N_scp)`` output tile costs

    compute cycles = M_scp · N_scp / (M_pe · N_pe · K_pe_elems)
    memory  cycles = (M_scp + N_scp) · elem_bytes / bytes_per_cycle

The utilization-guaranteeing direction is ``memory ≤ compute`` (PE never
starves), which yields a *minimum* scratchpad tile.  The paper's Eq. 2 is
printed with the opposite inequality ("compute ≤ memory"); as written it
would bound the scratchpad from *above* and would contradict Fig. 7
(lower bandwidth ⇒ larger scratchpad).  We implement the physical
direction and keep ``paper_eq2_lhs_rhs`` so the reproduction tests can
exercise the printed form too.  See DESIGN.md §2.

Level 2 (the TPU adaptation): the same inequality applied twice —
  * HBM→VMEM: choose the Pallas GEMM tile ``(bm, bn, bk)`` so that the
    MXU time of one tile ≥ its DMA time, under the VMEM capacity bound.
  * ICI: choose how much of a weight matrix to keep chip-resident vs.
    re-gather, comparing matmul time against link time.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.config import MatrixUnitConfig
from repro.core.hardware import TpuChip, TARGET_CHIP
from repro.core.precision import DataType, policy


# ---------------------------------------------------------------------------
# Level 1: the paper's scratchpad constraint.
# ---------------------------------------------------------------------------

def compute_cycles_per_k(cfg: MatrixUnitConfig, dt: DataType,
                         m_scp: int = None, n_scp: int = None) -> float:
    m = cfg.m_scp if m_scp is None else m_scp
    n = cfg.n_scp if n_scp is None else n_scp
    return m * n / (cfg.m_pe * cfg.n_pe * cfg.k_pe_elems(dt))


def memory_cycles_per_k(cfg: MatrixUnitConfig, dt: DataType,
                        m_scp: int = None, n_scp: int = None) -> float:
    m = cfg.m_scp if m_scp is None else m_scp
    n = cfg.n_scp if n_scp is None else n_scp
    return (m + n) * policy(dt).bytes_per_elem / cfg.bytes_per_cycle()


def feeds_pe_array(cfg: MatrixUnitConfig, dt: DataType = DataType.INT8) -> bool:
    """True iff the memory system can keep the PE array saturated."""
    return memory_cycles_per_k(cfg, dt) <= compute_cycles_per_k(cfg, dt)


def ideal_utilization(cfg: MatrixUnitConfig, dt: DataType = DataType.INT8) -> float:
    """Steady-state PE utilization bound implied by the constraint model."""
    c = compute_cycles_per_k(cfg, dt)
    m = memory_cycles_per_k(cfg, dt)
    return min(1.0, c / m) if m > c else 1.0


def paper_eq2_lhs_rhs(cfg: MatrixUnitConfig, dt: DataType = DataType.INT8):
    """Eq. 2 exactly as printed: (M·N·K)/(F·Mpe·Npe·Kpe) vs ((M+N)·K)/BW.

    Returned in seconds, K = K_scp.  (K cancels in the comparison; we keep
    it for fidelity to the printed form.)
    """
    k = cfg.k_scp_bytes / policy(dt).bytes_per_elem
    lhs = (cfg.m_scp * cfg.n_scp * k) / (
        cfg.freq_hz * cfg.m_pe * cfg.n_pe * cfg.k_pe_elems(dt))
    rhs = ((cfg.m_scp + cfg.n_scp) * k * policy(dt).bytes_per_elem) / cfg.bandwidth
    return lhs, rhs


def solve_scratchpad(cfg: MatrixUnitConfig, dt: DataType = DataType.INT8,
                     max_tile: int = 1024) -> "tuple[int, int]":
    """Smallest square power-of-two (M_scp, N_scp) that saturates the PEs.

    Square tiles minimise (M+N) loads per output element, matching the
    paper's symmetric choices (64×64 for the case study).
    """
    t = 16
    while t <= max_tile:
        if (memory_cycles_per_k(cfg, dt, t, t)
                <= compute_cycles_per_k(cfg, dt, t, t)):
            return t, t
        t *= 2
    return max_tile, max_tile


# ---------------------------------------------------------------------------
# Level 2a: TPU tile solver (HBM → VMEM).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TileConfig:
    """Pallas GEMM tile — the TPU-side 'scratchpad configuration'."""

    bm: int
    bn: int
    bk: int
    vmem_bytes: int
    compute_s: float      # per-tile MXU time at peak
    dma_s: float          # per-tile HBM time at peak

    @property
    def compute_bound(self) -> bool:
        return self.compute_s >= self.dma_s

    @property
    def ideal_utilization(self) -> float:
        return min(1.0, self.compute_s / max(self.dma_s, 1e-30))


def tile_vmem_bytes(bm: int, bn: int, bk: int, in_bytes: float,
                    accum_bytes: int = 4, buffers: int = 2) -> int:
    """VMEM working set: double-buffered A/B blocks + resident fp32 accum."""
    return int(buffers * (bm * bk + bk * bn) * in_bytes + bm * bn * accum_bytes)


def tile_times(bm: int, bn: int, bk: int, dt: DataType,
               chip: TpuChip = TARGET_CHIP) -> "tuple[float, float]":
    pol = policy(dt)
    peak = chip.peak_int8 if dt == DataType.INT8 else chip.peak_bf16
    compute_s = 2.0 * bm * bn * bk / peak
    dma_s = (bm * bk + bk * bn) * pol.bytes_per_elem / chip.hbm_bw
    return compute_s, dma_s


def solve_tiles(dt: DataType = DataType.BF16, chip: TpuChip = TARGET_CHIP,
                vmem_frac: float = 0.5, bk: int = 512,
                lane: int = 128) -> TileConfig:
    """Pick (bm, bn, bk) under Eq. 2 logic with TPU constants.

    Grow the square output tile in MXU-aligned steps until compute per
    tile covers DMA per tile, subject to the VMEM budget.  ``bk`` defaults
    to a K-panel deep enough to amortise the MXU pipeline (≥ 128, several
    lanes of the systolic array).
    """
    budget = chip.vmem_bytes * vmem_frac
    pol = policy(dt)
    best = None
    t = lane
    while True:
        vm = tile_vmem_bytes(t, t, bk, pol.bytes_per_elem)
        if vm > budget:
            break
        c, d = tile_times(t, t, bk, dt, chip)
        best = TileConfig(t, t, bk, vm, c, d)
        if c >= d:          # constraint satisfied — smallest such tile
            return best
        t += lane
    if best is None:
        raise ValueError("even the minimal tile exceeds the VMEM budget")
    return best             # bandwidth-bound: biggest tile that fits


# ---------------------------------------------------------------------------
# Level 2b: ICI shard constraint (the cross-chip reapplication).
# ---------------------------------------------------------------------------

def ici_gather_is_hidden(flops_per_chip: float, gather_bytes: float,
                         dt: DataType = DataType.BF16,
                         chip: TpuChip = TARGET_CHIP) -> bool:
    """Can an all-gather of ``gather_bytes`` hide behind the matmul?

    The distributed analogue of Eq. 2: collective time ≤ compute time
    means a weight-gathering sharding (e.g. ZeRO-3-style) costs nothing
    extra once overlapped; otherwise prefer keeping that operand resident
    (the 'scratchpad' at cluster scale is chip HBM).
    """
    peak = chip.peak_int8 if dt == DataType.INT8 else chip.peak_bf16
    compute_s = flops_per_chip / peak
    link_s = gather_bytes / chip.ici_bw_total
    return link_s <= compute_s


def arithmetic_intensity_needed(dt: DataType = DataType.BF16,
                                chip: TpuChip = TARGET_CHIP) -> float:
    """FLOP/byte at which a chip flips memory→compute bound (ridge point)."""
    peak = chip.peak_int8 if dt == DataType.INT8 else chip.peak_bf16
    return peak / chip.hbm_bw

"""Backend registry: names -> Backend classes, the zoo's default matmul
route, and the tuned capability-dispatch layer.

``get("desim", unit=..., granularity="panel")`` is the one lookup every
front door (serving, launch, benchmarks, examples, tests) goes through;
registering a new engine (multi-core DES, sharded execution, ...) is a
``@register("name")`` decoration away and every front door picks it up.

``get_tuned`` is the capability-aware variant: it resolves the best
autotuned kernel configuration for (current platform × shape class)
from the :mod:`repro.tune` cache and folds it into the constructor
kwargs.  Dispatch precedence, everywhere: **explicit argument > tuned
cache > untuned default** — passing any kwarg explicitly always wins,
and a missing/invalid cache silently degrades to the untuned defaults.
"""

from __future__ import annotations

from typing import Callable, Optional, Type

from repro.backend.base import Backend

_REGISTRY: "dict[str, Type[Backend]]" = {}

#: spelling compatibility: old benchmark/engine names -> registry names.
ALIASES = {"analytic": "analytical", "xla": "jax"}


def register(name: str, *,
             override: bool = False) -> Callable[[Type[Backend]], Type[Backend]]:
    """Register a Backend class under ``name``.

    Re-registering the *same* class is idempotent (module re-import
    safety); registering a different class under a taken name raises
    unless ``override=True`` — silent replacement has bitten every
    plugin registry ever.
    """
    def deco(cls: Type[Backend]) -> Type[Backend]:
        existing = _REGISTRY.get(name)
        if existing is not None and existing is not cls and not override:
            raise ValueError(
                f"backend name {name!r} already registered to "
                f"{existing.__name__}; pass register({name!r}, "
                f"override=True) to replace it")
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def resolve(name: str) -> str:
    canon = ALIASES.get(name, name)
    if canon not in _REGISTRY:
        raise KeyError(
            f"unknown backend {name!r}; registered: {available()} "
            f"(aliases: {dict(ALIASES)})")
    return canon


def get(name: str, **kwargs) -> Backend:
    """Instantiate a registered backend by name (aliases accepted)."""
    return _REGISTRY[resolve(name)](**kwargs)


def available() -> "tuple[str, ...]":
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# The model zoo's matmul route.  ``core.fusion.linear`` calls are resolved
# through here so the zoo speaks registry vocabulary; the default stays on
# the eager jax backend because Pallas-everywhere is too slow under
# interpret mode on CPU for whole-model tests (per-kernel coverage lives
# in tests/).
# ---------------------------------------------------------------------------

_DEFAULT_MATMUL = "jax"


def set_default_matmul_backend(name: str) -> str:
    """Route the model zoo's ``linear``/``cute_matmul`` calls through a
    different executing backend.  Returns the previous setting."""
    global _DEFAULT_MATMUL, _MATMUL_SET_EXPLICITLY
    canon = resolve(name)
    cls = _REGISTRY[canon]
    if not cls.executes or cls.models_time:
        raise ValueError(
            f"backend {canon!r} is not an eager matmul route for the "
            "model zoo; use 'jax' or 'pallas' (modelling backends price "
            "schedules, they don't serve projections)")
    prev, _DEFAULT_MATMUL = _DEFAULT_MATMUL, canon
    _MATMUL_SET_EXPLICITLY = True
    return prev


def default_matmul_backend() -> str:
    return _DEFAULT_MATMUL


def matmul_backend_string(name: Optional[str] = None,
                          shape: "Optional[tuple]" = None) -> str:
    """The ``cute_matmul(backend=...)`` string for a registry name.

    ``name=None`` resolves the default route with tuned-dispatch
    precedence: an explicit ``set_default_matmul_backend`` setting wins;
    otherwise, when ``shape`` (``(m, n, k)``) is given and the current
    platform's tuning cache pins a route for that shape class, the tuned
    route is used; else the untuned default (``"jax"`` → ``"xla"``).
    """
    if name is None and shape is not None and not _MATMUL_SET_EXPLICITLY:
        cfg = tuned_config(shape=shape)
        if cfg is not None and cfg.route is not None:
            return cfg.route
    cls = _REGISTRY[resolve(name or _DEFAULT_MATMUL)]
    s = getattr(cls, "matmul_string", None)
    if s is None:
        raise ValueError(f"backend {cls.name!r} has no cute_matmul route")
    return s


# ---------------------------------------------------------------------------
# Tuned capability dispatch (the runtime consumer of ``repro.tune``).
# ---------------------------------------------------------------------------

_DISPATCH_PLATFORM = "shuttle"       # the repo's canonical platform
_TUNED_DISPATCH = True
_MATMUL_SET_EXPLICITLY = False


def set_dispatch_platform(platform) -> str:
    """Pin the platform the tuned dispatch resolves against (a name from
    ``repro.core.hardware.PLATFORMS`` or a ``CpuPlatform``).  Returns
    the previous name."""
    global _DISPATCH_PLATFORM
    prev = _DISPATCH_PLATFORM
    _DISPATCH_PLATFORM = _platform_name(platform)
    return prev


def dispatch_platform() -> str:
    return _DISPATCH_PLATFORM


def set_tuned_dispatch(enabled: bool) -> bool:
    """Process-wide kill switch for the tuned cache (explicit arguments
    and untuned defaults are unaffected).  Returns the previous state."""
    global _TUNED_DISPATCH
    prev, _TUNED_DISPATCH = _TUNED_DISPATCH, bool(enabled)
    return prev


def tuned_dispatch_enabled() -> bool:
    return _TUNED_DISPATCH


def _platform_name(platform) -> str:
    from repro.core.hardware import PLATFORMS
    name = getattr(platform, "name", platform)
    if name is None:
        return _DISPATCH_PLATFORM
    if name not in PLATFORMS:
        raise KeyError(f"unknown platform {name!r}; known: "
                       f"{sorted(PLATFORMS)}")
    return name


def tuned_config(*, shape=None, sched=None, bucket: Optional[str] = None,
                 platform=None):
    """The cached :class:`~repro.tune.space.TunedConfig` for (platform ×
    shape class), or ``None`` when untuned (no cache entry, dispatch
    disabled, or no shape class derivable).

    The shape class comes from ``bucket`` (a literal cache key),
    ``sched`` (a serving ``BatchSchedule``), or ``shape`` (an ``(m, n,
    k)`` tuple or a ``MatMulTask``), in that precedence order.
    """
    if not _TUNED_DISPATCH:
        return None
    from repro import tune
    if bucket is None:
        if sched is not None:
            bucket = tune.schedule_bucket(sched)
        elif shape is not None:
            if hasattr(shape, "m"):
                shape = (shape.m, shape.n, shape.k)
            bucket = f"gemm|{tune.shape_bucket(*shape)}"
        else:
            return None
    return tune.lookup(_platform_name(platform), bucket)


def get_tuned(name: str, *, shape=None, sched=None,
              bucket: Optional[str] = None, **explicit) -> Backend:
    """Instantiate ``name`` with the best tuned configuration for the
    current platform and the given shape class.

    Explicit kwargs win over tuned ones; tuned ones win over the
    backend's untuned defaults; with no usable cache entry this is
    exactly ``get(name, **explicit)``.  Tuned kwargs a backend cannot
    accept (``k_stream`` on single-unit engines) are dropped, and a
    tuned ``overlap`` choice is applied by the serving engine (it is a
    schedule attribute, not a constructor kwarg).
    """
    cls = _REGISTRY[resolve(name)]
    cfg = tuned_config(shape=shape, sched=sched, bucket=bucket,
                       platform=explicit.get("platform"))
    kw: dict = {}
    if cfg is not None:
        from repro.core.config import CASE_STUDY
        base_unit = explicit.get("unit", CASE_STUDY)
        kw = cfg.backend_kwargs(base_unit)
        if not cls.supports_units:
            kw.pop("k_stream", None)
    kw.update(explicit)
    return cls(**kw)

"""Versioned, byte-deterministic tuning cache.

One JSON file per platform under ``src/repro/tune/cache/``, keyed by
shape bucket (``gemm|decode``, ``gemm|prefill``, ``sched|u2|decode``,
…).  Each entry stores the winning :class:`~repro.tune.space.TunedConfig`
(sparse — only non-default fields) plus the analytical and DES prices
that elected it, so a reader can audit *why* a variant won without
re-running the search.

Determinism is a contract: the same platform + budget re-tuned on the
same tree must write byte-identical files (``sort_keys`` JSON, floats
rounded to 3 decimals, no timestamps or hostnames).  A schema bump
(:data:`SCHEMA_VERSION`) invalidates old files — loaders treat a
mismatched version as "untuned" rather than guessing.
"""

from __future__ import annotations

import json
import pathlib
from typing import Optional

from repro.tune.space import TunedConfig

SCHEMA_VERSION = 1

#: shipped caches live next to the package so an installed tree is tuned
#: out of the box; tests/CI may point elsewhere via the ``path=`` args.
CACHE_DIR = pathlib.Path(__file__).resolve().parent / "cache"


def cache_path(platform_name: str,
               cache_dir: Optional[pathlib.Path] = None) -> pathlib.Path:
    return pathlib.Path(cache_dir or CACHE_DIR) / f"{platform_name}.json"


def _round(x):
    if isinstance(x, float):
        return round(x, 3)
    if isinstance(x, dict):
        return {k: _round(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_round(v) for v in x]
    return x


def dump_cache(platform_name: str, entries: dict) -> str:
    """Serialize ``{bucket: entry}`` to the canonical byte form.

    Entries are dicts with ``config`` (sparse TunedConfig fields) and
    ``metrics`` (floats, rounded here).  Key order, float precision and
    the trailing newline are all pinned so reruns diff clean.
    """
    doc = {
        "schema_version": SCHEMA_VERSION,
        "platform": platform_name,
        "entries": _round(entries),
    }
    return json.dumps(doc, indent=2, sort_keys=True) + "\n"


def save_cache(platform_name: str, entries: dict,
               cache_dir: Optional[pathlib.Path] = None) -> pathlib.Path:
    path = cache_path(platform_name, cache_dir)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(dump_cache(platform_name, entries))
    _MEMO.pop((platform_name, str(path.parent)), None)
    return path


def load_cache(platform_name: str,
               cache_dir: Optional[pathlib.Path] = None) -> dict:
    """``{bucket: entry}`` for one platform; ``{}`` when there is no
    usable cache (missing file, unreadable JSON, or a schema mismatch —
    an old cache must degrade to "untuned", never to a crash)."""
    path = cache_path(platform_name, cache_dir)
    try:
        doc = json.loads(path.read_text())
    except (OSError, ValueError):
        return {}
    if not isinstance(doc, dict) or doc.get("schema_version") != SCHEMA_VERSION:
        return {}
    entries = doc.get("entries")
    return entries if isinstance(entries, dict) else {}


_MEMO: "dict[tuple[str, str], dict]" = {}


def lookup(platform_name: str, bucket: str,
           cache_dir: Optional[pathlib.Path] = None) -> Optional[TunedConfig]:
    """The tuned config for (platform, bucket), or ``None`` when that
    pair is untuned.  Cache files are memoized per process; call
    :func:`clear_memo` after writing caches out-of-band."""
    key = (platform_name, str(pathlib.Path(cache_dir or CACHE_DIR)))
    if key not in _MEMO:
        _MEMO[key] = load_cache(platform_name, cache_dir)
    entry = _MEMO[key].get(bucket)
    if not entry or "config" not in entry:
        return None
    try:
        return TunedConfig.from_dict(entry["config"])
    except (TypeError, ValueError):
        return None


def clear_memo() -> None:
    _MEMO.clear()

"""The closed-form backend: ``core.simulator`` behind the same contract.

``dispatch``/``run_graph`` price a TaskGraph with a closed-form pipeline
model over the *same* per-tile costs the DES charges (``tile_costs``):
per layer group, the steady state runs the slower of the matrix-tile
stream ``max(compute, load+writeback)`` and the CPU dispatch stream,
with the first load exposed as fill and the last compute/writeback/
status-poll as drain; fused epilogues overlap as ``max(matrix, vector)``
with one epilogue share exposed (paper Listing 1).  Where the desim
backend *derives* the makespan from the event schedule, this backend
asserts it — the cross-backend parity suite pins the two within ~1%.

``units > 1`` (or an explicit — possibly heterogeneous —
``ClusterTopology``) switches to the **contention-aware cluster form**:
the graph is sharded by ``sim.partition`` exactly as ``desim-cluster``
would shard it, each unit's stream is priced with that unit's own
geometry and k-streamed fill, and the shared memory loader is priced as
a processor-sharing server: a unit's transfers are derated by the
M/G/1-PS slowdown ``1 / (1 - ρ_other)`` (capped at the number of
contending units), where ``ρ_other`` is the fraction of the group
makespan the *other* units' traffic occupies — solved by a short fixed
point, with the pool's aggregate capacity ``Σ shared work`` as the
saturation bound.  Validated ≤5% against ``desim-cluster`` on the paper
GEMM regime, so ``ServingEngine.plan`` can price (policy × partition ×
topology) candidates without running the DES.

``run_workload`` is ``simulate_workload`` verbatim for a single unit
(the paper's model-level analytical numbers) and the per-layer cluster
form for ``units > 1``.  No array outputs are produced — this backend
answers "how long", not "what".
"""

from __future__ import annotations

from typing import Callable

from repro.backend.base import ExecResult, GraphOperands, MatMulOperands
from repro.backend.cluster_backend import PartitionedBackend
from repro.backend.registry import register
from repro.core.fusion import Epilogue, NO_EPILOGUE
from repro.core.task import MatMulTask
from repro.obs import instrument
from repro.sim.lower import step_label

#: fixed-point sweeps for the shared-loader slowdown (converges in 2-3).
_CONTENTION_ITERS = 6


@register("analytical")
class AnalyticalBackend(PartitionedBackend):
    """First-order cost estimates from the closed-form model."""

    models_time = True

    def __init__(self, units: int = 1, strategy: str = "row-panel",
                 k_stream: bool = True, **kw):
        """``k_stream`` defaults on for every form — the single-unit
        closed form folds the first-chunk fill term exactly like the
        cluster form, matching the K-streamed machine ``simulate_graph``
        runs (parity re-baselined in ``tests/test_backend.py``, now
        within float noise on the GEMM regime).  ``k_stream=False``
        restores the legacy whole-tile-fill pricing for graphs simulated
        on a ``ClusterTopology(k_stream=False)`` machine."""
        super().__init__(units=units, strategy=strategy,
                         k_stream=k_stream, **kw)

    @property
    def _cluster(self) -> bool:
        return self.units > 1 or self._topology is not None

    def _stage(self, task: MatMulTask, operands: MatMulOperands,
               epilogue: Epilogue) -> Callable[[], ExecResult]:
        ep = None if epilogue is NO_EPILOGUE else epilogue
        graph = self.lower(task, epilogue=ep)
        if self._cluster:
            graph = self.partition(graph)
        return lambda: self.run_graph(graph)

    @instrument("run_graph")
    def run_graph(self, graph, operands: GraphOperands = None) -> ExecResult:
        """Closed-form makespan of a TaskGraph, mirroring the DES pipeline.

        Nodes are grouped by layer (successive layers of a schedule graph
        serialise on the dependency chain); within a group the matrix
        stream is ``fill + Σ max(compute, load+writeback) + drain``
        raced against the serial dispatch/check stream, and fused vector
        work overlaps it as ``max(matrix, vector)`` plus one exposed
        epilogue share.  Unfused groups (an explicit memory round-trip)
        serialise matrix, memory and vector phases.  With ``units > 1``
        the same walk runs per (group, unit) on the partitioned graph
        with the contention-aware shared-loader derate.
        """
        if self._cluster:
            return self._run_graph_cluster(graph)
        from repro.sim.desim import build_machine, tile_chunks, tile_costs
        machine = build_machine(self.unit, self.platform, self.vector)
        raw_bpc = self.unit.bandwidth / self.unit.freq_hz
        plat = self.platform
        groups: "dict[str, dict]" = {}
        order: "list[str]" = []
        ideal = 0.0
        for node in graph.topo_order():
            key = step_label(node.layer)
            if key not in groups:
                groups[key] = {"tiles": [], "nodes": [], "vec": 0.0,
                               "n_vec": 0, "mem": 0.0, "release": 0.0}
                order.append(key)
            g = groups[key]
            g["release"] = max(g["release"], node.release_time)
            if node.kind == "matmul":
                g["tiles"].append(tile_costs(machine, node))
                g["nodes"].append(node)
                ideal += (node.task.macs
                          / self.unit.macs_per_cycle(node.task.data_type))
            elif node.kind == "vector":
                g["vec"] += self.vector.cycles_for(node.vector_ops)
                g["n_vec"] += 1
            elif node.kind == "memory":
                g["mem"] += node.mem_bytes / machine.bytes_per_cycle

        cycles = 0.0
        spans: "dict[str, tuple[float, float]]" = {}
        detail = {"matrix": 0.0, "vector": 0.0, "memory": 0.0,
                  "dispatch": 0.0, "groups": len(order)}
        for key in order:
            g = groups[key]
            tiles, vec, mem = g["tiles"], g["vec"], g["mem"]
            # Successive groups serialise on the chain; a group also
            # waits out its release time (request arrival semantics).
            start = max(cycles, g["release"])
            if not tiles:
                cycles = start + vec + mem
                spans[key] = (start, cycles)
                detail["vector"] += vec
                detail["memory"] += mem
                continue
            # Three streams race; the slower one carries the makespan.
            # PE stream: first load exposed as fill, then back-to-back
            # computes, then the last tile's writeback / pipeline drain.
            # With k_stream the fill shrinks to the first K chunk (the
            # rest of the first tile's load hides behind its compute) and
            # the compute exposed past the loader drain shrinks to the
            # last tile's final chunk.
            last = tiles[-1]
            fill_load = tiles[0]["load"]
            last_exposed = last["compute"]
            if self.k_stream:
                first_chunks = tile_chunks(self.unit, plat, g["nodes"][0])
                fill_load = first_chunks[0][0] / raw_bpc
                last_exposed = tile_chunks(self.unit, plat,
                                           g["nodes"][-1])[-1][1]
            pe_stream = (fill_load
                         + sum(c["compute"] for c in tiles)
                         + max(last["writeback"],
                               self.unit.pe_pipeline_stages
                               + plat.check_cycles))
            # Loader stream: every load and writeback serialises through
            # the memory loader; the last compute lands after the loads
            # drain, overlapping the ~two writebacks still backlogged.
            backlog = min(len(tiles) - 1, 2) * last["writeback"]
            loader_stream = (sum(c["load"] + c["writeback"] for c in tiles)
                             + max(0.0, last_exposed - backlog))
            dispatch = len(tiles) * (plat.dispatch_cycles
                                     + plat.check_cycles)
            matrix = plat.dispatch_cycles + max(pe_stream, loader_stream,
                                                dispatch)
            if g["n_vec"] > 1 and not mem:
                # fused: the slower stream carries the group.  A compute-
                # bound group exposes the last epilogue share after the
                # final tile; a loader-bound group keeps draining queued
                # writebacks meanwhile, hiding up to that backlog; a
                # vector-bound group exposes the first tile as fill.
                share = vec / g["n_vec"]
                if loader_stream > max(pe_stream, dispatch):
                    share = max(0.0, share - 3.0 * last["writeback"])
                fill = (plat.dispatch_cycles + tiles[0]["load"]
                        + tiles[0]["compute"])
                cycles = start + max(matrix + share, fill + vec)
            else:
                # one epilogue after everything (LAYER granularity or an
                # unfused round-trip): phases serialise.
                cycles = start + matrix + vec + mem
            spans[key] = (start, cycles)
            detail["matrix"] += matrix
            detail["vector"] += vec
            detail["memory"] += mem
            detail["dispatch"] += dispatch
        detail["step_spans"] = spans
        return ExecResult(cycles=cycles, seconds=cycles / self.unit.freq_hz,
                          utilization=ideal / cycles if cycles else 0.0,
                          detail=detail)

    # ----- contention-aware cluster closed form ----------------------------
    def _run_graph_cluster(self, graph, topology=None) -> ExecResult:
        from repro.sim.desim import tile_chunks, tile_work
        part = self.partition(graph)
        topo = topology if topology is not None else self.topology()
        plat = topo.platform
        freq = topo.unit.freq_hz
        pool_bpc = topo.shared_bandwidth / freq
        mem_bpc = pool_bpc * plat.dram_efficiency

        # Group by layer, then by owning unit within a group (units run
        # a group's shards concurrently).  Groups are scheduled as a DAG
        # — a chained schedule graph degenerates to the serial walk, a
        # relaxed one lets hazard-free groups overlap wherever their
        # units differ (per-unit availability keeps same-unit groups
        # serial, mirroring what the DES's resource contention does).
        groups: "dict[str, dict]" = {}
        order: "list[str]" = []
        key_of_nid: "dict[int, str]" = {}
        ideal = 0.0
        for node in part.graph.topo_order():
            key = step_label(node.layer)
            key_of_nid[node.nid] = key
            if key not in groups:
                groups[key] = {"units": {}, "mem": 0.0, "release": 0.0,
                               "deps": set()}
                order.append(key)
            g = groups[key]
            g["release"] = max(g["release"], node.release_time)
            for d in node.deps:
                dk = key_of_nid[d]
                if dk != key:
                    g["deps"].add(dk)
            u = node.unit
            if node.kind == "memory":
                # inter-unit transfers / spills ride the shared pool.
                g["mem"] += node.mem_bytes / mem_bpc
                continue
            st = g["units"].setdefault(
                u, {"tiles": [], "vec": 0.0, "n_vec": 0})
            if node.kind == "matmul":
                cfg = topo.unit_config(u)
                private = topo.private_bandwidth(u)
                bpc = private / freq if private > 0 else pool_bpc
                # same row-buffer interleaving derate the DES charges
                # shared-pool streams (private slices never interleave).
                streams = 1 if private > 0 else topo.interleaved_streams()
                w = tile_work(cfg, plat, node, streams=streams)
                fill_bytes = (tile_chunks(cfg, plat, node,
                                          streams=streams)[0][0]
                              if topo.k_stream else w["load_eff"])
                st["tiles"].append({
                    "compute": w["compute"],
                    "load": w["load_eff"] / bpc,
                    "writeback": w["wb_eff"] / bpc,
                    "fill": fill_bytes / bpc,
                    "shared": private <= 0,
                    "cfg": cfg,
                })
                ideal += (node.task.macs
                          / cfg.macs_per_cycle(node.task.data_type))
            else:
                st["vec"] += topo.vector.cycles_for(node.vector_ops)
                st["n_vec"] += 1

        detail = {"groups": len(order), "memory": 0.0}

        def place(bg: "dict[str, tuple[float, int]]"):
            """One DAG placement pass; ``bg`` carries each group's
            concurrent *background* loader traffic (cycles of other
            groups' shared work inside its window, and how many foreign
            units contend) into the PS fixed point."""
            cycles = 0.0
            shared_total = 0.0
            mem_total = 0.0
            unit_free = [0.0] * topo.n_units
            end: "dict[str, float]" = {}
            spans: "dict[str, tuple[float, float]]" = {}
            group_shared: "dict[str, float]" = {}
            for key in order:
                g = groups[key]
                extra, n_bg = bg.get(key, (0.0, 0))
                shared, unit_times = self._cluster_group_cycles(
                    g, plat, background=extra, bg_units=n_bg)
                group_shared[key] = shared
                base = max([g["release"]] + [end[d] for d in g["deps"]],
                           default=0.0)
                g_end = base
                for u, tu in unit_times.items():
                    s_u = max(base, unit_free[u])
                    unit_free[u] = s_u + tu
                    g_end = max(g_end, unit_free[u])
                # pool-capacity floor + serialised transfer traffic.
                g_end = max(g_end, base + shared) + g["mem"]
                end[key] = g_end
                spans[key] = (base, g_end)
                cycles = max(cycles, g_end)
                shared_total += shared + g["mem"]
                mem_total += g["mem"]
            return cycles, shared_total, mem_total, spans, group_shared

        def cross_group_bg(spans, group_shared):
            """Overlap-weighted background traffic per group from the
            previous pass's windows: group *h*'s shared work lands in
            group *g* proportionally to their window overlap.  Empty for
            any chained schedule (dep-serialised windows never overlap),
            which keeps those placements bit-identical to the
            single-pass form."""
            bg: "dict[str, tuple[float, int]]" = {}
            for key in order:
                s0, e0 = spans[key]
                extra, foreign = 0.0, set()
                for other in order:
                    if other == key or group_shared[other] <= 0.0:
                        continue
                    s1, e1 = spans[other]
                    ov = min(e0, e1) - max(s0, s1)
                    if ov <= 0.0 or e1 <= s1:
                        continue
                    extra += group_shared[other] * ov / (e1 - s1)
                    foreign.update(
                        u for u, st in groups[other]["units"].items()
                        if any(t["shared"] for t in st["tiles"]))
                if extra > 0.0:
                    bg[key] = (extra, len(foreign))
            return bg

        # Pass 1 prices every group's fixed point in isolation; when the
        # relaxed DAG actually overlapped groups, re-derate each group
        # with the concurrent groups' loader traffic and re-place (the
        # windows stretch, so one refinement pass re-measures overlap).
        bg: "dict[str, tuple[float, int]]" = {}
        cycles, shared_total, mem_total, spans, group_shared = place(bg)
        for _ in range(2):
            new_bg = cross_group_bg(spans, group_shared)
            if not new_bg or new_bg == bg:
                break
            bg = new_bg
            cycles, shared_total, mem_total, spans, group_shared = \
                place(bg)
        detail["memory"] = mem_total
        detail["rederated_groups"] = len(bg)
        detail["loader_utilization"] = (shared_total / cycles
                                        if cycles else 0.0)
        detail["step_spans"] = spans
        detail["partition"] = {"strategy": part.strategy,
                               "n_units": part.n_units,
                               "transfers": part.n_transfers,
                               "transfer_bytes": part.transfer_bytes}
        n = topo.n_units
        return ExecResult(
            cycles=cycles, seconds=cycles / freq,
            utilization=ideal / (cycles * n) if cycles else 0.0,
            detail=detail)

    def _cluster_group_cycles(self, g: dict, plat, background: float = 0.0,
                              bg_units: int = 0) -> "tuple[float, dict]":
        """One layer group on the cluster: per-unit streams raced
        concurrently, shared-loader traffic derated by the PS slowdown
        fixed point (the caller applies the pool-capacity floor when
        placing the group).  ``background`` is loader traffic from
        *other* groups concurrently in flight (cycles of shared work
        falling inside this group's window, spread over ``bg_units``
        foreign units) — it joins every unit's ``ρ_other`` and raises
        the contender cap, so an overlapped relaxed group sees the
        whole pool's load the way the DES makes it.  Returns ``(shared
        loader work, per-unit cycles at the converged slowdowns)``."""
        units = g["units"]
        if not units:
            return 0.0, {}
        shared_work = {
            u: sum(t["load"] + t["writeback"] for t in st["tiles"]
                   if t["shared"])
            for u, st in units.items()}
        total_shared = sum(shared_work.values())
        contenders = [u for u, w in shared_work.items() if w > 0]
        if background > 0.0 and not contenders:
            background = 0.0          # no shared traffic to derate

        def unit_time(u: int, s: float) -> float:
            st = units[u]
            tiles, vec = st["tiles"], st["vec"]
            if not tiles:
                return vec

            def derate(t):                 # slowdown on shared traffic only
                return s if t["shared"] else 1.0

            last = tiles[-1]
            cfg = last["cfg"]
            pe_stream = (tiles[0]["fill"] * derate(tiles[0])
                         + sum(t["compute"] for t in tiles)
                         + max(last["writeback"] * derate(last),
                               cfg.pe_pipeline_stages + plat.check_cycles))
            backlog = (min(len(tiles) - 1, 2)
                       * last["writeback"] * derate(last))
            loader_stream = (sum((t["load"] + t["writeback"]) * derate(t)
                                 for t in tiles)
                             + max(0.0, last["compute"] - backlog))
            dispatch = len(tiles) * (plat.dispatch_cycles
                                     + plat.check_cycles)
            matrix = plat.dispatch_cycles + max(pe_stream, loader_stream,
                                                dispatch)
            if st["n_vec"] > 1:
                share = vec / st["n_vec"]
                if loader_stream > max(pe_stream, dispatch):
                    share = max(0.0, share
                                - 3.0 * last["writeback"] * derate(last))
                fill = (plat.dispatch_cycles
                        + tiles[0]["load"] * derate(tiles[0])
                        + tiles[0]["compute"])
                return max(matrix + share, fill + vec)
            return matrix + vec

        slow = {u: 1.0 for u in units}
        t_group = 0.0
        for _ in range(_CONTENTION_ITERS):
            t_group = max(unit_time(u, slow[u]) for u in units)
            # pool capacity floor (own + concurrent background traffic).
            t_group = max(t_group, total_shared + background)
            cap = float(max(len(contenders) + bg_units, 1))
            for u in contenders:
                rho_other = (total_shared - shared_work[u]
                             + background) / t_group
                slow[u] = (min(cap, 1.0 / (1.0 - rho_other))
                           if rho_other < 1.0 else cap)
        unit_times = {u: unit_time(u, slow[u]) for u in units}
        return total_shared, unit_times

    @instrument("run_workload")
    def run_workload(self, layers, *, fused=None, unit=None, platform=None,
                     vector=None):
        fused = self.fused if fused is None else fused
        if self._cluster:
            return self._run_workload_cluster(
                layers, fused=fused,
                topology=self.topology(unit, platform, vector))
        from repro.core.simulator import simulate_workload
        return simulate_workload(
            unit or self.unit, layers,
            platform=platform or self.platform,
            vector=vector or self.vector, fused=fused)

    def _run_workload_cluster(self, layers, *, fused: bool, topology):
        """``sim.lower.cluster_workload``'s dict shape, priced by the
        closed form instead of the DES: per layer, partition the graph
        across the topology's units and apply the contended formula."""
        from repro.sim.lower import aggregate_cluster_workload, \
            layer_to_graph

        def price_layer(layer):
            graph, _ = layer_to_graph(topology.unit, layer, fused=fused,
                                      granularity=self.granularity,
                                      platform=topology.platform)
            part = self.partition(graph)
            r = self._run_graph_cluster(part, topology)
            ideal = r.utilization * r.cycles * topology.n_units
            return {
                "cycles": r.cycles,
                "matrix": ideal,       # first order: busy PE == ideal
                "vector": sum(topology.vector.cycles_for(n.vector_ops)
                              for n in part.graph.vector_nodes()),
                "ideal": ideal,
                "loader_busy": r.detail["loader_utilization"] * r.cycles,
                "transfers": part.n_transfers,
            }

        return aggregate_cluster_workload(topology, layers, price_layer)

"""Property-style tests of the paged KV block allocator.

Every test here is plain deterministic pytest (no optional deps): the
allocator's contract is that behaviour is a pure function of ``(seed,
call order)``, so the properties — no double allocation, free+allocated
partitions the pool, eviction respects policy order, byte-identical
traces — are checked directly on scripted call sequences.  The
hypothesis-powered randomised version of the same properties lives in
``test_kvcache_properties.py`` (skipped when hypothesis is absent).
"""

import pytest

from repro.serving.kvcache import (
    EVICTION_POLICIES, KVPoolExhausted, PagedKVCache,
    RECOMPUTE_REFILL_FACTOR, kv_bytes_per_token, refill_cycles,
)


def cache(hot_blocks=4, block_tokens=4, policy="lru", seed=0, bpt=1.0):
    return PagedKVCache(hot_blocks=hot_blocks, block_tokens=block_tokens,
                        kv_bytes_per_token=bpt, policy=policy, seed=seed)


def check_partition(c):
    """free + allocated is a disjoint partition of the slot pool."""
    free, alloc = set(c.free_slots()), set(c.allocated_slots())
    assert free | alloc == set(range(c.hot_blocks))
    assert free & alloc == set()
    assert len(c.free_slots()) + len(c.allocated_slots()) == c.hot_blocks


# ----- construction ---------------------------------------------------------

def test_rejects_unknown_policy():
    with pytest.raises(ValueError, match="eviction policy"):
        cache(policy="mru")


def test_rejects_empty_pool():
    with pytest.raises(ValueError, match="hot_blocks"):
        cache(hot_blocks=0)


def test_rejects_zero_block_tokens():
    with pytest.raises(ValueError, match="block_tokens"):
        cache(block_tokens=0)


def test_fresh_pool_is_all_free():
    c = cache(hot_blocks=7)
    assert c.free_slots() == tuple(range(7))
    assert c.allocated_slots() == ()
    check_partition(c)


def test_block_bytes_product():
    c = cache(block_tokens=8, bpt=3.0)
    assert c.block_bytes == 24.0


# ----- allocation -----------------------------------------------------------

def test_append_packs_tokens_into_blocks():
    c = cache(block_tokens=4)
    c.append(0, 10, t=1.0)
    assert [b.tokens for b in c.blocks_of(0)] == [4, 4, 2]
    assert c.tokens_of(0) == 10


def test_append_fills_tail_block_before_allocating():
    c = cache(block_tokens=4)
    c.append(0, 3, t=1.0)
    c.append(0, 2, t=2.0)
    assert [b.tokens for b in c.blocks_of(0)] == [4, 1]
    assert len(c.allocated_slots()) == 2


def test_no_double_allocation_across_requests():
    c = cache(hot_blocks=6, block_tokens=2)
    c.append(0, 4, t=1.0)
    c.append(1, 4, t=2.0)
    c.append(2, 4, t=3.0)
    slots = [b.slot for r in (0, 1, 2) for b in c.blocks_of(r) if b.hot]
    assert len(slots) == len(set(slots)) == 6
    check_partition(c)


def test_partition_invariant_through_churn():
    c = cache(hot_blocks=5, block_tokens=2)
    c.append(0, 6, t=1.0)
    check_partition(c)
    c.append(1, 4, t=2.0)       # forces eviction
    check_partition(c)
    c.ensure_resident(0, t=3.0)
    check_partition(c)
    c.release(1, t=4.0)
    check_partition(c)


def test_zero_token_append_is_a_noop():
    c = cache()
    assert c.append(0, 0, t=1.0) == []
    assert c.blocks_of(0) == ()
    assert c.trace == []


def test_free_list_order_is_seeded():
    a = PagedKVCache(hot_blocks=8, block_tokens=4, seed=0)
    b = PagedKVCache(hot_blocks=8, block_tokens=4, seed=1)
    a.append(0, 16, t=0.0)
    b.append(0, 16, t=0.0)
    sa = [e[3] for e in a.trace if e[0] == "alloc"]
    sb = [e[3] for e in b.trace if e[0] == "alloc"]
    assert sa != sb              # different shuffle order
    assert len(sa) == len(sb) == 4
    assert set(sa) <= set(range(8)) and set(sb) <= set(range(8))


# ----- eviction -------------------------------------------------------------

def test_eviction_is_lru_order():
    c = cache(hot_blocks=3, block_tokens=2)
    c.append(0, 2, t=1.0)
    c.append(1, 2, t=2.0)
    c.append(2, 2, t=3.0)
    evicted = c.append(3, 2, t=4.0)     # pool full -> LRU victim is rid 0
    assert [v[0] for v in evicted] == [0]
    assert c.residency(0) == 0.0


def test_touch_on_append_refreshes_lru_rank():
    c = cache(hot_blocks=3, block_tokens=2)
    c.append(0, 2, t=1.0)
    c.append(1, 2, t=2.0)
    c.append(2, 2, t=3.0)
    c.append(0, 0, t=4.0)        # no-op: does not touch
    c.append(1, 0, t=4.0)
    # rid 0 is still LRU; a real write by rid 0 re-ranks it...
    evicted = c.append(3, 2, t=5.0)
    assert [v[0] for v in evicted] == [0]


def test_real_write_protects_against_eviction():
    c = cache(hot_blocks=3, block_tokens=4)
    c.append(0, 1, t=1.0)
    c.append(1, 4, t=2.0)
    c.append(2, 4, t=3.0)
    c.append(0, 1, t=4.0)        # tail fill: rid 0 now most recent
    evicted = c.append(3, 4, t=5.0)
    assert [v[0] for v in evicted] == [1]   # rid 1 became LRU


def test_eviction_respects_policy_order_multi():
    """Victims leave in strictly ascending recency order."""
    c = cache(hot_blocks=4, block_tokens=2)
    for r, t in ((0, 1.0), (1, 2.0), (2, 3.0), (3, 4.0)):
        c.append(r, 2, t=t)
    evicted = c.append(9, 6, t=5.0)          # needs 3 slots -> 3 victims
    assert [v[0] for v in evicted] == [0, 1, 2]


def test_lru_eviction_keeps_bytes_in_dram():
    c = cache(hot_blocks=1, block_tokens=2, policy="lru")
    c.append(0, 2, t=1.0)
    c.append(1, 2, t=2.0)
    (b,) = c.blocks_of(0)
    assert not b.hot and not b.dropped and b.slot is None
    assert c.refill_bytes(0) == c.block_bytes


def test_recompute_eviction_drops_bytes():
    c = cache(hot_blocks=1, block_tokens=2, policy="recompute")
    c.append(0, 2, t=1.0)
    c.append(1, 2, t=2.0)
    (b,) = c.blocks_of(0)
    assert not b.hot and b.dropped
    assert c.refill_bytes(0) == RECOMPUTE_REFILL_FACTOR * c.block_bytes


def test_own_fresh_blocks_are_pinned():
    """One append never evicts the blocks it just allocated."""
    c = cache(hot_blocks=3, block_tokens=2)
    c.append(0, 6, t=1.0)        # fills the whole pool
    evicted = c.append(1, 4, t=2.0)
    victims = {v[0] for v in evicted}
    assert victims == {0}
    assert c.residency(1) == 1.0


def test_pool_exhausted_raises():
    c = cache(hot_blocks=2, block_tokens=2)
    with pytest.raises(KVPoolExhausted):
        c.append(0, 10, t=1.0)   # one request larger than the pool


# ----- residency + refill ---------------------------------------------------

def test_residency_defaults_hot_for_unknown_request():
    c = cache()
    assert c.residency(42) == 1.0
    assert c.refill_bytes(42) == 0.0


def test_residency_fraction():
    c = cache(hot_blocks=2, block_tokens=2)
    c.append(0, 4, t=1.0)        # 2 blocks
    c.append(1, 2, t=2.0)        # evicts one of rid 0's
    assert c.residency(0) == pytest.approx(0.5)
    assert c.residency(1) == 1.0


def test_ensure_resident_restores_and_charges():
    c = cache(hot_blocks=2, block_tokens=2, bpt=3.0)
    c.append(0, 4, t=1.0)
    c.append(1, 2, t=2.0)
    owed = c.refill_bytes(0)
    assert owed == c.block_bytes == 6.0
    charged, evicted = c.ensure_resident(0, t=3.0)
    assert charged == owed
    assert c.residency(0) == 1.0
    assert c.refill_bytes(0) == 0.0
    assert [v[0] for v in evicted] == [1]    # rid 1 paid the slot back


def test_ensure_resident_noop_when_hot():
    c = cache()
    c.append(0, 4, t=1.0)
    assert c.ensure_resident(0, t=2.0) == (0.0, [])


def test_ensure_resident_pins_own_blocks():
    c = cache(hot_blocks=2, block_tokens=2)
    c.append(0, 4, t=1.0)
    c.append(1, 2, t=2.0)        # rid 0 half cold
    charged, evicted = c.ensure_resident(0, t=3.0)
    assert charged == c.block_bytes
    assert {v[0] for v in evicted} == {1}   # never its own hot block
    assert c.residency(0) == 1.0


def test_recompute_refill_costs_double():
    c = cache(hot_blocks=1, block_tokens=2, policy="recompute")
    c.append(0, 2, t=1.0)
    c.append(1, 2, t=2.0)
    charged, _ = c.ensure_resident(0, t=3.0)
    assert charged == RECOMPUTE_REFILL_FACTOR * c.block_bytes


def test_counters_track_events():
    c = cache(hot_blocks=2, block_tokens=2)
    c.append(0, 4, t=1.0)
    c.append(1, 2, t=2.0)
    c.ensure_resident(0, t=3.0)
    c.release(0, t=4.0)
    assert c.counters["allocs"] == 3
    assert c.counters["evictions"] == 2     # one per displaced block
    assert c.counters["refills"] == 1
    assert c.counters["refill_bytes"] == c.block_bytes
    assert c.counters["frees"] == 2


# ----- release --------------------------------------------------------------

def test_release_returns_slots_to_pool():
    c = cache(hot_blocks=4, block_tokens=2)
    c.append(0, 6, t=1.0)
    assert c.release(0, t=2.0) == 3
    assert c.free_slots() == tuple(range(4))
    assert c.blocks_of(0) == ()
    check_partition(c)


def test_release_unknown_request_is_noop():
    c = cache()
    assert c.release(99, t=1.0) == 0
    assert c.trace == []


def test_release_skips_cold_blocks():
    c = cache(hot_blocks=1, block_tokens=2)
    c.append(0, 2, t=1.0)
    c.append(1, 2, t=2.0)        # rid 0 fully cold
    assert c.release(0, t=3.0) == 0
    check_partition(c)


# ----- determinism ----------------------------------------------------------

def script(c):
    c.append(0, 5, t=1.0)
    c.append(1, 7, t=2.0)
    c.append(2, 3, t=3.0)
    c.ensure_resident(0, t=4.0)
    c.append(1, 2, t=5.0)
    c.release(0, t=6.0)
    c.ensure_resident(2, t=7.0)
    return c


@pytest.mark.parametrize("policy", EVICTION_POLICIES)
def test_trace_is_byte_identical_across_runs(policy):
    a = script(cache(hot_blocks=4, block_tokens=2, policy=policy, seed=3))
    b = script(cache(hot_blocks=4, block_tokens=2, policy=policy, seed=3))
    assert a.trace == b.trace
    assert repr(a.trace) == repr(b.trace)
    assert a.trace_digest() == b.trace_digest()


def test_trace_differs_across_seeds():
    a = script(cache(hot_blocks=4, block_tokens=2, seed=0))
    b = script(cache(hot_blocks=4, block_tokens=2, seed=5))
    assert a.trace_digest() != b.trace_digest()


def test_trace_events_are_well_formed():
    c = script(cache(hot_blocks=4, block_tokens=2))
    kinds = {"alloc", "evict", "refill", "free"}
    for kind, t, rid, slot, extra in c.trace:
        assert kind in kinds
        assert isinstance(t, float)
        assert 0 <= slot < c.hot_blocks
        assert rid >= 0
    times = [e[1] for e in c.trace]
    assert times == sorted(times)


# ----- helpers --------------------------------------------------------------

def test_kv_bytes_per_token_formula():
    class Cfg:
        kv_dim = 128
        n_layers = 4
    assert kv_bytes_per_token(Cfg) == 2.0 * 128 * 4
    assert kv_bytes_per_token(Cfg, dtype_bytes=2.0) == 2.0 * 128 * 4 * 2


def test_refill_cycles_matches_memory_node_price():
    from repro.core.config import PLATFORM_2TOPS
    from repro.core.hardware import SHUTTLE
    from repro.sim.desim import build_machine
    m = build_machine(PLATFORM_2TOPS, SHUTTLE)
    got = refill_cycles(4096.0, PLATFORM_2TOPS, SHUTTLE)
    assert got == pytest.approx(4096.0 / m.bytes_per_cycle)
    assert refill_cycles(0.0, PLATFORM_2TOPS, SHUTTLE) == 0.0
    # a units-wide pool moves the same bytes units times faster.
    assert refill_cycles(4096.0, PLATFORM_2TOPS, SHUTTLE, units=4) \
        == pytest.approx(got / 4)

"""The canonical Llama-style decode regime the tuner targets.

One deterministic serving queue — the yi-6b reduced config (a
Llama-style GQA decoder), six requests with 64 + 32·i-token prompts,
batch width 2 — planned with the decode-priority policy so the drain is
dominated by skinny-M decode steps.  This mirrors the serving bench
queue in ``benchmarks/run.py`` byte for byte so the tuned speedups the
cache records price exactly the workload the tracked benches report;
it is re-declared here because ``repro.*`` must not import from the
``benchmarks/`` harness.

:func:`measure_decode_regime` prices the four (tuned × fused) corners of
one platform on the cluster DES and isolates the epilogue-fusion
contribution — the paper attributes >30% of its end-to-end serving win
to fusion, and this is where that claim is measured rather than assumed.
"""

from __future__ import annotations

import dataclasses

from repro.core.hardware import PLATFORMS
from repro.tune.space import DEFAULT_CONFIG, TunedConfig, schedule_bucket

#: the queue — identical to ``benchmarks/run.py serving_queue``.
N_REQUESTS = 6
MAX_BATCH = 2
CACHE_LEN = 256
MODEL = "yi-6b"

#: the plan — the decode-heavy drain of that queue on a 2-unit cluster.
UNITS = 2
MAX_NEW_TOKENS = 16
POLICY = "decode-priority"


def decode_regime_engine():
    """(cfg, engine) with the canonical queue submitted."""
    import jax
    from repro.configs.registry import get_config
    from repro.serving.engine import ServingEngine

    cfg = get_config(MODEL, reduced=True)
    eng = ServingEngine(cfg, params=None, max_batch=MAX_BATCH,
                        cache_len=CACHE_LEN)
    key = jax.random.PRNGKey(0)
    for i in range(N_REQUESTS):
        key, sub = jax.random.split(key)
        eng.submit(jax.random.randint(sub, (64 + 32 * i,), 0,
                                      cfg.vocab_size))
    return cfg, eng


def decode_regime_schedule(units: int = UNITS,
                           max_new_tokens: int = MAX_NEW_TOKENS,
                           policy: str = POLICY):
    """(cfg, BatchSchedule) for the canonical decode-heavy drain."""
    cfg, eng = decode_regime_engine()
    sched = eng.plan(max_new_tokens=max_new_tokens, units=units,
                     policy=policy)
    return cfg, sched


def measure_decode_regime(platform_name: str,
                          tuned: "TunedConfig | None" = None,
                          units: int = UNITS) -> "dict[str, float]":
    """Cluster-DES makespans of the four (tuned × fused) corners on one
    platform, plus the derived speedups:

    * ``speedup``        — untuned-unfused / tuned-fused, the pinned
      end-to-end win the BENCH rows record;
    * ``tuned_speedup``  — untuned default (fused) / tuned, the tuning
      dispatch win in isolation;
    * ``fusion_speedup`` — tuned-unfused / tuned, the epilogue-fusion
      contribution with every other tuned knob held fixed.

    ``tuned=None`` resolves the platform's cached winner for the
    schedule's bucket (falling back to the untuned default).
    """
    from repro.tune.autotune import measure_schedule
    from repro.tune.cache import lookup

    platform = PLATFORMS[platform_name]
    _, sched = decode_regime_schedule(units=units)
    if tuned is None:
        tuned = lookup(platform_name, schedule_bucket(sched)) or DEFAULT_CONFIG
    corners = {
        "tuned": tuned,
        "tuned_unfused": dataclasses.replace(tuned, fused=False),
        "untuned": DEFAULT_CONFIG,
        "untuned_unfused": dataclasses.replace(DEFAULT_CONFIG, fused=False),
    }
    out = {name: measure_schedule(sched, cfg, platform)
           for name, cfg in corners.items()}
    out["speedup"] = out["untuned_unfused"] / out["tuned"]
    out["tuned_speedup"] = out["untuned"] / out["tuned"]
    out["fusion_speedup"] = out["tuned_unfused"] / out["tuned"]
    return out

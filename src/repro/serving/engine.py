"""Batched serving engine on the async programming model.

The paper's asyncMatMul/checkMatmul contract shows up twice here:

* per step — every projection is a ``cute_matmul`` with fused epilogue,
  routed through the ``repro.backend`` registry default
  (``set_default_matmul_backend`` re-routes serving without touching
  this module);
* across *schedules* — ``ServingEngine.plan`` lowers the pending queue
  into a continuous-batching prefill/decode :class:`BatchSchedule` whose
  ``LayerTrace`` steps feed ``sim.lower.workload_to_graph``, so a
  batching policy can be priced on the ``desim`` backend's per-resource
  timelines (and the identical schedule graph executed bit-exactly by
  ``backend.get("jax")``) before it ever hits hardware.

``generate`` is the synchronous core: prefill the prompt batch, then a
``lax.scan`` decode loop with greedy/temperature sampling.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.precision import DataType
from repro.core.simulator import VECTOR_OP_INSTRS, LayerTrace
from repro.core.task import MatMulTask
from repro.models.base import ArchConfig, family_module


@dataclasses.dataclass
class GenerateResult:
    tokens: jax.Array          # (B, n_new)
    logits_last: jax.Array     # (B, V)
    steps: int


def make_prefill(cfg: ArchConfig):
    mod = family_module(cfg)

    def prefill_step(params, batch, cache):
        return mod.prefill(cfg, params, batch, cache)
    return prefill_step


def make_decode(cfg: ArchConfig):
    mod = family_module(cfg)

    def decode_step(params, tokens, cache, pos):
        return mod.decode_step(cfg, params, tokens, cache, pos)
    return decode_step


def sample(logits, key, temperature: float = 0.0):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature,
                                  axis=-1).astype(jnp.int32)


def generate(cfg: ArchConfig, params, batch, *, max_new_tokens: int,
             temperature: float = 0.0, key=None,
             cache_len: Optional[int] = None) -> GenerateResult:
    """Prefill + scan-decode.  batch["tokens"]: (B, S_prompt)."""
    mod = family_module(cfg)
    b, s = batch["tokens"].shape
    cache_len = cache_len or (s + max_new_tokens)
    key = key if key is not None else jax.random.PRNGKey(0)

    cache = mod.init_cache(cfg, b, cache_len)
    logits, cache = mod.prefill(cfg, params, batch, cache)
    first = sample(logits, key, temperature)

    def body(carry, step_key):
        tok, cache, pos = carry
        logits, cache = mod.decode_step(cfg, params, tok[:, None], cache,
                                        pos)
        nxt = sample(logits, step_key, temperature)
        return (nxt, cache, pos + 1), (nxt, logits)

    keys = jax.random.split(key, max_new_tokens - 1) \
        if max_new_tokens > 1 else jnp.zeros((0, 2), jnp.uint32)
    (last, cache, _), (toks, logit_seq) = jax.lax.scan(
        body, (first, cache, jnp.int32(s)), keys)
    tokens = jnp.concatenate([first[:, None], jnp.moveaxis(toks, 0, 1)],
                             axis=1)
    logits_last = (logit_seq[-1] if max_new_tokens > 1 else logits)
    return GenerateResult(tokens=tokens, logits_last=logits_last,
                          steps=max_new_tokens)


# ---------------------------------------------------------------------------
# Batch schedules: the serving queue as a TaskGraph workload.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BatchStep:
    """One continuous-batching step: a padded batch through the model."""

    kind: str                    # "prefill" | "decode"
    requests: "tuple[int, ...]"  # request ids riding this batch
    tokens: int                  # rows M entering each projection GEMM
    repeat: int                  # model layers (× decode steps for decode)


@dataclasses.dataclass
class BatchSchedule:
    """A planned drain of the queue, in the simulator's vocabulary.

    ``layers`` carries one :class:`~repro.core.simulator.LayerTrace` per
    step (a representative transformer layer's projection GEMMs + vector
    work; ``repeat`` scales it to full depth), ready for
    ``sim.lower.workload_to_graph`` / any ``repro.backend`` engine.

    ``units`` records the cluster width the schedule is planned against:
    a cluster backend (``desim-cluster`` / ``sharded``) shards every
    step's GEMMs across that many matrix units, so the same schedule is
    priced on contended multi-unit timelines.
    """

    steps: "list[BatchStep]"
    layers: "list[LayerTrace]"
    units: int = 1

    def gemm_tasks(self) -> "dict[str, MatMulTask]":
        """``{graph GEMM label: task}`` — the labels
        ``workload_to_graph`` assigns, keyed for ``run_graph`` operands."""
        return {f"{lt.name}/g{i}": g
                for lt in self.layers for i, g in enumerate(lt.gemms)}

    def example_operands(self, key, low: int = -8, high: int = 8,
                         ) -> "dict[str, tuple]":
        """Random int8 ``(a, b)`` arrays for every GEMM of the schedule —
        lets an executing backend run the identical schedule graph for
        real (the parity suite checks jax and desim agree bit-exactly)."""
        ops = {}
        for label, t in self.gemm_tasks().items():
            key, ka, kb = jax.random.split(key, 3)
            ops[label] = (jax.random.randint(ka, (t.m, t.k), low, high,
                                             jnp.int8),
                          jax.random.randint(kb, (t.k, t.n), low, high,
                                             jnp.int8))
        return ops


def _step_layer(cfg: ArchConfig, name: str, tokens: int,
                repeat: int) -> LayerTrace:
    """One serving step as a fused region: the four projection GEMMs of a
    representative transformer layer (int8, the paper's W8A8 pipeline)
    plus first-order vector work (norms, dequant, activation, residual)."""
    d = cfg.d_model
    mlp_n = cfg.d_ff * (2 if cfg.mlp_glu else 1)
    gemms = (
        MatMulTask(m=tokens, n=cfg.q_dim + 2 * cfg.kv_dim, k=d,
                   data_type=DataType.INT8),
        MatMulTask(m=tokens, n=d, k=cfg.q_dim, data_type=DataType.INT8),
        MatMulTask(m=tokens, n=mlp_n, k=d, data_type=DataType.INT8),
        MatMulTask(m=tokens, n=d, k=cfg.d_ff, data_type=DataType.INT8),
    )
    act = (cfg.mlp_activation if cfg.mlp_activation in VECTOR_OP_INSTRS
           else "eltwise_misc")
    vector_ops = {
        "rmsnorm": 2.0 * tokens * d,
        "dequant": float(sum(t.m * t.n for t in gemms)),
        act: float(tokens * cfg.d_ff),
        "residual": 2.0 * tokens * d,
    }
    if cfg.mlp_glu:
        vector_ops["glu_mul"] = float(tokens * cfg.d_ff)
    return LayerTrace(name, gemms, vector_ops=vector_ops,
                      intermediate_bytes=4.0 * tokens * mlp_n,
                      repeat=repeat)


class ServingEngine:
    """Continuous-batching façade with async prefill dispatch."""

    def __init__(self, cfg: ArchConfig, params, max_batch: int = 8,
                 cache_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self._queue: list = []

    def submit(self, tokens) -> int:
        """Queue a request; returns a request id (asyncMatMul-style)."""
        self._queue.append(jnp.asarray(tokens))
        return len(self._queue) - 1

    # ----- batch schedules -> backends -----------------------------------
    def plan(self, max_new_tokens: int = 32, units: int = 1) -> BatchSchedule:
        """Plan the continuous-batching drain of the current queue
        (non-destructive): per padded chunk, one prefill step over
        ``B × S_padded`` tokens, then ``max_new_tokens`` decode steps of
        ``B`` tokens (collapsed into one repeated LayerTrace).

        ``units`` is the cluster width the schedule targets — recorded on
        the schedule and consumed by ``evaluate_schedule`` so a cluster
        backend prices the drain on ``units`` contended matrix units."""
        steps: "list[BatchStep]" = []
        layers: "list[LayerTrace]" = []
        queue = list(self._queue)
        first = 0
        while queue:
            chunk, queue = queue[: self.max_batch], queue[self.max_batch:]
            ids = tuple(range(first, first + len(chunk)))
            first += len(chunk)
            s = max(int(t.shape[-1]) for t in chunk)
            ci = len(steps) // 2
            prefill = BatchStep("prefill", ids, tokens=len(chunk) * s,
                                repeat=self.cfg.n_layers)
            decode = BatchStep("decode", ids, tokens=len(chunk),
                               repeat=self.cfg.n_layers * max_new_tokens)
            for step in (prefill, decode):
                steps.append(step)
                layers.append(_step_layer(
                    self.cfg, f"b{ci}/{step.kind}", step.tokens,
                    step.repeat))
        return BatchSchedule(steps, layers, units=units)

    def evaluate_schedule(self, backend_name: str = "desim",
                          max_new_tokens: int = 32, operands=None,
                          units: Optional[int] = None, **backend_kwargs):
        """Price the planned schedule on a modelling backend.

        Lowers ``plan(max_new_tokens, units)`` through
        ``workload_to_graph`` at the backend's granularity/fusion policy
        and runs the graph — ``desim`` returns the per-resource timeline
        (and, given ``operands``, the executed numbers);
        ``desim-cluster`` with ``units=N`` prices the same schedule on N
        matrix units contending for the shared loader.  Returns
        ``(schedule, ExecResult)``; ``result.detail["workload"]``
        carries the repeat-weighted whole-schedule cost dict.
        """
        from repro import backend
        units = 1 if units is None else units
        backend_kwargs["units"] = units
        eng = backend.get(backend_name, **backend_kwargs)
        if not eng.models_time:
            raise ValueError(
                f"backend {backend_name!r} executes but does not model "
                "time; use 'desim' or 'analytical'")
        sched = self.plan(max_new_tokens, units=units)
        graph = eng.lower(sched.layers)
        result = eng.run_graph(graph, operands)
        result.detail["workload"] = eng.run_workload(sched.layers)
        return sched, result

    def run(self, max_new_tokens: int = 32, temperature: float = 0.0):
        """Drain the queue in padded batches; returns list of token arrays."""
        out = []
        while self._queue:
            chunk, self._queue = (self._queue[: self.max_batch],
                                  self._queue[self.max_batch:])
            s = max(int(t.shape[-1]) for t in chunk)
            toks = jnp.stack([jnp.pad(t, (s - t.shape[-1], 0)) for t in chunk])
            batch = {"tokens": toks}
            if self.cfg.encdec is not None:
                batch["audio_embeds"] = jnp.zeros(
                    (toks.shape[0], self.cfg.encdec.n_audio_ctx,
                     self.cfg.d_model), jnp.float32)
            if self.cfg.vision_prefix:
                batch["vision_embeds"] = jnp.zeros(
                    (toks.shape[0], self.cfg.vision_prefix,
                     self.cfg.d_model), jnp.float32)
            res = generate(self.cfg, self.params, batch,
                           max_new_tokens=max_new_tokens,
                           temperature=temperature,
                           cache_len=self.cache_len)
            out.extend(list(res.tokens))
        return out

"""TaskGraph IR — the unified representation of asynchronous execution.

A ``TaskGraph`` is a DAG of ``Node``s.  Three node kinds mirror the
three hardware streams of the paper's microarchitecture:

* ``matmul`` — one ``asyncMatMul`` tile task (paper Table 1 / Listing 1):
  a :class:`~repro.core.task.MatMulTask` sub-problem plus the coordinates
  of the tile inside its parent GEMM.  Produced by ``tile_tasks``.
* ``vector`` — Saturn vector-unit work: either abstract op→element-count
  costs (for simulation) or an :class:`~repro.core.fusion.Epilogue`
  (for JAX lowering), usually both.
* ``memory`` — bulk DRAM traffic with no compute (the unfused
  intermediate round-trip).

``Granularity`` configures how much vector work rides behind each
synchronisation point — the "flexible granularity" axis of the paper's
async abstraction:

* ``TILE``  — one epilogue node per matrix tile (Listing 1, max overlap);
* ``PANEL`` — one epilogue node per row-panel of tiles;
* ``LAYER`` — one epilogue node after the whole GEMM (no overlap, but
  still skips the DRAM round-trip).

The same graph is consumed by ``sim.desim`` (resource-level discrete-
event simulation) and ``sim.lower.execute_graph_jax`` (execution through
``AsyncMatmulEngine``/``cute_matmul``).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional

from repro.core.task import MatMulTask, tile_tasks


class Granularity(str, enum.Enum):
    TILE = "tile"
    PANEL = "panel"
    LAYER = "layer"


@dataclasses.dataclass(frozen=True)
class TileCoord:
    """Placement of a tile inside its parent GEMM (row-major order)."""

    m0: int
    n0: int
    m: int
    n: int


@dataclasses.dataclass
class Node:
    """One schedulable unit.  ``deps`` are node ids that must complete
    before this node may start."""

    nid: int
    kind: str                         # "matmul" | "vector" | "memory"
    name: str
    deps: "tuple[int, ...]" = ()
    layer: str = ""                   # grouping label for traces
    unit: int = 0                     # matrix unit this node runs on
    #: earliest simulated cycle this node may start, independent of its
    #: deps — how request arrival times reach the machine model (a node
    #: whose deps finish earlier simply waits in the queue until then).
    release_time: float = 0.0
    # matmul payload
    task: Optional[MatMulTask] = None
    tile: Optional[TileCoord] = None
    # vector payload — abstract costs and/or a concrete epilogue
    vector_ops: "dict[str, float]" = dataclasses.field(default_factory=dict)
    epilogue: object = None           # fusion.Epilogue for JAX lowering
    # memory payload
    mem_bytes: float = 0.0


class TaskGraph:
    """Append-only DAG; nids are dense ints in insertion (program) order."""

    def __init__(self):
        self.nodes: "list[Node]" = []

    def __len__(self) -> int:
        return len(self.nodes)

    def add(self, kind: str, name: str, deps=(), **payload) -> Node:
        for d in deps:
            if not 0 <= d < len(self.nodes):
                raise ValueError(f"dep {d} of {name!r} does not exist yet")
        node = Node(nid=len(self.nodes), kind=kind, name=name,
                    deps=tuple(deps), **payload)
        self.nodes.append(node)
        return node

    # Appending can only reference earlier nids, so insertion order *is* a
    # topological order; ``topo_order`` re-checks in case deps were edited.
    def topo_order(self) -> "list[Node]":
        seen = set()
        for node in self.nodes:
            for d in node.deps:
                if d not in seen:
                    raise ValueError(
                        f"node {node.nid} ({node.name!r}) depends on {d} "
                        "which is not earlier in program order")
            seen.add(node.nid)
        return list(self.nodes)

    def matmul_nodes(self) -> "list[Node]":
        return [n for n in self.nodes if n.kind == "matmul"]

    def vector_nodes(self) -> "list[Node]":
        return [n for n in self.nodes if n.kind == "vector"]

    def sinks(self) -> "list[Node]":
        used = {d for n in self.nodes for d in n.deps}
        return [n for n in self.nodes if n.nid not in used]

    def stats(self) -> "dict[str, int]":
        out = {"nodes": len(self.nodes), "matmul": 0, "vector": 0,
               "memory": 0, "edges": 0}
        for n in self.nodes:
            out[n.kind] += 1
            out["edges"] += len(n.deps)
        return out


def group_tiles(tiles: "list[Node]", granularity: Granularity,
                n: int, tile_n: int) -> "list[list[Node]]":
    """Group one GEMM's tile nodes (row-major order) per the granularity:
    singletons (TILE), rows of ceil(n/tile_n) tiles (PANEL), or all
    together (LAYER)."""
    if granularity == Granularity.TILE:
        return [[t] for t in tiles]
    if granularity == Granularity.PANEL:
        n_cols = max(1, -(-n // tile_n))
        return [tiles[i:i + n_cols] for i in range(0, len(tiles), n_cols)]
    return [tiles]


def _tile_coords(task: MatMulTask, tile_m: int, tile_n: int):
    """Tile coordinates in the exact order ``tile_tasks`` emits them."""
    for m0 in range(0, task.m, tile_m):
        for n0 in range(0, task.n, tile_n):
            yield TileCoord(m0, n0, min(tile_m, task.m - m0),
                            min(tile_n, task.n - n0))


def build_gemm_graph(task: MatMulTask, tile_m: int, tile_n: int, *,
                     graph: Optional[TaskGraph] = None,
                     deps=(), layer: str = "gemm",
                     granularity: Granularity = Granularity.TILE,
                     vector_ops: "dict[str, float] | None" = None,
                     epilogue=None) -> "tuple[TaskGraph, list[Node]]":
    """Tile one logical matmul into a dependency-linked task graph.

    Matrix tiles come from ``tile_tasks`` (the asyncMatMul macro).  If
    ``vector_ops``/``epilogue`` is given, vector nodes are attached at the
    requested granularity, with the abstract cost split evenly across
    them.  Returns ``(graph, sink_nodes)`` — the nodes a successor layer
    must depend on.
    """
    graph = graph if graph is not None else TaskGraph()
    subtasks = tile_tasks(task, tile_m, tile_n)
    coords = list(_tile_coords(task, tile_m, tile_n))
    assert len(subtasks) == len(coords)

    tiles = [graph.add("matmul", f"{layer}/t{c.m0//tile_m},{c.n0//tile_n}",
                       deps=deps, layer=layer, task=sub, tile=c)
             for sub, c in zip(subtasks, coords)]
    if vector_ops is None and epilogue is None:
        return graph, tiles

    groups = group_tiles(tiles, granularity, task.n, tile_n)
    share = {op: n / len(groups) for op, n in (vector_ops or {}).items()}
    vecs = [graph.add("vector", f"{layer}/vec{i}",
                      deps=tuple(t.nid for t in grp), layer=layer,
                      vector_ops=dict(share), epilogue=epilogue)
            for i, grp in enumerate(groups)]
    return graph, vecs

"""MoE: grouped GEMM kernel, dispatch/combine invariants, EP partitioning."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.registry import get_config
from repro.core.fusion import Epilogue
from repro.kernels.moe.ops import grouped_matmul
from repro.kernels.moe.ref import grouped_matmul_ref
from repro.models.moe import moe_apply_local, moe_capacity, moe_init


class TestGroupedGemm:
    @pytest.mark.parametrize("e,cap,k,n", [(4, 96, 128, 256), (2, 64, 64, 128),
                                           (8, 33, 128, 128)])
    def test_vs_oracle(self, e, cap, k, n):
        x = jax.random.normal(jax.random.PRNGKey(0), (e, cap, k), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (e, k, n), jnp.float32)
        out = grouped_matmul(x, w, block_shape=(64, 128, 64))
        ref = grouped_matmul_ref(x, w, epilogue=Epilogue(out_dtype=jnp.float32))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=1e-4)

    def test_glu_epilogue(self):
        e, cap, k, n = 4, 64, 128, 128
        x = jax.random.normal(jax.random.PRNGKey(0), (e, cap, k), jnp.bfloat16)
        w = jax.random.normal(jax.random.PRNGKey(1), (e, k, 2 * n),
                              jnp.bfloat16)
        ep = Epilogue(activation="silu", glu=True, out_dtype=jnp.bfloat16)
        out = grouped_matmul(x, w, epilogue=ep, block_shape=(64, 128, 64))
        ref = grouped_matmul_ref(x, w.reshape(e, k, 2, n), epilogue=ep)
        o, r = np.asarray(out, np.float32), np.asarray(ref, np.float32)
        assert np.abs(o - r).max() / (np.abs(r).max() + 1e-9) < 2e-2


def _setup(arch="olmoe-1b-7b", t=64, seed=0):
    cfg = get_config(arch, reduced=True).with_(dtype=jnp.float32,
                                               backend="xla")
    p = moe_init(cfg, jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (t, cfg.d_model), jnp.float32)
    return cfg, p, x


class TestDispatch:
    def test_full_capacity_matches_dense_reference(self):
        """With capacity >= T·k, nothing drops: output == explicit top-k sum."""
        cfg, p, x = _setup()
        m = cfg.moe
        out = moe_apply_local(cfg, x, p["w_router"], p["experts_wi"],
                              p["experts_wo"], 0, capacity=x.shape[0] * m.top_k)

        logits = x @ p["w_router"]
        probs = jax.nn.softmax(logits, -1)
        gate, idx = jax.lax.top_k(probs, m.top_k)
        ref = jnp.zeros_like(x)
        for e in range(m.n_experts):
            h = x @ p["experts_wi"][e]
            half = h.shape[-1] // 2
            h = jax.nn.silu(h[:, :half]) * h[:, half:]
            y = h @ p["experts_wo"][e]
            w_e = jnp.sum(jnp.where(idx == e, gate, 0.0), axis=-1)
            ref += w_e[:, None] * y
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)

    def test_partition_sum_equals_full(self):
        """EP invariant: sum of per-shard partial outputs == full output."""
        cfg, p, x = _setup()
        m = cfg.moe
        cap = x.shape[0] * m.top_k
        full = moe_apply_local(cfg, x, p["w_router"], p["experts_wi"],
                               p["experts_wo"], 0, cap)
        e_half = m.n_experts // 2
        p1 = moe_apply_local(cfg, x, p["w_router"],
                             p["experts_wi"][:e_half],
                             p["experts_wo"][:e_half], 0, cap)
        p2 = moe_apply_local(cfg, x, p["w_router"],
                             p["experts_wi"][e_half:],
                             p["experts_wo"][e_half:], e_half, cap)
        np.testing.assert_allclose(np.asarray(p1 + p2), np.asarray(full),
                                   rtol=1e-4, atol=1e-4)

    def test_capacity_drops_bounded(self):
        """Tiny capacity: output is a damped version, never NaN/Inf."""
        cfg, p, x = _setup()
        out = moe_apply_local(cfg, x, p["w_router"], p["experts_wi"],
                              p["experts_wo"], 0, capacity=2)
        assert bool(jnp.all(jnp.isfinite(out)))

    @given(t=st.sampled_from([16, 32, 64]), seed=st.integers(0, 100))
    @settings(max_examples=8, deadline=None)
    def test_property_partition_invariance(self, t, seed):
        cfg, p, x = _setup(t=t, seed=seed)
        m = cfg.moe
        cap = t * m.top_k
        full = moe_apply_local(cfg, x, p["w_router"], p["experts_wi"],
                               p["experts_wo"], 0, cap)
        acc = jnp.zeros_like(full)
        step = m.n_experts // 4
        for s in range(0, m.n_experts, step):
            acc += moe_apply_local(cfg, x, p["w_router"],
                                   p["experts_wi"][s:s + step],
                                   p["experts_wo"][s:s + step], s, cap)
        np.testing.assert_allclose(np.asarray(acc), np.asarray(full),
                                   rtol=1e-4, atol=1e-4)

    def test_capacity_formula(self):
        cfg = get_config("olmoe-1b-7b")
        cap = moe_capacity(cfg, 65536)
        expect = 65536 * 8 * 1.25 / 64
        assert 0.95 * expect <= cap <= 1.1 * expect

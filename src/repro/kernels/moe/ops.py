"""jit'd wrapper for the grouped MoE GEMM kernel."""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.fusion import Epilogue
from repro.kernels.moe.grouped_matmul import grouped_matmul_kernel


def _pad(x, axis, mult):
    p = (-x.shape[axis]) % mult
    if not p:
        return x
    w = [(0, 0)] * x.ndim
    w[axis] = (0, p)
    return jnp.pad(x, w)


@functools.partial(jax.jit, static_argnames=("epilogue", "block_shape",
                                             "interpret"))
def grouped_matmul(x, w, *, epilogue: Epilogue = Epilogue(),
                   block_shape=(128, 128, 128), interpret: bool = True):
    """x: (E, C, K); w: (E, K, N) (or (E, K, 2, N/2) for GLU) -> (E, C, N')."""
    e, cap, k = x.shape
    if epilogue.glu and w.ndim == 3:
        w = w.reshape(e, k, 2, w.shape[-1] // 2)
    n_logical = w.shape[-1] * (2 if w.ndim == 4 else 1)
    acc_dtype = jnp.int32 if x.dtype == jnp.int8 else jnp.float32
    if epilogue.out_dtype is None:
        epilogue = dataclasses.replace(
            epilogue, out_dtype=x.dtype if x.dtype != jnp.int8 else jnp.int32)

    bm, bn, bk = block_shape
    bm = min(bm, _round_up(cap, 8))
    bn = min(bn, _round_up(n_logical, 128))
    bk = min(bk, _round_up(k, 128))
    x = _pad(_pad(x, 1, bm), 2, bk)
    if w.ndim == 4:
        w = _pad(_pad(w, 1, bk), 3, bn // 2)
    else:
        w = _pad(_pad(w, 1, bk), 2, bn)
    cp, kp = x.shape[1], x.shape[2]
    np_ = w.shape[-1] * (2 if w.ndim == 4 else 1)
    grid = (e, cp // bm, np_ // bn, kp // bk)
    n_out = np_ // 2 if epilogue.glu else np_
    bn_out = bn // 2 if epilogue.glu else bn

    w_spec = (pl.BlockSpec((1, bk, 2, bn // 2),
                           lambda ei, i, j, kk: (ei, kk, 0, j))
              if w.ndim == 4 else
              pl.BlockSpec((1, bk, bn), lambda ei, i, j, kk: (ei, kk, j)))

    kernel = functools.partial(grouped_matmul_kernel, ep=epilogue,
                               n_k=grid[3])
    try:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))
    except (AttributeError, TypeError):
        compiler_params = None

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda ei, i, j, kk: (ei, i, kk)),
            w_spec,
        ],
        out_specs=pl.BlockSpec((1, bm, bn_out),
                               lambda ei, i, j, kk: (ei, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, cp, n_out), epilogue.out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        compiler_params=compiler_params,
        interpret=interpret,
    )(x, w)
    return out[:, :cap, : (n_logical // 2 if epilogue.glu else n_logical)]


def _round_up(x, m):
    return x + (-x) % m

"""The cluster discrete-event backend: N matrix units, one shared loader.

``desim-cluster`` is ``desim`` scaled out: ``lower()`` tiles work as
usual, ``sim.partition`` shards the tiles across ``units`` (row-panel /
output-tile / layer-pipeline, with explicit inter-unit transfer nodes),
and ``sim.desim.simulate_cluster`` runs the partitioned graph on a
:class:`~repro.sim.resources.ClusterTopology` — per-unit dispatcher,
scratchpad banks, PE array and vector unit, all contending for one
shared memory loader under a fair-share or FCFS bandwidth-partitioning
policy.  Given concrete operands, the *same* partitioned graph also
executes through the JAX lowering, so numbers come back alongside the
contended timelines (the paper's unified-stack claim, cluster-sized).
"""

from __future__ import annotations

from typing import Callable, Optional

from repro.backend.base import (Backend, ExecResult, GraphOperands,
                                MatMulOperands)
from repro.backend.registry import register
from repro.core.fusion import Epilogue, NO_EPILOGUE
from repro.core.task import MatMulTask
from repro.obs import instrument
from repro.sim.resources import ClusterTopology


class PartitionedBackend(Backend):
    """Shared plumbing for the cluster-aware backends: a ``units``-wide
    partition strategy, TaskGraph sharding via ``sim.partition``, and
    the :class:`~repro.sim.resources.ClusterTopology` the modelling
    halves price against.

    ``affinity``/``weights`` feed the ``unit-affinity`` strategy — a
    serving policy's per-step placement hints plus relative per-unit
    throughputs (heterogeneous clusters).  An explicit (possibly
    heterogeneous) ``topology`` wins over the scalar knobs: it fixes
    the cluster width and supplies the partitioner's throughput
    weights, so mixed-unit deployments price correctly.
    """

    supports_units = True

    def __init__(self, units: int = 2, strategy: str = "row-panel",
                 affinity: "dict[str, int] | None" = None,
                 weights: "list[float] | None" = None,
                 loader_policy: str = "fair",
                 total_bandwidth: Optional[float] = None,
                 k_stream: bool = True,
                 topology: Optional[ClusterTopology] = None, **kw):
        from repro.sim.partition import STRATEGIES
        if strategy not in STRATEGIES:
            raise ValueError(f"unknown partition strategy {strategy!r}; "
                             f"one of {STRATEGIES}")
        if topology is not None:
            units = topology.n_units
            kw.setdefault("unit", topology.unit)
            kw.setdefault("platform", topology.platform)
            kw.setdefault("vector", topology.vector)
            if topology.heterogeneous and weights is None:
                weights = topology.throughput_weights()
        super().__init__(units=units, **kw)
        self.strategy = strategy
        self.affinity = affinity
        self.weights = weights
        self._topology = topology
        self.loader_policy = loader_policy
        self.total_bandwidth = total_bandwidth
        self.k_stream = k_stream

    def topology(self, unit=None, platform=None,
                 vector=None) -> ClusterTopology:
        if self._topology is not None:
            return self._topology
        return ClusterTopology(
            n_units=self.units, unit=unit or self.unit,
            platform=platform or self.platform,
            vector=vector or self.vector,
            loader_policy=self.loader_policy,
            total_bandwidth=self.total_bandwidth,
            k_stream=self.k_stream)

    def partition(self, graph):
        """Shard an (unpartitioned) TaskGraph for this backend's cluster;
        pre-partitioned input (``sim.partition.Partition``) passes
        through."""
        from repro.sim.partition import Partition, partition_graph
        if isinstance(graph, Partition):
            if graph.n_units != self.units:
                raise ValueError(
                    f"graph partitioned for {graph.n_units} unit(s) but "
                    f"backend has units={self.units}")
            return graph
        return partition_graph(graph, self.units, self.strategy,
                               affinity=self.affinity,
                               weights=self.weights)


@register("desim-cluster")
class ClusterDESimBackend(PartitionedBackend):
    """Multi-unit machine model + optional lockstep JAX execution."""

    executes = True
    models_time = True
    matmul_string = "xla"           # numeric half runs through XLA

    def _stage(self, task: MatMulTask, operands: MatMulOperands,
               epilogue: Epilogue) -> Callable[[], ExecResult]:
        ep = None if epilogue is NO_EPILOGUE else epilogue
        part = self.partition(self.lower(task, epilogue=ep))
        return lambda: self.run_graph(
            part, operands if operands.concrete else None)

    @instrument("run_graph")
    def run_graph(self, graph, operands: GraphOperands = None) -> ExecResult:
        from repro.sim.desim import simulate_cluster
        from repro.sim.lower import (execute_graph_jax,
                                     execute_workload_jax, step_spans)
        part = self.partition(graph)
        r = simulate_cluster(part.graph, self.topology())
        output, outputs = None, None
        if isinstance(operands, dict):
            outputs = execute_workload_jax(part.graph, operands)
        elif operands is not None and operands.concrete:
            output = execute_graph_jax(part.graph, operands.a, operands.b,
                                       operands=operands.epilogue)
        return ExecResult(
            output=output, outputs=outputs, cycles=r.cycles,
            seconds=r.seconds(),
            utilization=r.aggregate_matrix_utilization, timeline=r,
            detail={
                "utilizations": r.utilizations(),
                "unit_utilizations": r.unit_utilizations(),
                "loader_utilization": r.loader_utilization,
                "loader_contention": r.loader_contention(),
                "step_spans": step_spans(part.graph, r),
                "partition": {"strategy": part.strategy,
                              "n_units": part.n_units,
                              "transfers": part.n_transfers,
                              "transfer_bytes": part.transfer_bytes},
            })

    @instrument("run_workload")
    def run_workload(self, layers, *, fused=None, unit=None, platform=None,
                     vector=None):
        from repro.sim.lower import cluster_workload
        return cluster_workload(
            self.topology(unit, platform, vector), layers,
            strategy=self.strategy,
            fused=self.fused if fused is None else fused,
            granularity=self.granularity,
            affinity=self.affinity, weights=self.weights)

"""Serving-scheduler subsystem: pluggable batching policies.

Acceptance bars of the policy/mechanism split:

* ``full-prefill`` reproduces the pre-refactor inline ``plan()``
  byte-for-byte;
* every policy lowers through the shared ``BatchSchedule`` →
  ``workload_to_graph`` path and executes int8 bit-exactly on the
  ``jax`` vs ``sharded`` backends;
* ``decode-priority`` strictly lowers decode first-token p50 vs
  ``full-prefill`` (single-unit and the 2-unit cluster config);
* the contention-aware ``analytical`` closed form prices multi-unit
  deployments within ≤5% of ``desim-cluster`` on the paper GEMM regime,
  heterogeneous topologies included.
"""

import jax
import numpy as np
import pytest

from repro import backend
from repro.configs.registry import get_config
from repro.core.config import CASE_STUDY, PLATFORM_2TOPS
from repro.core.fusion import cute_matmul
from repro.core.hardware import GIGA, SHUTTLE
from repro.core.task import MatMulTask
from repro.serving.engine import BatchSchedule, BatchStep, ServingEngine, \
    _step_layer
from repro.serving import scheduler
from repro.sim import (ClusterTopology, UnitSpec, build_gemm_graph,
                       partition_graph, simulate_cluster)


def _engine(n_requests=5, max_batch=2, base_len=4, stride=1):
    cfg = get_config("yi-6b", reduced=True)
    eng = ServingEngine(cfg, params=None, max_batch=max_batch,
                        cache_len=64)
    key = jax.random.PRNGKey(0)
    for i in range(n_requests):
        key, sub = jax.random.split(key)
        eng.submit(jax.random.randint(sub, (base_len + stride * i,),
                                      0, 100))
    return cfg, eng


def _legacy_plan(eng, max_new_tokens, units=1):
    """The pre-refactor ``ServingEngine.plan`` body, verbatim — the pin
    ``full-prefill`` must reproduce byte-for-byte."""
    steps, layers = [], []
    queue = list(eng._queue)
    first = 0
    while queue:
        chunk, queue = queue[: eng.max_batch], queue[eng.max_batch:]
        ids = tuple(range(first, first + len(chunk)))
        first += len(chunk)
        s = max(int(t.shape[-1]) for t in chunk)
        ci = len(steps) // 2
        prefill = BatchStep("prefill", ids, tokens=len(chunk) * s,
                            repeat=eng.cfg.n_layers)
        decode = BatchStep("decode", ids, tokens=len(chunk),
                           repeat=eng.cfg.n_layers * max_new_tokens)
        for step in (prefill, decode):
            steps.append(step)
            layers.append(_step_layer(eng.cfg, f"b{ci}/{step.kind}",
                                      step.tokens, step.repeat))
    return BatchSchedule(steps, layers, units=units)


class TestPolicyRegistry:
    def test_registered_policies(self):
        names = set(scheduler.available_policies())
        assert names == {"full-prefill", "chunked-prefill",
                         "decode-priority", "auto-slo"}
        concrete = {n for n in names
                    if not getattr(scheduler.get_policy(n), "meta", False)}
        assert concrete == {"full-prefill", "chunked-prefill",
                            "decode-priority"}

    def test_unknown_policy_lists_names(self):
        with pytest.raises(KeyError, match="chunked-prefill"):
            scheduler.get_policy("shortest-job-first")

    def test_policy_kwargs_validated(self):
        with pytest.raises(ValueError, match="chunk_tokens"):
            scheduler.get_policy("chunked-prefill", chunk_tokens=0)

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            @scheduler.register_policy
            class Impostor(scheduler.SchedulingPolicy):
                name = "full-prefill"

                def schedule(self, ctx):
                    raise NotImplementedError


class TestFullPrefillPin:
    """``full-prefill`` is today's ``plan()`` — bit-identical."""

    @pytest.mark.parametrize("n_requests,max_batch", [(5, 2), (3, 4),
                                                      (8, 3)])
    def test_schedule_matches_legacy_plan(self, n_requests, max_batch):
        _, eng = _engine(n_requests, max_batch)
        for max_new in (4, 32):
            new = eng.plan(max_new_tokens=max_new)
            old = _legacy_plan(eng, max_new)
            assert new.steps == old.steps
            assert new.layers == old.layers
            assert new.units == old.units
            assert (new.policy, new.affinity, new.strategy) == \
                ("full-prefill", {}, None)

    def test_plan_default_policy_is_full_prefill(self):
        _, eng = _engine()
        assert eng.plan(max_new_tokens=4).policy == "full-prefill"

    def test_plan_non_destructive_and_units_recorded(self):
        _, eng = _engine()
        for policy in scheduler.available_policies():
            sched = eng.plan(max_new_tokens=4, units=3, policy=policy)
            assert sched.units == 3
            assert len(eng._queue) == 5


class TestPolicyLowering:
    """Conservation: every policy drains the same queue."""

    @pytest.mark.parametrize("policy", ["full-prefill", "chunked-prefill",
                                        "decode-priority"])
    def test_token_conservation(self, policy):
        cfg, eng = _engine(5, 2)
        max_new = 6
        sched = eng.plan(max_new_tokens=max_new, policy=policy,
                         chunk_tokens=4) if policy != "full-prefill" \
            else eng.plan(max_new_tokens=max_new)
        batches = scheduler.PolicyContext(
            cfg, tuple(int(t.shape[-1]) for t in eng._queue),
            eng.max_batch, max_new).batches()
        # prefill rows: every batch's B x S_padded tokens appear exactly
        # once across prefill/mixed steps.
        prefill_tokens = sum(
            st.tokens - len(st.decode_requests) for st in sched.steps
            if st.kind in ("prefill", "mixed"))
        assert prefill_tokens == sum(len(ids) * s for ids, s in batches)
        # decode iterations: every request gets exactly max_new tokens.
        per_req = {}
        for st in sched.steps:
            dr = st.decode_requests or (
                st.requests if st.kind == "decode" else ())
            iters = st.repeat // cfg.n_layers
            for r in dr:
                per_req[r] = per_req.get(r, 0) + iters
        assert per_req == {r: max_new for r in range(5)}

    @pytest.mark.parametrize("policy", ["chunked-prefill",
                                        "decode-priority"])
    def test_chunking_splits_prefill(self, policy):
        _, eng = _engine(4, 2, base_len=16, stride=4)
        sched = eng.plan(max_new_tokens=4, policy=policy, chunk_tokens=8)
        chunks = [st for st in sched.steps
                  if st.kind in ("prefill", "mixed")]
        assert len(chunks) > 2                     # genuinely chunked
        assert all(st.tokens - len(st.decode_requests) <= 8
                   for st in chunks)

    def test_layer_names_unique(self):
        for policy in scheduler.available_policies():
            _, eng = _engine(6, 2)
            sched = eng.plan(max_new_tokens=4, policy=policy)
            names = [lt.name for lt in sched.layers]
            assert len(names) == len(set(names)), (policy, names)


class TestExampleOperandsDeterminism:
    """Satellite fix: fold_in-derived per-GEMM keys — operands depend on
    (key, label) only, not on how many GEMMs precede them."""

    def test_same_key_same_operands(self):
        _, eng = _engine()
        sched = eng.plan(max_new_tokens=4)
        a = sched.example_operands(jax.random.PRNGKey(3))
        b = sched.example_operands(jax.random.PRNGKey(3))
        for label in a:
            assert (np.asarray(a[label][0]) == np.asarray(b[label][0])).all()
            assert (np.asarray(a[label][1]) == np.asarray(b[label][1])).all()

    def test_operands_independent_of_step_count(self):
        _, eng = _engine(4, 2)                    # two complete batches
        short = eng.plan(max_new_tokens=4)
        for i in (7, 9):                          # a third, new batch
            eng.submit(jax.random.randint(jax.random.PRNGKey(i), (i,),
                                          0, 100))
        longer = eng.plan(max_new_tokens=4)       # two more steps
        assert len(longer.steps) == len(short.steps) + 2
        ka, kb = jax.random.PRNGKey(5), jax.random.PRNGKey(5)
        ops_s, ops_l = short.example_operands(ka), longer.example_operands(kb)
        for label in ops_s:                       # shared labels identical
            assert (np.asarray(ops_s[label][0])
                    == np.asarray(ops_l[label][0])).all(), label
            assert (np.asarray(ops_s[label][1])
                    == np.asarray(ops_l[label][1])).all(), label


class TestPolicyExecutionParity:
    """Every policy's schedule graph executes int8 bit-exactly: jax vs
    sharded on the identical partitioned graph."""

    @pytest.mark.parametrize("policy", ["full-prefill", "chunked-prefill",
                                        "decode-priority"])
    def test_jax_vs_sharded_bit_exact(self, policy):
        _, eng = _engine(3, 2)
        kw = {} if policy == "full-prefill" else {"chunk_tokens": 6}
        sched = eng.plan(max_new_tokens=2, units=2, policy=policy, **kw)
        ops = sched.example_operands(jax.random.PRNGKey(7))
        jx = backend.get("jax")
        rj = jx.run_graph(jx.lower(sched.layers), ops)
        sh = backend.get("sharded", units=2, strategy="output-tile")
        rs = sh.run_graph(sh.lower(sched.layers), ops)
        assert set(rs.outputs) == set(rj.outputs) == set(ops)
        for label, (a, b) in ops.items():
            ref = np.asarray(cute_matmul(a, b, backend="xla"))
            assert (np.asarray(rj.outputs[label]) == ref).all(), label
            assert (np.asarray(rs.outputs[label]) == ref).all(), label

    def test_affinity_partition_executes_bit_exact(self):
        """decode-priority's unit-affinity hints shard the same graph
        the jax backend executes — placement changes timing, never
        numbers."""
        _, eng = _engine(3, 2)
        sched = eng.plan(max_new_tokens=2, units=2,
                         policy="decode-priority", chunk_tokens=6)
        assert sched.affinity                     # hints were emitted
        ops = sched.example_operands(jax.random.PRNGKey(8))
        jx = backend.get("jax")
        rj = jx.run_graph(jx.lower(sched.layers), ops)
        sh = backend.get("sharded", units=2, strategy="unit-affinity",
                         affinity=dict(sched.affinity))
        rs = sh.run_graph(sh.lower(sched.layers), ops)
        for label in ops:
            assert (np.asarray(rs.outputs[label])
                    == np.asarray(rj.outputs[label])).all(), label


class TestDecodeLatency:
    """The policy lever the refactor exists for."""

    def _p50(self, eng, cfg, policy, units, **kw):
        sched = eng.plan(max_new_tokens=8, units=units, policy=policy)
        m = scheduler.schedule_metrics(sched, cfg.n_layers, "analytical",
                                       **kw)
        return m

    def test_decode_priority_lowers_p50_single_unit(self):
        cfg, eng = _engine(6, 2, base_len=24, stride=8)
        full = self._p50(eng, cfg, "full-prefill", 1)
        dp = self._p50(eng, cfg, "decode-priority", 1)
        assert dp["decode_p50"] < full["decode_p50"]

    def test_decode_priority_lowers_p50_on_2unit_cluster(self):
        """CI acceptance: strictly lower decode p50 on the 2-unit
        cluster config."""
        cfg, eng = _engine(6, 2, base_len=24, stride=8)
        full = self._p50(eng, cfg, "full-prefill", 2)
        dp = self._p50(eng, cfg, "decode-priority", 2)
        assert dp["decode_p50"] < full["decode_p50"]
        # and the interleaving does not blow up total throughput
        assert dp["makespan"] < 1.2 * full["makespan"]

    def test_full_prefill_has_best_itl(self):
        """Lockstep decode pays nothing for interleaving — the cadence
        side of the trade the policy table documents."""
        cfg, eng = _engine(6, 2, base_len=24, stride=8)
        full = self._p50(eng, cfg, "full-prefill", 1)
        dp = self._p50(eng, cfg, "decode-priority", 1)
        assert full["itl_p50"] <= dp["itl_p50"]

    def test_latency_stats_validates_lengths(self):
        cfg, eng = _engine(3, 2)
        sched = eng.plan(max_new_tokens=2)
        with pytest.raises(ValueError, match="step prices"):
            scheduler.decode_latency_stats(sched, [1.0], cfg.n_layers)


class TestAutoPlan:
    def test_auto_returns_feasible_best(self):
        cfg, eng = _engine(5, 2, base_len=16, stride=8)
        sched, report = eng.autoplan(max_new_tokens=4, units=2)
        chosen = report["chosen"]
        assert chosen["candidate"] in report
        best_makespan = min(v["makespan"] for k, v in report.items()
                            if k != "chosen")
        assert chosen["makespan"] <= 1.05 * best_makespan
        assert sched.policy in scheduler.available_policies()
        assert sched.strategy in ("output-tile", "unit-affinity")

    def test_plan_auto_single_unit(self):
        cfg, eng = _engine(4, 2)
        sched = eng.plan(max_new_tokens=4, policy="auto")
        assert sched.policy in scheduler.available_policies()
        assert sched.units == 1

    def test_evaluate_schedule_wires_policy_affinity(self):
        _, eng = _engine(3, 2)
        sched, res = eng.evaluate_schedule(
            "analytical", max_new_tokens=2, units=2,
            policy="decode-priority")
        assert sched.policy == "decode-priority"
        assert res.detail["partition"]["strategy"] == "unit-affinity"
        assert res.cycles > 0


class TestAnalyticalClusterForm:
    """Contention-aware closed form vs desim-cluster, paper GEMM regime
    (per-unit 512x512x8192 int8 row-panel weak scaling): <=5%."""

    def _pair(self, n, total_bandwidth=None):
        unit = PLATFORM_2TOPS
        g, _ = build_gemm_graph(MatMulTask(m=512 * n, n=512, k=8192),
                                unit.m_scp, unit.n_scp)
        part = partition_graph(g, n, "row-panel")
        topo = ClusterTopology(n_units=n, unit=unit, platform=SHUTTLE,
                               total_bandwidth=total_bandwidth)
        des = simulate_cluster(part.graph, topo)
        ana = backend.get("analytical", units=n, unit=unit,
                          platform=SHUTTLE,
                          total_bandwidth=total_bandwidth)
        return des, ana.run_graph(part)

    @pytest.mark.parametrize("n", [2, 4])
    def test_pooled_weak_scaling_within_5pct(self, n):
        des, ana = self._pair(n)
        assert abs(ana.cycles / des.cycles - 1.0) <= 0.05
        assert abs(ana.utilization
                   - des.aggregate_matrix_utilization) <= 0.05

    @pytest.mark.parametrize("n", [2, 4])
    def test_saturated_loader_within_5pct(self, n):
        des, ana = self._pair(n, total_bandwidth=PLATFORM_2TOPS.bandwidth)
        assert abs(ana.cycles / des.cycles - 1.0) <= 0.05

    def test_heterogeneous_topology_within_5pct(self):
        fast = CASE_STUDY.with_(freq_hz=PLATFORM_2TOPS.freq_hz)
        topo = ClusterTopology(
            unit_specs=(UnitSpec(unit=fast), UnitSpec(unit=PLATFORM_2TOPS)),
            platform=SHUTTLE)
        g, _ = build_gemm_graph(MatMulTask(m=1024, n=512, k=8192),
                                64, 64)
        part = partition_graph(g, 2, "row-panel")
        des = simulate_cluster(part.graph, topo)
        ana = backend.get("analytical", topology=topo).run_graph(part)
        assert abs(ana.cycles / des.cycles - 1.0) <= 0.05

    def test_single_unit_path_untouched(self):
        """units=1 stays on the legacy closed form — the ~1% desim
        parity pins of PR 2 are not re-derived here."""
        eng = backend.get("analytical")
        assert eng.units == 1 and not eng._cluster

    def test_run_workload_cluster_dict_shape(self):
        from repro.core.simulator import LayerTrace
        layers = [LayerTrace("l", (MatMulTask(m=128, n=256, k=512),),
                             vector_ops={"silu": 128 * 256.0}, repeat=2)]
        r = backend.get("analytical", units=2).run_workload(layers)
        assert {"cycles", "matrix", "vector", "seconds", "flops",
                "matrix_utilization", "loader_utilization",
                "transfers"} <= set(r)
        single = backend.get("analytical").run_workload(layers)
        assert r["cycles"] < single["cycles"]


class TestHeterogeneousTopology:
    def test_unit_specs_fix_width(self):
        topo = ClusterTopology(unit_specs=(UnitSpec(), UnitSpec(),
                                           UnitSpec()))
        assert topo.n_units == 3 and topo.heterogeneous

    def test_width_mismatch_rejected(self):
        with pytest.raises(ValueError, match="unit_specs"):
            ClusterTopology(n_units=4, unit_specs=(UnitSpec(), UnitSpec()))

    def test_mixed_clocks_rejected(self):
        slow = CASE_STUDY.with_(freq_hz=CASE_STUDY.freq_hz / 2)
        with pytest.raises(ValueError, match="clock"):
            ClusterTopology(unit_specs=(UnitSpec(unit=CASE_STUDY),
                                        UnitSpec(unit=slow)))

    def test_private_slices_cannot_consume_pool(self):
        with pytest.raises(ValueError, match="private"):
            ClusterTopology(
                unit_specs=(UnitSpec(private_bandwidth=64 * GIGA),
                            UnitSpec(private_bandwidth=64 * GIGA)),
                total_bandwidth=100 * GIGA)

    def test_throughput_weights_reflect_pe(self):
        fast = CASE_STUDY.with_(freq_hz=PLATFORM_2TOPS.freq_hz)
        topo = ClusterTopology(
            unit_specs=(UnitSpec(unit=fast), UnitSpec(unit=PLATFORM_2TOPS)))
        w = topo.throughput_weights()
        assert w[0] == 2 * w[1]

    def test_private_slice_gets_its_own_loader(self):
        topo = ClusterTopology(
            unit_specs=(UnitSpec(private_bandwidth=24 * GIGA), UnitSpec()),
            total_bandwidth=96 * GIGA)
        assert topo.shared_bandwidth == 72 * GIGA
        g, _ = build_gemm_graph(MatMulTask(m=128, n=128, k=256), 64, 64)
        part = partition_graph(g, 2, "row-panel")
        r = simulate_cluster(part.graph, topo)
        assert "u0/local_loader" in r.intervals
        assert r.busy("u0/local_loader") > 0

    def test_desim_cluster_backend_accepts_topology(self):
        fast = CASE_STUDY.with_(freq_hz=PLATFORM_2TOPS.freq_hz)
        topo = ClusterTopology(
            unit_specs=(UnitSpec(unit=fast), UnitSpec(unit=PLATFORM_2TOPS)))
        eng = backend.get("desim-cluster", topology=topo)
        assert eng.units == 2
        assert eng.weights == topo.throughput_weights()
        r = eng.wait(eng.dispatch(MatMulTask(m=256, n=256, k=512)))
        assert r.cycles > 0 and r.timeline.n_units == 2


class TestUnitAffinityPartition:
    def _schedule_graph(self):
        _, eng = _engine(3, 2)
        sched = eng.plan(max_new_tokens=2)
        jx = backend.get("jax")
        return sched, jx.lower(sched.layers)

    def test_hints_honoured(self):
        sched, graph = self._schedule_graph()
        hints = {"b0/prefill": 1, "b1/decode": 0}
        part = partition_graph(graph, 2, "unit-affinity", affinity=hints)
        for node in part.graph.matmul_nodes():
            head = node.layer.rsplit("/g", 1)[0]
            if head in hints:
                assert node.unit == hints[head], node.layer

    def test_out_of_range_hint_rejected(self):
        _, graph = self._schedule_graph()
        with pytest.raises(ValueError, match="out of range"):
            partition_graph(graph, 2, "unit-affinity",
                            affinity={"b0/prefill": 5})

    def test_weights_bias_placement(self):
        """3x-throughput unit 0 should own ~3x the MACs of unit 1."""
        _, graph = self._schedule_graph()
        part = partition_graph(graph, 2, "unit-affinity",
                               weights=[3.0, 1.0])
        macs = [0.0, 0.0]
        for node in part.graph.matmul_nodes():
            macs[node.unit] += node.task.macs
        assert macs[0] > 1.5 * macs[1]

    def test_bad_weights_rejected(self):
        _, graph = self._schedule_graph()
        with pytest.raises(ValueError, match="weights"):
            partition_graph(graph, 2, "unit-affinity", weights=[1.0])


class TestTracePhaseMarkers:
    def test_phase_of(self):
        from repro.sim.trace import phase_of
        assert phase_of("b0/prefill/g0/t3") == "prefill"
        assert phase_of("b1/prefill.c2/g1/t0") == "prefill-chunk"
        assert phase_of("b2/mixed.c0/g0/t1") == "mixed"
        assert phase_of("dp3/decode/g2/t0/wb") == "decode"
        assert phase_of("b0+b1/decode.rr/g0/t0") == "decode"
        assert phase_of("gemm/t7") is None

    def test_chrome_trace_carries_phase_args(self):
        from repro.sim.trace import chrome_trace
        _, eng = _engine(3, 2)
        sched = eng.plan(max_new_tokens=2, policy="decode-priority",
                         chunk_tokens=6)
        desim = backend.get("desim")
        r = desim.run_graph(desim.lower(sched.layers))
        trace = chrome_trace(r.timeline)
        phases = {e["args"]["phase"] for e in trace["traceEvents"]
                  if e["ph"] == "X" and "phase" in e.get("args", {})}
        assert {"prefill-chunk", "decode"} <= phases
        for e in trace["traceEvents"]:       # shape regression
            if e["ph"] == "X" and "phase" in e.get("args", {}):
                assert e["cname"]
                assert set(e) >= {"name", "cat", "pid", "tid", "ts",
                                  "dur"}

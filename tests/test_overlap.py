"""Cross-step overlapped lowering + request arrival-time semantics.

Acceptance bars of the dependency-relaxed lowering (ISSUE 5 tentpole):

* ``overlap="relaxed"`` replaces the coarse step chain with true
  per-request KV/activation hazards — steps over disjoint requests carry
  no edge, same-request steps keep their order;
* a 2-unit decode-priority schedule's relaxed DES makespan is strictly
  (measurably) below the chained one, while int8 execution stays
  bit-exact — relaxed deps change *when*, never *what*;
* on a single unit, relaxed lowering buys no false overlap;
* ``Request.arrival_time`` flows into node release times honoured by the
  DES and approximated by the analytical timeline, so TTFT reflects
  queueing under load instead of the all-at-t=0 lower bound;
* the single-unit analytical closed form folds the k-stream first-chunk
  fill term (≤5% vs the K-streamed 1-unit DES).
"""

import jax
import numpy as np
import pytest

from repro import backend
from repro.configs.registry import get_config
from repro.core.config import CASE_STUDY, PLATFORM_2TOPS
from repro.core.hardware import SHUTTLE
from repro.core.simulator import LayerTrace
from repro.core.task import MatMulTask
from repro.serving.engine import BatchSchedule, BatchStep, Request, \
    ServingEngine, _step_layer
from repro.serving import scheduler
from repro.sim import (ClusterTopology, build_gemm_graph, partition_graph,
                       schedule_to_graph, simulate_cluster, simulate_graph,
                       step_spans, workload_to_graph)
from repro.sim.lower import execute_workload_jax, step_label


def _engine(n_requests=6, max_batch=2, base_len=24, stride=8,
            arrivals=None):
    cfg = get_config("yi-6b", reduced=True)
    eng = ServingEngine(cfg, params=None, max_batch=max_batch,
                        cache_len=256)
    key = jax.random.PRNGKey(0)
    for i in range(n_requests):
        key, sub = jax.random.split(key)
        eng.submit(jax.random.randint(sub, (base_len + stride * i,),
                                      0, 100),
                   arrival_time=arrivals[i] if arrivals else 0.0)
    return cfg, eng


def _hand_schedule(cfg, steps):
    """A BatchSchedule from bare (kind, requests, tokens, repeat) specs."""
    bsteps = [BatchStep(k, tuple(r), tokens=t, repeat=rep)
              for k, r, t, rep in steps]
    layers = [_step_layer(cfg, f"s{i}/{s.kind}", s.tokens, s.repeat)
              for i, s in enumerate(bsteps)]
    return BatchSchedule(bsteps, layers)


class TestStepDeps:
    """step_deps() is the per-request last-writer chain."""

    def test_kv_hazard_chain(self):
        cfg = get_config("yi-6b", reduced=True)
        sched = _hand_schedule(cfg, [
            ("prefill", (0, 1), 8, cfg.n_layers),   # s0
            ("decode", (0, 1), 2, cfg.n_layers),    # s1 <- s0
            ("prefill", (2,), 8, cfg.n_layers),     # s2 <- nothing
            ("decode", (0, 1, 2), 3, cfg.n_layers),  # s3 <- s1, s2
        ])
        assert sched.step_deps() == [(), (0,), (), (1, 2)]

    def test_cross_request_ordering_is_preserved(self):
        """A request's steps serialise in schedule order even when other
        steps interleave between them."""
        cfg, eng = _engine(6, 2)
        sched = eng.plan(max_new_tokens=4, units=2,
                         policy="decode-priority")
        deps = sched.step_deps()
        last = {}
        for j, step in enumerate(sched.steps):
            for r in step.requests:
                if r in last:
                    assert last[r] in deps[j], (j, r, deps[j])
                last[r] = j

    def test_disjoint_steps_share_no_edge_in_graph(self):
        cfg = get_config("yi-6b", reduced=True)
        sched = _hand_schedule(cfg, [
            ("prefill", (0,), 8, cfg.n_layers),
            ("prefill", (1,), 8, cfg.n_layers),
        ])
        sched.overlap = "relaxed"
        g = schedule_to_graph(CASE_STUDY, sched)
        s1_nodes = [n for n in g.nodes
                    if step_label(n.layer) == sched.layers[1].name]
        assert s1_nodes and all(not n.deps or all(
            step_label(g.nodes[d].layer) == sched.layers[1].name
            for d in n.deps) for n in s1_nodes)

    def test_relaxed_requires_step_deps(self):
        with pytest.raises(ValueError, match="step_deps"):
            workload_to_graph(CASE_STUDY, [], overlap="relaxed")
        with pytest.raises(ValueError, match="overlap mode"):
            workload_to_graph(CASE_STUDY, [], overlap="bogus")

    def test_step_deps_must_point_backwards(self):
        lt = LayerTrace("s0", (MatMulTask(m=64, n=64, k=64),))
        with pytest.raises(ValueError, match="earlier"):
            workload_to_graph(CASE_STUDY, [lt], overlap="relaxed",
                              step_deps=[(0,)])


class TestRelaxedOverlap:
    """The tentpole pins: overlap on 2 units, none on 1, bit-exactness."""

    def _makespans(self, policy="decode-priority", units=2,
                   backend_name="desim-cluster"):
        cfg, eng = _engine(6, 2)
        out = {}
        for ov in ("chained", "relaxed"):
            _, res = eng.evaluate_schedule(
                backend_name, max_new_tokens=8, units=units,
                policy=policy, overlap=ov, workload=False)
            out[ov] = res
        return out

    def test_two_unit_decode_priority_relaxed_beats_chained(self):
        """CI acceptance: strictly lower makespan by a measurable
        margin on the 2-unit decode-priority schedule."""
        res = self._makespans()
        assert res["relaxed"].cycles < 0.98 * res["chained"].cycles, \
            (res["relaxed"].cycles, res["chained"].cycles)

    def test_relaxed_steps_genuinely_overlap(self):
        """Some pair of steps runs concurrently on the DES timeline."""
        res = self._makespans()["relaxed"]
        spans = sorted(res.detail["step_spans"].values())
        assert any(b_start < a_end for (a_start, a_end), (b_start, _)
                   in zip(spans, spans[1:]))

    def test_single_unit_relaxed_equals_chained_analytical(self):
        """No false overlap: the single-unit analytical timeline is
        identical under both lowerings."""
        cfg, eng = _engine(4, 2)
        mets = {}
        for ov in ("chained", "relaxed"):
            sched = eng.plan(max_new_tokens=4, policy="decode-priority",
                             overlap=ov)
            mets[ov] = scheduler.schedule_metrics(sched, cfg.n_layers,
                                                  "analytical")
        assert mets["chained"] == mets["relaxed"]

    def test_single_unit_relaxed_des_no_false_overlap(self):
        """On one unit the DES serialises through the same resources:
        relaxed may pipeline slightly deeper across step boundaries but
        cannot manufacture parallel work."""
        res = self._makespans(units=1, backend_name="desim")
        rel = res["relaxed"].cycles / res["chained"].cycles
        assert 0.95 <= rel <= 1.001, rel

    def test_relaxed_execution_bit_exact_vs_chained(self):
        """Relaxed deps change when steps run, never what they compute."""
        cfg, eng = _engine(4, 2, base_len=8, stride=4)
        outs = {}
        for ov in ("chained", "relaxed"):
            sched = eng.plan(max_new_tokens=2, units=2,
                             policy="decode-priority", overlap=ov)
            graph = backend.get("jax").lower(sched)
            ops = sched.example_operands(jax.random.PRNGKey(7))
            outs[ov] = execute_workload_jax(graph, ops)
        assert outs["chained"].keys() == outs["relaxed"].keys()
        for k in outs["chained"]:
            np.testing.assert_array_equal(
                np.asarray(outs["chained"][k]),
                np.asarray(outs["relaxed"][k]))

    def test_partition_preserves_release_times(self):
        lt = LayerTrace("s0", (MatMulTask(m=128, n=128, k=256),))
        g = workload_to_graph(CASE_STUDY, [lt], release_times=[123.0])
        part = partition_graph(g, 2, "row-panel")
        assert all(n.release_time == 123.0 for n in part.graph.nodes
                   if n.kind == "matmul")

    def test_auto_plan_picks_relaxed_when_it_lowers_p50(self):
        cfg, eng = _engine(6, 2)
        sched, report = eng.autoplan(max_new_tokens=8, units=2)
        key = "decode-priority×unit-affinity"
        assert report[key + "×relaxed"]["decode_p50"] \
            < report[key]["decode_p50"]
        assert sched.overlap == "relaxed"


class TestArrivalTimes:
    """Request.arrival_time -> release times -> TTFT under load."""

    def test_submit_validates_arrivals(self):
        _, eng = _engine(1, 2, arrivals=[100.0])
        with pytest.raises(ValueError, match=">= 0"):
            eng.submit(jax.numpy.zeros((4,), jax.numpy.int32),
                       arrival_time=-1.0)
        with pytest.raises(ValueError, match="arrival order"):
            eng.submit(jax.numpy.zeros((4,), jax.numpy.int32),
                       arrival_time=50.0)

    def test_submit_accepts_request_records(self):
        cfg = get_config("yi-6b", reduced=True)
        eng = ServingEngine(cfg, params=None, max_batch=2)
        rid = eng.submit(Request(jax.numpy.zeros((4,), jax.numpy.int32),
                                 arrival_time=42.0))
        assert rid == 0
        assert eng.requests[0].arrival_time == 42.0

    def test_release_is_max_arrival_of_step_requests(self):
        arrivals = [0.0, 1000.0, 5000.0, 9000.0]
        cfg, eng = _engine(4, 2, arrivals=arrivals)
        sched = eng.plan(max_new_tokens=2)
        assert sched.release_times[0] == 1000.0    # batch 0 = reqs 0, 1
        assert sched.release_times[2] == 9000.0    # batch 1 = reqs 2, 3
        assert sched.arrival_times == tuple(arrivals)

    def test_des_honours_release_times(self):
        arrivals = [0.0, 0.0, 50000.0, 50000.0]
        cfg, eng = _engine(4, 2, arrivals=arrivals)
        _, res = eng.evaluate_schedule("desim", max_new_tokens=2,
                                       workload=False)
        spans = res.detail["step_spans"]
        b1 = [s for name, (s, _) in spans.items() if name.startswith("b1")]
        assert b1 and min(b1) >= 50000.0

    def test_ttft_reflects_arrivals(self):
        arrivals = [0.0, 0.0, 30000.0, 30000.0]
        cfg, eng0 = _engine(4, 2)
        _, engA = _engine(4, 2, arrivals=arrivals)
        m0 = scheduler.schedule_metrics(eng0.plan(max_new_tokens=2),
                                        cfg.n_layers, "analytical")
        mA = scheduler.schedule_metrics(engA.plan(max_new_tokens=2),
                                        cfg.n_layers, "analytical")
        # TTFT is measured from each request's own arrival: batch 1
        # starts later but also arrived later, so its queueing delay
        # shrinks while batch 0's is unchanged.
        assert mA["ttft_p50"] > 0.0
        assert mA["ttft_p99"] <= m0["ttft_p99"]
        assert mA["makespan"] >= m0["makespan"]
        assert m0["ttft_p50"] == m0["decode_p50"]   # alias

    def test_out_of_order_completion(self):
        """A late-arriving small batch finishes its first token before an
        earlier giant batch finishes decoding (decode-priority); the
        stats stay per-request consistent."""
        cfg = get_config("yi-6b", reduced=True)
        eng = ServingEngine(cfg, params=None, max_batch=1, cache_len=256)
        eng.submit(jax.numpy.zeros((192,), jax.numpy.int32))
        eng.submit(jax.numpy.zeros((8,), jax.numpy.int32),
                   arrival_time=100.0)
        sched = eng.plan(max_new_tokens=16, policy="decode-priority",
                         chunk_tokens=64)
        cycles = scheduler.price_steps(sched, "analytical")
        spans = scheduler.schedule_timeline(sched, cycles)
        m = scheduler.decode_latency_stats(sched, cycles, cfg.n_layers)
        assert m["ttft_p50"] > 0.0 and m["decode_tokens"] == 32.0
        assert all(e >= s for s, e in spans)
        # release times never start a step before its requests exist
        for (s, _), r in zip(spans, sched.release_times):
            assert s >= r

    def test_policy_context_validates_arrival_length(self):
        with pytest.raises(ValueError, match="arrival_times"):
            scheduler.PolicyContext(cfg=None, prompt_lengths=(4, 4),
                                    max_batch=2, max_new_tokens=1,
                                    arrival_times=(0.0,))


class TestKStreamClosedForm:
    """ROADMAP follow-up: the k-stream first-chunk fill term in the
    single-unit analytical closed form (≤5% vs the K-streamed DES)."""

    @pytest.mark.parametrize("unit", [CASE_STUDY, PLATFORM_2TOPS])
    def test_kstream_fill_fold_within_5pct(self, unit):
        task = MatMulTask(m=512, n=512, k=8192)
        g, _ = build_gemm_graph(task, unit.m_scp, unit.n_scp)
        topo = ClusterTopology(n_units=1, unit=unit, platform=SHUTTLE,
                               loader_policy="fcfs", k_stream=True)
        des = simulate_cluster(g, topo)
        ana = backend.get("analytical", unit=unit, platform=SHUTTLE,
                          k_stream=True).run_graph(g)
        assert abs(ana.cycles / des.cycles - 1.0) <= 0.05

    def test_single_unit_default_is_k_streamed(self):
        """backend.get("analytical") defaults k_stream on (the legacy
        whole-tile auto-default is gone), and the re-baselined parity
        vs the K-streamed ``simulate_graph`` machine is tighter than
        the old ~1% pin."""
        eng = backend.get("analytical")
        assert eng.k_stream is True
        task = MatMulTask(m=256, n=256, k=4096)
        g, _ = build_gemm_graph(task, CASE_STUDY.m_scp, CASE_STUDY.n_scp)
        des = simulate_graph(g, CASE_STUDY, SHUTTLE)
        assert abs(eng.run_graph(g).cycles / des.cycles - 1.0) < 0.005

    def test_cluster_form_defaults_chunk_aware(self):
        assert backend.get("analytical", units=2).k_stream is True
        # the explicit opt-out (legacy whole-tile fills) still exists
        assert backend.get("analytical", k_stream=False).k_stream is False


class TestStepSpans:
    def test_step_spans_cover_all_steps(self):
        cfg, eng = _engine(4, 2)
        sched, res = eng.evaluate_schedule("desim", max_new_tokens=2,
                                           workload=False)
        spans = res.detail["step_spans"]
        assert set(spans) == {lt.name for lt in sched.layers}

    def test_analytical_spans_serialise_when_chained(self):
        cfg, eng = _engine(4, 2)
        sched = eng.plan(max_new_tokens=2)
        res = backend.get("analytical").run_graph(
            backend.get("analytical").lower(sched))
        spans = [res.detail["step_spans"][lt.name] for lt in sched.layers]
        for (s0, e0), (s1, e1) in zip(spans, spans[1:]):
            assert s1 >= e0


class TestCrossGroupRederating:
    """The analytical cluster form's M/G/1-PS fixed point must see the
    loader traffic of *concurrently placed* relaxed groups, not just its
    own — the DES on the same graph is the ground truth it tracks.
    Shapes are the paper-GEMM prefill regime where the un-re-derated
    form under-estimated loader-bound overlap by 32–45%."""

    @staticmethod
    def _two_group_sched(m, n, k):
        from repro.core.precision import DataType
        layers = [
            LayerTrace("s0/prefill",
                       (MatMulTask(m=m, n=n, k=k,
                                   data_type=DataType.INT8),),
                       vector_ops={"dequant": float(m * n)}, repeat=1),
            LayerTrace("s1/prefill",
                       (MatMulTask(m=m, n=n, k=k,
                                   data_type=DataType.INT8),),
                       vector_ops={"dequant": float(m * n)}, repeat=1),
        ]
        steps = [BatchStep("prefill", (0,), tokens=m, repeat=1),
                 BatchStep("prefill", (1,), tokens=m, repeat=1)]
        return BatchSchedule(steps, layers, units=2, policy="hand",
                             affinity={"s0/prefill": 0, "s1/prefill": 1},
                             strategy="unit-affinity", overlap="relaxed")

    @pytest.mark.parametrize("m,n,k", [(256, 256, 1024),
                                       (128, 512, 2048),
                                       (512, 512, 512)])
    def test_relaxed_two_groups_within_5pct_of_des(self, m, n, k):
        sched = self._two_group_sched(m, n, k)
        kw = dict(units=2, strategy="unit-affinity",
                  affinity=dict(sched.affinity))
        des = backend.get("desim-cluster", **kw)
        an = backend.get("analytical", **kw)
        rd = des.run_graph(des.lower(sched))
        ra = an.run_graph(an.lower(sched))
        assert ra.detail["rederated_groups"] > 0, \
            "overlapping groups must trigger re-derating"
        err = abs(ra.cycles - rd.cycles) / rd.cycles
        assert err <= 0.05, (f"analytical {ra.cycles:.0f} vs DES "
                             f"{rd.cycles:.0f}: {err:.1%} > 5%")

    def test_chained_schedule_never_rederated(self):
        import dataclasses as _dc
        sched = _dc.replace(self._two_group_sched(256, 256, 1024),
                            overlap="chained")
        an = backend.get("analytical", units=2, strategy="unit-affinity",
                         affinity=dict(sched.affinity))
        res = an.run_graph(an.lower(sched))
        assert res.detail["rederated_groups"] == 0, \
            "chained groups share no window, so no background traffic"

    def test_rederating_only_raises_contended_estimates(self):
        # background traffic can only slow a group down, never speed
        # it up: the re-derated makespan dominates the isolated pass.
        sched = self._two_group_sched(256, 256, 1024)
        kw = dict(units=2, strategy="unit-affinity",
                  affinity=dict(sched.affinity))
        an = backend.get("analytical", **kw)
        graph = an.lower(sched)
        relaxed = an.run_graph(graph).cycles
        chained = backend.get(
            "analytical", units=2, strategy="unit-affinity",
            affinity=dict(sched.affinity)).run_graph(
                an.lower(__import__("dataclasses").replace(
                    sched, overlap="chained"))).cycles
        assert relaxed <= chained * (1 + 1e-9), \
            "overlap must never cost more than full serialisation"

"""Fault-tolerance substrate: checkpointing, watchdog, data pipeline."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, SyntheticLM
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.watchdog import PreemptionHandler, StepWatchdog


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = {"a": jnp.arange(8.0), "b": {"c": jnp.ones((3, 4))}}
        mgr.save(5, tree, extra={"data_step": 17})
        restored, extra = mgr.restore(5, tree)
        assert extra == {"data_step": 17}
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_async_save_and_latest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = {"w": jnp.ones(16)}
        mgr.save_async(1, tree)
        mgr.save_async(2, tree)
        mgr.wait()
        assert mgr.latest_step() == 2

    def test_keep_n_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in range(5):
            mgr.save(s, {"w": jnp.ones(4)})
        assert mgr.all_steps() == [3, 4]

    def test_atomic_no_tmp_left(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"w": jnp.ones(4)})
        assert not any(d.endswith("_tmp") for d in os.listdir(tmp_path))

    def test_structure_mismatch_detected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"w": jnp.ones(4)})
        with pytest.raises(ValueError):
            mgr.restore(1, {"w": jnp.ones(4), "extra": jnp.ones(2)})

    def test_elastic_restore_with_new_sharding(self, tmp_path):
        """Checkpoints are mesh-agnostic: restore with fresh shardings."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        mgr = CheckpointManager(str(tmp_path))
        tree = {"w": jnp.arange(16.0).reshape(4, 4)}
        mgr.save(1, tree)
        from repro.launch.mesh import compat_make_mesh
        mesh = compat_make_mesh((1,), ("data",))
        sh = {"w": NamedSharding(mesh, P("data", None))}
        restored, _ = mgr.restore(1, tree, shardings=sh)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))
        assert restored["w"].sharding == sh["w"]


class TestWatchdog:
    def test_straggler_detection(self):
        wd = StepWatchdog(ema_alpha=0.5, threshold=2.0)
        for _ in range(5):
            assert not wd.record_step(1.0)
        assert wd.record_step(5.0)           # 5x the EMA
        assert wd.straggler_events == 1

    def test_ema_outlier_clamped(self):
        wd = StepWatchdog(ema_alpha=0.5, threshold=2.0)
        wd.record_step(1.0)
        wd.record_step(100.0)                # clamped into the EMA
        assert wd.ema < 5.0

    def test_hang_callback(self):
        fired = []
        wd = StepWatchdog(hang_timeout=0.2, on_hang=lambda: fired.append(1))
        time.sleep(0.5)
        wd.close()
        assert fired

    def test_preemption_flag(self):
        import signal
        h = PreemptionHandler(signals=(signal.SIGUSR1,))
        assert not h.requested
        os.kill(os.getpid(), signal.SIGUSR1)
        time.sleep(0.05)
        assert h.requested
        h.restore()


class TestDataPipeline:
    def test_deterministic_replay(self):
        cfg = DataConfig(vocab_size=128, global_batch=4, seq_len=16)
        a, b = SyntheticLM(cfg), SyntheticLM(cfg)
        for _ in range(3):
            ba, bb = next(a), next(b)
            np.testing.assert_array_equal(np.asarray(ba["tokens"]),
                                          np.asarray(bb["tokens"]))

    def test_state_resume(self):
        cfg = DataConfig(vocab_size=128, global_batch=4, seq_len=16)
        a = SyntheticLM(cfg)
        next(a)
        next(a)
        state = a.state_dict()
        expected = next(a)
        b = SyntheticLM(cfg)
        b.load_state_dict(state)
        got = next(b)
        np.testing.assert_array_equal(np.asarray(expected["tokens"]),
                                      np.asarray(got["tokens"]))

    def test_labels_shift(self):
        cfg = DataConfig(vocab_size=128, global_batch=2, seq_len=16)
        batch = next(SyntheticLM(cfg))
        np.testing.assert_array_equal(np.asarray(batch["tokens"][:, 1:]),
                                      np.asarray(batch["labels"][:, :-1]))

    def test_host_sharding_disjoint(self):
        c0 = DataConfig(vocab_size=128, global_batch=8, seq_len=8,
                        n_hosts=2, host_id=0)
        c1 = DataConfig(vocab_size=128, global_batch=8, seq_len=8,
                        n_hosts=2, host_id=1)
        b0, b1 = next(SyntheticLM(c0)), next(SyntheticLM(c1))
        assert b0["tokens"].shape == (4, 8)
        assert not np.array_equal(np.asarray(b0["tokens"]),
                                  np.asarray(b1["tokens"]))

"""RWKV-6 (Finch) — attention-free SSM family.

Faithful block structure (arXiv:2404.05892):
  * Time-mix: token-shift DDLerp (shared low-rank W1 + per-target W2)
    produces r/k/v/g/w mixes; data-dependent decay via a decay LoRA;
    the WKV recurrence (kernels/rwkv6); per-head GroupNorm; SiLU gate;
    output projection.
  * Channel-mix: token-shift lerp, squared-ReLU FFN with a sigmoid
    receptance gate.

Paper applicability (DESIGN.md §4): the recurrence is vector work — all
projections still flow through ``cute_matmul``; the chunked WKV turns
the state update into MXU-sized outer products.

The XLA (distributed/dry-run) path uses ``rwkv6_chunked_jnp`` — the same
chunked math as the Pallas kernel in pure jnp under ``lax.scan`` so
cost_analysis sees its FLOPs; the Pallas kernel is selected by
``cfg.backend == 'pallas'``.
"""

from __future__ import annotations

import sys

import jax
import jax.numpy as jnp

from repro.core.fusion import linear
from repro.distributed.logical import constrain
from repro.models import common as cm
from repro.models.base import ArchConfig, register_family

_N_MIX = 5     # r, k, v, g, w


# ---------------------------------------------------------------------------
# Chunked WKV in pure jnp (shared math with the Pallas kernel).
# ---------------------------------------------------------------------------

def rwkv6_chunked_jnp(r, k, v, lw, u, *, chunk: int = 64,
                      initial_state=None):
    """r/k/v/lw: (B, H, T, C); u: (H, C) -> (o, final_state)."""
    b, h, t, c = r.shape
    pad = (-t) % chunk
    if pad:
        widths = ((0, 0), (0, 0), (0, pad), (0, 0))
        r, k, v, lw = (jnp.pad(x, widths) for x in (r, k, v, lw))
    tp = t + pad
    n = tp // chunk

    def to_chunks(x):
        return jnp.moveaxis(
            x.astype(jnp.float32).reshape(b, h, n, chunk, c), 2, 0)

    rc, kc, vc, lwc = map(to_chunks, (r, k, v, lw))
    mask = (jnp.arange(chunk)[:, None] > jnp.arange(chunk)[None, :])

    def body(state, inp):
        rr, kk, vv, ww = inp                      # (B, H, L, C)
        la = jnp.cumsum(ww, axis=2)
        la_prev = la - ww
        q_t = rr * jnp.exp(la_prev)
        o = jnp.einsum("bhlc,bhcd->bhld", q_t, state)
        diff = la_prev[:, :, :, None, :] - la[:, :, None, :, :]
        pair = (rr[:, :, :, None, :] * kk[:, :, None, :, :]
                * jnp.exp(jnp.where(mask[None, None, :, :, None],
                                    diff, -1e30)))
        p = jnp.sum(pair, axis=-1)                # (B, H, L, L)
        o = o + jnp.einsum("bhls,bhsd->bhld", p, vv)
        o = o + jnp.sum(rr * u[None, :, None, :] * kk, axis=-1,
                        keepdims=True) * vv
        la_last = la[:, :, -1:, :]
        k_scaled = kk * jnp.exp(la_last - la)
        state = (jnp.exp(la_last[:, :, 0, :])[..., None] * state
                 + jnp.einsum("bhlc,bhld->bhcd", k_scaled, vv))
        return state, o

    if initial_state is None:
        initial_state = jnp.zeros((b, h, c, c), jnp.float32)
    state, o = jax.lax.scan(body, initial_state, (rc, kc, vc, lwc))
    o = jnp.moveaxis(o, 0, 2).reshape(b, h, tp, c)[:, :, :t]
    return o.astype(r.dtype), state


def _wkv(cfg: ArchConfig, r, k, v, lw, u):
    if cfg.backend == "pallas":
        from repro.kernels.rwkv6.ops import rwkv6_scan
        return rwkv6_scan(r, k, v, lw, u, chunk=32)
    if cfg.backend == "dense":
        from repro.kernels.rwkv6.ref import rwkv6_ref
        return rwkv6_ref(r, k, v, lw, u)[0]
    return rwkv6_chunked_jnp(r, k, v, lw, u)[0]


# ---------------------------------------------------------------------------
# Parameters.
# ---------------------------------------------------------------------------

def _layer_init(cfg: ArchConfig, key):
    d, rw = cfg.d_model, cfg.rwkv
    ks = jax.random.split(key, 16)
    dt = cfg.dtype
    p = {
        "ln1": jnp.ones((d,), dt), "ln1_b": jnp.zeros((d,), dt),
        "ln2": jnp.ones((d,), dt), "ln2_b": jnp.zeros((d,), dt),
        # DDLerp token-shift mixes.
        "mu_x": (jax.random.uniform(ks[0], (d,)) * 0.5).astype(dt),
        "mu_rkvgw": (jax.random.uniform(ks[1], (_N_MIX, d)) * 0.5).astype(dt),
        "mix_w1": cm.dense_init(ks[2], (d, _N_MIX * rw.lora_mix), dt),
        "mix_w2": (jax.random.normal(ks[3], (_N_MIX, rw.lora_mix, d))
                   * 0.01).astype(dt),
        # Time-mix projections.
        "w_r": cm.dense_init(ks[4], (d, d), dt),
        "w_k": cm.dense_init(ks[5], (d, d), dt),
        "w_v": cm.dense_init(ks[6], (d, d), dt),
        "w_g": cm.dense_init(ks[7], (d, d), dt),
        "w_o": cm.dense_init(ks[8], (d, d), dt),
        # Data-dependent decay LoRA + per-channel bases.
        "w0": (jax.random.uniform(ks[9], (d,)) * 2.0 - 2.0).astype(jnp.float32),
        "decay_w1": cm.dense_init(ks[10], (d, rw.lora_decay), dt),
        "decay_w2": (jax.random.normal(ks[11], (rw.lora_decay, d))
                     * 0.01).astype(dt),
        "u": (jax.random.normal(ks[12], (d // rw.head_size, rw.head_size))
              * 0.3).astype(jnp.float32),
        "ln_x": jnp.ones((d,), dt), "ln_x_b": jnp.zeros((d,), dt),
        # Channel mix.
        "mu_cm_k": (jax.random.uniform(ks[13], (d,)) * 0.5).astype(dt),
        "mu_cm_r": (jax.random.uniform(ks[13], (d,)) * 0.5).astype(dt),
        "w_cm_k": cm.dense_init(ks[14], (d, cfg.d_ff), dt),
        "w_cm_v": cm.dense_init(ks[15], (cfg.d_ff, d), dt, in_axis=1),
        "w_cm_r": cm.dense_init(ks[9], (d, d), dt),
    }
    return p


def init(cfg: ArchConfig, key):
    ks = jax.random.split(key, 4)
    v = cfg.padded_vocab
    layer_keys = jax.random.split(ks[2], cfg.n_layers)
    return {
        "embedding": cm.embed_init(ks[0], (v, cfg.d_model), cfg.dtype),
        "lm_head": cm.dense_init(ks[1], (cfg.d_model, v), cfg.dtype),
        "ln_in": jnp.ones((cfg.d_model,), cfg.dtype),
        "ln_in_b": jnp.zeros((cfg.d_model,), cfg.dtype),
        "ln_final": jnp.ones((cfg.d_model,), cfg.dtype),
        "ln_final_b": jnp.zeros((cfg.d_model,), cfg.dtype),
        "layers": jax.vmap(lambda k: _layer_init(cfg, k))(layer_keys),
    }


# ---------------------------------------------------------------------------
# Block application.
# ---------------------------------------------------------------------------

def _shift(x, last=None):
    """Token shift: x_{t-1} (zeros or carried state at t=0)."""
    pad = jnp.zeros_like(x[:, :1]) if last is None else last[:, None]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def time_mix(cfg: ArchConfig, p, x, shift_state=None, wkv_state=None):
    b, t, d = x.shape
    rw = cfg.rwkv
    h = d // rw.head_size
    xx = _shift(x, shift_state) - x
    xxx = x + xx * p["mu_x"]
    mix = jnp.tanh(linear(xxx, p["mix_w1"]))            # (B, T, 5*r)
    mix = mix.reshape(b, t, _N_MIX, rw.lora_mix)
    dyn = jnp.einsum("btnr,nrd->btnd", mix, p["mix_w2"])
    mixed = x[:, :, None, :] + xx[:, :, None, :] * (
        p["mu_rkvgw"][None, None] + dyn)                # (B, T, 5, d)
    x_r, x_k, x_v, x_g, x_w = (mixed[:, :, i] for i in range(_N_MIX))

    r = linear(x_r, p["w_r"])
    k = linear(x_k, p["w_k"])
    v = linear(x_v, p["w_v"])
    g = linear(x_g, p["w_g"], activation="silu")
    w_dyn = jnp.tanh(linear(x_w, p["decay_w1"])) @ p["decay_w2"]
    lw = -jnp.exp(jnp.clip(p["w0"][None, None].astype(jnp.float32)
                           + w_dyn.astype(jnp.float32), -8.0, 6.0))

    def heads(z):
        return z.reshape(b, t, h, rw.head_size).transpose(0, 2, 1, 3)

    o = _wkv(cfg, heads(r), heads(k), heads(v), heads(lw), p["u"])
    o = o.transpose(0, 2, 1, 3).reshape(b, t, d)
    o = cm.groupnorm_heads(o, p["ln_x"], p["ln_x_b"], h)
    out = linear(o * g, p["w_o"])
    return constrain(out, ("batch", "seq", "embed")), x[:, -1]


def channel_mix(cfg: ArchConfig, p, x, shift_state=None):
    xx = _shift(x, shift_state) - x
    x_k = x + xx * p["mu_cm_k"]
    x_r = x + xx * p["mu_cm_r"]
    k = linear(x_k, p["w_cm_k"], activation="relu2")
    kv = linear(k, p["w_cm_v"])
    return jax.nn.sigmoid(linear(x_r, p["w_cm_r"]).astype(jnp.float32)
                          ).astype(x.dtype) * kv, x[:, -1]


def block_apply(cfg: ArchConfig, p, x):
    h = cm.layernorm(x, p["ln1"], p["ln1_b"])
    tm, _ = time_mix(cfg, p, h)
    x = x + tm
    h = cm.layernorm(x, p["ln2"], p["ln2_b"])
    cmix, _ = channel_mix(cfg, p, h)
    return x + cmix


def forward(cfg: ArchConfig, params, batch, return_hidden: bool = False):
    x = cm.embed_tokens(cfg, params["embedding"], batch["tokens"])
    x = cm.layernorm(x, params["ln_in"], params["ln_in_b"])

    def body(carry, lp):
        return block_apply(cfg, lp, carry), None

    if cfg.remat != "none":
        body = jax.checkpoint(body, policy=cm.remat_policy(cfg),
                              prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = cm.layernorm(x, params["ln_final"], params["ln_final_b"])
    if return_hidden:
        return x
    return cm.logits_out(cfg, params, x)


# ---------------------------------------------------------------------------
# Serving: state = per-layer (tm_shift, cm_shift, wkv_state).
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch_size: int, max_len: int, dtype=None):
    del max_len                                   # state is O(1) in context
    d, rw = cfg.d_model, cfg.rwkv
    h = d // rw.head_size
    dt = dtype or cfg.dtype
    n = cfg.n_layers
    return {
        "tm_shift": jnp.zeros((n, batch_size, d), dt),
        "cm_shift": jnp.zeros((n, batch_size, d), dt),
        "wkv": jnp.zeros((n, batch_size, h, rw.head_size, rw.head_size),
                         jnp.float32),
    }


def _stateful_block(cfg, lp, x, tm_s, cm_s, wkv_s):
    """Single-step (or chunk) block with explicit state; T small."""
    b, t, d = x.shape
    rw = cfg.rwkv
    h = d // rw.head_size
    hh = cm.layernorm(x, lp["ln1"], lp["ln1_b"])
    xx = _shift(hh, tm_s) - hh
    xxx = hh + xx * lp["mu_x"]
    mix = jnp.tanh(linear(xxx, lp["mix_w1"])).reshape(
        b, t, _N_MIX, rw.lora_mix)
    dyn = jnp.einsum("btnr,nrd->btnd", mix, lp["mix_w2"])
    mixed = hh[:, :, None, :] + xx[:, :, None, :] * (
        lp["mu_rkvgw"][None, None] + dyn)
    x_r, x_k, x_v, x_g, x_w = (mixed[:, :, i] for i in range(_N_MIX))
    r = linear(x_r, lp["w_r"])
    k = linear(x_k, lp["w_k"])
    v = linear(x_v, lp["w_v"])
    g = linear(x_g, lp["w_g"], activation="silu")
    w_dyn = jnp.tanh(linear(x_w, lp["decay_w1"])) @ lp["decay_w2"]
    lw = -jnp.exp(jnp.clip(lp["w0"][None, None].astype(jnp.float32)
                           + w_dyn.astype(jnp.float32), -8.0, 6.0))

    def heads(z):
        return z.reshape(b, t, h, rw.head_size).transpose(0, 2, 1, 3)

    if t > 1:      # prefill: chunked form (MXU-friendly, compact HLO)
        o, wkv_new = rwkv6_chunked_jnp(heads(r), heads(k), heads(v),
                                       heads(lw), lp["u"],
                                       initial_state=wkv_s)
    else:          # decode: exact single-step recurrence
        from repro.kernels.rwkv6.ref import rwkv6_ref
        o, wkv_new = rwkv6_ref(heads(r), heads(k), heads(v), heads(lw),
                               lp["u"], initial_state=wkv_s)
    o = o.transpose(0, 2, 1, 3).reshape(b, t, d)
    o = cm.groupnorm_heads(o, lp["ln_x"], lp["ln_x_b"], h)
    x = x + linear(o * g, lp["w_o"])
    tm_new = hh[:, -1]

    hh = cm.layernorm(x, lp["ln2"], lp["ln2_b"])
    cmix, cm_new = channel_mix(cfg, lp, hh, cm_s)
    return x + cmix, tm_new, cm_new, wkv_new


def _run_stateful(cfg, params, tokens, cache):
    x = cm.embed_tokens(cfg, params["embedding"], tokens)
    x = cm.layernorm(x, params["ln_in"], params["ln_in_b"])

    def body(carry, layer):
        x = carry
        lp, tm_s, cm_s, wkv_s = layer
        x, tm, cms, wkv = _stateful_block(cfg, lp, x, tm_s, cm_s, wkv_s)
        return x, (tm, cms, wkv)

    x, (tm, cms, wkv) = jax.lax.scan(
        body, x, (params["layers"], cache["tm_shift"], cache["cm_shift"],
                  cache["wkv"]))
    new_cache = {"tm_shift": tm.astype(cache["tm_shift"].dtype),
                 "cm_shift": cms.astype(cache["cm_shift"].dtype),
                 "wkv": wkv}
    x = cm.layernorm(x, params["ln_final"], params["ln_final_b"])
    return cm.logits_out(cfg, params, x[:, -1]), new_cache


def prefill(cfg: ArchConfig, params, batch, cache):
    return _run_stateful(cfg, params, batch["tokens"], cache)


def decode_step(cfg: ArchConfig, params, tokens, cache, pos):
    del pos                                        # state carries position
    return _run_stateful(cfg, params, tokens, cache)


register_family("rwkv6")(sys.modules[__name__])

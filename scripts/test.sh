#!/usr/bin/env bash
# Tier-1 verification: the command CI and the roadmap agree on.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"

"""Bench-harness contracts: the fail-loudly units guard and the tracked
KV-pressure rows.

The guard (``benchmarks.run.require_units_support``) exists because a
``u2``-labelled row priced by a single-unit backend silently records a
wrong baseline that every later CI run is then gated against — the
harness must refuse the row, not degrade it.  The ``kv|*`` rows pin the
tentpole's two headline effects as tracked metrics.
"""

import sys

import pytest

sys.path.insert(0, ".")  # repo root: benchmarks/ is a top-level package

from benchmarks.record import record_kv, record_serving          # noqa: E402
from benchmarks.run import require_units_support                 # noqa: E402


class TestRequireUnitsSupport:
    def test_cluster_backends_pass(self):
        require_units_support("analytical", 2)
        require_units_support("desim-cluster", 4)

    def test_single_unit_at_one_passes(self):
        require_units_support("desim", 1)

    def test_single_unit_multi_raises(self):
        with pytest.raises(ValueError, match="desim.*single matrix unit"):
            require_units_support("desim", 2)

    def test_error_names_the_requested_width(self):
        with pytest.raises(ValueError, match="units=4"):
            require_units_support("desim", 4)

    def test_workload_sim_refuses_silent_downgrade(self, monkeypatch):
        """The regression: ``workload_sim`` used to fall through to a
        units=1 engine when --units targeted a single-unit backend."""
        import benchmarks.run as run
        monkeypatch.setattr(run, "ENGINE", "desim")
        monkeypatch.setattr(run, "UNITS", 2)
        with pytest.raises(ValueError, match="single matrix unit"):
            run.workload_sim()

    def test_record_serving_refuses_single_unit_backend(self):
        """The u2 rows of the quick subset must abort the recording
        rather than silently pricing units=1 into the baseline."""
        with pytest.raises(ValueError, match="single matrix unit"):
            record_serving(quick=True, backend_name="desim")


@pytest.fixture(scope="module")
def kv_rows():
    return record_kv(quick=True)


class TestKVBenchRows:
    def test_row_keys(self, kv_rows):
        assert set(kv_rows) == {"kv|unlimited", "kv|pressured",
                                "kv|residency"}
        for entry in kv_rows.values():
            assert set(entry) == {"metrics", "info"}

    def test_pressure_visible(self, kv_rows):
        """The small pool's DES makespan visibly exceeds unlimited."""
        m = kv_rows["kv|pressured"]["metrics"]
        assert m["pressure_ratio"] > 1.01
        assert m["makespan"] > kv_rows["kv|unlimited"]["metrics"]["makespan"]
        assert m["evictions"] > 0
        assert m["refill_bytes"] > 0

    def test_residency_speedup(self, kv_rows):
        """Residency-aware decode-priority beats blind on decode p50;
        the metric name carries 'speedup' so check_bench treats a drop
        as a regression."""
        from scripts.check_bench import higher_is_better
        m = kv_rows["kv|residency"]["metrics"]
        assert m["residency_speedup"] > 1.05
        assert higher_is_better("residency_speedup")
        assert not higher_is_better("pressure_ratio")

    def test_deterministic(self, kv_rows):
        again = record_kv(quick=True)
        a = {k: v["metrics"] for k, v in kv_rows.items()}
        b = {k: v["metrics"] for k, v in again.items()}
        assert a == b
        assert (again["kv|pressured"]["info"]["trace_digest"]
                == kv_rows["kv|pressured"]["info"]["trace_digest"])

    def test_check_bench_gates_kv_regression(self, kv_rows, tmp_path):
        """A worsened kv row against the recorded baseline fails the
        gate; the identical snapshot passes."""
        import copy
        import json
        from scripts.check_bench import main as check_main

        doc = {"schema_version": 1, "bench": "serving", "entries": kv_rows}
        base = tmp_path / "base"
        fresh = tmp_path / "fresh"
        for d in (base, fresh):
            d.mkdir()
        (base / "BENCH_serving.json").write_text(json.dumps(doc))
        (fresh / "BENCH_serving.json").write_text(json.dumps(doc))
        assert check_main(["--baseline-dir", str(base),
                           "--fresh-dir", str(fresh)]) == 0

        worse = copy.deepcopy(doc)
        worse["entries"]["kv|pressured"]["metrics"]["makespan"] *= 1.5
        worse["entries"]["kv|residency"]["metrics"][
            "residency_speedup"] *= 0.5
        (fresh / "BENCH_serving.json").write_text(json.dumps(worse))
        assert check_main(["--baseline-dir", str(base),
                           "--fresh-dir", str(fresh)]) == 1

"""RG-LRU (Griffin / RecurrentGemma) gated linear recurrence kernel.

    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ x_t,   a_t = exp(log_a_t) ≤ 1

``log_a`` and the gated input are computed by the surrounding block
(matmuls through ``cute_matmul``); the kernel is the pure recurrence —
vector-unit work in the paper's taxonomy, overlapped with the
projection GEMMs at the layer level (DESIGN.md §4).

Channels are independent, so the grid parallelises (batch × channel
blocks) and walks chunks of time sequentially with the carry in VMEM.
Inside a chunk a ``fori_loop`` runs the exact recurrence (L small); a
production variant would use the associative-scan form.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def rglru_kernel(log_a_ref, x_ref, o_ref, h_ref, *, chunk: int):
    t0 = pl.program_id(2)

    @pl.when(t0 == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    log_a = log_a_ref[0].astype(jnp.float32)      # (L, bc)
    x = x_ref[0].astype(jnp.float32)              # (L, bc)
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably: 1 - exp(2·log_a) via expm1.
    beta = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    gated = beta * x

    def body(t, h):
        h = a[t] * h + gated[t]
        pl.store(o_ref, (pl.dslice(0, 1), pl.dslice(t, 1), slice(None)),
                 h[None, None].astype(o_ref.dtype))
        return h

    h_final = jax.lax.fori_loop(0, chunk, body, h_ref[0, :])
    h_ref[0, :] = h_final

"""The asynchronous matmul task descriptor — paper Table 1, verbatim.

The entire ISA surface of CUTEv2 is: write these interface registers,
fire ``asyncMatMul``, poll ``Status`` with ``checkMatmul``.  We keep the
exact field set so the RTL-world simulator, the XLA backend and the
Pallas backend all speak one vocabulary.  Base addresses and strides are
symbolic in the JAX world (arrays are values, not pointers) but are kept
because the simulator's memory-loader model and the reproduction
benchmarks consume them (stride patterns drive DRAM efficiency, §5.4).
"""

from __future__ import annotations

import dataclasses
import enum

from repro.core.precision import DataType, policy


class BiasType(str, enum.Enum):
    """Paper Table 1: Zero, Row-Repeat, Full."""

    ZERO = "zero"
    ROW = "row"      # (N,) broadcast over rows — "Row-Repeat"
    FULL = "full"    # (M, N)


class Status(enum.IntEnum):
    IDLE = 0
    RUNNING = 1
    DONE = 2


@dataclasses.dataclass
class MatMulTask:
    """One asyncMatMul: C[M,N] (+)= A[M,K] @ B[K,N] + bias."""

    m: int
    n: int
    k: int
    data_type: DataType = DataType.INT8
    bias_type: BiasType = BiasType.ZERO
    transpose: bool = False          # result transpose flag
    accumulate: bool = False         # C += vs C =
    # Memory descriptors (symbolic under JAX; used by the simulator).
    base_a: int = 0
    base_b: int = 0
    base_bias: int = 0
    base_c: int = 0
    stride_a: int = 0                # row strides in elements; 0 = dense
    stride_b: int = 0
    stride_bias: int = 0
    stride_c: int = 0
    status: Status = Status.IDLE

    def __post_init__(self):
        if min(self.m, self.n, self.k) <= 0:
            raise ValueError(f"degenerate task {self.m}x{self.n}x{self.k}")
        if self.stride_a == 0:
            self.stride_a = self.k
        if self.stride_b == 0:
            self.stride_b = self.n
        if self.stride_c == 0:
            self.stride_c = self.n

    # ----- cost metadata ---------------------------------------------------
    @property
    def macs(self) -> int:
        return self.m * self.n * self.k

    @property
    def flops(self) -> int:
        return 2 * self.macs

    @property
    def in_bytes(self) -> float:
        eb = policy(self.data_type).bytes_per_elem
        bias = 0.0
        if self.bias_type == BiasType.ROW:
            bias = self.n * 4.0
        elif self.bias_type == BiasType.FULL:
            bias = self.m * self.n * 4.0
        return (self.m * self.k + self.k * self.n) * eb + bias

    def out_bytes(self, out_elem_bytes: float = 4.0) -> float:
        return self.m * self.n * out_elem_bytes

    def arithmetic_intensity(self) -> float:
        return self.flops / (self.in_bytes + self.out_bytes())


def tile_tasks(task: MatMulTask, tile_m: int, tile_n: int) -> "list[MatMulTask]":
    """Split one logical matmul into scratchpad-tile-granularity tasks.

    This is what the ``asyncMatMul`` *macro* of Listing 1 does: "dispatches
    a task per tile, with tile size determined by shared storage capacity".
    Edge tiles keep their true (smaller) extents.
    """
    out = []
    for m0 in range(0, task.m, tile_m):
        for n0 in range(0, task.n, tile_n):
            out.append(dataclasses.replace(
                task,
                m=min(tile_m, task.m - m0),
                n=min(tile_n, task.n - n0),
                base_a=task.base_a + m0 * task.stride_a,
                base_b=task.base_b + n0,
                base_c=task.base_c + m0 * task.stride_c + n0,
                status=Status.IDLE,
            ))
    return out

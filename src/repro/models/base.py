"""Architecture configuration + the model registry.

One ``ArchConfig`` dataclass drives every assigned architecture; family-
specific sub-configs (MoE, RNN, RWKV, encoder-decoder) are optional
fields.  Every model family implements the same functional protocol:

    init(cfg, key)                          -> params pytree
    forward(cfg, params, batch)             -> logits (B, S, V)   [train]
    init_cache(cfg, batch, max_len, dtype)  -> cache pytree
    prefill(cfg, params, batch, cache)      -> (last_logits, cache)
    decode_step(cfg, params, token, cache, pos) -> (logits, cache)

``batch`` is a dict: tokens (B, S) plus stub-frontend tensors for the
VLM / audio entries (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


def round_up(x: int, m: int) -> int:
    return x + (-x) % m


@dataclasses.dataclass(frozen=True)
class MoeConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    renormalize: bool = False          # OLMoE keeps raw softmax weights
    dense_parallel: bool = False       # Arctic: dense MLP residual branch


@dataclasses.dataclass(frozen=True)
class RnnConfig:                       # Griffin / RecurrentGemma RG-LRU
    d_rnn: int
    conv_width: int = 4
    c: float = 8.0                     # log_a = -c * softplus(Λ) * sigmoid(r)
    block_pattern: "tuple[str, ...]" = ("rec", "rec", "attn")


@dataclasses.dataclass(frozen=True)
class RwkvConfig:
    head_size: int = 64
    lora_mix: int = 32                 # DDLerp low-rank dim
    lora_decay: int = 64
    lora_gate: int = 64


@dataclasses.dataclass(frozen=True)
class EncDecConfig:                    # Whisper
    n_encoder_layers: int
    n_audio_ctx: int = 1500
    learned_pos: bool = True
    # Whisper's real decoder context is 448; the assignment's shape grid
    # drives the backbone to 4k/32k, so the learned table is sized to fit.
    max_positions: int = 32768


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                        # transformer | rwkv6 | griffin | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # --- attention / block flags -----------------------------------------
    rope_theta: float = 1e4
    rms_eps: float = 1e-6
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    query_scale: Optional[float] = None      # None -> 1/sqrt(head_dim)
    window: int = 0                          # local-attention window
    layer_pattern: str = "uniform"           # uniform | gemma2_alt | griffin
    mlp_activation: str = "silu"
    mlp_glu: bool = True
    sandwich_norms: bool = False             # gemma2 pre+post norms
    rmsnorm_unit_offset: bool = False        # gemma-style (1 + w) scale
    embed_scale: bool = False                # embed * sqrt(d_model)
    tie_embeddings: bool = False
    vocab_pad_to: int = 256
    # --- family extensions -------------------------------------------------
    moe: Optional[MoeConfig] = None
    rnn: Optional[RnnConfig] = None
    rwkv: Optional[RwkvConfig] = None
    encdec: Optional[EncDecConfig] = None
    vision_prefix: int = 0                   # InternVL stub image tokens
    # --- runtime -----------------------------------------------------------
    dtype: object = jnp.bfloat16
    backend: str = "xla"                     # xla | pallas | dense
    remat: str = "full"                      # full | dots | none
    kv_cache_dtype: object = jnp.bfloat16
    attn_chunk: int = 1024                   # chunked-XLA attention KV block
    attn_pv_bf16: bool = False               # P·V in bf16 (perf lever)
    moe_shard_map: bool = True               # False: GSPMD EP (decode lever)

    @property
    def padded_vocab(self) -> int:
        return round_up(self.vocab_size, self.vocab_pad_to)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def sm_scale(self) -> float:
        return (self.query_scale if self.query_scale is not None
                else self.head_dim ** -0.5)

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # --- parameter counting (MODEL_FLOPS denominators) ---------------------
    def param_count(self, active_only: bool = False) -> int:
        """Approximate parameter count; active_only counts top-k experts."""
        d, v = self.d_model, self.padded_vocab
        embed = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "rwkv6":
            rw = self.rwkv
            per = (5 * d * d                          # r, k, v, g, out proj
                   + 10 * d * rw.lora_mix             # DDLerp W1/W2
                   + 2 * d * rw.lora_decay + 2 * d * rw.lora_gate
                   + 2 * d * self.d_ff + d * d)       # channel mix (k, v, r)
            return embed + self.n_layers * per
        attn = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
        glu_mult = 2 if self.mlp_glu else 1
        dense_mlp = d * self.d_ff * glu_mult + self.d_ff * d
        per = attn + dense_mlp
        if self.moe:
            e = self.moe.top_k if active_only else self.moe.n_experts
            expert = d * self.moe.d_ff_expert * glu_mult + self.moe.d_ff_expert * d
            per = attn + e * expert + d * self.moe.n_experts
            if self.moe.dense_parallel:
                per += dense_mlp
        if self.family == "griffin":
            rn = self.rnn
            n_rec = sum(1 for i in range(self.n_layers)
                        if rn.block_pattern[i % len(rn.block_pattern)] == "rec")
            n_att = self.n_layers - n_rec
            rec = (2 * d * rn.d_rnn + rn.d_rnn * d       # in/out projections
                   + rn.conv_width * rn.d_rnn + 2 * rn.d_rnn * rn.d_rnn // 16)
            per_att = attn + dense_mlp
            per_rec = rec + dense_mlp
            return embed + n_rec * per_rec + n_att * per_att
        if self.family == "encdec":
            enc_layers = self.encdec.n_encoder_layers
            cross = attn                                  # cross-attention
            return (embed + enc_layers * per
                    + self.n_layers * (per + cross))
        return embed + self.n_layers * per


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register_family(name: str):
    def deco(module):
        _REGISTRY[name] = module
        return module
    return deco


def family_module(cfg: ArchConfig):
    """Resolve the functional module implementing ``cfg.family``."""
    # Import for side effects (registration); idempotent via sys.modules.
    from repro.models import transformer, rwkv6, recurrentgemma, whisper  # noqa: F401
    return _REGISTRY[cfg.family]

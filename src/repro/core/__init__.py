"""CUTEv2 core: the paper's contribution as a composable JAX module.

Public surface:
  * ``MatrixUnitConfig`` / presets — paper Table 2 + Eq. 1.
  * ``constraint`` — Eq. 2 at scratchpad, VMEM and ICI levels.
  * ``MatMulTask`` / ``BiasType`` — paper Table 1 interface registers.
  * ``AsyncMatmulEngine`` / ``pipelined_fused_matmul`` — asyncMatMul /
    checkMatmul programming model (Listing 1).
  * ``cute_matmul`` / ``linear`` / ``Epilogue`` — the unified fused-matmul
    API every model routes through.
  * ``simulator`` — cycle-approximate reproduction of the paper's
    evaluation platform.
  * ``roofline`` — TPU three-term roofline for the dry-run analysis.
"""

from repro.core.config import (CASE_STUDY, PLATFORM_2TOPS, MatrixUnitConfig,
                               scaled_config, scaling_sweep)
from repro.core.engine import AsyncMatmulEngine, Handle, pipelined_fused_matmul
from repro.core.fusion import (ACTIVATIONS, Epilogue, EpilogueOperands,
                               NO_EPILOGUE, NO_OPERANDS, apply_epilogue,
                               cute_matmul, linear)
from repro.core.precision import (BF16, DataType, FP8, FP16, FP32, INT8,
                                  PrecisionPolicy, TF32, policy)
from repro.core.task import BiasType, MatMulTask, Status, tile_tasks

__all__ = [
    "CASE_STUDY", "PLATFORM_2TOPS", "MatrixUnitConfig", "scaled_config",
    "scaling_sweep", "AsyncMatmulEngine", "Handle", "pipelined_fused_matmul",
    "ACTIVATIONS", "Epilogue", "EpilogueOperands", "NO_EPILOGUE",
    "NO_OPERANDS", "apply_epilogue", "cute_matmul", "linear", "BF16",
    "DataType", "FP8", "FP16", "FP32", "INT8", "PrecisionPolicy", "TF32",
    "policy", "BiasType", "MatMulTask", "Status", "tile_tasks",
]

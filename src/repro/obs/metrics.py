"""Metrics registry: counters, gauges and histograms with labels.

The paper's headline results are *measurement claims* (≥90% matrix-unit
utilization, >30% of the end-to-end gain from matrix–vector overlap);
this module is the repo's durable measurement layer.  A
:class:`MetricsRegistry` holds three metric kinds, each addressable by
name + label set:

* :class:`Counter` — monotonically increasing totals (requests planned,
  cache hits, graphs priced);
* :class:`Gauge` — last-write-wins values (aggregate utilization of the
  most recent run);
* :class:`Histogram` — sampled distributions with nearest-rank
  ``p50/p90/p99`` (TTFT, inter-token latency, per-step cycles,
  backend wall-clock).

Two exporters: :meth:`MetricsRegistry.snapshot` (a JSON-able dict, the
``BENCH_*.json`` / ``--metrics-out`` currency) and
:meth:`MetricsRegistry.prometheus_text` (the Prometheus text exposition
format, so a scraper can lift the same numbers).

Collection is **disabled by default** outside the serving/bench entry
points: the module-level default registry starts disabled, and a
disabled registry hands out a shared no-op metric so instrumented hot
paths (the DES, backend ``run_graph``) pay one attribute check and
nothing else.  ``launch/serve.py --metrics-out`` and
``benchmarks/record.py`` enable it; tests construct their own enabled
registries.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Optional


def _percentile(xs: "list[float]", q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]); 0.0 on empty input —
    the same convention ``serving.scheduler`` uses."""
    if not xs:
        return 0.0
    xs = sorted(xs)
    rank = max(1, math.ceil(q / 100.0 * len(xs)))
    return xs[min(rank, len(xs)) - 1]


@dataclasses.dataclass
class Counter:
    """Monotonic total.  ``inc`` with a negative amount raises — a
    counter that can go down is a gauge wearing a disguise."""

    name: str
    labels: "tuple[tuple[str, str], ...]" = ()
    value: float = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease "
                             f"(inc({amount}))")
        self.value += amount


@dataclasses.dataclass
class Gauge:
    """Last-write-wins value."""

    name: str
    labels: "tuple[tuple[str, str], ...]" = ()
    value: float = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.value -= amount


@dataclasses.dataclass
class Histogram:
    """Sampled distribution; keeps the raw samples (serving runs are
    thousands of observations, not millions) so any percentile is exact
    nearest-rank rather than bucket-interpolated."""

    name: str
    labels: "tuple[tuple[str, str], ...]" = ()
    samples: "list[float]" = dataclasses.field(default_factory=list)

    def observe(self, value: float) -> None:
        self.samples.append(float(value))

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def sum(self) -> float:
        return float(sum(self.samples))

    def percentile(self, q: float) -> float:
        return _percentile(self.samples, q)

    def summary(self) -> "dict[str, float]":
        return {
            "count": float(self.count),
            "sum": self.sum,
            "min": min(self.samples) if self.samples else 0.0,
            "max": max(self.samples) if self.samples else 0.0,
            "p50": self.percentile(50.0),
            "p90": self.percentile(90.0),
            "p99": self.percentile(99.0),
        }


class _NullMetric:
    """The shared no-op metric a disabled registry hands out: every
    mutator is a pass, so instrumented call sites need no branches."""

    __slots__ = ()

    def inc(self, amount: float = 1.0) -> None:
        pass

    def dec(self, amount: float = 1.0) -> None:
        pass

    def set(self, value: float) -> None:
        pass

    def observe(self, value: float) -> None:
        pass


NULL_METRIC = _NullMetric()


def _label_key(labels: dict) -> "tuple[tuple[str, str], ...]":
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class MetricsRegistry:
    """Named, labeled metrics behind get-or-create accessors.

    ``counter("requests_total", policy="auto")`` returns the one child
    for that (name, label set) — repeated calls accumulate into the same
    series.  A disabled registry returns :data:`NULL_METRIC` from every
    accessor, making instrumentation free when observability is off.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._metrics: "dict[tuple, object]" = {}
        self._kinds: "dict[str, str]" = {}     # name -> kind (consistency)

    # ----- lifecycle -------------------------------------------------------
    def enable(self) -> "MetricsRegistry":
        self.enabled = True
        return self

    def disable(self) -> "MetricsRegistry":
        self.enabled = False
        return self

    def clear(self) -> None:
        self._metrics.clear()
        self._kinds.clear()

    # ----- accessors -------------------------------------------------------
    def _get(self, kind: str, cls, name: str, labels: dict):
        if not self.enabled:
            return NULL_METRIC
        prev = self._kinds.setdefault(name, kind)
        if prev != kind:
            raise ValueError(f"metric {name!r} already registered as a "
                             f"{prev}, not a {kind}")
        key = (kind, name, _label_key(labels))
        metric = self._metrics.get(key)
        if metric is None:
            metric = self._metrics[key] = cls(name, key[2])
        return metric

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get("histogram", Histogram, name, labels)

    def timer(self, name: str, **labels) -> "_Timer":
        """Context manager observing elapsed wall-clock seconds into the
        ``name`` histogram (no-op when disabled)."""
        return _Timer(self.histogram(name, **labels))

    # ----- exporters -------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-able dump: ``{counters: {name: [{labels, value}]},
        gauges: {...}, histograms: {name: [{labels, count, sum, p50,
        p90, p99, ...}]}}`` — the shape ``--metrics-out`` writes and the
        docs catalogue documents."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for (kind, name, labels), m in sorted(self._metrics.items()):
            row = {"labels": dict(labels)}
            if kind == "histogram":
                row.update(m.summary())
                out["histograms"].setdefault(name, []).append(row)
            else:
                row["value"] = m.value
                out[kind + "s"].setdefault(name, []).append(row)
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition format (one line per series;
        histograms exported as ``_count`` / ``_sum`` plus quantile
        gauges — a pragmatic summary, not cumulative buckets)."""
        lines: "list[str]" = []

        def fmt(name, labels, value):
            if labels:
                body = ",".join(f'{k}="{v}"' for k, v in labels)
                return f"{name}{{{body}}} {value:g}"
            return f"{name} {value:g}"

        by_name: "dict[tuple, list]" = {}
        for (kind, name, labels), m in sorted(self._metrics.items()):
            by_name.setdefault((kind, name), []).append((labels, m))
        for (kind, name), series in by_name.items():
            ptype = {"counter": "counter", "gauge": "gauge",
                     "histogram": "summary"}[kind]
            lines.append(f"# TYPE {name} {ptype}")
            for labels, m in series:
                if kind == "histogram":
                    lines.append(fmt(name + "_count", labels, m.count))
                    lines.append(fmt(name + "_sum", labels, m.sum))
                    for q in (50, 90, 99):
                        ql = labels + (("quantile", f"0.{q}"),)
                        lines.append(fmt(name, ql, m.percentile(q)))
                else:
                    lines.append(fmt(name, labels, m.value))
        return "\n".join(lines) + ("\n" if lines else "")


class _Timer:
    def __init__(self, hist):
        self._hist = hist
        self._t0: Optional[float] = None

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._hist.observe(time.perf_counter() - self._t0)
        return False


#: The process-wide default registry.  Starts **disabled** — the DES and
#: backend hot paths are instrumented against it, and outside the
#: serving/bench entry points every observation is a no-op.
_DEFAULT = MetricsRegistry(enabled=False)


def default_registry() -> MetricsRegistry:
    return _DEFAULT


def enable_metrics() -> MetricsRegistry:
    """Turn the default registry on (serving/bench entry points)."""
    return _DEFAULT.enable()


def disable_metrics() -> MetricsRegistry:
    return _DEFAULT.disable()

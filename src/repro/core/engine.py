"""The asynchronous programming model: ``asyncMatMul`` / ``checkMatmul``.

Paper Listing 1::

    for (tile in tiles) asyncMatMul(tile);          // fire and forget
    for (tile in tiles) { checkMatmul(tile);        // sync primitive
                          vector_epilogue(tile); }  // overlapped on VPU

JAX is a dataflow language, so "asynchrony" is not something the user
schedules with fences — but the *programming model* still matters: it is
what lets one software stack target four CPUs in the paper, and one model
zoo target two backends here.  ``AsyncMatmulEngine`` keeps the paper's
dispatch/check/wait vocabulary:

* ``dispatch(task, a, b, ...)`` returns a ``Handle`` immediately; nothing
  is computed at dispatch time (the thunk is staged).
* ``check(handle)`` / ``wait(handle)`` force the result.  Under ``jit``
  the forcing point determines where the matmul lands in the schedule —
  exactly the role ``checkMatmul`` plays in Listing 1.
* ``pipelined_fused_matmul`` is Listing 1 end-to-end: tile the M axis,
  dispatch every tile, then walk the tiles applying the vector epilogue.
  On TPU the same overlap is realised *inside* the Pallas kernel (grid
  pipelining); this function is the graph-level mirror used by serving
  and by the reproduction tests.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.config import MatrixUnitConfig, CASE_STUDY
from repro.core.fusion import (Epilogue, EpilogueOperands, NO_EPILOGUE,
                               NO_OPERANDS, cute_matmul, apply_epilogue)
from repro.core.task import MatMulTask, Status, tile_tasks


@dataclasses.dataclass
class Handle:
    """The ``Status`` interface register, reified.

    ``done()`` reads the task's Status register — the same word
    ``checkMatmul`` polls in hardware — so a handle and its task can
    never disagree about completion (``IDLE -> RUNNING`` at dispatch,
    ``-> DONE`` when forced).
    """

    task: MatMulTask
    _thunk: Callable[[], jax.Array]
    _result: Optional[jax.Array] = None

    def done(self) -> bool:
        return self.task.status is Status.DONE

    def force(self) -> jax.Array:
        if self._result is None:
            self._result = self._thunk()
            self.task.status = Status.DONE
        return self._result


class AsyncMatmulEngine:
    """Software façade of the decoupled matrix unit."""

    def __init__(self, unit: MatrixUnitConfig = CASE_STUDY,
                 backend: str = "xla"):
        self.unit = unit
        self.backend = backend
        self.dispatched: "list[Handle]" = []

    # -- asyncMatMul --------------------------------------------------------
    def dispatch(self, task: MatMulTask, a: jax.Array, b: jax.Array, *,
                 epilogue: Epilogue = NO_EPILOGUE,
                 operands: EpilogueOperands = NO_OPERANDS) -> Handle:
        if a.shape[-2:] != (task.m, task.k) or b.shape[-2:] != (task.k, task.n):
            raise ValueError(
                f"operands {a.shape}x{b.shape} disagree with task "
                f"{task.m}x{task.k}x{task.n}")
        task.status = Status.RUNNING
        thunk = lambda: cute_matmul(a, b, epilogue=epilogue, operands=operands,
                                    backend=self.backend)
        h = Handle(task, thunk)
        self.dispatched.append(h)
        return h

    # -- checkMatmul --------------------------------------------------------
    def check(self, handle: Handle) -> bool:
        return handle.done()

    def wait(self, handle: Handle) -> jax.Array:
        return handle.force()

    def drain(self) -> "list[jax.Array]":
        return [h.force() for h in self.dispatched]


def pipelined_fused_matmul(a: jax.Array, b: jax.Array,
                           vector_epilogue: Callable[[jax.Array], jax.Array],
                           *, tile_m: int = 128,
                           engine: Optional[AsyncMatmulEngine] = None,
                           task: Optional[MatMulTask] = None) -> jax.Array:
    """Listing 1, faithfully: tile-granular dispatch + overlapped epilogue.

    ``vector_epilogue`` is arbitrary vector-unit work (softmax, RMSNorm,
    dequant...) applied per M-tile.  Under jit, XLA observes one matmul
    consumer chain per tile with no cross-tile dependency — the schedule
    the paper's hardware realises physically.
    """
    if engine is None:
        engine = AsyncMatmulEngine()
    m, k = a.shape[-2:]
    n = b.shape[-1]
    if task is None:
        task = MatMulTask(m=m, n=n, k=k)
    if m % tile_m:
        raise ValueError(f"tile_m={tile_m} must divide M={m}")

    handles = []
    for i, sub in enumerate(tile_tasks(task, tile_m, n)):
        a_tile = jax.lax.dynamic_slice_in_dim(a, i * tile_m, tile_m, axis=-2)
        handles.append(engine.dispatch(sub, a_tile, b))       # asyncMatMul
    outs = []
    for h in handles:                                         # checkMatmul
        outs.append(vector_epilogue(engine.wait(h)))          # vector work
    return jnp.concatenate(outs, axis=-2)

"""einsum oracle for the grouped MoE GEMM."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.fusion import Epilogue, EpilogueOperands, apply_epilogue


def grouped_matmul_ref(x, w, *, epilogue: Epilogue = Epilogue(),
                       accum_dtype=jnp.float32):
    """x: (E, C, K); w: (E, K, N) or (E, K, 2, N/2) under GLU."""
    if w.ndim == 4:
        w = w.reshape(w.shape[0], w.shape[1], -1)
    acc = jnp.einsum("eck,ekn->ecn", x, w,
                     preferred_element_type=accum_dtype)
    return apply_epilogue(acc, epilogue, EpilogueOperands())

"""End-to-end driver: train a ~100M-param llama-arch model for a few
hundred steps on the synthetic stream, with fault-tolerant checkpointing.

    PYTHONPATH=src python examples/train_lm.py --steps 300 [--small]

``--small`` shrinks to smoke scale (seconds on CPU).  The default builds
a genuine ~100M-parameter model (d=640, 10 layers, 32k vocab) and runs
the full production loop: sharded init, microbatched train step, async
checkpoints, straggler watchdog, resume-on-restart (deliverable (b)).
"""

import argparse
import os
import sys
import time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.base import family_module
from repro.optim import adamw
from repro.runtime.checkpoint import CheckpointManager
from repro.runtime.watchdog import StepWatchdog
from repro.training.train_step import TrainConfig, make_train_step


def build_config(small: bool):
    base = get_config("yi-6b")          # llama-arch family wiring
    if small:
        return base.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          head_dim=16, d_ff=128, vocab_size=512,
                          dtype=jnp.float32, remat="none", attn_chunk=64)
    return base.with_(n_layers=10, d_model=640, n_heads=10, n_kv_heads=5,
                      head_dim=64, d_ff=1920, vocab_size=32000,
                      dtype=jnp.float32, remat="none", attn_chunk=256)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = build_config(args.small)
    if args.small:
        args.seq_len = min(args.seq_len, 64)
    mod = family_module(cfg)
    print(f"model: {cfg.param_count() / 1e6:.1f}M params "
          f"({cfg.n_layers}L d={cfg.d_model} vocab={cfg.padded_vocab})")

    tcfg = TrainConfig(
        optimizer=adamw.AdamWConfig(lr=3e-3, total_steps=args.steps,
                                    warmup_steps=max(args.steps // 20, 1)),
        loss_chunk=min(256, args.seq_len))
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  global_batch=args.global_batch,
                                  seq_len=args.seq_len))
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    wd = StepWatchdog()

    params = mod.init(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(tcfg.optimizer, params)
    start = 0
    if mgr.latest_step() is not None:
        restored, extra = mgr.restore(mgr.latest_step(),
                                      {"params": params, "opt": opt})
        params, opt = restored["params"], restored["opt"]
        data.load_state_dict(extra["data"])
        start = extra["step"]
        print(f"resumed from step {start}")

    first_loss = None
    for step in range(start, args.steps):
        t0 = time.perf_counter()
        params, opt, metrics, _ = step_fn(params, opt, next(data))
        loss = float(metrics["loss"])
        wd.record_step(time.perf_counter() - t0)
        if first_loss is None:
            first_loss = loss
        if step % 20 == 0:
            print(f"step {step:4d}  loss {loss:.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"{(time.perf_counter() - t0) * 1e3:.0f} ms", flush=True)
        if (step + 1) % 100 == 0:
            mgr.save_async(step + 1, {"params": params, "opt": opt},
                           extra={"data": data.state_dict(),
                                  "step": step + 1})
    mgr.wait()
    wd.close()
    print(f"final loss {loss:.4f} (started {first_loss:.4f}); "
          f"checkpoints at {args.ckpt_dir}: steps {mgr.all_steps()}")


if __name__ == "__main__":
    main()

"""Fused matmul Pallas kernel vs the pure-jnp oracle: shape/dtype sweeps
+ hypothesis property tests (interpret mode)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import precision as prec
from repro.core.fusion import Epilogue, EpilogueOperands
from repro.core.task import BiasType
from repro.kernels.matmul.ops import fused_matmul, supports
from repro.kernels.matmul.ref import fused_matmul_ref


def _run(a, b, ep=Epilogue(), ops=EpilogueOperands(), policy=None,
         bs=(64, 128, 128), rtol=2e-2):
    out = fused_matmul(a, b, epilogue=ep, operands=ops, policy=policy,
                       block_shape=bs)
    ep2 = ep if ep.out_dtype is not None else dataclasses.replace(
        ep, out_dtype=out.dtype)
    acc = policy.accum_dtype if policy else (
        jnp.int32 if a.dtype == jnp.int8 else jnp.float32)
    ref = fused_matmul_ref(a, b, epilogue=ep2, operands=ops, accum_dtype=acc)
    o = np.asarray(out, np.float32)
    r = np.asarray(ref, np.float32)
    err = np.abs(o - r).max() / (np.abs(r).max() + 1e-9)
    assert err < rtol, err
    return out


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


DTYPES = [jnp.float32, jnp.bfloat16, jnp.float16, jnp.int8,
          jnp.float8_e4m3fn]


@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: d.__name__)
def test_dtype_sweep(rng, dtype):
    if dtype == jnp.int8:
        a = jax.random.randint(rng, (96, 128), -127, 127, jnp.int8)
        b = jax.random.randint(rng, (128, 128), -127, 127, jnp.int8)
        _run(a, b, Epilogue(out_dtype=jnp.int32), rtol=1e-6)
    else:
        a = jax.random.normal(rng, (96, 128)).astype(dtype)
        b = jax.random.normal(jax.random.PRNGKey(1), (128, 128)).astype(dtype)
        _run(a, b, rtol=3e-2 if dtype != jnp.float32 else 1e-5)


@pytest.mark.parametrize("shape", [(64, 128, 128), (200, 384, 256),
                                   (33, 130, 257), (512, 128, 640)])
def test_shape_sweep(rng, shape):
    m, k, n = shape
    a = jax.random.normal(rng, (m, k), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    _run(a, b, rtol=1e-5)


@pytest.mark.parametrize("act", ["relu", "gelu", "silu", "gelu_tanh",
                                 "relu2", "sigmoid"])
def test_activation_epilogues(rng, act):
    a = jax.random.normal(rng, (64, 128), jnp.bfloat16)
    b = jax.random.normal(jax.random.PRNGKey(1), (128, 128), jnp.bfloat16)
    _run(a, b, Epilogue(activation=act))


def test_bias_row_and_full(rng):
    a = jax.random.normal(rng, (64, 128), jnp.bfloat16)
    b = jax.random.normal(jax.random.PRNGKey(1), (128, 256), jnp.bfloat16)
    bias_r = jax.random.normal(jax.random.PRNGKey(2), (256,), jnp.float32)
    _run(a, b, Epilogue(bias_type=BiasType.ROW), EpilogueOperands(bias=bias_r))
    bias_f = jax.random.normal(jax.random.PRNGKey(3), (64, 256), jnp.float32)
    _run(a, b, Epilogue(bias_type=BiasType.FULL),
         EpilogueOperands(bias=bias_f))


def test_glu_epilogues(rng):
    a = jax.random.normal(rng, (64, 128), jnp.bfloat16)
    b = jax.random.normal(jax.random.PRNGKey(1), (128, 512), jnp.bfloat16)
    _run(a, b, Epilogue(activation="silu", glu=True))
    bias = jax.random.normal(jax.random.PRNGKey(2), (512,), jnp.float32)
    _run(a, b, Epilogue(activation="gelu_tanh", glu=True,
                        bias_type=BiasType.ROW), EpilogueOperands(bias=bias))


def test_int8_dequant_pipeline(rng):
    """SmoothQuant-style: int8 x int8 -> int32 -> scales -> bf16 + silu."""
    a = jax.random.randint(rng, (64, 256), -127, 127, jnp.int8)
    b = jax.random.randint(jax.random.PRNGKey(1), (256, 128), -127, 127,
                           jnp.int8)
    sa = jax.random.uniform(jax.random.PRNGKey(2), (64,), jnp.float32,
                            0.005, 0.02)
    sb = jax.random.uniform(jax.random.PRNGKey(3), (128,), jnp.float32,
                            0.005, 0.02)
    _run(a, b, Epilogue(has_scale_a=True, has_scale_b=True,
                        activation="silu", out_dtype=jnp.bfloat16),
         EpilogueOperands(scale_a=sa, scale_b=sb))


def test_residual_and_softcap(rng):
    a = jax.random.normal(rng, (64, 128), jnp.bfloat16)
    b = jax.random.normal(jax.random.PRNGKey(1), (128, 128), jnp.bfloat16)
    res = jax.random.normal(jax.random.PRNGKey(2), (64, 128), jnp.float32)
    _run(a, b, Epilogue(has_residual=True), EpilogueOperands(residual=res))
    _run(a, b, Epilogue(softcap=30.0))


def test_batched_inputs(rng):
    a = jax.random.normal(rng, (3, 32, 128), jnp.bfloat16)
    b = jax.random.normal(jax.random.PRNGKey(1), (128, 128), jnp.bfloat16)
    out = _run(a, b)
    assert out.shape == (3, 32, 128)


def test_supports_contract():
    assert supports((64, 128), (128, 256), Epilogue())
    assert not supports((64, 100), (100, 256), Epilogue())
    assert supports((64, 128), (128, 2, 128), Epilogue(glu=True))


@given(m=st.integers(1, 150), k=st.integers(1, 3), n=st.integers(1, 3),
       seed=st.integers(0, 2**31))
@settings(max_examples=12, deadline=None)
def test_property_arbitrary_shapes(m, k, n, seed):
    """Tiling+padding is exact for any shape (fp32, zero-padded K)."""
    key = jax.random.PRNGKey(seed)
    ka, kb = jax.random.split(key)
    a = jax.random.normal(ka, (m, 64 * k), jnp.float32)
    b = jax.random.normal(kb, (64 * k, 64 * n), jnp.float32)
    out = fused_matmul(a, b, block_shape=(64, 64, 64))
    ref = a @ b
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=1e-4)

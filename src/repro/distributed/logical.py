"""Logical-axis sharding rules (MaxText-style) + activation constraints.

Models annotate activations with *logical* axes ("batch", "seq", "heads",
"mlp", "vocab", ...).  A ``ShardingRules`` context maps logical axes to
mesh axes; ``constrain`` applies ``with_sharding_constraint`` only when a
mesh is active **and** the dimension is divisible by the mapped mesh-axis
size (gemma2-2b's 8 heads on a 16-way model axis silently fall back to
GSPMD's choice — the divisibility-aware fallback of DESIGN.md §6).

Changing the rules dict is the primary lever of the §Perf hillclimb:
re-lower with a different mapping, re-read the roofline terms.
"""

from __future__ import annotations

import contextlib
import math
from typing import Optional, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisSpec = Union[str, "tuple[str, ...]", None]

#: default mapping; pod is folded into the data dimension of the batch.
#: "embed" -> "data" is FSDP/ZeRO-3: parameters (and optimizer moments)
#: shard their non-TP dimension over the data axis; GSPMD all-gathers
#: them per layer inside the scan and reduce-scatters gradients.
DEFAULT_RULES: "dict[str, AxisSpec]" = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_shard": "data",          # sequence parallelism (long-context)
    "heads": "model",
    "kv_heads": "model",
    "embed": "data",              # FSDP axis
    "mlp": "model",
    "mlp_expert": None,
    "vocab": "model",
    "experts": "model",
    "audio_ctx": None,
}

_ACTIVE: "list[tuple[Mesh, dict]]" = []


@contextlib.contextmanager
def use_rules(mesh: Optional[Mesh], rules: Optional[dict] = None):
    if mesh is None:
        yield
        return
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    merged = {k: v for k, v in merged.items() if v is not None}
    _ACTIVE.append((mesh, merged))
    try:
        yield
    finally:
        _ACTIVE.pop()


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE[-1][0] if _ACTIVE else None


def _axis_size(mesh: Mesh, ax: AxisSpec) -> int:
    if ax is None:
        return 1
    if isinstance(ax, str):
        ax = (ax,)
    return math.prod(mesh.shape[a] for a in ax)


def spec_for(shape, logical_axes) -> Optional[P]:
    """PartitionSpec for ``shape`` under the active rules (None = inactive)."""
    if not _ACTIVE:
        return None
    mesh, rules = _ACTIVE[-1]
    used: set = set()
    parts = []
    for dim, lax_name in zip(shape, logical_axes):
        ax = rules.get(lax_name) if lax_name else None
        if ax is not None:
            names = (ax,) if isinstance(ax, str) else tuple(ax)
            # Keep only axes present in this mesh (e.g. "pod" is absent on
            # the single-pod mesh) and not already used by another dim.
            names = tuple(n for n in names
                          if n in mesh.shape and n not in used)
            if names and dim % _axis_size(mesh, names) == 0:
                used.update(names)
                parts.append(names if len(names) > 1 else names[0])
                continue
        parts.append(None)
    return P(*parts)


def constrain(x: jax.Array, logical_axes) -> jax.Array:
    spec = spec_for(x.shape, logical_axes)
    if spec is None:
        return x
    mesh, _ = _ACTIVE[-1]
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def sharding_for(shape, logical_axes) -> Optional[NamedSharding]:
    spec = spec_for(shape, logical_axes)
    if spec is None:
        return None
    return NamedSharding(_ACTIVE[-1][0], spec)

"""W8A8 inference path — the paper's int8 pipeline as a composable layer.

The paper evaluates all three models in 8-bit with SmoothQuant-O1
(§5.1).  This module is that pipeline on top of ``cute_matmul``:

    weights:      offline per-output-channel absmax int8 (+ fp32 scale),
                  optionally SmoothQuant-migrated by per-in-channel s;
    activations:  dynamic per-row absmax int8 (the vector-unit prologue
                  of Fig. 5 — ``kernels/quant`` on the Pallas path);
    matmul:       int8×int8→int32 on the matrix unit;
    epilogue:     dequant scales + bias + activation fused (Table 1's
                  BiasType + the ``scale_a``/``scale_b`` operands).

``W8A8Linear.from_float`` is the offline step; ``__call__`` is the whole
fused online step — one ``cute_matmul``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.fusion import Epilogue, EpilogueOperands, cute_matmul
from repro.core.task import BiasType
from repro.kernels.quant.ref import (quantize_colwise_ref,
                                     quantize_rowwise_ref,
                                     smoothquant_migrate)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class W8A8Linear:
    """Quantized linear layer: y = act(deq(q(x/s) @ Wq) + b)."""

    w_q: jax.Array                    # (K, N) int8
    w_scale: jax.Array                # (N,) fp32
    smooth: Optional[jax.Array]       # (K,) fp32 per-in-channel divisor
    bias: Optional[jax.Array]         # (N,) fp32

    @classmethod
    def from_float(cls, w, bias=None, act_absmax=None, alpha: float = 0.5):
        """Offline quantization; pass calibration ``act_absmax`` (K,) to
        enable SmoothQuant migration (O1)."""
        smooth = None
        w = w.astype(jnp.float32)
        if act_absmax is not None:
            smooth = smoothquant_migrate(act_absmax, jnp.abs(w).max(1),
                                         alpha)
            w = w * smooth[:, None]
        q, scale = quantize_colwise_ref(w)
        return cls(w_q=q, w_scale=scale, smooth=smooth, bias=bias)

    def __call__(self, x, *, activation: str = "none",
                 out_dtype=jnp.bfloat16, backend: Optional[str] = None):
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        if self.smooth is not None:
            x2 = x2 / self.smooth
        x_q, x_scale = quantize_rowwise_ref(x2)
        ep = Epilogue(
            bias_type=BiasType.ROW if self.bias is not None else
            BiasType.ZERO,
            activation=activation, has_scale_a=True, has_scale_b=True,
            out_dtype=out_dtype)
        y = cute_matmul(x_q, self.w_q, epilogue=ep,
                        operands=EpilogueOperands(
                            bias=self.bias, scale_a=x_scale,
                            scale_b=self.w_scale),
                        backend=backend)
        return y.reshape(*lead, y.shape[-1])


def quantize_mlp(wi, wo, x_calib):
    """Quantize a SwiGLU MLP pair with activation calibration."""
    lin_in = W8A8Linear.from_float(
        wi, act_absmax=jnp.abs(x_calib.reshape(-1, x_calib.shape[-1])
                               ).max(0))
    # Hidden-activation calibration from the calibration batch itself.
    h = jax.nn.silu(x_calib @ wi[:, : wi.shape[1] // 2]) * (
        x_calib @ wi[:, wi.shape[1] // 2:])
    lin_out = W8A8Linear.from_float(
        wo, act_absmax=jnp.abs(h.reshape(-1, h.shape[-1])).max(0))
    return lin_in, lin_out

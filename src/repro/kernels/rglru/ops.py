"""jit'd wrapper for the RG-LRU kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.rglru.rglru import rglru_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "block_c", "interpret"))
def rglru_scan(log_a, x, *, chunk: int = 64, block_c: int = 512,
               interpret: bool = True):
    """log_a, x: (B, T, C) -> h sequence (B, T, C), zero initial state."""
    b, t, c = x.shape
    pad_t = (-t) % chunk
    if pad_t:
        widths = ((0, 0), (0, pad_t), (0, 0))
        log_a = jnp.pad(log_a, widths)
        x = jnp.pad(x, widths)
    bc = min(block_c, c)
    pad_c = (-c) % bc
    if pad_c:
        widths = ((0, 0), (0, 0), (0, pad_c))
        log_a = jnp.pad(log_a, widths)
        x = jnp.pad(x, widths)
    tp, cp = t + pad_t, c + pad_c
    grid = (b, cp // bc, tp // chunk)     # time innermost (sequential)

    kernel = functools.partial(rglru_kernel, chunk=chunk)
    try:
        compiler_params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    except (AttributeError, TypeError):
        compiler_params = None

    o = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bc), lambda bi, ci, ti: (bi, ti, ci)),
            pl.BlockSpec((1, chunk, bc), lambda bi, ci, ti: (bi, ti, ci)),
        ],
        out_specs=pl.BlockSpec((1, chunk, bc), lambda bi, ci, ti: (bi, ti, ci)),
        out_shape=jax.ShapeDtypeStruct((b, tp, cp), x.dtype),
        scratch_shapes=[pltpu.VMEM((1, bc), jnp.float32)],
        compiler_params=compiler_params,
        interpret=interpret,
    )(log_a, x)
    return o[:, :t, :c]

import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede any jax-importing import: jax locks the device count at
# first backend init.  Everything else in the framework sees 1 device.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds abstract (ShapeDtypeStruct) parameters,
optimizer state, batch and caches with their production shardings,
lowers the appropriate step function (train_step / prefill / decode) on
the 16×16 single-pod and 2×16×16 multi-pod meshes, compiles it, and
records ``memory_analysis()``, ``cost_analysis()`` and per-collective
byte counts into ``benchmarks/results/dryrun/<mesh>/<arch>__<shape>.json``
— the §Roofline tables read these files.

Usage:
    python -m repro.launch.dryrun --arch deepseek-67b --shape train_4k
    python -m repro.launch.dryrun --all [--jobs 4] [--force]
"""

import argparse
import json
import subprocess
import sys
import time
import traceback

import jax

from repro.configs.registry import (ALL_ARCHS, SHAPES, all_cells,
                                    cell_applicable, get_config, input_specs)
from repro.core import hlo_cost
from repro.core import roofline as rl
from repro.distributed import logical, sharding
from repro.launch.mesh import make_production_mesh
from repro.models.base import family_module
from repro.training.train_step import TrainConfig, abstract_state, \
    make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")

#: per-cell runtime overrides discovered during §Perf iterations; the
#: baseline run uses an empty dict (see benchmarks/roofline.py for both).
PERF_OVERRIDES: dict = {}


def _result_path(mesh_name: str, arch: str, shape: str, tag: str = "") -> str:
    d = os.path.join(os.path.abspath(RESULTS_DIR), mesh_name + tag)
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{arch}__{shape}.json")


def default_train_config(cfg, spec, mesh) -> TrainConfig:
    """Pick microbatches so the layer-scan carry stays ≲ 4 GiB/device.

    The scan-over-layers checkpoint saves one residual-stream tensor per
    layer: B_local × S × d_model × 2 bytes × n_layers.  Gradient
    accumulation divides B_local.
    """
    data = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    b_local = max(spec.global_batch // data, 1)
    carry = b_local * spec.seq_len * cfg.d_model * 2 * cfg.n_layers
    target = 4 * (1 << 30)
    mb = 1
    while mb < b_local and carry / mb > target:
        mb *= 2
    return TrainConfig(microbatches=mb)


def build_cell(cfg, shape_name: str, mesh, rules=None,
               tcfg: TrainConfig = None):
    """Returns (fn, sharded abstract args) for one cell."""
    spec = SHAPES[shape_name]
    mod = family_module(cfg)
    tcfg = tcfg or default_train_config(cfg, spec, mesh)
    batch = input_specs(cfg, spec)
    batch = sharding.apply_shardings(
        batch, sharding.batch_shardings(batch, mesh, rules))
    params, opt_state = abstract_state(cfg, tcfg)
    pshard = sharding.param_shardings(params, mesh, rules)
    params = sharding.apply_shardings(params, pshard)

    if spec.mode == "train":
        opt_shard = {
            "step": sharding.param_shardings(opt_state["step"], mesh, rules),
            "mu": sharding.param_shardings(opt_state["mu"], mesh, rules),
            "nu": sharding.param_shardings(opt_state["nu"], mesh, rules),
            "master": sharding.param_shardings(opt_state["master"], mesh,
                                               rules),
        }
        opt_state = sharding.apply_shardings(opt_state, opt_shard)
        step = make_train_step(cfg, tcfg)
        if tcfg.grad_compression:
            residual = jax.eval_shape(
                lambda p: jax.tree.map(
                    lambda x: jax.numpy.zeros(x.shape, jax.numpy.float32),
                    p), params)
            residual = sharding.apply_shardings(
                residual, sharding.param_shardings(residual, mesh, rules))
            fn = lambda p, o, b, r: step(p, o, b, r)[:3]
            return fn, (params, opt_state, batch, residual)
        fn = lambda p, o, b: step(p, o, b)[:3]
        return fn, (params, opt_state, batch)

    if spec.mode == "prefill":
        cache = jax.eval_shape(
            lambda: mod.init_cache(cfg, spec.global_batch, spec.seq_len))
        cache = sharding.apply_shardings(
            cache, sharding.cache_shardings(cache, mesh, cfg, rules))
        fn = lambda p, b, c: mod.prefill(cfg, p, b, c)
        return fn, (params, batch, cache)

    # decode
    cache = jax.eval_shape(
        lambda: mod.init_cache(cfg, spec.global_batch, spec.seq_len))
    cache = sharding.apply_shardings(
        cache, sharding.cache_shardings(cache, mesh, cfg, rules))
    pos = jax.ShapeDtypeStruct((), jax.numpy.int32)
    fn = lambda p, t, c, pp: mod.decode_step(cfg, p, t, c, pp)
    return fn, (params, batch["tokens"], cache, pos)


def model_flops(cfg, shape_name: str) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); D per mode."""
    spec = SHAPES[shape_name]
    n = cfg.param_count(active_only=cfg.moe is not None)
    d_tokens = spec.global_batch * (1 if spec.mode == "decode"
                                    else spec.seq_len)
    mult = 6.0 if spec.mode == "train" else 2.0
    return mult * n * d_tokens


def run_cell(arch: str, shape: str, mesh_name: str, force: bool = False,
             rules=None, overrides=None, tag: str = "",
             tcfg: TrainConfig = None) -> dict:
    out_path = _result_path(mesh_name, arch, shape, tag)
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    cfg = get_config(arch, **(overrides or {}))
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = mesh.size
    t0 = time.time()
    with logical.use_rules(mesh, rules):
        fn, args = build_cell(cfg, shape, mesh, rules, tcfg)
        lowered = jax.jit(fn).lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    cost = hlo_cost.analyze(hlo)     # trip-count-aware (DESIGN.md §3)
    mf = model_flops(cfg, shape)
    roof = rl.Roofline(
        flops_per_chip=cost.flops,
        bytes_per_chip=cost.bytes,
        coll_bytes_per_chip=cost.collective_bytes,
        chips=chips,
        model_flops_per_chip=mf / chips,
    )
    result = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "chips": chips,
        "mode": SHAPES[shape].mode,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "cost_analysis": {k: float(v) for k, v in ca.items()
                          if isinstance(v, (int, float))},
        "memory": {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
            "code_bytes": getattr(ma, "generated_code_size_in_bytes", None),
        },
        "collective_bytes": dict(cost.per_collective,
                                 total=cost.collective_bytes),
        "unparsed_loops": cost.unparsed_loops,
        "model_flops_total": mf,
        "roofline": roof.as_dict(),
        "hlo_bytes": len(hlo),
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def _run_all(args):
    cells = []
    for arch, shape in all_cells():
        for mesh_name in args.meshes:
            cells.append((arch, shape, mesh_name))
    print(f"dry-run: {len(cells)} cells", flush=True)
    procs, failures, done = [], [], 0
    for arch, shape, mesh_name in cells:
        if os.path.exists(_result_path(mesh_name, arch, shape)) \
                and not args.force:
            done += 1
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", mesh_name]
        if args.force:
            cmd.append("--force")
        procs.append(((arch, shape, mesh_name),
                      subprocess.Popen(cmd)))
        while len(procs) >= args.jobs:
            procs, f, d = _reap(procs)
            failures += f
            done += d
            time.sleep(0.5)
    while procs:
        procs, f, d = _reap(procs)
        failures += f
        done += d
        time.sleep(0.5)
    print(f"dry-run complete: {done} ok, {len(failures)} failed")
    for cell in failures:
        print("  FAILED:", cell)
    return 1 if failures else 0


def _reap(procs):
    live, failures, done = [], [], 0
    for cell, p in procs:
        rc = p.poll()
        if rc is None:
            live.append((cell, p))
        elif rc == 0:
            done += 1
            print("  ok:", cell, flush=True)
        else:
            failures.append(cell)
            print("  FAIL:", cell, flush=True)
    return live, failures, done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ALL_ARCHS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--meshes", nargs="+", default=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--jobs", type=int, default=2)
    args = ap.parse_args()

    if args.all:
        sys.exit(_run_all(args))

    if not (args.arch and args.shape):
        ap.error("--arch/--shape required unless --all")
    if not cell_applicable(args.arch, args.shape):
        print(f"SKIP (inapplicable): {args.arch} x {args.shape}")
        return
    try:
        r = run_cell(args.arch, args.shape, args.mesh, force=args.force)
    except Exception:
        traceback.print_exc()
        sys.exit(1)
    roof = r["roofline"]
    print(f"{args.arch} x {args.shape} x {args.mesh}: "
          f"compile={r['compile_s']}s "
          f"compute={roof['compute_s']:.2e}s memory={roof['memory_s']:.2e}s "
          f"collective={roof['collective_s']:.2e}s "
          f"dominant={roof['dominant']} "
          f"roofline_frac={roof['roofline_fraction']:.3f} "
          f"temp={r['memory']['temp_bytes']}")


if __name__ == "__main__":
    main()

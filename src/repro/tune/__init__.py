"""Model-guided kernel autotuning and the per-platform tuning cache.

``space`` declares the search space (:class:`TunedConfig`, shape
buckets), ``autotune`` runs the propose/dispose loop (analytical model
ranks, DES elects), ``cache`` persists winners per platform, and
``regime`` pins the canonical Llama-style decode-regime measurement.
The runtime consumer is ``repro.backend.registry.get_tuned`` — dispatch
precedence is explicit argument > tuned cache > untuned default.
"""

from repro.tune.cache import (SCHEMA_VERSION, cache_path, clear_memo,
                              dump_cache, load_cache, lookup, save_cache)
from repro.tune.space import (DEFAULT_CONFIG, TunedConfig, bucket_of_task,
                              gemm_candidates, schedule_bucket,
                              schedule_candidates, shape_bucket)

__all__ = [
    "SCHEMA_VERSION", "cache_path", "clear_memo", "dump_cache",
    "load_cache", "lookup", "save_cache",
    "DEFAULT_CONFIG", "TunedConfig", "bucket_of_task", "gemm_candidates",
    "schedule_bucket", "schedule_candidates", "shape_bucket",
]

"""The autotuner's search space: kernel variants and shape buckets.

One candidate is a :class:`TunedConfig` — the software-visible knobs the
paper's configurable matrix unit leaves to the stack: the scratchpad
tile the GEMM is cut into (``tile_m``/``tile_n``, at most the platform's
``m_scp``/``n_scp``), the epilogue granularity (``tile | panel |
layer``), K-chunked scratchpad streaming (``k_stream``), epilogue fusion
on/off, and — for whole serving schedules — the step-overlap lowering
mode (``chained | relaxed``).  ``TunedConfig()`` with no arguments *is*
the untuned default every backend constructs with, so "tuned beats
default" is a comparison inside one space.

Winners are cached per (platform × shape bucket): :func:`shape_bucket`
classifies a GEMM by its row count (decode steps feed skinny M, prefill
feeds deep M — the regimes the paper's Fig. 6/Table 6 separate), and
:func:`schedule_bucket` classifies a serving ``BatchSchedule`` by its
repeat-weighted decode share plus the cluster width it targets.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

#: decode steps enter the projection GEMMs with one row per in-flight
#: sequence; anything at or under this M is priced as the decode regime.
DECODE_MAX_M = 32


@dataclasses.dataclass(frozen=True)
class TunedConfig:
    """One kernel variant — the no-argument instance is the untuned
    default (scratchpad-sized tiles, tile granularity, fused epilogues,
    K-streaming on, caller-chosen overlap)."""

    tile_m: Optional[int] = None        # None: the unit's full m_scp
    tile_n: Optional[int] = None        # None: the unit's full n_scp
    granularity: str = "tile"
    fused: bool = True
    k_stream: bool = True
    overlap: Optional[str] = None       # schedules only; None: caller's
    #: executing ``cute_matmul`` route ("xla" | "pallas" | "auto") this
    #: variant pins for the shape class; None: the zoo-wide default.
    #: Not searched by the autotuner (wall-clock under interpret mode is
    #: not the machine being modelled) — hand-pinnable in a cache file.
    route: Optional[str] = None

    def backend_kwargs(self, unit, platform=None) -> dict:
        """Backend-constructor kwargs this variant implies.  ``unit`` is
        the platform's matrix-unit geometry; a sub-scratchpad tile is
        applied as a ``with_()`` override, so every backend (and both
        graph lowerings) inherits it through the one ``unit`` field.
        ``k_stream`` only reaches backends that accept it — the registry
        dispatch layer drops it for single-unit engines."""
        u = unit
        if self.tile_m is not None or self.tile_n is not None:
            u = unit.with_(m_scp=self.tile_m or unit.m_scp,
                           n_scp=self.tile_n or unit.n_scp)
        kw = dict(unit=u, granularity=self.granularity, fused=self.fused,
                  k_stream=self.k_stream)
        if platform is not None:
            kw["platform"] = platform
        return kw

    def to_dict(self) -> dict:
        """JSON form — only non-default fields, so cache files stay
        small and the default round-trips to ``{}``."""
        out = {}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if v != f.default:
                out[f.name] = v
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "TunedConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        bad = set(d) - known
        if bad:
            raise ValueError(f"unknown TunedConfig fields {sorted(bad)}; "
                             f"known: {sorted(known)}")
        return cls(**d)


#: the untuned default — what every dispatch falls back to.
DEFAULT_CONFIG = TunedConfig()


def shape_bucket(m: int, n: int, k: int) -> str:
    """Classify one GEMM shape: ``"decode"`` for skinny-M projection
    GEMMs (one row per in-flight sequence), ``"prefill"`` for everything
    with real row parallelism.  ``n``/``k`` are accepted for forward
    compatibility; today M alone separates the serving regimes."""
    del n, k
    return "decode" if m <= DECODE_MAX_M else "prefill"


def bucket_of_task(task) -> str:
    """:func:`shape_bucket` of a ``MatMulTask``, keyed for the cache."""
    return f"gemm|{shape_bucket(task.m, task.n, task.k)}"


def schedule_bucket(sched) -> str:
    """Cache key of a serving ``BatchSchedule``: cluster width plus
    whether the drain is decode- or prefill-dominated by repeat-weighted
    step count (decode steps repeat ``n_layers × iterations``, so a
    modest ``max_new_tokens`` already tips a queue decode-heavy).

    Schedules carrying KV refill traffic (``refill_bytes`` stamped by a
    residency-aware plan) get a ``|kv`` suffix: a loader already paying
    refill bytes favours different tile/overlap trade-offs than the
    all-resident regime, so tuned entries must not leak across."""
    decode = sum(s.repeat for s in sched.steps
                 if s.kind == "decode" or s.decode_requests)
    prefill = sum(s.repeat for s in sched.steps
                  if not (s.kind == "decode" or s.decode_requests))
    regime = "decode" if decode >= prefill else "prefill"
    kv = "|kv" if any(getattr(sched, "refill_bytes", ()) or ()) else ""
    return f"sched|u{sched.units}|{regime}{kv}"


def _tile_choices(unit) -> "list[tuple[Optional[int], Optional[int]]]":
    """(tile_m, tile_n) candidates: the full scratchpad tile plus the
    half-size cuts in each dimension (smaller tiles trade loader burst
    length against dispatch-stream pressure — the CSR-vs-RoCC axis)."""
    out = [(None, None)]
    half_m = unit.m_scp // 2
    half_n = unit.n_scp // 2
    if half_m >= unit.m_pe:
        out.append((half_m, None))
    if half_n >= unit.n_pe:
        out.append((None, half_n))
    if half_m >= unit.m_pe and half_n >= unit.n_pe:
        out.append((half_m, half_n))
    return out


def gemm_candidates(unit) -> "list[TunedConfig]":
    """The GEMM-bucket search space, deterministically ordered with the
    untuned default first (rank ties resolve toward the default)."""
    out = [DEFAULT_CONFIG]
    for tile_m, tile_n in _tile_choices(unit):
        for gran in ("tile", "panel", "layer"):
            for fused in (True, False):
                for k_stream in (True, False):
                    cfg = TunedConfig(tile_m=tile_m, tile_n=tile_n,
                                      granularity=gran, fused=fused,
                                      k_stream=k_stream)
                    if cfg != DEFAULT_CONFIG:
                        out.append(cfg)
    return out


def schedule_candidates(unit) -> "list[TunedConfig]":
    """The schedule-bucket space: the GEMM knobs that matter at schedule
    scale (granularity × fusion × K-streaming) crossed with the overlap
    lowering mode.  Tile cuts are left to the GEMM buckets — a serving
    step's skinny GEMMs rarely fill even one scratchpad tile."""
    del unit
    out = [DEFAULT_CONFIG]
    for overlap in (None, "relaxed"):
        for gran in ("tile", "panel", "layer"):
            for fused in (True, False):
                for k_stream in (True, False):
                    cfg = TunedConfig(granularity=gran, fused=fused,
                                      k_stream=k_stream, overlap=overlap)
                    if cfg != DEFAULT_CONFIG:
                        out.append(cfg)
    return out

"""internvl2-1b [vlm]: 24L d=896 14H (GQA kv=2) d_ff=4864 vocab=151655.

InternViT frontend + Qwen2-0.5B language backbone.  The ViT is a STUB per
the assignment: ``input_specs()`` supplies 256 precomputed patch-token
embeddings that occupy the first positions (models/transformer.py
``vision_prefix``).  Qwen2 quirks: QKV bias (the paper's
``BiasType=RowRepeat`` epilogue in real use).  Vocab padded 151655→151808
for TP sharding.  [arXiv:2404.16821; hf]
"""

from repro.models.base import ArchConfig

N_IMAGE_TOKENS = 256

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="transformer",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    rope_theta=1e6,
    qkv_bias=True,
    mlp_activation="silu",
    mlp_glu=True,
    vision_prefix=N_IMAGE_TOKENS,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                        head_dim=16, d_ff=128, vocab_size=512,
                        vision_prefix=8, attn_chunk=32)

"""Configurable matrix-unit parameters (paper Table 2) and Eq. 1.

``MatrixUnitConfig`` is the generator record of the paper: a PE array
``M_pe × N_pe`` where each PE reduces ``K_pe`` bits per cycle, a
scratchpad bounded by ``(M_scp, N_scp, K_scp)``, and the bandwidth the
surrounding SoC can feed it.  ``throughput()`` is Eq. 1 verbatim.

Presets cover the paper's case study (Table 2, Intel-AMX-comparable),
the scaling sweep of Table 4 (2×2 … 16×16 PE arrays, 256/512-bit reduce,
8–64 GB/s), and the 0.5–32 TOPS envelope claimed in §1.
"""

from __future__ import annotations

import dataclasses

from repro.core.hardware import GIGA, TERA
from repro.core.precision import DataType, policy


@dataclasses.dataclass(frozen=True)
class MatrixUnitConfig:
    """Paper Table 2 — configurable architectural parameters."""

    freq_hz: float = 2.0 * GIGA
    m_pe: int = 4                 # rows of PE array
    n_pe: int = 4                 # cols of PE array
    k_pe_bits: int = 512          # per-PE reduce width (bits/cycle)
    m_scp: int = 64               # max resident M in scratchpad
    n_scp: int = 64               # max resident N in scratchpad
    k_scp_bytes: int = 64         # max resident K in scratchpad (bytes)
    bandwidth: float = 48 * GIGA  # data-supply bandwidth (bytes/s)
    scratchpad_banks: int = 2     # double buffering (paper §4.1)
    accum_bytes: int = 4          # resident C is fp32/int32
    pe_pipeline_stages: int = 6   # paper §4.1: six-stage PE pipeline

    # ----- Eq. 1 ----------------------------------------------------------
    def k_pe_elems(self, data_type: DataType) -> int:
        """Elements reduced per PE per cycle for an n-bit format."""
        bits = policy(data_type).bits
        return self.k_pe_bits // bits

    def macs_per_cycle(self, data_type: DataType) -> int:
        return self.m_pe * self.n_pe * self.k_pe_elems(data_type)

    def throughput(self, data_type: DataType = DataType.INT8) -> float:
        """Eq. 1: ``Freq × M_pe × N_pe × (K_pe/n) × 2`` ops/s."""
        return self.freq_hz * self.macs_per_cycle(data_type) * 2

    # ----- scratchpad -----------------------------------------------------
    def scratchpad_bytes(self) -> int:
        """Total SRAM the configuration implies (A+B double-buffered, C resident)."""
        a = self.m_scp * self.k_scp_bytes
        b = self.n_scp * self.k_scp_bytes
        c = self.m_scp * self.n_scp * self.accum_bytes
        return self.scratchpad_banks * (a + b) + c

    def bytes_per_cycle(self) -> float:
        return self.bandwidth / self.freq_hz

    def with_(self, **kw) -> "MatrixUnitConfig":
        return dataclasses.replace(self, **kw)

    def describe(self) -> str:
        tops = self.throughput(DataType.INT8) / TERA
        return (f"{self.m_pe}x{self.n_pe} PE, K_pe={self.k_pe_bits}b, "
                f"scp=({self.m_scp},{self.n_scp},{self.k_scp_bytes}B), "
                f"{self.bandwidth / GIGA:.0f} GB/s -> {tops:.2f} TOPS(int8)")


# ---------------------------------------------------------------------------
# Presets.
# ---------------------------------------------------------------------------

#: Paper Table 2 case study — compute/bandwidth comparable to Xeon 8580 AMX.
CASE_STUDY = MatrixUnitConfig()
assert abs(CASE_STUDY.throughput(DataType.INT8) - 4.096 * TERA) < 1e9

#: §5.2 — the four integration platforms all run a 2 TOPS unit.
PLATFORM_2TOPS = MatrixUnitConfig(k_pe_bits=256, m_scp=64, n_scp=64,
                                  bandwidth=48 * GIGA)
assert abs(PLATFORM_2TOPS.throughput(DataType.INT8) - 2.048 * TERA) < 1e9


def scaled_config(m_pe: int, n_pe: int, k_pe_bits: int,
                  bandwidth: float) -> MatrixUnitConfig:
    """Build a Table-4 style configuration; scratchpad sized by Eq. 2.

    Import is deferred to avoid a cycle: constraint.py needs the config
    class defined above.
    """
    from repro.core.constraint import solve_scratchpad

    base = MatrixUnitConfig(m_pe=m_pe, n_pe=n_pe, k_pe_bits=k_pe_bits,
                            bandwidth=bandwidth)
    m_scp, n_scp = solve_scratchpad(base, DataType.INT8)
    return base.with_(m_scp=m_scp, n_scp=n_scp)


#: §1 claims a 0.5–32 TOPS envelope; Table 4 gives the PE sweep.
def scaling_sweep() -> "list[MatrixUnitConfig]":
    sweep = []
    for (m, n), kbits, bw in [
        ((2, 2), 256, 8 * GIGA),     # 0.512 TOPS embedded
        ((4, 4), 256, 16 * GIGA),    # 2.048 TOPS
        ((4, 4), 512, 48 * GIGA),    # 4.096 TOPS (case study class)
        ((8, 8), 512, 64 * GIGA),    # 16.4 TOPS
        ((16, 16), 512, 64 * GIGA),  # 65.5 TOPS upper stress point
    ]:
        sweep.append(scaled_config(m, n, kbits, bw))
    return sweep

"""jnp oracle for quantization + SmoothQuant scale migration."""

from __future__ import annotations

import jax.numpy as jnp


def quantize_rowwise_ref(x):
    """x: (..., M, K) -> (q int8, scale f32 (..., M))."""
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.where(absmax == 0.0, 1.0, absmax / 127.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale[..., 0]


def quantize_colwise_ref(w):
    """Static per-output-channel weight quant: w (K, N) -> (q, scale (N,))."""
    wf = w.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(wf), axis=0, keepdims=True)
    scale = jnp.where(absmax == 0.0, 1.0, absmax / 127.0)
    q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
    return q, scale[0]


def smoothquant_migrate(x_absmax, w_absmax, alpha: float = 0.5):
    """SmoothQuant §4: s_j = max|X_j|^α / max|W_j|^(1-α) (per in-channel).

    Activations are divided by ``s``, weights multiplied — difficulty
    migrates from activations to weights.  O1 applies this offline.
    """
    s = jnp.power(jnp.maximum(x_absmax, 1e-5), alpha) / jnp.power(
        jnp.maximum(w_absmax, 1e-5), 1.0 - alpha)
    return jnp.maximum(s, 1e-5)

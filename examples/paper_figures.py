"""Reproduce the paper's headline numbers in one command.

    PYTHONPATH=src python examples/paper_figures.py

Prints the Fig.6 utilization curves, the Fig.7 scaling band, the Table 6
fused/unfused speedups with the overlap-contribution split (§1: 66.7 /
50.9 / 33.6 %), and Table 7 area/power — all from the cycle-approximate
simulator of the CUTEv2 matrix unit.
"""

import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import run as bench


def main():
    print("name,us_per_call,derived")
    bench.bench_eq1_throughput()
    bench.bench_fig6_platforms()
    bench.bench_fig7_scaling()
    bench.bench_fig8_gemm()
    bench.bench_table6_models()
    bench.bench_overlap_contribution()
    bench.bench_table7_area()


if __name__ == "__main__":
    main()

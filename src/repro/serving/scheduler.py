"""Pluggable serving batching policies — the policy half of ``plan``.

``ServingEngine.plan`` used to hard-code one batching policy (full
prefill, then lockstep decode).  The paper attributes a large share of
CUTEv2's end-to-end gain to *overlapped* matrix–vector execution exposed
by the asynchronous abstraction; at serving scale that overlap is a
scheduling decision — when a request's prefill chunks run relative to
the decode iterations already in flight.  This module makes that
decision pluggable:

* :class:`SchedulingPolicy` — the protocol: ``schedule(PolicyContext)``
  lowers the pending queue into a
  :class:`~repro.serving.engine.BatchSchedule`.
* a registry (``register_policy`` / ``get_policy``) with three built-in
  policies:

  ===================  ====================================================
  ``full-prefill``     today's behaviour, bit-identical schedules: per
                       padded batch, one whole-prompt prefill step then
                       all decode steps lockstep.  Best per-token cadence,
                       worst queueing — a later batch waits for every
                       earlier batch's complete drain.
  ``chunked-prefill``  Sarathi-style: the prompt is split into
                       ``chunk_tokens``-token chunks and in-flight decode
                       iterations *piggyback* on each chunk (one mixed
                       step), so prefill of batch *i+1* overlaps decode of
                       batch *i*.  Throughput-oriented; decode tokens
                       surface once per chunk.
  ``decode-priority``  decode steps preempt prefill chunks at layer
                       granularity: each scheduling round runs one merged
                       decode iteration of everything in flight *before*
                       the next prefill chunk, and the drain is a fair
                       round-robin across batches — decode first-token
                       latency is bounded by chunks-per-prefill rather
                       than whole earlier drains.  On a cluster it pins
                       decode steps to unit 0 via affinity hints (list
                       the fastest unit first in a heterogeneous
                       topology).
  ===================  ====================================================

Every policy lowers to the same ``BatchSchedule`` → ``workload_to_graph``
path, so any policy is priceable on ``desim`` / ``desim-cluster``
timelines, priced by the contention-aware ``analytical`` closed form
without running the DES, and executed bit-exactly on the ``jax``
backend.  Two scheduling axes ride along the schedule itself:
**arrival times** (``PolicyContext.arrival_times`` → per-step release
times → ``Node.release_time``, so TTFT reflects queueing under load
instead of the all-at-t=0 lower bound) and the **overlap mode**
(``chained`` serial vs ``relaxed`` true per-request hazards only — see
``BatchSchedule.step_deps`` / ``docs/serving.md``).
:func:`decode_latency_stats` turns per-step prices into the serving
metrics (TTFT p50/p99 from each request's own arrival, inter-token
latency, overlap-aware makespan) and :func:`select_schedule` auto-picks
the best (policy × partition × overlap) candidate —
``plan(policy="auto")``.
"""

from __future__ import annotations

import abc
import dataclasses
import math
from typing import Optional


# ---------------------------------------------------------------------------
# Context + registry.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PolicyContext:
    """Everything a batching policy may look at: the queue (per-request
    prompt lengths, in submission order), the engine's batching limit,
    the decode horizon, the cluster width the schedule targets, and the
    per-request arrival times.

    ``arrival_times`` (cycles, one per request, non-decreasing — the
    queue is the arrival order) is how load reaches the plan: a step's
    release time is the latest arrival among its requests, stamped onto
    the lowered graph as ``Node.release_time`` and used as the TTFT
    baseline by :func:`decode_latency_stats`.  Empty means the classic
    all-arrived-at-t=0 queue.

    ``prefill_progress`` / ``decode_done`` carry **partial state across
    re-plans** — the online loop's currency: per request, how many
    prompt tokens are already prefilled and how many decode iterations
    already emitted.  A request whose prefill completed in an earlier
    epoch re-enters the plan as *carryover* (:meth:`carryover`): it
    skips prefill and only its owed decode iterations are scheduled.
    Both default empty — all-zero progress, the classic one-shot plan,
    bit-identical to the pre-online behaviour.

    ``kv_residency`` / ``kv_refill_bytes`` thread the paged KV cache's
    state (:mod:`repro.serving.kvcache`) into the plan: per request, the
    hot fraction of its KV blocks and the loader bytes owed before it
    can decode again.  A policy may *prefer* hot requests
    (``decode-priority`` does); either way :meth:`SchedulingPolicy
    ._finish` stamps each request's owed refill onto the first step that
    touches it, so the lowering prices the refill as a real ``memory``
    node.  Both default empty — KV is free and always resident, the
    classic behaviour.
    """

    cfg: object                       # models.base.ArchConfig
    prompt_lengths: "tuple[int, ...]"
    max_batch: int
    max_new_tokens: int
    units: int = 1
    arrival_times: "tuple[float, ...]" = ()
    prefill_progress: "tuple[int, ...]" = ()
    decode_done: "tuple[int, ...]" = ()
    kv_residency: "tuple[float, ...]" = ()
    kv_refill_bytes: "tuple[float, ...]" = ()

    def __post_init__(self):
        for field in ("arrival_times", "prefill_progress", "decode_done",
                      "kv_residency", "kv_refill_bytes"):
            val = getattr(self, field)
            if val and len(val) != len(self.prompt_lengths):
                raise ValueError(
                    f"{len(val)} {field} for "
                    f"{len(self.prompt_lengths)} requests")
        if any(not 0.0 <= r <= 1.0 for r in self.kv_residency):
            raise ValueError(f"kv_residency outside [0, 1]: "
                             f"{self.kv_residency}")
        if any(b < 0.0 for b in self.kv_refill_bytes):
            raise ValueError(f"negative kv_refill_bytes: "
                             f"{self.kv_refill_bytes}")

    def arrival_of(self, request: int) -> float:
        """Arrival cycle of a request (0.0 when arrivals untracked)."""
        return (self.arrival_times[request]
                if request < len(self.arrival_times) else 0.0)

    def residency_of(self, request: int) -> float:
        """Hot-KV fraction of a request (1.0 when residency untracked —
        the classic everything-is-resident assumption)."""
        return (self.kv_residency[request]
                if request < len(self.kv_residency) else 1.0)

    def refill_of(self, request: int) -> float:
        """KV refill bytes a request owes before decoding (0.0 when
        residency untracked)."""
        return (self.kv_refill_bytes[request]
                if request < len(self.kv_refill_bytes) else 0.0)

    def remaining_prompt(self, request: int) -> int:
        """Prompt tokens of ``request`` still to prefill."""
        done = (self.prefill_progress[request]
                if request < len(self.prefill_progress) else 0)
        return max(0, self.prompt_lengths[request] - done)

    def decode_owed(self, request: int) -> int:
        """Decode iterations ``request`` is still owed."""
        done = (self.decode_done[request]
                if request < len(self.decode_done) else 0)
        return max(0, self.max_new_tokens - done)

    def carryover(self) -> "list[tuple[int, int]]":
        """``[(request id, decode iterations owed)]`` for requests whose
        prefill already completed in an earlier epoch but still owe
        decode — the preempted/resumed decode streams every policy must
        reschedule *before* (or interleaved with) fresh prefill work."""
        return [(r, self.decode_owed(r))
                for r in range(len(self.prompt_lengths))
                if self.remaining_prompt(r) == 0 and self.decode_owed(r) > 0]

    @property
    def n_layers(self) -> int:
        return self.cfg.n_layers

    def batches(self) -> "list[tuple[tuple[int, ...], int]]":
        """Padded batch chunks in queue order: ``[(request ids, S_padded)]``
        — the same chunking every policy (and the pre-refactor ``plan``)
        uses, so policies differ only in *when* steps run.  Requests
        with no prompt tokens left (online carryover) are excluded;
        partially-prefilled requests are padded to their *remaining*
        length — the work a re-plan actually schedules."""
        out = []
        todo = [(r, self.remaining_prompt(r))
                for r in range(len(self.prompt_lengths))
                if self.remaining_prompt(r) > 0]
        while todo:
            chunk, todo = todo[: self.max_batch], todo[self.max_batch:]
            out.append((tuple(r for r, _ in chunk),
                        max(s for _, s in chunk)))
        return out


POLICIES: "dict[str, type]" = {}


def register_policy(cls):
    """Class decorator: add a :class:`SchedulingPolicy` to the registry
    under its ``name``."""
    name = cls.name
    prev = POLICIES.get(name)
    if prev is not None and prev is not cls:
        raise ValueError(f"policy {name!r} already registered by "
                         f"{prev.__name__}")
    POLICIES[name] = cls
    return cls


def available_policies() -> "tuple[str, ...]":
    return tuple(POLICIES)


def get_policy(name: str, **kw) -> "SchedulingPolicy":
    try:
        cls = POLICIES[name]
    except KeyError:
        raise KeyError(f"unknown scheduling policy {name!r}; one of "
                       f"{sorted(POLICIES)} (or 'auto')") from None
    return cls(**kw)


class SchedulingPolicy(abc.ABC):
    """One batching policy: queue in, :class:`BatchSchedule` out.

    Subclasses implement :meth:`schedule`; the shared helpers
    (``_emit`` / ``_finish``) keep every policy on the common
    ``BatchStep``/``LayerTrace`` lowering path and stamp the
    context's arrival times onto the schedule as per-step release
    times, so arrival semantics and overlap modes work for any
    registered policy without per-policy code.
    """

    name: str = "abstract"
    #: meta-policies (e.g. ``auto-slo``) wrap the candidate sweep rather
    #: than lowering a schedule shape of their own; the default sweep
    #: skips them so a sweep can never recurse into itself.
    meta: bool = False

    @abc.abstractmethod
    def schedule(self, ctx: PolicyContext):
        """Lower ``ctx`` into a :class:`~repro.serving.engine
        .BatchSchedule` (policy / affinity / arrival-derived release
        fields filled in)."""

    # ----- shared lowering helpers -----------------------------------------
    def _emit(self, steps, layers, ctx, kind, name, requests, tokens,
              repeat, decode_requests=()):
        from repro.serving.engine import BatchStep, _step_layer
        steps.append(BatchStep(kind, tuple(requests), tokens=tokens,
                               repeat=repeat,
                               decode_requests=tuple(decode_requests)))
        layers.append(_step_layer(ctx.cfg, name, tokens, repeat))

    def _finish(self, steps, layers, ctx, affinity=None):
        from repro.serving.engine import BatchSchedule
        release = ()
        if ctx.arrival_times:
            # a padded batch step cannot form before its last request
            # arrives; decode/mixed steps inherit the same bound (their
            # hazard deps dominate it in practice).
            release = tuple(
                max((ctx.arrival_of(r) for r in s.requests), default=0.0)
                for s in steps)
        refill = ()
        if any(ctx.kv_refill_bytes):
            # a request's owed KV refill is paid once, on the first step
            # that touches it — after that its blocks are hot for the
            # rest of the plan.  The lowering turns nonzero step refill
            # into a real ``memory`` node the DES/analytical forms price.
            owed = {r: ctx.refill_of(r)
                    for r in range(len(ctx.prompt_lengths))
                    if ctx.refill_of(r) > 0.0}
            per_step = []
            for s in steps:
                per_step.append(sum(owed.pop(r, 0.0) for r in s.requests))
            refill = tuple(per_step)
        return BatchSchedule(steps, layers, units=ctx.units,
                             policy=self.name,
                             affinity=dict(affinity or {}),
                             arrival_times=tuple(ctx.arrival_times),
                             release_times=release,
                             refill_bytes=refill)

    def _carryover_inflight(self, ctx: PolicyContext) -> "list[_InFlight]":
        """Online carryover as in-flight decode entries: requests whose
        prefill completed in an earlier epoch, grouped by owed decode
        count so the round-robin collapse stays merged.  Empty for the
        classic one-shot context."""
        by_owed: "dict[int, list[int]]" = {}
        for r, owed in ctx.carryover():
            by_owed.setdefault(owed, []).append(r)
        return [_InFlight(ci=-1, ids=tuple(ids), left=owed,
                          label=f"carry{owed}")
                for owed, ids in sorted(by_owed.items())]

    def _split_by_residency(self, ctx, inflight):
        """Partition in-flight decode entries into (hot, cold) by the
        context's KV residency: a request owing refill bytes is cold.
        Entries mixing both split into two, name-tagged ``.hot`` /
        ``.cold`` so the step labels stay unique."""
        hot, cold = [], []
        for d in inflight:
            h = tuple(i for i in d.ids if ctx.refill_of(i) <= 0.0)
            c = tuple(i for i in d.ids if ctx.refill_of(i) > 0.0)
            if h and not c:
                hot.append(d)
            elif c and not h:
                cold.append(d)
            else:
                if h:
                    hot.append(_InFlight(d.ci, h, d.left, d.tag + ".hot"))
                if c:
                    cold.append(_InFlight(d.ci, c, d.left, d.tag + ".cold"))
        return hot, cold

    def _drain_round_robin(self, steps, layers, ctx, inflight):
        """Fair round-robin drain of everything still owing decode
        iterations, collapsed into one merged step per distinct horizon
        (every in-flight batch advances one token per round)."""
        while inflight:
            m = min(d.left for d in inflight)
            ids = tuple(i for d in inflight for i in d.ids)
            tag = "+".join(d.tag for d in inflight)
            self._emit(steps, layers, ctx, "decode", f"{tag}/decode.rr",
                       ids, tokens=len(ids), repeat=ctx.n_layers * m,
                       decode_requests=ids)
            for d in inflight:
                d.left -= m
            inflight[:] = [d for d in inflight if d.left > 0]


# ---------------------------------------------------------------------------
# The three built-in policies.
# ---------------------------------------------------------------------------

@register_policy
class FullPrefillPolicy(SchedulingPolicy):
    """The pre-refactor ``ServingEngine.plan`` behaviour, verbatim: per
    padded batch one prefill step over ``B × S_padded`` tokens, then all
    ``max_new_tokens`` decode iterations collapsed into one lockstep
    step.  Schedules are bit-identical to the old inline policy (pinned
    by ``tests/test_scheduler.py``).  Online carryover (decode streams
    resumed from an earlier epoch) drains first, lockstep — finishing
    interrupted streams before new prefill is this policy's creed."""

    name = "full-prefill"

    def schedule(self, ctx: PolicyContext):
        steps, layers = [], []
        self._drain_round_robin(steps, layers, ctx,
                                self._carryover_inflight(ctx))
        for ci, (ids, s) in enumerate(ctx.batches()):
            b = len(ids)
            self._emit(steps, layers, ctx, "prefill", f"b{ci}/prefill",
                       ids, tokens=b * s, repeat=ctx.n_layers)
            self._emit(steps, layers, ctx, "decode", f"b{ci}/decode",
                       ids, tokens=b,
                       repeat=ctx.n_layers * ctx.max_new_tokens)
        return self._finish(steps, layers, ctx)


@dataclasses.dataclass
class _InFlight:
    ci: int
    ids: "tuple[int, ...]"
    left: int                        # decode iterations still owed
    label: str = ""                  # step-name tag ("": derive from ci)

    @property
    def tag(self) -> str:
        return self.label or f"b{self.ci}"


class _ChunkingPolicy(SchedulingPolicy):
    """Shared machinery for the chunk-interleaving policies."""

    def __init__(self, chunk_tokens: int = 256):
        if chunk_tokens < 1:
            raise ValueError(f"chunk_tokens must be >= 1, "
                             f"got {chunk_tokens}")
        self.chunk_tokens = chunk_tokens

    def _chunks(self, total: int) -> "list[int]":
        n = max(1, math.ceil(total / self.chunk_tokens))
        return [min(self.chunk_tokens, total - j * self.chunk_tokens)
                for j in range(n)]


@register_policy
class ChunkedPrefillPolicy(_ChunkingPolicy):
    """Chunked prefill with piggybacked decode (Sarathi-style): each
    scheduling step is one ``chunk_tokens`` slice of the current prompt
    *plus* one decode iteration for every request already decoding — one
    mixed batch through the model, so prefill of later batches overlaps
    decode of earlier ones without dedicated decode slots."""

    name = "chunked-prefill"

    def schedule(self, ctx: PolicyContext):
        steps, layers = [], []
        # online carryover decode streams piggyback from the first chunk
        inflight: "list[_InFlight]" = self._carryover_inflight(ctx)
        for ci, (ids, s) in enumerate(ctx.batches()):
            b = len(ids)
            for j, chunk in enumerate(self._chunks(b * s)):
                riders = [d for d in inflight if d.left > 0]
                rider_ids = tuple(i for d in riders for i in d.ids)
                kind = "mixed" if riders else "prefill"
                self._emit(
                    steps, layers, ctx, kind,
                    f"b{ci}/{kind}.c{j}", ids + rider_ids,
                    tokens=chunk + len(rider_ids), repeat=ctx.n_layers,
                    decode_requests=rider_ids)
                for d in riders:
                    d.left -= 1
                inflight = [d for d in inflight if d.left > 0]
            inflight.append(_InFlight(ci, ids, ctx.max_new_tokens))
        self._drain_round_robin(steps, layers, ctx, inflight)
        return self._finish(steps, layers, ctx)


@register_policy
class DecodePriorityPolicy(_ChunkingPolicy):
    """Decode-priority interleaving: every scheduling round runs one
    merged decode iteration of everything in flight *before* the next
    prefill chunk — decode work preempts prefill at layer granularity
    (a decode step's layers slot between the chunk's layers rather than
    behind the whole prompt), so a request starts decoding as soon as
    its own prefill lands instead of waiting out earlier batches'
    drains.  On a cluster the policy hints the latency-critical decode
    stream onto unit 0 for the ``unit-affinity`` partition strategy
    (list the fastest unit first in a heterogeneous topology); prefill
    GEMMs stay unhinted so the partitioner balances them over every
    unit.

    With KV residency threaded through the context
    (``ctx.kv_residency`` — see :mod:`repro.serving.kvcache`) and
    ``residency_aware`` on (the default), the carried-over decode
    streams are served **hot-first**: requests whose KV blocks are all
    resident drain before any cold stream's refill is waited out, so
    hot first-token latencies stop paying for other requests' evicted
    blocks.  The cold streams still pay their refill (stamped onto
    their first step and priced as a memory node) — the policy moves
    the refill out of the hot requests' critical path, it never hides
    it.  ``residency_aware=False`` is the residency-blind twin: same
    physics, one merged drain that makes everyone wait out the refill.
    """

    name = "decode-priority"

    def __init__(self, chunk_tokens: int = 256,
                 residency_aware: bool = True):
        super().__init__(chunk_tokens)
        self.residency_aware = residency_aware

    def schedule(self, ctx: PolicyContext):
        steps, layers = [], []
        affinity: "dict[str, int]" = {}
        # online carryover preempts the very first prefill chunk
        inflight: "list[_InFlight]" = self._carryover_inflight(ctx)
        if self.residency_aware and any(ctx.kv_refill_bytes):
            hot, cold = self._split_by_residency(ctx, inflight)
            if hot and cold:
                # hot streams drain to completion first; cold streams
                # re-enter the normal preemption flow behind them and
                # pay their refill there.
                self._drain_round_robin(steps, layers, ctx, hot)
                inflight = cold
        rr = 0

        def emit_decode(name, rid, repeat):
            self._emit(steps, layers, ctx, "decode", name, rid,
                       tokens=len(rid), repeat=repeat,
                       decode_requests=rid)
            # the hint covers decode steps *competing* with prefill
            # chunks; the tail drain (_drain_round_robin) has the
            # cluster to itself and is left to the partitioner's
            # balancer.
            if ctx.units > 1:
                affinity[name] = 0

        for ci, (ids, s) in enumerate(ctx.batches()):
            b = len(ids)
            for j, chunk in enumerate(self._chunks(b * s)):
                riders = [d for d in inflight if d.left > 0]
                if riders:
                    rid = tuple(i for d in riders for i in d.ids)
                    emit_decode(f"dp{rr}/decode", rid, ctx.n_layers)
                    rr += 1
                    for d in riders:
                        d.left -= 1
                    inflight = [d for d in inflight if d.left > 0]
                self._emit(steps, layers, ctx, "prefill",
                           f"b{ci}/prefill.c{j}", ids, tokens=chunk,
                           repeat=ctx.n_layers)
            inflight.append(_InFlight(ci, ids, ctx.max_new_tokens))
        self._drain_round_robin(steps, layers, ctx, inflight)
        return self._finish(steps, layers, ctx, affinity)


# ---------------------------------------------------------------------------
# Pricing: per-step costs -> serving latency metrics.
# ---------------------------------------------------------------------------

def backend_kwargs_for(sched, default_strategy: str = "output-tile",
                       **overrides) -> dict:
    """Backend-constructor kwargs a schedule implies: its cluster width,
    its auto-chosen partition strategy (or ``unit-affinity`` when the
    policy emitted placement hints, else ``default_strategy`` —
    serving GEMMs are short and wide, so ``output-tile`` shards the
    dimension that actually spreads work).  Explicit ``overrides``
    win."""
    kw = dict(overrides)
    if sched.units > 1:
        kw.setdefault("units", sched.units)
        strat = kw.setdefault("strategy", sched.strategy
                              or ("unit-affinity" if sched.affinity
                                  else default_strategy))
        if strat == "unit-affinity" and sched.affinity:
            kw.setdefault("affinity", dict(sched.affinity))
    return kw


#: memoised per-step prices: a serving sweep re-prices the same
#: (layer shape × backend config) hundreds of times — decode steps of
#: one schedule share a shape, and ``select_schedule`` prices every
#: (policy × strategy × overlap) candidate.  Keyed by the backend's
#: resolved constructor kwargs and the layer's full cost signature, so
#: a hit is exact by construction; hit/miss totals land in the obs
#: registry (``price_cache_{hits,misses}_total``) when it is enabled.
_PRICE_CACHE: "dict[tuple, dict]" = {}
_PRICE_CACHE_MAX = 4096


def _layer_price_key(lt, sched, backend_name: str, kw: dict,
                     release: float = 0.0, refill: float = 0.0) -> tuple:
    """Cache key of one step's price: everything its cost can depend on.
    ``LayerTrace``/``MatMulTask`` are dataclasses with content reprs;
    the step *name* only matters when the partition affinity hints it
    somewhere, so unhinted same-shape steps share an entry.

    The schedule's ``overlap`` mode and the step's ``release`` time are
    part of the key: today's per-step ``run_workload`` pricing is
    arrival- and overlap-independent, but the cache contract is "a hit
    is exact by construction" — the online loop re-prices the *same
    shapes* under shifted arrivals every admission epoch, and a backend
    that starts charging release gaps or cross-step contention into
    step costs must never alias a stale entry (pinned by
    ``tests/test_online.py``).  ``refill`` — the step's owed KV refill
    bytes — is part of the key for the same reason: a step's price
    includes its refill memory traffic, so the same shape under
    different residency must never alias."""
    hinted = lt.name if lt.name in (sched.affinity or {}) else None
    return (backend_name, repr(sorted(kw.items())), hinted,
            sched.overlap, release, refill,
            tuple(repr(g) for g in lt.gemms),
            tuple(sorted(lt.vector_ops.items())),
            lt.intermediate_bytes, lt.repeat)


def clear_price_cache() -> None:
    _PRICE_CACHE.clear()


def _price_workloads(sched, backend_name: str,
                     **backend_kwargs) -> "list[dict]":
    """Per-step ``run_workload`` dicts on a modelling backend (repeat
    included) — one pricing pass feeding both the latency timeline and
    the aggregate utilization.  Prices are memoised per (backend config
    × step cost signature); the modelling backends are deterministic,
    so a hit returns the identical dict."""
    from repro import backend
    from repro.obs import default_registry
    kw = backend_kwargs_for(sched, **backend_kwargs)
    eng = None
    reg = default_registry()
    out: "list[dict]" = []
    rel = list(sched.release_times) or [0.0] * len(sched.layers)
    refills = list(getattr(sched, "refill_bytes", ()) or ())
    refills += [0.0] * (len(sched.layers) - len(refills))
    for lt, release, refill in zip(sched.layers, rel, refills):
        key = _layer_price_key(lt, sched, backend_name, kw, release, refill)
        w = _PRICE_CACHE.get(key)
        if w is None:
            reg.counter("price_cache_misses_total",
                        backend=backend_name).inc()
            if eng is None:
                eng = backend.get(backend_name, **kw)
                if not eng.models_time:
                    raise ValueError(f"backend {backend_name!r} does not "
                                     "model time")
            w = eng.run_workload([lt])
            if refill > 0.0:
                # the step's KV refill rides the shared loader before
                # its tiles — the same memory-node price the lowered
                # graph carries, added serially here so per-step
                # pricing and the full-graph DES/analytical forms see
                # the same cost.
                from repro.serving.kvcache import refill_cycles
                extra = refill_cycles(refill, eng.unit, eng.platform,
                                      units=sched.units)
                w = dict(w, cycles=w["cycles"] + extra,
                         kv_refill_cycles=extra)
            if len(_PRICE_CACHE) >= _PRICE_CACHE_MAX:
                _PRICE_CACHE.clear()
            _PRICE_CACHE[key] = w
        else:
            reg.counter("price_cache_hits_total",
                        backend=backend_name).inc()
        out.append(dict(w))
    return out


def price_steps(sched, backend_name: str = "analytical",
                **backend_kwargs) -> "list[float]":
    """Cycles of each schedule step on a modelling backend (repeat
    included) — the timeline ``decode_latency_stats`` consumes.  Cluster
    backends (``units > 1``) price each step sharded across the
    schedule's units; the contention-aware ``analytical`` form does so
    without running the DES."""
    return [w["cycles"]
            for w in _price_workloads(sched, backend_name,
                                      **backend_kwargs)]


def _percentile(xs: "list[float]", q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]); 0.0 on empty input."""
    if not xs:
        return 0.0
    xs = sorted(xs)
    rank = max(1, math.ceil(q / 100.0 * len(xs)))
    return xs[min(rank, len(xs)) - 1]


def _effective_strategy(sched) -> str:
    """The partition strategy pricing actually uses for ``sched`` — the
    same resolution order as :func:`backend_kwargs_for`."""
    return sched.strategy or ("unit-affinity" if sched.affinity
                              else "output-tile")


def schedule_timeline(sched,
                      step_cycles: "list[float]",
                      ) -> "list[tuple[float, float]]":
    """Per-step ``(start, end)`` cycles of a priced schedule — the
    first-order timeline :func:`decode_latency_stats` consumes.

    ``overlap="chained"`` (and every single-unit schedule): steps run
    serially, each waiting out its release time first — exactly the
    classic cumulative walk when arrivals are all zero.

    ``overlap="relaxed"`` on a multi-unit ``unit-affinity`` schedule:
    a step starts at the latest of its release time, its hazard deps'
    (:meth:`~repro.serving.engine.BatchSchedule.step_deps`) completions,
    and the free time of the units it occupies — a step with an affinity
    hint occupies that unit alone, unhinted steps occupy the remaining
    (un-hinted) units, so a pinned decode stream runs beside prefill
    chunks the way the partitioner lays them out.  This is a list-
    schedule approximation (each step is still priced at its backend
    cost); the DES on the relaxed graph is the ground truth it tracks.
    """
    if len(step_cycles) != len(sched.steps):
        raise ValueError(f"{len(step_cycles)} step prices for "
                         f"{len(sched.steps)} steps")
    n = len(sched.steps)
    rel = list(sched.release_times) or [0.0] * n
    relaxed = (sched.overlap == "relaxed" and sched.units > 1
               and _effective_strategy(sched) == "unit-affinity"
               and sched.affinity)
    if not relaxed:
        spans = []
        t = 0.0
        for r, cyc in zip(rel, step_cycles):
            start = max(t, r)
            t = start + cyc
            spans.append((start, t))
        return spans

    deps = sched.step_deps()
    hinted = set(sched.affinity.values())
    rest = [u for u in range(sched.units) if u not in hinted] \
        or list(range(sched.units))
    free = [0.0] * sched.units
    end: "list[float]" = [0.0] * n
    spans = []
    for j, (step, cyc) in enumerate(zip(sched.steps, step_cycles)):
        hint = sched.affinity.get(sched.layers[j].name)
        occupies = [hint] if hint is not None else rest
        start = max([rel[j]] + [end[d] for d in deps[j]]
                    + [free[u] for u in occupies])
        end[j] = start + cyc
        for u in occupies:
            free[u] = end[j]
        spans.append((start, end[j]))
    return spans


def schedule_spans(sched, step_cycles: "list[float]", n_layers: int):
    """The per-request lifecycle :class:`~repro.obs.spans.SpanLog` of a
    priced schedule, placed on the same :func:`schedule_timeline` that
    :func:`decode_latency_stats` uses — ``arrival → admission →
    prefill(.chunk_j) → decode_iter_k → complete`` for every request,
    without running the DES (``evaluate_schedule`` attaches the
    DES-grounded log under ``result.detail["span_log"]``)."""
    from repro.obs import SpanLog
    return SpanLog.from_schedule(sched, schedule_timeline(sched, step_cycles),
                                 n_layers)


def decode_latency_stats(sched, step_cycles: "list[float]",
                         n_layers: int) -> "dict[str, float]":
    """Serving metrics from a priced schedule.

    Steps are placed on the :func:`schedule_timeline` (serial for
    chained schedules, hazard/unit-constrained for relaxed multi-unit
    ones; release times from request arrivals either way); a step
    covering ``repeat / n_layers`` decode iterations emits its tokens
    uniformly across its span.  Reported:

    * ``ttft_p50`` / ``ttft_p99`` — per-request **time to first token**:
      from the request's own arrival to its first decode token (the
      queueing delay a batching policy controls; full prefill makes
      later batches wait out every earlier drain).  With an all-at-t=0
      queue this equals the classic decode-queueing delay.
    * ``decode_p50`` / ``decode_p99`` — same values, kept under the
      pre-arrival-semantics names every existing caller uses.
    * ``itl_p50`` / ``itl_p99`` — inter-token latency between successive
      decode tokens of one request (the cadence cost of interleaving).
    * ``makespan`` — cycles until the last step completes (strictly
      below the serial sum when relaxed overlap genuinely overlaps).
    """
    spans = schedule_timeline(sched, step_cycles)
    first: "dict[int, float]" = {}
    last: "dict[int, float]" = {}
    itl: "list[float]" = []
    for step, (start, end) in zip(sched.steps, spans):
        dr = step.decode_requests or (
            step.requests if step.kind == "decode" else ())
        if dr:
            iters = max(1, round(step.repeat / n_layers))
            for j in range(iters):
                tok = start + (end - start) * (j + 1) / iters
                for r in dr:
                    if r in last:
                        itl.append(tok - last[r])
                    else:
                        first[r] = tok
                    last[r] = tok
    lat = [t - sched.arrival_of(r) for r, t in first.items()]
    ttft = {
        "ttft_p50": _percentile(lat, 50.0),
        "ttft_p99": _percentile(lat, 99.0),
    }
    return {
        "makespan": max((e for _, e in spans), default=0.0),
        "decode_p50": ttft["ttft_p50"],
        "decode_p99": ttft["ttft_p99"],
        **ttft,
        "itl_p50": _percentile(itl, 50.0),
        "itl_p99": _percentile(itl, 99.0),
        "decode_tokens": float(len(itl) + len(first)),
    }


def schedule_metrics(sched, n_layers: int,
                     backend_name: str = "analytical",
                     **backend_kwargs) -> "dict[str, float]":
    """One-call pricing: per-step costs + latency stats + aggregate
    matrix utilization of the whole schedule on ``backend_name`` — one
    ``run_workload`` pass per step, shared by both.  An explicit
    ``strategy=`` override reaches the latency timeline too, so the
    relaxed-overlap placement model always matches the partition the
    steps were actually priced under."""
    works = _price_workloads(sched, backend_name, **backend_kwargs)
    cycles = [w["cycles"] for w in works]
    resolved = backend_kwargs_for(sched, **backend_kwargs).get("strategy")
    if resolved is not None and resolved != sched.strategy:
        sched = dataclasses.replace(sched, strategy=resolved)
    stats = decode_latency_stats(sched, cycles, n_layers)
    total = sum(cycles)
    # the single-unit simulate_workload reports busy matrix cycles, the
    # cluster forms report per-layer utilization directly; either way
    # the schedule aggregate is the cycle-weighted mean.
    busy = sum(w.get("matrix_utilization",
                     w["matrix"] / c if c else 0.0) * c
               for w, c in zip(works, cycles))
    stats["matrix_utilization"] = busy / total if total else 0.0
    stats["workload_cycles"] = total
    return stats


# ---------------------------------------------------------------------------
# Auto-selection: price (policy x partition) candidates, pick the best.
# ---------------------------------------------------------------------------

def select_schedule(ctx: PolicyContext, *,
                    backend_name: str = "analytical",
                    objective: str = "decode_p50",
                    makespan_slack: float = 0.05,
                    policies: "Optional[list[str]]" = None,
                    strategies: "Optional[list[str]]" = None,
                    overlaps: "Optional[list[str]]" = None,
                    policy_kw: "Optional[dict]" = None,
                    ttft_p99_slo: "Optional[float]" = None,
                    **backend_kwargs):
    """Price every (policy × partition strategy × overlap) candidate
    with the closed-form ``analytical`` backend (no DES run) and return
    ``(best BatchSchedule, report)``.

    Objective: minimise ``objective`` (a :func:`decode_latency_stats`
    key) among candidates whose makespan is within ``makespan_slack`` of
    the fastest candidate — latency policies may not buy their p50 with
    unbounded throughput loss.  On a cluster the sweep includes
    ``overlap="relaxed"`` lowering (true data hazards only), so a
    relaxed-overlap candidate is picked exactly when the overlap lowers
    the objective; single-unit sweeps stay chained (relaxed cannot
    overlap anything there).  ``policy_kw`` (e.g. ``chunk_tokens``)
    is forwarded to every candidate policy that accepts it.  ``report``
    maps candidate keys to their metric dicts (chained candidates keep
    the bare ``policy×strategy`` key; relaxed ones append
    ``×relaxed``), the chosen one repeated under ``"chosen"``.

    ``ttft_p99_slo`` (cycles) switches to **SLO selection** — the
    ``auto-slo`` policy's rule: among candidates whose ``ttft_p99``
    meets the target, pick the *cheapest* (lowest ``workload_cycles``,
    makespan tie-break) regardless of the slack rule; when *no*
    candidate meets the target, degrade gracefully to the candidate
    closest to it (lowest ``ttft_p99``).  ``report["chosen"]["slo_met"]``
    records which branch fired.

    The default sweep covers every registered *concrete* policy;
    meta-policies (``SchedulingPolicy.meta``) are skipped so the sweep
    cannot recurse into the policy that invoked it.
    """
    names = list(policies if policies is not None else
                 [n for n, c in POLICIES.items()
                  if not getattr(c, "meta", False)])
    strats = list(strategies or
                  (["output-tile", "unit-affinity"] if ctx.units > 1
                   else [None]))
    ovs = list(overlaps or
               (["chained", "relaxed"] if ctx.units > 1 else ["chained"]))
    from repro.sim.lower import OVERLAP_MODES
    bad = [ov for ov in ovs if ov not in OVERLAP_MODES]
    if bad:
        raise ValueError(f"unknown overlap mode(s) {bad}; "
                         f"one of {OVERLAP_MODES}")
    cands: "dict[str, tuple]" = {}
    for pname in names:
        try:
            policy = get_policy(pname, **(policy_kw or {}))
        except TypeError:          # e.g. chunk_tokens on full-prefill
            policy = get_policy(pname)
        base = policy.schedule(ctx)
        for strat in strats:
            for ov in ovs:
                sched = dataclasses.replace(base, strategy=strat,
                                            overlap=ov)
                if ov == "relaxed" and not (
                        _effective_strategy(sched) == "unit-affinity"
                        and sched.affinity):
                    # identical metrics to the chained twin (the relaxed
                    # timeline only differs under hinted unit-affinity
                    # placement) — don't pay a second pricing pass.
                    continue
                kw = dict(backend_kwargs)
                if ctx.units > 1:
                    kw["units"] = ctx.units
                m = schedule_metrics(sched, ctx.n_layers, backend_name,
                                     **kw)
                key = (f"{pname}" + (f"×{strat}" if strat else "")
                       + (f"×{ov}" if ov != "chained" else ""))
                cands[key] = (sched, m)
    if not cands:
        raise ValueError(
            "no priceable candidates: overlap='relaxed' only differs "
            "under a hint-emitting policy with the 'unit-affinity' "
            "strategy — include 'chained' in overlaps or widen the sweep")
    slo_met = None
    if ttft_p99_slo is not None:
        meeting = {k: v for k, v in cands.items()
                   if v[1]["ttft_p99"] <= ttft_p99_slo}
        slo_met = bool(meeting)
        if meeting:                  # cheapest candidate meeting the SLO
            key = min(meeting, key=lambda k: (
                meeting[k][1]["workload_cycles"],
                meeting[k][1]["makespan"]))
        else:                        # none can: closest to the target
            key = min(cands, key=lambda k: (cands[k][1]["ttft_p99"],
                                            cands[k][1]["makespan"]))
        sched, metrics = cands[key]
    else:
        best_makespan = min(m["makespan"] for _, m in cands.values())
        feasible = {k: v for k, v in cands.items()
                    if v[1]["makespan"]
                    <= (1 + makespan_slack) * best_makespan}
        key = min(feasible, key=lambda k: (feasible[k][1][objective],
                                           feasible[k][1]["makespan"]))
        sched, metrics = feasible[key]
    report = {k: m for k, (_, m) in cands.items()}
    report["chosen"] = dict(metrics, candidate=key)
    if slo_met is not None:
        report["chosen"]["slo_met"] = slo_met
    return sched, report


# ---------------------------------------------------------------------------
# SLO-aware meta-policy: cheapest candidate meeting a p99 TTFT target.
# ---------------------------------------------------------------------------

@register_policy
class AutoSLOPolicy(SchedulingPolicy):
    """``policy="auto-slo"``: run the full (policy × partition ×
    overlap) candidate sweep and pick the **cheapest** candidate
    (lowest ``workload_cycles``) whose analytical ``ttft_p99`` meets
    ``ttft_p99_target`` — serve the SLO, spend nothing beyond it.  When
    no candidate can meet the target the policy degrades gracefully to
    the candidate closest to it; with no target at all it reduces to
    the classic slack-bounded ``objective`` selection ("auto").

    A *meta*-policy: it owns no schedule shape, so the sweep it invokes
    skips it (``meta = True``) and the returned schedule keeps the
    winning concrete policy's name, affinity and overlap.  The sweep's
    full pricing report is kept on :attr:`last_report` for callers (the
    online loop logs the chosen candidate per admission epoch)."""

    name = "auto-slo"
    meta = True

    def __init__(self, ttft_p99_target: "Optional[float]" = None,
                 backend_name: str = "analytical",
                 objective: str = "decode_p50",
                 makespan_slack: float = 0.05,
                 policies: "Optional[list[str]]" = None,
                 strategies: "Optional[list[str]]" = None,
                 overlaps: "Optional[list[str]]" = None,
                 policy_kw: "Optional[dict]" = None,
                 **backend_kwargs):
        self.ttft_p99_target = ttft_p99_target
        self.backend_name = backend_name
        self.objective = objective
        self.makespan_slack = makespan_slack
        self.policies = policies
        self.strategies = strategies
        self.overlaps = overlaps
        self.policy_kw = policy_kw
        self.backend_kwargs = backend_kwargs
        self.last_report: "Optional[dict]" = None

    def schedule(self, ctx: PolicyContext):
        sched, report = select_schedule(
            ctx, backend_name=self.backend_name, objective=self.objective,
            makespan_slack=self.makespan_slack, policies=self.policies,
            strategies=self.strategies, overlaps=self.overlaps,
            policy_kw=self.policy_kw, ttft_p99_slo=self.ttft_p99_target,
            **self.backend_kwargs)
        self.last_report = report
        return sched

"""Regression gate for the tracked BENCH_*.json trajectory.

Compares fresh snapshots (a ``benchmarks/record.py`` run, usually
``--quick`` in CI) against the committed baselines at the repo root and
exits non-zero when any ``metrics`` value drifted more than
``--tolerance`` (default 10%) in the *bad* direction:

* names containing ``util`` / ``eff`` / ``goodput`` / ``qps`` /
  ``speedup`` are better-higher — a drop fails (goodput and
  saturation-knee QPS come from the online sustained-load rows,
  speedups from the tuned-dispatch ``tuned|*`` rows);
* everything else (``makespan``, ``ttft_*``, ``itl_*``, ``cycles``,
  ``*_seconds``, ``preemptions``) is better-lower — a rise fails.

Improvements of any size pass (with a note: re-record the baseline to
bank them).  ``info`` blocks — wall-clock, environment — are never
compared.  Every fresh entry must exist in the baseline and share its
``schema_version``; a quick run is a row subset of the full baseline by
construction, so missing *baseline* entries are fine, missing *fresh*
ones are not checked (CI only validates what it ran).

Run:  python scripts/check_bench.py --baseline-dir . --fresh-dir /tmp/bench
"""

from __future__ import annotations

import argparse
import json
import os
import sys

BENCH_FILES = ("BENCH_serving.json", "BENCH_cluster.json")

#: metric-name fragments where higher is better (drops regress).
_HIGHER_BETTER = ("util", "eff", "goodput", "qps", "speedup")


def higher_is_better(name: str) -> bool:
    return any(frag in name for frag in _HIGHER_BETTER)


def compare_doc(base: dict, fresh: dict, tolerance: float,
                fname: str) -> "tuple[list[str], list[str], int]":
    """(failures, drift_report_lines, n_compared) for one document pair.
    Bit-identical metrics count as compared but print no line."""
    failures: "list[str]" = []
    lines: "list[str]" = []
    compared = 0
    if base.get("schema_version") != fresh.get("schema_version"):
        failures.append(
            f"{fname}: schema_version mismatch "
            f"(baseline {base.get('schema_version')} vs fresh "
            f"{fresh.get('schema_version')}) — re-record the baseline")
        return failures, lines, compared
    for key, entry in sorted(fresh.get("entries", {}).items()):
        b_entry = base.get("entries", {}).get(key)
        if b_entry is None:
            failures.append(
                f"{fname}: entry {key!r} missing from baseline — "
                f"re-record to add it")
            continue
        for metric, new in sorted(entry.get("metrics", {}).items()):
            old = b_entry["metrics"].get(metric)
            if old is None:
                failures.append(
                    f"{fname}: {key}: metric {metric!r} missing from "
                    f"baseline")
                continue
            compared += 1
            if old == new:
                continue
            rel = (new - old) / abs(old) if old else float("inf")
            bad = rel < 0 if higher_is_better(metric) else rel > 0
            mark = " "
            if bad and abs(rel) > tolerance:
                mark = "✗"
                failures.append(
                    f"{fname}: {key}: {metric} regressed {rel:+.1%} "
                    f"({old:.6g} -> {new:.6g}, tolerance {tolerance:.0%})")
            elif not bad and abs(rel) > tolerance:
                mark = "+"        # large improvement: bank it
            lines.append(f"  {mark} {key:<34} {metric:<32} "
                         f"{old:>14.6g} -> {new:>14.6g}  {rel:+.2%}")
    return failures, lines, compared


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline-dir", default=".",
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--fresh-dir", required=True,
                    help="directory holding the freshly recorded snapshots")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="max bad-direction relative drift (default 0.10)")
    args = ap.parse_args(argv)

    failures: "list[str]" = []
    compared = 0
    for fname in BENCH_FILES:
        f_path = os.path.join(args.fresh_dir, fname)
        b_path = os.path.join(args.baseline_dir, fname)
        if not os.path.exists(f_path):
            continue                      # that bench wasn't recorded
        if not os.path.exists(b_path):
            failures.append(f"{fname}: no committed baseline at {b_path}")
            continue
        with open(b_path) as f:
            base = json.load(f)
        with open(f_path) as f:
            fresh = json.load(f)
        fails, lines, n = compare_doc(base, fresh, args.tolerance, fname)
        failures.extend(fails)
        compared += n
        print(f"{fname}: {n} metrics checked, {len(lines)} drifted")
        if lines:
            print("\n".join(lines))
    if compared == 0 and not failures:
        failures.append("no BENCH_*.json found in --fresh-dir "
                        "(did benchmarks/record.py run?)")
    if failures:
        print(f"\nFAIL — {len(failures)} problem(s):", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print(f"\nOK — {compared} metrics within {args.tolerance:.0%} "
          f"of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Online closed-loop serving: streaming admission + incremental re-plans.

``ServingEngine.plan`` is an offline one-shot — the whole queue is known
at t = 0, one schedule is built, priced, executed.  Production traffic
*keeps arriving*; this module closes the loop:

* an arrival process (:mod:`repro.serving.arrivals`) feeds requests to
  :class:`OnlineServingEngine.run`;
* the loop runs in **admission epochs**: at each epoch it admits every
  request that has arrived, re-plans the whole in-flight set through the
  registered batching policies (``policy="auto-slo"`` sweeps policy ×
  partition × overlap via :func:`~repro.serving.scheduler
  .select_schedule`, priced by the contention-aware analytical closed
  form — cheap enough to re-price on every admission, and the pricing
  cache makes repeat shapes free), then **commits** only the prefix of
  steps that start before the next arrival (at least one step — the
  admission-epoch granularity is a scheduling *step*, the chunk/layer
  granularity the decoupled-ISA argument buys, not a whole request
  drain);
* each committed epoch executes through the same
  ``ServingEngine.run_schedule`` DES path the offline planner uses, so
  spans and metrics stay grounded in measured per-resource timelines;
* requests cut mid-decode by a re-plan are **preempted** — their
  ``(prefill_progress, decode_done)`` state re-enters the next plan via
  :class:`~repro.serving.scheduler.PolicyContext` carryover, and the
  resumed stream continues at ``decode_iter<k>`` in the global span log;
  a bounded in-flight set (``max_inflight`` + ``evict_to_admit``)
  additionally **evicts** the least-progressed decode stream back to
  the waiting queue, state retained, when fresh arrivals would
  otherwise starve.

Progress bookkeeping is *padded-token* accounting: a committed prefill
or mixed step advances each prefill participant by
``ceil(prefill_tokens / participants)`` of the padded batch stream,
capped at its remaining prompt.  This is exact for ``full-prefill``
(every step covers the batch's whole padded prompt) and for the
single-request epochs a low offered load produces; under heterogeneous
batches it credits padding to the shorter prompts — an over-approx that
only makes a request *eligible* to decode earlier, never drops work.

:class:`OnlineResult` carries the closed-loop serving metrics — TTFT /
ITL percentiles measured on the global clock from each request's true
arrival, goodput (completed requests per second, optionally only those
meeting a TTFT SLO), preemption/eviction counts — plus the per-epoch
records and a cross-epoch :class:`~repro.obs.SpanLog` whose
``validate()`` holds through preemption and eviction.
:func:`qps_sweep` and :func:`find_saturation` are the sustained-load
benches built on top (``benchmarks/record.py`` tracks them in
``BENCH_serving.json``).
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Iterable, Optional

#: horizon/arrival comparison slack (cycles) — float noise, not policy.
_EPS = 1e-9


@dataclasses.dataclass
class OnlineRequest:
    """One request's closed-loop state, carried across re-plans."""

    rid: int
    arrival: float
    prompt_len: int
    prefill_done: int = 0
    decode_done: int = 0
    admitted: "Optional[float]" = None     # first admission epoch clock
    finish: "Optional[float]" = None       # last owned step's end (global)
    preemptions: int = 0
    evictions: int = 0

    def done(self, max_new_tokens: int) -> bool:
        return (self.prefill_done >= self.prompt_len
                and self.decode_done >= max_new_tokens)


@dataclasses.dataclass
class EpochRecord:
    """What one admission epoch planned, committed, and executed."""

    index: int
    clock: float                   # epoch start, global cycles
    makespan: float                # committed sub-schedule's DES makespan
    admitted: "tuple[int, ...]"    # request ids admitted this epoch
    committed_steps: int           # steps executed ...
    planned_steps: int             # ... of the full re-plan
    policy: str                    # concrete policy of the (chosen) plan
    strategy: "Optional[str]"
    overlap: str
    preempted: "tuple[int, ...]" = ()
    evicted: "tuple[int, ...]" = ()
    candidate: "Optional[str]" = None   # auto-slo sweep's chosen key
    slo_met: "Optional[bool]" = None


@dataclasses.dataclass
class OnlineResult:
    """The closed-loop run: per-request outcomes, per-epoch records,
    the cross-epoch span log, and the serving metrics derived from
    them."""

    requests: "list[OnlineRequest]"
    epochs: "list[EpochRecord]"
    span_log: object               # repro.obs.SpanLog
    makespan: float                # global clock at drain, cycles
    max_new_tokens: int
    freq_hz: float

    # ----- latency ---------------------------------------------------------
    def ttfts(self) -> "dict[int, float]":
        """Per-request time to first token (cycles, from true arrival)."""
        out = {}
        for r in self.requests:
            try:
                out[r.rid] = self.span_log.ttft(r.rid)
            except KeyError:
                pass                        # never decoded (shouldn't happen)
        return out

    def itls(self) -> "list[float]":
        """Inter-token gaps between successive decode tokens, pooled."""
        ends: "dict[int, list[float]]" = {}
        for s in self.span_log:
            if s.phase.startswith("decode_iter"):
                ends.setdefault(s.request, []).append(s.end)
        gaps: "list[float]" = []
        for ts in ends.values():
            ts.sort()
            gaps.extend(b - a for a, b in zip(ts, ts[1:]))
        return gaps

    def ttft_stats(self) -> "dict[str, float]":
        from repro.serving.scheduler import _percentile
        lat = list(self.ttfts().values())
        itl = self.itls()
        return {"ttft_p50": _percentile(lat, 50.0),
                "ttft_p99": _percentile(lat, 99.0),
                "itl_p50": _percentile(itl, 50.0),
                "itl_p99": _percentile(itl, 99.0)}

    # ----- throughput ------------------------------------------------------
    def completed(self, ttft_slo: "Optional[float]" = None,
                  ) -> "list[OnlineRequest]":
        """Requests that finished — optionally only those whose TTFT met
        ``ttft_slo`` (cycles): the *goodput* numerator."""
        done = [r for r in self.requests if r.finish is not None]
        if ttft_slo is None:
            return done
        t = self.ttfts()
        return [r for r in done
                if r.rid in t and t[r.rid] <= ttft_slo + _EPS]

    def goodput_qps(self, ttft_slo: "Optional[float]" = None) -> float:
        """Completed (SLO-meeting) requests per *second* of makespan."""
        if self.makespan <= 0:
            return 0.0
        return len(self.completed(ttft_slo)) / (self.makespan / self.freq_hz)

    @property
    def n_preemptions(self) -> int:
        return sum(r.preemptions for r in self.requests)

    @property
    def n_evictions(self) -> int:
        return sum(r.evictions for r in self.requests)

    def summary(self, ttft_slo: "Optional[float]" = None,
                ) -> "dict[str, float]":
        """One flat dict for benches/CLI tables."""
        s = self.ttft_stats()
        s.update(makespan=self.makespan,
                 completed=float(len(self.completed())),
                 goodput_qps=self.goodput_qps(ttft_slo),
                 epochs=float(len(self.epochs)),
                 preemptions=float(self.n_preemptions),
                 evictions=float(self.n_evictions))
        return s


class OnlineServingEngine:
    """The closed loop: arrivals in, committed admission epochs out.

    ``policy`` names any registered concrete policy, ``"auto"`` (classic
    slack-bounded sweep), or ``"auto-slo"`` — with ``ttft_p99_slo`` set
    (cycles), planning always goes through the SLO-aware sweep.  Plans
    are priced with ``plan_backend`` (the analytical closed form — cheap
    enough for every admission); committed epochs execute on
    ``execute_backend`` (``"desim"`` grounds spans in the DES;
    ``"analytical"`` keeps large saturation sweeps fast).

    ``max_inflight`` bounds the set re-planned each epoch (default:
    unbounded — every arrived request).  ``evict_to_admit=True`` lets a
    waiting arrival displace the least-progressed *decoding* request
    (state retained, re-admitted later) instead of queueing behind it.

    ``kv_hot_blocks`` (default ``None`` = unlimited KV) turns on the
    paged KV-cache residency model: a
    :class:`~repro.serving.kvcache.PagedKVCache` of that many hot
    blocks is threaded across admission epochs — prefill/decode credit
    appends KV tokens, decode participation re-pins cold blocks
    (``ensure_resident``), and the per-request residency / refill bytes
    feed :class:`~repro.serving.scheduler.PolicyContext` so
    residency-aware policies can prefer hot requests and the priced
    plans carry ``kv_refill`` memory nodes.  Evictions and refills emit
    ``kv_evicted`` / ``kv_refill`` span markers and
    ``online_kv_*`` counters.
    """

    def __init__(self, cfg, *, max_batch: int = 4,
                 max_new_tokens: int = 8, units: int = 1,
                 policy: str = "full-prefill", overlap: str = "chained",
                 plan_backend: str = "analytical",
                 execute_backend: str = "desim",
                 max_inflight: "Optional[int]" = None,
                 evict_to_admit: bool = False,
                 ttft_p99_slo: "Optional[float]" = None,
                 policy_kw: "Optional[dict]" = None,
                 freq_hz: "Optional[float]" = None,
                 kv_hot_blocks: "Optional[int]" = None,
                 kv_block_tokens: int = 16, kv_policy: str = "lru",
                 kv_seed: int = 0, kv_commit_steps: int = 2,
                 metrics=None, **backend_kwargs):
        from repro.core.config import CASE_STUDY
        from repro.serving.engine import ServingEngine
        if max_inflight is not None and max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, "
                             f"got {max_inflight}")
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_new_tokens = max_new_tokens
        self.units = units
        self.policy = policy
        self.overlap = overlap
        self.plan_backend = plan_backend
        self.execute_backend = execute_backend
        self.max_inflight = max_inflight
        self.evict_to_admit = evict_to_admit
        self.ttft_p99_slo = ttft_p99_slo
        self.policy_kw = dict(policy_kw or {})
        if kv_commit_steps < 1:
            raise ValueError(f"kv_commit_steps must be >= 1, "
                             f"got {kv_commit_steps}")
        self.kv_hot_blocks = kv_hot_blocks
        self.kv_block_tokens = kv_block_tokens
        self.kv_policy = kv_policy
        self.kv_seed = kv_seed
        self.kv_commit_steps = kv_commit_steps
        self.kv_cache = None           # built per run() when enabled
        self.backend_kwargs = dict(backend_kwargs)
        unit = backend_kwargs.get("unit")
        self.freq_hz = float(freq_hz if freq_hz is not None else
                             getattr(unit, "freq_hz", CASE_STUDY.freq_hz))
        # params are never touched on the modelling path; the inner
        # engine supplies run_schedule + metrics plumbing.
        self.inner = ServingEngine(cfg, None, max_batch=max_batch,
                                   metrics=metrics)
        self.metrics = self.inner.metrics

    # ----- planning --------------------------------------------------------
    def _planner(self):
        from repro.serving.scheduler import get_policy
        if self.policy in ("auto", "auto-slo") or \
                self.ttft_p99_slo is not None:
            return get_policy(
                "auto-slo", ttft_p99_target=self.ttft_p99_slo,
                backend_name=self.plan_backend,
                policy_kw=(self.policy_kw or None))
        return get_policy(self.policy, **self.policy_kw)

    def _plan(self, planner, ctx):
        sched = planner.schedule(ctx)
        if not getattr(planner, "meta", False):
            sched.overlap = self.overlap
        return sched, getattr(planner, "last_report", None)

    def _context(self, inflight: "list[OnlineRequest]", clock: float):
        from repro.serving.scheduler import PolicyContext
        arr = tuple(max(0.0, r.arrival - clock) for r in inflight)
        kv_res, kv_ref = (), ()
        if self.kv_cache is not None:
            kv_res = tuple(self.kv_cache.residency(r.rid)
                           for r in inflight)
            kv_ref = tuple(self.kv_cache.refill_bytes(r.rid)
                           for r in inflight)
        return PolicyContext(
            cfg=self.cfg,
            prompt_lengths=tuple(r.prompt_len for r in inflight),
            max_batch=self.max_batch,
            max_new_tokens=self.max_new_tokens,
            units=self.units,
            arrival_times=arr if any(arr) else (),
            prefill_progress=tuple(r.prefill_done for r in inflight),
            decode_done=tuple(r.decode_done for r in inflight),
            kv_residency=kv_res, kv_refill_bytes=kv_ref)

    # ----- the event loop --------------------------------------------------
    def run(self, source: "Iterable") -> OnlineResult:
        """Drive the closed loop over an arrival source (any iterable of
        :class:`~repro.serving.arrivals.Arrival`) until every request
        completes; returns the :class:`OnlineResult`."""
        from repro.obs import SpanAssembler
        from repro.serving.scheduler import (price_steps,
                                             schedule_timeline)
        arrivals = list(source)
        reqs = [OnlineRequest(i, a.time, a.prompt_len)
                for i, a in enumerate(arrivals)]
        self.kv_cache = None
        if self.kv_hot_blocks is not None:
            from repro.serving.kvcache import (PagedKVCache,
                                               kv_bytes_per_token)
            # one request's full stream must fit the hot pool (vLLM's
            # block-manager admission rule): an oversized request would
            # deadlock on its own pinned blocks instead of thrashing.
            need = max((r.prompt_len for r in reqs), default=0) \
                + self.max_new_tokens
            need_blocks = -(-need // self.kv_block_tokens)
            if need_blocks > self.kv_hot_blocks:
                raise ValueError(
                    f"kv_hot_blocks={self.kv_hot_blocks} cannot hold one "
                    f"request's working set ({need} tokens = "
                    f"{need_blocks} blocks of {self.kv_block_tokens}); "
                    f"raise kv_hot_blocks or kv_block_tokens")
            self.kv_cache = PagedKVCache(
                hot_blocks=self.kv_hot_blocks,
                block_tokens=self.kv_block_tokens,
                kv_bytes_per_token=kv_bytes_per_token(self.cfg),
                policy=self.kv_policy, seed=self.kv_seed)
        asm = SpanAssembler(self.cfg.n_layers)
        for r in reqs:
            asm.observe_arrival(r.rid, r.arrival)
        pending = deque(reqs)
        waiting: "list[OnlineRequest]" = []
        inflight: "list[OnlineRequest]" = []
        epochs: "list[EpochRecord]" = []
        planner = self._planner()
        m = self.metrics
        pol = self.policy
        clock = 0.0
        while pending or waiting or inflight:
            # --- arrivals due now join the waiting queue ------------------
            while pending and pending[0].arrival <= clock + _EPS:
                waiting.append(pending.popleft())
            if not waiting and not inflight:
                clock = pending[0].arrival          # idle: jump to next
                continue
            # --- admission (+ optional eviction to admit) -----------------
            cap = self.max_inflight or (len(waiting) + len(inflight))
            admitted, evicted = [], []
            while waiting and len(inflight) < cap:
                r = waiting.pop(0)
                if r.admitted is None:
                    r.admitted = clock
                elif r.evictions:
                    asm.mark(r.rid, "resumed", clock)
                inflight.append(r)
                admitted.append(r.rid)
            if self.evict_to_admit:
                while waiting:
                    victims = sorted(
                        (x for x in inflight
                         if x.decode_done > 0
                         and not x.done(self.max_new_tokens)),
                        key=lambda x: (x.decode_done, x.rid))
                    if not victims:
                        break
                    v = victims[0]
                    inflight.remove(v)
                    v.evictions += 1
                    evicted.append(v.rid)
                    asm.mark(v.rid, "evicted", clock)
                    waiting.append(v)           # back of the queue
                    r = waiting.pop(0)
                    if r.admitted is None:
                        r.admitted = clock
                    elif r.evictions:
                        asm.mark(r.rid, "resumed", clock)
                    inflight.append(r)
                    admitted.append(r.rid)
            inflight.sort(key=lambda x: x.rid)
            m.counter("online_admissions_total", policy=pol).inc(
                len(admitted))
            m.counter("online_evictions_total", policy=pol).inc(
                len(evicted))
            m.gauge("online_queue_depth", policy=pol).set(
                len(waiting) + len(pending))
            m.histogram("online_queue_depth_epochs", policy=pol).observe(
                len(waiting) + len(pending))
            # --- re-plan the in-flight set --------------------------------
            ctx = self._context(inflight, clock)
            sched, report = self._plan(planner, ctx)
            if not sched.steps:                    # nothing left to do
                for r in inflight:
                    if r.finish is None:
                        r.finish = clock
                inflight.clear()
                continue
            # --- commit horizon: steps starting before the next arrival ---
            cycles = price_steps(sched, self.plan_backend,
                                 **self.backend_kwargs)
            timeline = schedule_timeline(sched, cycles)
            if pending:
                horizon = pending[0].arrival - clock
                k = max(1, sum(1 for s, _ in timeline
                               if s < horizon - _EPS))
            else:
                k = len(sched.steps)
            if self.kv_cache is not None:
                # a plan is priced against residency at epoch start;
                # eviction churn invalidates it, so under a bounded KV
                # pool re-plan every ``kv_commit_steps`` steps.
                k = min(k, self.kv_commit_steps)
            csched = dataclasses.replace(
                sched, steps=sched.steps[:k], layers=sched.layers[:k],
                release_times=tuple(sched.release_times[:k]),
                refill_bytes=tuple(sched.refill_bytes[:k]))
            # --- execute the committed epoch on the grounded path ---------
            res = self.inner.run_schedule(
                csched, backend_name=self.execute_backend,
                workload=False, attach_spans=False,
                **self.backend_kwargs)
            spans = res.detail.get("step_spans")
            if spans is None:       # backend without per-step windows
                spans = {lt.name: w
                         for lt, w in zip(csched.layers, timeline[:k])}
            windows = [tuple(spans[lt.name]) for lt in csched.layers]
            epoch_make = max(e for _, e in windows)
            asm.add_epoch(csched, spans, offset=clock,
                          id_map={i: r.rid for i, r in
                                  enumerate(inflight)})
            # --- progress + finish bookkeeping ----------------------------
            self._advance(csched, windows, inflight, clock, asm=asm)
            cut = k < len(sched.steps)
            preempted = []
            if cut:
                for r in inflight:
                    if (0 < r.decode_done < self.max_new_tokens
                            and r.prefill_done >= r.prompt_len):
                        r.preemptions += 1
                        preempted.append(r.rid)
                        asm.mark(r.rid, "preempted", clock + epoch_make)
            done = [r for r in inflight if r.done(self.max_new_tokens)]
            inflight = [r for r in inflight
                        if not r.done(self.max_new_tokens)]
            if self.kv_cache is not None:
                for r in done:
                    self.kv_cache.release(r.rid, t=clock + epoch_make)
            m.counter("online_epochs_total", policy=pol).inc()
            m.counter("online_preemptions_total", policy=pol).inc(
                len(preempted))
            m.counter("online_completions_total", policy=pol).inc(
                len(done))
            chosen = (report or {}).get("chosen", {})
            epochs.append(EpochRecord(
                index=len(epochs), clock=clock, makespan=epoch_make,
                admitted=tuple(admitted), committed_steps=k,
                planned_steps=len(sched.steps), policy=sched.policy,
                strategy=sched.strategy, overlap=sched.overlap,
                preempted=tuple(preempted), evicted=tuple(evicted),
                candidate=chosen.get("candidate"),
                slo_met=chosen.get("slo_met")))
            clock += epoch_make
        log = asm.finalize()
        return OnlineResult(requests=reqs, epochs=epochs, span_log=log,
                            makespan=clock,
                            max_new_tokens=self.max_new_tokens,
                            freq_hz=self.freq_hz)

    def _advance(self, csched, windows, inflight, clock: float,
                 asm=None) -> None:
        """Fold one committed epoch's steps into per-request progress
        (padded-token prefill accounting, capped decode credit) and
        stamp finish times as requests drain.  When the paged KV cache
        is enabled, credited tokens append KV blocks and decode
        participation re-pins cold blocks, emitting ``kv_evicted`` /
        ``kv_refill`` markers into ``asm``."""
        n_layers = self.cfg.n_layers
        for step, (start, end) in zip(csched.steps, windows):
            dr = set(step.decode_requests or (
                step.requests if step.kind == "decode" else ()))
            pre = [i for i in step.requests if i not in dr]
            iters = max(1, round(step.repeat / n_layers))
            t = clock + end
            if pre:
                share = step.tokens - (len(dr) if step.kind == "mixed"
                                       else 0)
                per = max(1, math.ceil(share / len(pre)))
                for i in pre:
                    r = inflight[i]
                    credit = min(r.prompt_len, r.prefill_done + per) \
                        - r.prefill_done
                    r.prefill_done += credit
                    self._kv_append(r.rid, credit, t, asm)
            for i in dr:
                r = inflight[i]
                credit = min(self.max_new_tokens,
                             r.decode_done + iters) - r.decode_done
                r.decode_done += credit
                self._kv_touch(r.rid, t, asm)
                self._kv_append(r.rid, credit, t, asm)
            for i in step.requests:
                r = inflight[i]
                if r.done(self.max_new_tokens):
                    if r.finish is None and self.kv_cache is not None:
                        # free the pool at completion, not epoch end —
                        # a done request must never be an eviction
                        # victim (its span chain already closed).
                        self.kv_cache.release(r.rid, t=t)
                    r.finish = clock + end

    # ----- paged-KV bookkeeping -------------------------------------------
    def _kv_append(self, rid: int, n_tokens: int, t: float, asm) -> None:
        if self.kv_cache is None or n_tokens <= 0:
            return
        self._kv_evicted(self.kv_cache.append(rid, n_tokens, t=t), t, asm)

    def _kv_touch(self, rid: int, t: float, asm) -> None:
        """Decode needs the whole KV stream hot: re-pin cold blocks,
        pricing the refill into counters + span markers."""
        if self.kv_cache is None:
            return
        cost, evictions = self.kv_cache.ensure_resident(rid, t=t)
        if cost > 0.0:
            self.metrics.counter("online_kv_refills_total",
                                 policy=self.policy).inc()
            self.metrics.counter("online_kv_refill_bytes_total",
                                 policy=self.policy).inc(cost)
            if asm is not None:
                asm.mark(rid, "kv_refill", t)
        self._kv_evicted(evictions, t, asm)

    def _kv_evicted(self, evictions, t: float, asm) -> None:
        for victim, _slot, _tier in evictions:
            self.metrics.counter("online_kv_evictions_total",
                                 policy=self.policy).inc()
            if asm is not None:
                asm.mark(victim, "kv_evicted", t)


# ---------------------------------------------------------------------------
# Sustained-load benches: offered-QPS sweep + saturation knee.
# ---------------------------------------------------------------------------

def qps_sweep(cfg, qps_list: "Iterable[float]", *, n_requests: int = 8,
              seed: int = 0,
              prompt_lengths: "Optional[tuple[int, ...]]" = None,
              ttft_slo: "Optional[float]" = None,
              **engine_kw) -> "list[dict]":
    """Run the closed loop at each offered QPS (seeded Poisson traffic)
    and return one metrics row per point — the TTFT/ITL/goodput curves
    of one policy.  ``engine_kw`` goes to :class:`OnlineServingEngine`
    (``policy=``, ``units=``, ``execute_backend=``, ...)."""
    from repro.serving.arrivals import PoissonArrivals, qps_to_gap
    rows = []
    for qps in qps_list:
        eng = OnlineServingEngine(cfg, **engine_kw)
        src = PoissonArrivals(
            mean_gap=qps_to_gap(qps, eng.freq_hz), n=n_requests,
            seed=seed, prompt_lengths=prompt_lengths)
        res = eng.run(src)
        row = {"offered_qps": float(qps), **res.summary(ttft_slo)}
        rows.append(row)
    return rows


def find_saturation(cfg, *, start_qps: float, factor: float = 2.0,
                    max_points: int = 7, keepup_ratio: float = 0.8,
                    n_requests: int = 8, seed: int = 0,
                    prompt_lengths: "Optional[tuple[int, ...]]" = None,
                    ttft_slo: "Optional[float]" = None,
                    **engine_kw) -> dict:
    """Locate a policy's goodput collapse: sweep offered QPS
    geometrically from ``start_qps`` until goodput falls below
    ``keepup_ratio`` × offered (or ``max_points`` is hit).  Returns the
    swept ``points``, the ``knee_qps`` (last offered rate the policy
    kept up with; 0.0 if it never did) and ``peak_goodput_qps`` — the
    saturation throughput the knee plateaus at."""
    points = qps_sweep(
        cfg, [start_qps * factor ** i for i in range(max_points)],
        n_requests=n_requests, seed=seed, prompt_lengths=prompt_lengths,
        ttft_slo=ttft_slo, **engine_kw)
    knee = 0.0
    saturated = False
    kept = []
    for row in points:
        row["keeps_up"] = (row["goodput_qps"]
                           >= keepup_ratio * row["offered_qps"])
        if row["keeps_up"] and not saturated:
            knee = row["offered_qps"]
        else:
            saturated = True
        kept.append(row)
    return {"points": kept, "knee_qps": knee,
            "peak_goodput_qps": max((r["goodput_qps"] for r in kept),
                                    default=0.0),
            "saturated": saturated}

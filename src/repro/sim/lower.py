"""Lowerings in and out of the TaskGraph IR.

In:  ``layer_to_graph`` / ``workload_to_graph`` convert the analytical
model's :class:`~repro.core.simulator.LayerTrace` records (and anything
built on ``MatMulTask``) into dependency-linked TaskGraphs, fused
(Listing 1: per-tile epilogues overlap the matrix stream) or unfused
(vector phase after all tiles, with the DRAM round-trip of the
intermediate as an explicit memory node).

Out (machine): ``desim_layer`` / ``desim_workload`` run the graphs on
the discrete-event machine and report the same dict shape as
``simulate_layer`` / ``simulate_workload`` so callers can swap engines.

Out (JAX): ``execute_graph_jax`` walks the *same* graph and executes it
through ``AsyncMatmulEngine``/``cute_matmul`` — matrix nodes dispatch
accumulator-tile matmuls, vector nodes apply the fused epilogue — which
is the paper's unified-software-stack claim made literal: one IR, one
schedule, two targets.  ``execute_workload_jax`` extends that to
multi-GEMM schedule graphs (e.g. a serving ``BatchSchedule`` lowered by
``workload_to_graph``): one ``{gemm label: (a, b)}`` operand dict, one
output dict, same program order the DES timed.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.config import MatrixUnitConfig
from repro.core.engine import AsyncMatmulEngine
from repro.core.fusion import (Epilogue, EpilogueOperands, NO_OPERANDS,
                               _infer_policy, apply_epilogue)
from repro.core.hardware import CpuPlatform, SHUTTLE
from repro.core.simulator import (LayerTrace, SATURN_512,
                                  VECTOR_OP_INSTRS, VectorUnit)
from repro.core.task import BiasType, MatMulTask
from repro.sim.desim import DESimResult, simulate_graph
from repro.sim.graph import (Granularity, Node, TaskGraph, build_gemm_graph,
                             group_tiles)


# ---------------------------------------------------------------------------
# LayerTrace -> TaskGraph.
# ---------------------------------------------------------------------------

def layer_to_graph(unit: MatrixUnitConfig, layer: LayerTrace, *,
                   fused: bool = True,
                   granularity: Granularity = Granularity.TILE,
                   platform: CpuPlatform = SHUTTLE,
                   graph: Optional[TaskGraph] = None,
                   deps=()) -> "tuple[TaskGraph, list[Node]]":
    """One LayerTrace execution (repeat is handled by the caller).

    Fused: the layer's vector work is spread over epilogue nodes at the
    requested granularity, so it streams behind the matrix tiles.
    Unfused: every tile completes, the intermediate (beyond the L2
    working set) round-trips DRAM as a memory node, then one vector node
    runs the whole epilogue phase.
    """
    graph = graph if graph is not None else TaskGraph()
    tiles: "list[Node]" = []
    gemm_groups: "list[list[Node]]" = []     # granularity applied per GEMM
    for gi, g in enumerate(layer.gemms):
        graph, t = build_gemm_graph(
            g, unit.m_scp, unit.n_scp, graph=graph, deps=deps,
            layer=f"{layer.name}/g{gi}")
        tiles.extend(t)
        gemm_groups.extend(group_tiles(t, granularity, g.n, unit.n_scp))
    if not layer.vector_ops:
        return graph, tiles

    if fused:
        groups = [tiles] if granularity == Granularity.LAYER else gemm_groups
        share = {op: n / len(groups) for op, n in layer.vector_ops.items()}
        vecs = [graph.add("vector", f"{layer.name}/vec{i}",
                          deps=tuple(t.nid for t in grp), layer=layer.name,
                          vector_ops=dict(share))
                for i, grp in enumerate(groups)]
        return graph, vecs

    spill = max(0.0, layer.intermediate_bytes - platform.l2_bytes)
    vdeps = [t.nid for t in tiles]
    if spill > 0:
        # store + reload of the intermediate through the memory loader.
        mem = graph.add("memory", f"{layer.name}/spill",
                        deps=tuple(vdeps), layer=layer.name,
                        mem_bytes=2.0 * spill)
        vdeps = [mem.nid]
    vec = graph.add("vector", f"{layer.name}/vec", deps=tuple(vdeps),
                    layer=layer.name, vector_ops=dict(layer.vector_ops))
    return graph, [vec]


#: ``workload_to_graph`` step-chaining modes (see ``overlap=``).
OVERLAP_MODES = ("chained", "relaxed")


def workload_to_graph(unit: MatrixUnitConfig, layers: "list[LayerTrace]", *,
                      fused: bool = True,
                      granularity: Granularity = Granularity.TILE,
                      platform: CpuPlatform = SHUTTLE,
                      expand_repeat: bool = False,
                      overlap: str = "chained",
                      step_deps: "list[tuple[int, ...]] | None" = None,
                      release_times: "list[float] | None" = None,
                      refill_bytes: "list[float] | None" = None,
                      ) -> TaskGraph:
    """Lower a list of ``LayerTrace`` steps into one TaskGraph.

    :param unit: matrix-unit geometry the GEMMs are tiled for.
    :param layers: one :class:`~repro.core.simulator.LayerTrace` per
        schedule step (e.g. a serving ``BatchSchedule.layers``).
    :param fused: attach per-granularity epilogue vector nodes (Listing
        1 overlap) instead of one post-GEMM vector phase with the
        intermediate's DRAM round-trip.
    :param granularity: how much vector work rides behind each
        synchronisation point (``TILE`` / ``PANEL`` / ``LAYER``).
    :param platform: CPU platform (dispatch/check costs, DRAM derate).
    :param expand_repeat: instantiate ``layer.repeat`` copies of each
        step; by default one instance per step is emitted (the DES
        multiplies, like the analytical model).
    :param overlap: how successive steps are linked.

        * ``"chained"`` (default) — layer *i+1*'s tiles depend on layer
          *i*'s sinks: the whole schedule is one serial chain, the safe
          over-approximation every pre-overlap caller used.
        * ``"relaxed"`` — step *i*'s deps are only the sinks of the
          steps named by ``step_deps[i]`` (its true data hazards, e.g.
          the per-request KV/activation chain a
          :meth:`~repro.serving.engine.BatchSchedule.step_deps`
          computes).  Steps with no hazard between them carry **no
          edge**: placed on disjoint units they genuinely run
          concurrently, and per-unit resource ordering is left to the
          DES (same-unit steps still serialise on the dispatcher, banks
          and PE).  Results are unchanged — execution order per GEMM is
          dependency-driven either way.
    :param step_deps: per-step dependency lists (indices into
        ``layers``), required when ``overlap="relaxed"``; each entry may
        only name earlier steps.
    :param release_times: per-step earliest-start cycles (request
        arrival semantics): stamped on every node of the step as
        :attr:`~repro.sim.graph.Node.release_time`, honoured by the DES
        and approximated by the analytical backend.  ``None`` means
        everything is available at t = 0.
    :param refill_bytes: per-step KV-cache refill bytes (paged-KV
        residency — see :mod:`repro.serving.kvcache`): a step owing a
        nonzero refill gets a ``memory`` node ``<name>/kv_refill``
        *ahead of its tiles*, riding the shared/private
        ``BandwidthResource`` loaders exactly like a spill round-trip,
        so the DES and the analytical form both price the refill while
        JAX execution (memory nodes are simulation-only) is unchanged.
        ``None`` means KV is free and always resident.
    """
    if overlap not in OVERLAP_MODES:
        raise ValueError(f"unknown overlap mode {overlap!r}; one of "
                         f"{OVERLAP_MODES}")
    if overlap == "relaxed":
        if step_deps is None:
            raise ValueError('overlap="relaxed" needs step_deps (the '
                             "true cross-step data hazards); use "
                             "BatchSchedule.step_deps() for schedules")
        if len(step_deps) != len(layers):
            raise ValueError(f"{len(step_deps)} step_deps entries for "
                             f"{len(layers)} steps")
    if release_times is not None and len(release_times) != len(layers):
        raise ValueError(f"{len(release_times)} release_times for "
                         f"{len(layers)} steps")
    if refill_bytes is not None and len(refill_bytes) != len(layers):
        raise ValueError(f"{len(refill_bytes)} refill_bytes for "
                         f"{len(layers)} steps")
    graph = TaskGraph()
    step_sinks: "list[list[int]]" = []
    deps: "list[int]" = []
    for i, layer in enumerate(layers):
        if overlap == "relaxed":
            deps = []
            for d in step_deps[i]:
                if not 0 <= d < i:
                    raise ValueError(
                        f"step {i} depends on step {d}; deps must name "
                        "earlier steps")
                deps.extend(step_sinks[d])
        first_nid = len(graph)
        if refill_bytes is not None and refill_bytes[i] > 0.0:
            # evicted-block refill: the step's KV streams back through
            # the memory loader before its first tile may start.
            mem = graph.add("memory", f"{layer.name}/kv_refill",
                            deps=tuple(deps), layer=layer.name,
                            mem_bytes=float(refill_bytes[i]))
            deps = [mem.nid]
        for _ in range(layer.repeat if expand_repeat else 1):
            graph, sinks = layer_to_graph(
                unit, layer, fused=fused, granularity=granularity,
                platform=platform, graph=graph, deps=tuple(deps))
            deps = [s.nid for s in sinks]
        step_sinks.append(list(deps))
        if release_times is not None and release_times[i] > 0.0:
            for node in graph.nodes[first_nid:]:
                node.release_time = release_times[i]
    return graph


def schedule_to_graph(unit: MatrixUnitConfig, sched, *,
                      fused: bool = True,
                      granularity: Granularity = Granularity.TILE,
                      platform: CpuPlatform = SHUTTLE,
                      overlap: "Optional[str]" = None) -> TaskGraph:
    """Lower a serving ``BatchSchedule`` with its own overlap mode,
    hazard deps and arrival-derived release times — the schedule-aware
    form of :func:`workload_to_graph` every backend's ``lower()`` uses
    when handed a schedule instead of bare layers.  ``overlap``
    overrides the schedule's recorded mode without mutating it (the
    tuned-dispatch path re-lowers one plan under a cached overlap
    choice)."""
    overlap = overlap or getattr(sched, "overlap", "chained")
    return workload_to_graph(
        unit, list(sched.layers), fused=fused, granularity=granularity,
        platform=platform, overlap=overlap,
        step_deps=(sched.step_deps() if overlap == "relaxed" else None),
        release_times=list(getattr(sched, "release_times", ()) or ())
        or None,
        refill_bytes=list(getattr(sched, "refill_bytes", ()) or ())
        or None)


# ---------------------------------------------------------------------------
# DES-backed equivalents of simulate_layer / simulate_workload.
# ---------------------------------------------------------------------------

def desim_layer(unit: MatrixUnitConfig, layer: LayerTrace, *,
                platform: CpuPlatform = SHUTTLE,
                vector: VectorUnit = SATURN_512,
                fused: bool = True,
                granularity: Granularity = Granularity.TILE,
                ) -> "dict[str, float]":
    graph, _ = layer_to_graph(unit, layer, fused=fused,
                              granularity=granularity, platform=platform)
    r = simulate_graph(graph, unit, platform, vector)
    return {"cycles": r.cycles * layer.repeat,
            "matrix": r.busy("pe_array") * layer.repeat,
            "vector": r.busy("vector_unit") * layer.repeat,
            "result": r}


def desim_workload(unit: MatrixUnitConfig, layers: "list[LayerTrace]", *,
                   platform: CpuPlatform = SHUTTLE,
                   vector: VectorUnit = SATURN_512,
                   fused: bool = True,
                   granularity: Granularity = Granularity.TILE,
                   ) -> "dict[str, float]":
    tot = {"cycles": 0.0, "matrix": 0.0, "vector": 0.0}
    ideal = 0.0
    for layer in layers:
        r = desim_layer(unit, layer, platform=platform, vector=vector,
                        fused=fused, granularity=granularity)
        for k in tot:
            tot[k] += r[k]
        ideal += r["result"].ideal_matrix_cycles * layer.repeat
    tot["seconds"] = tot["cycles"] / unit.freq_hz
    tot["flops"] = sum(l.flops() for l in layers)
    tot["matrix_utilization"] = ideal / tot["cycles"] if tot["cycles"] else 0.0
    return tot


def desim_gemm(unit: MatrixUnitConfig, task: MatMulTask,
               platform: CpuPlatform = SHUTTLE,
               vector: VectorUnit = SATURN_512) -> DESimResult:
    """Bare GEMM through the DES (the Fig. 6 experiment shape)."""
    graph, _ = build_gemm_graph(task, unit.m_scp, unit.n_scp)
    return simulate_graph(graph, unit, platform, vector)


def exposed_dispatch(unit: MatrixUnitConfig, task: MatMulTask,
                     platform: CpuPlatform,
                     vector: VectorUnit = SATURN_512) -> float:
    """Cycles the CPU interface adds to the makespan: simulated time
    minus the same graph on an idealised zero-cost interface.  The
    CSR-mailbox platform (Kunminghu) exposes far more than RoCC ones in
    tile streams whose per-tile service time is comparable to the
    dispatch cost (paper Table 3 / §4.4)."""
    real = desim_gemm(unit, task, platform, vector).cycles
    free = dataclasses.replace(platform, dispatch_cycles=0, check_cycles=0)
    return real - desim_gemm(unit, task, free, vector).cycles


# ---------------------------------------------------------------------------
# TaskGraph -> JAX execution (the same graph, run for real).
# ---------------------------------------------------------------------------

def _slice_operands(ops: EpilogueOperands, ep: Epilogue,
                    m0: int, m: int, n0: int, n: int) -> EpilogueOperands:
    def cut(x, sl):
        return None if x is None else x[sl]
    bias = ops.bias
    if bias is not None:
        bias = bias[n0:n0 + n] if ep.bias_type == BiasType.ROW \
            else bias[m0:m0 + m, n0:n0 + n]
    return EpilogueOperands(
        bias=bias,
        scale_a=cut(ops.scale_a, slice(m0, m0 + m)),
        scale_b=cut(ops.scale_b, slice(n0, n0 + n)),
        residual=None if ops.residual is None
        else ops.residual[m0:m0 + m, n0:n0 + n])


def matmul_dep_tiles(graph: TaskGraph, node: Node) -> "list[Node]":
    """Matmul producers of ``node``, looking *through* memory nodes —
    a partitioned graph routes cross-unit edges via transfer nodes, but
    the data dependency is still on the producing tiles."""
    out: "list[Node]" = []
    seen: "set[int]" = set()
    stack = list(node.deps)
    while stack:
        d = stack.pop()
        if d in seen:
            continue
        seen.add(d)
        dn = graph.nodes[d]
        if dn.kind == "matmul":
            out.append(dn)
        elif dn.kind == "memory":
            stack.extend(dn.deps)
    return sorted(out, key=lambda n: n.nid)


def _epilogue_regions(graph: TaskGraph, policy, n_total: int):
    """Yield ``(ep, dep_tiles, (m_lo, m_hi, n_lo, n_hi))`` for each
    epilogue-carrying vector node, in program order, with the output
    dtype resolved and the GLU full-N guard applied — the one region
    walk both execution routes share."""
    for node in graph.topo_order():
        if node.kind != "vector" or node.epilogue is None:
            continue                          # cost-only node (sim graphs)
        ep = node.epilogue
        if ep.out_dtype is None:
            ep = dataclasses.replace(ep, out_dtype=policy.output_dtype)
        dep_tiles = matmul_dep_tiles(graph, node)
        m_lo = min(t.tile.m0 for t in dep_tiles)
        m_hi = max(t.tile.m0 + t.tile.m for t in dep_tiles)
        n_lo = min(t.tile.n0 for t in dep_tiles)
        n_hi = max(t.tile.n0 + t.tile.n for t in dep_tiles)
        if ep.glu and (n_lo != 0 or n_hi != n_total):
            raise ValueError("GLU epilogues need a full-N region; use "
                             "PANEL or LAYER granularity")
        yield ep, dep_tiles, (m_lo, m_hi, n_lo, n_hi)


def _place_region(out, part, ep, m_total: int, n_total: int,
                  m_lo: int, m_hi: int, n_lo: int):
    """Write one finished epilogue region into the (lazily created)
    output; GLU halves the column space."""
    if out is None:
        n_out = n_total // 2 if ep.glu else n_total
        out = jnp.zeros((m_total, n_out), part.dtype)
    col = n_lo // 2 if ep.glu else n_lo
    return out.at[m_lo:m_hi, col:col + part.shape[-1]].set(part)


def execute_graph_jax(graph: TaskGraph, a: jax.Array, b: jax.Array, *,
                      operands: EpilogueOperands = NO_OPERANDS,
                      engine: Optional[AsyncMatmulEngine] = None) -> jax.Array:
    """Execute a single-GEMM TaskGraph on real arrays.

    Matrix nodes fire ``asyncMatMul`` (accumulator-precision tiles, no
    epilogue — the matrix unit's output); vector nodes force the handles
    they depend on (``checkMatmul``) and apply their ``Epilogue`` to the
    assembled region.  Node order is the graph's program order, so the
    schedule the DES times is the schedule JAX traces.
    """
    engine = engine or AsyncMatmulEngine()
    policy = _infer_policy(a)
    tiles = graph.matmul_nodes()
    if not tiles:
        raise ValueError("graph has no matmul nodes")
    gemms = {t.layer for t in tiles}
    if len(gemms) > 1:
        raise ValueError(
            f"graph spans {len(gemms)} GEMMs ({sorted(gemms)[:3]}...); "
            "execute_graph_jax runs single-GEMM graphs — lower each "
            "layer GEMM separately")
    m_total = max(t.tile.m0 + t.tile.m for t in tiles)
    n_total = max(t.tile.n0 + t.tile.n for t in tiles)

    acc_ep = Epilogue(out_dtype=policy.accum_dtype)   # exact accumulators
    handles = {
        node.nid: engine.dispatch(            # asyncMatMul, program order
            node.task, a[node.tile.m0:node.tile.m0 + node.tile.m, :],
            b[:, node.tile.n0:node.tile.n0 + node.tile.n], epilogue=acc_ep)
        for node in graph.topo_order() if node.kind == "matmul"}
    # (memory nodes are simulation-only: nothing to execute.)
    out = None
    for ep, dep_tiles, (m_lo, m_hi, n_lo, n_hi) in \
            _epilogue_regions(graph, policy, n_total):
        region = jnp.zeros((m_hi - m_lo, n_hi - n_lo), policy.accum_dtype)
        for t in dep_tiles:
            acc = engine.wait(handles[t.nid])         # checkMatmul
            region = region.at[
                t.tile.m0 - m_lo:t.tile.m0 - m_lo + t.tile.m,
                t.tile.n0 - n_lo:t.tile.n0 - n_lo + t.tile.n].set(acc)
        part = apply_epilogue(
            region, ep, _slice_operands(operands, ep, m_lo, m_hi - m_lo,
                                        n_lo, n_hi - n_lo))
        out = _place_region(out, part, ep, m_total, n_total, m_lo, m_hi,
                            n_lo)

    if out is None:                           # no epilogue nodes: raw acc
        out = jnp.zeros((m_total, n_total), policy.accum_dtype)
        for t in tiles:
            acc = engine.wait(handles[t.nid])
            out = out.at[t.tile.m0:t.tile.m0 + t.tile.m,
                         t.tile.n0:t.tile.n0 + t.tile.n].set(acc)
        out = out.astype(policy.output_dtype)
    return out


def apply_graph_epilogues(graph: TaskGraph, acc: jax.Array, *,
                          operands: EpilogueOperands = NO_OPERANDS,
                          in_dtype=None) -> jax.Array:
    """Finish a single-GEMM graph from a *precomputed* full accumulator.

    The cluster execution path (``backend.get("sharded")``) computes the
    accumulator with one ``shard_map`` over the partition instead of
    per-tile dispatches; this walks the same vector nodes
    ``execute_graph_jax`` would and applies their epilogues to the same
    regions, so both routes produce identical outputs.
    """
    policy = _infer_policy(jnp.zeros((), in_dtype)) if in_dtype is not None \
        else _infer_policy(acc)
    tiles = graph.matmul_nodes()
    if not tiles:
        raise ValueError("graph has no matmul nodes")
    m_total = max(t.tile.m0 + t.tile.m for t in tiles)
    n_total = max(t.tile.n0 + t.tile.n for t in tiles)
    out = None
    for ep, _, (m_lo, m_hi, n_lo, n_hi) in \
            _epilogue_regions(graph, policy, n_total):
        region = acc[m_lo:m_hi, n_lo:n_hi].astype(policy.accum_dtype)
        part = apply_epilogue(
            region, ep, _slice_operands(operands, ep, m_lo, m_hi - m_lo,
                                        n_lo, n_hi - n_lo))
        out = _place_region(out, part, ep, m_total, n_total, m_lo, m_hi,
                            n_lo)
    if out is None:                           # no epilogue nodes: raw acc
        out = acc.astype(policy.output_dtype)
    return out


def aggregate_cluster_workload(topology, layers: "list[LayerTrace]",
                               price_layer) -> "dict[str, float]":
    """Assemble the cluster workload dict (``simulate_workload`` shape
    plus cluster diagnostics) from any per-layer pricer.

    ``price_layer(layer)`` returns one *instance*'s
    ``{cycles, matrix, vector, ideal, loader_busy, transfers}``; repeat
    weighting and the utilization/seconds/flops tail live here so the
    DES pricer (:func:`cluster_workload`) and the analytical closed
    form agree on the aggregation by construction."""
    tot = {"cycles": 0.0, "matrix": 0.0, "vector": 0.0}
    ideal = 0.0
    loader_busy = 0.0
    transfers = 0
    for layer in layers:
        r = price_layer(layer)
        tot["cycles"] += r["cycles"] * layer.repeat
        tot["matrix"] += r["matrix"] * layer.repeat
        tot["vector"] += r["vector"] * layer.repeat
        ideal += r["ideal"] * layer.repeat
        loader_busy += r["loader_busy"] * layer.repeat
        transfers += r["transfers"]
    tot["seconds"] = tot["cycles"] / topology.unit.freq_hz
    tot["flops"] = sum(l.flops() for l in layers)
    tot["matrix_utilization"] = (
        ideal / (tot["cycles"] * topology.n_units) if tot["cycles"] else 0.0)
    tot["loader_utilization"] = (loader_busy / tot["cycles"]
                                 if tot["cycles"] else 0.0)
    tot["transfers"] = float(transfers)
    return tot


def cluster_workload(topology, layers: "list[LayerTrace]", *,
                     strategy: str = "row-panel",
                     fused: bool = True,
                     granularity: Granularity = Granularity.TILE,
                     affinity: "dict[str, int] | None" = None,
                     weights: "list[float] | None" = None,
                     ) -> "dict[str, float]":
    """``desim_workload`` on a cluster: per layer, partition the graph
    across the topology's units and simulate on the contended machine.
    ``affinity``/``weights`` reach the partitioner (the
    ``unit-affinity`` strategy), so workload pricing shards exactly
    like ``run_graph`` on the same backend."""
    from repro.sim.desim import simulate_cluster, unit_prefix
    from repro.sim.partition import partition_graph

    def price_layer(layer):
        graph, _ = layer_to_graph(topology.unit, layer, fused=fused,
                                  granularity=granularity,
                                  platform=topology.platform)
        part = partition_graph(graph, topology.n_units, strategy,
                               affinity=affinity, weights=weights)
        r = simulate_cluster(part.graph, topology)
        return {
            "cycles": r.cycles,
            "matrix": sum(r.busy(unit_prefix(i, r.n_units) + "pe_array")
                          for i in range(r.n_units)),
            "vector": sum(r.busy(unit_prefix(i, r.n_units)
                                 + "vector_unit")
                          for i in range(r.n_units)),
            "ideal": r.ideal_matrix_cycles,
            "loader_busy": r.loader_busy,
            "transfers": part.n_transfers,
        }

    return aggregate_cluster_workload(topology, layers, price_layer)


_STEP_GEMM_SUFFIX = re.compile(r"/g\d+$")


def step_label(node_layer: str) -> str:
    """Schedule-step name of a graph node's ``layer`` label — the
    ``LayerTrace.name`` before the per-GEMM ``/g<i>`` suffix
    ``workload_to_graph`` appends."""
    return _STEP_GEMM_SUFFIX.sub("", node_layer)


def step_spans(graph: TaskGraph, result) -> "dict[str, tuple[float, float]]":
    """Per-step ``(start, end)`` cycles of a simulated schedule graph.

    Groups ``result.node_span`` (a :class:`~repro.sim.desim.DESimResult`)
    by :func:`step_label`, so a relaxed-overlap run shows directly which
    steps the DES actually overlapped — the measurement behind the
    cross-step-overlap acceptance pins."""
    out: "dict[str, tuple[float, float]]" = {}
    for node in graph.nodes:
        span = result.node_span.get(node.nid)
        if span is None:
            continue
        key = step_label(node.layer)
        cur = out.get(key)
        out[key] = span if cur is None else (min(cur[0], span[0]),
                                             max(cur[1], span[1]))
    return out


def offset_step_spans(spans: "dict[str, tuple[float, float]]",
                      offset: float) -> "dict[str, tuple[float, float]]":
    """Shift per-step ``(start, end)`` windows by ``offset`` cycles —
    an admission epoch's DES run starts its clock at 0, so the online
    loop adds the epoch's global start before folding the windows into
    the cross-epoch span log."""
    return {k: (s + offset, e + offset) for k, (s, e) in spans.items()}


def gemm_labels(graph: TaskGraph) -> "list[str]":
    """Distinct GEMM labels of a graph, in program order.  One label per
    ``build_gemm_graph`` call — for a ``workload_to_graph`` schedule that
    is ``f"{layer.name}/g{gemm_index}"``."""
    seen: "list[str]" = []
    for n in graph.matmul_nodes():
        if n.layer not in seen:
            seen.append(n.layer)
    return seen


def _subgraph_for_gemm(graph: TaskGraph, label: str) -> TaskGraph:
    """Extract one GEMM from a schedule graph as a standalone single-GEMM
    graph (nids remapped, cross-layer scheduling deps dropped).

    Epilogue-carrying vector nodes come along when all their matrix deps
    belong to the GEMM; LAYER-granularity epilogues spanning several
    GEMMs cannot be executed per-GEMM and are left behind (the caller
    gets raw accumulator outputs for those GEMMs).
    """
    sub = TaskGraph()
    remap: "dict[int, int]" = {}
    for node in graph.nodes:
        if node.kind == "matmul" and node.layer == label:
            remap[node.nid] = sub.add(
                "matmul", node.name, layer=node.layer, unit=node.unit,
                task=node.task, tile=node.tile).nid
        elif node.kind == "vector" and node.epilogue is not None:
            mdeps = [t.nid for t in matmul_dep_tiles(graph, node)]
            if mdeps and all(d in remap for d in mdeps):
                sub.add("vector", node.name,
                        deps=tuple(remap[d] for d in mdeps),
                        layer=node.layer, unit=node.unit,
                        vector_ops=dict(node.vector_ops),
                        epilogue=node.epilogue)
    return sub


def iter_gemm_operands(graph: TaskGraph, operands: "dict[str, object]"):
    """Validate + normalise a ``{gemm label: operands}`` dict against a
    schedule graph; yields ``(label, a, b, epilogue_operands)`` in
    schedule order.  Accepted per-GEMM forms: an ``(a, b)`` tuple, an
    ``(a, b, EpilogueOperands)`` triple, or any object with ``.a``/
    ``.b`` (and optionally ``.epilogue``) attributes such as
    ``repro.backend.MatMulOperands``.  GEMMs without operands are
    skipped (a schedule may be only partially concrete)."""
    labels = gemm_labels(graph)
    unknown = set(operands) - set(labels)
    if unknown:
        raise KeyError(
            f"operands for unknown GEMM labels {sorted(unknown)[:4]}; "
            f"graph has {labels[:4]}...")
    for label in labels:
        ops = operands.get(label)
        if ops is None:
            continue
        if isinstance(ops, (tuple, list)):
            a, b = ops[0], ops[1]
            eops = ops[2] if len(ops) > 2 else NO_OPERANDS
        else:
            a, b = ops.a, ops.b
            eops = getattr(ops, "epilogue", NO_OPERANDS)
        yield label, a, b, eops


def execute_workload_jax(graph: TaskGraph, operands: "dict[str, object]", *,
                         engine: Optional[AsyncMatmulEngine] = None,
                         ) -> "dict[str, jax.Array]":
    """Execute a multi-GEMM schedule TaskGraph on real arrays.

    ``operands`` maps a GEMM label (see :func:`gemm_labels`) to its
    arrays (the forms :func:`iter_gemm_operands` accepts).  Each GEMM is
    executed through :func:`execute_graph_jax` in schedule order.
    Returns ``{label: output array}``.
    """
    engine = engine or AsyncMatmulEngine()
    outs: "dict[str, jax.Array]" = {}
    for label, a, b, eops in iter_gemm_operands(graph, operands):
        outs[label] = execute_graph_jax(
            _subgraph_for_gemm(graph, label), a, b, operands=eops,
            engine=engine)
    return outs


# ---------------------------------------------------------------------------
# Epilogue -> abstract Saturn costs, so one graph carries both payloads.
# ---------------------------------------------------------------------------

def epilogue_vector_ops(ep: Epilogue, m: int, n: int) -> "dict[str, float]":
    """First-order Saturn cost of applying ``ep`` to an (m, n) tile —
    lets ``build_gemm_graph`` attach both the JAX payload and the sim
    cost to the same vector nodes."""
    elems = float(m * n)
    ops: "dict[str, float]" = {}

    def add(op, n_el):
        ops[op] = ops.get(op, 0.0) + n_el

    if ep.has_scale_a or ep.has_scale_b:
        add("dequant", elems)
    if ep.bias_type != BiasType.ZERO:
        add("bias", elems)
    if ep.softcap:
        add("softcap", elems)
    act_elems = elems / 2 if ep.glu else elems
    if ep.activation != "none":
        add(ep.activation if ep.activation in VECTOR_OP_INSTRS else
            "eltwise_misc", act_elems)
    if ep.glu:
        add("glu_mul", elems / 2)
    if ep.has_residual:
        add("residual", act_elems if ep.glu else elems)
    if not ops:
        add("copy", elems)
    return ops

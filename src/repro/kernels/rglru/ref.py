"""lax.scan oracle for the RG-LRU recurrence (also the decode step)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_ref(log_a, x, initial_state=None):
    """log_a, x: (B, T, C) -> (h_seq (B, T, C), final_state (B, C))."""
    b, t, c = x.shape
    if initial_state is None:
        initial_state = jnp.zeros((b, c), jnp.float32)

    def step(h, inp):
        la_t, x_t = inp
        a_t = jnp.exp(la_t)
        beta = jnp.sqrt(-jnp.expm1(2.0 * la_t))
        h = a_t * h + beta * x_t
        return h, h

    xs = (jnp.moveaxis(log_a.astype(jnp.float32), 1, 0),
          jnp.moveaxis(x.astype(jnp.float32), 1, 0))
    final, hs = jax.lax.scan(step, initial_state, xs)
    return jnp.moveaxis(hs, 0, 1).astype(x.dtype), final


def rglru_decode_step(state, log_a, x):
    """One-token step: state (B, C), log_a/x (B, C) -> (out, new_state)."""
    a = jnp.exp(log_a.astype(jnp.float32))
    beta = jnp.sqrt(-jnp.expm1(2.0 * log_a.astype(jnp.float32)))
    new = a * state + beta * x.astype(jnp.float32)
    return new.astype(x.dtype), new

"""Batched serving engine on the async programming model.

The paper's asyncMatMul/checkMatmul contract shows up twice here:

* per step — every projection is a ``cute_matmul`` with fused epilogue;
* across requests — ``ServingEngine`` dispatches prefill work through
  ``AsyncMatmulEngine`` handles so a continuous-batching outer loop can
  overlap host-side scheduling with device compute (dispatch returns
  immediately; ``checkMatmul``-style forcing happens at collection).

``generate`` is the synchronous core: prefill the prompt batch, then a
``lax.scan`` decode loop with greedy/temperature sampling.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.base import ArchConfig, family_module


@dataclasses.dataclass
class GenerateResult:
    tokens: jax.Array          # (B, n_new)
    logits_last: jax.Array     # (B, V)
    steps: int


def make_prefill(cfg: ArchConfig):
    mod = family_module(cfg)

    def prefill_step(params, batch, cache):
        return mod.prefill(cfg, params, batch, cache)
    return prefill_step


def make_decode(cfg: ArchConfig):
    mod = family_module(cfg)

    def decode_step(params, tokens, cache, pos):
        return mod.decode_step(cfg, params, tokens, cache, pos)
    return decode_step


def sample(logits, key, temperature: float = 0.0):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature,
                                  axis=-1).astype(jnp.int32)


def generate(cfg: ArchConfig, params, batch, *, max_new_tokens: int,
             temperature: float = 0.0, key=None,
             cache_len: Optional[int] = None) -> GenerateResult:
    """Prefill + scan-decode.  batch["tokens"]: (B, S_prompt)."""
    mod = family_module(cfg)
    b, s = batch["tokens"].shape
    cache_len = cache_len or (s + max_new_tokens)
    key = key if key is not None else jax.random.PRNGKey(0)

    cache = mod.init_cache(cfg, b, cache_len)
    logits, cache = mod.prefill(cfg, params, batch, cache)
    first = sample(logits, key, temperature)

    def body(carry, step_key):
        tok, cache, pos = carry
        logits, cache = mod.decode_step(cfg, params, tok[:, None], cache,
                                        pos)
        nxt = sample(logits, step_key, temperature)
        return (nxt, cache, pos + 1), (nxt, logits)

    keys = jax.random.split(key, max_new_tokens - 1) \
        if max_new_tokens > 1 else jnp.zeros((0, 2), jnp.uint32)
    (last, cache, _), (toks, logit_seq) = jax.lax.scan(
        body, (first, cache, jnp.int32(s)), keys)
    tokens = jnp.concatenate([first[:, None], jnp.moveaxis(toks, 0, 1)],
                             axis=1)
    logits_last = (logit_seq[-1] if max_new_tokens > 1 else logits)
    return GenerateResult(tokens=tokens, logits_last=logits_last,
                          steps=max_new_tokens)


class ServingEngine:
    """Continuous-batching façade with async prefill dispatch."""

    def __init__(self, cfg: ArchConfig, params, max_batch: int = 8,
                 cache_len: int = 512):
        from repro.core.engine import AsyncMatmulEngine
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.async_engine = AsyncMatmulEngine()
        self._queue: list = []

    def submit(self, tokens) -> int:
        """Queue a request; returns a request id (asyncMatMul-style)."""
        self._queue.append(jnp.asarray(tokens))
        return len(self._queue) - 1

    def run(self, max_new_tokens: int = 32, temperature: float = 0.0):
        """Drain the queue in padded batches; returns list of token arrays."""
        out = []
        while self._queue:
            chunk, self._queue = (self._queue[: self.max_batch],
                                  self._queue[self.max_batch:])
            s = max(int(t.shape[-1]) for t in chunk)
            toks = jnp.stack([jnp.pad(t, (s - t.shape[-1], 0)) for t in chunk])
            batch = {"tokens": toks}
            if self.cfg.encdec is not None:
                batch["audio_embeds"] = jnp.zeros(
                    (toks.shape[0], self.cfg.encdec.n_audio_ctx,
                     self.cfg.d_model), jnp.float32)
            if self.cfg.vision_prefix:
                batch["vision_embeds"] = jnp.zeros(
                    (toks.shape[0], self.cfg.vision_prefix,
                     self.cfg.d_model), jnp.float32)
            res = generate(self.cfg, self.params, batch,
                           max_new_tokens=max_new_tokens,
                           temperature=temperature,
                           cache_len=self.cache_len)
            out.extend(list(res.tokens))
        return out

"""Chunked RWKV-6 (Finch) WKV kernel — data-dependent per-channel decay.

The recurrence (per head, state S ∈ R^{C×C}):

    o_t = r_t · (S_{t-1} + diag(u) k_tᵀ v_t)
    S_t = diag(w_t) · S_{t-1} + k_tᵀ v_t,     w_t = exp(lw_t), lw_t ≤ 0

TPU adaptation (DESIGN.md §4): the element-wise recurrence itself has no
matmul for the paper's PE array — but the *chunked* reformulation turns
it into small dense products (inter-chunk state contribution ``q̃ @ S``
and the state update ``K̃ᵀ @ V`` hit the MXU), with the remaining
intra-chunk pairwise-decay term on the VPU.  That is the paper's
matrix/vector split applied inside a single operator.

Numerics: everything is kept in log space with non-positive exponents —
``exp(la_{t-1} - la_s)`` for s < t and ``exp(la_L - la_s)`` are both ≤ 1
because cumulative log-decay is non-increasing.  The intra-chunk term is
computed with an explicit (L, L, C) pairwise tensor, which is exact and
overflow-free (a production kernel would use the GLA two-level split;
with L = chunk 32–64 and C = 64 the tensor is ≤ 1 MiB of VMEM).

Grid: (B·H, T/L) — chunk axis sequential, state carried in VMEM scratch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def rwkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_ref, *,
                 n_chunks: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)      # (L, C)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)    # (L, C), log decay <= 0
    u = u_ref[0].astype(jnp.float32)      # (C,)
    L = r.shape[0]

    la = jnp.cumsum(lw, axis=0)           # inclusive prefix log-decay
    la_prev = la - lw                     # la_{t-1} (la_0 = 0)

    # Inter-chunk: r_t ⊙ exp(la_{t-1}) @ S_0          (MXU)
    q_t = r * jnp.exp(la_prev)
    o = jnp.dot(q_t, s_ref[...], preferred_element_type=jnp.float32)

    # Intra-chunk: P[t,s] = Σ_c r_tc k_sc exp(la_{t-1,c} - la_{s,c}), s<t.
    diff = la_prev[:, None, :] - la[None, :, :]        # (L, L, C), <=0 for s<t
    mask = (jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
            > jax.lax.broadcasted_iota(jnp.int32, (L, L), 1))
    pair = r[:, None, :] * k[None, :, :] * jnp.exp(
        jnp.where(mask[..., None], diff, -1e30))
    p = jnp.sum(pair, axis=-1)                         # (L, L)
    o += jnp.dot(p, v, preferred_element_type=jnp.float32)

    # Bonus diagonal: ((r_t ⊙ u) · k_t) v_t            (VPU)
    o += jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True) * v
    o_ref[0] = o.astype(o_ref.dtype)

    # State update: S_L = diag(exp(la_L)) S_0 + (K ⊙ exp(la_L - la_s))ᵀ V.
    la_last = la[-1]                                   # (C,)
    k_scaled = k * jnp.exp(la_last[None, :] - la)      # <= 1 factors
    s_ref[...] = (jnp.exp(la_last)[:, None] * s_ref[...]
                  + jnp.dot(k_scaled.T, v, preferred_element_type=jnp.float32))

"""Benchmark harness — one function per paper table/figure + the TPU
roofline report.  Prints ``name,us_per_call,derived`` CSV rows
(us_per_call = wall time of the benchmark computation itself; derived =
the headline metric that the corresponding paper artifact reports).

Run:  PYTHONPATH=src python -m benchmarks.run [--only NAME]
"""

from __future__ import annotations

import argparse
import time

ROWS = []

#: Backend-registry name of the modelling engine pricing table6/overlap
#: ("analytical" = closed-form core.simulator, "desim" = discrete-event
#: task-graph runtime, "desim-cluster" = multi-unit contended DES;
#: aliases like "analytic" accepted).  Set by --engine.
ENGINE = "analytical"

#: Cluster width for the cluster bench and (when the selected engine
#: supports it) for the workload pricer.  Set by --units.
UNITS = 1

#: True when --units was given explicitly (the serving bench defaults
#: its cluster point to 2 units otherwise).
UNITS_SET = False

#: Serving batching policies the serving bench compares; --policy
#: restricts the sweep to one of them (or "auto").
POLICY = None

#: True when --tuned was given: serving plans resolve through the
#: per-platform tuning cache (``repro.backend.get_tuned`` dispatch)
#: instead of the untuned defaults.
TUNED = False


def require_units_support(backend_name: str, units: int) -> None:
    """Refuse a multi-unit bench row on a single-unit backend.  A bench
    that quietly prices ``units=1`` while the row is labelled ``u2``
    records a wrong baseline that every later run is then gated
    against — so this is a hard error, not a skip."""
    from repro import backend
    if units != 1 and not backend.get(backend_name).supports_units:
        raise ValueError(
            f"bench row wants units={units} but backend "
            f"{backend_name!r} models a single matrix unit; use a "
            "cluster-aware backend ('desim-cluster', 'analytical') or "
            "drop the row — refusing to silently record units=1")


def workload_sim():
    """The model-level simulator the --engine registry lookup selects
    (same signature as ``core.simulator.simulate_workload``)."""
    from repro import backend
    require_units_support(ENGINE, UNITS)
    eng = backend.get(ENGINE)
    if eng.supports_units:
        # pin the cluster width to --units (cluster backends default to
        # units=2 otherwise)
        eng = backend.get(ENGINE, units=UNITS)

    def run(unit, layers, *, fused=True):
        return eng.run_workload(layers, unit=unit, fused=fused)
    return run


def emit(name: str, us: float, derived: str):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}", flush=True)


def timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


# ---------------------------------------------------------------------------
# Table 2 / Eq. 1 — throughput of the configurable unit.
# ---------------------------------------------------------------------------

def bench_eq1_throughput():
    from repro.core.config import CASE_STUDY, scaling_sweep
    from repro.core.hardware import TERA
    from repro.core.precision import DataType

    def run():
        rows = []
        for cfg in [CASE_STUDY] + scaling_sweep():
            rows.append((cfg.describe(),
                         cfg.throughput(DataType.INT8) / TERA))
        return rows

    rows, us = timed(run)
    case = rows[0][1]
    emit("eq1_throughput_case_study", us, f"tops_int8={case:.3f}(paper:4.096)")
    lo = min(r[1] for r in rows)
    hi = max(r[1] for r in rows)
    emit("eq1_scaling_envelope", us, f"tops_range={lo:.2f}..{hi:.1f}"
         f"(paper:0.5..32)")


# ---------------------------------------------------------------------------
# Fig. 6 — GEMM utilization across the four CPU platforms (2 TOPS unit).
# ---------------------------------------------------------------------------

def bench_fig6_platforms():
    from repro.core.config import PLATFORM_2TOPS
    from repro.core.hardware import PLATFORMS
    from repro.core.simulator import simulate_gemm
    from repro.core.task import MatMulTask

    def run():
        out = {}
        for name, platform in PLATFORMS.items():
            utils = []
            for k in (256, 512, 1024, 2048, 4096, 8192):
                r = simulate_gemm(PLATFORM_2TOPS,
                                  MatMulTask(m=512, n=512, k=k), platform)
                utils.append(r.utilization)
            out[name] = min(utils)
        return out

    out, us = timed(run)
    worst = min(out.values())
    detail = " ".join(f"{k}={v:.3f}" for k, v in out.items())
    emit("fig6_gemm_util_4platforms", us,
         f"min_util={worst:.3f}(paper:>0.90) {detail}")


# ---------------------------------------------------------------------------
# Fig. 7 — utilization across compute/bandwidth scales, Eq.2-sized.
# ---------------------------------------------------------------------------

def bench_fig7_scaling():
    from repro.core import constraint
    from repro.core.config import MatrixUnitConfig
    from repro.core.hardware import GIGA, SHUTTLE
    from repro.core.simulator import simulate_gemm
    from repro.core.task import MatMulTask

    #: paper-style points — four bandwidth settings, each with a peak
    #: sized to the balance the paper's Fig. 7 shows (~0.8 band with the
    #: printed-Eq.2 64x64 scratchpad): (PE, K_pe bits, bandwidth GB/s).
    points = [((2, 2), 256, 8), ((2, 2), 512, 16), ((4, 4), 256, 32),
              ((4, 4), 512, 64),
              ((4, 4), 512, 48)]     # the Table-2 case study (starved)

    def run():
        paper_band, ours_band = [], []
        for (m, n), kb, bw in points:
            base = MatrixUnitConfig(m_pe=m, n_pe=n, k_pe_bits=kb,
                                    bandwidth=bw * GIGA)
            task = MatMulTask(m=512, n=512, k=4096)
            # Paper's printed Eq.2 keeps the 64x64 scratchpad.
            paper_band.append(simulate_gemm(base, task, SHUTTLE).utilization)
            # Saturating direction (beyond-paper): Eq.2 solved for >=100%.
            ms, ns = constraint.solve_scratchpad(base)
            sat = base.with_(m_scp=ms, n_scp=ns)
            ours_band.append(simulate_gemm(sat, task, SHUTTLE).utilization)
        return paper_band, ours_band

    (paper_band, ours_band), us = timed(run)
    emit("fig7_scaling_paper_eq2", us,
         "util=" + "/".join(f"{u:.2f}" for u in paper_band)
         + "(paper:~0.80)")
    emit("fig7_scaling_saturating_eq2", us,
         "util=" + "/".join(f"{u:.2f}" for u in ours_band)
         + "(beyond-paper:>0.9)")


# ---------------------------------------------------------------------------
# Fig. 8 — large-GEMM throughput vs the commercial baselines.
# ---------------------------------------------------------------------------

def bench_fig8_gemm():
    from repro.core.config import CASE_STUDY
    from repro.core.hardware import BASELINES, SHUTTLE, TERA
    from repro.core.simulator import (LayerTrace, baseline_layer_seconds,
                                      simulate_gemm)
    from repro.core.task import MatMulTask

    def run():
        task = MatMulTask(m=512, n=512, k=4096)
        ours = simulate_gemm(CASE_STUDY, task, SHUTTLE)
        ours_tops = task.flops / ours.seconds(CASE_STUDY.freq_hz) / TERA
        rel = {}
        for name, base in BASELINES.items():
            t = baseline_layer_seconds(base, LayerTrace("g", (task,)))
            rel[name] = task.flops / t / TERA
        return ours_tops, rel

    (ours_tops, rel), us = timed(run)
    detail = " ".join(f"vs_{k}={ours_tops / v:.2f}x" for k, v in rel.items())
    emit("fig8_gemm_vs_baselines", us,
         f"ours={ours_tops:.2f}TOPS {detail}(paper:>1x amx/mma,~1x sme)")


# ---------------------------------------------------------------------------
# Table 6 / Figs. 9–11 — model inference, fused vs unfused vs baselines.
# ---------------------------------------------------------------------------

def bench_table6_models():
    from benchmarks.workloads import WORKLOADS
    from repro.core.config import CASE_STUDY
    from repro.core.hardware import BASELINES
    from repro.core.simulator import baseline_workload_seconds
    simulate_workload = workload_sim()

    paper = {  # Table 6 (R, B, L) rows: (unfused, fused) speedups.
        "resnet50": {"xeon8580": (1.19, 1.57), "ibms1022": (7.16, 8.87),
                     "applem4": (3.82, 5.04)},
        "bert": {"xeon8580": (1.28, 1.57), "ibms1022": (2.72, 3.33),
                 "applem4": (1.72, 2.11)},
        "llama3": {"xeon8580": (1.87, 2.31), "ibms1022": (2.39, 3.08),
                   "applem4": (2.55, 3.16)},
    }

    for wname, build in WORKLOADS.items():
        layers = build()
        t0 = time.perf_counter()
        fused = simulate_workload(CASE_STUDY, layers, fused=True)["seconds"]
        unfused = simulate_workload(CASE_STUDY, layers,
                                    fused=False)["seconds"]
        us = (time.perf_counter() - t0) * 1e6
        for bname, base in BASELINES.items():
            tb = baseline_workload_seconds(base, layers, workload=wname)
            tb_raw = baseline_workload_seconds(base, layers)
            su_u, su_f = tb / unfused, tb / fused
            pu, pf = paper[wname][bname]
            emit(f"table6_{wname}_vs_{bname}", us,
                 f"unfused={su_u:.2f}x fused={su_f:.2f}x"
                 f"(paper:{pu:.2f}/{pf:.2f}) raw_hw={tb_raw / fused:.2f}x")
        emit(f"table6_{wname}_fusion_gain", us,
             f"fused_over_unfused={unfused / fused:.2f}x"
             f"(paper_implied:{paper[wname]['xeon8580'][1] / paper[wname]['xeon8580'][0]:.2f}x)")


# ---------------------------------------------------------------------------
# §1 overlap-contribution claim (66.7/50.9/33.6 % of gain vs Xeon).
# ---------------------------------------------------------------------------

def bench_overlap_contribution():
    from benchmarks.workloads import WORKLOADS
    from repro.core.config import CASE_STUDY
    from repro.core.hardware import XEON_8580
    from repro.core.simulator import baseline_workload_seconds
    simulate_workload = workload_sim()

    paper = {"resnet50": 66.7, "bert": 50.9, "llama3": 33.6}
    for wname, build in WORKLOADS.items():
        layers = build()
        t0 = time.perf_counter()
        fused = simulate_workload(CASE_STUDY, layers, fused=True)["seconds"]
        unfused = simulate_workload(CASE_STUDY, layers,
                                    fused=False)["seconds"]
        tb = baseline_workload_seconds(XEON_8580, layers, workload=wname)
        us = (time.perf_counter() - t0) * 1e6
        su_f, su_u = tb / fused, tb / unfused
        contrib = 100.0 * (su_f - su_u) / max(su_f - 1.0, 1e-9)
        emit(f"overlap_contribution_{wname}", us,
             f"pct_of_gain={contrib:.1f}(paper:{paper[wname]:.1f})")


# ---------------------------------------------------------------------------
# Discrete-event task-graph runtime (repro.sim) — cross-check + claims.
# ---------------------------------------------------------------------------

def bench_desim():
    from benchmarks.workloads import llama3_1b_layers
    from repro.core.config import CASE_STUDY, PLATFORM_2TOPS
    from repro.core.hardware import BOOM, KUNMINGHU, PLATFORMS
    from repro.core.simulator import simulate_gemm, simulate_workload
    from repro.core.task import MatMulTask
    from repro.sim.lower import desim_gemm, desim_workload, exposed_dispatch

    # ≥90% matrix-unit utilization for a large int8 GEMM, all 4 platforms,
    # now derived from per-resource timelines instead of a closed form.
    task = MatMulTask(m=512, n=512, k=8192)

    def run_util():
        out = {}
        for name, p in PLATFORMS.items():
            r = desim_gemm(PLATFORM_2TOPS, task, p)
            a = simulate_gemm(PLATFORM_2TOPS, task, p)
            out[name] = (r.matrix_utilization, r.cycles / a.cycles)
        return out

    out, us = timed(run_util)
    worst = min(u for u, _ in out.values())
    drift = max(abs(rel - 1.0) for _, rel in out.values())
    emit("desim_gemm_util_4platforms", us,
         f"min_util={worst:.3f}(paper:>0.90) max_vs_analytic={drift:.1%}")

    # Dispatch-queue backpressure: CSR mailbox (Kunminghu) vs RoCC (BOOM)
    # on a dispatch-dominated tiny-tile stream (paper Table 3 regime).
    tiny_unit = PLATFORM_2TOPS.with_(m_scp=16, n_scp=16)
    tiny = MatMulTask(m=128, n=128, k=32)
    (csr, rocc), us = timed(lambda: (
        exposed_dispatch(tiny_unit, tiny, KUNMINGHU),
        exposed_dispatch(tiny_unit, tiny, BOOM)))
    emit("desim_exposed_dispatch_csr_vs_rocc", us,
         f"csr={csr:.0f}cyc rocc={rocc:.0f}cyc ratio={csr / max(rocc, 1):.1f}x")

    # ≥30% overlap-attributed speedup, fused vs unfused TaskGraph on the
    # Llama-style stack, cross-checked against the analytical engine.
    def run_overlap():
        layers = llama3_1b_layers(seq=1024)
        f = desim_workload(CASE_STUDY, layers, fused=True)
        u = desim_workload(CASE_STUDY, layers, fused=False)
        af = simulate_workload(CASE_STUDY, layers, fused=True)
        return u["cycles"] / f["cycles"], f["cycles"] / af["cycles"], \
            f["matrix_utilization"]

    (gain, rel, util), us = timed(run_overlap)
    emit("desim_llama_overlap_gain", us,
         f"fused_over_unfused={gain:.2f}x(paper:>1.30) "
         f"vs_analytic={rel:.3f} matrix_util={util:.3f}")


# ---------------------------------------------------------------------------
# Cluster scaling (repro.sim cluster topology + desim-cluster backend).
# ---------------------------------------------------------------------------

def bench_cluster():
    """Weak scaling on the paper GEMM regime (512 rows × 512 × 8192 per
    unit, int8) across 1..max(UNITS, 4) matrix units sharing the memory
    loader, plus a fixed-total-bandwidth sweep that exposes where the
    shared loader saturates."""
    from repro.core.config import PLATFORM_2TOPS
    from repro.core.hardware import GIGA, SHUTTLE
    from repro.core.task import MatMulTask
    from repro.sim import (ClusterTopology, build_gemm_graph,
                           partition_graph, simulate_cluster)

    unit = PLATFORM_2TOPS
    sweep = sorted({1, 2, 4, max(UNITS, 1)})

    def weak(n_units, total_bandwidth=None):
        g, _ = build_gemm_graph(
            MatMulTask(m=512 * n_units, n=512, k=8192), unit.m_scp,
            unit.n_scp)
        part = partition_graph(g, n_units, "row-panel")
        topo = ClusterTopology(n_units=n_units, unit=unit,
                               platform=SHUTTLE,
                               total_bandwidth=total_bandwidth)
        return simulate_cluster(part.graph, topo)

    base = None
    for n in sweep:
        r, us = timed(lambda n=n: weak(n))
        base = base if base is not None else r.cycles
        emit(f"cluster_weak_u{n}", us,
             f"agg_util={r.aggregate_matrix_utilization:.3f}(goal:>0.85) "
             f"loader_util={r.loader_utilization:.3f} "
             f"contention={r.loader_contention():.2f} "
             f"eff={base / r.cycles:.3f}")

    # Strong bandwidth pressure: the pool stays at one unit's channel.
    for n in sweep:
        r, us = timed(lambda n=n: weak(n, total_bandwidth=unit.bandwidth))
        emit(f"cluster_weak_fixedbw_u{n}", us,
             f"agg_util={r.aggregate_matrix_utilization:.3f} "
             f"loader_util={r.loader_utilization:.3f} "
             f"(shared {unit.bandwidth / GIGA:.0f} GB/s pool)")


# ---------------------------------------------------------------------------
# Serving scheduler: batching policies priced on cluster timelines.
# ---------------------------------------------------------------------------

def serving_queue(n_requests: int = 6, max_batch: int = 2,
                  arrival_gap: float = 0.0):
    """The canonical serving bench queue: a yi-6b-reduced engine with
    ``n_requests`` prompts of 64 + 32·i tokens (deterministic key-0
    contents), shared by this harness and ``benchmarks/record.py`` so
    the tracked ``BENCH_serving.json`` prices exactly the workload the
    CSV bench prints."""
    import jax
    from repro.configs.registry import get_config
    from repro.serving.engine import ServingEngine

    cfg = get_config("yi-6b", reduced=True)
    eng = ServingEngine(cfg, params=None, max_batch=max_batch,
                        cache_len=256)
    key = jax.random.PRNGKey(0)
    for i in range(n_requests):
        key, sub = jax.random.split(key)
        eng.submit(jax.random.randint(sub, (64 + 32 * i,), 0,
                                      cfg.vocab_size),
                   arrival_time=arrival_gap * i)
    return cfg, eng


def concrete_policies() -> "list[str]":
    """Registered non-meta batching policies — the sweepable set
    (``auto-slo`` wraps the sweep itself and is benched separately by
    the online loop)."""
    from repro.serving.scheduler import POLICIES
    return [n for n, c in POLICIES.items() if not getattr(c, "meta", False)]


def bench_serving():
    """TTFT p50/p99 + inter-token latency + aggregate matrix utilization
    per batching policy on a Llama-style config (yi-6b reduced, 6
    requests), priced by the contention-aware analytical closed form —
    single unit and the ``--units`` cluster (default 2), with both
    chained and relaxed-overlap lowerings on the cluster point."""
    from repro.serving.scheduler import schedule_metrics

    cfg, eng = serving_queue()
    cluster = UNITS if UNITS_SET else 2
    sweep = (1,) if cluster == 1 else (1, cluster)
    policies = [POLICY] if POLICY else concrete_policies() + ["auto"]
    for pol in policies:
        for u in sweep:
            # chained on one unit (relaxed buys nothing there); both
            # lowerings on the cluster point.  "auto" sweeps internally.
            overlaps = ("chained",) if (u == 1 or pol == "auto") \
                else ("chained", "relaxed")
            for ov in overlaps:
                def run(pol=pol, u=u, ov=ov):
                    sched = eng.plan(max_new_tokens=16, units=u,
                                     policy=pol, overlap=ov, tuned=TUNED)
                    return sched, schedule_metrics(sched, cfg.n_layers,
                                                   "analytical")

                (sched, m), us = timed(run)
                tag = f"serving_{pol}_u{u}" + \
                    ("_relaxed" if ov == "relaxed" else "")
                emit(tag, us,
                     f"policy={sched.policy} "
                     f"overlap={sched.overlap} "
                     f"ttft_p50={m['ttft_p50']:.0f} "
                     f"ttft_p99={m['ttft_p99']:.0f} "
                     f"itl_p50={m['itl_p50']:.0f} "
                     f"agg_matrix_util={m['matrix_utilization']:.3f} "
                     f"makespan={m['makespan']:.0f}")


# ---------------------------------------------------------------------------
# Online closed-loop serving: sustained-load QPS sweep + saturation knee.
# ---------------------------------------------------------------------------

#: the canonical online-bench traffic shape (fixed-seed Poisson over the
#: serving queue's prompt lengths) — shared with ``benchmarks/record.py``
#: so the tracked rows price exactly what this CSV bench prints.
ONLINE_TRAFFIC = dict(n_requests=6, seed=0, prompt_lengths=(64, 96, 128))
ONLINE_ENGINE = dict(max_batch=2, max_new_tokens=8,
                     execute_backend="analytical")


def bench_online():
    """Closed-loop sustained load per policy: offered-QPS sweep (TTFT /
    ITL / goodput curves) plus the saturation sweep locating where each
    policy's goodput collapses (``repro.serving.online``).  Fixed-seed
    Poisson arrivals, analytical epoch execution — deterministic and
    fast enough for CI; ``--policy`` restricts the sweep."""
    from repro.configs.registry import get_config
    from repro.serving.online import find_saturation, qps_sweep

    cfg = get_config("yi-6b", reduced=True)
    policies = ([POLICY] if POLICY and POLICY != "auto"
                else concrete_policies())
    for pol in policies:
        rows, us = timed(lambda pol=pol: qps_sweep(
            cfg, [1e4, 1e5, 1e6], policy=pol,
            **ONLINE_TRAFFIC, **ONLINE_ENGINE))
        for r in rows:
            emit(f"online_{pol}_q{r['offered_qps']:.0e}", us / len(rows),
                 f"ttft_p50={r['ttft_p50']:.0f} "
                 f"ttft_p99={r['ttft_p99']:.0f} "
                 f"itl_p50={r['itl_p50']:.0f} "
                 f"goodput={r['goodput_qps']:.0f}req/s "
                 f"epochs={r['epochs']:.0f} "
                 f"preempt={r['preemptions']:.0f}")
        sat, us = timed(lambda pol=pol: find_saturation(
            cfg, start_qps=1e4, factor=4.0, max_points=6, policy=pol,
            **ONLINE_TRAFFIC, **ONLINE_ENGINE))
        emit(f"online_{pol}_saturation", us,
             f"knee_qps={sat['knee_qps']:.0f} "
             f"peak_goodput={sat['peak_goodput_qps']:.0f}req/s "
             f"saturated={sat['saturated']}")


# ---------------------------------------------------------------------------
# Table 7 — area/power.
# ---------------------------------------------------------------------------

def bench_table7_area():
    from repro.core.area import estimate
    from repro.core.config import CASE_STUDY

    ap, us = timed(lambda: estimate(CASE_STUDY))
    emit("table7_area_power", us,
         f"mm2={ap.total_mm2:.3f}(paper:0.531) W={ap.total_w:.3f}"
         f"(paper:1.506)")
    sat, us2 = timed(lambda: estimate(CASE_STUDY.with_(m_scp=128,
                                                       n_scp=128)))
    emit("table7_area_saturating_variant", us2,
         f"mm2={sat.total_mm2:.3f} (+{sat.total_mm2 - ap.total_mm2:.3f} "
         f"buys >95% util)")


# ---------------------------------------------------------------------------
# Pallas kernel microbenchmark (interpret mode: correctness-grade timing).
# ---------------------------------------------------------------------------

def bench_kernels():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.fusion import Epilogue
    from repro.kernels.matmul.ops import fused_matmul
    from repro.kernels.matmul.ref import fused_matmul_ref

    a = jax.random.normal(jax.random.PRNGKey(0), (256, 512), jnp.bfloat16)
    b = jax.random.normal(jax.random.PRNGKey(1), (512, 512), jnp.bfloat16)
    ep = Epilogue(activation="gelu", out_dtype=jnp.bfloat16)
    out = fused_matmul(a, b, epilogue=ep, block_shape=(128, 128, 128))
    out.block_until_ready()
    t0 = time.perf_counter()
    out = fused_matmul(a, b, epilogue=ep, block_shape=(128, 128, 128))
    out.block_until_ready()
    us = (time.perf_counter() - t0) * 1e6
    ref = fused_matmul_ref(a, b, epilogue=ep)
    r = np.asarray(ref, np.float32)
    err = float(np.abs(np.asarray(out, np.float32) - r).max()
                / (np.abs(r).max() + 1e-9))
    emit("kernel_fused_matmul_interpret", us, f"rel_err={err:.2e}")


# ---------------------------------------------------------------------------
# TPU roofline report (reads the dry-run artifacts).
# ---------------------------------------------------------------------------

def bench_roofline():
    from benchmarks.roofline import pick_hillclimb_cells, summarize
    t0 = time.perf_counter()
    rows = summarize(print_table=False)
    us = (time.perf_counter() - t0) * 1e6
    if not rows:
        emit("roofline_table", us, "no dry-run artifacts (run dryrun --all)")
        return
    emit("roofline_cells", us, f"n={len(rows)}")
    picks = pick_hillclimb_cells(rows)
    for why, r in picks.items():
        emit(f"roofline_{why}", us,
             f"{r['arch']}x{r['shape']} frac={r['frac']:.3f} "
             f"dom={r['dominant']} coll_share={r['coll_share']:.2f}")
    best = max((r for r in rows if r["mesh"] == "single"),
               key=lambda r: r["frac"])
    emit("roofline_best_cell", us,
         f"{best['arch']}x{best['shape']} frac={best['frac']:.3f}")


# ---------------------------------------------------------------------------
# Tuned dispatch: the autotuner's measured end-to-end win.
# ---------------------------------------------------------------------------

#: platforms the tune bench prices (two distinct dispatch models —
#: RoCC in-order and CSR OoO — is the acceptance bar; --only tune with
#: all four is a cache-regeneration sanity sweep, not the default).
TUNE_PLATFORMS = ("shuttle", "kunminghu")


def bench_tune():
    """Tuned vs untuned cluster-DES makespan of the canonical
    Llama-style decode regime per platform, with the epilogue-fusion
    contribution isolated (tuned-unfused / tuned-fused)."""
    from repro.tune.regime import measure_decode_regime

    for plat in TUNE_PLATFORMS:
        m, us = timed(lambda plat=plat: measure_decode_regime(plat))
        emit(f"tune_decode_{plat}", us,
             f"tuned={m['tuned']:.0f} untuned={m['untuned']:.0f} "
             f"tuned_speedup={m['tuned_speedup']:.3f} "
             f"fusion_speedup={m['fusion_speedup']:.3f} "
             f"end_to_end_speedup={m['speedup']:.3f}")


BENCHES = {
    "eq1": bench_eq1_throughput,
    "fig6": bench_fig6_platforms,
    "fig7": bench_fig7_scaling,
    "fig8": bench_fig8_gemm,
    "table6": bench_table6_models,
    "overlap": bench_overlap_contribution,
    "desim": bench_desim,
    "cluster": bench_cluster,
    "serving": bench_serving,
    "online": bench_online,
    "table7": bench_table7_area,
    "kernels": bench_kernels,
    "roofline": bench_roofline,
    "tune": bench_tune,
}


def main() -> None:
    global ENGINE, UNITS, UNITS_SET, POLICY, TUNED
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, metavar="NAME[,NAME...]",
                    help="run only the named bench(es), comma-separated; "
                         "an unknown name errors with the known list")
    ap.add_argument("--engine", default="analytical",
                    help="repro.backend registry name of the modelling "
                         "engine for table6/overlap (aliases accepted): "
                         "'analytical' (closed form), 'desim' (the "
                         "discrete-event TaskGraph runtime) or "
                         "'desim-cluster' (multi-unit contended DES; "
                         "combine with --units)")
    ap.add_argument("--units", type=int, default=None,
                    help="matrix units for the cluster bench sweep, the "
                         "serving bench's cluster point (default 2) and, "
                         "when --engine supports it (desim-cluster, "
                         "analytical), the workload pricer")
    ap.add_argument("--policy", default=None,
                    choices=("full-prefill", "chunked-prefill",
                             "decode-priority", "auto"),
                    help="restrict the serving/online benches to one "
                         "batching policy (default: sweep all concrete "
                         "policies + auto)")
    ap.add_argument("--tuned", action="store_true",
                    help="resolve serving plans through the per-platform "
                         "tuning cache (repro.backend.get_tuned dispatch) "
                         "instead of the untuned defaults")
    args = ap.parse_args()
    only = None
    if args.only:
        only = [s.strip() for s in args.only.split(",") if s.strip()]
        unknown = sorted(set(only) - set(BENCHES))
        if unknown:
            ap.error(f"unknown bench name(s): {', '.join(unknown)}; "
                     f"known benches: {', '.join(BENCHES)}")
    from repro import backend
    try:
        ENGINE = backend.resolve(args.engine)
    except KeyError as e:
        ap.error(str(e))
    if args.units is not None and args.units < 1:
        ap.error(f"--units must be >= 1, got {args.units}")
    UNITS_SET = args.units is not None
    UNITS = args.units if UNITS_SET else 1
    POLICY = args.policy
    TUNED = args.tuned
    probe = backend.get(ENGINE)
    if UNITS != 1 and not probe.supports_units and only != ["cluster"]:
        ap.error(f"--units {UNITS} needs a cluster-aware --engine "
                 "('desim-cluster'), or --only cluster")
    if not probe.models_time:
        ap.error(f"--engine {ENGINE!r} executes numbers but does not "
                 "model time; pick one of "
                 f"{[n for n in backend.available() if backend.get(n).models_time]}")
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if only is not None and name not in only:
            continue
        fn()


if __name__ == "__main__":
    main()

"""Layer-shape traces for the paper's three evaluation models (§5.1):
ResNet-50 v1.5, BERT-base (seq 384), Llama3.2-1B (SmoothQuant-O1 int8).

Convolutions are expressed as im2col GEMMs (M = OH·OW, N = C_out,
K = C_in·kh·kw) — the mapping the matrix unit executes.  Vector-op
element counts drive the Saturn model: (de)quantization around every
int8 GEMM, activations, normalisation, softmax; the SiLU/softmax divide
cost is what makes Llama3's Gate/Up and Score ops expensive on Saturn
(paper §5.4).
"""

from __future__ import annotations

from repro.core.simulator import LayerTrace
from repro.core.task import BiasType, MatMulTask


def _gemm(m, n, k, bias=BiasType.ROW):
    return MatMulTask(m=m, n=n, k=k, bias_type=bias)


# ---------------------------------------------------------------------------
# ResNet-50 v1.5, batch 1, int8.
# ---------------------------------------------------------------------------

def resnet50_layers() -> "list[LayerTrace]":
    layers = []

    def conv(name, hw, cin, cout, kk, repeat=1, residual=False):
        m, k = hw * hw, cin * kk * kk
        vec = {"quant": m * cout, "dequant": m * cout, "relu": m * cout}
        if residual:
            vec["residual"] = m * cout
        layers.append(LayerTrace(
            name=name, gemms=(_gemm(m, cout, k),), vector_ops=vec,
            intermediate_bytes=4.0 * m * cout, repeat=repeat))

    conv("conv1", 112, 3, 64, 7)
    # Bottleneck stages: (blocks, hw, width, out).
    for stage, (blocks, hw, w, out, cin) in enumerate([
            (3, 56, 64, 256, 64), (4, 28, 128, 512, 256),
            (6, 14, 256, 1024, 512), (3, 7, 512, 2048, 1024)]):
        conv(f"s{stage}_proj", hw, cin, out, 1)          # shortcut proj
        for b in range(blocks):
            c_in = cin if b == 0 else out
            conv(f"s{stage}b{b}_1x1a", hw, c_in, w, 1)
            conv(f"s{stage}b{b}_3x3", hw, w, w, 3)
            conv(f"s{stage}b{b}_1x1b", hw, w, out, 1, residual=True)
    layers.append(LayerTrace(
        "fc", gemms=(_gemm(1, 1000, 2048),),
        vector_ops={"pool": 7 * 7 * 2048, "dequant": 1000},
        intermediate_bytes=4.0 * 2048))
    return layers


# ---------------------------------------------------------------------------
# BERT-base, seq 384, batch 1, int8 (the paper's small-GEMM stress).
# ---------------------------------------------------------------------------

def bert_base_layers(seq: int = 384) -> "list[LayerTrace]":
    d, h, dh, ff = 768, 12, 64, 3072
    per_layer = [
        LayerTrace("qkv", gemms=(_gemm(seq, 3 * d, d),),
                   vector_ops={"quant": seq * 3 * d, "dequant": seq * 3 * d},
                   intermediate_bytes=4.0 * seq * 3 * d),
        LayerTrace("scores", gemms=tuple(_gemm(seq, seq, dh,
                                               bias=BiasType.ZERO)
                                         for _ in range(h)),
                   vector_ops={"softmax": h * seq * seq},
                   intermediate_bytes=4.0 * h * seq * seq),
        LayerTrace("context", gemms=tuple(_gemm(seq, dh, seq,
                                                bias=BiasType.ZERO)
                                          for _ in range(h)),
                   vector_ops={"dequant": seq * d},
                   intermediate_bytes=4.0 * seq * d),
        LayerTrace("out_proj", gemms=(_gemm(seq, d, d),),
                   vector_ops={"layernorm": seq * d, "residual": seq * d,
                               "quant": seq * d},
                   intermediate_bytes=4.0 * seq * d),
        LayerTrace("ffn_in", gemms=(_gemm(seq, ff, d),),
                   vector_ops={"gelu": seq * ff, "quant": seq * ff,
                               "dequant": seq * ff},
                   intermediate_bytes=4.0 * seq * ff),
        LayerTrace("ffn_out", gemms=(_gemm(seq, d, ff),),
                   vector_ops={"layernorm": seq * d, "residual": seq * d,
                               "dequant": seq * d},
                   intermediate_bytes=4.0 * seq * d),
    ]
    return [LayerTrace(l.name, l.gemms, l.vector_ops, l.intermediate_bytes,
                       repeat=12) for l in per_layer]


# ---------------------------------------------------------------------------
# Llama3.2-1B, prefill 512, int8 SmoothQuant-O1.
# ---------------------------------------------------------------------------

def llama3_1b_layers(seq: int = 512) -> "list[LayerTrace]":
    d, hq, hkv, dh, ff, v = 2048, 32, 8, 64, 8192, 128256
    per_layer = [
        LayerTrace("qkv", gemms=(_gemm(seq, (hq + 2 * hkv) * dh, d),),
                   vector_ops={"rmsnorm": seq * d, "rope": seq * hq * dh,
                               "quant": seq * d, "dequant": seq * 3 * d},
                   intermediate_bytes=4.0 * seq * 3 * d),
        LayerTrace("score", gemms=tuple(_gemm(seq, seq, dh,
                                              bias=BiasType.ZERO)
                                        for _ in range(hq)),
                   vector_ops={"softmax": hq * seq * seq},
                   intermediate_bytes=4.0 * hq * seq * seq),
        LayerTrace("context", gemms=tuple(_gemm(seq, dh, seq,
                                                bias=BiasType.ZERO)
                                          for _ in range(hq)),
                   vector_ops={"dequant": seq * d},
                   intermediate_bytes=4.0 * seq * d),
        LayerTrace("o_proj", gemms=(_gemm(seq, d, d, bias=BiasType.ZERO),),
                   vector_ops={"residual": seq * d, "quant": seq * d},
                   intermediate_bytes=4.0 * seq * d),
        # Gate & Up — the SiLU divide makes these vector-heavy (§5.4).
        LayerTrace("gate_up", gemms=(_gemm(seq, 2 * ff, d,
                                           bias=BiasType.ZERO),),
                   vector_ops={"rmsnorm": seq * d, "silu": seq * ff,
                               "glu_mul": seq * ff, "quant": seq * ff,
                               "dequant": seq * 2 * ff},
                   intermediate_bytes=4.0 * seq * 2 * ff),
        LayerTrace("down", gemms=(_gemm(seq, d, ff, bias=BiasType.ZERO),),
                   vector_ops={"residual": seq * d, "dequant": seq * d},
                   intermediate_bytes=4.0 * seq * d),
    ]
    layers = [LayerTrace(l.name, l.gemms, l.vector_ops,
                         l.intermediate_bytes, repeat=16) for l in per_layer]
    layers.append(LayerTrace(
        "lm_head", gemms=(_gemm(1, v, d, bias=BiasType.ZERO),),
        vector_ops={"softmax": v}, intermediate_bytes=4.0 * v))
    return layers


WORKLOADS = {
    "resnet50": resnet50_layers,
    "bert": bert_base_layers,
    "llama3": llama3_1b_layers,
}

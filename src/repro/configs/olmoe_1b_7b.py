"""olmoe-1b-7b [moe]: 16L d=2048 16H d_ff=1024/expert, 64 experts top-8.

QK-norm attention; router keeps raw softmax top-8 weights (no renorm).
vocab 50304.  [arXiv:2409.02060; hf]
"""

from repro.models.base import ArchConfig, MoeConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="transformer",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    qk_norm=True,
    mlp_activation="silu",
    mlp_glu=True,
    moe=MoeConfig(n_experts=64, top_k=8, d_ff_expert=1024,
                  capacity_factor=1.25, renormalize=False),
)


def reduced() -> ArchConfig:
    # capacity_factor = E/top_k: zero dropping, so prefill/decode/forward
    # are exactly consistent in the smoke tests.
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                        head_dim=16, d_ff=64, vocab_size=512, attn_chunk=32,
                        moe=MoeConfig(n_experts=8, top_k=2, d_ff_expert=64,
                                      capacity_factor=4.0,
                                      renormalize=False))

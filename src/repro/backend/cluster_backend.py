"""The cluster discrete-event backend: N matrix units, one shared loader.

``desim-cluster`` is ``desim`` scaled out: ``lower()`` tiles work as
usual, ``sim.partition`` shards the tiles across ``units`` (row-panel /
output-tile / layer-pipeline, with explicit inter-unit transfer nodes),
and ``sim.desim.simulate_cluster`` runs the partitioned graph on a
:class:`~repro.sim.resources.ClusterTopology` — per-unit dispatcher,
scratchpad banks, PE array and vector unit, all contending for one
shared memory loader under a fair-share or FCFS bandwidth-partitioning
policy.  Given concrete operands, the *same* partitioned graph also
executes through the JAX lowering, so numbers come back alongside the
contended timelines (the paper's unified-stack claim, cluster-sized).
"""

from __future__ import annotations

from typing import Callable, Optional

from repro.backend.base import (Backend, ExecResult, GraphOperands,
                                MatMulOperands)
from repro.backend.registry import register
from repro.core.fusion import Epilogue, NO_EPILOGUE
from repro.core.task import MatMulTask
from repro.sim.resources import ClusterTopology


class PartitionedBackend(Backend):
    """Shared plumbing for the cluster backends: a ``units``-wide
    partition strategy and TaskGraph sharding via ``sim.partition``."""

    supports_units = True

    def __init__(self, units: int = 2, strategy: str = "row-panel", **kw):
        from repro.sim.partition import STRATEGIES
        if strategy not in STRATEGIES:
            raise ValueError(f"unknown partition strategy {strategy!r}; "
                             f"one of {STRATEGIES}")
        super().__init__(units=units, **kw)
        self.strategy = strategy

    def partition(self, graph):
        """Shard an (unpartitioned) TaskGraph for this backend's cluster;
        pre-partitioned input (``sim.partition.Partition``) passes
        through."""
        from repro.sim.partition import Partition, partition_graph
        if isinstance(graph, Partition):
            if graph.n_units != self.units:
                raise ValueError(
                    f"graph partitioned for {graph.n_units} unit(s) but "
                    f"backend has units={self.units}")
            return graph
        return partition_graph(graph, self.units, self.strategy)


@register("desim-cluster")
class ClusterDESimBackend(PartitionedBackend):
    """Multi-unit machine model + optional lockstep JAX execution."""

    executes = True
    models_time = True
    matmul_string = "xla"           # numeric half runs through XLA

    def __init__(self, units: int = 2, strategy: str = "row-panel",
                 loader_policy: str = "fair",
                 total_bandwidth: Optional[float] = None,
                 k_stream: bool = True, **kw):
        super().__init__(units=units, strategy=strategy, **kw)
        self.loader_policy = loader_policy
        self.total_bandwidth = total_bandwidth
        self.k_stream = k_stream

    def topology(self, unit=None, platform=None,
                 vector=None) -> ClusterTopology:
        return ClusterTopology(
            n_units=self.units, unit=unit or self.unit,
            platform=platform or self.platform,
            vector=vector or self.vector,
            loader_policy=self.loader_policy,
            total_bandwidth=self.total_bandwidth,
            k_stream=self.k_stream)

    def _stage(self, task: MatMulTask, operands: MatMulOperands,
               epilogue: Epilogue) -> Callable[[], ExecResult]:
        ep = None if epilogue is NO_EPILOGUE else epilogue
        part = self.partition(self.lower(task, epilogue=ep))
        return lambda: self.run_graph(
            part, operands if operands.concrete else None)

    def run_graph(self, graph, operands: GraphOperands = None) -> ExecResult:
        from repro.sim.desim import simulate_cluster
        from repro.sim.lower import execute_graph_jax, execute_workload_jax
        part = self.partition(graph)
        r = simulate_cluster(part.graph, self.topology())
        output, outputs = None, None
        if isinstance(operands, dict):
            outputs = execute_workload_jax(part.graph, operands)
        elif operands is not None and operands.concrete:
            output = execute_graph_jax(part.graph, operands.a, operands.b,
                                       operands=operands.epilogue)
        return ExecResult(
            output=output, outputs=outputs, cycles=r.cycles,
            seconds=r.seconds(),
            utilization=r.aggregate_matrix_utilization, timeline=r,
            detail={
                "utilizations": r.utilizations(),
                "unit_utilizations": r.unit_utilizations(),
                "loader_utilization": r.loader_utilization,
                "loader_contention": r.loader_contention(),
                "partition": {"strategy": part.strategy,
                              "n_units": part.n_units,
                              "transfers": part.n_transfers,
                              "transfer_bytes": part.transfer_bytes},
            })

    def run_workload(self, layers, *, fused=None, unit=None, platform=None,
                     vector=None):
        from repro.sim.lower import cluster_workload
        return cluster_workload(
            self.topology(unit, platform, vector), layers,
            strategy=self.strategy,
            fused=self.fused if fused is None else fused,
            granularity=self.granularity)

"""Deterministic synthetic token pipeline with checkpointable state.

Production shape without a corpus: a counter-seeded generator emits
packed (tokens, labels) batches; state is one integer (the step), so
resuming from a checkpoint replays the exact stream (fault tolerance —
runtime/checkpoint.py stores it).  Host sharding: each data-parallel
host slices its batch rows by ``host_id``; under single-process jit the
full batch is built and GSPMD scatters it.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


@dataclasses.dataclass
class DataState:
    step: int = 0

    def to_dict(self) -> dict:
        return {"step": self.step}

    @classmethod
    def from_dict(cls, d: dict) -> "DataState":
        return cls(step=int(d["step"]))


class SyntheticLM:
    """Zipf-ish synthetic LM stream: next-token = f(current) + noise, so
    models can actually drive loss below entropy (examples/train_lm.py)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.state = DataState()

    def _batch_np(self, step: int):
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 32) | step)
        b = cfg.global_batch // cfg.n_hosts
        # Markov-ish stream: x_{t+1} = (a * x_t + b + noise) % V.
        x = np.empty((b, cfg.seq_len + 1), np.int32)
        x[:, 0] = rng.integers(0, cfg.vocab_size, b)
        noise = (rng.random((b, cfg.seq_len)) < 0.1)
        rand_tok = rng.integers(0, cfg.vocab_size, (b, cfg.seq_len))
        for t in range(cfg.seq_len):
            nxt = (x[:, t] * 31 + 17) % cfg.vocab_size
            x[:, t + 1] = np.where(noise[:, t], rand_tok[:, t], nxt)
        return {"tokens": x[:, :-1], "labels": x[:, 1:]}

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        batch = self._batch_np(self.state.step * self.cfg.n_hosts
                               + self.cfg.host_id)
        self.state.step += 1
        return jax.tree.map(jnp.asarray, batch)

    # -- checkpointable iterator state -----------------------------------
    def state_dict(self) -> dict:
        return self.state.to_dict()

    def load_state_dict(self, d: dict) -> None:
        self.state = DataState.from_dict(d)

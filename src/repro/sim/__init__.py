"""Discrete-event task-graph runtime for the CUTEv2 reproduction.

One ``TaskGraph`` IR (``sim.graph``) drives two consumers:

* ``sim.desim`` — a discrete-event, resource-level simulator (CPU
  dispatcher, memory loader, scratchpad banks, PE array, Saturn vector
  unit) that derives per-resource timelines instead of asserting the
  closed-form ``max(matrix, vec)`` of ``core.simulator``.
* ``sim.lower`` — a lowering that executes the *same* graph through
  ``AsyncMatmulEngine``/``cute_matmul`` on the JAX side, making the
  paper's "unified software stack" literal.

``sim.trace`` exports the simulated timelines as Chrome-trace JSON
(viewable in Perfetto / chrome://tracing).
"""

from repro.sim.graph import (Granularity, Node, TaskGraph,
                             build_gemm_graph)
from repro.sim.resources import (BandwidthResource, ClusterTopology,
                                 UnitSpec)
from repro.sim.desim import (ClusterDESimResult, DESimResult, Machine,
                             build_cluster, simulate_cluster,
                             simulate_graph)
from repro.sim.partition import (Partition, STRATEGIES, partition_graph)
from repro.sim.lower import (OVERLAP_MODES, cluster_workload, desim_gemm,
                             desim_layer, desim_workload,
                             epilogue_vector_ops, execute_graph_jax,
                             execute_workload_jax, exposed_dispatch,
                             gemm_labels, layer_to_graph, schedule_to_graph,
                             step_spans, workload_to_graph)
from repro.sim.trace import chrome_trace, dump_chrome_trace

__all__ = [
    "Granularity", "Node", "TaskGraph", "build_gemm_graph",
    "BandwidthResource", "ClusterTopology", "UnitSpec",
    "ClusterDESimResult", "DESimResult", "Machine", "build_cluster",
    "simulate_cluster", "simulate_graph",
    "Partition", "STRATEGIES", "partition_graph",
    "OVERLAP_MODES", "cluster_workload", "desim_gemm", "desim_layer",
    "desim_workload", "epilogue_vector_ops", "execute_graph_jax",
    "execute_workload_jax", "exposed_dispatch", "gemm_labels",
    "layer_to_graph", "schedule_to_graph", "step_spans",
    "workload_to_graph",
    "chrome_trace", "dump_chrome_trace",
]

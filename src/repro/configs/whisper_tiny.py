"""whisper-tiny [audio]: 4L enc + 4L dec, d=384 6H d_ff=1536 vocab=51865.

Encoder-decoder; the conv frontend is a STUB (``input_specs()`` provides
precomputed frame embeddings (B, 1500, 384)).  Learned positional
embeddings, pre-LN LayerNorm blocks, GELU MLP (no GLU), tied output
embedding.  Vocab padded 51865→51968.  [arXiv:2212.04356]
"""

from repro.models.base import ArchConfig, EncDecConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,                 # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    mlp_activation="gelu",
    mlp_glu=False,
    tie_embeddings=True,
    encdec=EncDecConfig(n_encoder_layers=4, n_audio_ctx=1500),
)


def reduced() -> ArchConfig:
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                        head_dim=16, d_ff=128, vocab_size=512, attn_chunk=32,
                        encdec=EncDecConfig(n_encoder_layers=2,
                                            n_audio_ctx=24,
                                            max_positions=256))
